//! Network-level Pareto fronts: the whole-DNN trade-off curves of the
//! paper's Figs 15-18, emitted for a full network instead of one fusion
//! set.
//!
//! The scalar partitioner (`network::search_network`) answers "what is the
//! best partition under ONE objective"; this example runs the vector-cost
//! front DP (`network::search_network_pareto`) on ResNet-18 — real residual
//! edges, so the DP runs over graph cuts — and prints every non-dominated
//! (latency, energy, capacity, off-chip) partition under a 256 KiB GLB.
//! It then re-runs the scalar DP once per objective and checks that each
//! scalar optimum sits on the front: the front is a strict generalization,
//! one run replaces k scalar sweeps.
//!
//! Run with: `cargo run --release --example network_pareto`

use looptree::arch::Arch;
use looptree::coordinator::Coordinator;
use looptree::mapspace::MapSpaceConfig;
use looptree::network::{self, NetworkSearchSpec};
use looptree::search::SearchSpec;
use looptree::util::table::Table;

fn main() {
    let net = network::resnet18();
    let arch = Arch::generic(256); // 256 KiB GLB
    let pool = Coordinator::new(0);
    // A deliberately coarse per-segment mapspace keeps the demo quick; the
    // objectives and the beam cap are the Pareto-specific knobs.
    let spec = NetworkSearchSpec {
        max_segment_layers: 2,
        search: SearchSpec {
            mapspace: MapSpaceConfig {
                uniform_retention: true,
                tile_sizes: vec![4, 8],
                ..Default::default()
            },
            ..Default::default()
        },
        max_front_per_state: 16,
        ..Default::default()
    };

    let front = network::search_network_pareto(&net, &arch, &spec, &pool)
        .expect("pareto search found no partition");
    let names: Vec<&str> = front.objectives.iter().map(|o| o.name()).collect();
    println!(
        "{}: {} non-dominated partitions over [{}] ({} distinct segment shapes searched, \
         {} per-segment front points memoized)",
        net.name,
        front.points.len(),
        names.join(", "),
        front.distinct_searched,
        front.segment_front_points,
    );
    let mut header: Vec<&str> = vec!["#"];
    header.extend(names.iter().copied());
    header.push("cuts");
    header.push("fits");
    let mut table = Table::new(&header);
    for (i, p) in front.points.iter().enumerate() {
        let mut row = vec![i.to_string()];
        row.extend(p.costs.iter().map(|c| format!("{c:.4e}")));
        row.push(p.cuts.len().to_string());
        row.push(p.all_fit().to_string());
        table.row(&row);
    }
    println!("{}", table.render());

    // Every scalar optimum lies on the front: the front subsumes k scalar
    // sweeps (exact here because the per-segment searches are exhaustive).
    // Integer-count axes compare exactly; the energy axis on a branched
    // graph gets an ulp-scale tolerance, since the scalar lattice DP sums
    // in application order while the front sums in canonical sink order
    // (same policy as the scalar_optima_lie_on_pareto_front test).
    for (axis, &objective) in front.objectives.iter().enumerate() {
        let scalar_spec = NetworkSearchSpec {
            search: SearchSpec { objective, ..spec.search.clone() },
            ..spec.clone()
        };
        let scalar = network::search_network(&net, &arch, &scalar_spec, &pool)
            .expect("scalar search found no partition");
        let front_min = front.min_cost(axis).expect("front is non-empty");
        let tol = 1e-12 * scalar.total_score.abs().max(1.0);
        let on_front = (front_min - scalar.total_score).abs() <= tol;
        println!(
            "scalar {:>8} optimum {:.6e}  == front axis minimum {:.6e}  ({})",
            objective.name(),
            scalar.total_score,
            front_min,
            if on_front { "on the front" } else { "MISMATCH" },
        );
    }
    println!(
        "\nOne front DP replaces one scalar sweep per objective and also exposes\n\
         every intermediate trade-off (e.g. the partitions trading a little\n\
         latency for much less on-chip capacity). `looptree network --pareto\n\
         --json` emits these fronts as re-feedable documents."
    );
}
