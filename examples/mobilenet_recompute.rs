//! Recomputation trade-off study on MobileNetV2 inverted-residual blocks:
//! sweep the retention-recomputation choice for each intermediate fmap and
//! chart the capacity/recompute Pareto per stage (paper §VI-C / Fig 15 on
//! the real network's shapes).
//!
//! Run with: `cargo run --release --example mobilenet_recompute`

use looptree::casestudies::{study_session, study_tiles};
use looptree::einsum::{workloads, TensorId, TensorKind};
use looptree::mapping::{InterLayerMapping, Parallelism, Partition};
use looptree::mapspace::{pareto_front, ParetoPoint};
use looptree::util::table::Table;

fn main() {
    let mut table = Table::new(&[
        "stage", "shape", "recompute frac", "capacity (elems)", "vs no-recompute",
    ]);
    for (stage, &(w, c)) in workloads::MOBILENETV2_STAGES.iter().enumerate() {
        let fs = workloads::mobilenetv2_block(stage);
        let ev = study_session(&fs);
        let last = fs.last();
        let p3 = last.rank_index("P3").unwrap();
        let q3 = last.rank_index("Q3").unwrap();
        let inters: Vec<TensorId> = fs.tensors_of_kind(TensorKind::Intermediate);

        // Sweep: tile sizes × per-fmap retention level (band vs box).
        let mut pts: Vec<ParetoPoint<(f64, i64)>> = Vec::new();
        for &tp in &study_tiles(last.rank_sizes[p3]) {
            for &tq in &study_tiles(last.rank_sizes[q3]) {
                for combo in 0..(1 << inters.len()) {
                    let mut mapping = InterLayerMapping::tiled(
                        vec![
                            Partition { dim: p3, tile: tp },
                            Partition { dim: q3, tile: tq },
                        ],
                        Parallelism::Sequential,
                    );
                    for (i, &t) in inters.iter().enumerate() {
                        let lvl = if combo >> i & 1 == 1 { 2 } else { 1 };
                        mapping = mapping.with_retention(t, lvl);
                    }
                    let m = looptree::casestudies::eval(&ev, &mapping);
                    let cap: i64 = m.per_tensor_occupancy.iter().sum();
                    pts.push(ParetoPoint {
                        x: m.recompute_fraction(),
                        y: cap as f64,
                        payload: (m.recompute_fraction(), cap),
                    });
                }
            }
        }
        let front = pareto_front(pts);
        let no_rec_cap = front
            .iter()
            .filter(|p| p.payload.0 == 0.0)
            .map(|p| p.payload.1)
            .min()
            .unwrap_or(0);
        for p in &front {
            table.row(&[
                format!("block{}", stage + 1),
                format!("{w}x{w}x{c}"),
                format!("{:.3}", p.payload.0),
                p.payload.1.to_string(),
                format!("{:.2}x", no_rec_cap as f64 / p.payload.1.max(1) as f64),
            ]);
        }
    }
    println!(
        "MobileNetV2 per-block recompute/capacity Pareto fronts (P3,Q3 schedule):\n"
    );
    println!("{}", table.render());
    println!(
        "A few percent of recomputation often buys a ~2x smaller intermediate\n\
         buffer — the paper's recomputation trade-off (§VI-C), on real shapes."
    );
}
