//! DSE over ResNet-18 basic blocks: for each stage, search the full
//! mapspace for the mapping minimizing energy-delay product under a fixed
//! GLB budget, and report how the optimal schedule changes with layer shape
//! (the paper's Fig 4 / §VI-B motivation: widths and channel counts vary by
//! orders of magnitude, so no single choice wins).
//!
//! Run with: `cargo run --release --example resnet_dse`

use looptree::arch::Arch;
use looptree::coordinator::Coordinator;
use looptree::einsum::workloads;
use looptree::mapspace::MapSpaceConfig;
use looptree::model::Evaluator;
use looptree::search::{self, Objective, SearchSpec};
use looptree::util::table::Table;

fn main() {
    let arch = Arch::generic(128); // 128 KiB GLB
    let pool = Coordinator::new(0);

    let mut table = Table::new(&[
        "stage", "shape", "best schedule", "tiles", "latency (cyc)", "energy (uJ)", "occupancy", "fits",
    ]);
    for (stage, &(w, c)) in workloads::RESNET18_STAGES.iter().enumerate() {
        let fs = workloads::resnet18_block(stage);
        let spec = SearchSpec {
            objective: Objective::FeasibleEdp,
            mapspace: MapSpaceConfig {
                // Keep the sweep tractable: the interesting single- and
                // double-rank schedules with a few tile sizes.
                schedules: vec![
                    vec!["P2".into()],
                    vec!["P2".into(), "Q2".into()],
                    vec!["C2".into()],
                    vec!["C2".into(), "P2".into()],
                    vec!["M2".into()],
                ],
                tile_sizes: vec![2, 4, 8],
                uniform_retention: false,
                ..Default::default()
            },
            ..Default::default()
        };
        let ev = Evaluator::new(&fs, &arch).expect("valid specs");
        let res = search::run(&ev, &spec, &pool).expect("search found no mapping");
        let b = &res.best;
        table.row(&[
            format!("conv{}_x", stage + 2),
            format!("{w}x{w}x{c}"),
            b.mapping.schedule_string(&fs),
            format!("{:?}", b.mapping.partitions.iter().map(|p| p.tile).collect::<Vec<_>>()),
            b.metrics.latency_cycles.to_string(),
            format!("{:.1}", b.metrics.energy_uj()),
            b.metrics.occupancy_peak.to_string(),
            b.metrics.capacity_ok.to_string(),
        ]);
    }
    println!("ResNet-18 per-stage optimal fused mappings (128 KiB GLB, EDP objective):\n");
    println!("{}", table.render());
    println!(
        "Under an EDP objective with a tight GLB, channel-first schedules with\n\
         small spatial tiles dominate; capacity-focused sweeps (bench_fig14)\n\
         show the schedule shifting with layer shape — the paper's takeaway 1."
    );
}
