//! Network-level fused-segment partitioning: where should a whole DNN be
//! cut into fused segments, and what does fusion buy over running every
//! layer alone?
//!
//! For ResNet-18 — with its *real residual edges* — and a BERT encoder
//! block, this example runs the partitioner (`network::search_network`)
//! under a fixed GLB budget, then scores the unfused baseline (every layer
//! its own segment) with the *same* per-segment search for a like-for-like
//! comparison. ResNet-18 is a branched graph, so the DP runs over graph
//! cuts: watch for segments whose node set spans a residual `add` together
//! with the conv feeding it — fusion across a branch point, which the old
//! chain IR could not even represent. Repeated block shapes (e.g. ResNet's
//! identical stage-2 residual blocks) are searched once and memoized.
//!
//! Run with: `cargo run --release --example network_partition`
//! (optionally `-- --objective offchip` to optimize and compare under a
//! different objective; default `feasible-edp`).

use looptree::arch::Arch;
use looptree::coordinator::Coordinator;
use looptree::network::{self, Network, NetworkSearchResult, NetworkSearchSpec};
use looptree::search::Objective;
use looptree::util::table::{fmt_count, Table};

fn report(net: &Network, r: &NetworkSearchResult) {
    println!(
        "{}: {} of {} candidate segments searched",
        net.name, r.distinct_searched, r.candidate_segments
    );
    let mut table =
        Table::new(&["segment", "nodes", "score", "latency (cyc)", "offchip", "branch?", "fits"]);
    for s in &r.segments {
        table.row(&[
            s.span.clone(),
            s.range_label(),
            format!("{:.3e}", s.best.score),
            fmt_count(s.best.metrics.latency_cycles),
            fmt_count(s.best.metrics.offchip_total()),
            if s.spans_branch(net) { "fused-add".into() } else { String::new() },
            s.best.metrics.capacity_ok.to_string(),
        ]);
    }
    println!("{}", table.render());
    let branching = r.segments.iter().filter(|s| s.spans_branch(net)).count();
    if branching > 0 {
        println!(
            "{branching} segment(s) fuse across a residual branch point — the add runs \
             on-chip against the skip tensor, saving the main path's DRAM round trip.\n"
        );
    }
}

fn main() {
    let arch = Arch::generic(256); // 256 KiB GLB
    let pool = Coordinator::new(0);
    // `--objective <name>` switches what both the partitioner and the
    // unfused baseline optimize, so the comparison below is always
    // like-for-like under the spec's own objective (e.g. `--objective
    // offchip` compares off-chip-optimal fused vs off-chip-optimal
    // unfused), instead of re-scoring with a hardcoded metric.
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut spec = NetworkSearchSpec::default();
    if let Some(i) = args.iter().position(|a| a == "--objective") {
        let name = args.get(i + 1).expect("--objective needs a value");
        spec.search.objective = Objective::parse(name).unwrap_or_else(|e| panic!("{e}"));
    }
    let objective = spec.search.objective;

    for net in [network::resnet18(), network::bert_encoder(1, 12, 512, 64)] {
        let best = network::search_network(&net, &arch, &spec, &pool)
            .expect("network search found no partition");
        report(&net, &best);

        // Unfused baseline: every (non-virtual) node its own segment, same
        // per-segment search, same objective.
        let singles: Vec<Vec<usize>> = (0..net.num_layers())
            .filter(|&i| !net.layers[i].op.is_virtual())
            .map(|i| vec![i])
            .collect();
        let unfused = network::evaluate_segments(&net, &arch, &spec, &singles, &pool)
            .expect("unfused baseline failed");
        println!(
            "{}: fused-optimal {} {:.4e} vs unfused {:.4e} ({:.2}x); \
             offchip {} vs {}, latency {} vs {}\n",
            net.name,
            objective.name(),
            best.total_score,
            unfused.total_score,
            unfused.total_score / best.total_score,
            fmt_count(best.total_offchip()),
            fmt_count(unfused.total_offchip()),
            fmt_count(best.total_latency()),
            fmt_count(unfused.total_latency()),
        );
    }
    println!(
        "The partitioner answers the question a single FusionSet cannot:\n\
         which layers to fuse, and where to cut — now over a DAG of layers,\n\
         so residual adds and skip connections are fusable instead of being\n\
         dropped from the workload. Per-segment mapspace searches are\n\
         memoized over canonical segment signatures, and the segment cover\n\
         minimizing the summed objective is found by DP over graph cuts\n\
         (chain cut points when the network is a pure path)."
    );
}
