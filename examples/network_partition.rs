//! Network-level fused-segment partitioning: where should a whole DNN be
//! cut into fused segments, and what does fusion buy over running every
//! layer alone?
//!
//! For ResNet-18 and a BERT encoder block, this example runs the
//! dynamic-programming partitioner (`network::search_network`) under a
//! fixed GLB budget, then scores the unfused baseline (a cut after every
//! layer) with the *same* per-segment search for a like-for-like
//! comparison. Repeated block shapes (e.g. ResNet's identical stage-2
//! blocks) are searched once and memoized.
//!
//! Run with: `cargo run --release --example network_partition`

use looptree::arch::Arch;
use looptree::coordinator::Coordinator;
use looptree::network::{self, NetworkSearchResult, NetworkSearchSpec};
use looptree::util::table::{fmt_count, Table};

fn report(name: &str, r: &NetworkSearchResult) {
    println!(
        "{name}: cuts at {:?} ({} of {} candidate segments searched)",
        r.cuts, r.distinct_searched, r.candidate_segments
    );
    let mut table = Table::new(&["segment", "score", "latency (cyc)", "offchip", "fits"]);
    for s in &r.segments {
        table.row(&[
            s.span.clone(),
            format!("{:.3e}", s.best.score),
            fmt_count(s.best.metrics.latency_cycles),
            fmt_count(s.best.metrics.offchip_total()),
            s.best.metrics.capacity_ok.to_string(),
        ]);
    }
    println!("{}", table.render());
}

fn main() {
    let arch = Arch::generic(256); // 256 KiB GLB
    let pool = Coordinator::new(0);
    let spec = NetworkSearchSpec::default();

    for net in [network::resnet18(), network::bert_encoder(1, 12, 512, 64)] {
        let best = network::search_network(&net, &arch, &spec, &pool)
            .expect("network search found no partition");
        report(&net.name, &best);

        // Unfused baseline: a cut after every layer, same per-segment search.
        let all_cuts: Vec<usize> = (1..net.num_layers()).collect();
        let unfused = network::evaluate_partition(&net, &arch, &spec, &all_cuts, &pool)
            .expect("unfused baseline failed");
        println!(
            "{}: fused-optimal offchip {} vs unfused {} ({:.2}x), latency {} vs {}\n",
            net.name,
            fmt_count(best.total_offchip()),
            fmt_count(unfused.total_offchip()),
            unfused.total_offchip() as f64 / best.total_offchip() as f64,
            fmt_count(best.total_latency()),
            fmt_count(unfused.total_latency()),
        );
    }
    println!(
        "The partitioner answers the question a single FusionSet cannot:\n\
         which layers to fuse, and where to cut — per-segment mapspace\n\
         searches are memoized over distinct segment shapes, and the cut\n\
         set minimizing the summed objective is found by DP over the chain."
    );
}
