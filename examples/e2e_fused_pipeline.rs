//! End-to-end driver (the full three-layer stack on a real workload):
//!
//! 1. **DSE (L3 model)** — search the mapspace for the conv+conv fusion set
//!    that matches the AOT-compiled artifact configuration, and pick the
//!    best retained-band mapping.
//! 2. **Execution (L3 runtime + L2/L1 artifacts)** — drive the chosen
//!    inter-layer schedule tile by tile through the PJRT stage executables
//!    (conv_stage1_*/conv_stage2, lowered from JAX by `make artifacts`),
//!    with the rust coordinator owning the retained Fmap2 band.
//! 3. **Cross-check** — verify numerics against the monolithic reference
//!    executable and compare *measured* data movement against the model's
//!    predictions; report wall-clock throughput for the fused pipeline vs
//!    the monolithic fused kernel and the layer-by-layer reference.
//!
//! Run with: `make artifacts && cargo run --release --example e2e_fused_pipeline`

use looptree::arch::Arch;
use looptree::einsum::{workloads, TensorId, TensorKind};
use looptree::mapping::{InterLayerMapping, Parallelism, Partition};
use looptree::model::Evaluator;
use looptree::runtime::Runtime;
use std::time::Instant;

fn gen(seed0: u64, n: usize, scale: f32) -> Vec<f32> {
    let mut seed = seed0;
    (0..n)
        .map(|_| {
            seed ^= seed << 13;
            seed ^= seed >> 7;
            seed ^= seed << 17;
            ((seed as f64 / u64::MAX as f64) as f32 - 0.5) * scale
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }
    let mut rt = Runtime::open(&dir)?;
    println!("PJRT platform: {}", rt.platform());

    let ch = rt.config_i64("channels")?;
    let rows = rt.config_i64("rows")?;
    let tile_p = rt.config_i64("tile_p")?;
    let halo1 = rt.config_i64("halo1")? as usize;
    let halo_t = rt.config_i64("halo_total")?;
    let h = rows + halo_t;

    // ---- 1) model-side DSE over the artifact's fusion set ----
    // The workload matching the artifacts: conv+conv with P2 = rows.
    let fs = workloads::conv_conv(rows - 2, ch); // builder adds +2 per layer
    let arch = Arch::generic(64); // 64 KiB GLB
    let ev = Evaluator::new(&fs, &arch).expect("valid specs");
    let last = fs.last();
    let p2 = last.rank_index("P2").unwrap();
    let fmap2 = TensorId(2);

    // Model sweep over tile sizes (informational) — then pick the best
    // mapping among tiles with a compiled artifact variant (AOT means one
    // executable per variant; here the build ships tile_p only).
    let compiled_tiles = [tile_p];
    let mut best: Option<(i64, InterLayerMapping)> = None;
    for tile in [tile_p / 2, tile_p, tile_p * 2] {
        if tile < 1 || tile > last.rank_sizes[p2] {
            continue;
        }
        let mapping = InterLayerMapping::tiled(
            vec![Partition { dim: p2, tile }],
            Parallelism::Sequential,
        )
        .with_retention(fmap2, 1);
        let m = ev.evaluate(&mapping).unwrap();
        let available = compiled_tiles.contains(&tile);
        println!(
            "  candidate tile {tile}: occupancy {} elems, offchip {} elems, fits={} artifact={}",
            m.occupancy_peak,
            m.offchip_total(),
            m.capacity_ok,
            available
        );
        if m.capacity_ok
            && available
            && best.as_ref().map(|(o, _)| m.occupancy_peak < *o).unwrap_or(true)
        {
            best = Some((m.occupancy_peak, mapping));
        }
    }
    let (_, mapping) = best.expect("no feasible mapping with a compiled artifact");
    let model_metrics = ev.evaluate(&mapping).unwrap();
    println!(
        "\nchosen mapping: schedule {}, tile {} (model: {})",
        mapping.schedule_string(&fs),
        mapping.partitions[0].tile,
        model_metrics.summary()
    );

    // ---- 2) drive the fused tile pipeline through PJRT ----
    let (chs, hs) = (ch as usize, h as usize);
    let x = gen(0xE2E, chs * hs * hs, 1.0);
    let w1 = gen(0xF00D, chs * chs * 9, 0.1);
    let w2 = gen(0xBEEF, chs * chs * 9, 0.1);
    let xs = [ch, h, h];
    let ws = [ch, ch, 3, 3];
    let w2cols = hs - 2;
    let tile_pu = tile_p as usize;
    let rows_u = rows as usize;

    let t_ref = Instant::now();
    let reference = rt
        .load("conv_conv_ref")?
        .run_f32(&[(&x, &xs), (&w1, &ws), (&w2, &ws)])?;
    let ref_time = t_ref.elapsed();

    let t_mono = Instant::now();
    let fused_mono = rt
        .load("conv_conv_fused")?
        .run_f32(&[(&x, &xs), (&w1, &ws), (&w2, &ws)])?;
    let mono_time = t_mono.elapsed();

    let slice_rows = |data: &[f32], r0: usize, nrows: usize| -> Vec<f32> {
        let mut out = Vec::with_capacity(chs * nrows * hs);
        for c in 0..chs {
            let base = c * hs * hs + r0 * hs;
            out.extend_from_slice(&data[base..base + nrows * hs]);
        }
        out
    };

    let t_pipe = Instant::now();
    let mut fmap2_rows: Vec<Vec<f32>> = Vec::new();
    let mut got = vec![0f32; chs * rows_u * (w2cols - 2)];
    let mut produced = 0usize;
    let mut hbm_words_moved = 0usize; // what the coordinator actually fetched/drained
    for i in 0..rows_u / tile_pu {
        let (fresh, x_block, stage) = if i == 0 {
            let f = tile_pu + halo1;
            (f, slice_rows(&x, 0, f + 2), "conv_stage1_first")
        } else {
            (tile_pu, slice_rows(&x, produced, tile_pu + 2), "conv_stage1_steady")
        };
        hbm_words_moved += x_block.len();
        let xbs = [ch, (fresh + 2) as i64, h];
        let f2 = rt.load(stage)?.run_f32(&[(&x_block, &xbs), (&w1, &ws)])?;
        for r in 0..fresh {
            let mut rowbuf = Vec::with_capacity(chs * w2cols);
            for c in 0..chs {
                let base = c * fresh * w2cols + r * w2cols;
                rowbuf.extend_from_slice(&f2[base..base + w2cols]);
            }
            fmap2_rows.push(rowbuf);
        }
        produced += fresh;
        // Sliding band of tile_p + halo1 rows (the retained intermediate).
        let band_rows = tile_pu + halo1;
        let start = fmap2_rows.len() - band_rows;
        let mut band = vec![0f32; chs * band_rows * w2cols];
        for (ri, row) in fmap2_rows[start..].iter().enumerate() {
            for c in 0..chs {
                band[c * band_rows * w2cols + ri * w2cols..][..w2cols]
                    .copy_from_slice(&row[c * w2cols..(c + 1) * w2cols]);
            }
        }
        // Retention: drop rows that slid out of the band.
        if fmap2_rows.len() > band_rows {
            fmap2_rows.drain(0..fmap2_rows.len() - band_rows);
        }
        let bs = [ch, band_rows as i64, w2cols as i64];
        let tile = rt.load("conv_stage2")?.run_f32(&[(&band, &bs), (&w2, &ws)])?;
        let out_cols = w2cols - 2;
        hbm_words_moved += tile.len();
        for c in 0..chs {
            for r in 0..tile_pu {
                let src = c * tile_pu * out_cols + r * out_cols;
                let dst = c * rows_u * out_cols + (i * tile_pu + r) * out_cols;
                got[dst..dst + out_cols].copy_from_slice(&tile[src..src + out_cols]);
            }
        }
    }
    let pipe_time = t_pipe.elapsed();

    // ---- 3) cross-checks + report ----
    let max_err = got
        .iter()
        .zip(&reference)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    let mono_err = fused_mono
        .iter()
        .zip(&reference)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    println!("\nnumerics: pipeline max|err| = {max_err:.2e}, fused kernel max|err| = {mono_err:.2e}");
    assert!(max_err < 1e-3 && mono_err < 1e-3);

    // Model-predicted HBM traffic for the fmap side (input reads + output
    // writes; weights live on-chip across tiles in both).
    let fmap_tensors: i64 = fs
        .tensors
        .iter()
        .enumerate()
        .filter(|(_, t)| matches!(t.kind, TensorKind::InputFmap | TensorKind::OutputFmap))
        .map(|(x_, _)| model_metrics.per_tensor_offchip[x_])
        .sum();
    println!(
        "data movement: model predicts {} fmap elems over HBM; coordinator measured {} \
         ({}x input overlap from the halo)",
        fmap_tensors,
        hbm_words_moved,
        format!("{:.2}", hbm_words_moved as f64 / fmap_tensors as f64),
    );

    println!("\nwall-clock (PJRT CPU):");
    println!("  layer-by-layer reference : {ref_time:?}");
    println!("  monolithic fused kernel  : {mono_time:?}");
    println!("  rust-driven tile pipeline: {pipe_time:?}");
    let stats = rt.total_stats();
    println!(
        "  executable invocations: {} ({} input elems, {} output elems)",
        stats.invocations, stats.input_elems, stats.output_elems
    );
    println!("\nE2E OK: DSE -> artifacts -> PJRT pipeline -> verified numerics");
    Ok(())
}
