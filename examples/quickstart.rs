//! Quickstart: define a fusion set, open a validate-once `Evaluator`
//! session, evaluate a few retention choices, and serialize the winner as
//! JSON.
//!
//! Run with: `cargo run --release --example quickstart`

use looptree::arch::Arch;
use looptree::einsum::{workloads, TensorId};
use looptree::mapping::{InterLayerMapping, Parallelism, Partition};
use looptree::model::Evaluator;

fn main() {
    // Two fused 3×3 conv layers, ResNet-ish shape: 28×28 spatial, 64 ch.
    let fs = workloads::conv_conv(28, 64);
    println!("fusion set: {}", fs.name);
    for t in &fs.tensors {
        println!("  {:8} {:?} ({:?})", t.name, t.shape, t.kind);
    }

    // A 256 KiB-GLB Eyeriss-class accelerator. The session validates both
    // specs once; every evaluate() after that is the cheap hot path.
    let arch = Arch::generic(256);
    let ev = Evaluator::new(&fs, &arch).expect("valid specs");

    // Partition the last layer's output rows (P2) into tiles of 4 and
    // process tiles sequentially: the classic fused-layer dataflow.
    let p2 = fs.last().rank_index("P2").unwrap();
    let mapping = InterLayerMapping::tiled(
        vec![Partition { dim: p2, tile: 4 }],
        Parallelism::Sequential,
    );
    let m = ev.evaluate(&mapping).unwrap();
    println!("\nP2-tiled fused mapping: {}", m.summary());
    println!("fits in 256 KiB GLB: {}", m.capacity_ok);

    // Compare against untiled fusion (whole intermediate retained)...
    let untiled = ev
        .evaluate(&InterLayerMapping::untiled(Parallelism::Sequential))
        .unwrap();
    println!("\nuntiled fusion:         {}", untiled.summary());
    println!(
        "tiling reduces required capacity {:.1}x at the same off-chip traffic",
        untiled.occupancy_peak as f64 / m.occupancy_peak as f64
    );

    // ...and against a recompute variant (retain only the innermost tile).
    let fmap2 = TensorId(2);
    let q2 = fs.last().rank_index("Q2").unwrap();
    let recompute_mapping = InterLayerMapping::tiled(
        vec![
            Partition { dim: p2, tile: 4 },
            Partition { dim: q2, tile: 7 },
        ],
        Parallelism::Sequential,
    )
    .with_retention(fmap2, 2);
    let recompute = ev.evaluate(&recompute_mapping).unwrap();
    println!("\nrecompute variant:      {}", recompute.summary());
    println!(
        "recomputation: +{:.1}% ops for {:.1}x less intermediate buffer",
        100.0 * recompute.recompute_fraction(),
        m.per_tensor_occupancy[2] as f64 / recompute.per_tensor_occupancy[2] as f64
    );

    // Everything round-trips through the JSON spec layer — this document is
    // a valid `looptree analyze --config` input.
    let mut doc = looptree::spec::AnalyzeConfig {
        workload: fs.clone(),
        arch: arch.clone(),
        mapping: recompute_mapping,
    }
    .to_json();
    if let looptree::util::json::Json::Obj(o) = &mut doc {
        o.insert("metrics".into(), recompute.to_json());
    }
    println!("\nJSON spec (analyze --config compatible):\n{}", doc.pretty());
}
