//! Property-based cross-validation: random workloads × random mappings must
//! satisfy the model's invariants, and the model must agree with the
//! element-level simulator on every count. The PRNG is deterministic
//! (seeded), so failures are reproducible.

use looptree::arch::Arch;
use looptree::einsum::{workloads, FusionSet, TensorId, TensorKind};
use looptree::mapping::{InterLayerMapping, Parallelism, Partition};
use looptree::model::{evaluate, EvalOptions};
use looptree::sim::simulate;
use looptree::util::prng::Prng;

/// Sample a random workload small enough for the element-level simulator.
fn random_fusion_set(rng: &mut Prng) -> FusionSet {
    match rng.index(5) {
        0 => workloads::conv_conv(4 + rng.range_i64(0, 10), 2 + rng.range_i64(0, 4)),
        1 => workloads::conv_conv_conv(6 + rng.range_i64(0, 8), 2 + rng.range_i64(0, 2)),
        2 => workloads::pwise_dwise_pwise(4 + rng.range_i64(0, 8), 2 + rng.range_i64(0, 3)),
        3 => workloads::fc_fc(8 + rng.range_i64(0, 24), 4 + rng.range_i64(0, 12)),
        _ => workloads::self_attention(1, 2, 8 + rng.range_i64(0, 8), 4),
    }
}

/// Sample a random mapping for the fusion set: 0–3 partitioned ranks with
/// random tiles, random per-tensor retention levels, random parallelism.
fn random_mapping(fs: &FusionSet, rng: &mut Prng) -> InterLayerMapping {
    let last = fs.last();
    let nparts = rng.index(4);
    let mut dims: Vec<usize> = (0..last.ndim()).collect();
    rng.shuffle(&mut dims);
    let mut partitions = Vec::new();
    for &dim in dims.iter().take(nparts) {
        let extent = last.rank_sizes[dim];
        if extent < 2 {
            continue;
        }
        let tile = rng.range_i64(1, extent);
        partitions.push(Partition { dim, tile });
    }
    let parallelism = if rng.chance(0.5) {
        Parallelism::Sequential
    } else {
        Parallelism::Pipeline
    };
    let k = partitions.len();
    let mut m = InterLayerMapping::tiled(partitions, parallelism);
    for x in 0..fs.tensors.len() {
        if rng.chance(0.5) {
            m = m.with_retention(TensorId(x), rng.index(k + 1));
        }
    }
    m
}

#[test]
fn model_matches_simulator_on_random_mappings() {
    let mut rng = Prng::new(0xC0FFEE);
    let arch = Arch::generic(1 << 20);
    let mut checked = 0;
    for case in 0..60 {
        let fs = random_fusion_set(&mut rng);
        let mapping = random_mapping(&fs, &mut rng);
        if mapping.total_iterations(&fs) > 4000 {
            continue; // keep the element-level simulator fast
        }
        let m = evaluate(&fs, &arch, &mapping, &EvalOptions::default())
            .unwrap_or_else(|e| panic!("case {case} ({}): model: {e}", fs.name));
        let s = simulate(&fs, &arch, &mapping)
            .unwrap_or_else(|e| panic!("case {case} ({}): sim: {e}", fs.name));
        let tag = format!(
            "case {case}: {} sched={} ret={:?} par={:?}",
            fs.name,
            mapping.schedule_string(&fs),
            (0..fs.tensors.len())
                .map(|x| mapping.retention_for(TensorId(x)))
                .collect::<Vec<_>>(),
            mapping.parallelism
        );
        assert_eq!(m.offchip_reads, s.offchip_reads, "{tag}: reads");
        assert_eq!(m.offchip_writes, s.offchip_writes, "{tag}: writes");
        assert_eq!(m.total_ops, s.total_ops, "{tag}: ops");
        assert_eq!(m.recompute_ops, s.recompute_ops, "{tag}: recompute");
        assert_eq!(
            m.per_tensor_occupancy, s.per_tensor_occupancy,
            "{tag}: occupancy"
        );
        assert_eq!(
            m.per_tensor_offchip, s.per_tensor_offchip,
            "{tag}: per-tensor offchip"
        );
        checked += 1;
    }
    assert!(checked >= 30, "only {checked} cases exercised");
}

#[test]
fn model_invariants_on_random_mappings() {
    let mut rng = Prng::new(0xBEEF);
    let arch = Arch::generic(1 << 20);
    for case in 0..120 {
        let fs = random_fusion_set(&mut rng);
        let mapping = random_mapping(&fs, &mut rng);
        if mapping.total_iterations(&fs) > 100_000 {
            continue;
        }
        let m = evaluate(&fs, &arch, &mapping, &EvalOptions::default())
            .unwrap_or_else(|e| panic!("case {case}: {e}"));
        let tag = format!("case {case}: {} {}", fs.name, mapping.schedule_string(&fs));

        // Work is never below the algorithmic minimum.
        assert!(m.total_ops >= fs.total_ops(), "{tag}: ops below algmin");
        assert_eq!(m.total_ops - fs.total_ops(), m.recompute_ops, "{tag}");
        assert!(m.recompute_ops >= 0, "{tag}: negative recompute");

        // Transfers are never below the algorithmic minimum.
        assert!(
            m.offchip_total() >= fs.algmin_offchip_elems(),
            "{tag}: transfers below algmin"
        );
        // The final output is written exactly once.
        let out = fs.tensors_of_kind(TensorKind::OutputFmap)[0];
        assert_eq!(m.per_tensor_offchip[out.0], fs.tensor(out).size(), "{tag}");

        // Occupancy sanity: every non-intermediate tensor's peak is at most
        // its full size...
        for (x, t) in fs.tensors.iter().enumerate() {
            if t.kind != TensorKind::Intermediate {
                assert!(
                    m.per_tensor_occupancy[x] <= t.size(),
                    "{tag}: {} occupancy {} > size {}",
                    t.name,
                    m.per_tensor_occupancy[x],
                    t.size()
                );
            }
        }
        // ...and the peak never exceeds the per-tensor sum.
        let sum: i64 = m.per_tensor_occupancy.iter().sum();
        assert!(m.occupancy_peak <= sum, "{tag}: peak {} > sum {sum}", m.occupancy_peak);

        // Latency covers both compute and memory.
        assert!(m.latency_cycles >= m.compute_cycles.max(m.memory_cycles), "{tag}");
        assert!(m.energy.total_pj() > 0.0, "{tag}: zero energy");
    }
}

#[test]
fn untiled_mapping_is_always_algmin() {
    let mut rng = Prng::new(7);
    let arch = Arch::generic(1 << 20);
    for _ in 0..20 {
        let fs = random_fusion_set(&mut rng);
        let m = evaluate(
            &fs,
            &arch,
            &InterLayerMapping::untiled(Parallelism::Sequential),
            &EvalOptions::default(),
        )
        .unwrap();
        assert_eq!(m.recompute_ops, 0, "{}", fs.name);
        assert_eq!(m.offchip_total(), fs.algmin_offchip_elems(), "{}", fs.name);
    }
}
