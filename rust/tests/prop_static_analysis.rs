//! Static analysis vs. the empirical oracle.
//!
//! The `analysis` module makes three families of closed-form claims, each of
//! which must hold against the evaluated ground truth on randomized
//! mappings over the five built-in workload families:
//!
//! * the **prover**'s certified steady-state jumps leave the engine
//!   bit-identical to the exhaustive reference walk;
//! * the **bounds** ([`capacity_lower_bound`], [`ObjectiveFloors`]) never
//!   exceed the corresponding evaluated metric;
//! * the **pruner** never changes a search result — pruning on and off
//!   return the same best mapping at the same score, bit for bit.

use looptree::analysis::{capacity_lower_bound, prove_levels, SessionStatics};
use looptree::arch::Arch;
use looptree::coordinator::Coordinator;
use looptree::einsum::{workloads, FusionSet, TensorId};
use looptree::mapping::{InterLayerMapping, Parallelism, Partition};
use looptree::model::Evaluator;
use looptree::search::{self, Algorithm, Objective, SearchSpec};
use looptree::util::prng::Prng;

fn workload_pool() -> Vec<FusionSet> {
    vec![
        workloads::conv_conv(20, 4),
        workloads::conv_conv_conv(16, 4),
        workloads::pwise_dwise_pwise(12, 3),
        workloads::fc_fc(24, 8),
        workloads::self_attention(1, 2, 12, 4),
    ]
}

/// A randomized mapping: 0–3 partition levels with ragged tiles, random
/// per-tensor retention, both parallelisms (same shape as the fast-path
/// property tests).
fn random_mapping(fs: &FusionSet, rng: &mut Prng) -> InterLayerMapping {
    let last = fs.last();
    let mut partitions: Vec<Partition> = Vec::new();
    let mut dims: Vec<usize> = (0..last.ndim()).collect();
    rng.shuffle(&mut dims);
    for &dim in dims.iter().take(rng.index(4)) {
        let extent = last.rank_sizes[dim];
        if extent < 2 {
            continue;
        }
        partitions.push(Partition { dim, tile: rng.range_i64(1, extent) });
    }
    let parallelism = if rng.chance(0.5) {
        Parallelism::Sequential
    } else {
        Parallelism::Pipeline
    };
    let k = partitions.len();
    let mut m = InterLayerMapping::tiled(partitions, parallelism);
    for x in 0..fs.tensors.len() {
        if rng.chance(0.5) {
            m = m.with_retention(TensorId(x), rng.index(k + 1));
        }
    }
    m
}

/// Closed-form bounds vs. evaluated metrics: the capacity lower bound and
/// every objective floor must hold for every randomized mapping.
#[test]
fn bounds_never_exceed_evaluated_metrics() {
    let mut rng = Prng::new(0x0B0B_57A7);
    let arch = Arch::generic(1 << 14);
    for fs in &workload_pool() {
        let ev = Evaluator::new(fs, &arch).unwrap();
        let fl = ev.floors();
        for sub in 0..12 {
            let m = random_mapping(fs, &mut rng);
            if m.total_iterations(fs) > 20_000 {
                continue;
            }
            let tag = format!("{} #{sub}", fs.name);
            let lb = ev.capacity_lower_bound(&m).unwrap();
            let metrics = ev.evaluate(&m).unwrap();
            assert!(
                lb <= metrics.occupancy_peak,
                "{tag}: capacity bound {lb} > evaluated peak {}",
                metrics.occupancy_peak
            );
            let lat_floor = match m.parallelism {
                Parallelism::Sequential => fl.latency_seq,
                Parallelism::Pipeline => fl.latency_pipe,
            };
            assert!(lat_floor <= metrics.latency_cycles, "{tag}: latency floor");
            assert!(fl.energy_pj <= metrics.energy.total_pj(), "{tag}: energy floor");
            assert!(fl.offchip_elems <= metrics.offchip_total(), "{tag}: offchip floor");
        }
    }
}

/// The prover's deltas must reproduce the empirical walk exactly. The
/// fast-path property suite already checks `evaluate` == reference on
/// random mappings; here we additionally require that the prover *fires*
/// on the canonical sliding-window schedules, so the static path is known
/// to be exercised rather than vacuously falling back.
#[test]
fn prover_certifies_canonical_schedules_and_stays_exact() {
    let arch = Arch::generic(1 << 14);
    let mut proven = 0;
    for fs in &workload_pool() {
        let st = SessionStatics::build(fs);
        let ev = Evaluator::new(fs, &arch).unwrap();
        let last = fs.last();
        for dim in st.out_dims.clone() {
            let extent = last.rank_sizes[dim];
            if extent < 8 {
                continue;
            }
            for tile in [1, 2] {
                let m = InterLayerMapping::tiled(
                    vec![Partition { dim, tile }],
                    Parallelism::Sequential,
                );
                let counts = m.level_counts(fs);
                let proofs = prove_levels(fs, &st, &m, &counts);
                if proofs[0].is_some() {
                    proven += 1;
                }
                let mut fast = ev.evaluate(&m).unwrap();
                let mut slow = ev.evaluate_reference(&m).unwrap();
                // Path attribution is diagnostic and differs by construction.
                fast.path = Default::default();
                slow.path = Default::default();
                assert_eq!(
                    format!("{fast:?}"),
                    format!("{slow:?}"),
                    "{} dim {dim} tile {tile}",
                    fs.name
                );
            }
        }
    }
    assert!(proven >= 5, "prover fired only {proven} times — it has gone vacuous");
}

/// Randomized mappings through `prove_levels` directly: whatever the
/// verdict, the engine (which consumes it) must match the reference walk.
#[test]
fn randomized_mappings_stay_exact_under_the_prover() {
    let mut rng = Prng::new(0x9047_EE57);
    let arch = Arch::generic(1 << 13);
    for fs in &workload_pool() {
        let st = SessionStatics::build(fs);
        let ev = Evaluator::new(fs, &arch).unwrap();
        for sub in 0..8 {
            let m = random_mapping(fs, &mut rng);
            if m.total_iterations(fs) > 20_000 {
                continue;
            }
            // The prover must never panic, whatever the mapping.
            let _ = prove_levels(fs, &st, &m, &m.level_counts(fs));
            // And the engine consuming its verdicts must stay exact.
            let mut fast = ev.evaluate(&m).unwrap();
            let mut slow = ev.evaluate_reference(&m).unwrap();
            // Path attribution is diagnostic and differs by construction.
            fast.path = Default::default();
            slow.path = Default::default();
            assert_eq!(
                format!("{fast:?}"),
                format!("{slow:?}"),
                "{} #{sub}",
                fs.name
            );
        }
    }
}

/// The sanity anchor for the capacity bound: at the first leaf the bound is
/// *exact* for an untiled mapping (the whole-domain needs are materialized
/// at once and nothing else is ever held).
#[test]
fn capacity_bound_is_exact_for_untiled_fusion() {
    let arch = Arch::generic(1 << 20);
    for fs in &workload_pool() {
        let ev = Evaluator::new(fs, &arch).unwrap();
        let m = InterLayerMapping::untiled(Parallelism::Sequential);
        let lb = capacity_lower_bound(fs, &m);
        let metrics = ev.evaluate(&m).unwrap();
        assert_eq!(lb, metrics.occupancy_peak, "{}", fs.name);
    }
}

fn pruning_spec(algorithm: Algorithm, prune: bool) -> SearchSpec {
    SearchSpec {
        algorithm,
        objective: Objective::FeasibleEdp,
        seed: 7,
        samples: 120,
        mapspace: looptree::mapspace::MapSpaceConfig {
            schedules: vec![
                vec!["P2".into()],
                vec!["P2".into(), "Q2".into()],
                vec!["C2".into()],
            ],
            tile_sizes: vec![2, 4, 8, 16],
            ..Default::default()
        },
        prune,
        ..Default::default()
    }
}

/// Pruning on vs. off: same best mapping, same score (bit for bit), on both
/// batch algorithms, under capacity pressure where pruning actually fires.
#[test]
fn pruning_is_bit_identical_to_no_pruning() {
    let pool = Coordinator::new(2);
    // 2 KiB prunes every candidate (exercising the guard's re-evaluate-all
    // fallback), 32 KiB splits the space, 64 KiB prunes only the coarsest.
    for glb_kib in [2, 32, 64] {
        let arch = Arch::generic(glb_kib);
        let fs = workloads::conv_conv(28, 16);
        let ev = Evaluator::new(&fs, &arch).unwrap();
        for alg in [Algorithm::Exhaustive, Algorithm::Random] {
            let on = search::run(&ev, &pruning_spec(alg, true), &pool).unwrap();
            let off = search::run(&ev, &pruning_spec(alg, false), &pool).unwrap();
            let tag = format!("{glb_kib} KiB {alg:?}");
            assert_eq!(off.pruned, 0, "{tag}: prune=false must not prune");
            assert_eq!(
                on.best.score.to_bits(),
                off.best.score.to_bits(),
                "{tag}: best score"
            );
            assert_eq!(
                on.best.mapping.to_json().pretty(),
                off.best.mapping.to_json().pretty(),
                "{tag}: best mapping"
            );
            // Pruned candidates are exactly the ones missing from the
            // evaluated set (unless the guard re-evaluated everything,
            // which reports pruned = 0).
            assert_eq!(
                on.evaluated.len() + on.pruned,
                off.evaluated.len(),
                "{tag}: evaluated + pruned must cover the candidate set"
            );
        }
    }
}

/// Under severe capacity pressure the pruner must actually skip work — the
/// counter is wired through and nonzero.
#[test]
fn pruner_skips_provably_infeasible_candidates() {
    let pool = Coordinator::new(2);
    let fs = workloads::conv_conv(28, 16);
    // 32 KiB: fine row tilings fit comfortably, channel tilings and coarse
    // row tilings provably cannot — the pruner must fire, and the guard
    // must hold (the best survivor is feasible, far below any penalty).
    let arch = Arch::generic(32);
    let ev = Evaluator::new(&fs, &arch).unwrap();
    let res = search::run(&ev, &pruning_spec(Algorithm::Exhaustive, true), &pool).unwrap();
    assert!(
        res.pruned > 0,
        "expected pruned candidates under a 32 KiB GLB, got {:?} evaluated",
        res.evaluated.len()
    );
}
