//! Property-based tests for the rectilinear-region substrate: randomized
//! set-algebra identities checked against a brute-force point-set oracle.
//! Every other layer of the system (model, simulator, case studies) rests
//! on these operations being exact.

use looptree::poly::{AffineExpr, AffineMap, IBox, Interval, Region};
use looptree::util::prng::Prng;
use std::collections::HashSet;

const DIMS: usize = 3;
const COORD: i64 = 8; // small universe so the oracle is cheap

fn random_box(rng: &mut Prng) -> IBox {
    IBox::new(
        (0..DIMS)
            .map(|_| {
                let lo = rng.range_i64(-2, COORD);
                let hi = lo + rng.range_i64(0, 5);
                Interval::new(lo, hi)
            })
            .collect(),
    )
}

fn points(b: &IBox) -> HashSet<Vec<i64>> {
    let mut out = HashSet::new();
    if b.is_empty() {
        return out;
    }
    let mut c: Vec<i64> = b.dims.iter().map(|d| d.lo).collect();
    loop {
        out.insert(c.clone());
        let mut d = DIMS;
        loop {
            if d == 0 {
                return out;
            }
            d -= 1;
            c[d] += 1;
            if c[d] < b.dims[d].hi {
                break;
            }
            c[d] = b.dims[d].lo;
        }
    }
}

fn region_points(r: &Region) -> HashSet<Vec<i64>> {
    let mut out = HashSet::new();
    for b in r.boxes() {
        out.extend(points(b));
    }
    out
}

#[test]
fn region_ops_match_point_set_oracle() {
    let mut rng = Prng::new(0x901F);
    for case in 0..300 {
        let nboxes = 1 + rng.index(3);
        let mut r = Region::empty(DIMS);
        let mut oracle: HashSet<Vec<i64>> = HashSet::new();
        for _ in 0..nboxes {
            let b = random_box(&mut rng);
            r.union_box(&b);
            oracle.extend(points(&b));
        }
        // Volume == point count; representation stays disjoint.
        assert_eq!(r.volume() as usize, oracle.len(), "case {case}: union volume");
        assert_eq!(region_points(&r), oracle, "case {case}: union points");

        // Subtract a random box.
        let s = random_box(&mut rng);
        let sub = r.subtract_box(&s);
        let mut oracle_sub = oracle.clone();
        for p in points(&s) {
            oracle_sub.remove(&p);
        }
        assert_eq!(region_points(&sub), oracle_sub, "case {case}: subtract");

        // Intersect with a random box.
        let i = random_box(&mut rng);
        let inter = r.intersect_box(&i);
        let ipts = points(&i);
        let oracle_int: HashSet<_> = oracle.intersection(&ipts).cloned().collect();
        assert_eq!(region_points(&inter), oracle_int, "case {case}: intersect");

        // Coalesce preserves the set.
        let mut co = r.clone();
        co.coalesce();
        assert_eq!(region_points(&co), oracle, "case {case}: coalesce");
        assert!(co.complexity() <= r.complexity(), "case {case}: coalesce grew");
    }
}

#[test]
fn region_algebra_identities() {
    let mut rng = Prng::new(77);
    for case in 0..200 {
        let mut a = Region::empty(DIMS);
        let mut b = Region::empty(DIMS);
        for _ in 0..(1 + rng.index(2)) {
            a.union_box(&random_box(&mut rng));
            b.union_box(&random_box(&mut rng));
        }
        // (A − B) ∪ (A ∩ B) == A
        let mut rebuilt = a.subtract(&b);
        rebuilt.union(&a.intersect(&b));
        assert!(rebuilt.set_eq(&a), "case {case}: partition identity");
        // A − B and B are disjoint.
        assert_eq!(a.subtract(&b).intersect(&b).volume(), 0, "case {case}");
        // Inclusion-exclusion on volumes.
        let mut u = a.clone();
        u.union(&b);
        assert_eq!(
            u.volume(),
            a.volume() + b.volume() - a.intersect(&b).volume(),
            "case {case}: inclusion-exclusion"
        );
        // Containment is antisymmetric with set_eq.
        if a.contains_region(&b) && b.contains_region(&a) {
            assert!(a.set_eq(&b), "case {case}");
        }
    }
}

#[test]
fn affine_image_matches_pointwise_map() {
    let mut rng = Prng::new(1234);
    for case in 0..200 {
        // A random 2-term affine map with positive coefficients (the access
        // pattern family of our Einsums: p, p+r, 2p+r).
        let c0 = rng.range_i64(1, 3);
        let c1 = rng.range_i64(1, 3);
        let off = rng.range_i64(-2, 3);
        let expr = AffineExpr::sum((0, c0), (1, c1)).with_offset(off);
        let map = AffineMap::new(vec![expr.clone(), AffineExpr::var(2)]);
        let b = {
            // non-empty box only
            let mut bb = random_box(&mut rng);
            for d in bb.dims.iter_mut() {
                if d.is_empty() {
                    *d = Interval::new(d.lo, d.lo + 1);
                }
            }
            bb
        };
        let img = map.image_box(&b);
        // Oracle: apply the map to every point; image box must contain all
        // attained values and its bounds must be attained.
        let mut attained = HashSet::new();
        for p in points(&b) {
            let v0 = c0 * p[0] + c1 * p[1] + off;
            attained.insert((v0, p[2]));
            assert!(img.dims[0].contains(v0), "case {case}: {v0} not in {img}");
            assert!(img.dims[1].contains(p[2]), "case {case}");
        }
        let lo = attained.iter().map(|&(v, _)| v).min().unwrap();
        let hi = attained.iter().map(|&(v, _)| v).max().unwrap();
        assert_eq!(img.dims[0], Interval::new(lo, hi + 1), "case {case}: tight bounds");
    }
}

#[test]
fn preimage_roundtrip_identity_maps() {
    let mut rng = Prng::new(4321);
    for _ in 0..100 {
        let full = IBox::from_bounds(&[(0, 8), (0, 8), (0, 8)]);
        let map = AffineMap::identity(&[0, 2]);
        let mut data = IBox::new(vec![
            Interval::new(rng.range_i64(0, 4), rng.range_i64(4, 9)),
            Interval::new(rng.range_i64(0, 4), rng.range_i64(4, 9)),
        ]);
        // Clip to the full box's projection.
        data = data.intersect(&IBox::from_bounds(&[(0, 8), (0, 8)]));
        let ops = map.preimage_identity_box(&data, &full);
        // The image of the preimage is exactly the data box.
        let img = map.image_box(&ops);
        assert_eq!(img, data);
        // The preimage extends fully along the unmentioned dim.
        if !ops.is_empty() {
            assert_eq!(ops.dims[1], Interval::new(0, 8));
        }
    }
}
