//! Relative-link checker for the documentation layer: every `](path)`
//! markdown link in README.md, docs/*.md, and examples/configs/README.md
//! must resolve to a file that exists in the repository. External URLs and
//! in-page anchors are skipped. CI runs this as part of the serve-smoke
//! job, so a doc reorganization cannot silently strand links.

use std::path::{Path, PathBuf};

fn repo_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("..")
}

/// Extract `](target)` link targets from markdown text. Good enough for
/// the repo's docs: it scans for the literal `](` and reads to the
/// matching `)`, ignoring nested parentheses (none of our links have any).
fn link_targets(text: &str) -> Vec<String> {
    let mut out = Vec::new();
    let bytes = text.as_bytes();
    let mut i = 0;
    while let Some(pos) = text[i..].find("](") {
        let start = i + pos + 2;
        let Some(len) = text[start..].find(')') else { break };
        // Trim an optional markdown title suffix: `](path "title")`.
        let raw = &text[start..start + len];
        let target = raw.split_whitespace().next().unwrap_or("").to_string();
        out.push(target);
        i = start + len;
        if i >= bytes.len() {
            break;
        }
    }
    out
}

fn check_file(doc: &Path, errors: &mut Vec<String>) {
    let text = std::fs::read_to_string(doc)
        .unwrap_or_else(|e| panic!("read {}: {e}", doc.display()));
    let base = doc.parent().expect("doc file has a parent directory");
    for target in link_targets(&text) {
        if target.is_empty()
            || target.starts_with("http://")
            || target.starts_with("https://")
            || target.starts_with("mailto:")
            || target.starts_with('#')
        {
            continue;
        }
        let path_part = target.split('#').next().unwrap_or(&target);
        let resolved = base.join(path_part);
        if !resolved.exists() {
            errors.push(format!(
                "{}: broken relative link '{target}' (resolved {})",
                doc.display(),
                resolved.display()
            ));
        }
    }
}

#[test]
fn all_relative_doc_links_resolve() {
    let root = repo_root();
    let mut docs = vec![root.join("README.md"), root.join("examples/configs/README.md")];
    let docs_dir = root.join("docs");
    assert!(docs_dir.is_dir(), "docs/ directory is missing");
    let mut md_in_docs: Vec<PathBuf> = std::fs::read_dir(&docs_dir)
        .unwrap()
        .map(|e| e.unwrap().path())
        .filter(|p| p.extension().is_some_and(|x| x == "md"))
        .collect();
    md_in_docs.sort();
    assert!(
        md_in_docs.len() >= 3,
        "expected ARCHITECTURE/PROTOCOL/LINTS under docs/, found {md_in_docs:?}"
    );
    docs.extend(md_in_docs);
    let mut errors = Vec::new();
    for doc in &docs {
        assert!(doc.exists(), "documentation file missing: {}", doc.display());
        check_file(doc, &mut errors);
    }
    assert!(errors.is_empty(), "broken documentation links:\n{}", errors.join("\n"));
}

#[test]
fn link_extraction_handles_titles_and_anchors() {
    let md = "[a](docs/X.md) [b](https://example.com) [c](#local) [d](Y.md#sec) [e](Z.md \"t\")";
    let targets = link_targets(md);
    assert_eq!(targets, vec!["docs/X.md", "https://example.com", "#local", "Y.md#sec", "Z.md"]);
}
