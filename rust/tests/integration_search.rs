//! Search + coordinator integration: end-to-end DSE flows over real
//! workloads, checking search quality and coordinator determinism.

use looptree::arch::Arch;
use looptree::coordinator::Coordinator;
use looptree::einsum::workloads;
use looptree::mapspace::{pareto_front, MapSpace, MapSpaceConfig, ParetoPoint};
use looptree::model::Metrics;
use looptree::search;

fn edp(m: &Metrics) -> f64 {
    let p = if m.capacity_ok { 1.0 } else { 1e9 };
    p * m.latency_cycles as f64 * m.energy.total_pj()
}

#[test]
fn exhaustive_beats_or_matches_heuristics() {
    let fs = workloads::conv_conv(28, 32);
    let arch = Arch::generic(128);
    let pool = Coordinator::new(2);
    let cfg = MapSpaceConfig {
        schedules: vec![
            vec!["P2".into()],
            vec!["P2".into(), "Q2".into()],
            vec!["C2".into()],
        ],
        tile_sizes: vec![4, 8],
        ..Default::default()
    };
    let ex = search::exhaustive(&fs, &arch, &cfg, edp, &pool).unwrap();
    let ann = search::annealing(&fs, &arch, 300, 3, edp).unwrap();
    let gen_ = search::genetic(&fs, &arch, 16, 10, 3, edp, &pool).unwrap();
    // The restricted-space exhaustive optimum is a meaningful baseline: the
    // heuristics roam a larger space, so they may do better — but never
    // catastrophically worse.
    assert!(ann.best.score <= ex.best.score * 10.0);
    assert!(gen_.best.score <= ex.best.score * 10.0);
    // The exhaustive search over this restricted space must find the best
    // of its own evaluations (sanity).
    let min = ex.evaluated.iter().map(|s| s.score).fold(f64::INFINITY, f64::min);
    assert_eq!(ex.best.score, min);
}

#[test]
fn feasibility_under_capacity_pressure() {
    // With a tiny GLB the search must still find *feasible* mappings, and
    // they should be tiled (untiled fusion cannot fit).
    let fs = workloads::conv_conv(28, 64);
    let arch = Arch::generic(48); // 48 KiB
    let pool = Coordinator::new(2);
    let cfg = MapSpaceConfig::default();
    let res = search::exhaustive(&fs, &arch, &cfg, edp, &pool).unwrap();
    assert!(res.best.metrics.capacity_ok, "no feasible mapping found");
    assert!(
        !res.best.mapping.partitions.is_empty(),
        "a tiled mapping is required at this capacity"
    );
}

#[test]
fn pareto_front_from_search_results() {
    let fs = workloads::conv_conv(28, 32);
    let arch = Arch::generic(1 << 20).unbounded_glb();
    let pool = Coordinator::new(2);
    let cfg = MapSpaceConfig {
        schedules: vec![vec!["P2".into()], vec!["C2".into()]],
        tile_sizes: vec![2, 4, 8],
        ..Default::default()
    };
    let res = search::exhaustive(&fs, &arch, &cfg, |m| m.occupancy_peak as f64, &pool).unwrap();
    let pts: Vec<ParetoPoint<()>> = res
        .evaluated
        .iter()
        .map(|s| ParetoPoint {
            x: s.metrics.occupancy_peak as f64,
            y: s.metrics.offchip_total() as f64,
            payload: (),
        })
        .collect();
    let front = pareto_front(pts);
    assert!(!front.is_empty());
    // Fronts are monotone: increasing capacity never increases transfers.
    for w in front.windows(2) {
        assert!(w[0].x < w[1].x && w[0].y > w[1].y);
    }
}

#[test]
fn mapspace_counts_scale_with_constraints() {
    let fs = workloads::pwise_dwise_pwise(28, 16);
    let base = MapSpaceConfig {
        schedules: vec![vec!["P3".into(), "Q3".into()]],
        tile_sizes: vec![4],
        ..Default::default()
    };
    let full = MapSpace::enumerate(&fs, &base);
    let uniform = MapSpace::enumerate(
        &fs,
        &MapSpaceConfig { uniform_retention: true, ..base.clone() },
    );
    // Per-tensor retention: (k+1)^(#non-output tensors) per tile point vs
    // k+1 for uniform.
    assert!(full.len() > 10 * uniform.len());
}

#[test]
fn coordinator_scales_workers() {
    // Same results regardless of worker count (already covered), and no
    // deadlocks with more workers than jobs.
    let pool = Coordinator::new(16);
    let out = pool.run(3, |i| i + 1);
    assert_eq!(out, vec![1, 2, 3]);
}
