//! Search + coordinator integration: end-to-end DSE flows over real
//! workloads through the unified `search::run` entry, checking search
//! quality, determinism, and coordinator behavior.

use looptree::arch::Arch;
use looptree::coordinator::Coordinator;
use looptree::einsum::workloads;
use looptree::mapspace::{pareto_front, MapSpace, MapSpaceConfig, ParetoPoint};
use looptree::model::Evaluator;
use looptree::search::{self, Algorithm, Objective, SearchSpec};

fn spec(algorithm: Algorithm) -> SearchSpec {
    SearchSpec {
        algorithm,
        objective: Objective::FeasibleEdp,
        seed: 3,
        samples: 300,
        iters: 300,
        population: 16,
        generations: 10,
        ..Default::default()
    }
}

#[test]
fn exhaustive_beats_or_matches_heuristics() {
    let fs = workloads::conv_conv(28, 32);
    let arch = Arch::generic(128);
    let ev = Evaluator::new(&fs, &arch).unwrap();
    let pool = Coordinator::new(2);
    let ex_spec = SearchSpec {
        mapspace: MapSpaceConfig {
            schedules: vec![
                vec!["P2".into()],
                vec!["P2".into(), "Q2".into()],
                vec!["C2".into()],
            ],
            tile_sizes: vec![4, 8],
            ..Default::default()
        },
        ..spec(Algorithm::Exhaustive)
    };
    let ex = search::run(&ev, &ex_spec, &pool).unwrap();
    let ann = search::run(&ev, &spec(Algorithm::Annealing), &pool).unwrap();
    let gen_ = search::run(&ev, &spec(Algorithm::Genetic), &pool).unwrap();
    // The restricted-space exhaustive optimum is a meaningful baseline: the
    // heuristics roam a larger space, so they may do better — but never
    // catastrophically worse.
    assert!(ann.best.score <= ex.best.score * 10.0);
    assert!(gen_.best.score <= ex.best.score * 10.0);
    // The exhaustive search over this restricted space must find the best
    // of its own evaluations (sanity).
    let min = ex.evaluated.iter().map(|s| s.score).fold(f64::INFINITY, f64::min);
    assert_eq!(ex.best.score, min);
}

#[test]
fn feasibility_under_capacity_pressure() {
    // With a tiny GLB the search must still find *feasible* mappings, and
    // they should be tiled (untiled fusion cannot fit).
    let fs = workloads::conv_conv(28, 64);
    let arch = Arch::generic(48); // 48 KiB
    let ev = Evaluator::new(&fs, &arch).unwrap();
    let pool = Coordinator::new(2);
    let res = search::run(&ev, &spec(Algorithm::Exhaustive), &pool).unwrap();
    assert!(res.best.metrics.capacity_ok, "no feasible mapping found");
    assert!(
        !res.best.mapping.partitions.is_empty(),
        "a tiled mapping is required at this capacity"
    );
}

#[test]
fn search_is_deterministic_for_a_spec() {
    // The round-trip contract: the same (workload, arch, spec) triple must
    // reproduce the same best mapping — this is what lets a `--json` result
    // document be re-fed as a config.
    let fs = workloads::conv_conv(28, 32);
    let arch = Arch::generic(128);
    let ev = Evaluator::new(&fs, &arch).unwrap();
    for algorithm in [
        Algorithm::Exhaustive,
        Algorithm::Random,
        Algorithm::Annealing,
        Algorithm::Genetic,
    ] {
        let s = SearchSpec {
            samples: 80,
            iters: 80,
            population: 8,
            generations: 4,
            mapspace: MapSpaceConfig {
                schedules: vec![vec!["P2".into()], vec!["C2".into()]],
                tile_sizes: vec![4, 8],
                ..Default::default()
            },
            ..spec(algorithm)
        };
        let a = search::run(&ev, &s, &Coordinator::new(4)).unwrap();
        let b = search::run(&ev, &s, &Coordinator::new(1)).unwrap();
        assert_eq!(
            a.best.mapping, b.best.mapping,
            "{algorithm:?}: best mapping must not depend on worker count"
        );
        assert_eq!(a.best.score.to_bits(), b.best.score.to_bits(), "{algorithm:?}");
    }
}

#[test]
fn pareto_front_from_search_results() {
    let fs = workloads::conv_conv(28, 32);
    let arch = Arch::generic(1 << 20).unbounded_glb();
    let ev = Evaluator::new(&fs, &arch).unwrap();
    let pool = Coordinator::new(2);
    let s = SearchSpec {
        objective: Objective::Capacity,
        mapspace: MapSpaceConfig {
            schedules: vec![vec!["P2".into()], vec!["C2".into()]],
            tile_sizes: vec![2, 4, 8],
            ..Default::default()
        },
        ..spec(Algorithm::Exhaustive)
    };
    let res = search::run(&ev, &s, &pool).unwrap();
    let pts: Vec<ParetoPoint<()>> = res
        .evaluated
        .iter()
        .map(|sc| ParetoPoint {
            x: sc.metrics.occupancy_peak as f64,
            y: sc.metrics.offchip_total() as f64,
            payload: (),
        })
        .collect();
    let front = pareto_front(pts);
    assert!(!front.is_empty());
    // Fronts are monotone: increasing capacity never increases transfers.
    for w in front.windows(2) {
        assert!(w[0].x < w[1].x && w[0].y > w[1].y);
    }
}

#[test]
fn mapspace_counts_scale_with_constraints() {
    let fs = workloads::pwise_dwise_pwise(28, 16);
    let base = MapSpaceConfig {
        schedules: vec![vec!["P3".into(), "Q3".into()]],
        tile_sizes: vec![4],
        ..Default::default()
    };
    let full = MapSpace::enumerate(&fs, &base);
    let uniform = MapSpace::enumerate(
        &fs,
        &MapSpaceConfig { uniform_retention: true, ..base.clone() },
    );
    // Per-tensor retention: (k+1)^(#non-output tensors) per tile point vs
    // k+1 for uniform.
    assert!(full.len() > 10 * uniform.len());
}

#[test]
fn coordinator_scales_workers() {
    // Same results regardless of worker count (already covered), and no
    // deadlocks with more workers than jobs.
    let pool = Coordinator::new(16);
    let out = pool.run(3, |i| i + 1);
    assert_eq!(out, vec![1, 2, 3]);
}
