//! Golden lint corpus: every deliberately-malformed spec under
//! `examples/lint/` must produce exactly its expected `LT0xx` codes, and
//! every shipped example config under `examples/configs/` must lint clean.

use looptree::analysis::lint_document;
use looptree::util::json::Json;

fn lint_file(path: &str) -> looptree::analysis::LintReport {
    let text = std::fs::read_to_string(path).unwrap_or_else(|e| panic!("{path}: {e}"));
    let doc = Json::parse(&text).unwrap_or_else(|e| panic!("{path}: {e}"));
    lint_document(&doc)
}

#[test]
fn malformed_corpus_is_golden() {
    // (file, expected codes in order, expected exit code)
    let corpus: &[(&str, &[&str], i32)] = &[
        ("bad_shape.json", &["LT001"], 2),
        ("bad_workload.json", &["LT002"], 2),
        ("bad_mapping_dim.json", &["LT004"], 2),
        ("bad_capacity.json", &["LT005"], 1),
        ("bad_retention_output.json", &["LT006"], 1),
        ("bad_degenerate_partition.json", &["LT007"], 1),
        ("bad_reduction_partition.json", &["LT008"], 1),
        ("bad_zero_budget.json", &["LT009"], 1),
        ("bad_mapspace_rank.json", &["LT010", "LT010"], 2),
    ];
    for &(file, expected, exit) in corpus {
        let path = format!("../examples/lint/{file}");
        let report = lint_file(&path);
        let codes: Vec<&str> = report.diagnostics.iter().map(|d| d.code).collect();
        assert_eq!(codes, expected, "{file}: {:#?}", report.diagnostics);
        assert_eq!(report.exit_code(), exit, "{file}");
        for d in &report.diagnostics {
            assert!(!d.message.is_empty(), "{file}: empty message");
            assert!(!d.hint.is_empty(), "{file}: empty hint");
        }
    }
}

#[test]
fn malformed_network_corpus_is_golden() {
    // (file, expected codes in order, expected severity-derived exit code,
    // expected path of the first diagnostic)
    let corpus: &[(&str, &[&str], i32, &str)] = &[
        ("bad_edge_shape.json", &["LT101"], 2, "network.nodes[4]"),
        ("bad_dangling_node.json", &["LT102"], 1, "network.nodes[1]"),
        ("bad_cuts_multisink.json", &["LT103"], 2, "cuts[0]"),
        ("bad_interior_pad.json", &["LT104"], 2, "cuts"),
        ("bad_residual_parity.json", &["LT105"], 2, "cuts"),
        ("bad_glb_segment.json", &["LT106"], 1, "cuts"),
    ];
    for &(file, expected, exit, path) in corpus {
        let report = lint_file(&format!("../examples/lint/network/{file}"));
        let codes: Vec<&str> = report.diagnostics.iter().map(|d| d.code).collect();
        assert_eq!(codes, expected, "{file}: {:#?}", report.diagnostics);
        assert_eq!(report.exit_code(), exit, "{file}");
        assert_eq!(report.diagnostics[0].path, path, "{file}");
        for d in &report.diagnostics {
            assert!(!d.message.is_empty(), "{file}: empty message");
            assert!(!d.hint.is_empty(), "{file}: empty hint");
        }
    }
}

#[test]
fn corpus_directory_is_fully_pinned() {
    // Every file in examples/lint/ must appear in the golden table above —
    // adding a corpus file without pinning its codes is an error.
    let mut files: Vec<String> = std::fs::read_dir("../examples/lint")
        .unwrap()
        .map(|e| e.unwrap().file_name().into_string().unwrap())
        .collect();
    files.sort();
    assert_eq!(
        files,
        vec![
            "bad_capacity.json",
            "bad_degenerate_partition.json",
            "bad_mapping_dim.json",
            "bad_mapspace_rank.json",
            "bad_reduction_partition.json",
            "bad_retention_output.json",
            "bad_shape.json",
            "bad_workload.json",
            "bad_zero_budget.json",
            "network",
        ]
    );
    // Same rule for the network corpus subdirectory.
    let mut files: Vec<String> = std::fs::read_dir("../examples/lint/network")
        .unwrap()
        .map(|e| e.unwrap().file_name().into_string().unwrap())
        .collect();
    files.sort();
    assert_eq!(
        files,
        vec![
            "bad_cuts_multisink.json",
            "bad_dangling_node.json",
            "bad_edge_shape.json",
            "bad_glb_segment.json",
            "bad_interior_pad.json",
            "bad_residual_parity.json",
        ]
    );
}

#[test]
fn shipped_example_configs_lint_clean() {
    let mut checked = 0;
    for entry in std::fs::read_dir("../examples/configs").unwrap() {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("json") {
            continue;
        }
        let report = lint_file(path.to_str().unwrap());
        assert_eq!(
            report.exit_code(),
            0,
            "{}: {:#?}",
            path.display(),
            report.diagnostics
        );
        checked += 1;
    }
    assert!(checked >= 5, "expected the shipped example configs");
}

#[test]
fn diagnostics_serialize_with_stable_fields() {
    let report = lint_file("../examples/lint/bad_mapspace_rank.json");
    let json = report.to_json();
    assert_eq!(json.get("exit_code").and_then(Json::as_f64), Some(2.0));
    let diags = json.get("diagnostics").and_then(Json::as_arr).unwrap();
    assert_eq!(diags.len(), 2);
    for d in diags {
        for key in ["code", "severity", "path", "message", "hint"] {
            assert!(d.get(key).is_some(), "missing {key}");
        }
    }
    // Paths point into the mapspace section.
    assert_eq!(
        diags[0].get("path").and_then(Json::as_str),
        Some("search.mapspace.schedules[0][1]")
    );
    assert_eq!(
        diags[1].get("path").and_then(Json::as_str),
        Some("search.mapspace.tile_sizes[2]")
    );
}
