//! Symbolic box walk vs. the reference walk.
//!
//! The closed-form symbolic tier (see `model::engine` and
//! `analysis::symbolic`) must be *bit-identical* to both the steady-state
//! fast path and the exhaustive reference walk — every integer count and
//! every derived `f64` down to the last bit — on the five validation
//! designs and on randomized (workload, mapping) pairs covering ragged
//! tiles, repartitioned ranks, per-tensor retention, and both parallelism
//! modes. Beyond agreement, this suite pins *coverage*: the symbolic walk
//! must actually fire (`Metrics::path.symbolic`) on every canonical
//! workload under single output-rank partitions, so the closed-form path is
//! known to be exercised rather than vacuously falling back.

use std::collections::HashMap;

use looptree::analysis::SessionStatics;
use looptree::arch::Arch;
use looptree::einsum::{workloads, FusionSet, TensorId};
use looptree::mapping::{InterLayerMapping, Parallelism, Partition};
use looptree::model::Evaluator;
use looptree::util::prng::Prng;
use looptree::validation::{design_points, Scale};

fn workload_pool() -> Vec<FusionSet> {
    vec![
        workloads::conv_conv(20, 4),
        workloads::conv_conv_conv(16, 4),
        workloads::pwise_dwise_pwise(12, 3),
        workloads::fc_fc(24, 8),
        workloads::self_attention(1, 2, 12, 4),
    ]
}

/// All three tiers on one mapping, compared field-for-field via the full
/// `Debug` rendering with the diagnostic path attribution neutralized.
fn assert_tiers_equal(ev: &Evaluator, mapping: &InterLayerMapping, tag: &str) {
    let mut sym = ev
        .evaluate(mapping)
        .unwrap_or_else(|e| panic!("{tag}: default path: {e}"));
    let mut fast = ev
        .evaluate_no_symbolic(mapping)
        .unwrap_or_else(|e| panic!("{tag}: fast path: {e}"));
    let mut reference = ev
        .evaluate_reference(mapping)
        .unwrap_or_else(|e| panic!("{tag}: reference: {e}"));
    sym.path = Default::default();
    fast.path = Default::default();
    reference.path = Default::default();
    assert_eq!(
        format!("{sym:?}"),
        format!("{reference:?}"),
        "{tag}: symbolic vs reference"
    );
    assert_eq!(
        format!("{fast:?}"),
        format!("{reference:?}"),
        "{tag}: fast vs reference"
    );
}

/// A randomized mapping: 0–3 partition levels with ragged tiles — the same
/// rank may be re-partitioned at a nested tile size — random per-tensor
/// retention, both parallelisms.
fn random_mapping(fs: &FusionSet, rng: &mut Prng) -> InterLayerMapping {
    let last = fs.last();
    let mut partitions: Vec<Partition> = Vec::new();
    let mut extents: HashMap<usize, i64> = HashMap::new();
    for _ in 0..rng.index(4) {
        let dim = rng.index(last.ndim());
        let extent = *extents.get(&dim).unwrap_or(&last.rank_sizes[dim]);
        if extent < 2 {
            continue;
        }
        let tile = rng.range_i64(1, extent);
        partitions.push(Partition { dim, tile });
        extents.insert(dim, tile);
    }
    let parallelism = if rng.chance(0.5) {
        Parallelism::Sequential
    } else {
        Parallelism::Pipeline
    };
    let k = partitions.len();
    let mut m = InterLayerMapping::tiled(partitions, parallelism);
    for x in 0..fs.tensors.len() {
        if rng.chance(0.5) {
            m = m.with_retention(TensorId(x), rng.index(k + 1));
        }
    }
    m
}

/// The five validation designs (DepFin, Fused-layer CNN, ISAAC, PipeLayer,
/// FLAT) through all three tiers — the acceptance gate of the symbolic path.
#[test]
fn five_validation_designs_identical_through_all_tiers() {
    for point in design_points(Scale::Test) {
        // As the validation drivers run them (unbounded GLB) …
        let ev = Evaluator::new(&point.fs, &point.arch.unbounded_glb())
            .unwrap_or_else(|e| panic!("{}: {e}", point.design));
        assert_tiers_equal(&ev, &point.mapping, point.design);
        // … and with the real capacity bound (capacity_ok included).
        let ev = Evaluator::new(&point.fs, &point.arch).unwrap();
        assert_tiers_equal(&ev, &point.mapping, &format!("{} (bounded)", point.design));
    }
}

/// Randomized mappings — ragged tiles, nested re-partitions, mixed
/// retention, both parallelisms — through all three tiers. Whether the
/// symbolic walk covers a mapping or bails mid-walk, the result must be
/// bit-identical.
#[test]
fn randomized_mappings_identical_through_all_tiers() {
    let mut rng = Prng::new(0x5711_B0CE);
    let arch = Arch::generic(1 << 13);
    for fs in &workload_pool() {
        let ev = Evaluator::new(fs, &arch).unwrap();
        for sub in 0..10 {
            let m = random_mapping(fs, &mut rng);
            if m.total_iterations(fs) > 20_000 {
                continue;
            }
            assert_tiers_equal(&ev, &m, &format!("{} #{sub}", fs.name));
        }
    }
}

/// Coverage pin: on every canonical workload, every single output-rank
/// partition with default retention must be evaluated by the symbolic walk
/// end to end — `Metrics::path.symbolic` set and the walked-leaf counter
/// live. If a refactor silently demotes these schedules to the region walk,
/// this fails rather than letting the closed-form tier go vacuous.
#[test]
fn symbolic_walk_fires_on_every_canonical_workload() {
    let arch = Arch::generic(1 << 14);
    for fs in &workload_pool() {
        let st = SessionStatics::build(fs);
        let ev = Evaluator::new(fs, &arch).unwrap();
        let last = fs.last();
        let mut exercised = 0;
        for dim in st.out_dims.clone() {
            let extent = last.rank_sizes[dim];
            if extent < 4 {
                continue;
            }
            for tile in [1, 2] {
                let m = InterLayerMapping::tiled(
                    vec![Partition { dim, tile }],
                    Parallelism::Sequential,
                );
                let tag = format!("{} dim {dim} tile {tile}", fs.name);
                let metrics = ev.evaluate(&m).unwrap();
                assert!(metrics.path.symbolic, "{tag}: symbolic walk fell back");
                assert!(
                    metrics.path.walked_iterations >= 1,
                    "{tag}: symbolic walk visited no leaves"
                );
                exercised += 1;
            }
        }
        assert!(
            exercised > 0,
            "{}: no output rank was long enough to exercise the symbolic walk",
            fs.name
        );
    }
}
