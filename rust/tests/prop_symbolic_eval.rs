//! Symbolic box walk vs. the reference walk.
//!
//! The closed-form symbolic tier (see `model::engine` and
//! `analysis::symbolic`) must be *bit-identical* to both the steady-state
//! fast path and the exhaustive reference walk — every integer count and
//! every derived `f64` down to the last bit — on the five validation
//! designs and on randomized (workload, mapping) pairs covering ragged
//! tiles, repartitioned ranks, per-tensor retention, and both parallelism
//! modes. Beyond agreement, this suite pins *coverage*: the symbolic walk
//! must actually fire (`Metrics::path.symbolic`) on every canonical
//! workload under single output-rank partitions — and, since the bounded
//! box-union calculus, under row+column (two output-rank) partitions too,
//! including ragged and nested variants. Width itself is pinned: the
//! retention-0 row+column tilings must report `peak_union_width == 2` on
//! the spatial workloads (multibox path genuinely live), width 1 on the
//! disjoint-projection `fc_fc`, and a hand-built width-3 overflow must
//! refuse bit-identically and be memoized, so the closed-form path is
//! known to be exercised rather than vacuously falling back.

use std::collections::HashMap;

use looptree::analysis::SessionStatics;
use looptree::arch::Arch;
use looptree::einsum::{workloads, FusionSet, TensorId};
use looptree::mapping::{InterLayerMapping, Parallelism, Partition};
use looptree::model::Evaluator;
use looptree::util::prng::Prng;
use looptree::validation::{design_points, Scale};

fn workload_pool() -> Vec<FusionSet> {
    vec![
        workloads::conv_conv(20, 4),
        workloads::conv_conv_conv(16, 4),
        workloads::pwise_dwise_pwise(12, 3),
        workloads::fc_fc(24, 8),
        workloads::self_attention(1, 2, 12, 4),
    ]
}

/// All three tiers on one mapping, compared field-for-field via the full
/// `Debug` rendering with the diagnostic path attribution neutralized.
fn assert_tiers_equal(ev: &Evaluator, mapping: &InterLayerMapping, tag: &str) {
    let mut sym = ev
        .evaluate(mapping)
        .unwrap_or_else(|e| panic!("{tag}: default path: {e}"));
    let mut fast = ev
        .evaluate_no_symbolic(mapping)
        .unwrap_or_else(|e| panic!("{tag}: fast path: {e}"));
    let mut reference = ev
        .evaluate_reference(mapping)
        .unwrap_or_else(|e| panic!("{tag}: reference: {e}"));
    sym.path = Default::default();
    fast.path = Default::default();
    reference.path = Default::default();
    assert_eq!(
        format!("{sym:?}"),
        format!("{reference:?}"),
        "{tag}: symbolic vs reference"
    );
    assert_eq!(
        format!("{fast:?}"),
        format!("{reference:?}"),
        "{tag}: fast vs reference"
    );
}

/// A randomized mapping: 0–3 partition levels with ragged tiles — the same
/// rank may be re-partitioned at a nested tile size — random per-tensor
/// retention, both parallelisms.
fn random_mapping(fs: &FusionSet, rng: &mut Prng) -> InterLayerMapping {
    let last = fs.last();
    let mut partitions: Vec<Partition> = Vec::new();
    let mut extents: HashMap<usize, i64> = HashMap::new();
    for _ in 0..rng.index(4) {
        let dim = rng.index(last.ndim());
        let extent = *extents.get(&dim).unwrap_or(&last.rank_sizes[dim]);
        if extent < 2 {
            continue;
        }
        let tile = rng.range_i64(1, extent);
        partitions.push(Partition { dim, tile });
        extents.insert(dim, tile);
    }
    let parallelism = if rng.chance(0.5) {
        Parallelism::Sequential
    } else {
        Parallelism::Pipeline
    };
    let k = partitions.len();
    let mut m = InterLayerMapping::tiled(partitions, parallelism);
    for x in 0..fs.tensors.len() {
        if rng.chance(0.5) {
            m = m.with_retention(TensorId(x), rng.index(k + 1));
        }
    }
    m
}

/// A randomized row+column output tiling: two partition levels drawn from
/// distinct *output* ranks of the sink (so the symbolic gate is open),
/// ragged tiles, an optional nested re-partition of the first rank, either
/// parallelism, and either full (`tiled`) or whole-tensor (level 0)
/// retention — the mapping family the bounded box-union tier was built
/// for. Returns the mapping plus whether the symbolic walk is *required*
/// to cover it (two-level full-retention tilings must never fall back;
/// deeper or retention-0 variants may legitimately refuse).
fn random_out_tiling(
    fs: &FusionSet,
    st: &SessionStatics,
    rng: &mut Prng,
) -> Option<(InterLayerMapping, bool)> {
    let last = fs.last();
    let dims: Vec<usize> = st
        .out_dims
        .iter()
        .copied()
        .filter(|&d| last.rank_sizes[d] >= 2)
        .collect();
    if dims.len() < 2 {
        return None;
    }
    let a = dims[rng.index(dims.len())];
    let b = loop {
        let b = dims[rng.index(dims.len())];
        if b != a {
            break b;
        }
    };
    let ta = rng.range_i64(1, last.rank_sizes[a]);
    let tb = rng.range_i64(1, last.rank_sizes[b]);
    let mut partitions = vec![Partition { dim: a, tile: ta }, Partition { dim: b, tile: tb }];
    let nested = ta >= 2 && rng.chance(0.4);
    if nested {
        partitions.push(Partition { dim: a, tile: rng.range_i64(1, ta) });
    }
    let parallelism = if rng.chance(0.5) {
        Parallelism::Sequential
    } else {
        Parallelism::Pipeline
    };
    let m = InterLayerMapping::tiled(partitions, parallelism);
    let whole_tensor = rng.chance(0.33);
    let must_cover = !nested && !whole_tensor;
    let m = if whole_tensor {
        m.with_uniform_retention(0)
    } else {
        m
    };
    Some((m, must_cover))
}

/// The five validation designs (DepFin, Fused-layer CNN, ISAAC, PipeLayer,
/// FLAT) through all three tiers — the acceptance gate of the symbolic path.
#[test]
fn five_validation_designs_identical_through_all_tiers() {
    for point in design_points(Scale::Test) {
        // As the validation drivers run them (unbounded GLB) …
        let ev = Evaluator::new(&point.fs, &point.arch.unbounded_glb())
            .unwrap_or_else(|e| panic!("{}: {e}", point.design));
        assert_tiers_equal(&ev, &point.mapping, point.design);
        // … and with the real capacity bound (capacity_ok included).
        let ev = Evaluator::new(&point.fs, &point.arch).unwrap();
        assert_tiers_equal(&ev, &point.mapping, &format!("{} (bounded)", point.design));
    }
}

/// Randomized mappings — ragged tiles, nested re-partitions, mixed
/// retention, both parallelisms — through all three tiers. Whether the
/// symbolic walk covers a mapping or bails mid-walk, the result must be
/// bit-identical.
#[test]
fn randomized_mappings_identical_through_all_tiers() {
    let mut rng = Prng::new(0x5711_B0CE);
    let arch = Arch::generic(1 << 13);
    for fs in &workload_pool() {
        let ev = Evaluator::new(fs, &arch).unwrap();
        for sub in 0..10 {
            let m = random_mapping(fs, &mut rng);
            if m.total_iterations(fs) > 20_000 {
                continue;
            }
            assert_tiers_equal(&ev, &m, &format!("{} #{sub}", fs.name));
        }
    }
}

/// Coverage pin: on every canonical workload, every single output-rank
/// partition with default retention must be evaluated by the symbolic walk
/// end to end — `Metrics::path.symbolic` set and the walked-leaf counter
/// live. If a refactor silently demotes these schedules to the region walk,
/// this fails rather than letting the closed-form tier go vacuous.
#[test]
fn symbolic_walk_fires_on_every_canonical_workload() {
    let arch = Arch::generic(1 << 14);
    for fs in &workload_pool() {
        let st = SessionStatics::build(fs);
        let ev = Evaluator::new(fs, &arch).unwrap();
        let last = fs.last();
        let mut exercised = 0;
        for dim in st.out_dims.clone() {
            let extent = last.rank_sizes[dim];
            if extent < 4 {
                continue;
            }
            for tile in [1, 2] {
                let m = InterLayerMapping::tiled(
                    vec![Partition { dim, tile }],
                    Parallelism::Sequential,
                );
                let tag = format!("{} dim {dim} tile {tile}", fs.name);
                let metrics = ev.evaluate(&m).unwrap();
                assert!(metrics.path.symbolic, "{tag}: symbolic walk fell back");
                assert!(
                    metrics.path.walked_iterations >= 1,
                    "{tag}: symbolic walk visited no leaves"
                );
                exercised += 1;
            }
        }
        assert!(
            exercised > 0,
            "{}: no output rank was long enough to exercise the symbolic walk",
            fs.name
        );
    }
}

/// Randomized row+column output tilings — ragged tiles, nested
/// re-partitions, pipeline and sequential, full and whole-tensor
/// retention — through all three tiers. Two-level full-retention tilings
/// must additionally be *covered* by the symbolic walk (the bounded
/// box-union calculus keeps every transient set within width 2 there);
/// deeper or retention-0 variants may refuse, but must stay bit-identical
/// either way.
#[test]
fn randomized_row_column_tilings_identical_through_all_tiers() {
    let mut rng = Prng::new(0xB0C5_E7D1);
    let arch = Arch::generic(1 << 13);
    for fs in &workload_pool() {
        let st = SessionStatics::build(fs);
        let ev = Evaluator::new(fs, &arch).unwrap();
        for sub in 0..12 {
            let Some((m, must_cover)) = random_out_tiling(fs, &st, &mut rng) else {
                break;
            };
            if m.total_iterations(fs) > 20_000 {
                continue;
            }
            let tag = format!("{} 2-D #{sub}", fs.name);
            assert_tiers_equal(&ev, &m, &tag);
            if must_cover {
                let metrics = ev.evaluate(&m).unwrap();
                assert!(
                    metrics.path.symbolic,
                    "{tag}: two-level full-retention output tiling fell back \
                     (schedule {}, tiles {:?})",
                    m.schedule_string(fs),
                    m.partitions.iter().map(|p| p.tile).collect::<Vec<_>>()
                );
            }
        }
    }
}

/// Coverage pin for the bounded box-union tier: on every canonical
/// workload, *pairs* of output ranks — row+column tilings, with ragged
/// tiles and a nested re-partition — must be covered by the symbolic walk
/// end to end under full retention (single-box or multibox as the shapes
/// demand). Before the union calculus these schedules all fell back to the
/// region walk at the first wrap leaf.
#[test]
fn symbolic_walk_fires_on_row_plus_column_tilings() {
    let arch = Arch::generic(1 << 14);
    for fs in &workload_pool() {
        let st = SessionStatics::build(fs);
        let ev = Evaluator::new(fs, &arch).unwrap();
        let last = fs.last();
        let dims: Vec<usize> = st
            .out_dims
            .iter()
            .copied()
            .filter(|&d| last.rank_sizes[d] >= 4)
            .collect();
        let mut exercised = 0;
        for (i, &a) in dims.iter().enumerate() {
            for &b in &dims[i + 1..] {
                // (1,1): unit tiles; (2,3): ragged on any extent not
                // divisible by the tile.
                for (ta, tb) in [(1i64, 1i64), (2, 3)] {
                    let m = InterLayerMapping::tiled(
                        vec![Partition { dim: a, tile: ta }, Partition { dim: b, tile: tb }],
                        Parallelism::Sequential,
                    );
                    let tag = format!(
                        "{} dims ({},{}) tiles ({ta},{tb})",
                        fs.name, last.rank_names[a], last.rank_names[b]
                    );
                    assert_tiers_equal(&ev, &m, &tag);
                    let metrics = ev.evaluate(&m).unwrap();
                    assert!(metrics.path.symbolic, "{tag}: symbolic walk fell back");
                    assert!(
                        (1..=2).contains(&metrics.path.peak_union_width),
                        "{tag}: covered walk reported peak union width {}",
                        metrics.path.peak_union_width
                    );
                    exercised += 1;
                }
                // Nested re-partition of the first rank under the column
                // split: [(a,4), (b,2), (a,1)].
                if last.rank_sizes[a] >= 8 {
                    let m = InterLayerMapping::tiled(
                        vec![
                            Partition { dim: a, tile: 4 },
                            Partition { dim: b, tile: 2 },
                            Partition { dim: a, tile: 1 },
                        ],
                        Parallelism::Sequential,
                    );
                    let tag = format!(
                        "{} nested ({},{})",
                        fs.name, last.rank_names[a], last.rank_names[b]
                    );
                    assert_tiers_equal(&ev, &m, &tag);
                    let metrics = ev.evaluate(&m).unwrap();
                    assert!(metrics.path.symbolic, "{tag}: symbolic walk fell back");
                    exercised += 1;
                }
            }
        }
        assert!(
            exercised > 0,
            "{}: no output-rank pair was long enough to exercise the multibox walk",
            fs.name
        );
    }
}

/// Width pin: under whole-tensor (level 0) retention, row+column tilings
/// accumulate genuine two-box availability unions — a completed band plus
/// the partial row in flight — so the walk must report the multibox path
/// (`peak_union_width == 2`) on the spatial workloads. `fc_fc` is the
/// documented single-box exception: its two output ranks (`M2`, `E2`)
/// project to *disjoint* tensors (the intermediate sees only `M2`, the
/// second filter only `E2`) and nothing has halos, so every set stays one
/// box and the walk reports width 1.
#[test]
fn multibox_width_pinned_per_workload() {
    let arch = Arch::generic(1 << 14);
    let spatial = [
        (workloads::conv_conv(20, 4), "P2", "Q2"),
        (workloads::conv_conv_conv(16, 4), "P3", "Q3"),
        (workloads::pwise_dwise_pwise(12, 3), "P3", "Q3"),
        (workloads::self_attention(1, 2, 12, 4), "H2", "M2"),
    ];
    for (fs, ra, rb) in &spatial {
        let ev = Evaluator::new(fs, &arch).unwrap();
        let last = fs.last();
        let m = InterLayerMapping::tiled(
            vec![
                Partition { dim: last.rank_index(ra).unwrap(), tile: 1 },
                Partition { dim: last.rank_index(rb).unwrap(), tile: 1 },
            ],
            Parallelism::Sequential,
        )
        .with_uniform_retention(0);
        let tag = format!("{} ({ra},{rb}) retention 0", fs.name);
        assert_tiers_equal(&ev, &m, &tag);
        let metrics = ev.evaluate(&m).unwrap();
        assert!(metrics.path.symbolic, "{tag}: symbolic walk fell back");
        assert_eq!(
            metrics.path.peak_union_width, 2,
            "{tag}: expected the multibox path"
        );
    }

    let fs = workloads::fc_fc(24, 8);
    let ev = Evaluator::new(&fs, &arch).unwrap();
    let last = fs.last();
    let m = InterLayerMapping::tiled(
        vec![
            Partition { dim: last.rank_index("M2").unwrap(), tile: 1 },
            Partition { dim: last.rank_index("E2").unwrap(), tile: 1 },
        ],
        Parallelism::Sequential,
    )
    .with_uniform_retention(0);
    assert_tiers_equal(&ev, &m, "fc_fc (M2,E2) retention 0");
    let metrics = ev.evaluate(&m).unwrap();
    assert!(metrics.path.symbolic, "fc_fc (M2,E2): symbolic walk fell back");
    assert_eq!(
        metrics.path.peak_union_width, 1,
        "fc_fc (M2,E2): disjoint projections must stay single-box"
    );
}

/// The runtime refusal + memo pipeline end to end on a mapping that
/// provably exceeds the width bound: two chained batched convs under a
/// B,P,Q partition with whole-tensor retention need a *three*-box
/// availability union at the batch-wrap leaf, so the symbolic walk refuses
/// (bit-identically bailing to the region walk) and the session memoizes
/// the mapping signature.
#[test]
fn width_overflow_refuses_bit_identically() {
    use looptree::einsum::FusionSetBuilder;
    let fs = FusionSetBuilder::new("batched-refuser", &[3, 2, 8, 8])
        .conv2d_batched(2, 3, 3, 1)
        .conv2d_batched(2, 3, 3, 1)
        .build();
    let arch = Arch::generic(1 << 14);
    let ev = Evaluator::new(&fs, &arch).unwrap();
    let last = fs.last();
    let m = InterLayerMapping::tiled(
        ["B2", "P2", "Q2"]
            .iter()
            .map(|n| Partition { dim: last.rank_index(n).unwrap(), tile: 1 })
            .collect(),
        Parallelism::Sequential,
    )
    .with_uniform_retention(0);
    assert_tiers_equal(&ev, &m, "batched-refuser B,P,Q retention 0");
    // assert_tiers_equal already ran the default path once (refusing and
    // memoizing) — from here on the session skips the symbolic attempt.
    let metrics = ev.evaluate(&m).unwrap();
    assert!(!metrics.path.symbolic && !metrics.path.sym_refused);
    assert!(ev.refusal_memo_hits() >= 1, "refusal was not memoized");
}
