//! Full validation-suite integration: run everything end to end at test
//! scale and check the Table V claim (worst-case error within the paper's
//! band plus small-scale pipeline-fill slack).

use looptree::validation::{run_all, summarize, Scale};

#[test]
fn all_validations_within_band() {
    let rows = run_all(Scale::Test);
    assert!(rows.len() >= 15, "expected a full validation sweep");
    // Count metrics (transfers, capacities, ops) are exact; latency and
    // derived metrics stay within the paper's band + fill slack.
    let worst = rows.iter().map(|r| r.error_pct()).fold(0.0f64, f64::max);
    assert!(worst <= 8.0, "worst-case error {worst:.2}%");
    // The exact-count subset really is exact.
    for r in &rows {
        if r.metric.contains("elems") {
            assert_eq!(
                r.looptree, r.reference,
                "{} {} {} must be exact",
                r.design, r.workload, r.metric
            );
        }
    }
    // Every design from Table V appears.
    for d in ["DepFin", "Fused-layer CNN", "ISAAC", "PipeLayer", "FLAT"] {
        assert!(rows.iter().any(|r| r.design == d), "{d} missing");
    }
    // And the summary renders a max-error line per design.
    let text = summarize(&rows);
    assert!(text.contains("Table V summary"));
}
