//! Steady-state fast path vs. exhaustive reference walk.
//!
//! The tile-classification fast path (see `model::engine`) must be
//! *bit-identical* to the reference walk — not approximately equal: every
//! integer count and every derived `f64` (energy, NoC hop-words) down to
//! the last bit, on the five validation designs and on randomized
//! (workload, mapping) pairs covering ragged tiles, repartitioned ranks,
//! per-tensor retention, and both parallelism modes.

use looptree::einsum::{workloads, FusionSet, TensorId};
use looptree::mapping::{InterLayerMapping, Parallelism, Partition};
use looptree::model::{Evaluator, Metrics};
use looptree::util::prng::Prng;
use looptree::validation::{design_points, Scale};

/// Bitwise equality across every metric field.
fn assert_bitwise_equal(a: &Metrics, b: &Metrics, tag: &str) {
    assert_eq!(a.latency_cycles, b.latency_cycles, "{tag}: latency_cycles");
    assert_eq!(a.compute_cycles, b.compute_cycles, "{tag}: compute_cycles");
    assert_eq!(a.memory_cycles, b.memory_cycles, "{tag}: memory_cycles");
    assert_eq!(
        a.sequential_compute_cycles, b.sequential_compute_cycles,
        "{tag}: sequential_compute_cycles"
    );
    assert_eq!(a.offchip_reads, b.offchip_reads, "{tag}: offchip_reads");
    assert_eq!(a.offchip_writes, b.offchip_writes, "{tag}: offchip_writes");
    assert_eq!(a.glb_reads, b.glb_reads, "{tag}: glb_reads");
    assert_eq!(a.glb_writes, b.glb_writes, "{tag}: glb_writes");
    assert_eq!(
        a.noc_hop_words.to_bits(),
        b.noc_hop_words.to_bits(),
        "{tag}: noc_hop_words"
    );
    assert_eq!(a.per_tensor_offchip, b.per_tensor_offchip, "{tag}: per_tensor_offchip");
    assert_eq!(a.occupancy_peak, b.occupancy_peak, "{tag}: occupancy_peak");
    assert_eq!(
        a.per_tensor_occupancy, b.per_tensor_occupancy,
        "{tag}: per_tensor_occupancy"
    );
    assert_eq!(a.capacity_ok, b.capacity_ok, "{tag}: capacity_ok");
    assert_eq!(a.total_ops, b.total_ops, "{tag}: total_ops");
    assert_eq!(a.recompute_ops, b.recompute_ops, "{tag}: recompute_ops");
    assert_eq!(
        a.per_tensor_recompute, b.per_tensor_recompute,
        "{tag}: per_tensor_recompute"
    );
    assert_eq!(a.iterations, b.iterations, "{tag}: iterations");
    for (field, x, y) in [
        ("dram_pj", a.energy.dram_pj, b.energy.dram_pj),
        ("glb_pj", a.energy.glb_pj, b.energy.glb_pj),
        ("rf_pj", a.energy.rf_pj, b.energy.rf_pj),
        ("compute_pj", a.energy.compute_pj, b.energy.compute_pj),
        ("noc_pj", a.energy.noc_pj, b.energy.noc_pj),
    ] {
        assert_eq!(x.to_bits(), y.to_bits(), "{tag}: energy.{field}");
    }
}

fn check_both_paths(ev: &Evaluator, mapping: &InterLayerMapping, tag: &str) {
    let fast = ev
        .evaluate(mapping)
        .unwrap_or_else(|e| panic!("{tag}: fast path: {e}"));
    let reference = ev
        .evaluate_reference(mapping)
        .unwrap_or_else(|e| panic!("{tag}: reference: {e}"));
    assert_bitwise_equal(&fast, &reference, tag);
}

/// The five validation designs (DepFin, Fused-layer CNN, ISAAC, PipeLayer,
/// FLAT) through both paths — the acceptance gate of the fast path.
#[test]
fn five_validation_designs_identical_through_both_paths() {
    for point in design_points(Scale::Test) {
        // As the validation drivers run them (unbounded GLB) …
        let ev = Evaluator::new(&point.fs, &point.arch.unbounded_glb())
            .unwrap_or_else(|e| panic!("{}: {e}", point.design));
        check_both_paths(&ev, &point.mapping, point.design);
        // … and with the real capacity bound (capacity_ok included).
        let ev = Evaluator::new(&point.fs, &point.arch).unwrap();
        check_both_paths(&ev, &point.mapping, &format!("{} (bounded)", point.design));
    }
}

/// Long row-tiled walks — the configuration the fast path exists for; the
/// steady run must jump hundreds of iterations while staying exact, and
/// `iterations` must still report the logical walk length.
#[test]
fn long_row_tiled_walks_are_exact() {
    let arch = looptree::arch::Arch::generic(1 << 14);
    for (rows, ch, tile) in [(56, 8, 1), (56, 8, 4), (49, 4, 3), (40, 4, 7)] {
        let fs = workloads::conv_conv(rows, ch);
        let ev = Evaluator::new(&fs, &arch).unwrap();
        let p2 = fs.last().rank_index("P2").unwrap();
        for par in [Parallelism::Sequential, Parallelism::Pipeline] {
            let mapping =
                InterLayerMapping::tiled(vec![Partition { dim: p2, tile }], par);
            let tag = format!("conv_conv({rows},{ch}) tile {tile} {par:?}");
            check_both_paths(&ev, &mapping, &tag);
            let m = ev.evaluate(&mapping).unwrap();
            assert_eq!(
                m.iterations,
                mapping.total_iterations(&fs),
                "{tag}: iterations must report the logical walk length"
            );
        }
    }
}

fn divisors(n: i64) -> Vec<i64> {
    (1..=n).filter(|d| n % d == 0).collect()
}

/// A randomized mapping: 0–3 partition levels with ragged tiles, optional
/// hierarchical re-partitioning of one rank (exact outer division, as the
/// window algebra requires), per-tensor retention, both parallelisms.
fn random_mapping(fs: &FusionSet, rng: &mut Prng) -> InterLayerMapping {
    let last = fs.last();
    let mut partitions: Vec<Partition> = Vec::new();
    let mut dims: Vec<usize> = (0..last.ndim()).collect();
    rng.shuffle(&mut dims);
    for &dim in dims.iter().take(rng.index(4)) {
        let extent = last.rank_sizes[dim];
        if extent < 2 {
            continue;
        }
        let tile = rng.range_i64(1, extent); // ragged tiles common
        partitions.push(Partition { dim, tile });
    }
    // Occasionally re-partition the first partitioned rank hierarchically.
    if !partitions.is_empty() && rng.chance(0.3) {
        let outer = partitions[0].dim;
        let extent = last.rank_sizes[outer];
        let divs = divisors(extent);
        let t1 = divs[rng.index(divs.len())];
        if t1 >= 2 {
            partitions[0].tile = t1;
            let t2 = 1 + rng.range_i64(0, t1);
            partitions.push(Partition { dim: outer, tile: t2 });
        }
    }
    let parallelism = if rng.chance(0.5) {
        Parallelism::Sequential
    } else {
        Parallelism::Pipeline
    };
    let k = partitions.len();
    let mut m = InterLayerMapping::tiled(partitions, parallelism);
    for x in 0..fs.tensors.len() {
        if rng.chance(0.5) {
            m = m.with_retention(TensorId(x), rng.index(k + 1));
        }
    }
    m
}

#[test]
fn randomized_mappings_identical_through_both_paths() {
    let mut rng = Prng::new(0xFA57_0A7);
    let arch = looptree::arch::Arch::generic(256);
    for case in 0..30 {
        let fs = match rng.index(4) {
            0 => workloads::conv_conv(8 + rng.range_i64(0, 16), 2 + rng.range_i64(0, 6)),
            1 => workloads::pwise_dwise_pwise(6 + rng.range_i64(0, 10), 2 + rng.range_i64(0, 3)),
            2 => workloads::fc_fc(8 + rng.range_i64(0, 24), 4 + rng.range_i64(0, 12)),
            _ => workloads::self_attention(1, 2, 8 + rng.range_i64(0, 12), 4),
        };
        let ev = Evaluator::new(&fs, &arch).unwrap();
        for sub in 0..6 {
            let mapping = random_mapping(&fs, &mut rng);
            if mapping.total_iterations(&fs) > 30_000 {
                continue;
            }
            check_both_paths(&ev, &mapping, &format!("case {case}.{sub} ({})", fs.name));
        }
    }
}
