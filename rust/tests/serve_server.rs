//! Integration tests for `looptree serve`: response byte-identity against
//! the one-shot CLI, cross-request cache determinism, thread-count
//! independence, warm-started search, and protocol error envelopes.

use looptree::serve::{process_request, response_stats, ServeOptions, ServeState, Server};
use looptree::util::json::Json;
use std::path::{Path, PathBuf};
use std::process::Command;

fn envelope(kind: &str, config: Json, warm_start: bool) -> Json {
    let mut pairs = vec![
        ("kind".to_string(), Json::Str(kind.to_string())),
        ("config".to_string(), config),
    ];
    if warm_start {
        pairs.push(("warm_start".to_string(), Json::Bool(true)));
    }
    Json::Obj(pairs.into_iter().collect())
}

fn small_network_config() -> Json {
    Json::parse(
        r#"{
            "network": {"name": "t", "layers": [
                {"name": "c0", "input_shape": [8, 14, 14],
                 "op": {"op": "conv2d", "out_channels": 8, "r": 3, "s": 3, "stride": 1}},
                {"name": "c1", "input_shape": [8, 12, 12],
                 "op": {"op": "conv2d", "out_channels": 8, "r": 3, "s": 3, "stride": 1}},
                {"name": "c2", "input_shape": [8, 10, 10],
                 "op": {"op": "conv2d", "out_channels": 8, "r": 3, "s": 3, "stride": 1}}
            ]},
            "arch": "generic:256",
            "segment_search": {
                "max_segment_layers": 2,
                "search": {"mapspace": {"uniform_retention": true, "tile_sizes": [4]}}
            }
        }"#,
    )
    .unwrap()
}

fn annealing_search_config() -> Json {
    Json::parse(
        r#"{
            "workload": "conv_conv:14x8",
            "arch": "generic:256",
            "search": {
                "algorithm": "annealing", "iters": 60, "seed": 11,
                "mapspace": {"uniform_retention": true, "tile_sizes": [2, 4]}
            }
        }"#,
    )
    .unwrap()
}

fn result_text(resp: &Json) -> String {
    resp.get("result").expect("response carries a result").pretty()
}

#[test]
fn repeated_network_request_is_byte_identical_with_cache_hits() {
    let state = ServeState::new(&ServeOptions::default());
    let req = envelope("network", small_network_config(), false);
    let r1 = process_request(&state, &req);
    let r2 = process_request(&state, &req);
    assert_eq!(r1.get("ok").and_then(Json::as_bool), Some(true), "{r1}");
    let s1 = response_stats(&r1);
    let s2 = response_stats(&r2);
    assert!(s1.cache_misses > 0, "first request must populate the cache");
    assert_eq!(s1.cache_hits, 0, "first request on a cold cache cannot hit");
    assert!(s2.cache_hits > 0, "second identical request must hit");
    assert_eq!(s2.cache_misses, 0, "second identical request must not re-search");
    assert_eq!(
        result_text(&r1),
        result_text(&r2),
        "cache reuse changed the result document"
    );
}

#[test]
fn responses_are_independent_of_thread_count() {
    let mk = |threads| {
        ServeState::new(&ServeOptions { threads, ..ServeOptions::default() })
    };
    let one = mk(1);
    let eight = mk(8);
    for req in [
        envelope("network", small_network_config(), false),
        envelope("analyze", Json::parse(r#"{"workload": "conv_conv:28x64"}"#).unwrap(), false),
        envelope("search", annealing_search_config(), false),
    ] {
        let a = process_request(&one, &req);
        let b = process_request(&eight, &req);
        assert_eq!(a.pretty(), b.pretty(), "response depends on worker count");
    }
}

#[test]
fn warm_started_search_reports_and_never_regresses() {
    let state = ServeState::new(&ServeOptions::default());
    let cold = process_request(&state, &envelope("search", annealing_search_config(), false));
    assert_eq!(cold.get("ok").and_then(Json::as_bool), Some(true), "{cold}");
    assert_eq!(response_stats(&cold).cache_misses, 1);
    let best = |resp: &Json| {
        resp.get("result")
            .and_then(|r| r.get("result"))
            .and_then(|r| r.get("best"))
            .and_then(|b| b.get("score"))
            .and_then(Json::as_f64)
            .expect("search response carries result.best.score")
    };
    let cold_best = best(&cold);
    let warm = process_request(&state, &envelope("search", annealing_search_config(), true));
    let ws = response_stats(&warm);
    assert_eq!(ws.warm_starts, 1, "warm pool was seeded, so this must warm-start");
    assert_eq!((ws.cache_hits, ws.cache_misses), (0, 0), "warm_start bypasses the summary cache");
    assert!(
        best(&warm) <= cold_best,
        "warm-started search regressed: {} > {cold_best}",
        best(&warm)
    );
}

#[test]
fn error_envelope_carries_id_and_message() {
    let state = ServeState::new(&ServeOptions::default());
    let bad_kind =
        Json::parse(r#"{"id": 7, "kind": "frobnicate", "config": {}}"#).unwrap();
    let resp = process_request(&state, &bad_kind);
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false));
    assert!(resp.get("error").and_then(Json::as_str).is_some(), "{resp}");
    assert_eq!(resp.get("id").and_then(Json::as_i64), Some(7), "id must echo back");
    let no_config = Json::parse(r#"{"kind": "analyze"}"#).unwrap();
    let resp = process_request(&state, &no_config);
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false));
}

#[test]
fn id_rides_through_successful_responses() {
    let state = ServeState::new(&ServeOptions::default());
    let mut req = envelope("lint", small_network_config(), false);
    if let Json::Obj(map) = &mut req {
        map.insert("id".to_string(), Json::Str("req-42".to_string()));
    }
    let resp = process_request(&state, &req);
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true), "{resp}");
    assert_eq!(resp.get("id").and_then(Json::as_str), Some("req-42"));
    assert_eq!(resp.get("kind").and_then(Json::as_str), Some("lint"));
}

// ---------------------------------------------------- over-the-wire tests --

#[test]
fn http_server_round_trips_and_reports_health() {
    let server = Server::bind("127.0.0.1:0", ServeOptions::default()).unwrap();
    let handle = server.spawn();
    let req = envelope("network", small_network_config(), false);
    let (status, r1) = handle.post(&req).unwrap();
    assert_eq!(status, 200, "{r1}");
    let (_, r2) = handle.post(&req).unwrap();
    assert!(response_stats(&r2).cache_hits > 0, "cache must persist across connections");
    assert_eq!(result_text(&r1), result_text(&r2));

    // Malformed request kinds map to HTTP 400 with an error envelope.
    let (status, err) = handle
        .post(&Json::parse(r#"{"kind": "nope", "config": {}}"#).unwrap())
        .unwrap();
    assert_eq!(status, 400);
    assert_eq!(err.get("ok").and_then(Json::as_bool), Some(false));

    let (status, text) = looptree::serve::post_json_raw(
        &handle.addr(),
        "/",
        &envelope("analyze", Json::parse(r#"{"workload": "conv_conv:14x8"}"#).unwrap(), false),
    )
    .unwrap();
    assert_eq!(status, 200);
    assert!(text.contains("\"metrics\""), "analyze response carries metrics: {text}");

    // GET /health over a raw socket: liveness plus lifetime cache totals.
    {
        use std::io::{Read, Write};
        let mut s = std::net::TcpStream::connect(handle.addr()).unwrap();
        s.write_all(b"GET /health HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n").unwrap();
        let mut raw = Vec::new();
        s.read_to_end(&mut raw).unwrap();
        let raw = String::from_utf8(raw).unwrap();
        assert!(raw.starts_with("HTTP/1.1 200"), "{raw}");
        let body = raw.split("\r\n\r\n").nth(1).unwrap();
        let health = Json::parse(body).unwrap();
        assert_eq!(health.get("ok").and_then(Json::as_bool), Some(true));
        assert!(health.get("cache_hits_total").and_then(Json::as_f64).is_some());
    }
    handle.stop();
}

// ------------------------------------------- CLI byte-identity (tentpole) --

fn repo_config_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("../examples/configs")
}

fn cli_json(sub: &str, config_path: &Path) -> String {
    let out = Command::new(env!("CARGO_BIN_EXE_looptree"))
        .args([sub, "--config", config_path.to_str().unwrap(), "--json"])
        .output()
        .expect("run one-shot CLI");
    // lint exits nonzero on findings; every other subcommand must succeed.
    if sub != "lint" {
        assert!(
            out.status.success(),
            "{sub} {config_path:?} failed: {}",
            String::from_utf8_lossy(&out.stderr)
        );
    }
    String::from_utf8(out.stdout).expect("CLI emits UTF-8")
}

/// The acceptance criterion: for every example config, the serve response's
/// `result` section is byte-for-byte the one-shot CLI `--json` document.
#[test]
fn serve_results_match_one_shot_cli_for_every_example_config() {
    let dir = repo_config_dir();
    let mut entries: Vec<PathBuf> = std::fs::read_dir(&dir)
        .expect("examples/configs exists")
        .map(|e| e.unwrap().path())
        .filter(|p| p.extension().is_some_and(|x| x == "json"))
        .collect();
    entries.sort();
    assert!(!entries.is_empty(), "no example configs found in {dir:?}");
    let state = ServeState::new(&ServeOptions::default());
    let mut checked = 0;
    for path in &entries {
        let name = path.file_name().unwrap().to_str().unwrap();
        let Some(kind) = ["analyze", "search", "network"]
            .into_iter()
            .find(|k| name.starts_with(&format!("{k}_")))
        else {
            continue;
        };
        let config = Json::parse(&std::fs::read_to_string(path).unwrap())
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        let resp = process_request(&state, &envelope(kind, config, false));
        assert_eq!(
            resp.get("ok").and_then(Json::as_bool),
            Some(true),
            "{name}: {resp}"
        );
        let served = format!("{}\n", result_text(&resp));
        let cli = cli_json(kind, path);
        assert_eq!(served, cli, "{name}: serve response diverged from one-shot CLI");
        checked += 1;
    }
    assert!(checked >= 4, "expected to cover the example configs, got {checked}");
}

/// Lint parity: the serve `lint` result equals `looptree lint --json`.
#[test]
fn serve_lint_matches_cli_lint() {
    let dir = repo_config_dir();
    let path = dir.join("analyze_conv28.json");
    let config = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    let state = ServeState::new(&ServeOptions::default());
    let resp = process_request(&state, &envelope("lint", config, false));
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true), "{resp}");
    let served = format!("{}\n", result_text(&resp));
    let cli = cli_json("lint", &path);
    assert_eq!(served, cli, "serve lint diverged from one-shot CLI lint");
}
