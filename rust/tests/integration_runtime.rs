//! PJRT runtime integration: load the AOT artifacts, execute, and verify
//! that the rust-driven tile pipeline (L3 owning the inter-layer schedule)
//! reproduces the monolithic reference numerics.
//!
//! Requires `make artifacts` (skipped with a notice when absent, so cargo
//! test works before the python step in fresh checkouts).

use looptree::runtime::Runtime;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

/// Deterministic pseudo-random inputs (xorshift; any data works — rust
/// drives the fused pipeline and the reference with the same values).
fn gen_inputs(ch: i64, h: i64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut seed = 0x12345678u64;
    let mut next = move || {
        seed ^= seed << 13;
        seed ^= seed >> 7;
        seed ^= seed << 17;
        (seed as f64 / u64::MAX as f64) as f32 - 0.5
    };
    let x: Vec<f32> = (0..ch * h * h).map(|_| next()).collect();
    let w1: Vec<f32> = (0..ch * ch * 9).map(|_| next() * 0.1).collect();
    let w2: Vec<f32> = (0..ch * ch * 9).map(|_| next() * 0.1).collect();
    (x, w1, w2)
}

#[test]
fn fused_artifact_matches_reference_executable() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts missing (run `make artifacts`)");
        return;
    };
    let mut rt = Runtime::open(&dir).unwrap();
    let ch = rt.config_i64("channels").unwrap();
    let rows = rt.config_i64("rows").unwrap();
    let halo_t = rt.config_i64("halo_total").unwrap();
    let h = rows + halo_t;
    let (x, w1, w2) = gen_inputs(ch, h);
    let xs = [ch, h, h];
    let ws = [ch, ch, 3, 3];

    let fused = rt
        .load("conv_conv_fused")
        .unwrap()
        .run_f32(&[(&x, &xs), (&w1, &ws), (&w2, &ws)])
        .unwrap();
    let reference = rt
        .load("conv_conv_ref")
        .unwrap()
        .run_f32(&[(&x, &xs), (&w1, &ws), (&w2, &ws)])
        .unwrap();
    assert_eq!(fused.len(), reference.len());
    for (i, (a, b)) in fused.iter().zip(&reference).enumerate() {
        assert!((a - b).abs() < 1e-3, "elem {i}: fused {a} vs ref {b}");
    }
}

#[test]
fn mlp_fused_artifact_matches_reference() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts missing (run `make artifacts`)");
        return;
    };
    let mut rt = Runtime::open(&dir).unwrap();
    let (tokens, d1, e1, e2) = (
        rt.config_i64("tokens").unwrap(),
        rt.config_i64("d1").unwrap(),
        rt.config_i64("e1").unwrap(),
        rt.config_i64("e2").unwrap(),
    );
    let mut seed = 99u64;
    let mut next = move || {
        seed ^= seed << 13;
        seed ^= seed >> 7;
        seed ^= seed << 17;
        (seed as f64 / u64::MAX as f64) as f32 - 0.5
    };
    let x: Vec<f32> = (0..tokens * d1).map(|_| next()).collect();
    let w1: Vec<f32> = (0..d1 * e1).map(|_| next() * 0.1).collect();
    let w2: Vec<f32> = (0..e1 * e2).map(|_| next() * 0.1).collect();
    let fused = rt
        .load("mlp_fused")
        .unwrap()
        .run_f32(&[(&x, &[tokens, d1]), (&w1, &[d1, e1]), (&w2, &[e1, e2])])
        .unwrap();
    let reference = rt
        .load("mlp_ref")
        .unwrap()
        .run_f32(&[(&x, &[tokens, d1]), (&w1, &[d1, e1]), (&w2, &[e1, e2])])
        .unwrap();
    for (i, (a, b)) in fused.iter().zip(&reference).enumerate() {
        assert!((a - b).abs() < 1e-3, "elem {i}: {a} vs {b}");
    }
}

#[test]
fn rust_driven_tile_pipeline_matches_reference() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts missing (run `make artifacts`)");
        return;
    };
    let mut rt = Runtime::open(&dir).unwrap();
    let ch = rt.config_i64("channels").unwrap() as usize;
    let rows = rt.config_i64("rows").unwrap() as usize;
    let tile_p = rt.config_i64("tile_p").unwrap() as usize;
    let halo1 = rt.config_i64("halo1").unwrap() as usize;
    let halo_t = rt.config_i64("halo_total").unwrap() as usize;
    let h = rows + halo_t;
    let w2cols = h - 2; // fmap2 width
    let (x, w1, w2) = gen_inputs(ch as i64, h as i64);
    let xs = [ch as i64, h as i64, h as i64];
    let ws = [ch as i64, ch as i64, 3, 3];

    let reference = rt
        .load("conv_conv_ref")
        .unwrap()
        .run_f32(&[(&x, &xs), (&w1, &ws), (&w2, &ws)])
        .unwrap();

    // Rust-driven retain dataflow: stage1 produces only fresh Fmap2 rows; a
    // sliding band of tile_p + halo1 rows feeds stage2 — the L3 coordinator
    // owns the inter-layer schedule, PJRT owns per-tile compute.
    let slice_rows = |data: &[f32], r0: usize, nrows: usize| -> Vec<f32> {
        let mut out = Vec::with_capacity(ch * nrows * h);
        for c in 0..ch {
            let base = c * h * h + r0 * h;
            out.extend_from_slice(&data[base..base + nrows * h]);
        }
        out
    };

    // fmap2 rows in (row -> [ch * w2cols], channel-major per row) form.
    let mut fmap2_rows: Vec<Vec<f32>> = Vec::new();
    let mut out_tiles: Vec<Vec<f32>> = Vec::new();
    let mut produced = 0usize;

    for i in 0..rows / tile_p {
        let (fresh_rows, x_block, stage) = if i == 0 {
            let fresh = tile_p + halo1;
            (fresh, slice_rows(&x, 0, fresh + 2), "conv_stage1_first")
        } else {
            let fresh = tile_p;
            (
                fresh,
                slice_rows(&x, produced, fresh + 2),
                "conv_stage1_steady",
            )
        };
        let in_rows = fresh_rows + 2;
        let xbs = [ch as i64, in_rows as i64, h as i64];
        let f2 = rt
            .load(stage)
            .unwrap()
            .run_f32(&[(&x_block, &xbs), (&w1, &ws)])
            .unwrap();
        // f2 layout [ch, fresh_rows, w2cols] -> per-row buffers.
        for r in 0..fresh_rows {
            let mut rowbuf = Vec::with_capacity(ch * w2cols);
            for c in 0..ch {
                let base = c * fresh_rows * w2cols + r * w2cols;
                rowbuf.extend_from_slice(&f2[base..base + w2cols]);
            }
            fmap2_rows.push(rowbuf);
        }
        produced += fresh_rows;

        // Sliding band: the last tile_p + halo1 fmap2 rows.
        let band_rows = tile_p + halo1;
        let start = fmap2_rows.len() - band_rows;
        let mut band = vec![0f32; ch * band_rows * w2cols];
        for (ri, row) in fmap2_rows[start..].iter().enumerate() {
            for c in 0..ch {
                let src = &row[c * w2cols..(c + 1) * w2cols];
                let dst = c * band_rows * w2cols + ri * w2cols;
                band[dst..dst + w2cols].copy_from_slice(src);
            }
        }
        let bs = [ch as i64, band_rows as i64, w2cols as i64];
        let tile = rt
            .load("conv_stage2")
            .unwrap()
            .run_f32(&[(&band, &bs), (&w2, &ws)])
            .unwrap();
        out_tiles.push(tile);
    }
    assert_eq!(produced, rows + halo1, "retain dataflow: fmap2 produced once");

    // Assemble [ch, rows, out_cols] from per-tile [ch, tile_p, out_cols].
    let out_cols = w2cols - 2;
    let mut got = vec![0f32; ch * rows * out_cols];
    for (ti, tile) in out_tiles.iter().enumerate() {
        for c in 0..ch {
            for r in 0..tile_p {
                let src = c * tile_p * out_cols + r * out_cols;
                let dst = c * rows * out_cols + (ti * tile_p + r) * out_cols;
                got[dst..dst + out_cols].copy_from_slice(&tile[src..src + out_cols]);
            }
        }
    }
    assert_eq!(got.len(), reference.len());
    for (i, (a, b)) in got.iter().zip(&reference).enumerate() {
        assert!((a - b).abs() < 1e-3, "elem {i}: pipeline {a} vs ref {b}");
    }
    // The executed schedule's stats exist for model cross-checks.
    let stats = rt.total_stats();
    assert!(stats.invocations >= (rows / tile_p) as u64 * 2);
}
