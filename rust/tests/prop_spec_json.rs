//! Property tests for the JSON spec layer: every spec type round-trips
//! (`from_json(to_json(x)) == x`, or re-serializes identically where the
//! type has no `PartialEq`) across randomized instances, including a full
//! serialize → parse → evaluate path whose metrics must match the original.

use looptree::arch::{presets, Arch};
use looptree::einsum::{workloads, FusionSet, TensorId};
use looptree::mapping::{InterLayerMapping, Parallelism, Partition};
use looptree::mapspace::MapSpaceConfig;
use looptree::model::{Evaluator, Metrics};
use looptree::search::{Algorithm, Objective, SearchSpec};
use looptree::util::json::Json;
use looptree::util::prng::Prng;

/// Serialize, re-parse the *text* (exercising the parser), deserialize.
fn text_round_trip(j: &Json) -> Json {
    Json::parse(&j.to_string()).unwrap()
}

fn sample_fusion_sets() -> Vec<FusionSet> {
    vec![
        workloads::conv_conv(14, 8),
        workloads::conv_conv_conv(12, 4),
        workloads::pwise_dwise_pwise(14, 8),
        workloads::fc_fc(32, 16),
        workloads::self_attention(2, 2, 16, 8),
        workloads::fsrcnn(10),
        workloads::mnist_convs_batched(2, 2),
    ]
}

#[test]
fn fusion_sets_round_trip() {
    for fs in sample_fusion_sets() {
        let j = fs.to_json();
        let back = FusionSet::from_json(&text_round_trip(&j))
            .unwrap_or_else(|e| panic!("{}: {e}", fs.name));
        assert_eq!(back.to_json().to_string(), j.to_string(), "{}", fs.name);
        // Structural invariants hold on the parsed copy.
        assert!(back.validate().is_ok());
        assert_eq!(back.total_ops(), fs.total_ops());
        assert_eq!(back.algmin_offchip_elems(), fs.algmin_offchip_elems());
    }
}

#[test]
fn archs_round_trip() {
    for arch in [
        Arch::generic(1),
        Arch::generic(256),
        Arch::generic(1 << 20).unbounded_glb(),
        presets::depfin(),
        presets::fused_cnn(),
        presets::isaac(),
        presets::pipelayer(),
        presets::flat(),
    ] {
        let j = arch.to_json();
        let back = Arch::from_json(&text_round_trip(&j))
            .unwrap_or_else(|e| panic!("{}: {e}", arch.name));
        assert_eq!(back.to_json().to_string(), j.to_string(), "{}", arch.name);
        assert_eq!(back.glb_capacity(), arch.glb_capacity());
        assert_eq!(back.word_bytes, arch.word_bytes);
        assert_eq!(back.compute.macs, arch.compute.macs);
    }
}

fn random_mapping(fs: &FusionSet, rng: &mut Prng) -> InterLayerMapping {
    let last = fs.last();
    let nparts = rng.index(4);
    let mut dims: Vec<usize> = (0..last.ndim())
        .filter(|&d| last.rank_sizes[d] > 1)
        .collect();
    rng.shuffle(&mut dims);
    let mut partitions = Vec::new();
    for &dim in dims.iter().take(nparts) {
        let extent = last.rank_sizes[dim];
        partitions.push(Partition { dim, tile: rng.range_i64(1, extent.max(2)) });
    }
    let parallelism = if rng.chance(0.5) {
        Parallelism::Sequential
    } else {
        Parallelism::Pipeline
    };
    let k = partitions.len();
    let mut m = InterLayerMapping::tiled(partitions, parallelism);
    for x in 0..fs.tensors.len() {
        if rng.chance(0.6) {
            m = m.with_retention(TensorId(x), rng.index(k + 1));
        }
    }
    m
}

#[test]
fn mappings_round_trip() {
    let mut rng = Prng::new(0x1234);
    for fs in sample_fusion_sets() {
        for _ in 0..20 {
            let m = random_mapping(&fs, &mut rng);
            let back = InterLayerMapping::from_json(&text_round_trip(&m.to_json())).unwrap();
            assert_eq!(back, m, "{}", fs.name);
        }
    }
}

#[test]
fn mapspace_configs_round_trip() {
    let mut rng = Prng::new(0xFEED);
    for _ in 0..30 {
        let nsched = rng.index(4);
        let cfg = MapSpaceConfig {
            schedules: (0..nsched)
                .map(|_| {
                    (0..1 + rng.index(3))
                        .map(|_| ["P2", "Q2", "C2", "M2"][rng.index(4)].to_string())
                        .collect()
                })
                .collect(),
            tile_sizes: (0..rng.index(5)).map(|_| rng.range_i64(1, 64)).collect(),
            uniform_retention: rng.chance(0.5),
            parallelism: if rng.chance(0.5) {
                vec![Parallelism::Sequential]
            } else {
                vec![Parallelism::Sequential, Parallelism::Pipeline]
            },
            max_mappings: rng.index(1_000_000),
        };
        let back = MapSpaceConfig::from_json(&text_round_trip(&cfg.to_json())).unwrap();
        assert_eq!(back, cfg);
    }
}

#[test]
fn search_specs_round_trip() {
    let mut rng = Prng::new(0xABCD);
    let algorithms = [
        Algorithm::Exhaustive,
        Algorithm::Random,
        Algorithm::Annealing,
        Algorithm::Genetic,
    ];
    let objectives = [
        Objective::Latency,
        Objective::Energy,
        Objective::Edp,
        Objective::Capacity,
        Objective::FeasibleEdp,
    ];
    for _ in 0..40 {
        let spec = SearchSpec {
            algorithm: algorithms[rng.index(4)],
            objective: objectives[rng.index(5)],
            // Full u64 range: seeds above 2^53 take the exact string
            // encoding on the wire.
            seed: rng.next_u64(),
            samples: rng.index(10_000),
            iters: rng.index(10_000),
            population: rng.index(200),
            generations: rng.index(100),
            mapspace: MapSpaceConfig::default(),
            penalize_infeasible: rng.chance(0.5),
        };
        let back = SearchSpec::from_json(&text_round_trip(&spec.to_json())).unwrap();
        assert_eq!(back, spec);
    }
}

#[test]
fn metrics_round_trip_from_real_evaluations() {
    let mut rng = Prng::new(0x7777);
    for fs in sample_fusion_sets() {
        let arch = Arch::generic(256);
        let ev = Evaluator::new(&fs, &arch).unwrap();
        for _ in 0..5 {
            let mapping = random_mapping(&fs, &mut rng);
            if mapping.total_iterations(&fs) > 20_000 {
                continue;
            }
            let Ok(m) = ev.evaluate(&mapping) else { continue };
            let j = m.to_json();
            let back = Metrics::from_json(&text_round_trip(&j)).unwrap();
            assert_eq!(back.to_json().to_string(), j.to_string(), "{}", fs.name);
            assert_eq!(back.latency_cycles, m.latency_cycles);
            assert_eq!(back.offchip_reads, m.offchip_reads);
            assert_eq!(back.per_tensor_occupancy, m.per_tensor_occupancy);
            assert_eq!(
                back.energy.total_pj().to_bits(),
                m.energy.total_pj().to_bits()
            );
        }
    }
}

#[test]
fn serialized_specs_evaluate_identically() {
    // The full wire path: serialize (workload, arch, mapping) to text,
    // parse it back, evaluate both sides — the metrics must be identical.
    let mut rng = Prng::new(0x9999);
    for fs in sample_fusion_sets() {
        let arch = Arch::generic(512);
        let fs2 = FusionSet::from_json(&text_round_trip(&fs.to_json())).unwrap();
        let arch2 = Arch::from_json(&text_round_trip(&arch.to_json())).unwrap();
        let ev = Evaluator::new(&fs, &arch).unwrap();
        let ev2 = Evaluator::new(&fs2, &arch2).unwrap();
        for _ in 0..3 {
            let mapping = random_mapping(&fs, &mut rng);
            if mapping.total_iterations(&fs) > 20_000 {
                continue;
            }
            let mapping2 =
                InterLayerMapping::from_json(&text_round_trip(&mapping.to_json())).unwrap();
            match (ev.evaluate(&mapping), ev2.evaluate(&mapping2)) {
                (Ok(a), Ok(b)) => {
                    assert_eq!(a.latency_cycles, b.latency_cycles, "{}", fs.name);
                    assert_eq!(a.offchip_reads, b.offchip_reads, "{}", fs.name);
                    assert_eq!(a.occupancy_peak, b.occupancy_peak, "{}", fs.name);
                    assert_eq!(a.total_ops, b.total_ops, "{}", fs.name);
                    assert_eq!(
                        a.energy.total_pj().to_bits(),
                        b.energy.total_pj().to_bits(),
                        "{}",
                        fs.name
                    );
                }
                (Err(a), Err(b)) => assert_eq!(a, b),
                (a, b) => panic!("{}: divergent results: {a:?} vs {b:?}", fs.name),
            }
        }
    }
}
