//! Cross-validation: the analytical model and the element-level simulator
//! are independent implementations of the same mapping semantics. Their
//! counts (off-chip transfers, recomputation, occupancy) must agree exactly;
//! latency agrees up to pipeline fill/drain modeling (checked within a
//! tolerance, the paper's validation-error methodology).

use looptree::arch::Arch;
use looptree::einsum::{workloads, FusionSet, TensorId};
use looptree::mapping::{InterLayerMapping, Parallelism, Partition};
use looptree::model::{evaluate, EvalOptions};
use looptree::sim::simulate;

fn check(fs: &FusionSet, mapping: &InterLayerMapping, tag: &str) {
    let arch = Arch::generic(1 << 20); // 1 GiB GLB: capacity-unconstrained
    let m = evaluate(fs, &arch, mapping, &EvalOptions::default())
        .unwrap_or_else(|e| panic!("{tag}: model failed: {e}"));
    let s = simulate(fs, &arch, mapping).unwrap_or_else(|e| panic!("{tag}: sim failed: {e}"));

    assert_eq!(m.offchip_reads, s.offchip_reads, "{tag}: offchip reads");
    assert_eq!(m.offchip_writes, s.offchip_writes, "{tag}: offchip writes");
    assert_eq!(m.total_ops, s.total_ops, "{tag}: total ops");
    assert_eq!(m.recompute_ops, s.recompute_ops, "{tag}: recompute");
    assert_eq!(m.iterations, s.iterations, "{tag}: iterations");
    assert_eq!(
        m.per_tensor_occupancy, s.per_tensor_occupancy,
        "{tag}: per-tensor occupancy"
    );
    assert_eq!(
        m.per_tensor_offchip, s.per_tensor_offchip,
        "{tag}: per-tensor offchip"
    );
    // Energy: both implementations apply the same per-action costs to
    // independently derived counts (the simulator measures by execution,
    // the model accumulates integer totals and converts once at the end),
    // so this anchors the model's float metrics against an implementation
    // that does not share its accumulation code. Counts agree exactly;
    // only f64 summation order differs, so 1% is generous.
    let e_model = m.energy.total_pj();
    let rel = (e_model - s.energy_pj).abs() / s.energy_pj.abs().max(1.0);
    assert!(
        rel < 0.01,
        "{tag}: energy model={e_model} sim={} (rel err {rel})",
        s.energy_pj
    );

    // Latency: the simulator explicitly serializes each tile's DRAM fetches
    // before its compute (no infinite prefetch), while the model assumes
    // Buffets-style decoupled orchestration (paper §IV-C1). On tiny test
    // workloads the pipeline-fill effect is proportionally large, so allow
    // 10%; the validation suite reports the measured error on the real
    // configurations (paper: ≤4%).
    let tol = 0.10 * s.compute_cycles.max(1) as f64;
    assert!(
        ((m.compute_cycles - s.compute_cycles).abs() as f64) <= tol.max(2.0),
        "{tag}: compute cycles model={} sim={}",
        m.compute_cycles,
        s.compute_cycles
    );
}

fn p_last(fs: &FusionSet) -> usize {
    fs.last()
        .rank_index(&format!("P{}", fs.num_layers()))
        .unwrap()
}

fn q_last(fs: &FusionSet) -> usize {
    fs.last()
        .rank_index(&format!("Q{}", fs.num_layers()))
        .unwrap()
}

#[test]
fn conv_conv_row_tiling() {
    let fs = workloads::conv_conv(14, 4);
    for tile in [1, 3, 4, 12] {
        let m = InterLayerMapping::tiled(
            vec![Partition { dim: p_last(&fs), tile }],
            Parallelism::Sequential,
        );
        check(&fs, &m, &format!("conv_conv p-tile {tile}"));
    }
}

#[test]
fn conv_conv_untiled() {
    let fs = workloads::conv_conv(10, 4);
    check(
        &fs,
        &InterLayerMapping::untiled(Parallelism::Sequential),
        "untiled",
    );
}

#[test]
fn conv_conv_2d_tiling_with_deep_retention() {
    let fs = workloads::conv_conv(12, 4);
    let (p, q) = (p_last(&fs), q_last(&fs));
    let inter = TensorId(2);
    for lvl in [1usize, 2] {
        let m = InterLayerMapping::tiled(
            vec![
                Partition { dim: p, tile: 4 },
                Partition { dim: q, tile: 5 },
            ],
            Parallelism::Sequential,
        )
        .with_retention(inter, lvl);
        check(&fs, &m, &format!("2d retention lvl {lvl}"));
    }
}

#[test]
fn conv_conv_pipeline() {
    let fs = workloads::conv_conv(14, 4);
    let m = InterLayerMapping::tiled(
        vec![Partition { dim: p_last(&fs), tile: 3 }],
        Parallelism::Pipeline,
    );
    check(&fs, &m, "pipeline");
}

#[test]
fn channel_partitioned() {
    let fs = workloads::conv_conv(10, 8);
    let c2 = fs.last().rank_index("C2").unwrap();
    let m = InterLayerMapping::tiled(
        vec![Partition { dim: c2, tile: 2 }],
        Parallelism::Sequential,
    );
    check(&fs, &m, "channel partitioned");
}

#[test]
fn channel_then_rows_refetch() {
    let fs = workloads::conv_conv(10, 8);
    let c2 = fs.last().rank_index("C2").unwrap();
    let p = p_last(&fs);
    let m = InterLayerMapping::tiled(
        vec![
            Partition { dim: c2, tile: 4 },
            Partition { dim: p, tile: 2 },
        ],
        Parallelism::Sequential,
    )
    .with_retention(TensorId(0), 2); // refetch Fmap1 per channel tile
    check(&fs, &m, "channel+rows refetch");
}

#[test]
fn pdp_block() {
    let fs = workloads::pwise_dwise_pwise(10, 4);
    let p3 = fs.last().rank_index("P3").unwrap();
    for tile in [2, 5] {
        let m = InterLayerMapping::tiled(
            vec![Partition { dim: p3, tile }],
            Parallelism::Sequential,
        );
        check(&fs, &m, &format!("pdp tile {tile}"));
    }
}

#[test]
fn fc_fc_token_tiling() {
    let fs = workloads::fc_fc(32, 16);
    let m2 = fs.last().rank_index("M2").unwrap();
    let m = InterLayerMapping::tiled(
        vec![Partition { dim: m2, tile: 8 }],
        Parallelism::Sequential,
    );
    check(&fs, &m, "fc_fc");
}

#[test]
fn three_conv_mixed_retention() {
    let fs = workloads::conv_conv_conv(12, 2);
    let p3 = fs.last().rank_index("P3").unwrap();
    let q3 = fs.last().rank_index("Q3").unwrap();
    let parts = vec![
        Partition { dim: p3, tile: 2 },
        Partition { dim: q3, tile: 4 },
    ];
    for (l2, l3) in [(1, 1), (1, 2), (2, 1), (2, 2)] {
        let m = InterLayerMapping::tiled(parts.clone(), Parallelism::Sequential)
            .with_retention(TensorId(2), l2)
            .with_retention(TensorId(4), l3);
        check(&fs, &m, &format!("3conv retention {l2}/{l3}"));
    }
}

#[test]
fn attention_tiling() {
    let fs = workloads::self_attention(1, 2, 16, 8);
    let mr = fs.last().rank_index("M2").unwrap();
    let m = InterLayerMapping::tiled(
        vec![Partition { dim: mr, tile: 4 }],
        Parallelism::Sequential,
    );
    check(&fs, &m, "attention");
}

#[test]
fn ragged_tiles() {
    let fs = workloads::conv_conv(13, 3); // P2 = 11, awkward
    let m = InterLayerMapping::tiled(
        vec![Partition { dim: p_last(&fs), tile: 4 }],
        Parallelism::Sequential,
    );
    check(&fs, &m, "ragged");
}

#[test]
fn strided_depthwise() {
    use looptree::einsum::FusionSetBuilder;
    let fs = FusionSetBuilder::new("pw+dw-s2", &[4, 13, 13])
        .pointwise(8)
        .depthwise(3, 3, 2)
        .build();
    let p2 = fs.last().rank_index("P2").unwrap();
    let m = InterLayerMapping::tiled(
        vec![Partition { dim: p2, tile: 2 }],
        Parallelism::Sequential,
    );
    check(&fs, &m, "strided dwise");
}

#[test]
fn pooling_in_fusion_set() {
    use looptree::einsum::FusionSetBuilder;
    let fs = FusionSetBuilder::new("conv+pool", &[2, 14, 14])
        .conv2d(4, 3, 3, 1)
        .maxpool(2, 2)
        .build();
    let p2 = fs.last().rank_index("P2").unwrap();
    let m = InterLayerMapping::tiled(
        vec![Partition { dim: p2, tile: 2 }],
        Parallelism::Sequential,
    );
    check(&fs, &m, "conv+pool");
}
