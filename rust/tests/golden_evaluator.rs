//! Golden equivalence: the validate-once `Evaluator` session must reproduce
//! the legacy free `evaluate()` bit-for-bit — on all five validation designs
//! (DepFin, Fused-layer CNN, ISAAC, PipeLayer, FLAT) and on randomized
//! (workload, mapping) pairs. The session refactor moves *where* validation
//! and intra-layer derivation happen; it must not move a single bit of the
//! metrics.

use looptree::einsum::{workloads, FusionSet, TensorId};
use looptree::mapping::{InterLayerMapping, Parallelism, Partition};
use looptree::model::{evaluate, EvalOptions, Evaluator, Metrics};
use looptree::util::prng::Prng;
use looptree::validation::{design_points, Scale};

/// Bitwise equality across every metric field.
fn assert_bitwise_equal(a: &Metrics, b: &Metrics, tag: &str) {
    assert_eq!(a.latency_cycles, b.latency_cycles, "{tag}: latency_cycles");
    assert_eq!(a.compute_cycles, b.compute_cycles, "{tag}: compute_cycles");
    assert_eq!(a.memory_cycles, b.memory_cycles, "{tag}: memory_cycles");
    assert_eq!(
        a.sequential_compute_cycles, b.sequential_compute_cycles,
        "{tag}: sequential_compute_cycles"
    );
    assert_eq!(a.offchip_reads, b.offchip_reads, "{tag}: offchip_reads");
    assert_eq!(a.offchip_writes, b.offchip_writes, "{tag}: offchip_writes");
    assert_eq!(a.glb_reads, b.glb_reads, "{tag}: glb_reads");
    assert_eq!(a.glb_writes, b.glb_writes, "{tag}: glb_writes");
    assert_eq!(
        a.noc_hop_words.to_bits(),
        b.noc_hop_words.to_bits(),
        "{tag}: noc_hop_words"
    );
    assert_eq!(a.per_tensor_offchip, b.per_tensor_offchip, "{tag}: per_tensor_offchip");
    assert_eq!(a.occupancy_peak, b.occupancy_peak, "{tag}: occupancy_peak");
    assert_eq!(
        a.per_tensor_occupancy, b.per_tensor_occupancy,
        "{tag}: per_tensor_occupancy"
    );
    assert_eq!(a.capacity_ok, b.capacity_ok, "{tag}: capacity_ok");
    assert_eq!(a.total_ops, b.total_ops, "{tag}: total_ops");
    assert_eq!(a.recompute_ops, b.recompute_ops, "{tag}: recompute_ops");
    assert_eq!(
        a.per_tensor_recompute, b.per_tensor_recompute,
        "{tag}: per_tensor_recompute"
    );
    assert_eq!(a.iterations, b.iterations, "{tag}: iterations");
    for (field, x, y) in [
        ("dram_pj", a.energy.dram_pj, b.energy.dram_pj),
        ("glb_pj", a.energy.glb_pj, b.energy.glb_pj),
        ("rf_pj", a.energy.rf_pj, b.energy.rf_pj),
        ("compute_pj", a.energy.compute_pj, b.energy.compute_pj),
        ("noc_pj", a.energy.noc_pj, b.energy.noc_pj),
    ] {
        assert_eq!(x.to_bits(), y.to_bits(), "{tag}: energy.{field}");
    }
}

#[test]
fn session_matches_legacy_on_all_five_validation_designs() {
    for point in design_points(Scale::Test) {
        // Validations run with the GLB unbounded, as the drivers do.
        let arch = point.arch.unbounded_glb();
        let legacy = evaluate(&point.fs, &arch, &point.mapping, &EvalOptions::default())
            .unwrap_or_else(|e| panic!("{}: legacy: {e}", point.design));
        let ev = Evaluator::new(&point.fs, &arch)
            .unwrap_or_else(|e| panic!("{}: session: {e}", point.design));
        let session = ev
            .evaluate(&point.mapping)
            .unwrap_or_else(|e| panic!("{}: session eval: {e}", point.design));
        assert_bitwise_equal(&session, &legacy, point.design);
        // And with the design's real capacity bound, capacity_ok included.
        let legacy_b =
            evaluate(&point.fs, &point.arch, &point.mapping, &EvalOptions::default()).unwrap();
        let session_b = Evaluator::new(&point.fs, &point.arch)
            .unwrap()
            .evaluate(&point.mapping)
            .unwrap();
        assert_bitwise_equal(&session_b, &legacy_b, point.design);
    }
}

fn random_mapping(fs: &FusionSet, rng: &mut Prng) -> InterLayerMapping {
    let last = fs.last();
    let nparts = rng.index(4);
    let mut dims: Vec<usize> = (0..last.ndim()).collect();
    rng.shuffle(&mut dims);
    let mut partitions = Vec::new();
    for &dim in dims.iter().take(nparts) {
        let extent = last.rank_sizes[dim];
        if extent < 2 {
            continue;
        }
        let tile = rng.range_i64(1, extent);
        partitions.push(Partition { dim, tile });
    }
    let parallelism = if rng.chance(0.5) {
        Parallelism::Sequential
    } else {
        Parallelism::Pipeline
    };
    let k = partitions.len();
    let mut m = InterLayerMapping::tiled(partitions, parallelism);
    for x in 0..fs.tensors.len() {
        if rng.chance(0.5) {
            m = m.with_retention(TensorId(x), rng.index(k + 1));
        }
    }
    m
}

#[test]
fn session_matches_legacy_on_random_mappings() {
    let mut rng = Prng::new(0x5E55);
    let arch = looptree::arch::Arch::generic(256);
    for case in 0..40 {
        let fs = match rng.index(4) {
            0 => workloads::conv_conv(6 + rng.range_i64(0, 10), 2 + rng.range_i64(0, 6)),
            1 => workloads::pwise_dwise_pwise(6 + rng.range_i64(0, 8), 2 + rng.range_i64(0, 3)),
            2 => workloads::fc_fc(8 + rng.range_i64(0, 24), 4 + rng.range_i64(0, 12)),
            _ => workloads::self_attention(1, 2, 8 + rng.range_i64(0, 8), 4),
        };
        let ev = Evaluator::new(&fs, &arch).unwrap();
        for _ in 0..5 {
            let mapping = random_mapping(&fs, &mut rng);
            if mapping.total_iterations(&fs) > 50_000 {
                continue;
            }
            let legacy = evaluate(&fs, &arch, &mapping, &EvalOptions::default())
                .unwrap_or_else(|e| panic!("case {case} ({}): {e}", fs.name));
            let session = ev.evaluate(&mapping).unwrap();
            assert_bitwise_equal(&session, &legacy, &format!("case {case} ({})", fs.name));
        }
    }
}
