//! Regenerates paper Fig 18: tiled fusion vs best-of(layer-by-layer,
//! untiled fusion) transfers/capacity fronts.

use looptree::casestudies::fig18;
use looptree::util::bench::bench_once;

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let (fronts, t) = bench_once("fig18 sweep", || fig18::run(!full));
    println!("{}", fig18::render(&fronts));
    println!("{}", t.report());
}
