//! Regenerates paper Fig 15: recomputation vs capacity Pareto fronts per
//! schedule on pwise+dwise+pwise.

use looptree::casestudies::fig15;
use looptree::util::bench::bench_once;

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let (curves, t) = bench_once("fig15 sweep", || fig15::run(!full));
    println!("{}", fig15::render(&curves));
    println!("{}", t.report());
}
