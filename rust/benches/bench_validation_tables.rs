//! Regenerates the validation tables (paper Tables V-VIII + Fig 13 series)
//! and times each design's model evaluation.

use looptree::util::bench::bench_once;
use looptree::validation::{self, Scale};

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let scale = if full { Scale::Full } else { Scale::Test };
    println!("scale: {scale:?} (pass --full for publication-sized workloads)\n");
    let mut all = Vec::new();
    for (name, f) in [
        ("DepFin (Table V row)", validation::validate_depfin as fn(Scale) -> Vec<_>),
        ("Fused-layer CNN (Table VI)", validation::validate_fused_cnn),
        ("ISAAC (Table VII)", validation::validate_isaac),
        ("PipeLayer (Table VIII)", validation::validate_pipelayer),
        ("FLAT (Fig 13)", validation::validate_flat),
    ] {
        let (rows, t) = bench_once(name, || f(scale));
        println!("{}", t.report());
        all.extend(rows);
    }
    println!("\n{}", validation::summarize(&all));
    let worst = all.iter().map(|r| r.error_pct()).fold(0.0f64, f64::max);
    println!("worst-case model-vs-reference error: {worst:.2}% (paper: <= 4%)");
}
