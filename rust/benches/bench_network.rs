//! Network-level partitioning benchmark: DP over fused-segment cut sets
//! with memoized per-segment mapspace searches, on the built-in whole-DNN
//! chains. The headline numbers are the end-to-end partition time and the
//! memoization leverage (distinct shapes searched vs candidate segments).
//!
//! Emits `BENCH_network.json`; `LOOPTREE_BENCH_SMOKE=1` shrinks the
//! per-segment search budgets for CI.

use looptree::arch::Arch;
use looptree::coordinator::Coordinator;
use looptree::mapspace::MapSpaceConfig;
use looptree::network::{self, Network, NetworkSearchSpec};
use looptree::search::SearchSpec;
use looptree::util::bench::{bench, reps, smoke, write_bench_json};
use looptree::util::json::Json;

fn spec() -> NetworkSearchSpec {
    NetworkSearchSpec {
        max_segment_layers: if smoke() { 2 } else { 3 },
        search: SearchSpec {
            mapspace: MapSpaceConfig {
                uniform_retention: true,
                tile_sizes: if smoke() { vec![8] } else { vec![2, 8, 32] },
                ..Default::default()
            },
            ..Default::default()
        },
    }
}

fn main() {
    let arch = Arch::generic(256);
    let pool = Coordinator::new(0);
    let spec = spec();
    let (warmup, iters) = reps(1, 5);

    let nets: Vec<Network> = vec![
        network::resnet18(),
        network::mobilenet_v2(),
        network::vgg16(),
        network::bert_encoder(1, 12, 512, 64),
    ];

    let mut rows: Vec<Json> = Vec::new();
    for net in &nets {
        let result = network::search_network(net, &arch, &spec, &pool)
            .expect("network search found no partition");
        let t = bench(&format!("search_network({})", net.name), warmup, iters, || {
            network::search_network(net, &arch, &spec, &pool).unwrap()
        });
        println!(
            "{}  -> {} cuts, {}/{} segments searched, total {:.3e}",
            t.report(),
            result.cuts.len(),
            result.distinct_searched,
            result.candidate_segments,
            result.total_score
        );
        rows.push(Json::Obj(
            [
                ("workload".to_string(), Json::Str(net.name.clone())),
                ("mean_ns".to_string(), Json::Num(t.mean.as_nanos() as f64)),
                ("layers".to_string(), Json::Num(net.num_layers() as f64)),
                ("cuts".to_string(), Json::Num(result.cuts.len() as f64)),
                (
                    "candidate_segments".to_string(),
                    Json::Num(result.candidate_segments as f64),
                ),
                (
                    "distinct_searched".to_string(),
                    Json::Num(result.distinct_searched as f64),
                ),
                ("total_score".to_string(), Json::Num(result.total_score)),
                (
                    "total_offchip_elems".to_string(),
                    Json::Num(result.total_offchip() as f64),
                ),
                ("all_fit".to_string(), Json::Bool(result.all_fit())),
            ]
            .into_iter()
            .collect(),
        ));
    }

    let report = Json::Obj([("rows".to_string(), Json::Arr(rows))].into_iter().collect());
    match write_bench_json("BENCH_network.json", &report) {
        Ok(()) => println!("wrote BENCH_network.json"),
        Err(e) => eprintln!("failed to write BENCH_network.json: {e}"),
    }
}
