//! Network-level partitioning benchmark: DP over fused-segment covers
//! (chain cut points for paths, graph cuts for branched DAGs) with
//! memoized per-segment mapspace searches, on the built-in whole-DNN
//! graphs. The headline numbers are the end-to-end partition time and the
//! memoization leverage (distinct shapes searched vs candidate segments).
//!
//! Emits `BENCH_network.json` (schema pinned by
//! `util::bench::check_network_bench_schema`); `LOOPTREE_BENCH_SMOKE=1`
//! shrinks the per-segment search budgets for CI.

use looptree::arch::Arch;
use looptree::coordinator::Coordinator;
use looptree::mapspace::MapSpaceConfig;
use looptree::network::{self, LayerOp, Network, NetworkSearchSpec};
use looptree::search::SearchSpec;
use looptree::util::bench::{bench, check_network_bench_schema, reps, smoke, write_bench_json};
use looptree::util::json::Json;

fn spec() -> NetworkSearchSpec {
    NetworkSearchSpec {
        max_segment_layers: if smoke() { 2 } else { 3 },
        search: SearchSpec {
            mapspace: MapSpaceConfig {
                uniform_retention: true,
                tile_sizes: if smoke() { vec![8] } else { vec![2, 8, 32] },
                ..Default::default()
            },
            ..Default::default()
        },
        ..Default::default()
    }
}

fn main() {
    let arch = Arch::generic(256);
    let pool = Coordinator::new(0);
    let spec = spec();
    let (warmup, iters) = reps(1, 5);

    // resnet18 / mobilenetv2 carry their real residual edges (graph DP);
    // resnet18_chain pins the path fast-path against the same backbone.
    let nets: Vec<Network> = vec![
        network::resnet18(),
        network::resnet18_chain(),
        network::mobilenet_v2(),
        network::vgg16(),
        network::bert_encoder(1, 12, 512, 64),
    ];

    let mut rows: Vec<Json> = Vec::new();
    for net in &nets {
        let result = network::search_network(net, &arch, &spec, &pool)
            .expect("network search found no partition");
        let t = bench(&format!("search_network({})", net.name), warmup, iters, || {
            network::search_network(net, &arch, &spec, &pool).unwrap()
        });
        let branching = result.segments.iter().filter(|s| s.spans_branch(net)).count();
        println!(
            "{}  -> {} cuts, {}/{} segments searched, {} branch-fused, total {:.3e}",
            t.report(),
            result.cuts.len(),
            result.distinct_searched,
            result.candidate_segments,
            branching,
            result.total_score
        );
        rows.push(result.bench_row(&net.name, net.num_layers(), t.mean.as_nanos() as f64));
    }

    // A conv stack sized so the fused pair provably overflows a small GLB:
    // the closed-form capacity floor prunes the 2-layer candidate before any
    // mapspace search and the lossless guard certifies the survivor optimum,
    // so `candidates_pruned` is a nonzero deterministic counter the CI
    // determinism gate diffs across runs.
    let mut prune_net = Network { name: "prune_stack".into(), layers: vec![] };
    for i in 0..2 {
        prune_net.push(
            &format!("conv{i}"),
            &[96, 22, 22],
            LayerOp::Conv2d { out_channels: 96, r: 3, s: 3, stride: 1 },
        );
    }
    let prune_arch = Arch::generic(128);
    let prune_spec = NetworkSearchSpec { max_segment_layers: 2, ..spec.clone() };
    {
        let result = network::search_network(&prune_net, &prune_arch, &prune_spec, &pool)
            .expect("prune_stack search found no partition");
        assert!(
            result.candidates_pruned > 0,
            "prune_stack must exercise static candidate pruning"
        );
        let t = bench("search_network(prune_stack)", warmup, iters, || {
            network::search_network(&prune_net, &prune_arch, &prune_spec, &pool).unwrap()
        });
        println!(
            "{}  -> {} cuts, {}/{} segments searched, {} statically pruned, total {:.3e}",
            t.report(),
            result.cuts.len(),
            result.distinct_searched,
            result.candidate_segments,
            result.candidates_pruned,
            result.total_score
        );
        rows.push(result.bench_row(
            &prune_net.name,
            prune_net.num_layers(),
            t.mean.as_nanos() as f64,
        ));
    }

    // Pareto-front DP (vector costs over the default latency/energy/
    // capacity/offchip axes) on one branched and one path network. The
    // beam cap keeps the label sets bounded; front sizes are deterministic
    // counters the CI determinism gate diffs across runs.
    let pareto_spec = NetworkSearchSpec {
        max_front_per_state: if smoke() { 8 } else { 32 },
        ..spec.clone()
    };
    let mut pareto_rows: Vec<Json> = Vec::new();
    for net in [network::resnet18(), network::vgg16()] {
        let result = network::search_network_pareto(&net, &arch, &pareto_spec, &pool)
            .expect("network pareto search found no partition");
        let t = bench(
            &format!("search_network_pareto({})", net.name),
            warmup,
            iters,
            || network::search_network_pareto(&net, &arch, &pareto_spec, &pool).unwrap(),
        );
        println!(
            "{}  -> {} front points ({} memoized per-segment points, {}/{} segments searched)",
            t.report(),
            result.points.len(),
            result.segment_front_points,
            result.distinct_searched,
            result.candidate_segments,
        );
        pareto_rows.push(result.bench_row(&net.name, net.num_layers(), t.mean.as_nanos() as f64));
    }

    let report = Json::Obj(
        [
            ("rows".to_string(), Json::Arr(rows)),
            ("pareto_rows".to_string(), Json::Arr(pareto_rows)),
        ]
        .into_iter()
        .collect(),
    );
    check_network_bench_schema(&report).expect("BENCH_network.json schema drifted");
    match write_bench_json("BENCH_network.json", &report) {
        Ok(()) => println!("wrote BENCH_network.json"),
        Err(e) => eprintln!("failed to write BENCH_network.json: {e}"),
    }
}
