//! Serve load-test harness: boot an in-process `looptree serve` server and
//! drive it with N concurrent synthetic clients over real TCP, measuring
//! request latency percentiles and throughput per scenario. The headline
//! numbers are the cold-vs-warmed latency gap (the cross-request segment
//! cache's leverage) and the deterministic cache counters
//! (`cache_hits`/`cache_misses`/`warm_starts`), which the CI determinism
//! gate diffs across two runs.
//!
//! Emits `BENCH_serve.json` (schema pinned by
//! `util::bench::check_serve_bench_schema`); `LOOPTREE_BENCH_SMOKE=1`
//! shrinks request counts for CI.

use looptree::arch::Arch;
use looptree::einsum::workloads;
use looptree::mapspace::MapSpaceConfig;
use looptree::network::{LayerOp, Network, NetworkSearchSpec};
use looptree::search::{Algorithm, SearchSpec};
use looptree::serve::{bench_row, response_stats, post_json, ServeOptions, Server, ServerHandle};
use looptree::spec::{NetworkConfig, SearchConfig, ServeStats};
use looptree::util::bench::{check_serve_bench_schema, smoke, write_bench_json, LatencyStats};
use looptree::util::json::Json;
use std::time::{Duration, Instant};

fn envelope(kind: &str, config: Json, warm_start: bool) -> Json {
    let mut pairs = vec![
        ("kind".to_string(), Json::Str(kind.to_string())),
        ("config".to_string(), config),
    ];
    if warm_start {
        pairs.push(("warm_start".to_string(), Json::Bool(true)));
    }
    Json::Obj(pairs.into_iter().collect())
}

/// A small conv chain whose repeated blocks give the segment memo (and the
/// cross-request cache) something to deduplicate, cheap enough for smoke.
fn bench_network_config() -> Json {
    let mut net = Network { name: "serve_stack".into(), layers: vec![] };
    for i in 0..4 {
        net.push(
            &format!("conv{i}"),
            &[16, 14, 14],
            LayerOp::Conv2d { out_channels: 16, r: 3, s: 3, stride: 1 },
        );
    }
    let cfg = NetworkConfig {
        network: net,
        arch: Arch::generic(256),
        segment_search: NetworkSearchSpec {
            max_segment_layers: 2,
            search: SearchSpec {
                mapspace: MapSpaceConfig {
                    uniform_retention: true,
                    tile_sizes: vec![8],
                    ..Default::default()
                },
                ..Default::default()
            },
            ..Default::default()
        },
        cuts: None,
        pareto: false,
    };
    cfg.to_json()
}

fn bench_search_config() -> Json {
    let cfg = SearchConfig {
        workload: workloads::conv_conv(14, 8),
        arch: Arch::generic(256),
        search: SearchSpec {
            algorithm: Algorithm::Annealing,
            iters: if smoke() { 40 } else { 200 },
            seed: 7,
            mapspace: MapSpaceConfig {
                uniform_retention: true,
                tile_sizes: vec![2, 8],
                ..Default::default()
            },
            ..Default::default()
        },
    };
    cfg.to_json()
}

struct ScenarioResult {
    times: Vec<Duration>,
    elapsed: Duration,
    stats: ServeStats,
    all_ok: bool,
    responses: Vec<Json>,
}

/// Fan `requests_per_client` copies of `doc` out over `clients` concurrent
/// TCP clients and tally latencies, envelope counters, and ok-ness.
fn drive(handle: &ServerHandle, doc: &Json, clients: usize, requests_per_client: usize) -> ScenarioResult {
    let addr = handle.addr();
    let t0 = Instant::now();
    let per_client: Vec<(Vec<Duration>, Vec<Json>)> = std::thread::scope(|scope| {
        let jobs: Vec<_> = (0..clients)
            .map(|_| {
                scope.spawn(move || {
                    let mut times = Vec::with_capacity(requests_per_client);
                    let mut responses = Vec::with_capacity(requests_per_client);
                    for _ in 0..requests_per_client {
                        let r0 = Instant::now();
                        let (status, resp) =
                            post_json(&addr, "/", doc).expect("serve request failed");
                        times.push(r0.elapsed());
                        assert_eq!(status, 200, "unexpected HTTP status: {resp}");
                        responses.push(resp);
                    }
                    (times, responses)
                })
            })
            .collect();
        jobs.into_iter().map(|j| j.join().expect("client thread panicked")).collect()
    });
    let elapsed = t0.elapsed();
    let mut times = Vec::new();
    let mut responses = Vec::new();
    for (t, r) in per_client {
        times.extend(t);
        responses.extend(r);
    }
    let mut stats = ServeStats::default();
    let mut all_ok = true;
    for resp in &responses {
        let s = response_stats(resp);
        stats.cache_hits += s.cache_hits;
        stats.cache_misses += s.cache_misses;
        stats.warm_starts += s.warm_starts;
        all_ok &= resp.get("ok").and_then(Json::as_bool).unwrap_or(false);
    }
    ScenarioResult { times, elapsed, stats, all_ok, responses }
}

fn report_row(name: &str, clients: usize, r: &ScenarioResult) -> Json {
    let lat = LatencyStats::from_times(&r.times);
    println!(
        "{name:28} {:>4} reqs x{clients:>2} clients  p50 {:?}  p99 {:?}  hits {}  misses {}  warm {}",
        r.times.len(),
        lat.p50,
        lat.p99,
        r.stats.cache_hits,
        r.stats.cache_misses,
        r.stats.warm_starts
    );
    bench_row(name, clients, r.times.len(), &lat, r.elapsed, &r.stats, r.all_ok)
}

fn main() {
    let server = Server::bind("127.0.0.1:0", ServeOptions::default())
        .expect("bind serve bench server");
    let handle = server.spawn();
    let (serial_reqs, clients, reqs_per_client) = if smoke() { (3, 4, 2) } else { (8, 8, 8) };

    let net_doc = envelope("network", bench_network_config(), false);
    let search_cold = envelope("search", bench_search_config(), false);
    let search_warm = envelope("search", bench_search_config(), true);

    let mut rows: Vec<Json> = Vec::new();

    // Cold + sequential: the first request populates the cache (misses
    // only), the rest replay it (hits only) — so the aggregate counters are
    // exact functions of the request count and the network's distinct
    // segment-signature count.
    let cold = drive(&handle, &net_doc, 1, serial_reqs);
    assert!(cold.stats.cache_misses > 0, "cold scenario must miss");
    assert!(cold.stats.cache_hits > 0, "replays within the cold scenario must hit");
    let first = response_stats(&cold.responses[0]);
    assert_eq!(first.cache_hits, 0, "first-ever request cannot hit");
    rows.push(report_row("network-cold-serial", 1, &cold));

    // Fully-warmed concurrent replay: every request is a pure cache hit, so
    // the counters stay deterministic under any client interleaving.
    let warmed = drive(&handle, &net_doc, clients, reqs_per_client);
    assert_eq!(warmed.stats.cache_misses, 0, "warmed scenario must not miss");
    rows.push(report_row("network-warmed-concurrent", clients, &warmed));

    // Warm-started annealing: a cold run seeds the warm pool, then every
    // warm_start request reports warm_starts=1 and may only improve on the
    // cold best (the seeds join the evaluated set).
    let seed_run = drive(&handle, &search_cold, 1, 1);
    assert!(seed_run.all_ok, "cold search must succeed");
    let cold_best = seed_run.responses[0]
        .get("result")
        .and_then(|r| r.get("result"))
        .and_then(|r| r.get("best"))
        .and_then(|b| b.get("score"))
        .and_then(Json::as_f64)
        .expect("cold search response carries a best score");
    let warm = drive(&handle, &search_warm, 1, serial_reqs);
    assert_eq!(
        warm.stats.warm_starts,
        warm.responses.len() as u64,
        "every warm_start request must report a warm start"
    );
    for resp in &warm.responses {
        let warm_best = resp
            .get("result")
            .and_then(|r| r.get("result"))
            .and_then(|r| r.get("best"))
            .and_then(|b| b.get("score"))
            .and_then(Json::as_f64)
            .expect("warm search response carries a best score");
        assert!(
            warm_best <= cold_best,
            "warm-started search regressed: {warm_best} > {cold_best}"
        );
    }
    rows.push(report_row("search-warm-start", 1, &warm));

    handle.stop();

    let report = Json::Obj(
        [("rows".to_string(), Json::Arr(rows))].into_iter().collect(),
    );
    check_serve_bench_schema(&report).expect("BENCH_serve.json schema drifted");
    match write_bench_json("BENCH_serve.json", &report) {
        Ok(()) => println!("wrote BENCH_serve.json"),
        Err(e) => eprintln!("failed to write BENCH_serve.json: {e}"),
    }
}
