//! Regenerates paper Fig 16: per-tensor vs uniform retention trade-off.

use looptree::casestudies::fig16;
use looptree::util::bench::bench_once;

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let (res, t) = bench_once("fig16 sweep", || fig16::run(!full));
    println!("{}", fig16::render(&res));
    println!("{}", t.report());
}
