//! Regenerates paper Fig 17: per-intermediate-fmap retain-recompute choices
//! on conv+conv+conv.

use looptree::casestudies::fig17;
use looptree::util::bench::bench_once;

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let (curves, t) = bench_once("fig17 sweep", || fig17::run(!full));
    println!("{}", fig17::render(&curves));
    println!("{}", t.report());
}
