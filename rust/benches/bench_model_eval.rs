//! Model-evaluation throughput: the paper's §IV claim that the analytical
//! model is orders of magnitude faster than simulation, the validate-once
//! `Evaluator` session vs. the legacy free `evaluate()`, and — the headline
//! of this bench since the symbolic tier landed — the full three-tier
//! comparison: closed-form symbolic box walk vs. steady-state fast path vs.
//! exhaustive reference walk on long row-tiled walks. The bench asserts all
//! three tiers agree bit-for-bit and pins which configurations the symbolic
//! walk must cover (`Metrics::path.symbolic`), which of those must take the
//! bounded box-union (multibox) path (`peak_union_width >= 2`), and that a
//! genuinely-refusing mapping gets its repeat symbolic attempts absorbed by
//! the session's refusal memo.
//!
//! Emits `BENCH_model_eval.json` (workload, mean ns, iterations/s, the
//! fast-vs-reference speedups, and the symbolic-vs-fast speedups) so the
//! perf trajectory is tracked run over run; `LOOPTREE_BENCH_SMOKE=1` clamps
//! repetitions for CI.

use looptree::arch::Arch;
use looptree::einsum::{workloads, FusionSetBuilder};
use looptree::mapping::{InterLayerMapping, Parallelism, Partition};
use looptree::model::{evaluate, EvalOptions, Evaluator};
use looptree::sim::simulate;
use looptree::util::bench::{
    bench, check_model_eval_bench_schema, reps, write_bench_json, BenchResult,
};
use looptree::util::json::Json;

/// One `symbolic_speedups` row of `BENCH_model_eval.json`: the three-tier
/// timing comparison plus the deterministic path-attribution counters the
/// CI determinism gate diffs.
struct SymRow {
    label: String,
    iterations: i64,
    symbolic_ns: f64,
    fast_ns: f64,
    reference_ns: f64,
    speedup_vs_fast: f64,
    symbolic_fired: bool,
    peak_union_width: i64,
    refusal_memo_hits: i64,
}

impl SymRow {
    fn to_json(&self) -> Json {
        Json::Obj(
            [
                ("workload".to_string(), Json::Str(self.label.clone())),
                ("iterations".to_string(), Json::Num(self.iterations as f64)),
                ("symbolic_mean_ns".to_string(), Json::Num(self.symbolic_ns)),
                ("fast_mean_ns".to_string(), Json::Num(self.fast_ns)),
                ("reference_mean_ns".to_string(), Json::Num(self.reference_ns)),
                (
                    "speedup_vs_fast".to_string(),
                    Json::Num(self.speedup_vs_fast),
                ),
                ("symbolic_fired".to_string(), Json::Bool(self.symbolic_fired)),
                (
                    "multibox_fired".to_string(),
                    Json::Bool(self.peak_union_width >= 2),
                ),
                (
                    "peak_union_width".to_string(),
                    Json::Num(self.peak_union_width as f64),
                ),
                (
                    "refusal_memo_hits".to_string(),
                    Json::Num(self.refusal_memo_hits as f64),
                ),
            ]
            .into_iter()
            .collect(),
        )
    }
}

fn main() {
    let arch = Arch::generic(1 << 20);
    let opts = EvalOptions::default();
    let mut rows: Vec<BenchResult> = Vec::new();
    let mut speedups: Vec<Json> = Vec::new();
    let mut symbolic_speedups: Vec<Json> = Vec::new();

    println!("== symbolic vs fast path vs reference walk ==");
    // (rows, ch, partition spec): the 112×112 row-tiled configurations are
    // the acceptance gate — the reference walk is O(total tiles), the
    // steady-state fast path O(distinct tile classes), and the symbolic box
    // walk O(width² · schedule levels). `expect_symbolic` pins which
    // configurations the closed-form path must cover; `expect_multibox`
    // pins which of those need the bounded box-union calculus: row-only
    // (nested or not) tilings stay in single-box form, while the row+col
    // tiling wraps the fresh set into an L-shape at each column boundary —
    // two boxes, within the width bound, so the walk no longer falls back.
    struct FastRow {
        label: &'static str,
        rows: i64,
        ch: i64,
        tiles: &'static [(&'static str, i64)],
        expect_symbolic: bool,
        expect_multibox: bool,
    }
    let configs = [
        FastRow {
            label: "conv_conv(112,64) row-tiled",
            rows: 112,
            ch: 64,
            tiles: &[("P2", 1)],
            expect_symbolic: true,
            expect_multibox: false,
        },
        FastRow {
            label: "conv_conv(112,64) row+col-tiled",
            rows: 112,
            ch: 64,
            tiles: &[("P2", 1), ("Q2", 1)],
            expect_symbolic: true,
            expect_multibox: true,
        },
        FastRow {
            label: "conv_conv(112,64) nested row-tiled",
            rows: 112,
            ch: 64,
            tiles: &[("P2", 8), ("P2", 1)],
            expect_symbolic: true,
            expect_multibox: false,
        },
        FastRow {
            label: "conv_conv(56,64) row-tiled",
            rows: 56,
            ch: 64,
            tiles: &[("P2", 2)],
            expect_symbolic: true,
            expect_multibox: false,
        },
    ];
    let mut any_symbolic = false;
    let mut any_multibox = false;
    for cfg in &configs {
        let fs = workloads::conv_conv(cfg.rows, cfg.ch);
        let ev = Evaluator::new(&fs, &arch).unwrap();
        let partitions: Vec<Partition> = cfg
            .tiles
            .iter()
            .map(|&(name, tile)| Partition {
                dim: fs.last().rank_index(name).unwrap(),
                tile,
            })
            .collect();
        let mapping = InterLayerMapping::tiled(partitions, Parallelism::Sequential);
        let m_sym = ev.evaluate(&mapping).unwrap();
        let m_fast = ev.evaluate_no_symbolic(&mapping).unwrap();
        let m_ref = ev.evaluate_reference(&mapping).unwrap();
        assert_eq!(m_sym.latency_cycles, m_ref.latency_cycles, "symbolic path drifted");
        assert_eq!(m_sym.iterations, m_ref.iterations, "symbolic path drifted");
        assert_eq!(m_fast.latency_cycles, m_ref.latency_cycles, "fast path drifted");
        assert_eq!(m_fast.iterations, m_ref.iterations, "fast path drifted");
        if cfg.expect_symbolic {
            assert!(
                m_sym.path.symbolic,
                "symbolic walk unexpectedly fell back on {}",
                cfg.label
            );
        }
        assert_eq!(
            m_sym.path.peak_union_width >= 2,
            cfg.expect_multibox,
            "multibox expectation drifted on {} (peak union width {})",
            cfg.label,
            m_sym.path.peak_union_width
        );
        any_symbolic |= m_sym.path.symbolic;
        any_multibox |= m_sym.path.peak_union_width >= 2;
        let memo_hits = ev.refusal_memo_hits();

        let (w, n) = reps(2, 12);
        let symbolic = bench(&format!("symbolic  {}", cfg.label), w, n, || {
            ev.evaluate(&mapping).unwrap()
        });
        let fast = bench(&format!("fast      {}", cfg.label), w, n, || {
            ev.evaluate_no_symbolic(&mapping).unwrap()
        });
        let (w, n) = reps(1, 4);
        let reference = bench(&format!("reference {}", cfg.label), w, n, || {
            ev.evaluate_reference(&mapping).unwrap()
        });
        println!("{}", symbolic.report());
        println!("{}", fast.report());
        println!("{}", reference.report());
        let speedup = reference.mean.as_secs_f64() / fast.mean.as_secs_f64().max(1e-12);
        let speedup_vs_fast = fast.mean.as_secs_f64() / symbolic.mean.as_secs_f64().max(1e-12);
        println!(
            "    {} iterations walked; fast-vs-reference {speedup:.1}x; \
             symbolic-vs-fast {speedup_vs_fast:.2}x (fired: {}, peak union width: {})",
            m_ref.iterations, m_sym.path.symbolic, m_sym.path.peak_union_width
        );
        speedups.push(Json::Obj(
            [
                ("workload".to_string(), Json::Str(cfg.label.to_string())),
                ("iterations".to_string(), Json::Num(m_ref.iterations as f64)),
                (
                    "fast_mean_ns".to_string(),
                    Json::Num(fast.mean.as_nanos() as f64),
                ),
                (
                    "reference_mean_ns".to_string(),
                    Json::Num(reference.mean.as_nanos() as f64),
                ),
                ("speedup".to_string(), Json::Num(speedup)),
            ]
            .into_iter()
            .collect(),
        ));
        symbolic_speedups.push(
            SymRow {
                label: cfg.label.to_string(),
                iterations: m_ref.iterations,
                symbolic_ns: symbolic.mean.as_nanos() as f64,
                fast_ns: fast.mean.as_nanos() as f64,
                reference_ns: reference.mean.as_nanos() as f64,
                speedup_vs_fast,
                symbolic_fired: m_sym.path.symbolic,
                peak_union_width: m_sym.path.peak_union_width,
                refusal_memo_hits: memo_hits,
            }
            .to_json(),
        );
        rows.push(symbolic);
        rows.push(fast);
        rows.push(reference);
    }
    assert!(any_symbolic, "symbolic walk fired on no benchmark configuration");
    assert!(any_multibox, "multibox walk fired on no benchmark configuration");

    // Refusal + memoization row: two chained batched convs under a B,P,Q
    // partition with retention 0 need a width-3 availability union at the
    // batch-wrap leaf, so the width-2 calculus refuses once, memoizes the
    // mapping signature, and every later evaluation of the same mapping
    // skips the symbolic attempt outright (the timing advantage the
    // `memoized` series measures vs the first refused-then-bailed run).
    {
        let fs = FusionSetBuilder::new("conv_conv_batched(3,8)", &[3, 2, 8, 8])
            .conv2d_batched(2, 3, 3, 1)
            .conv2d_batched(2, 3, 3, 1)
            .build();
        let ev = Evaluator::new(&fs, &arch).unwrap();
        let label = "conv_conv_batched(3,8) batch+row+col-tiled (refuses)";
        let mapping = InterLayerMapping::tiled(
            ["B2", "P2", "Q2"]
                .iter()
                .map(|n| Partition { dim: fs.last().rank_index(n).unwrap(), tile: 1 })
                .collect(),
            Parallelism::Sequential,
        )
        .with_uniform_retention(0);
        let m_first = ev.evaluate(&mapping).unwrap();
        assert!(m_first.path.sym_refused, "expected a union-width refusal on {label}");
        let m_memo = ev.evaluate(&mapping).unwrap();
        assert!(!m_memo.path.symbolic && !m_memo.path.sym_refused);
        let memo_hits = ev.refusal_memo_hits();
        assert_eq!(memo_hits, 1, "refusal memo did not absorb the repeat attempt");
        let m_ref = ev.evaluate_reference(&mapping).unwrap();
        assert_eq!(m_first.latency_cycles, m_ref.latency_cycles, "refused walk drifted");
        assert_eq!(m_first.iterations, m_ref.iterations, "refused walk drifted");

        let (w, n) = reps(2, 12);
        let memoized = bench(&format!("memoized  {label}"), w, n, || {
            ev.evaluate(&mapping).unwrap()
        });
        let fast = bench(&format!("fast      {label}"), w, n, || {
            ev.evaluate_no_symbolic(&mapping).unwrap()
        });
        let (w, n) = reps(1, 4);
        let reference = bench(&format!("reference {label}"), w, n, || {
            ev.evaluate_reference(&mapping).unwrap()
        });
        println!("{}", memoized.report());
        println!("{}", fast.report());
        println!("{}", reference.report());
        let speedup_vs_fast =
            fast.mean.as_secs_f64() / memoized.mean.as_secs_f64().max(1e-12);
        println!(
            "    {} iterations walked; memoized-vs-fast {speedup_vs_fast:.2}x \
             ({memo_hits} memo hit before benching)",
            m_ref.iterations
        );
        symbolic_speedups.push(
            SymRow {
                label: label.to_string(),
                iterations: m_ref.iterations,
                symbolic_ns: memoized.mean.as_nanos() as f64,
                fast_ns: fast.mean.as_nanos() as f64,
                reference_ns: reference.mean.as_nanos() as f64,
                speedup_vs_fast,
                symbolic_fired: false,
                peak_union_width: 0,
                refusal_memo_hits: memo_hits,
            }
            .to_json(),
        );
        rows.push(memoized);
        rows.push(fast);
        rows.push(reference);
    }

    println!("\n== validate-once session vs per-call validation ==");
    for (r, ch, tile) in [(14, 8, 4), (28, 32, 4), (56, 64, 8)] {
        let fs = workloads::conv_conv(r, ch);
        let ev = Evaluator::new(&fs, &arch).unwrap();
        let p2 = fs.last().rank_index("P2").unwrap();
        let mapping = InterLayerMapping::tiled(
            vec![Partition { dim: p2, tile }],
            Parallelism::Sequential,
        );
        let (w, n) = reps(3, 30);
        let legacy = bench(&format!("free evaluate  r{r} c{ch} tile{tile}"), w, n, || {
            evaluate(&fs, &arch, &mapping, &opts).unwrap()
        });
        let session = bench(&format!("session        r{r} c{ch} tile{tile}"), w, n, || {
            ev.evaluate(&mapping).unwrap()
        });
        println!("{}", legacy.report());
        println!("{}", session.report());
        println!(
            "    session speedup: {:.2}x",
            legacy.mean.as_secs_f64() / session.mean.as_secs_f64().max(1e-12)
        );
        rows.push(legacy);
        rows.push(session);
    }

    println!("\n== model evaluation throughput (session, fast path) ==");
    for (r, ch, tile) in [(14, 8, 4), (28, 32, 4), (56, 64, 8), (112, 64, 14)] {
        let fs = workloads::conv_conv(r, ch);
        let ev = Evaluator::new(&fs, &arch).unwrap();
        let p2 = fs.last().rank_index("P2").unwrap();
        let mapping = InterLayerMapping::tiled(
            vec![Partition { dim: p2, tile }],
            Parallelism::Sequential,
        );
        let (w, n) = reps(3, 20);
        let b = bench(&format!("model conv_conv r{r} c{ch} tile{tile}"), w, n, || {
            ev.evaluate(&mapping).unwrap()
        });
        println!("{}", b.report());
        println!("    = {:.0} mapping evaluations/sec", b.iters_per_sec());
        rows.push(b);
    }

    println!("\n== model vs element-level simulator (same config) ==");
    {
        let fs = workloads::conv_conv(20, 8);
        let ev = Evaluator::new(&fs, &arch).unwrap();
        let p2 = fs.last().rank_index("P2").unwrap();
        let mapping = InterLayerMapping::tiled(
            vec![Partition { dim: p2, tile: 4 }],
            Parallelism::Sequential,
        );
        let (w, n) = reps(3, 20);
        let m = bench("analytical model", w, n, || ev.evaluate(&mapping).unwrap());
        let (w, n) = reps(1, 3);
        let s = bench("simulator", w, n, || simulate(&fs, &arch, &mapping).unwrap());
        println!("{}", m.report());
        println!("{}", s.report());
        println!(
            "speedup: {:.0}x (paper cites analytical models up to 1000x faster [36])",
            s.mean.as_secs_f64() / m.mean.as_secs_f64()
        );
        rows.push(m);
        rows.push(s);
    }

    let report = Json::Obj(
        [
            (
                "rows".to_string(),
                Json::Arr(rows.iter().map(BenchResult::to_json).collect()),
            ),
            ("fastpath_speedups".to_string(), Json::Arr(speedups)),
            ("symbolic_speedups".to_string(), Json::Arr(symbolic_speedups)),
        ]
        .into_iter()
        .collect(),
    );
    check_model_eval_bench_schema(&report).expect("BENCH_model_eval.json schema drifted");
    match write_bench_json("BENCH_model_eval.json", &report) {
        Ok(()) => println!("\nwrote BENCH_model_eval.json"),
        Err(e) => eprintln!("\nfailed to write BENCH_model_eval.json: {e}"),
    }
}
