//! Model-evaluation throughput: the paper's §IV claim that the analytical
//! model is orders of magnitude faster than simulation. Times model and
//! simulator on identical configurations and reports the ratio, plus raw
//! mapping-evaluations/second across workload sizes.

use looptree::arch::Arch;
use looptree::einsum::workloads;
use looptree::mapping::{InterLayerMapping, Parallelism, Partition};
use looptree::model::{evaluate, EvalOptions};
use looptree::sim::simulate;
use looptree::util::bench::bench;

fn main() {
    let arch = Arch::generic(1 << 20);
    let opts = EvalOptions::default();
    println!("== model evaluation throughput ==");
    for (rows, ch, tile) in [(14, 8, 4), (28, 32, 4), (56, 64, 8), (112, 64, 14)] {
        let fs = workloads::conv_conv(rows, ch);
        let p2 = fs.last().rank_index("P2").unwrap();
        let mapping = InterLayerMapping::tiled(
            vec![Partition { dim: p2, tile }],
            Parallelism::Sequential,
        );
        let r = bench(
            &format!("model conv_conv r{rows} c{ch} tile{tile}"),
            3,
            20,
            || evaluate(&fs, &arch, &mapping, &opts).unwrap(),
        );
        println!("{}", r.report());
        println!(
            "    = {:.0} mapping evaluations/sec",
            1.0 / r.mean.as_secs_f64()
        );
    }

    println!("\n== two-level (P2,Q2) heavy walk ==");
    {
        let fs = workloads::conv_conv(56, 64);
        let p2 = fs.last().rank_index("P2").unwrap();
        let q2 = fs.last().rank_index("Q2").unwrap();
        let mapping = InterLayerMapping::tiled(
            vec![
                Partition { dim: p2, tile: 4 },
                Partition { dim: q2, tile: 7 },
            ],
            Parallelism::Sequential,
        );
        let r = bench("model conv_conv r56 c64 P2,Q2 (104 iters)", 2, 10, || {
            evaluate(&fs, &arch, &mapping, &opts).unwrap()
        });
        println!("{}", r.report());
    }

    println!("\n== model vs element-level simulator (same config) ==");
    let fs = workloads::conv_conv(20, 8);
    let p2 = fs.last().rank_index("P2").unwrap();
    let mapping = InterLayerMapping::tiled(
        vec![Partition { dim: p2, tile: 4 }],
        Parallelism::Sequential,
    );
    let m = bench("analytical model", 3, 20, || {
        evaluate(&fs, &arch, &mapping, &opts).unwrap()
    });
    let s = bench("simulator", 1, 3, || simulate(&fs, &arch, &mapping).unwrap());
    println!("{}", m.report());
    println!("{}", s.report());
    println!(
        "speedup: {:.0}x (paper cites analytical models up to 1000x faster [36])",
        s.mean.as_secs_f64() / m.mean.as_secs_f64()
    );
}

#[allow(dead_code)]
fn two_level() {}
