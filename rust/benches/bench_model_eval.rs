//! Model-evaluation throughput: the paper's §IV claim that the analytical
//! model is orders of magnitude faster than simulation, the validate-once
//! `Evaluator` session vs. the legacy free `evaluate()`, and — the headline
//! of this bench since the symbolic tier landed — the full three-tier
//! comparison: closed-form symbolic box walk vs. steady-state fast path vs.
//! exhaustive reference walk on long row-tiled walks. The bench asserts all
//! three tiers agree bit-for-bit and pins which configurations the symbolic
//! walk must cover (`Metrics::path.symbolic`).
//!
//! Emits `BENCH_model_eval.json` (workload, mean ns, iterations/s, the
//! fast-vs-reference speedups, and the symbolic-vs-fast speedups) so the
//! perf trajectory is tracked run over run; `LOOPTREE_BENCH_SMOKE=1` clamps
//! repetitions for CI.

use looptree::arch::Arch;
use looptree::einsum::workloads;
use looptree::mapping::{InterLayerMapping, Parallelism, Partition};
use looptree::model::{evaluate, EvalOptions, Evaluator};
use looptree::sim::simulate;
use looptree::util::bench::{
    bench, check_model_eval_bench_schema, reps, write_bench_json, BenchResult,
};
use looptree::util::json::Json;

fn main() {
    let arch = Arch::generic(1 << 20);
    let opts = EvalOptions::default();
    let mut rows: Vec<BenchResult> = Vec::new();
    let mut speedups: Vec<Json> = Vec::new();
    let mut symbolic_speedups: Vec<Json> = Vec::new();

    println!("== symbolic vs fast path vs reference walk ==");
    // (rows, ch, partition spec): the 112×112 row-tiled configurations are
    // the acceptance gate — the reference walk is O(total tiles), the
    // steady-state fast path O(distinct tile classes), and the symbolic box
    // walk O(schedule levels). `expect_symbolic` pins which configurations
    // the closed-form path must cover: row-only (nested or not) tilings stay
    // in single-box form; the row+col tiling wraps the availability set into
    // an L-shape at each column boundary, so it must fall back.
    struct FastRow {
        label: &'static str,
        rows: i64,
        ch: i64,
        tiles: &'static [(&'static str, i64)],
        expect_symbolic: bool,
    }
    let configs = [
        FastRow {
            label: "conv_conv(112,64) row-tiled",
            rows: 112,
            ch: 64,
            tiles: &[("P2", 1)],
            expect_symbolic: true,
        },
        FastRow {
            label: "conv_conv(112,64) row+col-tiled",
            rows: 112,
            ch: 64,
            tiles: &[("P2", 1), ("Q2", 1)],
            expect_symbolic: false,
        },
        FastRow {
            label: "conv_conv(112,64) nested row-tiled",
            rows: 112,
            ch: 64,
            tiles: &[("P2", 8), ("P2", 1)],
            expect_symbolic: true,
        },
        FastRow {
            label: "conv_conv(56,64) row-tiled",
            rows: 56,
            ch: 64,
            tiles: &[("P2", 2)],
            expect_symbolic: true,
        },
    ];
    let mut any_symbolic = false;
    for cfg in &configs {
        let fs = workloads::conv_conv(cfg.rows, cfg.ch);
        let ev = Evaluator::new(&fs, &arch).unwrap();
        let partitions: Vec<Partition> = cfg
            .tiles
            .iter()
            .map(|&(name, tile)| Partition {
                dim: fs.last().rank_index(name).unwrap(),
                tile,
            })
            .collect();
        let mapping = InterLayerMapping::tiled(partitions, Parallelism::Sequential);
        let m_sym = ev.evaluate(&mapping).unwrap();
        let m_fast = ev.evaluate_no_symbolic(&mapping).unwrap();
        let m_ref = ev.evaluate_reference(&mapping).unwrap();
        assert_eq!(m_sym.latency_cycles, m_ref.latency_cycles, "symbolic path drifted");
        assert_eq!(m_sym.iterations, m_ref.iterations, "symbolic path drifted");
        assert_eq!(m_fast.latency_cycles, m_ref.latency_cycles, "fast path drifted");
        assert_eq!(m_fast.iterations, m_ref.iterations, "fast path drifted");
        if cfg.expect_symbolic {
            assert!(
                m_sym.path.symbolic,
                "symbolic walk unexpectedly fell back on {}",
                cfg.label
            );
        }
        any_symbolic |= m_sym.path.symbolic;

        let (w, n) = reps(2, 12);
        let symbolic = bench(&format!("symbolic  {}", cfg.label), w, n, || {
            ev.evaluate(&mapping).unwrap()
        });
        let fast = bench(&format!("fast      {}", cfg.label), w, n, || {
            ev.evaluate_no_symbolic(&mapping).unwrap()
        });
        let (w, n) = reps(1, 4);
        let reference = bench(&format!("reference {}", cfg.label), w, n, || {
            ev.evaluate_reference(&mapping).unwrap()
        });
        println!("{}", symbolic.report());
        println!("{}", fast.report());
        println!("{}", reference.report());
        let speedup = reference.mean.as_secs_f64() / fast.mean.as_secs_f64().max(1e-12);
        let speedup_vs_fast = fast.mean.as_secs_f64() / symbolic.mean.as_secs_f64().max(1e-12);
        println!(
            "    {} iterations walked; fast-vs-reference {speedup:.1}x; \
             symbolic-vs-fast {speedup_vs_fast:.2}x (fired: {})",
            m_ref.iterations, m_sym.path.symbolic
        );
        speedups.push(Json::Obj(
            [
                ("workload".to_string(), Json::Str(cfg.label.to_string())),
                ("iterations".to_string(), Json::Num(m_ref.iterations as f64)),
                (
                    "fast_mean_ns".to_string(),
                    Json::Num(fast.mean.as_nanos() as f64),
                ),
                (
                    "reference_mean_ns".to_string(),
                    Json::Num(reference.mean.as_nanos() as f64),
                ),
                ("speedup".to_string(), Json::Num(speedup)),
            ]
            .into_iter()
            .collect(),
        ));
        symbolic_speedups.push(Json::Obj(
            [
                ("workload".to_string(), Json::Str(cfg.label.to_string())),
                ("iterations".to_string(), Json::Num(m_ref.iterations as f64)),
                (
                    "symbolic_mean_ns".to_string(),
                    Json::Num(symbolic.mean.as_nanos() as f64),
                ),
                (
                    "fast_mean_ns".to_string(),
                    Json::Num(fast.mean.as_nanos() as f64),
                ),
                (
                    "reference_mean_ns".to_string(),
                    Json::Num(reference.mean.as_nanos() as f64),
                ),
                ("speedup_vs_fast".to_string(), Json::Num(speedup_vs_fast)),
                ("symbolic_fired".to_string(), Json::Bool(m_sym.path.symbolic)),
            ]
            .into_iter()
            .collect(),
        ));
        rows.push(symbolic);
        rows.push(fast);
        rows.push(reference);
    }
    assert!(any_symbolic, "symbolic walk fired on no benchmark configuration");

    println!("\n== validate-once session vs per-call validation ==");
    for (r, ch, tile) in [(14, 8, 4), (28, 32, 4), (56, 64, 8)] {
        let fs = workloads::conv_conv(r, ch);
        let ev = Evaluator::new(&fs, &arch).unwrap();
        let p2 = fs.last().rank_index("P2").unwrap();
        let mapping = InterLayerMapping::tiled(
            vec![Partition { dim: p2, tile }],
            Parallelism::Sequential,
        );
        let (w, n) = reps(3, 30);
        let legacy = bench(&format!("free evaluate  r{r} c{ch} tile{tile}"), w, n, || {
            evaluate(&fs, &arch, &mapping, &opts).unwrap()
        });
        let session = bench(&format!("session        r{r} c{ch} tile{tile}"), w, n, || {
            ev.evaluate(&mapping).unwrap()
        });
        println!("{}", legacy.report());
        println!("{}", session.report());
        println!(
            "    session speedup: {:.2}x",
            legacy.mean.as_secs_f64() / session.mean.as_secs_f64().max(1e-12)
        );
        rows.push(legacy);
        rows.push(session);
    }

    println!("\n== model evaluation throughput (session, fast path) ==");
    for (r, ch, tile) in [(14, 8, 4), (28, 32, 4), (56, 64, 8), (112, 64, 14)] {
        let fs = workloads::conv_conv(r, ch);
        let ev = Evaluator::new(&fs, &arch).unwrap();
        let p2 = fs.last().rank_index("P2").unwrap();
        let mapping = InterLayerMapping::tiled(
            vec![Partition { dim: p2, tile }],
            Parallelism::Sequential,
        );
        let (w, n) = reps(3, 20);
        let b = bench(&format!("model conv_conv r{r} c{ch} tile{tile}"), w, n, || {
            ev.evaluate(&mapping).unwrap()
        });
        println!("{}", b.report());
        println!("    = {:.0} mapping evaluations/sec", b.iters_per_sec());
        rows.push(b);
    }

    println!("\n== model vs element-level simulator (same config) ==");
    {
        let fs = workloads::conv_conv(20, 8);
        let ev = Evaluator::new(&fs, &arch).unwrap();
        let p2 = fs.last().rank_index("P2").unwrap();
        let mapping = InterLayerMapping::tiled(
            vec![Partition { dim: p2, tile: 4 }],
            Parallelism::Sequential,
        );
        let (w, n) = reps(3, 20);
        let m = bench("analytical model", w, n, || ev.evaluate(&mapping).unwrap());
        let (w, n) = reps(1, 3);
        let s = bench("simulator", w, n, || simulate(&fs, &arch, &mapping).unwrap());
        println!("{}", m.report());
        println!("{}", s.report());
        println!(
            "speedup: {:.0}x (paper cites analytical models up to 1000x faster [36])",
            s.mean.as_secs_f64() / m.mean.as_secs_f64()
        );
        rows.push(m);
        rows.push(s);
    }

    let report = Json::Obj(
        [
            (
                "rows".to_string(),
                Json::Arr(rows.iter().map(BenchResult::to_json).collect()),
            ),
            ("fastpath_speedups".to_string(), Json::Arr(speedups)),
            ("symbolic_speedups".to_string(), Json::Arr(symbolic_speedups)),
        ]
        .into_iter()
        .collect(),
    );
    check_model_eval_bench_schema(&report).expect("BENCH_model_eval.json schema drifted");
    match write_bench_json("BENCH_model_eval.json", &report) {
        Ok(()) => println!("\nwrote BENCH_model_eval.json"),
        Err(e) => eprintln!("\nfailed to write BENCH_model_eval.json: {e}"),
    }
}
