//! Model-evaluation throughput: the paper's §IV claim that the analytical
//! model is orders of magnitude faster than simulation, plus the
//! validate-once `Evaluator` session vs. the legacy free `evaluate()` —
//! the session skips per-call spec validation and intra-layer default
//! derivation, which dominates small walks.

use looptree::arch::Arch;
use looptree::einsum::workloads;
use looptree::mapping::{InterLayerMapping, Parallelism, Partition};
use looptree::model::{evaluate, EvalOptions, Evaluator};
use looptree::sim::simulate;
use looptree::util::bench::bench;

fn main() {
    let arch = Arch::generic(1 << 20);
    let opts = EvalOptions::default();

    println!("== validate-once session vs per-call validation ==");
    for (rows, ch, tile) in [(14, 8, 4), (28, 32, 4), (56, 64, 8)] {
        let fs = workloads::conv_conv(rows, ch);
        let ev = Evaluator::new(&fs, &arch).unwrap();
        let p2 = fs.last().rank_index("P2").unwrap();
        let mapping = InterLayerMapping::tiled(
            vec![Partition { dim: p2, tile }],
            Parallelism::Sequential,
        );
        let legacy = bench(
            &format!("free evaluate  r{rows} c{ch} tile{tile}"),
            3,
            30,
            || evaluate(&fs, &arch, &mapping, &opts).unwrap(),
        );
        let session = bench(
            &format!("session        r{rows} c{ch} tile{tile}"),
            3,
            30,
            || ev.evaluate(&mapping).unwrap(),
        );
        println!("{}", legacy.report());
        println!("{}", session.report());
        println!(
            "    session speedup: {:.2}x",
            legacy.mean.as_secs_f64() / session.mean.as_secs_f64().max(1e-12)
        );
    }

    println!("\n== model evaluation throughput (session) ==");
    for (rows, ch, tile) in [(14, 8, 4), (28, 32, 4), (56, 64, 8), (112, 64, 14)] {
        let fs = workloads::conv_conv(rows, ch);
        let ev = Evaluator::new(&fs, &arch).unwrap();
        let p2 = fs.last().rank_index("P2").unwrap();
        let mapping = InterLayerMapping::tiled(
            vec![Partition { dim: p2, tile }],
            Parallelism::Sequential,
        );
        let r = bench(
            &format!("model conv_conv r{rows} c{ch} tile{tile}"),
            3,
            20,
            || ev.evaluate(&mapping).unwrap(),
        );
        println!("{}", r.report());
        println!(
            "    = {:.0} mapping evaluations/sec",
            1.0 / r.mean.as_secs_f64()
        );
    }

    println!("\n== two-level (P2,Q2) heavy walk ==");
    {
        let fs = workloads::conv_conv(56, 64);
        let ev = Evaluator::new(&fs, &arch).unwrap();
        let p2 = fs.last().rank_index("P2").unwrap();
        let q2 = fs.last().rank_index("Q2").unwrap();
        let mapping = InterLayerMapping::tiled(
            vec![
                Partition { dim: p2, tile: 4 },
                Partition { dim: q2, tile: 7 },
            ],
            Parallelism::Sequential,
        );
        let r = bench("model conv_conv r56 c64 P2,Q2 (104 iters)", 2, 10, || {
            ev.evaluate(&mapping).unwrap()
        });
        println!("{}", r.report());
    }

    println!("\n== model vs element-level simulator (same config) ==");
    let fs = workloads::conv_conv(20, 8);
    let ev = Evaluator::new(&fs, &arch).unwrap();
    let p2 = fs.last().rank_index("P2").unwrap();
    let mapping = InterLayerMapping::tiled(
        vec![Partition { dim: p2, tile: 4 }],
        Parallelism::Sequential,
    );
    let m = bench("analytical model", 3, 20, || ev.evaluate(&mapping).unwrap());
    let s = bench("simulator", 1, 3, || simulate(&fs, &arch, &mapping).unwrap());
    println!("{}", m.report());
    println!("{}", s.report());
    println!(
        "speedup: {:.0}x (paper cites analytical models up to 1000x faster [36])",
        s.mean.as_secs_f64() / m.mean.as_secs_f64()
    );
}
