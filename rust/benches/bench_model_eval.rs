//! Model-evaluation throughput: the paper's §IV claim that the analytical
//! model is orders of magnitude faster than simulation, the validate-once
//! `Evaluator` session vs. the legacy free `evaluate()`, and — the headline
//! of this bench since the steady-state fast path landed — fast path vs.
//! exhaustive reference walk on long row-tiled walks, where evaluation cost
//! no longer scales with the fmap extent.
//!
//! Emits `BENCH_model_eval.json` (workload, mean ns, iterations/s, and the
//! fast-vs-reference speedups) so the perf trajectory is tracked run over
//! run; `LOOPTREE_BENCH_SMOKE=1` clamps repetitions for CI.

use looptree::arch::Arch;
use looptree::einsum::workloads;
use looptree::mapping::{InterLayerMapping, Parallelism, Partition};
use looptree::model::{evaluate, EvalOptions, Evaluator};
use looptree::sim::simulate;
use looptree::util::bench::{
    bench, check_model_eval_bench_schema, reps, write_bench_json, BenchResult,
};
use looptree::util::json::Json;

fn main() {
    let arch = Arch::generic(1 << 20);
    let opts = EvalOptions::default();
    let mut rows: Vec<BenchResult> = Vec::new();
    let mut speedups: Vec<Json> = Vec::new();

    println!("== fast path vs reference walk (steady-state classification) ==");
    // (rows, ch, partition spec): the 112×112 row-tiled configurations are
    // the acceptance gate — the reference walk is O(total tiles), the fast
    // path O(distinct tile classes).
    struct FastRow {
        label: &'static str,
        rows: i64,
        ch: i64,
        tiles: &'static [(&'static str, i64)],
    }
    let configs = [
        FastRow { label: "conv_conv(112,64) row-tiled", rows: 112, ch: 64, tiles: &[("P2", 1)] },
        FastRow {
            label: "conv_conv(112,64) row+col-tiled",
            rows: 112,
            ch: 64,
            tiles: &[("P2", 1), ("Q2", 1)],
        },
        FastRow { label: "conv_conv(56,64) row-tiled", rows: 56, ch: 64, tiles: &[("P2", 2)] },
    ];
    for cfg in &configs {
        let fs = workloads::conv_conv(cfg.rows, cfg.ch);
        let ev = Evaluator::new(&fs, &arch).unwrap();
        let partitions: Vec<Partition> = cfg
            .tiles
            .iter()
            .map(|&(name, tile)| Partition {
                dim: fs.last().rank_index(name).unwrap(),
                tile,
            })
            .collect();
        let mapping = InterLayerMapping::tiled(partitions, Parallelism::Sequential);
        let m_fast = ev.evaluate(&mapping).unwrap();
        let m_ref = ev.evaluate_reference(&mapping).unwrap();
        assert_eq!(m_fast.latency_cycles, m_ref.latency_cycles, "fast path drifted");
        assert_eq!(m_fast.iterations, m_ref.iterations, "fast path drifted");

        let (w, n) = reps(2, 12);
        let fast = bench(&format!("fast      {}", cfg.label), w, n, || {
            ev.evaluate(&mapping).unwrap()
        });
        let (w, n) = reps(1, 4);
        let reference = bench(&format!("reference {}", cfg.label), w, n, || {
            ev.evaluate_reference(&mapping).unwrap()
        });
        println!("{}", fast.report());
        println!("{}", reference.report());
        let speedup = reference.mean.as_secs_f64() / fast.mean.as_secs_f64().max(1e-12);
        println!(
            "    {} iterations walked; fast-path speedup: {speedup:.1}x",
            m_ref.iterations
        );
        speedups.push(Json::Obj(
            [
                ("workload".to_string(), Json::Str(cfg.label.to_string())),
                ("iterations".to_string(), Json::Num(m_ref.iterations as f64)),
                (
                    "fast_mean_ns".to_string(),
                    Json::Num(fast.mean.as_nanos() as f64),
                ),
                (
                    "reference_mean_ns".to_string(),
                    Json::Num(reference.mean.as_nanos() as f64),
                ),
                ("speedup".to_string(), Json::Num(speedup)),
            ]
            .into_iter()
            .collect(),
        ));
        rows.push(fast);
        rows.push(reference);
    }

    println!("\n== validate-once session vs per-call validation ==");
    for (r, ch, tile) in [(14, 8, 4), (28, 32, 4), (56, 64, 8)] {
        let fs = workloads::conv_conv(r, ch);
        let ev = Evaluator::new(&fs, &arch).unwrap();
        let p2 = fs.last().rank_index("P2").unwrap();
        let mapping = InterLayerMapping::tiled(
            vec![Partition { dim: p2, tile }],
            Parallelism::Sequential,
        );
        let (w, n) = reps(3, 30);
        let legacy = bench(&format!("free evaluate  r{r} c{ch} tile{tile}"), w, n, || {
            evaluate(&fs, &arch, &mapping, &opts).unwrap()
        });
        let session = bench(&format!("session        r{r} c{ch} tile{tile}"), w, n, || {
            ev.evaluate(&mapping).unwrap()
        });
        println!("{}", legacy.report());
        println!("{}", session.report());
        println!(
            "    session speedup: {:.2}x",
            legacy.mean.as_secs_f64() / session.mean.as_secs_f64().max(1e-12)
        );
        rows.push(legacy);
        rows.push(session);
    }

    println!("\n== model evaluation throughput (session, fast path) ==");
    for (r, ch, tile) in [(14, 8, 4), (28, 32, 4), (56, 64, 8), (112, 64, 14)] {
        let fs = workloads::conv_conv(r, ch);
        let ev = Evaluator::new(&fs, &arch).unwrap();
        let p2 = fs.last().rank_index("P2").unwrap();
        let mapping = InterLayerMapping::tiled(
            vec![Partition { dim: p2, tile }],
            Parallelism::Sequential,
        );
        let (w, n) = reps(3, 20);
        let b = bench(&format!("model conv_conv r{r} c{ch} tile{tile}"), w, n, || {
            ev.evaluate(&mapping).unwrap()
        });
        println!("{}", b.report());
        println!("    = {:.0} mapping evaluations/sec", b.iters_per_sec());
        rows.push(b);
    }

    println!("\n== model vs element-level simulator (same config) ==");
    {
        let fs = workloads::conv_conv(20, 8);
        let ev = Evaluator::new(&fs, &arch).unwrap();
        let p2 = fs.last().rank_index("P2").unwrap();
        let mapping = InterLayerMapping::tiled(
            vec![Partition { dim: p2, tile: 4 }],
            Parallelism::Sequential,
        );
        let (w, n) = reps(3, 20);
        let m = bench("analytical model", w, n, || ev.evaluate(&mapping).unwrap());
        let (w, n) = reps(1, 3);
        let s = bench("simulator", w, n, || simulate(&fs, &arch, &mapping).unwrap());
        println!("{}", m.report());
        println!("{}", s.report());
        println!(
            "speedup: {:.0}x (paper cites analytical models up to 1000x faster [36])",
            s.mean.as_secs_f64() / m.mean.as_secs_f64()
        );
        rows.push(m);
        rows.push(s);
    }

    let report = Json::Obj(
        [
            (
                "rows".to_string(),
                Json::Arr(rows.iter().map(BenchResult::to_json).collect()),
            ),
            ("fastpath_speedups".to_string(), Json::Arr(speedups)),
        ]
        .into_iter()
        .collect(),
    );
    check_model_eval_bench_schema(&report).expect("BENCH_model_eval.json schema drifted");
    match write_bench_json("BENCH_model_eval.json", &report) {
        Ok(()) => println!("\nwrote BENCH_model_eval.json"),
        Err(e) => eprintln!("\nfailed to write BENCH_model_eval.json: {e}"),
    }
}
