//! Search-algorithm benchmark: exhaustive vs random vs annealing vs genetic
//! through the unified `search::run` entry point on one shared `Evaluator`
//! session (paper §VII-C: prior search strategies adapt to the LoopTree
//! mapspace).

use looptree::arch::Arch;
use looptree::coordinator::Coordinator;
use looptree::einsum::workloads;
use looptree::mapspace::MapSpaceConfig;
use looptree::model::Evaluator;
use looptree::search::{self, Algorithm, Objective, SearchSpec};
use looptree::util::bench::bench_once;

fn main() {
    let fs = workloads::conv_conv(28, 64);
    let arch = Arch::generic(128);
    let ev = Evaluator::new(&fs, &arch).unwrap();
    let pool = Coordinator::new(0);

    let base = SearchSpec {
        objective: Objective::FeasibleEdp,
        seed: 7,
        samples: 500,
        iters: 500,
        population: 20,
        generations: 25,
        mapspace: MapSpaceConfig {
            schedules: vec![
                vec!["P2".into()],
                vec!["P2".into(), "Q2".into()],
                vec!["C2".into()],
                vec!["C2".into(), "P2".into()],
            ],
            tile_sizes: vec![2, 4, 8],
            ..Default::default()
        },
        ..Default::default()
    };

    let (ex, t) = bench_once("exhaustive", || {
        let spec = SearchSpec { algorithm: Algorithm::Exhaustive, ..base.clone() };
        search::run(&ev, &spec, &pool).unwrap()
    });
    println!(
        "{}  -> best {:.3e} over {} mappings",
        t.report(),
        ex.best.score,
        ex.evaluated.len()
    );

    let (rnd, t) = bench_once("random (500 samples)", || {
        let spec = SearchSpec { algorithm: Algorithm::Random, ..base.clone() };
        search::run(&ev, &spec, &pool).unwrap()
    });
    println!("{}  -> best {:.3e}", t.report(), rnd.best.score);

    let (ann, t) = bench_once("annealing (500 iters)", || {
        let spec = SearchSpec { algorithm: Algorithm::Annealing, ..base.clone() };
        search::run(&ev, &spec, &pool).unwrap()
    });
    println!("{}  -> best {:.3e}", t.report(), ann.best.score);

    let (gen_, t) = bench_once("genetic (20x25)", || {
        let spec = SearchSpec { algorithm: Algorithm::Genetic, ..base.clone() };
        search::run(&ev, &spec, &pool).unwrap()
    });
    println!("{}  -> best {:.3e}", t.report(), gen_.best.score);

    println!(
        "\nquality vs exhaustive optimum: random {:.2}x, annealing {:.2}x, genetic {:.2}x",
        rnd.best.score / ex.best.score,
        ann.best.score / ex.best.score,
        gen_.best.score / ex.best.score
    );
}
