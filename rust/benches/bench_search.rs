//! Search-algorithm benchmark: exhaustive vs random vs annealing vs genetic
//! on the same objective and budget (paper §VII-C: prior search strategies
//! adapt to the LoopTree mapspace).

use looptree::arch::Arch;
use looptree::coordinator::Coordinator;
use looptree::einsum::workloads;
use looptree::mapspace::MapSpaceConfig;
use looptree::model::Metrics;
use looptree::search;
use looptree::util::bench::bench_once;

fn main() {
    let fs = workloads::conv_conv(28, 64);
    let arch = Arch::generic(128);
    let pool = Coordinator::new(0);
    let objective = |m: &Metrics| -> f64 {
        let p = if m.capacity_ok { 1.0 } else { 1e9 };
        p * m.latency_cycles as f64 * m.energy.total_pj()
    };

    let cfg = MapSpaceConfig {
        schedules: vec![
            vec!["P2".into()],
            vec!["P2".into(), "Q2".into()],
            vec!["C2".into()],
            vec!["C2".into(), "P2".into()],
        ],
        tile_sizes: vec![2, 4, 8],
        ..Default::default()
    };
    let (ex, t) = bench_once("exhaustive", || {
        search::exhaustive(&fs, &arch, &cfg, objective, &pool).unwrap()
    });
    println!("{}  -> best {:.3e} over {} mappings", t.report(), ex.best.score, ex.evaluated.len());

    let (rnd, t) = bench_once("random (500 samples)", || {
        search::random_search(&fs, &arch, 500, 7, objective, &pool).unwrap()
    });
    println!("{}  -> best {:.3e}", t.report(), rnd.best.score);

    let (ann, t) = bench_once("annealing (500 iters)", || {
        search::annealing(&fs, &arch, 500, 7, objective).unwrap()
    });
    println!("{}  -> best {:.3e}", t.report(), ann.best.score);

    let (gen_, t) = bench_once("genetic (20x25)", || {
        search::genetic(&fs, &arch, 20, 25, 7, objective, &pool).unwrap()
    });
    println!("{}  -> best {:.3e}", t.report(), gen_.best.score);

    println!(
        "\nquality vs exhaustive optimum: random {:.2}x, annealing {:.2}x, genetic {:.2}x",
        rnd.best.score / ex.best.score,
        ann.best.score / ex.best.score,
        gen_.best.score / ex.best.score
    );
}
