//! Search-algorithm benchmark: exhaustive vs random vs annealing vs genetic
//! through the unified `search::run` entry point on one shared `Evaluator`
//! session (paper §VII-C: prior search strategies adapt to the LoopTree
//! mapspace). Search throughput rides on the evaluator's steady-state fast
//! path, so it no longer scales with the fmap extent.
//!
//! Emits `BENCH_search.json` (workload, mean ns, mappings/s, evaluated,
//! pruned, and symbolic-path counts per algorithm);
//! `LOOPTREE_BENCH_SMOKE=1` shrinks the search budgets for CI.

use looptree::arch::Arch;
use looptree::coordinator::Coordinator;
use looptree::einsum::workloads;
use looptree::mapspace::MapSpaceConfig;
use looptree::model::Evaluator;
use looptree::search::{self, Algorithm, Objective, SearchSpec};
use looptree::util::bench::{bench_once, check_search_bench_schema, smoke, write_bench_json};
use looptree::util::json::Json;

fn main() {
    let fs = workloads::conv_conv(28, 64);
    let arch = Arch::generic(128);
    let ev = Evaluator::new(&fs, &arch).unwrap();
    let pool = Coordinator::new(0);
    let budget = if smoke() { 40 } else { 500 };

    let base = SearchSpec {
        objective: Objective::FeasibleEdp,
        seed: 7,
        samples: budget,
        iters: budget,
        population: 20,
        generations: if smoke() { 2 } else { 25 },
        mapspace: MapSpaceConfig {
            schedules: vec![
                vec!["P2".into()],
                vec!["P2".into(), "Q2".into()],
                vec!["C2".into()],
                vec!["C2".into(), "P2".into()],
            ],
            tile_sizes: vec![2, 4, 8],
            ..Default::default()
        },
        ..Default::default()
    };

    let mut json_rows: Vec<Json> = Vec::new();
    let mut record = |name: &str,
                      mean_ns: f64,
                      evaluated: usize,
                      pruned: usize,
                      best: f64,
                      symbolic_evals: usize| {
        json_rows.push(Json::Obj(
            [
                ("workload".to_string(), Json::Str(name.to_string())),
                ("mean_ns".to_string(), Json::Num(mean_ns)),
                ("evaluated".to_string(), Json::Num(evaluated as f64)),
                ("pruned".to_string(), Json::Num(pruned as f64)),
                (
                    "mappings_per_sec".to_string(),
                    Json::Num(if mean_ns > 0.0 {
                        evaluated as f64 / (mean_ns / 1e9)
                    } else {
                        0.0
                    }),
                ),
                ("best_score".to_string(), Json::Num(best)),
                ("symbolic_evals".to_string(), Json::Num(symbolic_evals as f64)),
            ]
            .into_iter()
            .collect(),
        ));
    };

    let (ex, t) = bench_once("exhaustive", || {
        let spec = SearchSpec { algorithm: Algorithm::Exhaustive, ..base.clone() };
        search::run(&ev, &spec, &pool).unwrap()
    });
    println!(
        "{}  -> best {:.3e} over {} mappings",
        t.report(),
        ex.best.score,
        ex.evaluated.len()
    );
    record(
        "exhaustive",
        t.mean.as_nanos() as f64,
        ex.evaluated.len(),
        ex.pruned,
        ex.best.score,
        ex.symbolic_evals,
    );

    let (rnd, t) = bench_once("random", || {
        let spec = SearchSpec { algorithm: Algorithm::Random, ..base.clone() };
        search::run(&ev, &spec, &pool).unwrap()
    });
    println!("{}  -> best {:.3e}", t.report(), rnd.best.score);
    record(
        "random",
        t.mean.as_nanos() as f64,
        rnd.evaluated.len(),
        rnd.pruned,
        rnd.best.score,
        rnd.symbolic_evals,
    );

    let (ann, t) = bench_once("annealing", || {
        let spec = SearchSpec { algorithm: Algorithm::Annealing, ..base.clone() };
        search::run(&ev, &spec, &pool).unwrap()
    });
    println!("{}  -> best {:.3e}", t.report(), ann.best.score);
    record(
        "annealing",
        t.mean.as_nanos() as f64,
        ann.evaluated.len(),
        ann.pruned,
        ann.best.score,
        ann.symbolic_evals,
    );

    let (gen_, t) = bench_once("genetic", || {
        let spec = SearchSpec { algorithm: Algorithm::Genetic, ..base.clone() };
        search::run(&ev, &spec, &pool).unwrap()
    });
    println!("{}  -> best {:.3e}", t.report(), gen_.best.score);
    record(
        "genetic",
        t.mean.as_nanos() as f64,
        gen_.evaluated.len(),
        gen_.pruned,
        gen_.best.score,
        gen_.symbolic_evals,
    );

    println!(
        "\nquality vs exhaustive optimum: random {:.2}x, annealing {:.2}x, genetic {:.2}x",
        rnd.best.score / ex.best.score,
        ann.best.score / ex.best.score,
        gen_.best.score / ex.best.score
    );

    let report = Json::Obj(
        [("rows".to_string(), Json::Arr(json_rows))]
            .into_iter()
            .collect(),
    );
    check_search_bench_schema(&report).expect("BENCH_search.json schema drifted");
    match write_bench_json("BENCH_search.json", &report) {
        Ok(()) => println!("wrote BENCH_search.json"),
        Err(e) => eprintln!("failed to write BENCH_search.json: {e}"),
    }
}
