//! Regenerates paper Fig 14: buffer capacity required for algorithmic-
//! minimum off-chip transfers across partitioned-ranks/schedule choices.

use looptree::casestudies::fig14;
use looptree::util::bench::bench_once;

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let (bars, t) = bench_once("fig14 sweep", || fig14::run(!full));
    println!("{}", fig14::render(&bars));
    println!("{}", t.report());
}
