//! Intra-layer mapping (paper §III-E).
//!
//! LoopTree supports intra-layer choices so that per-tile hardware action
//! counts can be analyzed (paper §IV-B, Timeloop-style); they are not the
//! paper's focus, and neither are they ours. We model the two choices with
//! first-order impact on the action counts:
//!
//! * **spatial partitioning** — which of the layer's ranks are spread across
//!   the PE mesh (determines utilization and multicast fan-out);
//! * **innermost temporal reuse** — each tensor's operand is reused at the
//!   PE across iterations of ranks absent from its projection (register-level
//!   reuse), reducing GLB reads by that factor.

use crate::einsum::EinsumSpec;

/// Intra-layer mapping for one Einsum.
#[derive(Debug, Clone)]
pub struct IntraLayerMapping {
    /// `(local dim, spatial factor)`: the dim is split across PEs by the
    /// factor. Product of factors should not exceed the PE count.
    pub spatial: Vec<(usize, i64)>,
}

impl IntraLayerMapping {
    /// Heuristic default: spatialize the first two output-projected ranks
    /// (e.g. output channels × output rows) up to `pes` PEs.
    ///
    /// This mirrors the common output-stationary allocation that the
    /// validation targets use and gives full utilization whenever the tile
    /// extents divide the mesh.
    pub fn default_for(einsum: &EinsumSpec, pes: i64) -> Self {
        let out_dims = einsum.output.map.referenced_dims();
        let mut spatial = Vec::new();
        let mut budget = pes;
        for &d in out_dims.iter().take(2) {
            if budget <= 1 {
                break;
            }
            let f = einsum.rank_sizes[d].min(budget);
            if f > 1 {
                spatial.push((d, f));
                budget /= f;
            }
        }
        IntraLayerMapping { spatial }
    }

    /// Total spatial fan-out (PEs used when tile extents suffice).
    pub fn fanout(&self) -> i64 {
        self.spatial.iter().map(|&(_, f)| f).product()
    }

    /// Spatial factor assigned to `dim` (1 if not spatialized).
    pub fn factor_for(&self, dim: usize) -> i64 {
        self.spatial
            .iter()
            .find(|&&(d, _)| d == dim)
            .map(|&(_, f)| f)
            .unwrap_or(1)
    }

    /// Check the intra-layer mapping against an Einsum and a PE budget.
    pub fn validate(&self, einsum: &EinsumSpec, pes: i64) -> Result<(), String> {
        let mut seen = std::collections::HashSet::new();
        for &(d, f) in &self.spatial {
            if d >= einsum.ndim() {
                return Err(format!("spatial dim {d} out of range"));
            }
            if f < 1 {
                return Err(format!("spatial factor {f} < 1"));
            }
            if !seen.insert(d) {
                return Err(format!("dim {d} spatialized twice"));
            }
        }
        if self.fanout() > pes {
            return Err(format!("fanout {} exceeds {} PEs", self.fanout(), pes));
        }
        Ok(())
    }
}
