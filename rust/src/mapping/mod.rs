//! The LoopTree mapping taxonomy (paper §III, Table IV).
//!
//! A mapping fixes, for one fusion set on one architecture:
//!
//! * **Partitioned ranks** — a subset of the *last* layer's ranks, each with
//!   a **tile shape** (an integer tile size; the tile extends fully along
//!   unpartitioned ranks).
//! * **Tile processing schedule** — the order of the partitioned ranks
//!   (outer→inner), i.e. the loop-nest permutation the tiles are walked in.
//! * **Retain-recompute** (per intermediate fmap) and **retain-refetch**
//!   (per other tensor) — expressed uniformly (paper §III-D) as a *retention
//!   level* `j`: retain the tile formed by partitioning the first `j`
//!   schedule ranks (`j = 0` retains the whole tensor). Data not retained is
//!   recomputed (intermediates: no off-chip backing) or refetched (others).
//! * **Parallelism** — whether layer tiles are processed sequentially or in
//!   a pipeline (paper §III-C).
//!
//! Intra-layer mapping choices (paper §III-E) are carried by
//! [`IntraLayerMapping`] and consumed by `model::intra`.

mod inter;
mod intra;

pub use inter::{InterLayerMapping, Parallelism, Partition, RetLevel};
pub use intra::IntraLayerMapping;

#[cfg(test)]
mod tests;
