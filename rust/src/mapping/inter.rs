//! Inter-layer mapping: the fused-layer dataflow choices.

use crate::einsum::{FusionSet, TensorId};
use std::collections::HashMap;

/// One partitioned rank of the last layer with its tile size along that rank.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Partition {
    /// Local iteration-dim index in the *last* Einsum of the fusion set.
    pub dim: usize,
    /// Tile length along this rank (≥ 1). The last tile may be ragged.
    pub tile: i64,
}

/// Retention level: retain the tile formed by partitioning the first `j`
/// schedule ranks. `j = 0` = whole tensor, `j = k` = innermost tile.
pub type RetLevel = usize;

/// Sequential or pipelined processing of tiles across layers (paper Fig 7).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Parallelism {
    Sequential,
    Pipeline,
}

/// The inter-layer mapping (paper Table IV).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct InterLayerMapping {
    /// Partitioned ranks in schedule order (outer → inner). The same rank may
    /// appear more than once (hierarchical re-partitioning for multi-level
    /// buffers, paper §III-A) as long as tile sizes are strictly nested.
    pub partitions: Vec<Partition>,
    /// Per-tensor retention level; tensors absent from the map use
    /// [`InterLayerMapping::default_retention`].
    pub retention: HashMap<TensorId, RetLevel>,
    /// Retention level for tensors without an explicit choice.
    pub default_retention: RetLevel,
    /// How partitioned children execute (sequential or pipelined).
    pub parallelism: Parallelism,
}

impl InterLayerMapping {
    /// An untiled mapping: one tile covering everything (degenerates to
    /// untiled fusion — whole intermediate fmaps retained).
    pub fn untiled(parallelism: Parallelism) -> Self {
        InterLayerMapping {
            partitions: vec![],
            retention: HashMap::new(),
            default_retention: 0,
            parallelism,
        }
    }

    /// Convenience: partitions in schedule order with full retention at the
    /// innermost level for every tensor.
    pub fn tiled(partitions: Vec<Partition>, parallelism: Parallelism) -> Self {
        let k = partitions.len();
        InterLayerMapping {
            partitions,
            retention: HashMap::new(),
            default_retention: k,
            parallelism,
        }
    }

    /// Number of schedule levels (k).
    pub fn num_levels(&self) -> usize {
        self.partitions.len()
    }

    /// The retention level for tensor `t` (explicit or default).
    pub fn retention_for(&self, t: TensorId) -> RetLevel {
        *self.retention.get(&t).unwrap_or(&self.default_retention)
    }

    /// Builder: set tensor `t`'s retention level.
    pub fn with_retention(mut self, t: TensorId, level: RetLevel) -> Self {
        self.retention.insert(t, level);
        self
    }

    /// Uniform retention level for all tensors (the constrained mapspace of
    /// the paper's Fig 16 baseline).
    pub fn with_uniform_retention(mut self, level: RetLevel) -> Self {
        self.retention.clear();
        self.default_retention = level;
        self
    }

    /// Iteration count at each schedule level: `ceil(rank size / tile)`.
    /// For a repeated rank, the size at the deeper level is the outer tile.
    pub fn level_counts(&self, fs: &FusionSet) -> Vec<i64> {
        let last = fs.last();
        let mut cur_extent: HashMap<usize, i64> = HashMap::new();
        let mut counts = Vec::with_capacity(self.partitions.len());
        for p in &self.partitions {
            let extent = *cur_extent.get(&p.dim).unwrap_or(&last.rank_sizes[p.dim]);
            counts.push(extent.div_ceil(p.tile));
            cur_extent.insert(p.dim, p.tile);
        }
        counts
    }

    /// Total number of innermost iterations.
    pub fn total_iterations(&self, fs: &FusionSet) -> i64 {
        self.level_counts(fs).iter().product()
    }

    /// Structural validity with respect to a fusion set.
    pub fn validate(&self, fs: &FusionSet) -> Result<(), String> {
        let last = fs.last();
        let k = self.num_levels();
        let mut cur_extent: HashMap<usize, i64> = HashMap::new();
        for p in &self.partitions {
            if p.dim >= last.ndim() {
                return Err(format!("partition dim {} out of range", p.dim));
            }
            if p.tile < 1 {
                return Err(format!("tile {} < 1 on dim {}", p.tile, p.dim));
            }
            let extent = *cur_extent.get(&p.dim).unwrap_or(&last.rank_sizes[p.dim]);
            if p.tile > extent {
                return Err(format!(
                    "tile {} exceeds extent {} of dim {} ({})",
                    p.tile, extent, p.dim, last.rank_names[p.dim]
                ));
            }
            cur_extent.insert(p.dim, p.tile);
        }
        if self.default_retention > k {
            return Err(format!(
                "default retention {} exceeds {} levels",
                self.default_retention, k
            ));
        }
        for (&t, &lvl) in &self.retention {
            if t.0 >= fs.tensors.len() {
                return Err(format!("retention for unknown tensor {}", t.0));
            }
            if lvl > k {
                return Err(format!(
                    "retention level {} for {} exceeds {} levels",
                    lvl,
                    fs.tensor(t).name,
                    k
                ));
            }
        }
        Ok(())
    }

    /// Human-readable schedule, e.g. `"P2,Q2"` (paper §VI-B notation).
    pub fn schedule_string(&self, fs: &FusionSet) -> String {
        let last = fs.last();
        if self.partitions.is_empty() {
            return "untiled".into();
        }
        self.partitions
            .iter()
            .map(|p| last.rank_names[p.dim].clone())
            .collect::<Vec<_>>()
            .join(",")
    }
}
