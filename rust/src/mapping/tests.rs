use super::*;
use crate::einsum::workloads;

#[test]
fn untiled_mapping_validates() {
    let fs = workloads::conv_conv(14, 8);
    let m = InterLayerMapping::untiled(Parallelism::Sequential);
    assert!(m.validate(&fs).is_ok());
    assert_eq!(m.total_iterations(&fs), 1);
    assert_eq!(m.schedule_string(&fs), "untiled");
}

#[test]
fn tiled_mapping_level_counts() {
    let fs = workloads::conv_conv(14, 8);
    // Last layer Conv2 ranks: [M2,P2,Q2,C2,R2,S2]; P2=Q2=12.
    let p2 = fs.last().rank_index("P2").unwrap();
    let q2 = fs.last().rank_index("Q2").unwrap();
    let m = InterLayerMapping::tiled(
        vec![Partition { dim: p2, tile: 4 }, Partition { dim: q2, tile: 6 }],
        Parallelism::Sequential,
    );
    assert!(m.validate(&fs).is_ok());
    assert_eq!(m.level_counts(&fs), vec![3, 2]);
    assert_eq!(m.total_iterations(&fs), 6);
    assert_eq!(m.schedule_string(&fs), "P2,Q2");
}

#[test]
fn ragged_tiles_ceil() {
    let fs = workloads::conv_conv(14, 8); // P2 = 12
    let p2 = fs.last().rank_index("P2").unwrap();
    let m = InterLayerMapping::tiled(
        vec![Partition { dim: p2, tile: 5 }],
        Parallelism::Pipeline,
    );
    assert_eq!(m.level_counts(&fs), vec![3]); // 5+5+2
}

#[test]
fn repartitioned_rank_nested_counts() {
    let fs = workloads::conv_conv(30, 8); // P2 = 28
    let p2 = fs.last().rank_index("P2").unwrap();
    let m = InterLayerMapping::tiled(
        vec![Partition { dim: p2, tile: 14 }, Partition { dim: p2, tile: 7 }],
        Parallelism::Sequential,
    );
    assert!(m.validate(&fs).is_ok());
    assert_eq!(m.level_counts(&fs), vec![2, 2]); // 28/14, 14/7
}

#[test]
fn invalid_mappings_rejected() {
    let fs = workloads::conv_conv(14, 8);
    let p2 = fs.last().rank_index("P2").unwrap();
    // Tile exceeds extent.
    let m = InterLayerMapping::tiled(
        vec![Partition { dim: p2, tile: 100 }],
        Parallelism::Sequential,
    );
    assert!(m.validate(&fs).is_err());
    // Dim out of range.
    let m = InterLayerMapping::tiled(
        vec![Partition { dim: 99, tile: 1 }],
        Parallelism::Sequential,
    );
    assert!(m.validate(&fs).is_err());
    // Retention deeper than levels.
    let m = InterLayerMapping::tiled(
        vec![Partition { dim: p2, tile: 4 }],
        Parallelism::Sequential,
    )
    .with_retention(crate::einsum::TensorId(0), 5);
    assert!(m.validate(&fs).is_err());
}

#[test]
fn retention_defaults_and_overrides() {
    let fs = workloads::conv_conv(14, 8);
    let p2 = fs.last().rank_index("P2").unwrap();
    let t0 = crate::einsum::TensorId(0);
    let t1 = crate::einsum::TensorId(1);
    let m = InterLayerMapping::tiled(
        vec![Partition { dim: p2, tile: 4 }],
        Parallelism::Sequential,
    )
    .with_retention(t0, 0);
    assert_eq!(m.retention_for(t0), 0);
    assert_eq!(m.retention_for(t1), 1); // default = k
    let u = m.with_uniform_retention(1);
    assert_eq!(u.retention_for(t0), 1);
}

#[test]
fn intra_default_respects_pe_budget() {
    let fs = workloads::conv_conv(28, 64);
    let e = &fs.einsums[0];
    let im = IntraLayerMapping::default_for(e, 256);
    assert!(im.validate(e, 256).is_ok());
    assert!(im.fanout() <= 256);
    assert!(im.fanout() > 1);
}

#[test]
fn intra_validation_rejects_bad() {
    let fs = workloads::conv_conv(28, 64);
    let e = &fs.einsums[0];
    let im = IntraLayerMapping { spatial: vec![(0, 64), (1, 64)] };
    assert!(im.validate(e, 256).is_err()); // 4096 > 256
    let im = IntraLayerMapping { spatial: vec![(0, 2), (0, 2)] };
    assert!(im.validate(e, 256).is_err()); // duplicate dim
}
