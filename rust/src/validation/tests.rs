use super::*;

fn max_err(rows: &[ValRow]) -> f64 {
    rows.iter().map(|r| r.error_pct()).fold(0.0, f64::max)
}

#[test]
fn depfin_within_tolerance() {
    let rows = validate_depfin(Scale::Test);
    assert!(!rows.is_empty());
    // Counts match exactly; energy within the paper's 4% band.
    for r in &rows {
        assert!(
            r.error_pct() <= 4.0,
            "{} {} {}: {:.2}% (lt={} ref={})",
            r.design,
            r.workload,
            r.metric,
            r.error_pct(),
            r.looptree,
            r.reference
        );
    }
}

#[test]
fn fused_cnn_within_tolerance() {
    let rows = validate_fused_cnn(Scale::Test);
    // Paper Table VI: ≤1.2% on the real config; allow the paper's global 4%
    // plus pipeline-fill slack on the reduced test size.
    assert!(max_err(&rows) <= 8.0, "max err {:.2}%", max_err(&rows));
    // Transfers and capacities must be exact.
    for r in &rows {
        if r.metric != "latency (cycles)" {
            assert_eq!(r.looptree, r.reference, "{} {}", r.workload, r.metric);
        }
    }
}

#[test]
fn isaac_within_tolerance() {
    let rows = validate_isaac(Scale::Test);
    assert!(max_err(&rows) <= 4.0, "max err {:.2}%", max_err(&rows));
    // Capacity scaling across layers: conv3 (more channels, smaller rows)
    // differs from conv1 — the published table's qualitative shape.
    let caps: Vec<f64> = rows
        .iter()
        .filter(|r| r.metric.starts_with("input buf"))
        .map(|r| r.looptree)
        .collect();
    assert!(caps.len() >= 2);
    assert!(caps[0] < caps[1], "conv1 buffer smaller than conv2 (3ch vs 8ch input)");
}

#[test]
fn pipelayer_speedups() {
    let rows = validate_pipelayer(Scale::Test);
    for r in &rows {
        // Pipelining helps (speedup > 1) and the model tracks the reference.
        assert!(r.looptree > 1.0, "{}: no speedup", r.workload);
        assert!(
            r.error_pct() <= 6.0,
            "{}: {:.2}% (lt={:.2} ref={:.2})",
            r.workload,
            r.error_pct(),
            r.looptree,
            r.reference
        );
    }
    // Deeper chains pipeline better: MNIST-B (3 layers) > MNIST-A (2).
    let get = |w: &str| rows.iter().find(|r| r.workload == w).unwrap().looptree;
    assert!(get("MNIST-B") > get("MNIST-A"));
}

#[test]
fn flat_within_tolerance() {
    let rows = validate_flat(Scale::Test);
    assert!(max_err(&rows) <= 4.0, "max err {:.2}%", max_err(&rows));
    // Transfers exact.
    for r in rows.iter().filter(|r| r.metric.starts_with("offchip")) {
        assert_eq!(r.looptree, r.reference);
    }
}

#[test]
fn full_summary_renders() {
    let rows = validate_depfin(Scale::Test);
    let s = summarize(&rows);
    assert!(s.contains("DepFin"));
    assert!(s.contains("max error"));
}
