//! The five validation designs (paper Table V), each encoded as
//! (workload, architecture, mapping) per its publication.

use super::report::ValRow;
use super::Scale;
use crate::arch::{presets, Arch};
use crate::einsum::{FusionSet, FusionSetBuilder, TensorId, TensorKind};
use crate::mapping::{InterLayerMapping, Parallelism, Partition};
use crate::model::{Evaluator, Metrics};
use crate::sim::{simulate, SimMetrics};

/// Validate-once session for one design's (workload, architecture) pair,
/// with the GLB unbounded — validations measure *required* capacity.
fn session(fs: &FusionSet, arch: &Arch) -> Evaluator {
    let unbounded = arch.unbounded_glb();
    Evaluator::new(fs, &unbounded).unwrap_or_else(|e| panic!("{}: {e}", fs.name))
}

/// Evaluate model + reference simulator for one mapping on a session.
fn run(ev: &Evaluator, mapping: &InterLayerMapping) -> (Metrics, SimMetrics) {
    let fs = ev.fusion_set();
    let m = ev
        .evaluate(mapping)
        .unwrap_or_else(|e| panic!("{}: model: {e}", fs.name));
    let s = simulate(fs, ev.arch(), mapping)
        .unwrap_or_else(|e| panic!("{}: sim: {e}", fs.name));
    (m, s)
}

/// Row/column (P,Q) schedule for the last layer, retain bands (level 1):
/// the "fully retain" depth-first dataflow of DepFin and Fused-layer CNN.
fn pq_mapping(fs: &FusionSet, p_tile: i64, q_tile: i64, par: Parallelism) -> InterLayerMapping {
    let last = fs.last();
    let n = fs.num_layers();
    let p = last
        .rank_index(&format!("P{n}"))
        .unwrap_or_else(|| panic!("no P rank in {}", last.name));
    let q = last.rank_index(&format!("Q{n}")).unwrap();
    let mut m = InterLayerMapping::tiled(
        vec![Partition { dim: p, tile: p_tile }, Partition { dim: q, tile: q_tile }],
        par,
    );
    // Fully retain: intermediates at the band level (no recompute), weights
    // and the input fmap fully on-chip (no refetch).
    for (x, t) in fs.tensors.iter().enumerate() {
        let lvl = match t.kind {
            TensorKind::Intermediate => 1,
            TensorKind::Weight => 0,
            TensorKind::InputFmap => 1,
            TensorKind::OutputFmap => 2,
        };
        m = m.with_retention(TensorId(x), lvl);
    }
    m
}

// ---------------------------------------------------------------- DepFin --

/// DepFin [43]: depth-first (fused) CNN processor; P,Q-partitioned tiles
/// processed sequentially, everything retained. Validated outputs: energy,
/// capacity, off-chip transfers (paper: exact match on energy + transfers).
pub fn validate_depfin(scale: Scale) -> Vec<ValRow> {
    let rows = match scale {
        Scale::Test => 10,
        Scale::Full => 64,
    };
    let arch = presets::depfin();
    let mut out = Vec::new();
    for (wl_name, fs) in [
        ("FSRCNN", crate::einsum::workloads::fsrcnn(rows)),
        ("MC-CNN", crate::einsum::workloads::mc_cnn(rows)),
    ] {
        let mapping = pq_mapping(&fs, (rows / 8).max(1), (rows / 8).max(1), Parallelism::Sequential);
        let (m, s) = run(&session(&fs, &arch), &mapping);
        out.push(ValRow {
            design: "DepFin",
            workload: wl_name.into(),
            metric: "energy (uJ)",
            looptree: m.energy_uj(),
            reference: s.energy_pj / 1e6,
            published: None,
        });
        out.push(ValRow {
            design: "DepFin",
            workload: wl_name.into(),
            metric: "offchip (elems)",
            looptree: m.offchip_total() as f64,
            reference: (s.offchip_reads + s.offchip_writes) as f64,
            published: None,
        });
        out.push(ValRow {
            design: "DepFin",
            workload: wl_name.into(),
            metric: "capacity (elems)",
            looptree: m.occupancy_peak as f64,
            reference: s.occupancy_peak as f64,
            published: None,
        });
    }
    out
}

// ------------------------------------------------------- Fused-layer CNN --

/// Fused-layer CNN [16]: the original fused accelerator; P,Q partitioning,
/// pipelined tiles. Validated outputs: latency, per-buffer capacity (WBuf /
/// IOBuf / TBuf), off-chip transfers (paper Table VI).
pub fn validate_fused_cnn(scale: Scale) -> Vec<ValRow> {
    let rows = match scale {
        Scale::Test => 16,
        Scale::Full => 56,
    };
    // First two 3×3 conv layers of VGG-E at reduced resolution (3→64→64 ch;
    // channel structure preserved, spatial scaled for the element-level
    // reference).
    let ch = match scale {
        Scale::Test => 8,
        Scale::Full => 64,
    };
    let fs = FusionSetBuilder::new("vgg-e-c1c2", &[3, rows + 2, rows + 2])
        .conv2d(ch, 3, 3, 1)
        .conv2d(ch, 3, 3, 1)
        .build();
    let arch = presets::fused_cnn();
    let mapping = pq_mapping(&fs, (rows / 8).max(1), (rows / 2).max(1), Parallelism::Pipeline);
    let (m, s) = run(&session(&fs, &arch), &mapping);

    // Buffer split per the publication: WBuf = weights, IOBuf = input +
    // output fmaps, TBuf = intermediate tile.
    let cap_of = |metrics: &[i64]| -> (f64, f64, f64) {
        let mut w = 0.0;
        let mut io = 0.0;
        let mut t = 0.0;
        for (x, tn) in fs.tensors.iter().enumerate() {
            let v = metrics[x] as f64;
            match tn.kind {
                TensorKind::Weight => w += v,
                TensorKind::InputFmap | TensorKind::OutputFmap => io += v,
                TensorKind::Intermediate => t += v,
            }
        }
        (w, io, t)
    };
    let (mw, mio, mt) = cap_of(&m.per_tensor_occupancy);
    let (sw, sio, st) = cap_of(&s.per_tensor_occupancy);

    let wl = format!("VGG-E c1+c2 ({rows}px)");
    vec![
        ValRow {
            design: "Fused-layer CNN",
            workload: wl.clone(),
            metric: "latency (cycles)",
            looptree: m.latency_cycles as f64,
            reference: s.latency_cycles as f64,
            published: None,
        },
        ValRow {
            design: "Fused-layer CNN",
            workload: wl.clone(),
            metric: "WBuf (elems)",
            looptree: mw,
            reference: sw,
            published: None,
        },
        ValRow {
            design: "Fused-layer CNN",
            workload: wl.clone(),
            metric: "IOBuf (elems)",
            looptree: mio,
            reference: sio,
            published: None,
        },
        ValRow {
            design: "Fused-layer CNN",
            workload: wl.clone(),
            metric: "TBuf (elems)",
            looptree: mt,
            reference: st,
            published: None,
        },
        ValRow {
            design: "Fused-layer CNN",
            workload: wl,
            metric: "offchip (elems)",
            looptree: m.offchip_total() as f64,
            reference: (s.offchip_reads + s.offchip_writes) as f64,
            published: None,
        },
    ]
}

// ------------------------------------------------------------------ ISAAC --

/// ISAAC [17]: column-partitioned (Q) pipeline between conv layers backed by
/// eDRAM inter-stage buffers. Validated outputs: energy, buffer capacity.
/// The published Table VII numbers scale with `rows × channels × kernel
/// halo`; the reproduced claim is the model-vs-reference agreement and the
/// per-layer capacity *scaling* across VGG-1 layers.
pub fn validate_isaac(scale: Scale) -> Vec<ValRow> {
    let mut out = Vec::new();
    // Per-layer inter-stage buffers: ISAAC's Table (paper Table VII) sizes
    // the eDRAM buffer feeding each conv layer — a few kernel rows of that
    // layer's *input* fmap, which is exactly the input-fmap occupancy of a
    // column-partitioned pipeline in our taxonomy. (layer tag, in-ch,
    // spatial, out-ch); Test runs at reduced resolution.
    let configs: Vec<(&str, i64, i64, i64)> = match scale {
        Scale::Test => vec![("conv1", 3, 12, 8), ("conv2", 8, 12, 8), ("conv3", 8, 8, 16)],
        Scale::Full => vec![
            ("conv1", 3, 56, 64),
            ("conv2", 64, 56, 64),
            ("conv3", 64, 28, 128),
            ("conv5", 128, 14, 256),
        ],
    };
    let arch = presets::isaac();
    for (tag, c, hw, m_ch) in configs {
        let fs = FusionSetBuilder::new(&format!("vgg1-{tag}"), &[c, hw + 2, hw + 2])
            .conv2d(m_ch, 3, 3, 1)
            .conv2d(m_ch, 3, 3, 1)
            .build();
        let mapping = isaac_mapping(&fs);
        let (m, s) = run(&session(&fs, &arch), &mapping);
        out.push(ValRow {
            design: "ISAAC",
            workload: format!("VGG-1 {tag}"),
            metric: "energy (uJ)",
            looptree: m.energy_uj(),
            reference: s.energy_pj / 1e6,
            published: None,
        });
        // The layer's input buffer (column window of the input fmap).
        out.push(ValRow {
            design: "ISAAC",
            workload: format!("VGG-1 {tag}"),
            metric: "input buf (elems)",
            looptree: m.per_tensor_occupancy[0] as f64,
            reference: s.per_tensor_occupancy[0] as f64,
            published: None,
        });
    }
    out
}

/// Column partitioning: Q of the last layer, balanced-throughput pipeline
/// (the ISAAC assumption); weights live in the crossbars (level 0).
fn isaac_mapping(fs: &FusionSet) -> InterLayerMapping {
    let q = fs.last().rank_index("Q2").unwrap();
    let mut mapping = InterLayerMapping::tiled(
        vec![Partition { dim: q, tile: 2 }],
        Parallelism::Pipeline,
    );
    for (x, t) in fs.tensors.iter().enumerate() {
        let lvl = match t.kind {
            TensorKind::Weight => 0,
            _ => 1,
        };
        mapping = mapping.with_retention(TensorId(x), lvl);
    }
    mapping
}

// -------------------------------------------------------------- PipeLayer --

/// PipeLayer [18]: batch-partitioned ReRAM pipeline. Validated output: the
/// pipeline-over-sequential speedup (paper Table VIII: AlexNet 4.8×, VGG-A
/// 7.9×..8.0×, MNIST-A 2.0×, MNIST-B 2.9×..3.0×).
pub fn validate_pipelayer(scale: Scale) -> Vec<ValRow> {
    let batch = match scale {
        Scale::Test => 4,
        Scale::Full => 32,
    };
    let arch = presets::pipelayer();
    let mut out = Vec::new();
    let cases: Vec<(&str, FusionSet, Option<f64>)> = vec![
        (
            "AlexNet c3-c5",
            match scale {
                Scale::Test => small_batched_chain(batch, 3, 8, 10),
                Scale::Full => crate::einsum::workloads::alexnet_convs_batched(batch),
            },
            Some(4.8),
        ),
        (
            "VGG-A stage",
            match scale {
                Scale::Test => small_batched_chain(batch, 3, 6, 12),
                Scale::Full => crate::einsum::workloads::vgg_a_convs_batched(batch),
            },
            Some(8.0),
        ),
        (
            "MNIST-A",
            crate::einsum::workloads::mnist_convs_batched(batch, 2),
            Some(2.0),
        ),
        (
            "MNIST-B",
            crate::einsum::workloads::mnist_convs_batched(batch, 3),
            Some(3.0),
        ),
    ];
    for (tag, fs, published) in cases {
        let ev = session(&fs, &arch);
        let (m_seq, s_seq) = run(&ev, &pipelayer_mapping(&fs, Parallelism::Sequential));
        let (m_pipe, s_pipe) = run(&ev, &pipelayer_mapping(&fs, Parallelism::Pipeline));
        let lt_speedup = m_seq.compute_cycles as f64 / m_pipe.compute_cycles as f64;
        let sim_speedup = s_seq.compute_cycles as f64 / s_pipe.compute_cycles as f64;
        out.push(ValRow {
            design: "PipeLayer",
            workload: tag.into(),
            metric: "pipeline speedup",
            looptree: lt_speedup,
            reference: sim_speedup,
            published,
        });
    }
    out
}

/// Batch partitioning (one image per tile), everything but the crossbar
/// weights retained at the batch level — the PipeLayer dataflow.
fn pipelayer_mapping(fs: &FusionSet, par: Parallelism) -> InterLayerMapping {
    let b = fs.last().rank_index(&format!("B{}", fs.num_layers())).unwrap();
    let mut m = InterLayerMapping::tiled(vec![Partition { dim: b, tile: 1 }], par);
    for (x, t) in fs.tensors.iter().enumerate() {
        let lvl = if t.kind == TensorKind::Weight { 0 } else { 1 };
        m = m.with_retention(TensorId(x), lvl);
    }
    m
}

/// A small batched conv chain for test-scale PipeLayer runs.
fn small_batched_chain(batch: i64, layers: usize, ch: i64, hw: i64) -> FusionSet {
    let mut b = FusionSetBuilder::new(
        &format!("chain{layers}(b{batch})"),
        &[batch, ch, hw + 2 * layers as i64, hw + 2 * layers as i64],
    );
    for _ in 0..layers {
        b.conv2d_batched(ch, 3, 3, 1);
    }
    b.build()
}

// ------------------------------------------------------------------- FLAT --

/// B, H, M partitioning with every tensor retained at the innermost level —
/// the FLAT fused-attention dataflow for one M-tile size.
fn flat_mapping(fs: &FusionSet, m_tile: i64) -> InterLayerMapping {
    let last = fs.last();
    let b = last.rank_index("B2").unwrap();
    let h = last.rank_index("H2").unwrap();
    let mrank = last.rank_index("M2").unwrap();
    let mut mapping = InterLayerMapping::tiled(
        vec![
            Partition { dim: b, tile: 1 },
            Partition { dim: h, tile: 1 },
            Partition { dim: mrank, tile: m_tile },
        ],
        Parallelism::Sequential,
    );
    for x in 0..fs.tensors.len() {
        mapping = mapping.with_retention(TensorId(x), 3);
    }
    mapping
}

/// FLAT [30]: fused attention with B, H, M partitioning, sequential tiles.
/// Validated outputs: latency and off-chip transfers across tile shapes
/// (paper Fig 13: normalized series, ≤3.4% divergence).
pub fn validate_flat(scale: Scale) -> Vec<ValRow> {
    let (batch, heads, tokens, emb) = match scale {
        Scale::Test => (2, 2, 32, 8),
        Scale::Full => (4, 8, 128, 32),
    };
    let arch = presets::flat();
    let fs = crate::einsum::workloads::self_attention(batch, heads, tokens, emb);
    let ev = session(&fs, &arch);
    let mut out = Vec::new();
    for m_tile in [tokens / 8, tokens / 4, tokens / 2] {
        if m_tile < 1 {
            continue;
        }
        let mapping = flat_mapping(&fs, m_tile);
        let (m, s) = run(&ev, &mapping);
        let wl = format!("attn Mt={m_tile}");
        out.push(ValRow {
            design: "FLAT",
            workload: wl.clone(),
            metric: "latency (cycles)",
            looptree: m.latency_cycles as f64,
            reference: s.latency_cycles as f64,
            published: None,
        });
        out.push(ValRow {
            design: "FLAT",
            workload: wl,
            metric: "offchip (elems)",
            looptree: m.offchip_total() as f64,
            reference: (s.offchip_reads + s.offchip_writes) as f64,
            published: None,
        });
    }
    out
}

// ---------------------------------------------------------- design points --

/// One validated (workload, architecture, mapping) triple.
#[derive(Debug, Clone)]
pub struct DesignPoint {
    /// Published design name.
    pub design: &'static str,
    /// The encoded workload.
    pub fs: FusionSet,
    /// The encoded architecture.
    pub arch: Arch,
    /// The encoded mapping.
    pub mapping: InterLayerMapping,
}

/// A representative (workload, architecture, mapping) triple per validation
/// design (paper Table V), built exactly as the `validate_*` drivers build
/// them — the surface golden tests and external tools evaluate directly.
pub fn design_points(scale: Scale) -> Vec<DesignPoint> {
    let mut out = Vec::new();

    // DepFin: FSRCNN, sequential P,Q bands.
    {
        let rows = match scale {
            Scale::Test => 10,
            Scale::Full => 64,
        };
        let fs = crate::einsum::workloads::fsrcnn(rows);
        let mapping =
            pq_mapping(&fs, (rows / 8).max(1), (rows / 8).max(1), Parallelism::Sequential);
        out.push(DesignPoint { design: "DepFin", fs, arch: presets::depfin(), mapping });
    }

    // Fused-layer CNN: VGG-E c1+c2, pipelined P,Q bands.
    {
        let (rows, ch) = match scale {
            Scale::Test => (16, 8),
            Scale::Full => (56, 64),
        };
        let fs = FusionSetBuilder::new("vgg-e-c1c2", &[3, rows + 2, rows + 2])
            .conv2d(ch, 3, 3, 1)
            .conv2d(ch, 3, 3, 1)
            .build();
        let mapping =
            pq_mapping(&fs, (rows / 8).max(1), (rows / 2).max(1), Parallelism::Pipeline);
        out.push(DesignPoint {
            design: "Fused-layer CNN",
            fs,
            arch: presets::fused_cnn(),
            mapping,
        });
    }

    // ISAAC: column-partitioned pipeline.
    {
        let (c, hw, m_ch) = match scale {
            Scale::Test => (3, 12, 8),
            Scale::Full => (3, 56, 64),
        };
        let fs = FusionSetBuilder::new("vgg1-conv1", &[c, hw + 2, hw + 2])
            .conv2d(m_ch, 3, 3, 1)
            .conv2d(m_ch, 3, 3, 1)
            .build();
        let mapping = isaac_mapping(&fs);
        out.push(DesignPoint { design: "ISAAC", fs, arch: presets::isaac(), mapping });
    }

    // PipeLayer: batch-partitioned pipeline.
    {
        let batch = match scale {
            Scale::Test => 4,
            Scale::Full => 32,
        };
        let fs = crate::einsum::workloads::mnist_convs_batched(batch, 2);
        let mapping = pipelayer_mapping(&fs, Parallelism::Pipeline);
        out.push(DesignPoint {
            design: "PipeLayer",
            fs,
            arch: presets::pipelayer(),
            mapping,
        });
    }

    // FLAT: B,H,M-partitioned sequential attention.
    {
        let (batch, heads, tokens, emb) = match scale {
            Scale::Test => (2, 2, 32, 8),
            Scale::Full => (4, 8, 128, 32),
        };
        let fs = crate::einsum::workloads::self_attention(batch, heads, tokens, emb);
        let mapping = flat_mapping(&fs, tokens / 4);
        out.push(DesignPoint { design: "FLAT", fs, arch: presets::flat(), mapping });
    }

    out
}
