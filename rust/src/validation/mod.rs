//! Validation against prior architectures (paper §V, Tables V–VIII, Fig 13).
//!
//! Each prior accelerator is encoded as a (workload, architecture, mapping)
//! triple per its publication's dataflow description (paper Table V):
//!
//! | Design          | Partitioned ranks    | Retain-recompute | Parallelism |
//! |-----------------|----------------------|------------------|-------------|
//! | DepFin [43]     | Row, column          | Fully retain     | sequential  |
//! | Fused-layer [16]| Row, column          | Fully retain     | pipeline    |
//! | ISAAC [17]      | Column               | Fully retain     | pipeline    |
//! | PipeLayer [18]  | Batch                | Fully retain     | pipeline    |
//! | FLAT [30]       | Batch, heads, tokens | Fully retain     | sequential  |
//!
//! **Reference methodology.** The publications' absolute numbers come from
//! testbeds we cannot re-run (FPGA synthesis, ReRAM arrays, the FLAT
//! simulator). Following the paper's own approach for Fused-layer CNN ("we
//! create a simulation based on the architecture description"), the
//! reference for every design is our element-level executable simulator
//! (`sim`), and the validation claim reproduced is the *error band*: the
//! LoopTree analytical model agrees with an executed reference within the
//! paper's ≤4% worst case. Where the publication's relative results are
//! derivable (PipeLayer's pipeline speedups, ISAAC's per-layer buffer
//! scaling, DepFin's exact-match energy/transfers), the tables also print
//! the published values for comparison. See DESIGN.md §substitutions.

mod designs;
mod report;

pub use designs::{
    design_points, validate_depfin, validate_flat, validate_fused_cnn, validate_isaac,
    validate_pipelayer, DesignPoint,
};
pub use report::{summarize, ValRow};

/// Workload scale: tests run reduced spatial sizes (the element-level
/// reference simulator is O(elements)); benches run the full sizes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    /// Reduced spatial dims for fast CI runs.
    Test,
    /// Publication-sized workloads (bench / report runs).
    Full,
}

/// Run every validation and return all rows (the paper's Table V summary is
/// derived from these via [`summarize`]).
pub fn run_all(scale: Scale) -> Vec<ValRow> {
    let mut rows = Vec::new();
    rows.extend(validate_depfin(scale));
    rows.extend(validate_fused_cnn(scale));
    rows.extend(validate_isaac(scale));
    rows.extend(validate_pipelayer(scale));
    rows.extend(validate_flat(scale));
    rows
}

#[cfg(test)]
mod tests;
