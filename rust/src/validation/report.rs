//! Validation row/report types.

use crate::util::table::Table;

/// One validated metric: LoopTree model value vs. the executed reference.
#[derive(Debug, Clone)]
pub struct ValRow {
    /// Published design name.
    pub design: &'static str,
    /// Workload label.
    pub workload: String,
    /// Metric name being compared.
    pub metric: &'static str,
    /// LoopTree analytical model.
    pub looptree: f64,
    /// Executed reference (element-level simulator).
    pub reference: f64,
    /// Published value, when the publication reports a comparable number
    /// (informational; our substrate differs — see module docs).
    pub published: Option<f64>,
}

impl ValRow {
    /// Relative model-vs-reference error in percent.
    pub fn error_pct(&self) -> f64 {
        if self.reference == 0.0 {
            if self.looptree == 0.0 {
                0.0
            } else {
                f64::INFINITY
            }
        } else {
            100.0 * (self.looptree - self.reference).abs() / self.reference.abs()
        }
    }
}

/// Render rows as a table plus a per-design max-error summary (the paper's
/// Table V "Max. error" column).
pub fn summarize(rows: &[ValRow]) -> String {
    let mut t = Table::new(&[
        "design", "workload", "metric", "LoopTree", "reference", "published", "err %",
    ]);
    for r in rows {
        t.row(&[
            r.design.to_string(),
            r.workload.clone(),
            r.metric.to_string(),
            format!("{:.4}", r.looptree),
            format!("{:.4}", r.reference),
            r.published.map(|p| format!("{p:.4}")).unwrap_or_else(|| "-".into()),
            format!("{:.2}", r.error_pct()),
        ]);
    }
    let mut out = t.render();
    out.push('\n');

    let mut designs: Vec<&str> = rows.iter().map(|r| r.design).collect();
    designs.dedup();
    let mut s = Table::new(&["design", "max error %"]);
    for d in designs {
        let max = rows
            .iter()
            .filter(|r| r.design == d)
            .map(|r| r.error_pct())
            .fold(0.0f64, f64::max);
        s.row(&[d.to_string(), format!("{max:.2}")]);
    }
    out.push_str("Table V summary (model vs executed reference):\n");
    out.push_str(&s.render());
    out
}
