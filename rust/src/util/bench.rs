//! Minimal benchmark harness (criterion is not vendored in the offline
//! image). Benches are plain binaries (`harness = false`); this module
//! provides warmup + timed repetitions with mean/min/max reporting, a
//! machine-readable JSON emitter (`BENCH_*.json`, consumed by CI to track
//! the perf trajectory), and a smoke mode (`LOOPTREE_BENCH_SMOKE=1`) that
//! clamps repetitions for cheap CI runs.

use crate::util::json::Json;
use std::time::{Duration, Instant};

/// Result of one timed benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u32,
    pub mean: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:40} {:>12?} /iter (min {:?}, max {:?}, n={})",
            self.name, self.mean, self.min, self.max, self.iters
        )
    }

    /// Mean iterations per second (0 for a zero-duration mean).
    pub fn iters_per_sec(&self) -> f64 {
        let s = self.mean.as_secs_f64();
        if s > 0.0 {
            1.0 / s
        } else {
            0.0
        }
    }

    /// Machine-readable row: workload name, mean ns, iterations/s.
    pub fn to_json(&self) -> Json {
        Json::Obj(
            [
                ("workload".to_string(), Json::Str(self.name.clone())),
                ("mean_ns".to_string(), Json::Num(self.mean.as_nanos() as f64)),
                ("min_ns".to_string(), Json::Num(self.min.as_nanos() as f64)),
                ("max_ns".to_string(), Json::Num(self.max.as_nanos() as f64)),
                ("iters".to_string(), Json::Num(self.iters as f64)),
                ("iters_per_sec".to_string(), Json::Num(self.iters_per_sec())),
            ]
            .into_iter()
            .collect(),
        )
    }
}

/// `LOOPTREE_BENCH_SMOKE=1` clamps benches to 1 warmup / 3 reps so CI can
/// exercise them and upload the JSON artifact without paying full cost.
pub fn smoke() -> bool {
    std::env::var("LOOPTREE_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false)
}

/// `(warmup, iters)` honoring smoke mode.
pub fn reps(warmup: u32, iters: u32) -> (u32, u32) {
    if smoke() {
        (1, 3)
    } else {
        (warmup, iters)
    }
}

/// Write a bench report object to `path` (pretty JSON + trailing newline).
pub fn write_bench_json(path: &str, obj: &Json) -> std::io::Result<()> {
    std::fs::write(path, format!("{}\n", obj.pretty()))
}

/// The per-row keys of `BENCH_network.json` and their expected JSON type
/// (`true` = number, `false` = other). CI uploads that artifact; the bench
/// binary asserts this schema before writing and the test suite pins it, so
/// consumers downstream never see silent drift.
pub const NETWORK_BENCH_NUM_KEYS: [&str; 7] = [
    "mean_ns",
    "layers",
    "cuts",
    "candidate_segments",
    "distinct_searched",
    "total_score",
    "total_offchip_elems",
];

/// Validate a `BENCH_network.json` document: a `rows` array whose entries
/// carry a string `workload`, a bool `all_fit`, and every numeric key of
/// [`NETWORK_BENCH_NUM_KEYS`].
pub fn check_network_bench_schema(doc: &Json) -> Result<(), String> {
    let rows = doc
        .get("rows")
        .and_then(Json::as_arr)
        .ok_or("BENCH_network.json: missing 'rows' array")?;
    if rows.is_empty() {
        return Err("BENCH_network.json: 'rows' is empty".into());
    }
    for (i, row) in rows.iter().enumerate() {
        let ctx = |k: &str| format!("BENCH_network.json row {i}: bad or missing '{k}'");
        if row.get("workload").and_then(Json::as_str).is_none() {
            return Err(ctx("workload"));
        }
        if row.get("all_fit").and_then(Json::as_bool).is_none() {
            return Err(ctx("all_fit"));
        }
        for k in NETWORK_BENCH_NUM_KEYS {
            if row.get(k).and_then(Json::as_f64).is_none() {
                return Err(ctx(k));
            }
        }
    }
    Ok(())
}

/// Time `f` for `iters` repetitions after `warmup` repetitions.
pub fn bench<T>(name: &str, warmup: u32, iters: u32, mut f: impl FnMut() -> T) -> BenchResult {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed());
    }
    let total: Duration = times.iter().sum();
    BenchResult {
        name: name.to_string(),
        iters,
        mean: total / iters.max(1),
        min: times.iter().min().copied().unwrap_or_default(),
        max: times.iter().max().copied().unwrap_or_default(),
    }
}

/// Time a single (slow) run.
pub fn bench_once<T>(name: &str, f: impl FnOnce() -> T) -> (T, BenchResult) {
    let t0 = Instant::now();
    let out = f();
    let d = t0.elapsed();
    (
        out,
        BenchResult {
            name: name.to_string(),
            iters: 1,
            mean: d,
            min: d,
            max: d,
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_times() {
        let r = bench("noop", 2, 10, || 1 + 1);
        assert_eq!(r.iters, 10);
        assert!(r.min <= r.mean && r.mean <= r.max.max(r.mean));
        assert!(r.report().contains("noop"));
    }

    #[test]
    fn bench_once_returns_value() {
        let (v, r) = bench_once("compute", || 42);
        assert_eq!(v, 42);
        assert_eq!(r.iters, 1);
    }
}
