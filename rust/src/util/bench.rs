//! Minimal benchmark harness (criterion is not vendored in the offline
//! image). Benches are plain binaries (`harness = false`); this module
//! provides warmup + timed repetitions with mean/min/max reporting.

use std::time::{Duration, Instant};

/// Result of one timed benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u32,
    pub mean: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:40} {:>12?} /iter (min {:?}, max {:?}, n={})",
            self.name, self.mean, self.min, self.max, self.iters
        )
    }
}

/// Time `f` for `iters` repetitions after `warmup` repetitions.
pub fn bench<T>(name: &str, warmup: u32, iters: u32, mut f: impl FnMut() -> T) -> BenchResult {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed());
    }
    let total: Duration = times.iter().sum();
    BenchResult {
        name: name.to_string(),
        iters,
        mean: total / iters.max(1),
        min: times.iter().min().copied().unwrap_or_default(),
        max: times.iter().max().copied().unwrap_or_default(),
    }
}

/// Time a single (slow) run.
pub fn bench_once<T>(name: &str, f: impl FnOnce() -> T) -> (T, BenchResult) {
    let t0 = Instant::now();
    let out = f();
    let d = t0.elapsed();
    (
        out,
        BenchResult {
            name: name.to_string(),
            iters: 1,
            mean: d,
            min: d,
            max: d,
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_times() {
        let r = bench("noop", 2, 10, || 1 + 1);
        assert_eq!(r.iters, 10);
        assert!(r.min <= r.mean && r.mean <= r.max.max(r.mean));
        assert!(r.report().contains("noop"));
    }

    #[test]
    fn bench_once_returns_value() {
        let (v, r) = bench_once("compute", || 42);
        assert_eq!(v, 42);
        assert_eq!(r.iters, 1);
    }
}
