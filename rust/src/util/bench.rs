//! Minimal benchmark harness (criterion is not vendored in the offline
//! image). Benches are plain binaries (`harness = false`); this module
//! provides warmup + timed repetitions with mean/min/max reporting, a
//! machine-readable JSON emitter (`BENCH_*.json`, consumed by CI to track
//! the perf trajectory), and a smoke mode (`LOOPTREE_BENCH_SMOKE=1`) that
//! clamps repetitions for cheap CI runs.

use crate::util::json::Json;
use std::time::{Duration, Instant};

/// Result of one timed benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark name (stable row key).
    pub name: String,
    /// Timed iterations.
    pub iters: u32,
    /// Mean wall time per iteration.
    pub mean: Duration,
    /// Fastest iteration.
    pub min: Duration,
    /// Slowest iteration.
    pub max: Duration,
}

impl BenchResult {
    /// One-line human-readable report.
    pub fn report(&self) -> String {
        format!(
            "{:40} {:>12?} /iter (min {:?}, max {:?}, n={})",
            self.name, self.mean, self.min, self.max, self.iters
        )
    }

    /// Mean iterations per second (0 for a zero-duration mean).
    pub fn iters_per_sec(&self) -> f64 {
        let s = self.mean.as_secs_f64();
        if s > 0.0 {
            1.0 / s
        } else {
            0.0
        }
    }

    /// Machine-readable row: workload name, mean ns, iterations/s.
    pub fn to_json(&self) -> Json {
        Json::Obj(
            [
                ("workload".to_string(), Json::Str(self.name.clone())),
                ("mean_ns".to_string(), Json::Num(self.mean.as_nanos() as f64)),
                ("min_ns".to_string(), Json::Num(self.min.as_nanos() as f64)),
                ("max_ns".to_string(), Json::Num(self.max.as_nanos() as f64)),
                ("iters".to_string(), Json::Num(self.iters as f64)),
                ("iters_per_sec".to_string(), Json::Num(self.iters_per_sec())),
            ]
            .into_iter()
            .collect(),
        )
    }
}

/// `LOOPTREE_BENCH_SMOKE=1` clamps benches to 1 warmup / 3 reps so CI can
/// exercise them and upload the JSON artifact without paying full cost.
pub fn smoke() -> bool {
    std::env::var("LOOPTREE_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false)
}

/// `(warmup, iters)` honoring smoke mode.
pub fn reps(warmup: u32, iters: u32) -> (u32, u32) {
    if smoke() {
        (1, 3)
    } else {
        (warmup, iters)
    }
}

/// Write a bench report object to `path` (pretty JSON + trailing newline).
pub fn write_bench_json(path: &str, obj: &Json) -> std::io::Result<()> {
    std::fs::write(path, format!("{}\n", obj.pretty()))
}

/// Shared section checker behind the `BENCH_*.json` schema pins: `section`
/// must be a non-empty array whose entries carry a string `workload`, every
/// bool key, and every numeric key.
fn check_rows(
    doc: &Json,
    file: &str,
    section: &str,
    num_keys: &[&str],
    bool_keys: &[&str],
) -> Result<(), String> {
    let rows = doc
        .get(section)
        .and_then(Json::as_arr)
        .ok_or_else(|| format!("{file}: missing '{section}' array"))?;
    if rows.is_empty() {
        return Err(format!("{file}: '{section}' is empty"));
    }
    for (i, row) in rows.iter().enumerate() {
        let ctx = |k: &str| format!("{file} {section}[{i}]: bad or missing '{k}'");
        if row.get("workload").and_then(Json::as_str).is_none() {
            return Err(ctx("workload"));
        }
        for k in bool_keys {
            if row.get(k).and_then(Json::as_bool).is_none() {
                return Err(ctx(k));
            }
        }
        for k in num_keys {
            if row.get(k).and_then(Json::as_f64).is_none() {
                return Err(ctx(k));
            }
        }
    }
    Ok(())
}

/// The per-row numeric keys of `BENCH_network.json`'s `rows` section. CI
/// uploads that artifact and diffs its deterministic counters across two
/// runs; the bench binary asserts this schema before writing and the test
/// suite pins it, so consumers downstream never see silent drift.
pub const NETWORK_BENCH_NUM_KEYS: [&str; 9] = [
    "mean_ns",
    "layers",
    "cuts",
    "candidate_segments",
    "candidates_pruned",
    "distinct_searched",
    "total_score",
    "total_offchip_elems",
    "symbolic_segments",
];

/// The per-row numeric keys of `BENCH_network.json`'s `pareto_rows` section
/// (front sizes of the network-level Pareto DP).
pub const NETWORK_PARETO_BENCH_NUM_KEYS: [&str; 8] = [
    "mean_ns",
    "layers",
    "objectives",
    "front_points",
    "segment_front_points",
    "candidate_segments",
    "candidates_pruned",
    "distinct_searched",
];

/// Validate a `BENCH_network.json` document: a `rows` array whose entries
/// carry a string `workload`, a bool `all_fit`, and every numeric key of
/// [`NETWORK_BENCH_NUM_KEYS`]; plus a `pareto_rows` array whose entries
/// carry a string `workload` and every numeric key of
/// [`NETWORK_PARETO_BENCH_NUM_KEYS`].
pub fn check_network_bench_schema(doc: &Json) -> Result<(), String> {
    const FILE: &str = "BENCH_network.json";
    check_rows(doc, FILE, "rows", &NETWORK_BENCH_NUM_KEYS, &["all_fit"])?;
    check_rows(doc, FILE, "pareto_rows", &NETWORK_PARETO_BENCH_NUM_KEYS, &[])
}

/// The per-row numeric keys of `BENCH_search.json` (`evaluated`, `pruned`,
/// and `best_score` are deterministic counters; the CI determinism gate
/// excludes the timing-derived keys).
pub const SEARCH_BENCH_NUM_KEYS: [&str; 6] =
    ["mean_ns", "evaluated", "pruned", "mappings_per_sec", "best_score", "symbolic_evals"];

/// Validate a `BENCH_search.json` document: a `rows` array whose entries
/// carry a string `workload` and every numeric key of
/// [`SEARCH_BENCH_NUM_KEYS`].
pub fn check_search_bench_schema(doc: &Json) -> Result<(), String> {
    check_rows(doc, "BENCH_search.json", "rows", &SEARCH_BENCH_NUM_KEYS, &[])
}

/// The per-row numeric keys of `BENCH_model_eval.json`'s `rows` section
/// (each row is a [`BenchResult::to_json`] record).
pub const MODEL_EVAL_BENCH_NUM_KEYS: [&str; 5] =
    ["mean_ns", "min_ns", "max_ns", "iters", "iters_per_sec"];

/// The per-row numeric keys of `BENCH_model_eval.json`'s
/// `fastpath_speedups` section (`iterations` is the deterministic
/// distinct-tile counter the CI determinism gate diffs).
pub const MODEL_EVAL_SPEEDUP_NUM_KEYS: [&str; 4] =
    ["iterations", "fast_mean_ns", "reference_mean_ns", "speedup"];

/// The per-row numeric keys of `BENCH_model_eval.json`'s
/// `symbolic_speedups` section (three-tier comparison rows; each entry also
/// carries the bools `symbolic_fired` and `multibox_fired`, the
/// deterministic path-attribution flags the CI determinism gate diffs
/// alongside `iterations`, `peak_union_width`, and `refusal_memo_hits`).
pub const MODEL_EVAL_SYMBOLIC_NUM_KEYS: [&str; 7] = [
    "iterations",
    "symbolic_mean_ns",
    "fast_mean_ns",
    "reference_mean_ns",
    "speedup_vs_fast",
    "peak_union_width",
    "refusal_memo_hits",
];

/// The per-row bool keys of `BENCH_model_eval.json`'s `symbolic_speedups`
/// section: whether the tier-1 walk covered the row, and whether it ever
/// held a multi-box union while doing so (`peak_union_width >= 2`).
pub const MODEL_EVAL_SYMBOLIC_BOOL_KEYS: [&str; 2] = ["symbolic_fired", "multibox_fired"];

/// Validate a `BENCH_model_eval.json` document: `rows`, `fastpath_speedups`,
/// and `symbolic_speedups`, each non-empty with a string `workload` and the
/// matching numeric/bool keys.
pub fn check_model_eval_bench_schema(doc: &Json) -> Result<(), String> {
    const FILE: &str = "BENCH_model_eval.json";
    check_rows(doc, FILE, "rows", &MODEL_EVAL_BENCH_NUM_KEYS, &[])?;
    check_rows(doc, FILE, "fastpath_speedups", &MODEL_EVAL_SPEEDUP_NUM_KEYS, &[])?;
    check_rows(
        doc,
        FILE,
        "symbolic_speedups",
        &MODEL_EVAL_SYMBOLIC_NUM_KEYS,
        &MODEL_EVAL_SYMBOLIC_BOOL_KEYS,
    )
}

/// The per-row numeric keys of `BENCH_serve.json`'s `rows` section. Each
/// row is one load-test scenario of the serve bench harness: request-level
/// latency percentiles plus the deterministic cross-request-cache counters
/// (`clients`, `requests`, `cache_hits`, `cache_misses`, `warm_starts`) the
/// CI determinism gate diffs across two runs.
pub const SERVE_BENCH_NUM_KEYS: [&str; 10] = [
    "clients",
    "requests",
    "mean_ns",
    "p50_ns",
    "p90_ns",
    "p99_ns",
    "throughput_rps",
    "cache_hits",
    "cache_misses",
    "warm_starts",
];

/// The per-row bool keys of `BENCH_serve.json`'s `rows` section: whether
/// every response in the scenario came back `ok`.
pub const SERVE_BENCH_BOOL_KEYS: [&str; 1] = ["all_ok"];

/// Validate a `BENCH_serve.json` document: a `rows` array whose entries
/// carry a string `workload` (the scenario name), every numeric key of
/// [`SERVE_BENCH_NUM_KEYS`], and every bool key of
/// [`SERVE_BENCH_BOOL_KEYS`].
pub fn check_serve_bench_schema(doc: &Json) -> Result<(), String> {
    check_rows(doc, "BENCH_serve.json", "rows", &SERVE_BENCH_NUM_KEYS, &SERVE_BENCH_BOOL_KEYS)
}

/// Latency distribution over a set of per-request wall times, as reported
/// by the serve load-test harness.
#[derive(Debug, Clone)]
pub struct LatencyStats {
    /// Number of samples summarized.
    pub count: usize,
    /// Arithmetic mean.
    pub mean: Duration,
    /// Median (nearest-rank).
    pub p50: Duration,
    /// 90th percentile (nearest-rank).
    pub p90: Duration,
    /// 99th percentile (nearest-rank).
    pub p99: Duration,
}

impl LatencyStats {
    /// Summarize `times`; an empty sample yields all-zero stats.
    /// Percentiles use the nearest-rank method on the sorted sample, so
    /// every reported value is an actually observed latency.
    pub fn from_times(times: &[Duration]) -> LatencyStats {
        if times.is_empty() {
            return LatencyStats {
                count: 0,
                mean: Duration::ZERO,
                p50: Duration::ZERO,
                p90: Duration::ZERO,
                p99: Duration::ZERO,
            };
        }
        let mut sorted = times.to_vec();
        sorted.sort();
        let total: Duration = sorted.iter().sum();
        let pct = |p: f64| {
            let rank = ((p / 100.0) * sorted.len() as f64).ceil() as usize;
            sorted[rank.clamp(1, sorted.len()) - 1]
        };
        LatencyStats {
            count: sorted.len(),
            mean: total / sorted.len() as u32,
            p50: pct(50.0),
            p90: pct(90.0),
            p99: pct(99.0),
        }
    }
}

/// Time `f` for `iters` repetitions after `warmup` repetitions.
pub fn bench<T>(name: &str, warmup: u32, iters: u32, mut f: impl FnMut() -> T) -> BenchResult {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed());
    }
    let total: Duration = times.iter().sum();
    BenchResult {
        name: name.to_string(),
        iters,
        mean: total / iters.max(1),
        min: times.iter().min().copied().unwrap_or_default(),
        max: times.iter().max().copied().unwrap_or_default(),
    }
}

/// Time a single (slow) run.
pub fn bench_once<T>(name: &str, f: impl FnOnce() -> T) -> (T, BenchResult) {
    let t0 = Instant::now();
    let out = f();
    let d = t0.elapsed();
    (
        out,
        BenchResult {
            name: name.to_string(),
            iters: 1,
            mean: d,
            min: d,
            max: d,
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_times() {
        let r = bench("noop", 2, 10, || 1 + 1);
        assert_eq!(r.iters, 10);
        assert!(r.min <= r.mean && r.mean <= r.max.max(r.mean));
        assert!(r.report().contains("noop"));
    }

    #[test]
    fn bench_once_returns_value() {
        let (v, r) = bench_once("compute", || 42);
        assert_eq!(v, 42);
        assert_eq!(r.iters, 1);
    }

    #[test]
    fn search_bench_schema_is_pinned() {
        // The bench binary emits rows with exactly these keys; losing any
        // (or the rows array itself) must fail the check.
        let row = "{\"workload\":\"exhaustive\",\"mean_ns\":1.0,\"evaluated\":40,\
                   \"pruned\":0,\"mappings_per_sec\":2.0,\"best_score\":3.0,\
                   \"symbolic_evals\":40}";
        let doc = Json::parse(&format!("{{\"rows\":[{row}]}}")).unwrap();
        check_search_bench_schema(&doc).unwrap();
        assert!(check_search_bench_schema(&Json::parse("{}").unwrap()).is_err());
        assert!(check_search_bench_schema(&Json::parse("{\"rows\":[]}").unwrap()).is_err());
        let broken = "{\"rows\":[{\"workload\":\"x\",\"mean_ns\":1.0}]}";
        assert!(check_search_bench_schema(&Json::parse(broken).unwrap()).is_err());
        // A pre-symbolic row (no `symbolic_evals` key) must now be rejected.
        let stale = "{\"rows\":[{\"workload\":\"x\",\"mean_ns\":1.0,\"evaluated\":40,\
                     \"pruned\":0,\"mappings_per_sec\":2.0,\"best_score\":3.0}]}";
        assert!(check_search_bench_schema(&Json::parse(stale).unwrap()).is_err());
    }

    #[test]
    fn serve_bench_schema_is_pinned() {
        let row = "{\"workload\":\"replay-warm\",\"clients\":8.0,\"requests\":64.0,\
                   \"mean_ns\":1.0,\"p50_ns\":1.0,\"p90_ns\":2.0,\"p99_ns\":3.0,\
                   \"throughput_rps\":100.0,\"cache_hits\":5.0,\"cache_misses\":0.0,\
                   \"warm_starts\":0.0,\"all_ok\":true}";
        let doc = Json::parse(&format!("{{\"rows\":[{row}]}}")).unwrap();
        check_serve_bench_schema(&doc).unwrap();
        assert!(check_serve_bench_schema(&Json::parse("{}").unwrap()).is_err());
        assert!(check_serve_bench_schema(&Json::parse("{\"rows\":[]}").unwrap()).is_err());
        // A row missing the deterministic cache counters must be rejected.
        let stale = "{\"rows\":[{\"workload\":\"x\",\"clients\":1.0,\"requests\":1.0,\
                     \"mean_ns\":1.0,\"p50_ns\":1.0,\"p90_ns\":1.0,\"p99_ns\":1.0,\
                     \"throughput_rps\":1.0,\"all_ok\":true}]}";
        assert!(check_serve_bench_schema(&Json::parse(stale).unwrap()).is_err());
    }

    #[test]
    fn latency_stats_use_nearest_rank() {
        let times: Vec<Duration> = (1..=100).map(Duration::from_nanos).collect();
        let s = LatencyStats::from_times(&times);
        assert_eq!(s.count, 100);
        assert_eq!(s.p50, Duration::from_nanos(50));
        assert_eq!(s.p90, Duration::from_nanos(90));
        assert_eq!(s.p99, Duration::from_nanos(99));
        let empty = LatencyStats::from_times(&[]);
        assert_eq!(empty.count, 0);
        assert_eq!(empty.mean, Duration::ZERO);
    }

    #[test]
    fn model_eval_bench_schema_is_pinned() {
        // rows entries are BenchResult::to_json records — pin both sides.
        let row = bench("noop", 0, 2, || 1).to_json().to_string();
        let speedup = "{\"workload\":\"conv\",\"iterations\":12.0,\"fast_mean_ns\":1.0,\
                       \"reference_mean_ns\":2.0,\"speedup\":2.0}";
        let symbolic = "{\"workload\":\"conv\",\"iterations\":12.0,\"symbolic_mean_ns\":0.5,\
                        \"fast_mean_ns\":1.0,\"reference_mean_ns\":2.0,\
                        \"speedup_vs_fast\":2.0,\"symbolic_fired\":true,\
                        \"multibox_fired\":true,\"peak_union_width\":2.0,\
                        \"refusal_memo_hits\":0.0}";
        let doc = Json::parse(&format!(
            "{{\"rows\":[{row}],\"fastpath_speedups\":[{speedup}],\
               \"symbolic_speedups\":[{symbolic}]}}"
        ))
        .unwrap();
        check_model_eval_bench_schema(&doc).unwrap();
        // Each section is required and non-empty.
        let no_speedups = Json::parse(&format!("{{\"rows\":[{row}]}}")).unwrap();
        assert!(check_model_eval_bench_schema(&no_speedups).is_err());
        let pre_symbolic = Json::parse(&format!(
            "{{\"rows\":[{row}],\"fastpath_speedups\":[{speedup}]}}"
        ))
        .unwrap();
        assert!(check_model_eval_bench_schema(&pre_symbolic).is_err());
        let doc = Json::parse(&format!(
            "{{\"rows\":[],\"fastpath_speedups\":[{speedup}],\
               \"symbolic_speedups\":[{symbolic}]}}"
        ))
        .unwrap();
        assert!(check_model_eval_bench_schema(&doc).is_err());
        // A speedup row losing the deterministic counter fails.
        let doc = Json::parse(&format!(
            "{{\"rows\":[{row}],\"fastpath_speedups\":[{{\"workload\":\"conv\"}}],\
               \"symbolic_speedups\":[{symbolic}]}}"
        ))
        .unwrap();
        assert!(check_model_eval_bench_schema(&doc).is_err());
        // A symbolic row missing the bool path-attribution flag fails.
        let no_fired = "{\"workload\":\"conv\",\"iterations\":12.0,\"symbolic_mean_ns\":0.5,\
                        \"fast_mean_ns\":1.0,\"reference_mean_ns\":2.0,\
                        \"speedup_vs_fast\":2.0,\"multibox_fired\":false,\
                        \"peak_union_width\":1.0,\"refusal_memo_hits\":0.0}";
        let doc = Json::parse(&format!(
            "{{\"rows\":[{row}],\"fastpath_speedups\":[{speedup}],\
               \"symbolic_speedups\":[{no_fired}]}}"
        ))
        .unwrap();
        assert!(check_model_eval_bench_schema(&doc).is_err());
        // A pre-multibox symbolic row (no `multibox_fired` /
        // `peak_union_width` / `refusal_memo_hits`) must now be rejected.
        let stale = "{\"workload\":\"conv\",\"iterations\":12.0,\"symbolic_mean_ns\":0.5,\
                     \"fast_mean_ns\":1.0,\"reference_mean_ns\":2.0,\
                     \"speedup_vs_fast\":2.0,\"symbolic_fired\":true}";
        let doc = Json::parse(&format!(
            "{{\"rows\":[{row}],\"fastpath_speedups\":[{speedup}],\
               \"symbolic_speedups\":[{stale}]}}"
        ))
        .unwrap();
        assert!(check_model_eval_bench_schema(&doc).is_err());
    }
}
