//! Minimal JSON parser/serializer (serde is not vendored in the offline
//! image). Supports the full JSON grammar; used for the artifact manifest
//! and CLI config files.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document from text.
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    /// Object field lookup; `None` on non-objects.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Array element lookup; `None` on non-arrays.
    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    /// The string value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The value as an exact integer: `None` for fractional numbers and for
    /// magnitudes beyond 2^53 (where f64 stops representing integers
    /// exactly), so integer fields can't be silently truncated or mangled.
    pub fn as_i64(&self) -> Option<i64> {
        const MAX_SAFE: f64 = 9_007_199_254_740_992.0; // 2^53
        match self.as_f64() {
            Some(f) if f.fract() == 0.0 && f.abs() <= MAX_SAFE => Some(f as i64),
            _ => None,
        }
    }

    /// The elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// The key-value map, if this is an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// The boolean value, if this is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Multi-line rendering with two-space indentation (the CLI `--json`
    /// output). Parses back to the same value as [`Json`]'s compact
    /// `Display`.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.pretty_into(&mut out, 0);
        out
    }

    fn pretty_into(&self, out: &mut String, indent: usize) {
        match self {
            Json::Arr(v) if !v.is_empty() => {
                out.push_str("[\n");
                for (i, x) in v.iter().enumerate() {
                    for _ in 0..indent + 1 {
                        out.push_str("  ");
                    }
                    x.pretty_into(out, indent + 1);
                    if i + 1 < v.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                for _ in 0..indent {
                    out.push_str("  ");
                }
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    for _ in 0..indent + 1 {
                        out.push_str("  ");
                    }
                    out.push_str(&format!("{}: ", Json::Str(k.clone())));
                    v.pretty_into(out, indent + 1);
                    if i + 1 < m.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                for _ in 0..indent {
                    out.push_str("  ");
                }
                out.push('}');
            }
            other => out.push_str(&other.to_string()),
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad \\u")?,
                                16,
                            )
                            .map_err(|_| "bad \\u")?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf-8")?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-2.5e2").unwrap(), Json::Num(-250.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().idx(1).unwrap().as_i64(), Some(2));
        assert_eq!(
            j.get("a").unwrap().idx(2).unwrap().get("b").unwrap().as_str(),
            Some("x")
        );
        assert_eq!(j.get("c"), Some(&Json::Null));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrips() {
        let src = r#"{"cfg":{"rows":32,"names":["a","b"],"ok":true,"f":1.5}}"#;
        let j = Json::parse(src).unwrap();
        let again = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, again);
    }

    #[test]
    fn as_i64_rejects_fractional_and_unsafe_magnitudes() {
        assert_eq!(Json::Num(4.0).as_i64(), Some(4));
        assert_eq!(Json::Num(-3.0).as_i64(), Some(-3));
        assert_eq!(Json::Num(1.6).as_i64(), None);
        assert_eq!(Json::Num(f64::INFINITY).as_i64(), None);
        assert_eq!(Json::Num(9_007_199_254_740_992.0).as_i64(), Some(1 << 53));
        assert_eq!(Json::Num(9.1e15).as_i64(), None);
    }

    #[test]
    fn pretty_round_trips() {
        let src = r#"{"cfg":{"rows":32,"names":["a","b"],"ok":true,"f":1.5},"empty":[],"none":{}}"#;
        let j = Json::parse(src).unwrap();
        let pretty = j.pretty();
        assert!(pretty.contains('\n'));
        assert_eq!(Json::parse(&pretty).unwrap(), j);
    }

    #[test]
    fn unicode_escapes() {
        let j = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(j.as_str(), Some("Aé"));
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{
          "config": {"rows": 32, "tile_p": 8},
          "artifacts": {"conv": {"file": "conv.hlo.txt", "inputs": [[16,36,36]]}}
        }"#;
        let j = Json::parse(src).unwrap();
        let inputs = j
            .get("artifacts").unwrap()
            .get("conv").unwrap()
            .get("inputs").unwrap();
        assert_eq!(inputs.idx(0).unwrap().idx(2).unwrap().as_i64(), Some(36));
    }
}
