//! Aligned text tables for reproducing the paper's tables on stdout.

/// A simple column-aligned text table builder.
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// An empty table with the given column headers.
    pub fn new(header: &[&str]) -> Self {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row of owned cells.
    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    /// Append a row of borrowed cells.
    pub fn row_strs(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    /// Whether no rows have been added.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render with columns padded to their widest cell.
    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    line.push_str("  ");
                }
                line.push_str(c);
                for _ in c.chars().count()..widths[i] {
                    line.push(' ');
                }
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncols - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// Render as a GitHub-flavored markdown table (for EXPERIMENTS.md).
    pub fn render_markdown(&self) -> String {
        let mut out = String::new();
        out.push_str("| ");
        out.push_str(&self.header.join(" | "));
        out.push_str(" |\n|");
        for _ in &self.header {
            out.push_str("---|");
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str("| ");
            out.push_str(&row.join(" | "));
            out.push_str(" |\n");
        }
        out
    }
}

/// Format a byte count human-readably (KB with one decimal, as the paper's
/// tables do).
pub fn fmt_kb(bytes: i64) -> String {
    format!("{:.1}", bytes as f64 / 1024.0)
}

/// Format a large count with thousands separators.
pub fn fmt_count(n: i64) -> String {
    let s = n.abs().to_string();
    let mut out = String::new();
    for (i, c) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    if n < 0 {
        format!("-{out}")
    } else {
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row_strs(&["a", "1"]).row_strs(&["longer", "22"]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("name"));
        assert!(lines[2].starts_with("a"));
    }

    #[test]
    fn markdown_shape() {
        let mut t = Table::new(&["a", "b"]);
        t.row_strs(&["1", "2"]);
        let md = t.render_markdown();
        assert!(md.contains("| a | b |"));
        assert!(md.contains("| 1 | 2 |"));
    }

    #[test]
    fn count_formatting() {
        assert_eq!(fmt_count(1234567), "1,234,567");
        assert_eq!(fmt_count(12), "12");
        assert_eq!(fmt_count(-1234), "-1,234");
        assert_eq!(fmt_kb(2048), "2.0");
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row_strs(&["only-one"]);
    }
}
