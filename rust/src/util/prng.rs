//! Deterministic PRNG (splitmix64 + xoshiro256**) for search algorithms and
//! property-based tests. Replaces `rand`/`proptest` in the offline image.

/// xoshiro256** seeded via splitmix64. Deterministic, fast, good enough for
/// mapping sampling and property-test case generation.
#[derive(Debug, Clone)]
pub struct Prng {
    s: [u64; 4],
}

impl Prng {
    /// A PRNG seeded deterministically from `seed`.
    pub fn new(seed: u64) -> Self {
        // splitmix64 to expand the seed into the state.
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Prng {
            s: [next(), next(), next(), next()],
        }
    }

    /// The next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. `n` must be > 0: a zero bound panics with a
    /// descriptive message in every build profile (the old `debug_assert`
    /// left release builds to die on an inscrutable divide-by-zero).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "Prng::below(0): sampling from an empty range");
        // Modulo bias is negligible for our n << 2^64 use cases.
        self.next_u64() % n
    }

    /// Uniform usize in `[0, n)`.
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform i64 in `[lo, hi)`.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as i64
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Bernoulli with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a random element of a slice. Panics with a descriptive message
    /// on an empty slice (rather than a bare index-out-of-bounds or, in
    /// release builds, a divide-by-zero from the modulo).
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty(), "Prng::choose: empty slice");
        &xs[self.index(xs.len())]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Prng::new(1);
        let mut b = Prng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn below_in_range() {
        let mut p = Prng::new(7);
        for _ in 0..1000 {
            assert!(p.below(10) < 10);
            let x = p.range_i64(-5, 5);
            assert!((-5..5).contains(&x));
            let f = p.f64();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut p = Prng::new(3);
        let mut v: Vec<u32> = (0..50).collect();
        p.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }

    #[test]
    #[should_panic(expected = "Prng::below(0)")]
    fn below_zero_panics_with_message() {
        Prng::new(1).below(0);
    }

    #[test]
    #[should_panic(expected = "Prng::choose: empty slice")]
    fn choose_empty_panics_with_message() {
        let xs: [u32; 0] = [];
        Prng::new(1).choose(&xs);
    }

    #[test]
    fn rough_uniformity() {
        let mut p = Prng::new(11);
        let mut counts = [0u32; 8];
        for _ in 0..8000 {
            counts[p.index(8)] += 1;
        }
        for c in counts {
            assert!((700..1300).contains(&c), "bucket count {c} out of range");
        }
    }
}
