//! Self-contained utility substrates (the offline image has no crates.io
//! access beyond `xla`/`anyhow`, so these replace the usual ecosystem picks).

pub mod bench;
pub mod json;
pub mod odometer;
pub mod prng;
pub mod table;
