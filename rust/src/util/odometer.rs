//! Mixed-radix odometer increment, shared by the inter-layer walk
//! (`model::walk`), the element-level simulator's walk, and the mapspace
//! tile-size enumeration (`mapspace::enumerate`).

/// Increment `idx` one step in lexicographic order under per-level `counts`
/// (innermost = last index, fastest). Returns the deepest level whose
/// counter advanced, or `None` when the odometer wraps past the end (all
/// counters reset to zero).
pub fn odometer_step(idx: &mut [i64], counts: &[i64]) -> Option<usize> {
    debug_assert_eq!(idx.len(), counts.len());
    let mut lvl = idx.len();
    while lvl > 0 {
        lvl -= 1;
        idx[lvl] += 1;
        if idx[lvl] < counts[lvl] {
            return Some(lvl);
        }
        idx[lvl] = 0;
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn walks_lexicographically() {
        let counts = [2, 3];
        let mut idx = vec![0i64; 2];
        let mut seen = vec![(idx.clone(), None)];
        while let Some(lvl) = odometer_step(&mut idx, &counts) {
            seen.push((idx.clone(), Some(lvl)));
        }
        assert_eq!(idx, vec![0, 0], "wraps back to zero");
        assert_eq!(seen.len(), 6);
        assert_eq!(seen[1], (vec![0, 1], Some(1)));
        assert_eq!(seen[3], (vec![1, 0], Some(0)));
        assert_eq!(seen[5], (vec![1, 2], Some(1)));
    }

    #[test]
    fn empty_odometer_wraps_immediately() {
        let mut idx: Vec<i64> = vec![];
        assert_eq!(odometer_step(&mut idx, &[]), None);
    }
}
