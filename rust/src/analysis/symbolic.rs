//! Closed-form box calculus for the symbolic evaluation path.
//!
//! The engine's symbolic hot path (see `model::engine`) shadows the
//! reference walk with **bounded unions of axis-aligned boxes**
//! ([`BoxSet`], at most [`MAX_UNION_WIDTH`] disjoint member boxes) in place
//! of the general [`Region`](crate::poly::Region) unions: on surjective
//! producer chains whose partitions all sit on the sink's output ranks,
//! every per-tensor availability, needs, and fresh set the walk manipulates
//! stays within the width bound, so every set operation collapses to
//! O(width² · dims) interval arithmetic. This module provides the single-box
//! primitives — union, difference, intersection, overlap volume — and the
//! [`BoxSet`] union calculus built on top of them, plus the set-specialized
//! backward *needs* sweep that mirrors
//! [`window_needs`](crate::model::window_needs) on chains.
//!
//! Every helper is **exact or refuses**: when a result is not representable
//! within the width bound the helper returns `false` and the caller
//! abandons the symbolic walk for the general region path, so closed-form
//! evaluation can never be approximate. Empty boxes are kept canonical (all
//! dims `[0, 0)`), which keeps box equality and translate comparisons
//! representation-independent.
//!
//! # Why width 2 closes over row+column tilings
//!
//! Under a single output-rank partition (PR 7's scope) every availability
//! set is one box. Partition *two* output ranks — a row+column (P×Q) tiling
//! — and the walk's availability sets become **L-shaped**: a band of fully
//! completed rows `[0, a)×[0, W)` plus the partial current row
//! `[a, b)×[c0, c)`. That is exactly two disjoint boxes, and the walk's
//! operations preserve the bound:
//!
//! * a new leaf's needs are a window box; subtracting a 2-member
//!   availability peels at most one slab per member, and the surviving
//!   fresh piece abuts the partial-row segment, so the union re-merges;
//! * when a row completes, the partial-row segment abuts the band and
//!   [`BoxSet::canonicalize`] collapses the set back to width 1;
//! * retention truncation intersects with a needs window (per-member
//!   intersection never grows the width);
//! * preimages of disjoint data boxes under the identity-per-dim output
//!   accesses are disjoint, so operation sets inherit the bound.
//!
//! Nested repartitions of the same two ranks and ragged last tiles shift
//! where the merges happen but not the shape family. Tilings of *three or
//! more* output ranks can produce genuine width-3 staircases; those refuse
//! at the width check and demote to the region walk, exactly as every
//! single-box refusal did before.
//!
//! # Canonical form
//!
//! A [`BoxSet`] keeps its members disjoint, pairwise unmergeable, and
//! sorted lexicographically by per-dim bounds. Width-2 sets are additionally
//! re-split through their bounding hull: when `hull − members` is a single
//! *notch* box, the members are re-derived by slab-subtracting the notch
//! from the hull in fixed dimension order. Every L-shape and every pair of
//! parallel slabs therefore has **one** representation regardless of the
//! operation order that built it, which keeps set equality and rigid
//! translate comparisons (steady-state certification) representation
//! independent. All operations are translation-equivariant, so the member
//! decomposition of a translated set is the translated decomposition.

use crate::einsum::FusionSet;
use crate::poly::{AffineMap, IBox, Interval};

/// Maximum number of member boxes a [`BoxSet`] may hold before its
/// operations refuse. Width 2 is exactly what row+column output tilings
/// need (see the module docs' closure argument).
pub(crate) const MAX_UNION_WIDTH: usize = 2;

/// Reset `b` to the canonical empty box of `nd` dims (all `[0, 0)`).
pub(crate) fn box_reset_empty(b: &mut IBox, nd: usize) {
    b.dims.clear();
    b.dims.resize(nd, Interval::empty());
}

/// `dst = src`, reusing `dst`'s storage.
pub(crate) fn box_assign(dst: &mut IBox, src: &IBox) {
    dst.dims.clear();
    dst.dims.extend_from_slice(&src.dims);
}

/// `a ∪= b`, provided the union is exactly one box. Returns `false` (with
/// `a` unchanged) when it is not. The union is a box iff one operand
/// contains the other, or they differ in exactly one dim where the two
/// intervals overlap or abut.
pub(crate) fn box_union_assign(a: &mut IBox, b: &IBox) -> bool {
    if b.is_empty() {
        return true;
    }
    if a.is_empty() {
        box_reset_empty(a, b.ndim());
        a.dims.copy_from_slice(&b.dims);
        return true;
    }
    debug_assert_eq!(a.ndim(), b.ndim());
    if a.contains_box(b) {
        return true;
    }
    if b.contains_box(a) {
        a.dims.copy_from_slice(&b.dims);
        return true;
    }
    let mut diff_dim = None;
    for (d, (ia, ib)) in a.dims.iter().zip(&b.dims).enumerate() {
        if ia != ib {
            if diff_dim.is_some() {
                return false;
            }
            diff_dim = Some(d);
        }
    }
    // Neither contains the other, so exactly one dim differs; the union of
    // the two intervals there must itself be an interval (overlap or touch).
    let d = diff_dim.expect("containment handled above");
    let (ia, ib) = (a.dims[d], b.dims[d]);
    if ia.lo > ib.hi || ib.lo > ia.hi {
        return false;
    }
    a.dims[d] = ia.hull(&ib);
    true
}

/// `out = a − b`, provided the difference is exactly one box (possibly
/// empty). Returns `false` (with `out` unspecified) when the difference
/// needs more than one box: `a ∩ b` shrinks `a` in two or more dims, or
/// cuts an interior band out of one dim.
pub(crate) fn box_minus_into(a: &IBox, b: &IBox, out: &mut IBox) -> bool {
    let nd = a.ndim();
    if a.is_empty() {
        box_reset_empty(out, nd);
        return true;
    }
    if b.is_empty() || !a.overlaps(b) {
        box_reset_empty(out, nd);
        out.dims.copy_from_slice(&a.dims);
        return true;
    }
    if b.contains_box(a) {
        box_reset_empty(out, nd);
        return true;
    }
    // The intersection is nonempty and proper: the difference is one box
    // iff the intersection spans `a` fully in all but one dim, and in that
    // dim reaches one end of `a` (a one-sided remainder).
    let mut cut = None;
    for (d, (ia, ib)) in a.dims.iter().zip(&b.dims).enumerate() {
        let iv = ia.intersect(ib);
        if iv == *ia {
            continue;
        }
        if cut.is_some() {
            return false;
        }
        cut = Some((d, iv));
    }
    let (d, iv) = cut.expect("proper intersection differs somewhere");
    let ia = a.dims[d];
    let rest = if iv.lo == ia.lo {
        Interval::new(iv.hi, ia.hi)
    } else if iv.hi == ia.hi {
        Interval::new(ia.lo, iv.lo)
    } else {
        return false; // interior band: two-sided remainder
    };
    box_reset_empty(out, nd);
    out.dims.copy_from_slice(&a.dims);
    out.dims[d] = rest;
    true
}

/// `a ∩= b`, canonicalizing an empty result. Intersections of boxes are
/// always boxes, so this never refuses.
pub(crate) fn box_intersect_assign(a: &mut IBox, b: &IBox) {
    if a.is_empty() {
        return;
    }
    debug_assert_eq!(a.ndim(), b.ndim());
    for (ia, ib) in a.dims.iter_mut().zip(&b.dims) {
        *ia = ia.intersect(ib);
    }
    if a.is_empty() {
        let nd = a.ndim();
        box_reset_empty(a, nd);
    }
}

/// `|a ∩ b|` without materializing the intersection.
pub(crate) fn box_overlap_volume(a: &IBox, b: &IBox) -> i64 {
    if a.is_empty() || b.is_empty() {
        return 0;
    }
    debug_assert_eq!(a.ndim(), b.ndim());
    let mut v = 1i64;
    for (ia, ib) in a.dims.iter().zip(&b.dims) {
        let w = ia.hi.min(ib.hi) - ia.lo.max(ib.lo);
        if w <= 0 {
            return 0;
        }
        v *= w;
    }
    v
}

// --------------------------------------------------------------- BoxSet ----

/// Reusable scratch buffers for [`BoxSet`] operations. Owned by the caller
/// (the engine keeps one in its `EvalScratch`) so set operations perform at
/// most transient piece-list allocations after warm-up.
#[derive(Debug, Clone, Default)]
pub(crate) struct SetScratch {
    /// Piece list of the current slab subtraction.
    p1: Vec<IBox>,
    /// Second piece list (subtracting the second member).
    p2: Vec<IBox>,
    /// Bounding hull of a width-2 set mid-canonicalization.
    hull: IBox,
    /// Intermediate of the hull-notch computation.
    t1: IBox,
    /// The notch box (`hull − members`) of the canonical resplit.
    notch: IBox,
}

/// A bounded union of at most [`MAX_UNION_WIDTH`] **disjoint** axis-aligned
/// boxes, kept in the canonical form described in the module docs: empty
/// members dropped, mergeable pairs merged, width-2 sets re-split through
/// their hull notch, members sorted lexicographically. Every mutating
/// operation is *exact or refuses*: a `bool` return of `false` means the
/// exact result needs more than [`MAX_UNION_WIDTH`] members (the value is
/// then unspecified and the caller must abandon the symbolic walk, which
/// re-prepares all scratch state anyway).
///
/// Refusals are sufficient, not necessary: a pathological piece order can
/// refuse a set that a smarter decomposition would fit. That costs a tier
/// demotion, never exactness — and the shapes the walk actually produces
/// under row+column tilings (L-shapes, bands, split pairs) are covered by
/// the canonical form.
#[derive(Debug, Clone, Default)]
pub(crate) struct BoxSet {
    /// Member storage; only `mem[..len]` is live (dead slots keep their
    /// allocations for reuse).
    mem: [IBox; 2],
    len: usize,
    ndim: usize,
}

impl PartialEq for BoxSet {
    fn eq(&self, other: &Self) -> bool {
        self.ndim == other.ndim && self.members() == other.members()
    }
}

impl Eq for BoxSet {}

/// Strict lexicographic member order by per-dim `(lo, hi)`.
fn box_lex_gt(a: &IBox, b: &IBox) -> bool {
    for (ia, ib) in a.dims.iter().zip(&b.dims) {
        if ia.lo != ib.lo {
            return ia.lo > ib.lo;
        }
        if ia.hi != ib.hi {
            return ia.hi > ib.hi;
        }
    }
    false
}

impl BoxSet {
    /// Reset to the empty set of `nd` dims.
    pub(crate) fn reset_empty(&mut self, nd: usize) {
        self.len = 0;
        self.ndim = nd;
    }

    /// Dimensionality.
    pub(crate) fn ndim(&self) -> usize {
        self.ndim
    }

    /// Live member count (0 when empty).
    pub(crate) fn width(&self) -> usize {
        self.len
    }

    /// Whether the set has no points.
    pub(crate) fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The live members (disjoint, canonically ordered).
    pub(crate) fn members(&self) -> &[IBox] {
        &self.mem[..self.len]
    }

    /// Exact point count (members are disjoint, so volumes add).
    pub(crate) fn volume(&self) -> i64 {
        self.members().iter().map(|b| b.volume()).sum()
    }

    /// `self = src`, reusing member storage.
    pub(crate) fn assign(&mut self, src: &BoxSet) {
        self.ndim = src.ndim;
        self.len = src.len;
        for i in 0..src.len {
            box_assign(&mut self.mem[i], &src.mem[i]);
        }
    }

    /// `self = {b}` (or the empty set when `b` is empty).
    pub(crate) fn assign_box(&mut self, b: &IBox) {
        self.ndim = b.ndim();
        if b.is_empty() {
            self.len = 0;
        } else {
            self.len = 1;
            box_assign(&mut self.mem[0], b);
        }
    }

    /// Append a box known to be **disjoint** from every member, merging it
    /// into a member when the union is a single box. Returns `false` when
    /// the set is full and no merge applies. Does not canonicalize.
    fn push_merge(&mut self, b: &IBox) -> bool {
        if b.is_empty() {
            return true;
        }
        if self.len == 0 {
            self.len = 1;
            box_assign(&mut self.mem[0], b);
            return true;
        }
        if box_union_assign(&mut self.mem[0], b) {
            self.merge_pair();
            return true;
        }
        if self.len == 1 {
            self.len = 2;
            box_assign(&mut self.mem[1], b);
            return true;
        }
        if box_union_assign(&mut self.mem[1], b) {
            self.merge_pair();
            return true;
        }
        false
    }

    /// Collapse the two members into one when their union is a single box
    /// (cascade step after a member absorbed new data).
    fn merge_pair(&mut self) {
        if self.len == 2 {
            let (a, b) = self.mem.split_at_mut(1);
            if box_union_assign(&mut a[0], &b[0]) {
                self.len = 1;
            }
        }
    }

    /// Restore canonical form: drop empties, merge mergeable pairs, re-split
    /// width-2 sets through the hull notch, sort members. See module docs.
    fn canonicalize(&mut self, sc: &mut SetScratch) {
        if self.len == 2 && self.mem[1].is_empty() {
            self.len = 1;
        }
        if self.len == 2 && self.mem[0].is_empty() {
            self.mem.swap(0, 1);
            self.len = 1;
        }
        if self.len == 1 && self.mem[0].is_empty() {
            self.len = 0;
        }
        if self.len < 2 {
            return;
        }
        {
            let (a, b) = self.mem.split_at_mut(1);
            if box_union_assign(&mut a[0], &b[0]) {
                self.len = 1;
                return;
            }
        }
        // Canonical resplit: when `hull − m0 − m1` is one notch box, the set
        // is an L (or a hull-tiling pair, notch empty) and slab-subtracting
        // the notch from the hull in fixed dimension order yields the unique
        // canonical 2-decomposition, independent of how the set was built.
        box_assign(&mut sc.hull, &self.mem[0]);
        sc.hull.hull_assign(&self.mem[1]);
        let mut found = box_minus_into(&sc.hull, &self.mem[0], &mut sc.t1)
            && box_minus_into(&sc.t1, &self.mem[1], &mut sc.notch);
        if !found {
            found = box_minus_into(&sc.hull, &self.mem[1], &mut sc.t1)
                && box_minus_into(&sc.t1, &self.mem[0], &mut sc.notch);
        }
        if found && !sc.notch.is_empty() {
            sc.p1.clear();
            sc.hull.subtract_into(&sc.notch, &mut sc.p1);
            if sc.p1.len() == 2 {
                box_assign(&mut self.mem[0], &sc.p1[0]);
                box_assign(&mut self.mem[1], &sc.p1[1]);
            }
        }
        if box_lex_gt(&self.mem[0], &self.mem[1]) {
            self.mem.swap(0, 1);
        }
    }

    /// `self ∪= b` (any box, overlap allowed). Exact; refuses when the
    /// result needs more than [`MAX_UNION_WIDTH`] members.
    pub(crate) fn union_box_assign(&mut self, b: &IBox, sc: &mut SetScratch) -> bool {
        if b.is_empty() {
            return true;
        }
        debug_assert_eq!(b.ndim(), self.ndim);
        if self.len == 0 {
            self.len = 1;
            box_assign(&mut self.mem[0], b);
            return true;
        }
        // Direct merge first (covers containment either way and single-dim
        // extension) so a covering box replaces a member instead of being
        // fragmented against it.
        let mut merged = box_union_assign(&mut self.mem[0], b);
        if !merged && self.len == 2 {
            merged = box_union_assign(&mut self.mem[1], b);
        }
        if merged {
            self.merge_pair();
            self.canonicalize(sc);
            return true;
        }
        // General path: disjointify (pieces = b − members), then absorb.
        let two = self.len == 2;
        sc.p1.clear();
        b.subtract_into(&self.mem[0], &mut sc.p1);
        if two {
            sc.p2.clear();
            for p in &sc.p1 {
                p.subtract_into(&self.mem[1], &mut sc.p2);
            }
        }
        let pieces = if two { &sc.p2 } else { &sc.p1 };
        for p in pieces {
            if !self.push_merge(p) {
                return false;
            }
        }
        self.canonicalize(sc);
        true
    }

    /// `self ∪= other`. Exact or refuses.
    pub(crate) fn union_set_assign(&mut self, other: &BoxSet, sc: &mut SetScratch) -> bool {
        for i in 0..other.len {
            if !self.union_box_assign(&other.mem[i], sc) {
                return false;
            }
        }
        true
    }

    /// `self −= b`. Exact or refuses.
    pub(crate) fn minus_box_assign(&mut self, b: &IBox, sc: &mut SetScratch) -> bool {
        if self.len == 0 || b.is_empty() {
            return true;
        }
        sc.p1.clear();
        for m in self.members() {
            m.subtract_into(b, &mut sc.p1);
        }
        self.len = 0;
        for i in 0..sc.p1.len() {
            if !self.push_merge(&sc.p1[i]) {
                return false;
            }
        }
        self.canonicalize(sc);
        true
    }

    /// `self −= other`. Exact or refuses.
    pub(crate) fn minus_set_assign(&mut self, other: &BoxSet, sc: &mut SetScratch) -> bool {
        for i in 0..other.len {
            if !self.minus_box_assign(&other.mem[i], sc) {
                return false;
            }
        }
        true
    }

    /// `self ∩= b`. Never refuses: per-member intersection cannot grow the
    /// width (it may shrink it, so the set is re-canonicalized).
    pub(crate) fn intersect_box_assign(&mut self, b: &IBox, sc: &mut SetScratch) {
        for i in 0..self.len {
            box_intersect_assign(&mut self.mem[i], b);
        }
        self.canonicalize(sc);
    }

    /// `self ∩= other`. Exact or refuses (two width-2 sets intersect into up
    /// to four disjoint boxes).
    pub(crate) fn intersect_set_assign(&mut self, other: &BoxSet, sc: &mut SetScratch) -> bool {
        if self.len == 0 {
            return true;
        }
        if other.len == 0 {
            self.len = 0;
            return true;
        }
        if other.len == 1 {
            self.intersect_box_assign(&other.mem[0], sc);
            return true;
        }
        sc.p1.clear();
        for m in self.members() {
            for o in other.members() {
                let piece = m.intersect(o);
                if !piece.is_empty() {
                    sc.p1.push(piece);
                }
            }
        }
        self.len = 0;
        for i in 0..sc.p1.len() {
            if !self.push_merge(&sc.p1[i]) {
                return false;
            }
        }
        self.canonicalize(sc);
        true
    }

    /// `|self ∩ other|` without materializing the intersection. Exact
    /// because both member lists are disjoint.
    pub(crate) fn overlap_volume_set(&self, other: &BoxSet) -> i64 {
        let mut v = 0i64;
        for m in self.members() {
            for o in other.members() {
                v += box_overlap_volume(m, o);
            }
        }
        v
    }

    /// Translate every member in place. Canonical form is preserved: the
    /// member order and the hull-notch resplit are translation-equivariant.
    pub(crate) fn shift_assign(&mut self, offsets: &[i64]) {
        for i in 0..self.len {
            self.mem[i].shift_assign(offsets);
        }
    }

    /// Whether `self` is a rigid translate of `prev`, writing the per-dim
    /// offsets into `d`. Canonical form makes the member correspondence
    /// positional; two empty sets translate with offset 0.
    pub(crate) fn translate_of(&self, prev: &BoxSet, d: &mut [i64]) -> bool {
        if self.len != prev.len {
            return false;
        }
        if self.len == 0 {
            d.fill(0);
            return true;
        }
        for (dim, v) in d.iter_mut().enumerate() {
            *v = self.mem[0].dims[dim].lo - prev.mem[0].dims[dim].lo;
        }
        for i in 0..self.len {
            let (c, p) = (&self.mem[i], &prev.mem[i]);
            for dim in 0..self.ndim {
                if c.dims[dim].lo - p.dims[dim].lo != d[dim]
                    || c.dims[dim].hi - p.dims[dim].hi != d[dim]
                {
                    return false;
                }
            }
        }
        true
    }

    /// `out = map(self)`: the union of per-member images. Images of disjoint
    /// boxes may overlap, so this goes through the refusing union.
    pub(crate) fn image_into(
        &self,
        map: &AffineMap,
        out: &mut BoxSet,
        tmp: &mut IBox,
        sc: &mut SetScratch,
    ) -> bool {
        out.reset_empty(map.out_ndim());
        for m in self.members() {
            map.image_box_into(m, tmp);
            if !out.union_box_assign(tmp, sc) {
                return false;
            }
        }
        true
    }

    /// `out = map⁻¹(self)` for an identity-per-dim output access. Preimages
    /// of disjoint data boxes are disjoint (each pair of disjoint data boxes
    /// separates along some data dim, whose identity-mapped iteration dim
    /// separates the preimages), so the width bound is inherited and this
    /// never refuses.
    pub(crate) fn preimage_identity_into(
        &self,
        map: &AffineMap,
        full_domain: &IBox,
        out: &mut BoxSet,
        tmp: &mut IBox,
        sc: &mut SetScratch,
    ) {
        out.reset_empty(full_domain.ndim());
        for m in self.members() {
            map.preimage_identity_box_into(m, full_domain, tmp);
            let _fit = out.push_merge(tmp);
            debug_assert!(_fit, "disjoint preimages exceed the width bound");
        }
        out.canonicalize(sc);
    }
}

/// Union-set full-needs sweep: the per-tensor data needs of the sink window
/// `last_ops`, ignoring availability — the closed-form counterpart of
/// [`window_needs`](crate::model::window_needs), restricted to results
/// representable within [`MAX_UNION_WIDTH`] boxes per tensor.
///
/// On a surjective chain every tensor has a single consumer layer and the
/// identity output access round-trips each request exactly
/// (`image(preimage(fr)) = fr`), so the sweep stays single-box per tensor;
/// the union width additionally covers bounded fan-outs (a tensor whose
/// consumers' needs union to at most two boxes). The `false` return covers
/// everything else and sends the caller to the region sweep. On success
/// `data[x]` is tensor `x`'s needs set and the volumes agree with the
/// region sweep exactly.
pub(crate) fn set_needs_into(
    fs: &FusionSet,
    last_ops: &IBox,
    domains: &[IBox],
    data: &mut Vec<BoxSet>,
    ops_tmp: &mut BoxSet,
    img_tmp: &mut IBox,
    sc: &mut SetScratch,
) -> bool {
    let n = fs.num_layers();
    data.resize_with(fs.tensors.len(), BoxSet::default);
    for (x, tn) in fs.tensors.iter().enumerate() {
        data[x].reset_empty(tn.ndim());
    }
    for t in (0..n).rev() {
        let e = &fs.einsums[t];
        if t == n - 1 {
            ops_tmp.assign_box(last_ops);
        } else {
            // Upstream ops: preimage of what this layer's consumers (all
            // later in topological order, already swept) need of its output.
            let consumed = &data[e.output.tensor.0];
            consumed.preimage_identity_into(&e.output.map, &domains[t], ops_tmp, img_tmp, sc);
        }
        if ops_tmp.is_empty() {
            continue;
        }
        if !image_union_into(ops_tmp, &e.output.map, &mut data[e.output.tensor.0], img_tmp, sc) {
            return false;
        }
        for acc in &e.inputs {
            if !image_union_into(ops_tmp, &acc.map, &mut data[acc.tensor.0], img_tmp, sc) {
                return false;
            }
        }
    }
    true
}

/// `dst ∪= map(ops)`, member by member. Exact or refuses.
pub(crate) fn image_union_into(
    ops: &BoxSet,
    map: &AffineMap,
    dst: &mut BoxSet,
    tmp: &mut IBox,
    sc: &mut SetScratch,
) -> bool {
    for m in ops.members() {
        map.image_box_into(m, tmp);
        if !dst.union_box_assign(tmp, sc) {
            return false;
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::einsum::workloads;
    use crate::model::window_needs;
    use crate::poly::Region;

    fn bx(bounds: &[(i64, i64)]) -> IBox {
        IBox::from_bounds(bounds)
    }

    #[test]
    fn union_handles_containment_abutment_and_refusal() {
        // Containment both ways.
        let mut a = bx(&[(0, 4), (0, 4)]);
        assert!(box_union_assign(&mut a, &bx(&[(1, 2), (1, 2)])));
        assert_eq!(a, bx(&[(0, 4), (0, 4)]));
        let mut a = bx(&[(1, 2), (1, 2)]);
        assert!(box_union_assign(&mut a, &bx(&[(0, 4), (0, 4)])));
        assert_eq!(a, bx(&[(0, 4), (0, 4)]));
        // Abutting along one dim.
        let mut a = bx(&[(0, 4), (0, 4)]);
        assert!(box_union_assign(&mut a, &bx(&[(4, 6), (0, 4)])));
        assert_eq!(a, bx(&[(0, 6), (0, 4)]));
        // Disjoint along one dim: refused, operand unchanged.
        let mut a = bx(&[(0, 4), (0, 4)]);
        assert!(!box_union_assign(&mut a, &bx(&[(5, 6), (0, 4)])));
        assert_eq!(a, bx(&[(0, 4), (0, 4)]));
        // Two differing dims (L-shape): refused.
        let mut a = bx(&[(0, 4), (0, 4)]);
        assert!(!box_union_assign(&mut a, &bx(&[(2, 6), (2, 6)])));
        // Empty operands are canonical no-ops / assignments.
        let mut a = IBox::empty(2);
        assert!(box_union_assign(&mut a, &bx(&[(1, 3), (2, 5)])));
        assert_eq!(a, bx(&[(1, 3), (2, 5)]));
        assert!(box_union_assign(&mut a, &IBox::empty(2)));
        assert_eq!(a, bx(&[(1, 3), (2, 5)]));
    }

    #[test]
    fn minus_matches_region_subtraction_where_it_accepts() {
        let cases = [
            (bx(&[(0, 8), (0, 8)]), bx(&[(0, 8), (0, 3)])),  // one-sided
            (bx(&[(0, 8), (0, 8)]), bx(&[(0, 8), (5, 12)])), // one-sided hi
            (bx(&[(0, 8), (0, 8)]), bx(&[(0, 8), (0, 8)])),  // all
            (bx(&[(0, 8), (0, 8)]), bx(&[(10, 12), (0, 8)])), // disjoint
            (bx(&[(0, 8)]), bx(&[(2, 4)])),                  // 1-D interior: refuse
            (bx(&[(0, 8), (0, 8)]), bx(&[(2, 4), (2, 4)])),  // corner: refuse
        ];
        for (a, b) in &cases {
            let mut out = IBox::empty(0);
            let mut reg = Region::from_box(a.clone());
            reg.subtract_box_assign(b);
            if box_minus_into(a, b, &mut out) {
                assert_eq!(out.volume(), reg.volume(), "{a:?} - {b:?}");
                assert!(reg.set_eq(&Region::from_box(out.clone())));
            } else {
                // Refusals must be genuine multi-box differences.
                assert!(reg.complexity() > 1, "{a:?} - {b:?} was a box");
            }
        }
    }

    #[test]
    fn overlap_volume_and_intersect_agree() {
        let a = bx(&[(0, 8), (2, 6)]);
        let b = bx(&[(4, 12), (0, 4)]);
        assert_eq!(box_overlap_volume(&a, &b), 4 * 2);
        let mut c = a.clone();
        box_intersect_assign(&mut c, &b);
        assert_eq!(c.volume(), 8);
        // Empty intersection canonicalizes.
        let mut c = a.clone();
        box_intersect_assign(&mut c, &bx(&[(20, 30), (0, 4)]));
        assert!(c.is_empty());
        assert_eq!(c, IBox::empty(2));
        assert_eq!(box_overlap_volume(&a, &bx(&[(20, 30), (0, 4)])), 0);
    }

    #[test]
    fn set_needs_match_region_needs_on_chains() {
        for fs in [
            workloads::conv_conv(14, 4),
            workloads::conv_conv_conv(12, 4),
            workloads::pwise_dwise_pwise(12, 3),
            workloads::fc_fc(24, 8),
            workloads::self_attention(1, 2, 12, 4),
        ] {
            let domains: Vec<IBox> = fs.einsums.iter().map(|e| e.domain()).collect();
            let mut win = fs.last().domain();
            // A proper sub-window along the first dim keeps halos in play.
            win.dims[0] = Interval::new(0, win.dims[0].hi.div_ceil(2).max(1));
            let mut data = Vec::new();
            let mut ops = BoxSet::default();
            let mut tmp = IBox::empty(0);
            let mut sc = SetScratch::default();
            assert!(
                set_needs_into(&fs, &win, &domains, &mut data, &mut ops, &mut tmp, &mut sc),
                "{}: set sweep refused a chain",
                fs.name
            );
            let reg = window_needs(&fs, &win);
            for (x, tn) in fs.tensors.iter().enumerate() {
                assert!(
                    reg.data[x].set_eq(&set_region(&data[x])),
                    "{} tensor {}: set {:?} != region {}",
                    fs.name,
                    tn.name,
                    data[x],
                    reg.data[x]
                );
            }
        }
    }

    // ---------------------------------------------------- BoxSet tests ----

    /// A `Region` with the same points as `s` (the oracle representation).
    fn set_region(s: &BoxSet) -> Region {
        let nd = s.ndim();
        let mut r = Region::empty(nd);
        for m in s.members() {
            r.union_box(m);
        }
        r
    }

    fn set_of(nd: usize, boxes: &[IBox], sc: &mut SetScratch) -> BoxSet {
        let mut s = BoxSet::default();
        s.reset_empty(nd);
        for b in boxes {
            assert!(s.union_box_assign(b, sc), "set_of refused {b:?}");
        }
        s
    }

    #[test]
    fn boxset_invariants_and_canonical_form() {
        let mut sc = SetScratch::default();
        // An L-shape built in either union order canonicalizes identically.
        let band = bx(&[(0, 3), (0, 8)]);
        let segment = bx(&[(3, 4), (0, 5)]);
        let a = set_of(2, &[band.clone(), segment.clone()], &mut sc);
        let b = set_of(2, &[segment, band], &mut sc);
        assert_eq!(a, b);
        assert_eq!(a.width(), 2);
        assert_eq!(a.volume(), 3 * 8 + 5);
        // The resplit is the fixed-dim-order slab decomposition of hull −
        // notch: dim 0 peels first.
        assert_eq!(a.members()[0], bx(&[(0, 3), (0, 8)]));
        assert_eq!(a.members()[1], bx(&[(3, 4), (0, 5)]));

        // Abutting members collapse back to width 1 (row completion).
        let mut l = a.clone();
        assert!(l.union_box_assign(&bx(&[(3, 4), (5, 8)]), &mut sc));
        assert_eq!(l.width(), 1);
        assert_eq!(l.members()[0], bx(&[(0, 4), (0, 8)]));

        // A box covering a member replaces it rather than fragmenting.
        let mut s = set_of(2, &[bx(&[(0, 2), (0, 2)]), bx(&[(10, 12), (0, 2)])], &mut sc);
        assert!(s.union_box_assign(&bx(&[(0, 4), (0, 4)]), &mut sc));
        assert_eq!(s.width(), 2);
        assert_eq!(s.volume(), 16 + 4);

        // Width-3 unions refuse.
        let mut s = set_of(1, &[bx(&[(0, 2)]), bx(&[(4, 6)])], &mut sc);
        assert!(!s.union_box_assign(&bx(&[(8, 10)]), &mut sc));
        // ... but a bridging box merges everything back to width 1.
        let mut s = set_of(1, &[bx(&[(0, 2)]), bx(&[(4, 6)])], &mut sc);
        assert!(s.union_box_assign(&bx(&[(2, 4)]), &mut sc));
        assert_eq!(s.width(), 1);
        assert_eq!(s.members()[0], bx(&[(0, 6)]));
    }

    #[test]
    fn boxset_ops_match_region_oracle() {
        let mut sc = SetScratch::default();
        let shapes = [
            vec![bx(&[(0, 6), (0, 6)])],
            vec![bx(&[(0, 6), (0, 2)]), bx(&[(0, 2), (2, 6)])], // L
            vec![bx(&[(0, 2), (0, 6)]), bx(&[(4, 6), (0, 6)])], // split pair
        ];
        let probes = [
            bx(&[(1, 5), (1, 5)]),
            bx(&[(0, 6), (0, 3)]),
            bx(&[(2, 4), (0, 6)]),
            bx(&[(0, 1), (0, 1)]),
        ];
        for members in &shapes {
            for probe in &probes {
                let s = set_of(2, members, &mut sc);
                let r = set_region(&s);

                // minus
                let mut sm = s.clone();
                let mut rm = r.clone();
                rm.subtract_box_assign(probe);
                if sm.minus_box_assign(probe, &mut sc) {
                    assert!(rm.set_eq(&set_region(&sm)), "minus {members:?} − {probe:?}");
                }

                // intersect (never refuses for a box operand)
                let mut si = s.clone();
                si.intersect_box_assign(probe, &mut sc);
                let ri = r.intersect_box(probe);
                assert!(ri.set_eq(&set_region(&si)), "∩ {members:?} {probe:?}");
                assert_eq!(si.volume(), ri.volume());

                // union
                let mut su = s.clone();
                let mut ru = r.clone();
                ru.union_box(probe);
                if su.union_box_assign(probe, &mut sc) {
                    assert!(ru.set_eq(&set_region(&su)), "∪ {members:?} {probe:?}");
                }

                // overlap volume via a singleton set
                let mut ps = BoxSet::default();
                ps.reset_empty(2);
                ps.assign_box(probe);
                assert_eq!(
                    s.overlap_volume_set(&ps),
                    r.intersect_box(probe).volume(),
                    "|∩| {members:?} {probe:?}"
                );
            }
        }
    }

    #[test]
    fn boxset_set_operands_and_translation() {
        let mut sc = SetScratch::default();
        let l1 = set_of(2, &[bx(&[(0, 6), (0, 2)]), bx(&[(0, 2), (2, 6)])], &mut sc);
        let l2 = set_of(2, &[bx(&[(1, 7), (0, 6)])], &mut sc);

        // set ∩ set vs oracle
        let mut si = l1.clone();
        assert!(si.intersect_set_assign(&l2, &mut sc));
        let oracle = set_region(&l1).intersect(&set_region(&l2));
        assert!(oracle.set_eq(&set_region(&si)));

        // set − set vs oracle
        let mut sm = l1.clone();
        if sm.minus_set_assign(&l2, &mut sc) {
            let oracle = set_region(&l1).subtract(&set_region(&l2));
            assert!(oracle.set_eq(&set_region(&sm)));
        }

        // overlap volume between two multi-member sets
        assert_eq!(
            l1.overlap_volume_set(&l2),
            set_region(&l1).intersect(&set_region(&l2)).volume()
        );

        // Translation: shifted sets certify with the exact offsets; mutated
        // sets do not.
        let mut shifted = l1.clone();
        shifted.shift_assign(&[3, -1]);
        let mut d = [0i64; 2];
        assert!(shifted.translate_of(&l1, &mut d));
        assert_eq!(d, [3, -1]);
        let near = set_of(2, &[bx(&[(0, 6), (0, 2)]), bx(&[(0, 2), (2, 7)])], &mut sc);
        assert!(!near.translate_of(&l1, &mut d));
        let mut empty = BoxSet::default();
        empty.reset_empty(2);
        assert!(!empty.translate_of(&l1, &mut d));
        assert!(empty.clone().translate_of(&empty, &mut d));
        assert_eq!(d, [0, 0]);
    }
}
