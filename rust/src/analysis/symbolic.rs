//! Closed-form box calculus for the symbolic evaluation path.
//!
//! The engine's symbolic hot path (see `model::engine`) shadows the
//! reference walk with *single axis-aligned boxes* in place of the general
//! [`Region`](crate::poly::Region) unions: on surjective producer chains
//! every per-tensor availability, needs, and fresh set the walk manipulates
//! is provably one box, so every set operation collapses to O(dims)
//! interval arithmetic. This module provides the box primitives — union,
//! difference, intersection, overlap volume — each reporting whether the
//! exact result is still a single box, plus the box-specialized backward
//! *needs* sweep that mirrors [`window_needs`](crate::model::window_needs)
//! on chains.
//!
//! Every helper is **exact or refuses**: when a result is not representable
//! as one box the helper returns `false` and the caller abandons the
//! symbolic walk for the general region path, so closed-form evaluation can
//! never be approximate. Empty boxes are kept canonical (all dims
//! `[0, 0)`), which keeps box equality and translate comparisons
//! representation-independent.

use crate::einsum::FusionSet;
use crate::poly::{IBox, Interval};

/// Reset `b` to the canonical empty box of `nd` dims (all `[0, 0)`).
pub(crate) fn box_reset_empty(b: &mut IBox, nd: usize) {
    b.dims.clear();
    b.dims.resize(nd, Interval::empty());
}

/// `dst = src`, reusing `dst`'s storage.
pub(crate) fn box_assign(dst: &mut IBox, src: &IBox) {
    dst.dims.clear();
    dst.dims.extend_from_slice(&src.dims);
}

/// `a ∪= b`, provided the union is exactly one box. Returns `false` (with
/// `a` unchanged) when it is not. The union is a box iff one operand
/// contains the other, or they differ in exactly one dim where the two
/// intervals overlap or abut.
pub(crate) fn box_union_assign(a: &mut IBox, b: &IBox) -> bool {
    if b.is_empty() {
        return true;
    }
    if a.is_empty() {
        box_reset_empty(a, b.ndim());
        a.dims.copy_from_slice(&b.dims);
        return true;
    }
    debug_assert_eq!(a.ndim(), b.ndim());
    if a.contains_box(b) {
        return true;
    }
    if b.contains_box(a) {
        a.dims.copy_from_slice(&b.dims);
        return true;
    }
    let mut diff_dim = None;
    for (d, (ia, ib)) in a.dims.iter().zip(&b.dims).enumerate() {
        if ia != ib {
            if diff_dim.is_some() {
                return false;
            }
            diff_dim = Some(d);
        }
    }
    // Neither contains the other, so exactly one dim differs; the union of
    // the two intervals there must itself be an interval (overlap or touch).
    let d = diff_dim.expect("containment handled above");
    let (ia, ib) = (a.dims[d], b.dims[d]);
    if ia.lo > ib.hi || ib.lo > ia.hi {
        return false;
    }
    a.dims[d] = ia.hull(&ib);
    true
}

/// `out = a − b`, provided the difference is exactly one box (possibly
/// empty). Returns `false` (with `out` unspecified) when the difference
/// needs more than one box: `a ∩ b` shrinks `a` in two or more dims, or
/// cuts an interior band out of one dim.
pub(crate) fn box_minus_into(a: &IBox, b: &IBox, out: &mut IBox) -> bool {
    let nd = a.ndim();
    if a.is_empty() {
        box_reset_empty(out, nd);
        return true;
    }
    if b.is_empty() || !a.overlaps(b) {
        box_reset_empty(out, nd);
        out.dims.copy_from_slice(&a.dims);
        return true;
    }
    if b.contains_box(a) {
        box_reset_empty(out, nd);
        return true;
    }
    // The intersection is nonempty and proper: the difference is one box
    // iff the intersection spans `a` fully in all but one dim, and in that
    // dim reaches one end of `a` (a one-sided remainder).
    let mut cut = None;
    for (d, (ia, ib)) in a.dims.iter().zip(&b.dims).enumerate() {
        let iv = ia.intersect(ib);
        if iv == *ia {
            continue;
        }
        if cut.is_some() {
            return false;
        }
        cut = Some((d, iv));
    }
    let (d, iv) = cut.expect("proper intersection differs somewhere");
    let ia = a.dims[d];
    let rest = if iv.lo == ia.lo {
        Interval::new(iv.hi, ia.hi)
    } else if iv.hi == ia.hi {
        Interval::new(ia.lo, iv.lo)
    } else {
        return false; // interior band: two-sided remainder
    };
    box_reset_empty(out, nd);
    out.dims.copy_from_slice(&a.dims);
    out.dims[d] = rest;
    true
}

/// `a ∩= b`, canonicalizing an empty result. Intersections of boxes are
/// always boxes, so this never refuses.
pub(crate) fn box_intersect_assign(a: &mut IBox, b: &IBox) {
    if a.is_empty() {
        return;
    }
    debug_assert_eq!(a.ndim(), b.ndim());
    for (ia, ib) in a.dims.iter_mut().zip(&b.dims) {
        *ia = ia.intersect(ib);
    }
    if a.is_empty() {
        let nd = a.ndim();
        box_reset_empty(a, nd);
    }
}

/// `|a ∩ b|` without materializing the intersection.
pub(crate) fn box_overlap_volume(a: &IBox, b: &IBox) -> i64 {
    if a.is_empty() || b.is_empty() {
        return 0;
    }
    debug_assert_eq!(a.ndim(), b.ndim());
    let mut v = 1i64;
    for (ia, ib) in a.dims.iter().zip(&b.dims) {
        let w = ia.hi.min(ib.hi) - ia.lo.max(ib.lo);
        if w <= 0 {
            return 0;
        }
        v *= w;
    }
    v
}

/// Box-specialized full-needs sweep: the per-tensor data needs of the sink
/// window `last_ops`, ignoring availability — the closed-form counterpart
/// of [`window_needs`](crate::model::window_needs), restricted to results
/// represented as one box per tensor.
///
/// On a surjective chain every tensor has a single consumer layer and the
/// identity output access round-trips each request exactly
/// (`image(preimage(fr)) = fr`), so the sweep provably stays single-box;
/// the `false` return covers every other topology (a tensor whose
/// consumers' needs don't union to a box) and sends the caller to the
/// region sweep. On success `data[x]` is tensor `x`'s needs box and the
/// volumes agree with the region sweep exactly.
pub(crate) fn box_needs_into(
    fs: &FusionSet,
    last_ops: &IBox,
    domains: &[IBox],
    data: &mut Vec<IBox>,
    ops_tmp: &mut IBox,
    img_tmp: &mut IBox,
) -> bool {
    let n = fs.num_layers();
    data.resize_with(fs.tensors.len(), || IBox::empty(0));
    for (x, tn) in fs.tensors.iter().enumerate() {
        box_reset_empty(&mut data[x], tn.ndim());
    }
    for t in (0..n).rev() {
        let e = &fs.einsums[t];
        if t == n - 1 {
            box_reset_empty(ops_tmp, last_ops.ndim());
            ops_tmp.dims.copy_from_slice(&last_ops.dims);
        } else {
            // Upstream ops: preimage of what this layer's consumers (all
            // later in topological order, already swept) need of its output.
            e.output
                .map
                .preimage_identity_box_into(&data[e.output.tensor.0], &domains[t], ops_tmp);
        }
        if ops_tmp.is_empty() {
            continue;
        }
        e.output.map.image_box_into(ops_tmp, img_tmp);
        if !box_union_assign(&mut data[e.output.tensor.0], img_tmp) {
            return false;
        }
        for acc in &e.inputs {
            acc.map.image_box_into(ops_tmp, img_tmp);
            if !box_union_assign(&mut data[acc.tensor.0], img_tmp) {
                return false;
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::einsum::workloads;
    use crate::model::window_needs;
    use crate::poly::Region;

    fn bx(bounds: &[(i64, i64)]) -> IBox {
        IBox::from_bounds(bounds)
    }

    #[test]
    fn union_handles_containment_abutment_and_refusal() {
        // Containment both ways.
        let mut a = bx(&[(0, 4), (0, 4)]);
        assert!(box_union_assign(&mut a, &bx(&[(1, 2), (1, 2)])));
        assert_eq!(a, bx(&[(0, 4), (0, 4)]));
        let mut a = bx(&[(1, 2), (1, 2)]);
        assert!(box_union_assign(&mut a, &bx(&[(0, 4), (0, 4)])));
        assert_eq!(a, bx(&[(0, 4), (0, 4)]));
        // Abutting along one dim.
        let mut a = bx(&[(0, 4), (0, 4)]);
        assert!(box_union_assign(&mut a, &bx(&[(4, 6), (0, 4)])));
        assert_eq!(a, bx(&[(0, 6), (0, 4)]));
        // Disjoint along one dim: refused, operand unchanged.
        let mut a = bx(&[(0, 4), (0, 4)]);
        assert!(!box_union_assign(&mut a, &bx(&[(5, 6), (0, 4)])));
        assert_eq!(a, bx(&[(0, 4), (0, 4)]));
        // Two differing dims (L-shape): refused.
        let mut a = bx(&[(0, 4), (0, 4)]);
        assert!(!box_union_assign(&mut a, &bx(&[(2, 6), (2, 6)])));
        // Empty operands are canonical no-ops / assignments.
        let mut a = IBox::empty(2);
        assert!(box_union_assign(&mut a, &bx(&[(1, 3), (2, 5)])));
        assert_eq!(a, bx(&[(1, 3), (2, 5)]));
        assert!(box_union_assign(&mut a, &IBox::empty(2)));
        assert_eq!(a, bx(&[(1, 3), (2, 5)]));
    }

    #[test]
    fn minus_matches_region_subtraction_where_it_accepts() {
        let cases = [
            (bx(&[(0, 8), (0, 8)]), bx(&[(0, 8), (0, 3)])),  // one-sided
            (bx(&[(0, 8), (0, 8)]), bx(&[(0, 8), (5, 12)])), // one-sided hi
            (bx(&[(0, 8), (0, 8)]), bx(&[(0, 8), (0, 8)])),  // all
            (bx(&[(0, 8), (0, 8)]), bx(&[(10, 12), (0, 8)])), // disjoint
            (bx(&[(0, 8)]), bx(&[(2, 4)])),                  // 1-D interior: refuse
            (bx(&[(0, 8), (0, 8)]), bx(&[(2, 4), (2, 4)])),  // corner: refuse
        ];
        for (a, b) in &cases {
            let mut out = IBox::empty(0);
            let mut reg = Region::from_box(a.clone());
            reg.subtract_box_assign(b);
            if box_minus_into(a, b, &mut out) {
                assert_eq!(out.volume(), reg.volume(), "{a:?} - {b:?}");
                assert!(reg.set_eq(&Region::from_box(out.clone())));
            } else {
                // Refusals must be genuine multi-box differences.
                assert!(reg.complexity() > 1, "{a:?} - {b:?} was a box");
            }
        }
    }

    #[test]
    fn overlap_volume_and_intersect_agree() {
        let a = bx(&[(0, 8), (2, 6)]);
        let b = bx(&[(4, 12), (0, 4)]);
        assert_eq!(box_overlap_volume(&a, &b), 4 * 2);
        let mut c = a.clone();
        box_intersect_assign(&mut c, &b);
        assert_eq!(c.volume(), 8);
        // Empty intersection canonicalizes.
        let mut c = a.clone();
        box_intersect_assign(&mut c, &bx(&[(20, 30), (0, 4)]));
        assert!(c.is_empty());
        assert_eq!(c, IBox::empty(2));
        assert_eq!(box_overlap_volume(&a, &bx(&[(20, 30), (0, 4)])), 0);
    }

    #[test]
    fn box_needs_match_region_needs_on_chains() {
        for fs in [
            workloads::conv_conv(14, 4),
            workloads::conv_conv_conv(12, 4),
            workloads::pwise_dwise_pwise(12, 3),
            workloads::fc_fc(24, 8),
            workloads::self_attention(1, 2, 12, 4),
        ] {
            let domains: Vec<IBox> = fs.einsums.iter().map(|e| e.domain()).collect();
            let mut win = fs.last().domain();
            // A proper sub-window along the first dim keeps halos in play.
            win.dims[0] = Interval::new(0, win.dims[0].hi.div_ceil(2).max(1));
            let mut data = Vec::new();
            let (mut t1, mut t2) = (IBox::empty(0), IBox::empty(0));
            assert!(
                box_needs_into(&fs, &win, &domains, &mut data, &mut t1, &mut t2),
                "{}: box sweep refused a chain",
                fs.name
            );
            let reg = window_needs(&fs, &win);
            for (x, tn) in fs.tensors.iter().enumerate() {
                assert!(
                    reg.data[x].set_eq(&Region::from_box(data[x].clone())),
                    "{} tensor {}: box {:?} != region {}",
                    fs.name,
                    tn.name,
                    data[x],
                    reg.data[x]
                );
            }
        }
    }
}
