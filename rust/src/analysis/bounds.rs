//! Closed-form lower bounds on evaluation metrics, derived from backward
//! needs sweeps — no iteration walk.
//!
//! Soundness rests on three facts about the engine:
//!
//! * At the very first leaf the availability sets start empty, so nothing
//!   is truncated and nothing has been invalidated: the engine's occupancy
//!   there is exactly the full needs of the first leaf window. The peak
//!   occupancy can only be larger.
//! * A tensor retained at level 0 is never invalidated, so its availability
//!   grows monotonically; on a surjective session every element any leaf
//!   requests eventually materializes into it, so by the last leaf such a
//!   tensor occupies its full-domain needs. (Non-surjective sessions can
//!   request elements no producer ever makes, so this bound is gated on
//!   surjectivity; output fmaps are excluded because their occupancy is the
//!   per-iteration drain tile, not their availability frontier.)
//! * Every element the walk ever *uses* is materialized at least once
//!   (a consumer's needs outside availability are requested from the
//!   producer, and availability only ever holds previously materialized
//!   data), so per-layer executed operations and per-tensor off-chip
//!   fetches are bounded below by the full-domain needs.
//!
//! The needs sweeps themselves go through the symbolic union-box calculus
//! (`super::symbolic::set_needs_into`) whenever the footprints stay
//! within the bounded union width — the same closed forms the engine's symbolic evaluation path
//! uses, so the pruner and the evaluator share one source of truth for
//! occupancy — and fall back to the exact [`window_needs`] region sweep
//! otherwise. Either way the bound is exact set algebra, never an estimate.
//!
//! These bounds power the search pruner: a mapping whose
//! [`capacity_lower_bound`] already exceeds the buffer capacity is
//! infeasible without being evaluated, and [`ObjectiveFloors`] bound the
//! score such a mapping *would* receive, so pruning provably never changes
//! a search result.

use super::symbolic::{set_needs_into, BoxSet, SetScratch};
use crate::einsum::{FusionSet, TensorId, TensorKind};
use crate::mapping::InterLayerMapping;
use crate::model::{window_needs, TileWindows};
use crate::poly::IBox;

/// Per-tensor volumes of the needs of one sink window: the union-set sweep
/// where it applies (footprints within the bounded union width — which now
/// includes multi-consumer fan-outs whose needs union to two boxes), the
/// region sweep otherwise (identical results either way).
fn needs_volumes(fs: &FusionSet, win: &IBox, domains: &[IBox], vols: &mut Vec<i64>) {
    let mut data = Vec::new();
    let mut ops = BoxSet::default();
    let mut tmp = IBox::empty(0);
    let mut sc = SetScratch::default();
    vols.clear();
    if set_needs_into(fs, win, domains, &mut data, &mut ops, &mut tmp, &mut sc) {
        vols.extend(data.iter().map(|s| s.volume()));
    } else {
        vols.extend(window_needs(fs, win).data.iter().map(|r| r.volume()));
    }
}

/// A lower bound on `occupancy_peak` for *any* parallelism, in elements:
/// the larger of the exact first-leaf occupancy and (on surjective
/// sessions) the last-leaf occupancy of level-0-retained tensors. No
/// evaluation of `mapping` can peak below this.
///
/// Computes the surjectivity check inline; evaluator sessions that already
/// know it should call [`capacity_lower_bound_given`].
pub fn capacity_lower_bound(fs: &FusionSet, mapping: &InterLayerMapping) -> i64 {
    let surjective = fs.einsums.iter().all(|e| {
        e.output.map.image_box(&e.domain()) == fs.tensor(e.output.tensor).full_box()
    });
    capacity_lower_bound_given(fs, mapping, surjective)
}

/// [`capacity_lower_bound`] with the session's surjectivity verdict already
/// known (the evaluator caches it).
pub(crate) fn capacity_lower_bound_given(
    fs: &FusionSet,
    mapping: &InterLayerMapping,
    surjective: bool,
) -> i64 {
    let tw = TileWindows::new(fs, mapping);
    let domains: Vec<IBox> = fs.einsums.iter().map(|e| e.domain()).collect();
    let mut vols = Vec::new();

    // First leaf: full needs of the first window, nothing evicted yet.
    let prefix = vec![0i64; tw.num_levels()];
    needs_volumes(fs, &tw.window(&prefix), &domains, &mut vols);
    let first_leaf: i64 = vols.iter().sum();

    // Last leaf: tensors retained at level 0 have accumulated their whole
    // full-domain needs (surjective sessions only — see module docs).
    if !surjective {
        return first_leaf;
    }
    let ret0: Vec<usize> = (0..fs.tensors.len())
        .filter(|&x| {
            fs.tensors[x].kind != TensorKind::OutputFmap
                && mapping.retention_for(TensorId(x)) == 0
        })
        .collect();
    if ret0.is_empty() {
        return first_leaf;
    }
    needs_volumes(fs, &fs.last().domain(), &domains, &mut vols);
    let last_leaf: i64 = ret0.iter().map(|&x| vols[x]).sum();
    first_leaf.max(last_leaf)
}

/// Mapping-independent floors on the evaluation metrics of a session,
/// computed once from the full-domain backward needs. Each field is a
/// provable lower bound on the corresponding metric of *every* mapping of
/// the session (any tiling, retention, or parallelism).
#[derive(Debug, Clone)]
pub struct ObjectiveFloors {
    /// Sequential compute-latency floor: `Σ_t ceil(ops_t / fanout_t)`.
    pub latency_seq: i64,
    /// Pipeline compute-latency floor: the bottleneck stage's total work,
    /// `max_t ceil(ops_t / fanout_t)`.
    pub latency_pipe: i64,
    /// Compute-energy floor in pJ: `Σ_t ops_t · op_energy_t` (transfer
    /// energy excluded — availability truncation makes per-level transfer
    /// counts mapping-dependent in both directions).
    pub energy_pj: f64,
    /// Off-chip traffic floor in elements: every *used* element of an
    /// off-chip-backed tensor crosses the boundary at least once.
    pub offchip_elems: i64,
}

/// Compute [`ObjectiveFloors`] for a session. `fanout` and `op_energy_pj`
/// are per-layer (compute fanout in ops/cycle and energy per op in pJ), as
/// cached by the evaluator.
pub fn objective_floors(
    fs: &FusionSet,
    fanout: &[i64],
    op_energy_pj: &[f64],
) -> ObjectiveFloors {
    let needs = window_needs(fs, &fs.last().domain());
    let ops: Vec<i64> = needs.ops.iter().map(|r| r.volume()).collect();
    let lat: Vec<i64> = ops
        .iter()
        .zip(fanout)
        .map(|(&o, &f)| o.div_ceil(f.max(1)))
        .collect();
    let energy_pj = ops
        .iter()
        .zip(op_energy_pj)
        .map(|(&o, &e)| o as f64 * e)
        .sum();
    let offchip_elems = fs
        .offchip_backed_tensors()
        .into_iter()
        .map(|x| needs.data[x.0].volume())
        .sum();
    ObjectiveFloors {
        latency_seq: lat.iter().sum(),
        latency_pipe: lat.iter().copied().max().unwrap_or(0),
        energy_pj,
        offchip_elems,
    }
}
