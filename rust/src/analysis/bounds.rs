//! Closed-form lower bounds on evaluation metrics, derived from one
//! backward needs sweep — no iteration walk.
//!
//! Soundness rests on two facts about the engine:
//!
//! * At the very first leaf the availability sets start empty, so nothing
//!   is truncated and nothing has been invalidated: the engine's occupancy
//!   there is exactly the full needs of the first leaf window. The peak
//!   occupancy can only be larger.
//! * Every element the walk ever *uses* is materialized at least once
//!   (a consumer's needs outside availability are requested from the
//!   producer, and availability only ever holds previously materialized
//!   data), so per-layer executed operations and per-tensor off-chip
//!   fetches are bounded below by the full-domain needs.
//!
//! These bounds power the search pruner: a mapping whose
//! [`capacity_lower_bound`] already exceeds the buffer capacity is
//! infeasible without being evaluated, and [`ObjectiveFloors`] bound the
//! score such a mapping *would* receive, so pruning provably never changes
//! a search result.

use crate::einsum::FusionSet;
use crate::mapping::InterLayerMapping;
use crate::model::{window_needs, TileWindows};

/// Exact occupancy of the first leaf of the walk — a lower bound on
/// `occupancy_peak` for *any* retention assignment and parallelism, in
/// elements. The first leaf fetches and materializes its full needs with
/// nothing evicted yet, so no evaluation of `mapping` can peak below this.
pub fn capacity_lower_bound(fs: &FusionSet, mapping: &InterLayerMapping) -> i64 {
    let tw = TileWindows::new(fs, mapping);
    let prefix = vec![0i64; tw.num_levels()];
    let needs = window_needs(fs, &tw.window(&prefix));
    needs.data.iter().map(|r| r.volume()).sum()
}

/// Mapping-independent floors on the evaluation metrics of a session,
/// computed once from the full-domain backward needs. Each field is a
/// provable lower bound on the corresponding metric of *every* mapping of
/// the session (any tiling, retention, or parallelism).
#[derive(Debug, Clone)]
pub struct ObjectiveFloors {
    /// Sequential compute-latency floor: `Σ_t ceil(ops_t / fanout_t)`.
    pub latency_seq: i64,
    /// Pipeline compute-latency floor: the bottleneck stage's total work,
    /// `max_t ceil(ops_t / fanout_t)`.
    pub latency_pipe: i64,
    /// Compute-energy floor in pJ: `Σ_t ops_t · op_energy_t` (transfer
    /// energy excluded — availability truncation makes per-level transfer
    /// counts mapping-dependent in both directions).
    pub energy_pj: f64,
    /// Off-chip traffic floor in elements: every *used* element of an
    /// off-chip-backed tensor crosses the boundary at least once.
    pub offchip_elems: i64,
}

/// Compute [`ObjectiveFloors`] for a session. `fanout` and `op_energy_pj`
/// are per-layer (compute fanout in ops/cycle and energy per op in pJ), as
/// cached by the evaluator.
pub fn objective_floors(
    fs: &FusionSet,
    fanout: &[i64],
    op_energy_pj: &[f64],
) -> ObjectiveFloors {
    let needs = window_needs(fs, &fs.last().domain());
    let ops: Vec<i64> = needs.ops.iter().map(|r| r.volume()).collect();
    let lat: Vec<i64> = ops
        .iter()
        .zip(fanout)
        .map(|(&o, &f)| o.div_ceil(f.max(1)))
        .collect();
    let energy_pj = ops
        .iter()
        .zip(op_energy_pj)
        .map(|(&o, &e)| o as f64 * e)
        .sum();
    let offchip_elems = fs
        .offchip_backed_tensors()
        .into_iter()
        .map(|x| needs.data[x.0].volume())
        .sum();
    ObjectiveFloors {
        latency_seq: lat.iter().sum(),
        latency_pipe: lat.iter().copied().max().unwrap_or(0),
        energy_pj,
        offchip_elems,
    }
}
