//! Mapping-independent symbolic structure of a fusion set: how every
//! tensor's data footprint moves when the last layer's iteration window
//! slides along one of its ranks.
//!
//! The engine's steady-state fast path needs to know, per schedule level,
//! whether two consecutive children of the inter-layer walk are exact
//! translates of each other. The empirical certification observes this by
//! evaluating two children and comparing exit states box for box. This
//! module derives the same facts *statically*, by composing the per-layer
//! affine access maps through the fusion DAG once per session:
//!
//! * **touch** — does tensor dim `o` structurally reference sink rank `d`
//!   through any access chain? (Term-level, so coefficient cancellations
//!   still count as touched.)
//! * **coeff** — the net translate coefficient of tensor dim `o` per unit
//!   step of sink rank `d`, when all consumer paths agree (`None` when two
//!   paths disagree — the union of their needs does not translate rigidly).
//!
//! Both are exact under the separable affine maps of `poly::affine`: the
//! image of a box translated by `δ·e_d` along dim `d` is the image box
//! translated by `(Σ c·opshift)·δ` per output dim, with no change of shape,
//! as long as no clipping occurs — which the session-level surjectivity
//! check rules out for interior windows.

use crate::einsum::{FusionSet, TensorId};

/// Per-session static analysis of a fusion set (built once, mapping-free).
#[derive(Debug, Clone)]
pub struct SessionStatics {
    /// Every producer's output image covers its tensor, so backward
    /// preimages never clip and translate arguments are exact.
    pub surjective: bool,
    /// Sink ranks referenced by the last layer's output access; partitions
    /// on any other rank revisit output tiles (reduction-rank partitioning).
    pub out_dims: Vec<usize>,
    /// `touch[x][d][o]`: tensor `x` dim `o` structurally references sink
    /// rank `d` through some access chain.
    touch: Vec<Vec<Vec<bool>>>,
    /// `coeff[x][d][o]`: net translate coefficient of tensor `x` dim `o`
    /// per unit step of sink rank `d`; `None` when consumer paths disagree.
    coeff: Vec<Vec<Vec<Option<i64>>>>,
}

impl SessionStatics {
    /// Compose the access maps of `fs` through its DAG, once per session.
    pub fn build(fs: &FusionSet) -> SessionStatics {
        let n = fs.num_layers();
        let sink = fs.last();
        let nd = sink.ndim();
        let nt = fs.tensors.len();

        let surjective = fs.einsums.iter().all(|e| {
            e.output.map.image_box(&e.domain()) == fs.tensor(e.output.tensor).full_box()
        });
        let out_dims = sink.output.map.referenced_dims();

        let mut touch: Vec<Vec<Vec<bool>>> = fs
            .tensors
            .iter()
            .map(|t| vec![vec![false; t.ndim()]; nd])
            .collect();
        let mut coeff: Vec<Vec<Vec<Option<i64>>>> = fs
            .tensors
            .iter()
            .map(|t| vec![vec![None; t.ndim()]; nd])
            .collect();

        // One scalar propagation per sink rank `d`, in reverse topological
        // order: every consumer of a tensor is processed before its
        // producer, so a producer's op movement is derived from the fully
        // merged movement of its output tensor.
        for d in 0..nd {
            // Per-layer, per-local-dim movement of the op window.
            let mut op_touch: Vec<Vec<bool>> =
                fs.einsums.iter().map(|e| vec![false; e.ndim()]).collect();
            let mut op_coeff: Vec<Vec<Option<i64>>> =
                fs.einsums.iter().map(|e| vec![Some(0); e.ndim()]).collect();
            op_touch[n - 1][d] = true;
            op_coeff[n - 1][d] = Some(1);

            // Per-tensor merged movement; `seen` guards first-consumer
            // initialization vs cross-consumer consistency checks.
            let mut t_touch: Vec<Vec<bool>> =
                fs.tensors.iter().map(|t| vec![false; t.ndim()]).collect();
            let mut t_coeff: Vec<Vec<Option<i64>>> =
                fs.tensors.iter().map(|t| vec![Some(0); t.ndim()]).collect();
            let mut seen = vec![false; nt];

            for t in (0..n).rev() {
                let e = &fs.einsums[t];
                if t < n - 1 {
                    // This layer's ops are preimages of what its consumers
                    // (all already processed) need of its output: the op
                    // window moves with the output data window along each
                    // identity-mapped rank; reduction ranks never move.
                    let x = e.output.tensor.0;
                    debug_assert!(seen[x], "fusion set is not in topological order");
                    for (o, expr) in e.output.map.exprs.iter().enumerate() {
                        let dim = expr.as_identity().expect("validated output access");
                        op_touch[t][dim] = t_touch[x][o];
                        op_coeff[t][dim] = t_coeff[x][o];
                    }
                }
                // Project this layer's op movement onto every tensor it
                // accesses (inputs and output; the output projection is the
                // identity round-trip of the merge above, so it can never
                // introduce an inconsistency).
                for acc in e.inputs.iter().chain(std::iter::once(&e.output)) {
                    let x = acc.tensor.0;
                    let first = !seen[x];
                    for (o, expr) in acc.map.exprs.iter().enumerate() {
                        let touched =
                            expr.terms.iter().any(|&(dim, _)| op_touch[t][dim]);
                        let c: Option<i64> = expr
                            .terms
                            .iter()
                            .try_fold(0i64, |s, &(dim, cf)| {
                                op_coeff[t][dim].map(|oc| s + cf * oc)
                            });
                        if first {
                            t_touch[x][o] = touched;
                            t_coeff[x][o] = c;
                        } else {
                            t_touch[x][o] |= touched;
                            if t_coeff[x][o] != c {
                                t_coeff[x][o] = None;
                            }
                        }
                    }
                    seen[x] = true;
                }
            }

            for x in 0..nt {
                touch[x][d].clone_from(&t_touch[x]);
                coeff[x][d].clone_from(&t_coeff[x]);
            }
        }

        SessionStatics { surjective, out_dims, touch, coeff }
    }

    /// Tensor `x`'s footprint is structurally independent of sink rank `d`:
    /// no access chain from `x` to the sink references `d` in any term, so
    /// its data needs are identical for every window position *and size*
    /// along `d`.
    pub fn independent_of(&self, x: TensorId, d: usize) -> bool {
        self.touch[x.0][d].iter().all(|&t| !t)
    }

    /// The translate coefficient of tensor `x` dim `o` per unit step of sink
    /// rank `d` (`None` when consumer paths disagree).
    pub fn coeff_of(&self, x: TensorId, d: usize, o: usize) -> Option<i64> {
        self.coeff[x.0][d][o]
    }

    /// Whether every dim of tensor `x` has a consistent translate
    /// coefficient along sink rank `d`.
    pub fn consistent_along(&self, x: TensorId, d: usize) -> bool {
        self.coeff[x.0][d].iter().all(|c| c.is_some())
    }
}
