//! Config linting: structured diagnostics over the JSON documents the CLI
//! consumes, with stable `LT0xx` codes, severities, JSON-path spans, and
//! fix-it hints. Backs the `looptree lint` subcommand.
//!
//! | code  | severity | meaning                                            |
//! |-------|----------|----------------------------------------------------|
//! | LT001 | error    | unrecognized document shape                        |
//! | LT002 | error    | a section fails to parse or validate               |
//! | LT004 | error    | mapping invalid for the workload                   |
//! | LT005 | warning  | mapping provably exceeds the GLB capacity          |
//! | LT006 | warning  | retention entry on an output tensor (dead)         |
//! | LT007 | warning  | degenerate partition (tile ≥ rank extent)          |
//! | LT008 | warning  | partition on a reduction rank of the last layer    |
//! | LT009 | warning  | zero search budget for the selected algorithm      |
//! | LT010 | error    | unknown rank name / invalid tile size in mapspace  |
//! | LT101 | error    | network edge shape mismatch / op-shape failure     |
//! | LT102 | warning  | dead node (not an ancestor of the network output)  |
//! | LT103 | error    | fixed-`cuts` segment is non-convex / multi-sink    |
//! | LT104 | error    | interior `pad`/`concat` in a fixed-`cuts` segment  |
//! | LT105 | error    | residual margin parity violation in a segment      |
//! | LT106 | warning  | fixed-`cuts` segment provably exceeds the GLB      |
//!
//! Document shapes are detected by key: `network` ⇒ network config, else
//! `search` ⇒ search config, else `workload` ⇒ analyze config. Parse
//! errors reuse the JSON paths threaded through `spec` (e.g.
//! `workload.einsums[1].inputs[0]`), so every diagnostic points at the
//! offending key. The `LT1xx` network codes live in [`super::netlint`].

use super::capacity_lower_bound;
use crate::einsum::{FusionSet, TensorKind};
use crate::mapping::InterLayerMapping;
use crate::search::{Algorithm, SearchSpec};
use crate::spec::{AnalyzeConfig, NetworkConfig, SearchConfig};
use crate::util::json::Json;
use std::collections::BTreeMap;

/// Diagnostic severity. Errors make the document unusable; warnings flag
/// configurations that are legal but almost certainly not what was meant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Severity {
    /// Suspicious but usable; `lint` exits 1.
    Warning,
    /// Unusable document; `lint` exits 2.
    Error,
}

impl Severity {
    /// Stable wire name (`"warning"` / `"error"`).
    pub fn name(&self) -> &'static str {
        match self {
            Severity::Warning => "warning",
            Severity::Error => "error",
        }
    }
}

/// One lint finding: a stable code, a severity, the JSON path of the
/// offending key, a message, and a fix-it hint.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    /// Stable `LT0xx` code (see the module table).
    pub code: &'static str,
    /// Whether the document is unusable or merely suspicious.
    pub severity: Severity,
    /// JSON path of the offending key (e.g. `mapping.partitions[1]`);
    /// empty when the finding concerns the document as a whole.
    pub path: String,
    /// What is wrong.
    pub message: String,
    /// How to fix it.
    pub hint: String,
}

impl Diagnostic {
    fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("code".to_string(), Json::Str(self.code.to_string()));
        m.insert("severity".to_string(), Json::Str(self.severity.name().to_string()));
        m.insert("path".to_string(), Json::Str(self.path.clone()));
        m.insert("message".to_string(), Json::Str(self.message.clone()));
        m.insert("hint".to_string(), Json::Str(self.hint.clone()));
        Json::Obj(m)
    }

    /// One-line human rendering: `severity LT0xx at path: message (hint)`.
    pub fn render(&self) -> String {
        let at = if self.path.is_empty() {
            String::new()
        } else {
            format!(" at `{}`", self.path)
        };
        format!("{} {}{}: {} ({})", self.severity.name(), self.code, at, self.message, self.hint)
    }
}

/// All findings for one document, in deterministic order (document order of
/// the offending keys, errors from parsing first).
#[derive(Debug, Clone, Default)]
pub struct LintReport {
    /// The findings; empty means the document is clean.
    pub diagnostics: Vec<Diagnostic>,
}

impl LintReport {
    /// Whether any finding is an [`Severity::Error`].
    pub fn has_errors(&self) -> bool {
        self.diagnostics.iter().any(|d| d.severity == Severity::Error)
    }

    /// The `looptree lint` exit-code contract: 0 clean, 1 warnings only,
    /// 2 any error.
    pub fn exit_code(&self) -> i32 {
        if self.has_errors() {
            2
        } else if self.diagnostics.is_empty() {
            0
        } else {
            1
        }
    }

    /// The `--json` rendering: `{"diagnostics": [...], "exit_code": n}`.
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert(
            "diagnostics".to_string(),
            Json::Arr(self.diagnostics.iter().map(Diagnostic::to_json).collect()),
        );
        m.insert("exit_code".to_string(), Json::Num(self.exit_code() as f64));
        Json::Obj(m)
    }
}

pub(super) fn diag(
    code: &'static str,
    severity: Severity,
    path: impl Into<String>,
    message: impl Into<String>,
    hint: impl Into<String>,
) -> Diagnostic {
    Diagnostic { code, severity, path: path.into(), message: message.into(), hint: hint.into() }
}

/// Convert a threaded parse/validation error (`"json.path: message"`) into
/// a diagnostic, recovering the path span when the prefix looks like one.
/// Errors rooted at `mapping` are the mapping-vs-workload code `LT004`.
pub(super) fn parse_diag(err: String) -> Diagnostic {
    let (path, message) = match err.split_once(": ") {
        Some((p, m)) if !p.is_empty() && !p.contains(' ') => (p.to_string(), m.to_string()),
        _ => (String::new(), err),
    };
    let code = if path == "mapping" || path.starts_with("mapping.") || path.starts_with("mapping[")
    {
        "LT004"
    } else {
        "LT002"
    };
    diag(code, Severity::Error, path, message, "fix the value at the reported path")
}

/// Lint one parsed JSON document. Never fails: unparseable sections become
/// error diagnostics.
pub fn lint_document(doc: &Json) -> LintReport {
    let mut out = Vec::new();
    if doc.get("network").is_some() {
        lint_network(doc, &mut out);
    } else if doc.get("search").is_some() {
        lint_search(doc, &mut out);
    } else if doc.get("workload").is_some() {
        lint_analyze(doc, &mut out);
    } else {
        out.push(diag(
            "LT001",
            Severity::Error,
            "",
            "document has none of the `workload`, `search`, or `network` keys",
            "add a `workload` section (analyze/search configs) or a `network` section",
        ));
    }
    LintReport { diagnostics: out }
}

fn lint_analyze(doc: &Json, out: &mut Vec<Diagnostic>) {
    let cfg = match AnalyzeConfig::from_json(doc) {
        Ok(cfg) => cfg,
        Err(e) => {
            out.push(parse_diag(e));
            return;
        }
    };
    mapping_diags(&cfg.workload, &cfg.mapping, &cfg.arch, out);
}

fn lint_search(doc: &Json, out: &mut Vec<Diagnostic>) {
    let cfg = match SearchConfig::from_json(doc) {
        Ok(cfg) => cfg,
        Err(e) => {
            out.push(parse_diag(e));
            return;
        }
    };
    budget_diags(&cfg.search, "search", out);
    mapspace_diags(&cfg.workload, &cfg.search, "search.mapspace", out);
}

fn lint_network(doc: &Json, out: &mut Vec<Diagnostic>) {
    let cfg = match NetworkConfig::from_json(doc) {
        Ok(cfg) => cfg,
        Err(e) => {
            out.push(super::netlint::classify_network_error(e));
            return;
        }
    };
    super::netlint::network_diags(&cfg.network, "network", out);
    budget_diags(&cfg.segment_search.search, "segment_search.search", out);
    if let Some(cuts) = &cfg.cuts {
        super::netlint::cuts_diags(&cfg.network, &cfg.arch, cuts, "cuts", out);
    }
}

/// LT005/LT006/LT007/LT008: semantic warnings about a validated
/// (workload, mapping, arch) triple.
fn mapping_diags(
    fs: &FusionSet,
    mapping: &InterLayerMapping,
    arch: &crate::arch::Arch,
    out: &mut Vec<Diagnostic>,
) {
    let sink = fs.last();
    let out_dims = sink.output.map.referenced_dims();
    for (i, p) in mapping.partitions.iter().enumerate() {
        let name = &sink.rank_names[p.dim];
        let extent = sink.rank_sizes[p.dim];
        if p.tile >= extent {
            out.push(diag(
                "LT007",
                Severity::Warning,
                format!("mapping.partitions[{i}]"),
                format!(
                    "partition on rank `{name}` is degenerate: tile {} >= extent {extent} \
                     (a single child, so the level adds no reuse structure)",
                    p.tile
                ),
                "use a tile smaller than the rank extent, or drop the partition",
            ));
        }
        if !out_dims.contains(&p.dim) {
            out.push(diag(
                "LT008",
                Severity::Warning,
                format!("mapping.partitions[{i}]"),
                format!(
                    "partition on `{name}`, a reduction rank of the last layer: output tiles \
                     are revisited and the steady-state fast path is disabled"
                ),
                "partition a rank referenced by the last layer's output access instead",
            ));
        }
    }
    let mut dead: Vec<usize> = mapping
        .retention
        .keys()
        .filter(|t| fs.tensors[t.0].kind == TensorKind::OutputFmap)
        .map(|t| t.0)
        .collect();
    dead.sort_unstable();
    for x in dead {
        out.push(diag(
            "LT006",
            Severity::Warning,
            "mapping.retention",
            format!(
                "retention entry on output tensor `{}` is dead: output availability is \
                 never invalidated",
                fs.tensors[x].name
            ),
            "remove the entry (it has no effect on any metric)",
        ));
    }
    if let Some(cap) = arch.glb_capacity() {
        let lb = capacity_lower_bound(fs, mapping);
        if lb.saturating_mul(arch.word_bytes) > cap {
            out.push(diag(
                "LT005",
                Severity::Warning,
                "mapping",
                format!(
                    "provably infeasible: the first tile alone needs {} bytes of the \
                     {cap}-byte GLB (closed-form lower bound; no evaluation can fit)",
                    lb.saturating_mul(arch.word_bytes)
                ),
                "shrink the partition tiles, or use an architecture with a larger GLB",
            ));
        }
    }
}

/// LT009: a budget of zero for the selected algorithm (the search runs but
/// cannot explore anything).
fn budget_diags(search: &SearchSpec, base: &str, out: &mut Vec<Diagnostic>) {
    let zero: Option<(&str, &str)> = match search.algorithm {
        Algorithm::Exhaustive if search.mapspace.max_mappings == 0 => {
            Some(("mapspace.max_mappings", "no mappings are enumerated"))
        }
        Algorithm::Random if search.samples == 0 => Some(("samples", "no samples are drawn")),
        Algorithm::Annealing if search.iters == 0 => {
            Some(("iters", "only the initial candidate is evaluated"))
        }
        Algorithm::Genetic if search.population == 0 => {
            Some(("population", "the population is empty"))
        }
        Algorithm::Genetic if search.generations == 0 => {
            Some(("generations", "no generation is ever scored"))
        }
        _ => None,
    };
    if let Some((field, effect)) = zero {
        out.push(diag(
            "LT009",
            Severity::Warning,
            format!("{base}.{field}"),
            format!(
                "zero budget for the `{}` algorithm: {effect}",
                search.algorithm.name()
            ),
            "set a positive budget, or pick an algorithm whose budget is set",
        ));
    }
}

/// LT010: mapspace constraints that would panic or dead-end enumeration —
/// unknown rank names in `schedules`, non-positive `tile_sizes`.
fn mapspace_diags(fs: &FusionSet, search: &SearchSpec, base: &str, out: &mut Vec<Diagnostic>) {
    let sink = fs.last();
    for (i, sched) in search.mapspace.schedules.iter().enumerate() {
        for (j, name) in sched.iter().enumerate() {
            if sink.rank_index(name).is_none() {
                out.push(diag(
                    "LT010",
                    Severity::Error,
                    format!("{base}.schedules[{i}][{j}]"),
                    format!(
                        "unknown rank `{name}` on the last layer (valid: {})",
                        sink.rank_names.join("|")
                    ),
                    "use one of the last layer's rank names",
                ));
            }
        }
    }
    for (i, &t) in search.mapspace.tile_sizes.iter().enumerate() {
        if t <= 0 {
            out.push(diag(
                "LT010",
                Severity::Error,
                format!("{base}.tile_sizes[{i}]"),
                format!("tile size {t} is not positive"),
                "tile sizes must be >= 1",
            ));
        }
    }
}
