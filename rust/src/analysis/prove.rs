//! Static steady-state certification of an inter-layer mapping.
//!
//! The engine's fast path skips interior children of a schedule level once
//! it can show that consecutive children's exit states are exact translates
//! of each other. [`prove_levels`] derives that verdict — and the translate
//! deltas — in closed form from [`SessionStatics`], with no iteration walk.
//!
//! A level `l` with partition `(d, tile)` and child count `c ≥ 4` is
//! certified when **every** tensor of the fusion set falls in one of three
//! classes (and the session is surjective with all partitioned ranks on the
//! sink's output access):
//!
//! * **output** — the final output tensor is never invalidated and its
//!   availability advances by one output tile per child along each
//!   identity-mapped rank (the engine's `out_exempt` rule); its delta is
//!   `tile` on the dim mapped from `d`, 0 elsewhere.
//! * **class (a)** — the tensor's footprint is structurally independent of
//!   *every* partitioned rank: its needs are the same set for every window
//!   at every level, so it is fully materialized during the first leaf and
//!   neither invalidation nor re-fetch ever changes it. Delta 0, any
//!   retention level.
//! * **class (b)** — the tensor's footprint moves along `d` with consistent
//!   translate coefficients, and its retention level is at least `l + 1`:
//!   the retained prefix window sits at or inside the level-`l` child
//!   window, so the exit state after child `i` is the needs of a retained
//!   window whose indices agree with child `i − 1`'s exit everywhere except
//!   the level-`l` index — a rigid translate by `coeff · tile`. (Retention
//!   exactly `l + 1` is the special case where that window *is* the child
//!   window; deeper retention truncates more often inside the child but
//!   leaves the steady exit-to-exit translate unchanged, because on a
//!   surjective chain corresponding interior leaves of consecutive steady
//!   children see translate-identical availability by induction from the
//!   child-entry state.)
//!
//! Any tensor outside these classes makes the level unprovable and the
//! engine falls back to the empirical two-child certification, which
//! remains the oracle in property tests. The refusal itself is a typed
//! [`ProveFail`] so diagnostics (`analyze --explain`) can say *which*
//! tensor blocked the proof without the hot path paying for a message.

use super::SessionStatics;
use crate::einsum::{FusionSet, TensorId, TensorKind};
use crate::mapping::InterLayerMapping;

/// A statically certified schedule level: per-tensor availability deltas of
/// one steady child step (indexed `[tensor][tensor dim]`).
#[derive(Debug, Clone)]
pub struct LevelProof {
    /// Exit-state translate per steady child, per tensor, per tensor dim.
    pub deltas: Vec<Vec<i64>>,
}

/// Why a level (or the whole mapping) could not be statically certified.
/// Constructing one allocates nothing; [`ProveFail::describe`] renders the
/// human-readable reason on demand.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ProveFail {
    /// A producer's output image does not cover its tensor, so backward
    /// preimages clip and translate arguments are inexact. Session-wide.
    NotSurjective,
    /// Some partitioned rank is absent from the sink's output access
    /// (reduction-rank partitioning): output tiles revisit, so the jump's
    /// output-availability advance is unsound. Mapping-wide.
    PartitionOffOutput,
    /// Fewer than 4 children: the engine never jumps (child 0, one steady
    /// representative, the jump, and the explicit last child don't fit).
    TooFewChildren,
    /// This tensor fits none of the provable classes at this level.
    Unprovable {
        /// The first tensor that blocked the proof.
        tensor: TensorId,
    },
}

impl ProveFail {
    /// Human-readable reason, resolving tensor ids against `fs`.
    pub fn describe(&self, fs: &FusionSet) -> String {
        match self {
            ProveFail::NotSurjective => {
                "session is not surjective (producer images do not cover their tensors)".into()
            }
            ProveFail::PartitionOffOutput => {
                "a partitioned rank is absent from the sink output access \
                 (reduction-rank partitioning)"
                    .into()
            }
            ProveFail::TooFewChildren => "fewer than 4 children at this level".into(),
            ProveFail::Unprovable { tensor } => format!(
                "tensor {} fits no provable class (moving footprint without \
                 matching retention)",
                fs.tensor(*tensor).name
            ),
        }
    }
}

/// The mapping-wide preconditions shared by every level proof: session
/// surjectivity and all partitioned ranks on the sink's output access.
pub fn prove_gate(
    statics: &SessionStatics,
    mapping: &InterLayerMapping,
) -> Result<(), ProveFail> {
    if !statics.surjective {
        return Err(ProveFail::NotSurjective);
    }
    // The engine's steady-state jump advances output availability by one
    // tile per child without re-checking it; that is only sound when every
    // partitioned rank appears on the sink's output access.
    if !mapping
        .partitions
        .iter()
        .all(|p| statics.out_dims.contains(&p.dim))
    {
        return Err(ProveFail::PartitionOffOutput);
    }
    Ok(())
}

/// Certify one schedule level, assuming [`prove_gate`] already passed.
/// `counts` must be `mapping.level_counts(fs)`.
pub fn prove_level(
    fs: &FusionSet,
    statics: &SessionStatics,
    mapping: &InterLayerMapping,
    counts: &[i64],
    l: usize,
) -> Result<LevelProof, ProveFail> {
    // The engine only attempts a jump with at least 4 children (child 0,
    // one certified steady child, the jump, and the explicit last child).
    if counts[l] < 4 {
        return Err(ProveFail::TooFewChildren);
    }
    let nt = fs.tensors.len();
    let sink = fs.last();
    let part = &mapping.partitions[l];
    let mut deltas: Vec<Vec<i64>> = Vec::with_capacity(nt);
    for x in 0..nt {
        let id = TensorId(x);
        let tensor = fs.tensor(id);
        let mut d = vec![0i64; tensor.ndim()];
        if tensor.kind == TensorKind::OutputFmap {
            for (o, expr) in sink.output.map.exprs.iter().enumerate() {
                if expr.as_identity() == Some(part.dim) {
                    d[o] = part.tile;
                }
            }
        } else if mapping
            .partitions
            .iter()
            .all(|p| statics.independent_of(id, p.dim))
        {
            // class (a): delta stays all-zero.
        } else if mapping.retention_for(id) >= l + 1 && statics.consistent_along(id, part.dim) {
            // class (b): rigid translate by coeff · tile per child. Any
            // retention at or inside the child window qualifies — see the
            // module docs for why deeper retention keeps the same delta.
            for (o, v) in d.iter_mut().enumerate() {
                *v = statics
                    .coeff_of(id, part.dim, o)
                    .expect("checked consistent")
                    * part.tile;
            }
        } else {
            return Err(ProveFail::Unprovable { tensor: id });
        }
        deltas.push(d);
    }
    Ok(LevelProof { deltas })
}

/// Certify each schedule level of `mapping` statically. Entry `l` is
/// `Some(proof)` when the engine may jump from child 1 to the last child of
/// level `l` using `proof.deltas`; `None` sends that level to the empirical
/// certification walk. `counts` must be `mapping.level_counts(fs)`.
pub fn prove_levels(
    fs: &FusionSet,
    statics: &SessionStatics,
    mapping: &InterLayerMapping,
    counts: &[i64],
) -> Vec<Option<LevelProof>> {
    let k = mapping.partitions.len();
    if prove_gate(statics, mapping).is_err() {
        return vec![None; k];
    }
    (0..k)
        .map(|l| prove_level(fs, statics, mapping, counts, l).ok())
        .collect()
}

/// [`prove_levels`] with the refusal reasons kept — the diagnostic twin
/// behind `analyze --explain`. Gate failures apply to every level.
pub fn prove_levels_verbose(
    fs: &FusionSet,
    statics: &SessionStatics,
    mapping: &InterLayerMapping,
    counts: &[i64],
) -> Vec<Result<LevelProof, ProveFail>> {
    let k = mapping.partitions.len();
    if let Err(e) = prove_gate(statics, mapping) {
        return (0..k).map(|_| Err(e.clone())).collect();
    }
    (0..k)
        .map(|l| prove_level(fs, statics, mapping, counts, l))
        .collect()
}
