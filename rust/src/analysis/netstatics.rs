//! Network-level static floors: closed-form, mapping-independent lower
//! bounds per candidate fused segment, computed once per distinct segment
//! shape — no mapspace search, no iteration walk.
//!
//! This is the network-scale analogue of [`super::bounds`]. Where the
//! mapping-level pruner bounds one `(FusionSet, mapping)` pair, a
//! [`SegmentFloors`] bounds *every* mapping of a candidate segment at once:
//!
//! * **Capacity floor** ([`SegmentFloors::capacity_elems`]): the backward
//!   needs of a single-element sink window at the domain's lower corner.
//!   Every mapping's first leaf window starts at that corner and contains
//!   the unit box, needs are monotone in the window, and at the first leaf
//!   nothing has been evicted yet — so the engine's occupancy there, and a
//!   fortiori its peak, is at least this volume. A segment whose capacity
//!   floor already exceeds the GLB budget is infeasible under every mapping
//!   ([`SegmentFloors::provably_infeasible`]).
//! * **Objective floors** ([`SegmentFloors::floors`]): the evaluator's
//!   cached [`ObjectiveFloors`] — full-domain latency/energy/off-chip
//!   bounds that hold for any tiling, retention, or parallelism.
//!
//! The network DPs ([`search_network`](crate::network::search_network),
//! [`search_network_pareto`](crate::network::search_network_pareto)) use
//! these to skip the mapspace search of candidates that are provably
//! infeasible, under the same lossless discipline as the mapping-level
//! pruner: a pruned candidate's score is bounded below by
//! [`SegmentFloors::floor_score`] (resp. [`SegmentFloors::floor_costs`] per
//! Pareto axis), the DP result is accepted only when it beats every pruned
//! floor, and otherwise the search falls back to evaluating everything —
//! so results are bit-identical with pruning on or off.

use super::ObjectiveFloors;
use crate::arch::Arch;
use crate::model::{window_needs, Evaluator};
use crate::network::Network;
use crate::poly::IBox;
use crate::search::{Objective, SearchSpec};

/// Closed-form lower bounds for one candidate fused segment, valid for
/// every mapping of that segment (see the module docs for the argument).
#[derive(Debug, Clone)]
pub struct SegmentFloors {
    /// Lower bound on `occupancy_peak` (elements) of any mapping: the
    /// backward needs of the unit sink window at the domain's lower corner.
    pub capacity_elems: i64,
    /// Mapping-independent metric floors of the segment's evaluator session
    /// (latency, compute energy, off-chip traffic).
    pub floors: ObjectiveFloors,
}

/// Compute [`SegmentFloors`] for the candidate segment `nodes` of `net`.
/// Errors if the node set is not fusable or the session fails validation —
/// callers pruning DP candidates should treat an error as "no floor known"
/// and keep the candidate.
pub fn segment_floors(
    net: &Network,
    arch: &Arch,
    nodes: &[usize],
) -> Result<SegmentFloors, String> {
    let fs = net.segment_fusion_set_nodes(nodes)?;
    let ev = Evaluator::new(&fs, arch)?;
    let floors = ev.floors().clone();
    let domain = fs.last().domain();
    let unit = IBox::from_bounds(
        &domain.dims.iter().map(|d| (d.lo, d.lo + 1)).collect::<Vec<_>>(),
    );
    let capacity_elems = window_needs(&fs, &unit).data.iter().map(|r| r.volume()).sum();
    Ok(SegmentFloors { capacity_elems, floors })
}

impl SegmentFloors {
    /// Whether every mapping of the segment provably exceeds the GLB
    /// capacity of `arch`: the unit-window needs alone do not fit. `false`
    /// when the architecture has no GLB capacity limit.
    pub fn provably_infeasible(&self, arch: &Arch) -> bool {
        match arch.glb_capacity() {
            Some(cap) => self.capacity_elems.saturating_mul(arch.word_bytes) > cap,
            None => false,
        }
    }

    /// The floor of one objective axis *before* any infeasibility penalty:
    /// latency uses the pipeline floor (a lower bound for either
    /// parallelism), capacity the unit-window needs.
    fn base(&self, objective: Objective) -> f64 {
        let lat = self.floors.latency_pipe as f64;
        match objective {
            Objective::Latency => lat,
            Objective::Energy => self.floors.energy_pj,
            Objective::Edp | Objective::FeasibleEdp => lat * self.floors.energy_pj,
            Objective::Capacity => self.capacity_elems as f64,
            Objective::Offchip => self.floors.offchip_elems as f64,
        }
    }

    /// A lower bound on the *score* any mapping of a provably-infeasible
    /// segment would receive under `spec` — the network-level analogue of
    /// the search pruner's score floor. Infeasible mappings are penalized by
    /// [`Objective::INFEASIBLE_PENALTY`] (always for `FeasibleEdp`, and for
    /// every other objective when `spec.penalize_infeasible` is set), so the
    /// floor carries the same factor. Only meaningful for segments where
    /// [`SegmentFloors::provably_infeasible`] holds.
    pub fn floor_score(&self, spec: &SearchSpec) -> f64 {
        let base = self.base(spec.objective);
        if spec.objective == Objective::FeasibleEdp || spec.penalize_infeasible {
            base * Objective::INFEASIBLE_PENALTY
        } else {
            base
        }
    }

    /// Per-axis lower bounds on the cost vector any mapping of a
    /// provably-infeasible segment would contribute to a Pareto front under
    /// `spec` — [`SegmentFloors::floor_score`] applied axis-wise, matching
    /// [`SearchSpec::score_objective`]'s per-axis penalty rule.
    pub fn floor_costs(&self, objectives: &[Objective], spec: &SearchSpec) -> Vec<f64> {
        objectives
            .iter()
            .map(|&o| {
                let base = self.base(o);
                if o == Objective::FeasibleEdp || spec.penalize_infeasible {
                    base * Objective::INFEASIBLE_PENALTY
                } else {
                    base
                }
            })
            .collect()
    }
}
