//! Network-config lint: the `LT1xx` diagnostics `looptree lint` emits for
//! `NetworkConfig` documents — DAG structure problems, fixed-`cuts`
//! segments that cannot fuse (with the mandatory-cut explanation), and
//! segments whose closed-form capacity floor already exceeds the GLB.
//!
//! Works from the same once-per-network symbolic facts the DPs use: the
//! reference shape propagation of [`Network::validate`], the segment
//! materialization plans of `Network::segment_plan`, and the static floors
//! of [`super::netstatics`]. See the `LT1xx` rows of the
//! [`super::lint`] module table for the code assignments.

use super::lint::{diag, parse_diag, Diagnostic, Severity};
use super::netstatics::segment_floors;
use crate::arch::Arch;
use crate::network::Network;

/// Convert a `NetworkConfig` parse/validation error into a diagnostic.
/// Edge/shape validation failures — rerooted to `network.nodes[i]` paths by
/// the spec layer and recognizable by their `layer '…' (op …)` message
/// prefix — become `LT101`; everything else keeps the generic parse code.
pub(super) fn classify_network_error(err: String) -> Diagnostic {
    let d = parse_diag(err);
    let on_node = d.path.contains(".nodes[") || d.path.contains(".layers[");
    if on_node && d.message.starts_with("layer '") {
        diag(
            "LT101",
            Severity::Error,
            d.path,
            d.message,
            "fix the node's input_shape/op/inputs so every edge's shapes agree \
             with its producers",
        )
    } else {
        d
    }
}

/// `LT102`: nodes that are not ancestors of the network output (the last
/// node). Their results are computed and paid for but never consumed
/// downstream — legal, and almost certainly a wiring mistake.
pub(super) fn network_diags(net: &Network, base: &str, out: &mut Vec<Diagnostic>) {
    let n = net.layers.len();
    if n == 0 {
        return;
    }
    let mut live = vec![false; n];
    live[n - 1] = true;
    let mut stack = vec![n - 1];
    while let Some(i) = stack.pop() {
        for &p in &net.layers[i].inputs {
            if !live[p] {
                live[p] = true;
                stack.push(p);
            }
        }
    }
    for (i, l) in net.layers.iter().enumerate() {
        if !live[i] {
            out.push(diag(
                "LT102",
                Severity::Warning,
                format!("{base}.nodes[{i}]"),
                format!(
                    "node '{}' is dead: not an ancestor of the network output '{}', so its \
                     result is computed but never consumed",
                    l.name,
                    net.layers[n - 1].name
                ),
                "remove the node, or wire it (directly or transitively) into the final \
                 node's inputs",
            ));
        }
    }
}

/// Classify a `segment_plan` error: which `LT1xx` code and fix-it hint the
/// failure maps to. Matching is on the plan's stable error phrases (pinned
/// by the lint corpus).
fn classify_plan_error(e: &str) -> (&'static str, &'static str) {
    let mandatory_cut = [
        "never joins a fused segment",
        "explicit pad inside a fused segment",
        "cannot be a segment sink",
        "only pad nodes",
    ];
    let residual = ["cannot be center-cropped", "cannot merge", "operand arity mismatch"];
    if mandatory_cut.iter().any(|m| e.contains(m)) {
        (
            "LT104",
            "concat is virtual (pure DRAM address arithmetic) and an interior pad is a \
             mandatory cut — place a cut on every edge of this node",
        )
    } else if residual.iter().any(|m| e.contains(m)) {
        (
            "LT105",
            "residual branches must shrink by even margins to center-crop; insert an \
             explicit pad on the shallower branch or cut before the add",
        )
    } else {
        (
            "LT103",
            "move the cuts so every segment is a convex node set with a single sink",
        )
    }
}

/// `LT103`/`LT104`/`LT105`/`LT106`: diagnostics over the fixed segments a
/// `cuts` list induces, mirroring `evaluate_partition`'s cut-to-segment
/// mapping exactly (contiguous ranges between cuts, virtual nodes dropped).
/// Invalid cut values stop the sweep — later segments depend on them.
pub(super) fn cuts_diags(
    net: &Network,
    arch: &Arch,
    cuts: &[usize],
    base: &str,
    out: &mut Vec<Diagnostic>,
) {
    let n = net.num_layers();
    let mut bounds = vec![0usize];
    for (j, &c) in cuts.iter().enumerate() {
        let prev = *bounds.last().unwrap();
        if c == 0 || c >= n {
            out.push(diag(
                "LT103",
                Severity::Error,
                format!("{base}[{j}]"),
                format!("cut {c} out of range (0, {n})"),
                "interior cuts must satisfy 0 < cut < the layer count",
            ));
            return;
        }
        if c <= prev {
            out.push(diag(
                "LT103",
                Severity::Error,
                format!("{base}[{j}]"),
                format!("cuts must be strictly ascending (saw {c} after {prev})"),
                "sort the cut list and drop duplicates",
            ));
            return;
        }
        bounds.push(c);
    }
    bounds.push(n);
    for (j, w) in bounds.windows(2).enumerate() {
        let nodes: Vec<usize> =
            (w[0]..w[1]).filter(|&i| !net.layers[i].op.is_virtual()).collect();
        if nodes.is_empty() {
            continue;
        }
        // The segment starting at cut j-1 is attributed to that cut; the
        // leading segment (before any cut) to the list as a whole.
        let path = if j == 0 { base.to_string() } else { format!("{base}[{}]", j - 1) };
        match net.segment_plan(&nodes) {
            Err(e) => {
                let (code, hint) = classify_plan_error(&e);
                out.push(diag(
                    code,
                    Severity::Error,
                    path,
                    format!(
                        "segment {} cannot fuse: {e}",
                        net.span_name_nodes(&nodes)
                    ),
                    hint,
                ));
            }
            Ok(_) => {
                let Ok(fl) = segment_floors(net, arch, &nodes) else {
                    continue;
                };
                if fl.provably_infeasible(arch) {
                    let cap = arch.glb_capacity().expect("infeasible implies a capacity");
                    out.push(diag(
                        "LT106",
                        Severity::Warning,
                        path,
                        format!(
                            "segment {} is provably GLB-infeasible: its first tile alone \
                             needs {} bytes of the {cap}-byte GLB (closed-form lower \
                             bound; no mapping can fit)",
                            net.span_name_nodes(&nodes),
                            fl.capacity_elems.saturating_mul(arch.word_bytes)
                        ),
                        "move a cut to shrink the segment, or use an architecture with a \
                         larger GLB",
                    ));
                }
            }
        }
    }
}
