use super::*;
use crate::arch::Arch;
use crate::einsum::{workloads, TensorId, TensorKind};
use crate::mapping::{InterLayerMapping, Parallelism, Partition};
use crate::model::Evaluator;
use crate::spec::AnalyzeConfig;
use crate::util::json::Json;

fn p2_mapping(fs: &crate::einsum::FusionSet, tile: i64) -> InterLayerMapping {
    let p2 = fs.last().rank_index("P2").unwrap();
    InterLayerMapping::tiled(vec![Partition { dim: p2, tile }], Parallelism::Sequential)
}

// ------------------------------------------------------------- statics --

#[test]
fn statics_conv_conv_structure() {
    let fs = workloads::conv_conv(14, 8);
    let st = SessionStatics::build(&fs);
    assert!(st.surjective);
    let sink = fs.last();
    let p2 = sink.rank_index("P2").unwrap();
    let c2 = sink.rank_index("C2").unwrap();
    // Output dims of the sink are exactly [M2, P2, Q2].
    assert!(st.out_dims.contains(&p2));
    assert!(!st.out_dims.contains(&c2));

    // Weights never reference spatial sink ranks: class (a) along P2.
    let (input, w1, inter, w2) =
        (TensorId(0), TensorId(1), TensorId(2), TensorId(3));
    assert_eq!(fs.tensors[1].kind, TensorKind::Weight);
    assert!(st.independent_of(w1, p2));
    assert!(st.independent_of(w2, p2));
    // But they do reference the reduction rank C2 somewhere.
    assert!(!st.independent_of(w2, c2));

    // The fmaps slide along P2 with unit coefficient on their row dim
    // ([C,H,W] for the input, [M,P,Q] for the intermediate) and zero on
    // the others — a rigid translate.
    for x in [input, inter] {
        assert!(!st.independent_of(x, p2));
        assert!(st.consistent_along(x, p2));
        assert_eq!(st.coeff_of(x, p2, 0), Some(0));
        assert_eq!(st.coeff_of(x, p2, 1), Some(1));
        assert_eq!(st.coeff_of(x, p2, 2), Some(0));
    }
}

#[test]
fn statics_hold_on_all_builtin_workloads() {
    let sets = [
        workloads::conv_conv(14, 8),
        workloads::conv_conv_conv(12, 4),
        workloads::pwise_dwise_pwise(14, 4),
        workloads::fc_fc(64, 32),
        workloads::self_attention(1, 2, 16, 8),
    ];
    for fs in &sets {
        let st = SessionStatics::build(fs);
        assert!(st.surjective, "{} should be surjective", fs.name);
        assert!(!st.out_dims.is_empty(), "{}", fs.name);
    }
}

// -------------------------------------------------------------- prover --

#[test]
fn prover_certifies_sliding_p2_tiling() {
    let fs = workloads::conv_conv(28, 8);
    let st = SessionStatics::build(&fs);
    let m = p2_mapping(&fs, 4); // 7 children, default retention 1 = l+1
    let counts = m.level_counts(&fs);
    let proofs = prove_levels(&fs, &st, &m, &counts);
    assert_eq!(proofs.len(), 1);
    let proof = proofs[0].as_ref().expect("sliding P2 tiling is provable");
    // Output, intermediate, and input all advance by one P-tile; weights
    // are stationary.
    assert_eq!(proof.deltas[0], vec![0, 4, 0]); // Fmap1 [C,H,W]
    assert_eq!(proof.deltas[1], vec![0, 0, 0, 0]); // Filter1
    assert_eq!(proof.deltas[2], vec![0, 4, 0]); // Fmap2 [M,P,Q]
    assert_eq!(proof.deltas[3], vec![0, 0, 0, 0]); // Filter2
    assert_eq!(proof.deltas[4], vec![0, 4, 0]); // Fmap3 [M,P,Q]
}

#[test]
fn prover_refuses_unprovable_levels() {
    let fs = workloads::conv_conv(28, 8);
    let st = SessionStatics::build(&fs);
    let sink = fs.last();
    let p2 = sink.rank_index("P2").unwrap();
    let c2 = sink.rank_index("C2").unwrap();

    // Reduction-rank partition: the jump would advance output availability
    // along a rank the output does not have. Whole mapping unprovable.
    let m = InterLayerMapping::tiled(
        vec![Partition { dim: c2, tile: 2 }],
        Parallelism::Sequential,
    );
    let counts = m.level_counts(&fs);
    assert!(prove_levels(&fs, &st, &m, &counts)[0].is_none());

    // Too few children for a jump: provable structure, but nothing to skip.
    let m = p2_mapping(&fs, 14); // 2 children
    let counts = m.level_counts(&fs);
    assert!(prove_levels(&fs, &st, &m, &counts)[0].is_none());

    // Retention deeper than the partition level breaks class (b): the
    // retained window is smaller than the child window, so exit states
    // are not rigid translates (recompute raggedness).
    let m = InterLayerMapping::tiled(
        vec![
            Partition { dim: p2, tile: 4 },
            Partition {
                dim: sink.rank_index("Q2").unwrap(),
                tile: 4,
            },
        ],
        Parallelism::Sequential,
    )
    .with_retention(TensorId(2), 2);
    let counts = m.level_counts(&fs);
    assert!(prove_levels(&fs, &st, &m, &counts)[0].is_none());
}

#[test]
fn proven_fast_path_matches_reference_walk() {
    let fs = workloads::conv_conv(28, 8);
    let arch = Arch::generic(100_000_000);
    let ev = Evaluator::new(&fs, &arch).unwrap();
    for tile in [2, 4, 7] {
        let m = p2_mapping(&fs, tile);
        let mut fast = ev.evaluate(&m).unwrap();
        let mut slow = ev.evaluate_reference(&m).unwrap();
        // Path attribution is diagnostic and differs by construction.
        fast.path = Default::default();
        slow.path = Default::default();
        assert_eq!(format!("{fast:?}"), format!("{slow:?}"), "tile {tile}");
    }
}

// -------------------------------------------------------------- bounds --

#[test]
fn capacity_lower_bound_is_sound_and_nontrivial() {
    let fs = workloads::conv_conv(28, 8);
    let arch = Arch::generic(100_000_000);
    let ev = Evaluator::new(&fs, &arch).unwrap();
    let sink = fs.last();
    let q2 = sink.rank_index("Q2").unwrap();
    let mappings = [
        InterLayerMapping::untiled(Parallelism::Sequential),
        p2_mapping(&fs, 4),
        p2_mapping(&fs, 4).with_retention(TensorId(2), 0),
        InterLayerMapping::tiled(
            vec![Partition { dim: q2, tile: 7 }],
            Parallelism::Pipeline,
        ),
    ];
    for m in &mappings {
        let lb = ev.capacity_lower_bound(m).unwrap();
        let metrics = ev.evaluate(m).unwrap();
        assert!(lb > 0);
        assert!(
            lb <= metrics.occupancy_peak,
            "bound {lb} exceeds peak {}",
            metrics.occupancy_peak
        );
    }
}

#[test]
fn objective_floors_are_sound() {
    let fs = workloads::conv_conv(28, 8);
    let arch = Arch::generic(100_000_000);
    let ev = Evaluator::new(&fs, &arch).unwrap();
    let fl = ev.floors();
    let seq = ev.evaluate(&p2_mapping(&fs, 4)).unwrap();
    assert!(fl.latency_seq <= seq.latency_cycles);
    assert!(fl.energy_pj <= seq.energy.total_pj());
    assert!(fl.offchip_elems <= seq.offchip_total());
    let sink = fs.last();
    let q2 = sink.rank_index("Q2").unwrap();
    let pipe = ev
        .evaluate(&InterLayerMapping::tiled(
            vec![Partition { dim: q2, tile: 4 }],
            Parallelism::Pipeline,
        ))
        .unwrap();
    assert!(fl.latency_pipe <= pipe.latency_cycles);
}

// -------------------------------------------------------------- linter --

#[test]
fn lint_rejects_unrecognized_document() {
    let report = lint_document(&Json::parse("{}").unwrap());
    assert_eq!(report.diagnostics.len(), 1);
    assert_eq!(report.diagnostics[0].code, "LT001");
    assert_eq!(report.exit_code(), 2);
}

#[test]
fn lint_accepts_clean_analyze_config() {
    let fs = workloads::conv_conv(14, 8);
    let mapping = p2_mapping(&fs, 4);
    let cfg = AnalyzeConfig {
        workload: fs,
        arch: Arch::generic(1024),
        mapping,
    };
    let report = lint_document(&cfg.to_json());
    assert!(report.diagnostics.is_empty(), "{:?}", report.diagnostics);
    assert_eq!(report.exit_code(), 0);
}

#[test]
fn lint_warns_on_semantic_smells() {
    let fs = workloads::conv_conv(14, 8);
    let sink = fs.last();
    let p2 = sink.rank_index("P2").unwrap();
    let c2 = sink.rank_index("C2").unwrap();
    let out = TensorId(4);
    let mapping = InterLayerMapping::tiled(
        vec![
            Partition { dim: p2, tile: 14 }, // LT007: tile >= extent
            Partition { dim: c2, tile: 4 },  // LT008: reduction rank
        ],
        Parallelism::Sequential,
    )
    .with_retention(out, 1); // LT006: retention on the output fmap
    let cfg = AnalyzeConfig {
        workload: fs,
        arch: Arch::generic(1), // LT005: first leaf alone overflows 1 KiB
        mapping,
    };
    let report = lint_document(&cfg.to_json());
    let codes: Vec<&str> = report.diagnostics.iter().map(|d| d.code).collect();
    assert_eq!(codes, vec!["LT007", "LT008", "LT006", "LT005"]);
    assert!(!report.has_errors());
    assert_eq!(report.exit_code(), 1);
}

#[test]
fn lint_reports_parse_errors_with_paths() {
    let doc = Json::parse(r#"{"workload": "conv_conv:bogus"}"#).unwrap();
    let report = lint_document(&doc);
    assert_eq!(report.exit_code(), 2);
    assert_eq!(report.diagnostics[0].code, "LT002");
}
