//! Static mapping analysis: closed-form affine diagnostics over the fusion
//! DAG, derived by composing per-level access maps symbolically — in
//! O(levels), with no iteration walk.
//!
//! Five consumers build on the same per-session facts
//! ([`SessionStatics`]):
//!
//! * **symbolic evaluator** (`symbolic`, consumed by `model::engine`) — the
//!   box calculus behind the engine's closed-form evaluation path: exact
//!   single-box set algebra for footprints, transfers, and occupancy on
//!   surjective chains, with a typed refusal wherever a set stops being one
//!   box so the engine can fall back without losing exactness;
//! * **prover** ([`prove_levels`], [`prove_levels_verbose`]) — certifies
//!   the engine's steady-state jump statically, replacing the empirical
//!   two-child certification where the proof succeeds (the empirical walk
//!   remains the oracle in property tests);
//! * **pruner** ([`capacity_lower_bound`], [`ObjectiveFloors`]) — lets the
//!   searches skip provably-infeasible mappings before evaluation without
//!   changing any search result;
//! * **linter** ([`lint_document`]) — the `looptree lint` subcommand:
//!   structured diagnostics with stable `LT0xx` codes, severities,
//!   JSON-path spans, and fix-it hints;
//! * **network analyzer** (`netstatics` + `netlint`) — once-per-network
//!   static facts over the DAG: [`segment_floors`] are the closed-form
//!   per-candidate capacity/score bounds behind the network DPs' lossless
//!   candidate pruning, and the `LT1xx` network diagnostics extend the
//!   linter to `NetworkConfig` documents.

mod bounds;
mod lint;
mod netlint;
mod netstatics;
mod prove;
mod statics;
pub(crate) mod symbolic;

pub(crate) use bounds::capacity_lower_bound_given;
pub use bounds::{capacity_lower_bound, objective_floors, ObjectiveFloors};
pub use lint::{lint_document, Diagnostic, LintReport, Severity};
pub use netstatics::{segment_floors, SegmentFloors};
pub use prove::{
    prove_gate, prove_level, prove_levels, prove_levels_verbose, LevelProof, ProveFail,
};
pub use statics::SessionStatics;

#[cfg(test)]
mod tests;
