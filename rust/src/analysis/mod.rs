//! Static mapping analysis: closed-form affine diagnostics over the fusion
//! DAG, derived by composing per-level access maps symbolically — in
//! O(levels), with no iteration walk.
//!
//! Three consumers build on the same per-session facts
//! ([`SessionStatics`]):
//!
//! * **prover** ([`prove_levels`]) — certifies the engine's steady-state
//!   jump statically, replacing the empirical two-child certification where
//!   the proof succeeds (the empirical walk remains the oracle in property
//!   tests);
//! * **pruner** ([`capacity_lower_bound`], [`ObjectiveFloors`]) — lets the
//!   searches skip provably-infeasible mappings before evaluation without
//!   changing any search result;
//! * **linter** ([`lint_document`]) — the `looptree lint` subcommand:
//!   structured diagnostics with stable `LT0xx` codes, severities,
//!   JSON-path spans, and fix-it hints.

mod bounds;
mod lint;
mod prove;
mod statics;

pub use bounds::{capacity_lower_bound, objective_floors, ObjectiveFloors};
pub use lint::{lint_document, Diagnostic, LintReport, Severity};
pub use prove::{prove_levels, LevelProof};
pub use statics::SessionStatics;

#[cfg(test)]
mod tests;
