//! Parallel DSE job coordination.
//!
//! The mapspace searches evaluate thousands of independent mappings; this
//! module fans them out over a worker pool (std threads + an atomic work
//! queue — the offline image has no tokio, and model evaluation is pure CPU
//! work with no I/O to overlap). The coordinator is also used by the e2e
//! example to drive batched PJRT tile execution.
//!
//! Workers collect `(index, result)` pairs locally and the pool merges them
//! by index after join — no shared lock on the result vector, so fine-grained
//! jobs (cheap model walks) do not contend on every completion.

use crate::mapping::InterLayerMapping;
use crate::model::{Evaluator, Metrics};
use std::sync::atomic::{AtomicUsize, Ordering};

/// A worker pool for embarrassingly parallel DSE jobs.
#[derive(Debug, Clone)]
pub struct Coordinator {
    workers: usize,
}

impl Coordinator {
    /// `workers = 0` ⇒ use available parallelism.
    pub fn new(workers: usize) -> Self {
        let workers = if workers == 0 {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        } else {
            workers
        };
        Coordinator { workers }
    }

    /// Number of worker threads in the pool.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Evaluate every mapping on one session; results preserve input order.
    /// Individual failures are reported per slot, not propagated.
    /// Convenience alias for [`Evaluator::evaluate_batch`] on this pool.
    pub fn evaluate_all(
        &self,
        ev: &Evaluator,
        mappings: &[InterLayerMapping],
    ) -> Vec<Result<Metrics, String>> {
        ev.evaluate_batch(mappings, self)
    }

    /// Generic indexed fan-out: run `job(i)` for `i in 0..n` on the pool.
    pub fn run<T, F>(&self, n: usize, job: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        if n == 0 {
            return Vec::new();
        }
        let next = AtomicUsize::new(0);
        let nworkers = self.workers.min(n).max(1);

        // Each worker drains the shared counter into a private vector; the
        // pairs are merged by index once every worker has joined.
        let locals: Vec<Vec<(usize, T)>> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..nworkers)
                .map(|_| {
                    scope.spawn(|| {
                        let mut out: Vec<(usize, T)> = Vec::new();
                        loop {
                            let i = next.fetch_add(1, Ordering::Relaxed);
                            if i >= n {
                                break;
                            }
                            out.push((i, job(i)));
                        }
                        out
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("worker panicked"))
                .collect()
        });

        let mut results: Vec<Option<T>> = Vec::with_capacity(n);
        results.resize_with(n, || None);
        for local in locals {
            for (i, v) in local {
                results[i] = Some(v);
            }
        }
        results
            .into_iter()
            .map(|o| o.expect("worker skipped a slot"))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Arch;
    use crate::einsum::workloads;
    use crate::mapspace::{MapSpace, MapSpaceConfig};

    #[test]
    fn parallel_matches_serial() {
        let fs = workloads::conv_conv(14, 8);
        let arch = Arch::generic(1 << 20);
        let cfg = MapSpaceConfig {
            schedules: vec![vec!["P2".into()]],
            tile_sizes: vec![2, 4],
            uniform_retention: true,
            ..Default::default()
        };
        let ms = MapSpace::enumerate(&fs, &cfg);
        let ev = Evaluator::new(&fs, &arch).unwrap();
        let par = Coordinator::new(4).evaluate_all(&ev, ms.mappings());
        let ser = Coordinator::new(1).evaluate_all(&ev, ms.mappings());
        assert_eq!(par.len(), ser.len());
        for (p, s) in par.iter().zip(&ser) {
            let (p, s) = (p.as_ref().unwrap(), s.as_ref().unwrap());
            assert_eq!(p.offchip_reads, s.offchip_reads);
            assert_eq!(p.occupancy_peak, s.occupancy_peak);
            assert_eq!(p.latency_cycles, s.latency_cycles);
        }
    }

    #[test]
    fn run_preserves_order() {
        let c = Coordinator::new(3);
        let out = c.run(100, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn merge_covers_every_slot_under_contention() {
        // Many tiny jobs over many workers: the per-worker collection path
        // must still produce exactly one result per index.
        let c = Coordinator::new(8);
        let out = c.run(10_000, |i| i);
        assert_eq!(out.len(), 10_000);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i);
        }
    }

    #[test]
    fn empty_job_list() {
        let c = Coordinator::new(2);
        let out: Vec<usize> = c.run(0, |i| i);
        assert!(out.is_empty());
    }
}
