//! Accelerator architecture specification + accelergy-lite energy backend.
//!
//! The paper's model takes "an architecture expressed as a set of buffers and
//! compute units" (§III) and uses Accelergy [42] to turn action counts into
//! energy. This module provides both: [`Arch`] describes the buffer
//! hierarchy, the compute array, and the NoC; [`energy`] estimates per-action
//! energy from component class and size, with constants documented against
//! published numbers.

pub mod energy;
mod spec;
pub mod presets;

pub use spec::{Arch, BufferLevel, ComputeSpec, NocSpec};

#[cfg(test)]
mod tests;
