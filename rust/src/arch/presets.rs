//! Architecture presets for the validation targets (paper Table V) and the
//! case studies. Parameters follow each publication's description; where the
//! publication leaves something unstated the generic Eyeriss-class defaults
//! apply and the choice is noted.

use super::{energy, Arch, BufferLevel, ComputeSpec, NocSpec};

/// Fused-layer CNN [16]: Virtex-7 FPGA accelerator, 32-bit fixed-point in
/// BRAM, ~100 MHz, modest DSP array. Separate weight / IO / tile buffers are
/// modeled as one GLB level whose per-tensor occupancy the model reports
/// individually (the paper's WBuf / IOBuf / TBuf split).
pub fn fused_cnn() -> Arch {
    let word_bits = 32;
    Arch {
        name: "fused-cnn-fpga".into(),
        levels: vec![
            BufferLevel::dram(4.0, word_bits),
            BufferLevel::sram("BRAM", 2 * 1024 * 1024, 32.0, word_bits),
        ],
        compute: ComputeSpec {
            macs: 780, // the paper's DSP-slice budget
            mac_energy_pj: energy::mac_energy_pj(word_bits),
            clock_ghz: 0.1,
        },
        noc: NocSpec { rows: 26, cols: 30, hop_energy_pj: energy::NOC_HOP_PJ_PER_WORD },
        word_bytes: 4,
    }
}

/// ISAAC [17]: ReRAM crossbar tiles; what LoopTree models is the eDRAM
/// inter-tile buffering and the column-partitioned pipeline. 16-bit data.
pub fn isaac() -> Arch {
    let word_bits = 16;
    Arch {
        name: "isaac".into(),
        levels: vec![
            BufferLevel::dram(8.0, word_bits),
            BufferLevel::sram("eDRAM", 64 * 1024, 64.0, word_bits),
        ],
        compute: ComputeSpec {
            macs: 1024, // crossbar-equivalent MACs per tile group
            mac_energy_pj: 0.3, // in-situ analog MAC is cheap
            clock_ghz: 1.2,
        },
        noc: NocSpec { rows: 12, cols: 14, hop_energy_pj: energy::NOC_HOP_PJ_PER_WORD },
        word_bytes: 2,
    }
}

/// PipeLayer [18]: ReRAM training accelerator, batch-partitioned pipeline.
pub fn pipelayer() -> Arch {
    let word_bits = 16;
    Arch {
        name: "pipelayer".into(),
        levels: vec![
            BufferLevel::dram(8.0, word_bits),
            BufferLevel::sram("Buf", 256 * 1024, 64.0, word_bits),
        ],
        compute: ComputeSpec {
            macs: 2048,
            mac_energy_pj: 0.3,
            clock_ghz: 1.0,
        },
        noc: NocSpec { rows: 16, cols: 16, hop_energy_pj: energy::NOC_HOP_PJ_PER_WORD },
        word_bytes: 2,
    }
}

/// FLAT [30]: a TPU-like systolic accelerator for attention; large on-chip
/// buffer, bf16 datapath.
pub fn flat() -> Arch {
    let word_bits = 16;
    Arch {
        name: "flat".into(),
        levels: vec![
            BufferLevel::dram(32.0, word_bits),
            BufferLevel::sram("VMEM", 16 * 1024 * 1024, 256.0, word_bits),
        ],
        compute: ComputeSpec {
            macs: 16384, // 128×128 systolic array
            mac_energy_pj: energy::mac_energy_pj(word_bits),
            clock_ghz: 0.94,
        },
        noc: NocSpec { rows: 128, cols: 128, hop_energy_pj: energy::NOC_HOP_PJ_PER_WORD },
        word_bytes: 2,
    }
}

/// DepFin [43]: 12 nm depth-first CNN processor; 1 MiB-class on-chip SRAM,
/// 8-bit datapath.
pub fn depfin() -> Arch {
    let word_bits = 8;
    Arch {
        name: "depfin".into(),
        levels: vec![
            BufferLevel::dram(16.0, word_bits),
            BufferLevel::sram("L2", 1024 * 1024, 128.0, word_bits),
        ],
        compute: ComputeSpec {
            macs: 1024,
            mac_energy_pj: energy::mac_energy_pj(word_bits),
            clock_ghz: 0.2,
        },
        noc: NocSpec { rows: 32, cols: 32, hop_energy_pj: energy::NOC_HOP_PJ_PER_WORD },
        word_bytes: 1,
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn presets_validate() {
        for a in [
            super::fused_cnn(),
            super::isaac(),
            super::pipelayer(),
            super::flat(),
            super::depfin(),
        ] {
            assert!(a.validate().is_ok(), "{} invalid", a.name);
        }
    }
}
