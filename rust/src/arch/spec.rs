//! Architecture data model: buffer hierarchy, compute array, NoC.

use super::energy;

/// One level of the buffer hierarchy. Level 0 is always off-chip (DRAM);
/// level 1 is the on-chip global buffer whose capacity the fused-layer
/// mapping trades against transfers and recomputation; further levels (PE
/// scratchpads / register files) feed the intra-layer analysis.
#[derive(Debug, Clone)]
pub struct BufferLevel {
    /// Display name (e.g. `DRAM`, `GLB`).
    pub name: String,
    /// `None` = unbounded (off-chip).
    pub capacity_bytes: Option<i64>,
    /// Sustained bandwidth toward the level below (words of `word_bytes` per
    /// cycle across the whole level).
    pub bandwidth_words_per_cycle: f64,
    /// Energy per word read / written (pJ).
    pub read_energy_pj: f64,
    /// Energy per word written (pJ).
    pub write_energy_pj: f64,
}

impl BufferLevel {
    /// A DRAM-like unbounded backing store.
    pub fn dram(bandwidth_words_per_cycle: f64, word_bits: u32) -> Self {
        BufferLevel {
            name: "DRAM".into(),
            capacity_bytes: None,
            bandwidth_words_per_cycle,
            read_energy_pj: energy::dram_access_pj(word_bits),
            write_energy_pj: energy::dram_access_pj(word_bits),
        }
    }

    /// An on-chip SRAM buffer; access energy estimated from capacity.
    pub fn sram(name: &str, capacity_bytes: i64, bandwidth_words_per_cycle: f64, word_bits: u32) -> Self {
        let e = energy::sram_access_pj(capacity_bytes, word_bits);
        BufferLevel {
            name: name.into(),
            capacity_bytes: Some(capacity_bytes),
            bandwidth_words_per_cycle,
            read_energy_pj: e,
            write_energy_pj: e * energy::SRAM_WRITE_FACTOR,
        }
    }

    /// A small register file close to the MACs.
    pub fn regfile(name: &str, capacity_bytes: i64, word_bits: u32) -> Self {
        let e = energy::regfile_access_pj(capacity_bytes, word_bits);
        BufferLevel {
            name: name.into(),
            capacity_bytes: Some(capacity_bytes),
            bandwidth_words_per_cycle: f64::INFINITY,
            read_energy_pj: e,
            write_energy_pj: e,
        }
    }
}

/// The compute array.
#[derive(Debug, Clone)]
pub struct ComputeSpec {
    /// Number of MAC units (peak ops/cycle).
    pub macs: i64,
    /// Energy per MAC (pJ); `Max`/`Elementwise` ops are scaled from this
    /// (see [`energy`]).
    pub mac_energy_pj: f64,
    /// Clock (GHz) — used only to convert cycles to wall-clock in reports.
    pub clock_ghz: f64,
}

/// Network-on-chip geometry for multicast hop counting: an `rows × cols`
/// mesh of PE groups fed from the global buffer.
#[derive(Debug, Clone)]
pub struct NocSpec {
    /// Mesh rows.
    pub rows: i64,
    /// Mesh columns.
    pub cols: i64,
    /// Energy per word per hop (pJ).
    pub hop_energy_pj: f64,
}

impl NocSpec {
    /// Average hop count from the buffer (at the mesh edge) to a PE,
    /// assuming X-Y routing: hops(r, c) = r + c + 1.
    pub fn avg_hops(&self) -> f64 {
        // Mean of (r + c + 1) over the mesh.
        (self.rows as f64 - 1.0) / 2.0 + (self.cols as f64 - 1.0) / 2.0 + 1.0
    }

    /// Hop count to multicast one word to `n` PEs (a minimal X-Y multicast
    /// tree over a contiguous block of the mesh).
    pub fn multicast_hops(&self, n: i64) -> f64 {
        if n <= 0 {
            return 0.0;
        }
        let n = n.min(self.rows * self.cols) as f64;
        let cols = self.cols as f64;
        // A contiguous block of n PEs spans ceil(n/cols) rows; the tree walks
        // each occupied row plus the column spine.
        let rows_spanned = (n / cols).ceil();
        let row_width = n.min(cols);
        rows_spanned * row_width + rows_spanned
    }

    /// Total PE count (rows x cols).
    pub fn num_pes(&self) -> i64 {
        self.rows * self.cols
    }
}

/// A complete architecture: ordered buffer levels (outermost first: DRAM at
/// index 0, GLB at 1, deeper levels after), compute, NoC, word size.
#[derive(Debug, Clone)]
pub struct Arch {
    /// Display name of the architecture.
    pub name: String,
    /// Buffer levels, outermost first (DRAM at 0, GLB at 1).
    pub levels: Vec<BufferLevel>,
    /// PE array description.
    pub compute: ComputeSpec,
    /// On-chip network geometry.
    pub noc: NocSpec,
    /// Bytes per data word.
    pub word_bytes: i64,
}

impl Arch {
    /// Index of the on-chip global buffer level.
    pub const GLB: usize = 1;

    /// The off-chip backing level (index 0).
    pub fn dram(&self) -> &BufferLevel {
        &self.levels[0]
    }

    /// The on-chip global buffer level (index [`Arch::GLB`]).
    pub fn glb(&self) -> &BufferLevel {
        &self.levels[Self::GLB]
    }

    /// On-chip capacity available to the fused-layer mapping (bytes).
    pub fn glb_capacity(&self) -> Option<i64> {
        self.glb().capacity_bytes
    }

    /// Check structural invariants of the architecture description.
    pub fn validate(&self) -> Result<(), String> {
        if self.levels.len() < 2 {
            return Err("need at least DRAM + one on-chip level".into());
        }
        if self.levels[0].capacity_bytes.is_some() {
            return Err("level 0 must be unbounded off-chip".into());
        }
        if self.compute.macs <= 0 {
            return Err("compute.macs must be positive".into());
        }
        if self.word_bytes <= 0 {
            return Err("word_bytes must be positive".into());
        }
        Ok(())
    }

    /// A generic Eyeriss-class architecture used by tests/examples:
    /// 16-bit words, 256 KiB GLB, 16×16 PE mesh, 1 GHz.
    pub fn generic(glb_kib: i64) -> Arch {
        let word_bits = 16;
        Arch {
            name: format!("generic-{glb_kib}KiB"),
            levels: vec![
                BufferLevel::dram(16.0, word_bits),
                BufferLevel::sram("GLB", glb_kib * 1024, 64.0, word_bits),
                BufferLevel::regfile("RF", 512, word_bits),
            ],
            compute: ComputeSpec {
                macs: 256,
                mac_energy_pj: energy::mac_energy_pj(word_bits),
                clock_ghz: 1.0,
            },
            noc: NocSpec {
                rows: 16,
                cols: 16,
                hop_energy_pj: energy::NOC_HOP_PJ_PER_WORD,
            },
            word_bytes: (word_bits / 8) as i64,
        }
    }

    /// Same architecture with unbounded GLB — used when searching for the
    /// *required* capacity rather than checking against a budget.
    pub fn unbounded_glb(&self) -> Arch {
        let mut a = self.clone();
        a.levels[Self::GLB].capacity_bytes = None;
        a
    }
}
