//! accelergy-lite: per-action energy estimation.
//!
//! Replaces the Accelergy [42] backend: the LoopTree model only consumes
//! pJ-per-action numbers, which we derive from component class + size with
//! scaling rules anchored to published 45 nm measurements (Horowitz,
//! ISSCC'14 "Computing's energy problem", the numbers Eyeriss [11] and the
//! Accelergy component library are calibrated against):
//!
//! * 16-bit MAC ≈ 1.0 pJ (0.4 pJ multiply + add + pipeline overhead)
//! * 8 KiB SRAM access ≈ 10 pJ/16-bit word; energy ∝ √capacity
//! * register file access ≈ 0.5–1 pJ/word
//! * DRAM ≈ 650 pJ/16-bit word (≈ 1.3 nJ per 32-bit access)
//! * NoC ≈ 0.8 pJ/word/hop (Eyeriss-class 65 nm mesh, scaled)
//!
//! Absolute joules matter less than *ratios* for the paper's case studies
//! (DRAM ≈ 650× a MAC, GLB ≈ 10–30× a register), and those ratios are
//! faithful to the sources above.

/// SRAM write energy relative to read (slightly higher drive cost).
pub const SRAM_WRITE_FACTOR: f64 = 1.1;

/// NoC hop energy per 16-bit word (pJ).
pub const NOC_HOP_PJ_PER_WORD: f64 = 0.8;

/// Reference points for the SRAM scaling rule.
const SRAM_REF_BYTES: f64 = 8.0 * 1024.0;
const SRAM_REF_PJ_16B: f64 = 10.0;

/// Energy per word access of an SRAM of `capacity_bytes`, for `word_bits`
/// wide words. Scales with √capacity (bitline/wordline length) and linearly
/// with word width.
pub fn sram_access_pj(capacity_bytes: i64, word_bits: u32) -> f64 {
    let cap = (capacity_bytes.max(64)) as f64;
    let width_scale = word_bits as f64 / 16.0;
    SRAM_REF_PJ_16B * (cap / SRAM_REF_BYTES).sqrt() * width_scale
}

/// Energy per word access of a small register file.
pub fn regfile_access_pj(capacity_bytes: i64, word_bits: u32) -> f64 {
    let width_scale = word_bits as f64 / 16.0;
    // 0.5 pJ at 64 B, mild growth with size.
    let cap = capacity_bytes.max(16) as f64;
    0.5 * (cap / 64.0).sqrt().max(1.0) * width_scale
}

/// DRAM energy per word (pJ).
pub fn dram_access_pj(word_bits: u32) -> f64 {
    // 1.3 nJ per 32-bit access (Horowitz) → 650 pJ per 16-bit word.
    650.0 * word_bits as f64 / 16.0
}

/// MAC energy (pJ) by operand width.
pub fn mac_energy_pj(word_bits: u32) -> f64 {
    match word_bits {
        8 => 0.3,
        16 => 1.0,
        32 => 3.7,
        w => 1.0 * (w as f64 / 16.0).powi(2), // multiplier area ∝ width²
    }
}

/// Relative cost of non-MAC ops (paper workloads include max-pool and
/// softmax-ish elementwise stages).
pub fn op_energy_pj(kind: crate::einsum::OpKind, mac_pj: f64) -> f64 {
    match kind {
        crate::einsum::OpKind::Mac => mac_pj,
        // A comparator is far cheaper than a multiplier.
        crate::einsum::OpKind::Max => 0.1 * mac_pj,
        crate::einsum::OpKind::Elementwise => 0.5 * mac_pj,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sram_scaling_monotone() {
        let small = sram_access_pj(8 * 1024, 16);
        let big = sram_access_pj(512 * 1024, 16);
        assert!(big > small);
        // √(64×) = 8×
        assert!((big / small - 8.0).abs() < 1e-9);
        assert!((small - 10.0).abs() < 1e-9);
    }

    #[test]
    fn dram_dominates_sram_dominates_mac() {
        let dram = dram_access_pj(16);
        let glb = sram_access_pj(256 * 1024, 16);
        let mac = mac_energy_pj(16);
        assert!(dram > 5.0 * glb, "dram {dram} vs glb {glb}");
        assert!(glb > 10.0 * mac, "glb {glb} vs mac {mac}");
    }

    #[test]
    fn width_scaling() {
        assert!(mac_energy_pj(32) > 3.0 * mac_energy_pj(16));
        assert!(sram_access_pj(8192, 32) > sram_access_pj(8192, 16));
    }
}
