use super::*;

#[test]
fn generic_arch_validates() {
    let a = Arch::generic(256);
    assert!(a.validate().is_ok());
    assert_eq!(a.glb_capacity(), Some(256 * 1024));
    assert_eq!(a.word_bytes, 2);
}

#[test]
fn unbounded_glb() {
    let a = Arch::generic(256).unbounded_glb();
    assert_eq!(a.glb_capacity(), None);
    assert!(a.validate().is_ok());
}

#[test]
fn invalid_archs_rejected() {
    let mut a = Arch::generic(256);
    a.levels[0].capacity_bytes = Some(1024);
    assert!(a.validate().is_err());

    let mut b = Arch::generic(256);
    b.compute.macs = 0;
    assert!(b.validate().is_err());

    let mut c = Arch::generic(256);
    c.levels.truncate(1);
    assert!(c.validate().is_err());
}

#[test]
fn noc_hops_monotone_in_fanout() {
    let noc = NocSpec { rows: 16, cols: 16, hop_energy_pj: 1.0 };
    let h1 = noc.multicast_hops(1);
    let h16 = noc.multicast_hops(16);
    let h256 = noc.multicast_hops(256);
    assert!(h1 < h16 && h16 < h256);
    assert!(noc.multicast_hops(0) == 0.0);
    // Saturates at the mesh size.
    assert_eq!(noc.multicast_hops(256), noc.multicast_hops(10_000));
}

#[test]
fn dram_energy_exceeds_glb() {
    let a = Arch::generic(256);
    assert!(a.dram().read_energy_pj > a.glb().read_energy_pj);
}
