//! Extended-Einsum workload IR (paper §II-B).
//!
//! DNN layers are *tensor algebra operations*: each layer is an Einsum with
//! named ranks, a dense box iteration domain, and per-tensor affine accesses
//! (`p`, `p+r`, `2p+r`, …). A [`FusionSet`] is a chain of Einsums where each
//! layer's output fmap is the next layer's input fmap (the *intermediate*
//! fmaps whose retention-recomputation the mapping controls).

mod spec;
mod builder;
pub mod workloads;

pub use builder::FusionSetBuilder;
pub use spec::{EinsumSpec, FusionSet, OpKind, TensorAccess, TensorId, TensorInfo, TensorKind};

#[cfg(test)]
mod tests;
