//! Extended-Einsum workload IR (paper §II-B).
//!
//! DNN layers are *tensor algebra operations*: each layer is an Einsum with
//! named ranks, a dense box iteration domain, and per-tensor affine accesses
//! (`p`, `p+r`, `2p+r`, …). A [`FusionSet`] is a single-sink DAG of Einsums
//! where each layer's output fmap feeds one or more later layers (the
//! *intermediate* fmaps whose retention-recomputation the mapping controls);
//! a chain is the common special case ([`FusionSet::is_chain`]).

mod spec;
mod builder;
pub mod workloads;

pub use builder::{residual_merge_shape, FusionSetBuilder};
pub use spec::{EinsumSpec, FusionSet, OpKind, TensorAccess, TensorId, TensorInfo, TensorKind};

#[cfg(test)]
mod tests;
