//! Workload library: the paper's fusion sets (Table X) and the DNNs used by
//! the validation targets (§V) and case studies (§VI).
//!
//! Shapes follow the publications: ResNet-18 [34] / MobileNetV2 [1] blocks
//! for the case studies, VGG [3] / AlexNet [4] for ISAAC and PipeLayer,
//! FSRCNN [45] / MC-CNN [44] for DepFin, and BERT-style self-attention [6]
//! for FLAT.

use super::{FusionSet, FusionSetBuilder};

/// Table X row 1: `conv+conv`, modeled after ResNet blocks.
/// `rows = P1 = Q1 = P2 = Q2`, `channels = C1 = M1 = C2 = M2`, 3×3 kernels.
pub fn conv_conv(rows: i64, channels: i64) -> FusionSet {
    FusionSetBuilder::new(
        &format!("conv+conv(r{rows},c{channels})"),
        &[channels, rows + 2, rows + 2],
    )
    .conv2d(channels, 3, 3, 1)
    .conv2d(channels, 3, 3, 1)
    .build()
}

/// Three chained 3×3 convs — used by the per-intermediate-fmap
/// retain-recompute case study (Fig 17; two intermediate fmaps).
pub fn conv_conv_conv(rows: i64, channels: i64) -> FusionSet {
    FusionSetBuilder::new(
        &format!("conv+conv+conv(r{rows},c{channels})"),
        &[channels, rows + 4, rows + 4],
    )
    .conv2d(channels, 3, 3, 1)
    .conv2d(channels, 3, 3, 1)
    .conv2d(channels, 3, 3, 1)
    .build()
}

/// Table X row 2: `pwise+dwise+pwise`, a MobileNetV2 inverted-residual block
/// with expansion factor 6: `C1 = M3`, `M1 = M2 = C3 = 6·C1`, 3×3 depthwise.
pub fn pwise_dwise_pwise(rows: i64, c1: i64) -> FusionSet {
    FusionSetBuilder::new(
        &format!("pwise+dwise+pwise(r{rows},c{c1})"),
        &[c1, rows + 2, rows + 2],
    )
    .pointwise(6 * c1)
    .depthwise(3, 3, 1)
    .pointwise(c1)
    .build()
}

/// Table X row 3: `fc+fc`, a transformer feed-forward block.
/// `tokens = M1 = M2`, `emb = E1 = D2`, `D1 = E2 = 1024`.
pub fn fc_fc(tokens: i64, emb: i64) -> FusionSet {
    FusionSetBuilder::new(&format!("fc+fc(t{tokens},e{emb})"), &[tokens, 1024])
        .fc(emb)
        .fc(1024)
        .build()
}

/// BERT-style fused self-attention (scores → attend), the FLAT workload:
/// `L[b,h,m,n] = Q·Kᵀ`, `O[b,h,m,e] = softmax(L)·V`. The score fmap is the
/// intermediate whose tiling FLAT controls via B, H, M partitioning.
pub fn self_attention(batch: i64, heads: i64, tokens: i64, emb: i64) -> FusionSet {
    FusionSetBuilder::new(
        &format!("self-attention(b{batch},h{heads},t{tokens},e{emb})"),
        &[batch, heads, tokens, emb],
    )
    .attention_scores(tokens)
    .attention_values(emb)
    .build()
}

/// Fused-layer CNN [16] validation workload: the first two 3×3 conv layers
/// of VGG-E (224×224, 3→64→64 channels), the fusion the paper's Fig. 1
/// pyramid demonstrates.
pub fn vgg_e_first_two() -> FusionSet {
    FusionSetBuilder::new("vgg-e-conv1-conv2", &[3, 226, 226])
        .conv2d(64, 3, 3, 1)
        .conv2d(64, 3, 3, 1)
        .build()
}

/// Deeper VGG-E fused stage (conv1_1 .. pool1 .. conv2_1): exercises pooling
/// inside a fusion set.
pub fn vgg_e_stage_with_pool() -> FusionSet {
    FusionSetBuilder::new("vgg-e-conv1-pool-conv2", &[3, 226, 226])
        .conv2d(64, 3, 3, 1)
        .conv2d(64, 3, 3, 1)
        .maxpool(2, 2)
        .conv2d(128, 3, 3, 1)
        .build()
}

/// ISAAC [17] validation workloads: single VGG-1 (VGG-16) conv layers.
/// `which` ∈ {1, 2, 3, 5} per Table VII. Returned as a one-layer fusion set;
/// ISAAC pipelines *across* layers, which the validation driver builds by
/// chaining stages.
pub fn vgg1_layer(which: usize) -> FusionSet {
    // VGG-16 conv shapes (in channels, spatial, out channels).
    let (c, hw, m) = match which {
        1 => (3, 224, 64),
        2 => (64, 224, 64),
        3 => (64, 112, 128),
        4 => (128, 112, 128),
        5 => (128, 56, 256),
        _ => panic!("vgg1_layer: unsupported layer {which}"),
    };
    FusionSetBuilder::new(&format!("vgg1-conv{which}"), &[c, hw + 2, hw + 2])
        .conv2d(m, 3, 3, 1)
        .build()
}

/// Two consecutive VGG-16 layers for ISAAC-style column-partitioned
/// pipelining.
pub fn vgg1_pair(first: usize) -> FusionSet {
    let (c, hw, m1, m2) = match first {
        1 => (3, 224, 64, 64),
        3 => (64, 112, 128, 128),
        _ => panic!("vgg1_pair: unsupported start layer {first}"),
    };
    FusionSetBuilder::new(&format!("vgg1-conv{}-conv{}", first, first + 1), &[c, hw + 4, hw + 4])
        .conv2d(m1, 3, 3, 1)
        .conv2d(m2, 3, 3, 1)
        .build()
}

/// PipeLayer [18] validation: batched conv chains. PipeLayer partitions the
/// batch rank and pipelines across layers.
pub fn alexnet_convs_batched(batch: i64) -> FusionSet {
    // AlexNet conv3->conv4->conv5 (the chain with uniform 13x13 spatial size).
    FusionSetBuilder::new(&format!("alexnet-c3c4c5(b{batch})"), &[batch, 256, 15, 15])
        .conv2d_batched(384, 3, 3, 1)
        .conv2d_batched(384, 3, 3, 1)
        .conv2d_batched(256, 3, 3, 1)
        .build()
}

/// VGG-A conv chain (batched) for the PipeLayer speedup table.
pub fn vgg_a_convs_batched(batch: i64) -> FusionSet {
    FusionSetBuilder::new(&format!("vgg-a-stage3(b{batch})"), &[batch, 256, 30, 30])
        .conv2d_batched(256, 3, 3, 1)
        .conv2d_batched(256, 3, 3, 1)
        .conv2d_batched(256, 3, 3, 1)
        .build()
}

/// Small MNIST-scale CNNs for the PipeLayer speedup table (MNIST-A/B in
/// [18] are LeNet variants).
pub fn mnist_convs_batched(batch: i64, layers: usize) -> FusionSet {
    let mut b = FusionSetBuilder::new(&format!("mnist({layers}l,b{batch})"), &[batch, 1, 28, 28]);
    let mut chans = 20;
    for _ in 0..layers {
        b.conv2d_batched(chans, 5, 5, 1);
        chans = 50;
    }
    b.build()
}

/// DepFin [43] validation: FSRCNN super-resolution CNN (d=56, s=12, m=4):
/// feature extraction 5×5, shrink 1×1, four 3×3 mapping layers, expand 1×1.
/// DepFin fuses the full depth at high resolution.
pub fn fsrcnn(rows: i64) -> FusionSet {
    FusionSetBuilder::new(&format!("fsrcnn(r{rows})"), &[1, rows + 4, rows + 4])
        .conv2d(56, 5, 5, 1)
        .pointwise(12)
        .conv2d(12, 3, 3, 1)
        .pointwise(56)
        .build()
}

/// DepFin validation: MC-CNN fast stereo-matching feature network
/// (4 × conv3×3, 64 channels, full-resolution).
pub fn mc_cnn(rows: i64) -> FusionSet {
    FusionSetBuilder::new(&format!("mc-cnn(r{rows})"), &[1, rows + 6, rows + 6])
        .conv2d(64, 3, 3, 1)
        .conv2d(64, 3, 3, 1)
        .conv2d(64, 3, 3, 1)
        .build()
}

/// ResNet-18 stage shapes (Fig. 4 layers 1–5): `(width, channels)` pairs for
/// the five stages; widths/channels vary by orders of magnitude.
pub const RESNET18_STAGES: [(i64, i64); 5] =
    [(112, 64), (56, 64), (28, 128), (14, 256), (7, 512)];

/// A ResNet-18 basic block (two fused 3×3 convs) at stage `i` (0..5).
pub fn resnet18_block(stage: usize) -> FusionSet {
    let (w, c) = RESNET18_STAGES[stage];
    conv_conv(w, c)
}

/// MobileNetV2 block shapes (Fig. 4 layers 6–11): `(width, input channels)`.
pub const MOBILENETV2_STAGES: [(i64, i64); 6] =
    [(112, 16), (56, 24), (28, 32), (14, 64), (14, 96), (7, 160)];

/// A MobileNetV2 inverted-residual block at stage `i` (0..6).
pub fn mobilenetv2_block(stage: usize) -> FusionSet {
    let (w, c) = MOBILENETV2_STAGES[stage];
    pwise_dwise_pwise(w, c)
}

/// The Fig 14 shape sweep for `conv+conv`: (rows, channels) covering the
/// row-heavy to channel-heavy spectrum of Table X col. 3.
pub const CONV_CONV_SHAPES: [(i64, i64); 4] = [(112, 32), (56, 64), (28, 128), (14, 256)];

/// The Fig 14/15 shape sweep for `pwise+dwise+pwise` (rows, C1).
pub const PDP_SHAPES: [(i64, i64); 3] = [(56, 16), (28, 32), (14, 64)];

/// The Fig 14 shape sweep for `fc+fc` (tokens, emb).
pub const FC_FC_SHAPES: [(i64, i64); 3] = [(2048, 256), (512, 1024), (128, 4096)];
