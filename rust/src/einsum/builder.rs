//! Ergonomic construction of fusion sets from standard DNN layer types.
//!
//! The builder tracks the "current fmap" (the output of the last layer added)
//! and wires each new layer's input access to it, creating weight tensors as
//! needed. Rank naming follows the paper's Table II convention with a layer
//! suffix: `M2`, `P2`, `C2`, …
//!
//! Branched (DAG) fusion sets are built with three extra primitives:
//! [`FusionSetBuilder::external`] registers an additional off-chip input
//! fmap (e.g. a residual skip source cut off from the segment),
//! [`FusionSetBuilder::select`] rewinds the "current fmap" to any earlier
//! tensor (to grow a second branch from a fan-out point), and
//! [`FusionSetBuilder::add_residual`] merges the current fmap with other
//! tensors through an elementwise N-ary add — the residual/skip merge of
//! ResNet and MobileNetV2. The result must still be a single-sink DAG
//! ([`FusionSet::validate`]).

use super::spec::{EinsumSpec, FusionSet, OpKind, TensorAccess, TensorId, TensorInfo, TensorKind};
use crate::poly::{AffineExpr, AffineMap};

/// Builder for a [`FusionSet`] (chain or single-sink DAG).
pub struct FusionSetBuilder {
    name: String,
    tensors: Vec<TensorInfo>,
    einsums: Vec<EinsumSpec>,
    /// The tensor the next layer will consume.
    cur_fmap: TensorId,
    layer_idx: usize,
}

impl FusionSetBuilder {
    /// Start a fusion set whose first layer consumes a fmap of shape
    /// `input_shape` (e.g. `[C, H, W]` for convs, `[M, D]` for FC stacks).
    pub fn new(name: &str, input_shape: &[i64]) -> Self {
        let tensors = vec![TensorInfo {
            name: "Fmap1".into(),
            shape: input_shape.to_vec(),
            kind: TensorKind::InputFmap,
        }];
        FusionSetBuilder {
            name: name.into(),
            tensors,
            einsums: Vec::new(),
            cur_fmap: TensorId(0),
            layer_idx: 0,
        }
    }

    fn add_tensor(&mut self, name: String, shape: Vec<i64>, kind: TensorKind) -> TensorId {
        self.tensors.push(TensorInfo { name, shape, kind });
        TensorId(self.tensors.len() - 1)
    }

    /// Demote the current fmap to an intermediate if it is produced by an
    /// earlier einsum: called when a new layer consumes it. External inputs
    /// (never produced in this set) keep their [`TensorKind::InputFmap`]
    /// kind even when re-selected as the current fmap of a branch.
    fn demote_cur_to_intermediate(&mut self) {
        self.demote_to_intermediate(self.cur_fmap);
    }

    fn demote_to_intermediate(&mut self, t: TensorId) {
        if self.einsums.iter().any(|e| e.output.tensor == t) {
            self.tensors[t.0].kind = TensorKind::Intermediate;
        }
    }

    /// The tensor the next layer would consume (the last layer's output, or
    /// the tensor chosen by [`FusionSetBuilder::select`]).
    pub fn cur(&self) -> TensorId {
        self.cur_fmap
    }

    /// Register an additional off-chip input fmap (a tensor streamed from
    /// DRAM that no einsum in this set produces — e.g. a residual skip
    /// source living outside the fused segment). Returns its id for wiring
    /// via [`FusionSetBuilder::select`] or
    /// [`FusionSetBuilder::add_residual`].
    pub fn external(&mut self, shape: &[i64]) -> TensorId {
        let n = self.tensors.len();
        self.add_tensor(format!("Input{n}"), shape.to_vec(), TensorKind::InputFmap)
    }

    /// Make `t` the current fmap, so the next layer consumes it — the
    /// branch primitive: remember a fan-out point with
    /// [`FusionSetBuilder::cur`], build one branch, then `select` the saved
    /// tensor and build the other.
    pub fn select(&mut self, t: TensorId) -> &mut Self {
        assert!(t.0 < self.tensors.len(), "select: tensor out of range");
        assert!(
            self.tensors[t.0].kind != TensorKind::Weight,
            "select: cannot continue from a weight tensor"
        );
        self.cur_fmap = t;
        self
    }

    fn next_layer(&mut self) -> usize {
        self.layer_idx += 1;
        self.layer_idx
    }

    fn cur_shape(&self) -> &[i64] {
        &self.tensors[self.cur_fmap.0].shape
    }

    /// 2D convolution: `Out[m,p,q] = Σ_{c,r,s} In[c, p·st+r, q·st+s] · W[m,c,r,s]`.
    /// Input must be `[C, H, W]`; output is `[M, P, Q]` with
    /// `P = (H - r) / st + 1`.
    pub fn conv2d(&mut self, m: i64, r: i64, s: i64, stride: i64) -> &mut Self {
        let li = self.next_layer();
        let (c, h, w) = match *self.cur_shape() {
            [c, h, w] => (c, h, w),
            _ => panic!("conv2d requires a [C,H,W] input fmap"),
        };
        let p = (h - r) / stride + 1;
        let q = (w - s) / stride + 1;
        assert!(p > 0 && q > 0, "conv2d output would be empty");
        self.demote_cur_to_intermediate();
        let in_fmap = self.cur_fmap;
        let wt = self.add_tensor(format!("Filter{li}"), vec![m, c, r, s], TensorKind::Weight);
        let out = self.add_tensor(format!("Fmap{}", li + 1), vec![m, p, q], TensorKind::OutputFmap);
        // Local ranks: [M, P, Q, C, R, S] = dims 0..6.
        let (dm, dp, dq, dc, dr, ds) = (0, 1, 2, 3, 4, 5);
        let conv = |i: usize, k: usize| {
            if stride == 1 {
                AffineExpr::sum((i, 1), (k, 1))
            } else {
                AffineExpr::sum((i, stride), (k, 1))
            }
        };
        self.einsums.push(EinsumSpec {
            name: format!("Conv{li}"),
            rank_names: suffixed(&["M", "P", "Q", "C", "R", "S"], li),
            rank_sizes: vec![m, p, q, c, r, s],
            output: TensorAccess {
                tensor: out,
                map: AffineMap::identity(&[dm, dp, dq]),
            },
            inputs: vec![
                TensorAccess {
                    tensor: in_fmap,
                    map: AffineMap::new(vec![
                        AffineExpr::var(dc),
                        conv(dp, dr),
                        conv(dq, ds),
                    ]),
                },
                TensorAccess {
                    tensor: wt,
                    map: AffineMap::identity(&[dm, dc, dr, ds]),
                },
            ],
            op_kind: OpKind::Mac,
        });
        self.cur_fmap = out;
        self
    }

    /// Pointwise (1×1) convolution: `Out[m,p,q] = Σ_c In[c,p,q] · W[m,c]`.
    pub fn pointwise(&mut self, m: i64) -> &mut Self {
        let li = self.next_layer();
        let (c, h, w) = match *self.cur_shape() {
            [c, h, w] => (c, h, w),
            _ => panic!("pointwise requires a [C,H,W] input fmap"),
        };
        self.demote_cur_to_intermediate();
        let in_fmap = self.cur_fmap;
        let wt = self.add_tensor(format!("Filter{li}"), vec![m, c], TensorKind::Weight);
        let out = self.add_tensor(format!("Fmap{}", li + 1), vec![m, h, w], TensorKind::OutputFmap);
        let (dm, dp, dq, dc) = (0, 1, 2, 3);
        self.einsums.push(EinsumSpec {
            name: format!("Pwise{li}"),
            rank_names: suffixed(&["M", "P", "Q", "C"], li),
            rank_sizes: vec![m, h, w, c],
            output: TensorAccess {
                tensor: out,
                map: AffineMap::identity(&[dm, dp, dq]),
            },
            inputs: vec![
                TensorAccess {
                    tensor: in_fmap,
                    map: AffineMap::identity(&[dc, dp, dq]),
                },
                TensorAccess {
                    tensor: wt,
                    map: AffineMap::identity(&[dm, dc]),
                },
            ],
            op_kind: OpKind::Mac,
        });
        self.cur_fmap = out;
        self
    }

    /// Depthwise convolution: `Out[m,p,q] = Σ_{r,s} In[m, p·st+r, q·st+s] · W[m,r,s]`.
    /// The channel rank `M` is shared between input and output (no channel
    /// reduction) — the distinctive reuse pattern of MobileNet blocks.
    pub fn depthwise(&mut self, r: i64, s: i64, stride: i64) -> &mut Self {
        let li = self.next_layer();
        let (c, h, w) = match *self.cur_shape() {
            [c, h, w] => (c, h, w),
            _ => panic!("depthwise requires a [C,H,W] input fmap"),
        };
        let p = (h - r) / stride + 1;
        let q = (w - s) / stride + 1;
        self.demote_cur_to_intermediate();
        let in_fmap = self.cur_fmap;
        let wt = self.add_tensor(format!("Filter{li}"), vec![c, r, s], TensorKind::Weight);
        let out = self.add_tensor(format!("Fmap{}", li + 1), vec![c, p, q], TensorKind::OutputFmap);
        let (dm, dp, dq, dr, ds) = (0, 1, 2, 3, 4);
        let conv = |i: usize, k: usize| {
            if stride == 1 {
                AffineExpr::sum((i, 1), (k, 1))
            } else {
                AffineExpr::sum((i, stride), (k, 1))
            }
        };
        self.einsums.push(EinsumSpec {
            name: format!("Dwise{li}"),
            rank_names: suffixed(&["M", "P", "Q", "R", "S"], li),
            rank_sizes: vec![c, p, q, r, s],
            output: TensorAccess {
                tensor: out,
                map: AffineMap::identity(&[dm, dp, dq]),
            },
            inputs: vec![
                TensorAccess {
                    tensor: in_fmap,
                    map: AffineMap::new(vec![
                        AffineExpr::var(dm),
                        conv(dp, dr),
                        conv(dq, ds),
                    ]),
                },
                TensorAccess {
                    tensor: wt,
                    map: AffineMap::identity(&[dm, dr, ds]),
                },
            ],
            op_kind: OpKind::Mac,
        });
        self.cur_fmap = out;
        self
    }

    /// Max pooling: `Out[m,p,q] = max_{r,s} In[m, p·st+r, q·st+s]` — same
    /// access structure as depthwise but no weights and `Max` ops.
    pub fn maxpool(&mut self, k: i64, stride: i64) -> &mut Self {
        let li = self.next_layer();
        let (c, h, w) = match *self.cur_shape() {
            [c, h, w] => (c, h, w),
            _ => panic!("maxpool requires a [C,H,W] input fmap"),
        };
        let p = (h - k) / stride + 1;
        let q = (w - k) / stride + 1;
        self.demote_cur_to_intermediate();
        let in_fmap = self.cur_fmap;
        let out = self.add_tensor(format!("Fmap{}", li + 1), vec![c, p, q], TensorKind::OutputFmap);
        let (dm, dp, dq, dr, ds) = (0, 1, 2, 3, 4);
        let conv = |i: usize, kk: usize| {
            if stride == 1 {
                AffineExpr::sum((i, 1), (kk, 1))
            } else {
                AffineExpr::sum((i, stride), (kk, 1))
            }
        };
        self.einsums.push(EinsumSpec {
            name: format!("Pool{li}"),
            rank_names: suffixed(&["M", "P", "Q", "R", "S"], li),
            rank_sizes: vec![c, p, q, k, k],
            output: TensorAccess {
                tensor: out,
                map: AffineMap::identity(&[dm, dp, dq]),
            },
            inputs: vec![TensorAccess {
                tensor: in_fmap,
                map: AffineMap::new(vec![AffineExpr::var(dm), conv(dp, dr), conv(dq, ds)]),
            }],
            op_kind: OpKind::Max,
        });
        self.cur_fmap = out;
        self
    }

    /// Fully connected: `Out[m,e] = Σ_d In[m,d] · W[d,e]`. Input `[M, D]`.
    pub fn fc(&mut self, e: i64) -> &mut Self {
        let li = self.next_layer();
        let (m, d) = match *self.cur_shape() {
            [m, d] => (m, d),
            _ => panic!("fc requires a [M,D] input fmap"),
        };
        self.demote_cur_to_intermediate();
        let in_fmap = self.cur_fmap;
        let wt = self.add_tensor(format!("Filter{li}"), vec![d, e], TensorKind::Weight);
        let out = self.add_tensor(format!("Fmap{}", li + 1), vec![m, e], TensorKind::OutputFmap);
        let (dm, de, dd) = (0, 1, 2);
        self.einsums.push(EinsumSpec {
            name: format!("Fc{li}"),
            rank_names: suffixed(&["M", "E", "D"], li),
            rank_sizes: vec![m, e, d],
            output: TensorAccess {
                tensor: out,
                map: AffineMap::identity(&[dm, de]),
            },
            inputs: vec![
                TensorAccess {
                    tensor: in_fmap,
                    map: AffineMap::identity(&[dm, dd]),
                },
                TensorAccess {
                    tensor: wt,
                    map: AffineMap::identity(&[dd, de]),
                },
            ],
            op_kind: OpKind::Mac,
        });
        self.cur_fmap = out;
        self
    }

    /// Batched conv2d for PipeLayer-style batch partitioning. Input must be
    /// `[B, C, H, W]`; output is `[B, M, P, Q]`.
    pub fn conv2d_batched(&mut self, m: i64, r: i64, s: i64, stride: i64) -> &mut Self {
        let li = self.next_layer();
        let (b, c, h, w) = match *self.cur_shape() {
            [b, c, h, w] => (b, c, h, w),
            _ => panic!("conv2d_batched requires a [B,C,H,W] input fmap"),
        };
        let p = (h - r) / stride + 1;
        let q = (w - s) / stride + 1;
        self.demote_cur_to_intermediate();
        let in_fmap = self.cur_fmap;
        let wt = self.add_tensor(format!("Filter{li}"), vec![m, c, r, s], TensorKind::Weight);
        let out =
            self.add_tensor(format!("Fmap{}", li + 1), vec![b, m, p, q], TensorKind::OutputFmap);
        let (db, dm, dp, dq, dc, dr, ds) = (0, 1, 2, 3, 4, 5, 6);
        let conv = |i: usize, k: usize| {
            if stride == 1 {
                AffineExpr::sum((i, 1), (k, 1))
            } else {
                AffineExpr::sum((i, stride), (k, 1))
            }
        };
        self.einsums.push(EinsumSpec {
            name: format!("Conv{li}"),
            rank_names: suffixed(&["B", "M", "P", "Q", "C", "R", "S"], li),
            rank_sizes: vec![b, m, p, q, c, r, s],
            output: TensorAccess {
                tensor: out,
                map: AffineMap::identity(&[db, dm, dp, dq]),
            },
            inputs: vec![
                TensorAccess {
                    tensor: in_fmap,
                    map: AffineMap::new(vec![
                        AffineExpr::var(db),
                        AffineExpr::var(dc),
                        conv(dp, dr),
                        conv(dq, ds),
                    ]),
                },
                TensorAccess {
                    tensor: wt,
                    map: AffineMap::identity(&[dm, dc, dr, ds]),
                },
            ],
            op_kind: OpKind::Mac,
        });
        self.cur_fmap = out;
        self
    }

    /// Attention score matmul: `L[b,h,m,n] = Σ_e Q[b,h,m,e] · K[b,h,n,e]`.
    /// Input (the query) must be `[B, Hd, M, E]`; the key tensor is created
    /// as a weight-like streamed tensor of the same shape.
    pub fn attention_scores(&mut self, n: i64) -> &mut Self {
        let li = self.next_layer();
        let (b, hd, m, e) = match *self.cur_shape() {
            [b, hd, m, e] => (b, hd, m, e),
            _ => panic!("attention_scores requires a [B,H,M,E] input"),
        };
        self.demote_cur_to_intermediate();
        let q = self.cur_fmap;
        let k = self.add_tensor(format!("Key{li}"), vec![b, hd, n, e], TensorKind::Weight);
        let out = self.add_tensor(format!("Fmap{}", li + 1), vec![b, hd, m, n], TensorKind::OutputFmap);
        let (db, dh, dm, dn, de) = (0, 1, 2, 3, 4);
        self.einsums.push(EinsumSpec {
            name: format!("Scores{li}"),
            rank_names: suffixed(&["B", "H", "M", "N", "E"], li),
            rank_sizes: vec![b, hd, m, n, e],
            output: TensorAccess {
                tensor: out,
                map: AffineMap::identity(&[db, dh, dm, dn]),
            },
            inputs: vec![
                TensorAccess {
                    tensor: q,
                    map: AffineMap::identity(&[db, dh, dm, de]),
                },
                TensorAccess {
                    tensor: k,
                    map: AffineMap::identity(&[db, dh, dn, de]),
                },
            ],
            op_kind: OpKind::Mac,
        });
        self.cur_fmap = out;
        self
    }

    /// Attention value matmul: `O[b,h,m,e] = Σ_n S[b,h,m,n] · V[b,h,n,e]`
    /// where `S` is the (softmaxed, modeled in-place) score tensor.
    pub fn attention_values(&mut self, e: i64) -> &mut Self {
        let li = self.next_layer();
        let (b, hd, m, n) = match *self.cur_shape() {
            [b, hd, m, n] => (b, hd, m, n),
            _ => panic!("attention_values requires a [B,H,M,N] input"),
        };
        self.demote_cur_to_intermediate();
        let s = self.cur_fmap;
        let v = self.add_tensor(format!("Value{li}"), vec![b, hd, n, e], TensorKind::Weight);
        let out = self.add_tensor(format!("Fmap{}", li + 1), vec![b, hd, m, e], TensorKind::OutputFmap);
        let (db, dh, dm, de, dn) = (0, 1, 2, 3, 4);
        self.einsums.push(EinsumSpec {
            name: format!("Attend{li}"),
            rank_names: suffixed(&["B", "H", "M", "E", "N"], li),
            rank_sizes: vec![b, hd, m, e, n],
            output: TensorAccess {
                tensor: out,
                map: AffineMap::identity(&[db, dh, dm, de]),
            },
            inputs: vec![
                TensorAccess {
                    tensor: s,
                    map: AffineMap::identity(&[db, dh, dm, dn]),
                },
                TensorAccess {
                    tensor: v,
                    map: AffineMap::identity(&[db, dh, dn, de]),
                },
            ],
            op_kind: OpKind::Mac,
        });
        self.cur_fmap = out;
        self
    }

    /// Elementwise N-ary add merging the current fmap with `others` — the
    /// residual/skip merge of ResNet and MobileNetV2:
    /// `Out[…] = Cur[…] + Σ Other[…]`, [`OpKind::Elementwise`].
    ///
    /// Operand shapes must satisfy [`residual_merge_shape`]: larger 3D
    /// operands are center-cropped to the common spatial interior via
    /// constant-offset accesses (fused valid-convolution branches shrink
    /// relative to their padded reference).
    pub fn add_residual(&mut self, others: &[TensorId]) -> &mut Self {
        assert!(!others.is_empty(), "add_residual needs at least one other operand");
        let li = self.next_layer();
        let operands: Vec<TensorId> =
            std::iter::once(self.cur_fmap).chain(others.iter().copied()).collect();
        for &t in &operands {
            assert!(
                self.tensors[t.0].kind != TensorKind::Weight,
                "add_residual: operands must be fmaps, not weights"
            );
        }
        let shapes: Vec<&[i64]> =
            operands.iter().map(|&t| self.tensors[t.0].shape.as_slice()).collect();
        let out_shape = residual_merge_shape(&shapes)
            .unwrap_or_else(|e| panic!("add_residual: {e}"));
        let nd = out_shape.len();
        // Per-operand center-crop offsets (margins are valid by the merge
        // check above; they split evenly by construction).
        let mut accesses: Vec<TensorAccess> = Vec::with_capacity(operands.len());
        for &t in &operands {
            let s = self.tensors[t.0].shape.clone();
            let exprs: Vec<AffineExpr> = (0..nd)
                .map(|d| AffineExpr::var(d).with_offset((s[d] - out_shape[d]) / 2))
                .collect();
            accesses.push(TensorAccess { tensor: t, map: AffineMap::new(exprs) });
            self.demote_to_intermediate(t);
        }
        let out =
            self.add_tensor(format!("Fmap{}", li + 1), out_shape.clone(), TensorKind::OutputFmap);
        let rank_names: Vec<String> = match nd {
            2 => suffixed(&["M", "E"], li),
            3 => suffixed(&["M", "P", "Q"], li),
            4 => suffixed(&["B", "H", "M", "E"], li),
            _ => (0..nd).map(|d| format!("D{d}_{li}")).collect(),
        };
        let all_dims: Vec<usize> = (0..nd).collect();
        self.einsums.push(EinsumSpec {
            name: format!("Add{li}"),
            rank_names,
            rank_sizes: out_shape,
            output: TensorAccess { tensor: out, map: AffineMap::identity(&all_dims) },
            inputs: accesses,
            op_kind: OpKind::Elementwise,
        });
        self.cur_fmap = out;
        self
    }

    /// Finish and validate.
    pub fn build(&mut self) -> FusionSet {
        let fs = FusionSet {
            name: std::mem::take(&mut self.name),
            tensors: std::mem::take(&mut self.tensors),
            einsums: std::mem::take(&mut self.einsums),
        };
        if let Err(e) = fs.validate() {
            panic!("invalid fusion set `{}`: {e}", fs.name);
        }
        fs
    }
}

fn suffixed(names: &[&str], li: usize) -> Vec<String> {
    names.iter().map(|n| format!("{n}{li}")).collect()
}

/// Result shape of an elementwise residual merge — the single authority for
/// the center-crop reconciliation rule, shared by the segment planner
/// (`network::Network::segment_plan`, which reports `Err`) and
/// [`FusionSetBuilder::add_residual`] (which builds the accesses and treats
/// a violation as a caller bug).
///
/// All operands must agree on every non-spatial dimension. For 3D `[C,H,W]`
/// fmaps the two trailing (spatial) dims may differ: the output is the
/// elementwise minimum, and every operand's margin must be non-negative and
/// even so it center-crops symmetrically. Other arities require exact shape
/// equality.
pub fn residual_merge_shape(shapes: &[&[i64]]) -> Result<Vec<i64>, String> {
    let first = *shapes.first().ok_or("residual merge needs at least one operand")?;
    let nd = first.len();
    let mut out: Vec<i64> = first.to_vec();
    for s in &shapes[1..] {
        if s.len() != nd {
            return Err(format!("operand arity mismatch ({first:?} vs {s:?})"));
        }
        for d in 0..nd {
            if nd == 3 && d >= 1 {
                out[d] = out[d].min(s[d]);
            } else if out[d] != s[d] {
                return Err(format!("operand shapes {first:?} vs {s:?} cannot merge"));
            }
        }
    }
    for s in shapes {
        for d in 0..nd {
            let margin = s[d] - out[d];
            if margin < 0 || margin % 2 != 0 {
                return Err(format!("operand {s:?} cannot be center-cropped to {out:?}"));
            }
        }
    }
    Ok(out)
}
