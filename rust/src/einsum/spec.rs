//! Core workload data model: tensors, Einsums, fusion sets.

use crate::poly::{AffineMap, IBox, Interval, Region};

/// Index of a tensor within its [`FusionSet`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TensorId(pub usize);

/// Role of a tensor within a fusion set (paper §I / §III-D). Retention
/// choices for [`TensorKind::Intermediate`] tensors are retain-*recompute*
/// choices (no off-chip backing); all others are retain-*refetch*.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TensorKind {
    /// The input fmap of the first layer — streamed from off-chip.
    InputFmap,
    /// Filters / weights of any layer — streamed from off-chip.
    Weight,
    /// Produced by layer `i`, consumed by layer `i+1`; lives on-chip only.
    Intermediate,
    /// Output fmap of the last layer — drained to off-chip.
    OutputFmap,
}

/// A tensor in a fusion set.
#[derive(Debug, Clone)]
pub struct TensorInfo {
    /// Display name of the tensor.
    pub name: String,
    /// Extent of each coordinate dimension.
    pub shape: Vec<i64>,
    /// The tensor's role in the fusion set.
    pub kind: TensorKind,
}

impl TensorInfo {
    /// Number of coordinate dimensions.
    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    /// Total number of elements.
    pub fn size(&self) -> i64 {
        self.shape.iter().product()
    }

    /// The whole tensor as a box.
    pub fn full_box(&self) -> IBox {
        IBox::new(self.shape.iter().map(|&s| Interval::upto(s)).collect())
    }

    /// The whole tensor as a single-box region.
    pub fn full_region(&self) -> Region {
        Region::from_box(self.full_box())
    }
}

/// How an Einsum's iteration space touches one tensor: an affine map from the
/// Einsum's (local) iteration dims to the tensor's coordinate dims.
#[derive(Debug, Clone)]
pub struct TensorAccess {
    /// Which tensor is accessed.
    pub tensor: TensorId,
    /// Iteration dims to tensor coordinates.
    pub map: AffineMap,
}

/// What the compute units do per iteration point — used for op counting and
/// energy (a MAC vs. a comparator vs. an exp for softmax).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpKind {
    /// Multiply-accumulate (conv / matmul).
    Mac,
    /// Max-reduce (pooling).
    Max,
    /// Elementwise op (activation, softmax, scaling).
    Elementwise,
}

/// One layer as an extended Einsum: named ranks with a dense box domain, one
/// output access (identity per dim, by construction in the builders), and one
/// access per input tensor.
#[derive(Debug, Clone)]
pub struct EinsumSpec {
    /// Display name of the Einsum (layer).
    pub name: String,
    /// Local iteration dim names, e.g. `["M", "P", "Q", "C", "R", "S"]`.
    pub rank_names: Vec<String>,
    /// Extent of each local iteration dim.
    pub rank_sizes: Vec<i64>,
    /// The produced tensor's access.
    pub output: TensorAccess,
    /// One access per consumed tensor.
    pub inputs: Vec<TensorAccess>,
    /// The operator kind.
    pub op_kind: OpKind,
}

impl EinsumSpec {
    /// Number of iteration dims.
    pub fn ndim(&self) -> usize {
        self.rank_sizes.len()
    }

    /// Full iteration domain.
    pub fn domain(&self) -> IBox {
        IBox::new(self.rank_sizes.iter().map(|&s| Interval::upto(s)).collect())
    }

    /// Total operation count (product of rank sizes).
    pub fn total_ops(&self) -> i64 {
        self.rank_sizes.iter().product()
    }

    /// Local dim index of rank `name`, if present.
    pub fn rank_index(&self, name: &str) -> Option<usize> {
        self.rank_names.iter().position(|n| n == name)
    }

    /// Dims NOT referenced by the output access — the reduction ranks. An op
    /// region that produces a piece of output always extends fully along
    /// these.
    pub fn reduction_dims(&self) -> Vec<usize> {
        let out_dims = self.output.map.referenced_dims();
        (0..self.ndim()).filter(|d| !out_dims.contains(d)).collect()
    }

    /// Product of reduction-rank extents (ops per produced output element).
    pub fn reduction_extent(&self) -> i64 {
        self.reduction_dims()
            .iter()
            .map(|&d| self.rank_sizes[d])
            .product()
    }

    /// The access for `tensor`, searching inputs then output.
    pub fn access_for(&self, tensor: TensorId) -> Option<&TensorAccess> {
        self.inputs
            .iter()
            .find(|a| a.tensor == tensor)
            .or_else(|| (self.output.tensor == tensor).then_some(&self.output))
    }
}

/// A set of layers to be fused (paper §III: the user-defined *fusion set*).
///
/// Invariants (checked by [`FusionSet::validate`]):
/// * Einsums form a single-sink DAG in topological order: every input tensor
///   is produced by an *earlier* einsum or is an off-chip source
///   ([`TensorKind::InputFmap`] / [`TensorKind::Weight`]); every einsum's
///   output except the last is consumed by at least one later einsum; the
///   last einsum produces the unique [`TensorKind::OutputFmap`]. A chain is
///   the special case where each output feeds exactly the next einsum
///   ([`FusionSet::is_chain`]).
/// * Output accesses are identity-per-dimension (bare ranks), so operation
///   preimages of output regions are exact boxes.
#[derive(Debug, Clone)]
pub struct FusionSet {
    /// Display name of the fusion set.
    pub name: String,
    /// All tensors, indexed by [`TensorId`].
    pub tensors: Vec<TensorInfo>,
    /// Layers in producer-before-consumer order.
    pub einsums: Vec<EinsumSpec>,
}

impl FusionSet {
    /// The tensor with id `id`.
    pub fn tensor(&self, id: TensorId) -> &TensorInfo {
        &self.tensors[id.0]
    }

    /// Number of Einsum layers.
    pub fn num_layers(&self) -> usize {
        self.einsums.len()
    }

    /// The final (sink) layer.
    pub fn last(&self) -> &EinsumSpec {
        self.einsums.last().expect("empty fusion set")
    }

    /// The layer that produces `tensor`, if any.
    pub fn producer_of(&self, tensor: TensorId) -> Option<usize> {
        self.einsums.iter().position(|e| e.output.tensor == tensor)
    }

    /// The layers that consume `tensor`.
    pub fn consumers_of(&self, tensor: TensorId) -> Vec<usize> {
        self.einsums
            .iter()
            .enumerate()
            .filter(|(_, e)| e.inputs.iter().any(|a| a.tensor == tensor))
            .map(|(i, _)| i)
            .collect()
    }

    /// The intermediate tensor between layer `i` and layer `i+1`.
    pub fn intermediate_between(&self, i: usize) -> TensorId {
        self.einsums[i].output.tensor
    }

    /// All tensor ids of a given kind.
    pub fn tensors_of_kind(&self, kind: TensorKind) -> Vec<TensorId> {
        self.tensors
            .iter()
            .enumerate()
            .filter(|(_, t)| t.kind == kind)
            .map(|(i, _)| TensorId(i))
            .collect()
    }

    /// Every tensor with off-chip backing (everything but intermediates).
    pub fn offchip_backed_tensors(&self) -> Vec<TensorId> {
        self.tensors
            .iter()
            .enumerate()
            .filter(|(_, t)| t.kind != TensorKind::Intermediate)
            .map(|(i, _)| TensorId(i))
            .collect()
    }

    /// Total MAC-equivalent operations in the fusion set (algorithmic,
    /// without recomputation).
    pub fn total_ops(&self) -> i64 {
        self.einsums.iter().map(|e| e.total_ops()).sum()
    }

    /// Algorithmic-minimum off-chip traffic in elements: every off-chip
    /// backed tensor crosses the chip boundary exactly once (paper §VI-B).
    pub fn algmin_offchip_elems(&self) -> i64 {
        self.offchip_backed_tensors()
            .iter()
            .map(|&t| self.tensor(t).size())
            .sum()
    }

    /// Whether the einsums form a pure chain: each layer's output is consumed
    /// by exactly the next layer (and nothing else). The element-driven
    /// simulator only supports chains; the analytical model handles any
    /// valid single-sink DAG.
    pub fn is_chain(&self) -> bool {
        self.einsums.iter().enumerate().all(|(li, e)| {
            let out = e.output.tensor;
            self.einsums.iter().enumerate().all(|(ci, c)| {
                let consumes = c.inputs.iter().any(|a| a.tensor == out);
                consumes == (ci == li + 1)
            })
        })
    }

    /// Check structural invariants; returns a description of the first
    /// violation.
    pub fn validate(&self) -> Result<(), String> {
        if self.einsums.is_empty() {
            return Err("fusion set has no einsums".into());
        }
        let mut producer: Vec<Option<usize>> = vec![None; self.tensors.len()];
        for (li, e) in self.einsums.iter().enumerate() {
            if e.rank_names.len() != e.rank_sizes.len() {
                return Err(format!("{}: rank names/sizes length mismatch", e.name));
            }
            if e.rank_sizes.iter().any(|&s| s <= 0) {
                return Err(format!("{}: non-positive rank size", e.name));
            }
            // Output access must be identity per dim.
            for expr in &e.output.map.exprs {
                if expr.as_identity().is_none() {
                    return Err(format!("{}: output access is not identity-per-dim", e.name));
                }
            }
            // Access arity must match tensor ndim; footprints must fit.
            for acc in e.inputs.iter().chain(std::iter::once(&e.output)) {
                let t = self.tensor(acc.tensor);
                if acc.map.out_ndim() != t.ndim() {
                    return Err(format!(
                        "{}: access to {} has arity {} but tensor has {} dims",
                        e.name,
                        t.name,
                        acc.map.out_ndim(),
                        t.ndim()
                    ));
                }
                let fp = acc.map.image_box(&e.domain());
                if !t.full_box().contains_box(&fp) {
                    return Err(format!(
                        "{}: access footprint {} exceeds tensor {} shape {:?}",
                        e.name, fp, t.name, t.shape
                    ));
                }
            }
            // Topological order: inputs come from earlier einsums or from
            // off-chip sources; nothing consumes its own output.
            for acc in &e.inputs {
                let t = self.tensor(acc.tensor);
                match producer[acc.tensor.0] {
                    Some(p) if p < li => {}
                    Some(_) => {
                        return Err(format!(
                            "{}: input {} is consumed before it is produced",
                            e.name, t.name
                        ));
                    }
                    None => {
                        if !matches!(t.kind, TensorKind::InputFmap | TensorKind::Weight) {
                            return Err(format!(
                                "{}: input {} has kind {:?} but no producer",
                                e.name, t.name, t.kind
                            ));
                        }
                    }
                }
            }
            if producer[e.output.tensor.0].is_some() {
                return Err(format!(
                    "{}: tensor {} has more than one producer",
                    e.name,
                    self.tensor(e.output.tensor).name
                ));
            }
            producer[e.output.tensor.0] = Some(li);
        }
        // Single sink: every non-final output is consumed by a later einsum
        // (and classified Intermediate); the final einsum produces the one
        // OutputFmap.
        let n = self.einsums.len();
        for (li, e) in self.einsums.iter().enumerate() {
            let out = e.output.tensor;
            let consumed = self.einsums[li + 1..]
                .iter()
                .any(|c| c.inputs.iter().any(|a| a.tensor == out));
            let kind = self.tensor(out).kind;
            if li + 1 == n {
                if kind != TensorKind::OutputFmap {
                    return Err(format!(
                        "{}: final output tensor {} has kind {:?}, expected OutputFmap",
                        e.name,
                        self.tensor(out).name,
                        kind
                    ));
                }
            } else if !consumed {
                return Err(format!(
                    "{}: intermediate {} is never consumed (dangling branch output)",
                    e.name,
                    self.tensor(out).name
                ));
            } else if kind != TensorKind::Intermediate {
                return Err(format!(
                    "{}: output tensor {} has kind {:?}, expected Intermediate",
                    e.name,
                    self.tensor(out).name,
                    kind
                ));
            }
        }
        let outputs = self.tensors_of_kind(TensorKind::OutputFmap);
        if outputs.len() != 1 {
            return Err(format!(
                "fusion set must have exactly one output fmap, found {}",
                outputs.len()
            ));
        }
        Ok(())
    }
}
