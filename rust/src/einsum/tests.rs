use super::*;
use crate::poly::IBox;

#[test]
fn conv_conv_structure() {
    let fs = workloads::conv_conv(14, 64);
    assert!(fs.validate().is_ok());
    assert_eq!(fs.num_layers(), 2);
    assert_eq!(fs.tensors.len(), 5); // Fmap1, Filter1, Fmap2, Filter2, Fmap3
    let inter = fs.tensors_of_kind(TensorKind::Intermediate);
    assert_eq!(inter.len(), 1);
    assert_eq!(fs.tensor(inter[0]).name, "Fmap2");
    // Fmap2 shape: channels × (rows+?)... conv1 output of input (14+2)^2.
    assert_eq!(fs.tensor(inter[0]).shape, vec![64, 14, 14]);
    // Final output 12x12? No: conv2 consumes 14x14 -> 12x12.
    let out = fs.tensors_of_kind(TensorKind::OutputFmap);
    assert_eq!(fs.tensor(out[0]).shape, vec![64, 12, 12]);
}

#[test]
fn conv_chain_shapes_follow_halo() {
    // Input rows + 2 per 3x3 conv layer (stride 1, valid padding).
    let fs = workloads::conv_conv_conv(16, 8);
    assert!(fs.validate().is_ok());
    let shapes: Vec<&[i64]> = fs.tensors.iter().map(|t| t.shape.as_slice()).collect();
    assert_eq!(shapes[0], &[8, 20, 20]); // Fmap1
    let out = fs.tensors_of_kind(TensorKind::OutputFmap)[0];
    assert_eq!(fs.tensor(out).shape, vec![8, 14, 14]);
}

#[test]
fn pdp_block_dwise_shares_channel_rank() {
    let fs = workloads::pwise_dwise_pwise(28, 16);
    assert!(fs.validate().is_ok());
    assert_eq!(fs.num_layers(), 3);
    // Dwise: input and output channel count equal (96 = 6*16).
    let dwise = &fs.einsums[1];
    assert_eq!(dwise.name, "Dwise2");
    let in_t = fs.tensor(dwise.inputs[0].tensor);
    let out_t = fs.tensor(dwise.output.tensor);
    assert_eq!(in_t.shape[0], 96);
    assert_eq!(out_t.shape[0], 96);
    // Depthwise has no channel reduction: reduction ranks are R,S only.
    assert_eq!(dwise.reduction_extent(), 9);
}

#[test]
fn fc_fc_no_convolutional_reuse() {
    let fs = workloads::fc_fc(512, 1024);
    assert!(fs.validate().is_ok());
    for e in &fs.einsums {
        for acc in &e.inputs {
            // Every access expression is a bare rank: no sliding windows.
            for expr in &acc.map.exprs {
                assert!(expr.as_identity().is_some());
            }
        }
    }
}

#[test]
fn attention_chain() {
    let fs = workloads::self_attention(4, 12, 128, 64);
    assert!(fs.validate().is_ok());
    let inter = fs.tensors_of_kind(TensorKind::Intermediate);
    assert_eq!(inter.len(), 1);
    assert_eq!(fs.tensor(inter[0]).shape, vec![4, 12, 128, 128]); // scores
}

#[test]
fn strided_conv_footprint() {
    let fs = FusionSetBuilder::new("s2", &[8, 15, 15]).conv2d(16, 3, 3, 2).build();
    let e = &fs.einsums[0];
    // P = (15-3)/2 + 1 = 7.
    assert_eq!(e.rank_sizes[1], 7);
    // Input footprint of the full domain covers all 15 rows.
    let img = e.inputs[0].map.image_box(&e.domain());
    assert_eq!(img, IBox::from_bounds(&[(0, 8), (0, 15), (0, 15)]));
}

#[test]
fn pooling_has_no_weights() {
    let fs = workloads::vgg_e_stage_with_pool();
    assert!(fs.validate().is_ok());
    let pool = fs.einsums.iter().find(|e| e.name.starts_with("Pool")).unwrap();
    assert_eq!(pool.inputs.len(), 1);
    assert_eq!(pool.op_kind, OpKind::Max);
}

#[test]
fn total_ops_conv() {
    let fs = workloads::conv_conv(14, 4);
    // Each conv: M*P*Q*C*R*S = 4*14*14*4*9 (layer1: P=Q=14) + 4*12*12*4*9.
    let expected = 4 * 14 * 14 * 4 * 9 + 4 * 12 * 12 * 4 * 9;
    assert_eq!(fs.total_ops(), expected);
}

#[test]
fn algmin_transfers() {
    let fs = workloads::conv_conv(14, 4);
    // Fmap1 + Filter1 + Filter2 + Fmap3; Fmap2 is an intermediate.
    let expected = 4 * 16 * 16 + 4 * 4 * 9 + 4 * 4 * 9 + 4 * 12 * 12;
    assert_eq!(fs.algmin_offchip_elems(), expected);
}

#[test]
fn producer_consumer_wiring() {
    let fs = workloads::pwise_dwise_pwise(14, 8);
    let inter = fs.tensors_of_kind(TensorKind::Intermediate);
    for &t in &inter {
        let p = fs.producer_of(t).unwrap();
        let c = fs.consumers_of(t);
        assert_eq!(c, vec![p + 1]);
    }
}

#[test]
fn batched_workloads_validate() {
    assert!(workloads::alexnet_convs_batched(16).validate().is_ok());
    assert!(workloads::vgg_a_convs_batched(8).validate().is_ok());
    assert!(workloads::mnist_convs_batched(32, 2).validate().is_ok());
    assert!(workloads::fsrcnn(64).validate().is_ok());
    assert!(workloads::mc_cnn(64).validate().is_ok());
    assert!(workloads::vgg_e_first_two().validate().is_ok());
    for i in [1, 2, 3, 5] {
        assert!(workloads::vgg1_layer(i).validate().is_ok());
    }
}

#[test]
fn reduction_dims_conv() {
    let fs = workloads::conv_conv(14, 4);
    let e = &fs.einsums[0];
    // Output access is [M,P,Q] => reductions are C,R,S (dims 3,4,5).
    assert_eq!(e.reduction_dims(), vec![3, 4, 5]);
    assert_eq!(e.reduction_extent(), 4 * 3 * 3);
}
