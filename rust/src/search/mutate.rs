//! Random mapping generation and mutation operators for the stochastic
//! searches.

use crate::einsum::{FusionSet, TensorId, TensorKind};
use crate::mapping::{InterLayerMapping, Parallelism, Partition};
use crate::util::prng::Prng;

/// Sample a uniformly random (valid) mapping: up to 3 partitioned ranks with
/// power-of-two-ish tiles, random retention, random parallelism.
pub fn random_mapping(fs: &FusionSet, rng: &mut Prng) -> InterLayerMapping {
    let last = fs.last();
    let nparts = rng.index(4);
    let mut dims: Vec<usize> = (0..last.ndim())
        .filter(|&d| last.rank_sizes[d] > 1)
        .collect();
    rng.shuffle(&mut dims);
    let mut partitions = Vec::new();
    for &dim in dims.iter().take(nparts) {
        let extent = last.rank_sizes[dim];
        let mut tile = 1i64 << rng.index(8);
        tile = tile.min(extent);
        partitions.push(Partition { dim, tile });
    }
    let parallelism = if rng.chance(0.5) {
        Parallelism::Sequential
    } else {
        Parallelism::Pipeline
    };
    let k = partitions.len();
    let mut m = InterLayerMapping::tiled(partitions, parallelism);
    for (x, t) in fs.tensors.iter().enumerate() {
        if t.kind != TensorKind::OutputFmap && rng.chance(0.7) {
            m = m.with_retention(TensorId(x), rng.index(k + 1));
        }
    }
    m
}

/// Mutate one aspect of a mapping: tile size, retention level, schedule
/// order, partition set, or parallelism. Always returns a valid mapping.
pub fn mutate(fs: &FusionSet, m: &InterLayerMapping, rng: &mut Prng) -> InterLayerMapping {
    let last = fs.last();
    for _attempt in 0..8 {
        let mut out = m.clone();
        match rng.index(5) {
            // Scale a tile size up/down.
            0 if !out.partitions.is_empty() => {
                let i = rng.index(out.partitions.len());
                let p = &mut out.partitions[i];
                let extent = last.rank_sizes[p.dim];
                p.tile = if rng.chance(0.5) {
                    (p.tile * 2).min(extent)
                } else {
                    (p.tile / 2).max(1)
                };
            }
            // Change one tensor's retention level. Only non-output tensors
            // carry retention choices: the final output fmap is streamed to
            // off-chip, so `random_mapping` never assigns it retention and
            // mutation must not re-introduce it.
            1 => {
                let candidates: Vec<usize> = (0..fs.tensors.len())
                    .filter(|&x| fs.tensors[x].kind != TensorKind::OutputFmap)
                    .collect();
                if !candidates.is_empty() {
                    let x = *rng.choose(&candidates);
                    let k = out.partitions.len();
                    out.retention.insert(TensorId(x), rng.index(k + 1));
                }
            }
            // Swap two schedule levels.
            2 if out.partitions.len() >= 2 => {
                let i = rng.index(out.partitions.len());
                let j = rng.index(out.partitions.len());
                out.partitions.swap(i, j);
                clamp_retention(&mut out);
            }
            // Add or remove a partitioned rank.
            3 => {
                if out.partitions.len() < 3 && rng.chance(0.6) {
                    let candidates: Vec<usize> = (0..last.ndim())
                        .filter(|&d| {
                            last.rank_sizes[d] > 1
                                && !out.partitions.iter().any(|p| p.dim == d)
                        })
                        .collect();
                    if !candidates.is_empty() {
                        let dim = *rng.choose(&candidates);
                        let tile = (1i64 << rng.index(6)).min(last.rank_sizes[dim]);
                        let pos = rng.index(out.partitions.len() + 1);
                        out.partitions.insert(pos, Partition { dim, tile });
                    }
                } else if !out.partitions.is_empty() {
                    let i = rng.index(out.partitions.len());
                    out.partitions.remove(i);
                    clamp_retention(&mut out);
                }
            }
            // Flip parallelism.
            _ => {
                out.parallelism = match out.parallelism {
                    Parallelism::Sequential => Parallelism::Pipeline,
                    Parallelism::Pipeline => Parallelism::Sequential,
                };
            }
        }
        clamp_retention(&mut out);
        if out.validate(fs).is_ok() {
            return out;
        }
    }
    m.clone()
}

/// Clamp retention levels to the (possibly shrunk) number of levels.
fn clamp_retention(m: &mut InterLayerMapping) {
    let k = m.partitions.len();
    m.default_retention = m.default_retention.min(k);
    for lvl in m.retention.values_mut() {
        *lvl = (*lvl).min(k);
    }
}
