//! Mapspace search algorithms (paper §VII-C: prior search strategies can be
//! adapted to the LoopTree mapspace using LoopTree as the model).
//!
//! Four searches over the same objective interface:
//! * [`exhaustive`] — enumerate + evaluate everything (parallel).
//! * [`random_search`] — uniform sampling, for very large spaces.
//! * [`annealing`] — simulated annealing with mapping mutations.
//! * [`genetic`] — GAMMA-style [49] population search.
//!
//! Objectives are `Fn(&Metrics) -> f64` (minimize); infeasible mappings
//! (capacity overflow) can be filtered or penalized by the objective.

mod mutate;

use crate::arch::Arch;
use crate::coordinator::Coordinator;
use crate::einsum::FusionSet;
use crate::mapping::InterLayerMapping;
use crate::mapspace::{MapSpace, MapSpaceConfig};
use crate::model::{evaluate, EvalOptions, Metrics};
use crate::util::prng::Prng;

pub use mutate::{mutate, random_mapping};

/// A scored mapping.
#[derive(Debug, Clone)]
pub struct Scored {
    pub mapping: InterLayerMapping,
    pub metrics: Metrics,
    pub score: f64,
}

/// Result of a search: the best point plus everything evaluated (for Pareto
/// extraction).
#[derive(Debug, Clone)]
pub struct SearchResult {
    pub best: Scored,
    pub evaluated: Vec<Scored>,
}

fn score_all(
    fs: &FusionSet,
    arch: &Arch,
    mappings: &[InterLayerMapping],
    objective: &(dyn Fn(&Metrics) -> f64 + Sync),
    pool: &Coordinator,
) -> Vec<Scored> {
    let opts = EvalOptions::default();
    pool.evaluate_all(fs, arch, mappings, &opts)
        .into_iter()
        .zip(mappings)
        .filter_map(|(r, m)| {
            r.ok().map(|metrics| {
                let score = objective(&metrics);
                Scored { mapping: m.clone(), metrics, score }
            })
        })
        .collect()
}

fn best_of(evaluated: Vec<Scored>) -> Option<SearchResult> {
    let best = evaluated
        .iter()
        .min_by(|a, b| a.score.partial_cmp(&b.score).unwrap())?
        .clone();
    Some(SearchResult { best, evaluated })
}

/// Exhaustive search over an enumerated mapspace.
pub fn exhaustive(
    fs: &FusionSet,
    arch: &Arch,
    cfg: &MapSpaceConfig,
    objective: impl Fn(&Metrics) -> f64 + Sync,
    pool: &Coordinator,
) -> Option<SearchResult> {
    let ms = MapSpace::enumerate(fs, cfg);
    best_of(score_all(fs, arch, ms.mappings(), &objective, pool))
}

/// Uniform random sampling of `samples` mappings.
pub fn random_search(
    fs: &FusionSet,
    arch: &Arch,
    samples: usize,
    seed: u64,
    objective: impl Fn(&Metrics) -> f64 + Sync,
    pool: &Coordinator,
) -> Option<SearchResult> {
    let mut rng = Prng::new(seed);
    let mappings: Vec<InterLayerMapping> =
        (0..samples).map(|_| random_mapping(fs, &mut rng)).collect();
    best_of(score_all(fs, arch, &mappings, &objective, pool))
}

/// Simulated annealing (SET [29] uses the same strategy for inter-layer
/// scheduling). Serial by nature; `iters` model evaluations.
pub fn annealing(
    fs: &FusionSet,
    arch: &Arch,
    iters: usize,
    seed: u64,
    objective: impl Fn(&Metrics) -> f64,
) -> Option<SearchResult> {
    let mut rng = Prng::new(seed);
    let opts = EvalOptions::default();
    let mut cur = random_mapping(fs, &mut rng);
    let mut cur_metrics = evaluate(fs, arch, &cur, &opts).ok()?;
    let mut cur_score = objective(&cur_metrics);
    let mut best = Scored { mapping: cur.clone(), metrics: cur_metrics.clone(), score: cur_score };
    let mut evaluated = vec![best.clone()];

    let t0 = (cur_score.abs() + 1.0) * 0.3;
    for i in 0..iters {
        let temp = t0 * (1.0 - i as f64 / iters as f64).max(1e-3);
        let cand = mutate(fs, &cur, &mut rng);
        let Ok(metrics) = evaluate(fs, arch, &cand, &opts) else {
            continue;
        };
        let score = objective(&metrics);
        evaluated.push(Scored { mapping: cand.clone(), metrics: metrics.clone(), score });
        let accept = score <= cur_score
            || rng.chance(((cur_score - score) / temp).exp().clamp(0.0, 1.0));
        if accept {
            cur = cand;
            cur_metrics = metrics;
            cur_score = score;
            if cur_score < best.score {
                best = Scored {
                    mapping: cur.clone(),
                    metrics: cur_metrics.clone(),
                    score: cur_score,
                };
            }
        }
    }
    Some(SearchResult { best, evaluated })
}

/// Genetic search: tournament selection + mutation (no crossover across
/// schedules — tile sizes and retention levels recombine).
pub fn genetic(
    fs: &FusionSet,
    arch: &Arch,
    population: usize,
    generations: usize,
    seed: u64,
    objective: impl Fn(&Metrics) -> f64 + Sync,
    pool: &Coordinator,
) -> Option<SearchResult> {
    let mut rng = Prng::new(seed);
    let mut pop: Vec<InterLayerMapping> =
        (0..population).map(|_| random_mapping(fs, &mut rng)).collect();
    let mut all: Vec<Scored> = Vec::new();

    for _gen in 0..generations {
        let scored = score_all(fs, arch, &pop, &objective, pool);
        if scored.is_empty() {
            return None;
        }
        all.extend(scored.iter().cloned());
        // Tournament selection + mutation into the next generation.
        let mut next = Vec::with_capacity(population);
        // Elitism: keep the best.
        let elite = scored
            .iter()
            .min_by(|a, b| a.score.partial_cmp(&b.score).unwrap())
            .unwrap();
        next.push(elite.mapping.clone());
        while next.len() < population {
            let a = rng.choose(&scored);
            let b = rng.choose(&scored);
            let parent = if a.score <= b.score { a } else { b };
            next.push(mutate(fs, &parent.mapping, &mut rng));
        }
        pop = next;
    }
    best_of(all)
}

#[cfg(test)]
mod tests;
