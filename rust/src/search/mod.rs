//! Mapspace search (paper §VII-C: prior search strategies can be adapted to
//! the LoopTree mapspace using LoopTree as the model).
//!
//! One entry point, [`run`], drives four algorithms over a shared
//! [`Evaluator`] session:
//!
//! * [`Algorithm::Exhaustive`] — enumerate + evaluate everything (parallel).
//! * [`Algorithm::Random`] — uniform sampling, for very large spaces.
//! * [`Algorithm::Annealing`] — simulated annealing with mapping mutations.
//! * [`Algorithm::Genetic`] — GAMMA-style [49] population search.
//!
//! What to minimize is a serializable [`Objective`] (no ad-hoc closures), so
//! a whole search — workload, architecture, algorithm, objective, budgets —
//! round-trips through the JSON spec layer (`spec`) and the CLI. Score
//! comparisons use [`f64::total_cmp`], so a degenerate objective value can
//! never panic mid-search.

mod mutate;

use crate::coordinator::Coordinator;
use crate::einsum::FusionSet;
use crate::mapping::InterLayerMapping;
use crate::mapspace::{MapSpace, MapSpaceConfig};
use crate::model::{Evaluator, Metrics};
use crate::util::prng::Prng;

pub use mutate::{mutate, random_mapping};

/// What a search minimizes, derived from [`Metrics`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Objective {
    /// Total latency in cycles.
    Latency,
    /// Total energy in pJ.
    Energy,
    /// Energy–delay product.
    Edp,
    /// Peak buffer occupancy in elements (capacity-focused studies).
    Capacity,
    /// Total off-chip transfers in elements (reads + writes) — the paper's
    /// Fig 15 metric, and the natural additive objective for network-level
    /// partitioning (per-segment transfers sum to the network total).
    Offchip,
    /// Energy–delay product with capacity-infeasible mappings pushed to the
    /// back of the ranking by a large multiplicative penalty — the default
    /// for searches under a real GLB budget.
    FeasibleEdp,
}

impl Objective {
    /// Multiplier applied to infeasible mappings by [`Objective::FeasibleEdp`].
    pub const INFEASIBLE_PENALTY: f64 = 1e6;

    /// The scalar score (lower is better).
    pub fn score(&self, m: &Metrics) -> f64 {
        match self {
            Objective::Latency => m.latency_cycles as f64,
            Objective::Energy => m.energy.total_pj(),
            Objective::Edp => m.latency_cycles as f64 * m.energy.total_pj(),
            Objective::Capacity => m.occupancy_peak as f64,
            Objective::Offchip => m.offchip_total() as f64,
            Objective::FeasibleEdp => {
                let penalty = if m.capacity_ok { 1.0 } else { Self::INFEASIBLE_PENALTY };
                penalty * (m.latency_cycles as f64 * m.energy.total_pj())
            }
        }
    }

    /// Stable wire name (the JSON spec layer and the CLI use these).
    pub fn name(&self) -> &'static str {
        match self {
            Objective::Latency => "latency",
            Objective::Energy => "energy",
            Objective::Edp => "edp",
            Objective::Capacity => "capacity",
            Objective::Offchip => "offchip",
            Objective::FeasibleEdp => "feasible-edp",
        }
    }

    /// Inverse of [`Objective::name`].
    pub fn parse(s: &str) -> Result<Objective, String> {
        match s {
            "latency" => Ok(Objective::Latency),
            "energy" => Ok(Objective::Energy),
            "edp" => Ok(Objective::Edp),
            "capacity" => Ok(Objective::Capacity),
            "offchip" => Ok(Objective::Offchip),
            "feasible-edp" => Ok(Objective::FeasibleEdp),
            other => Err(format!(
                "unknown objective {other} (expected latency|energy|edp|capacity|offchip|feasible-edp)"
            )),
        }
    }
}

/// The search strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Algorithm {
    /// Enumerate and evaluate the whole constrained mapspace (parallel).
    Exhaustive,
    /// Uniform random sampling, for very large spaces.
    Random,
    /// Simulated annealing with mapping mutations (serial).
    Annealing,
    /// GAMMA-style population search: tournament selection + mutation.
    Genetic,
}

impl Algorithm {
    /// Stable wire name (the JSON spec layer and the CLI use these).
    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::Exhaustive => "exhaustive",
            Algorithm::Random => "random",
            Algorithm::Annealing => "annealing",
            Algorithm::Genetic => "genetic",
        }
    }

    /// Inverse of [`Algorithm::name`].
    pub fn parse(s: &str) -> Result<Algorithm, String> {
        match s {
            "exhaustive" => Ok(Algorithm::Exhaustive),
            "random" => Ok(Algorithm::Random),
            "annealing" | "anneal" => Ok(Algorithm::Annealing),
            "genetic" => Ok(Algorithm::Genetic),
            other => Err(format!(
                "unknown algorithm {other} (expected exhaustive|random|annealing|genetic)"
            )),
        }
    }
}

/// A complete, serializable search specification: algorithm, objective,
/// seed, per-algorithm budgets, and the mapspace constraints (exhaustive
/// only). Unused fields are ignored by the other algorithms.
#[derive(Debug, Clone, PartialEq)]
pub struct SearchSpec {
    /// Which search algorithm drives the exploration.
    pub algorithm: Algorithm,
    /// The scalar objective being minimized.
    pub objective: Objective,
    /// PRNG seed (random / annealing / genetic): same spec ⇒ same result.
    /// Round-trips JSON exactly for any u64 (seeds above 2^53 are carried
    /// as strings on the wire).
    pub seed: u64,
    /// Samples drawn by [`Algorithm::Random`].
    pub samples: usize,
    /// Model evaluations spent by [`Algorithm::Annealing`].
    pub iters: usize,
    /// Population size of [`Algorithm::Genetic`].
    pub population: usize,
    /// Generations run by [`Algorithm::Genetic`].
    pub generations: usize,
    /// Mapspace constraints enumerated by [`Algorithm::Exhaustive`].
    pub mapspace: MapSpaceConfig,
    /// Multiply the score of capacity-infeasible mappings by
    /// [`Objective::INFEASIBLE_PENALTY`] regardless of objective (default
    /// true), so searches under a real GLB budget rank feasible mappings
    /// first. [`Objective::FeasibleEdp`] already penalizes; this flag extends
    /// the same treatment to the plain objectives.
    pub penalize_infeasible: bool,
    /// Skip provably capacity-infeasible candidates before evaluation
    /// (default true). Applies to the batch algorithms (exhaustive, random)
    /// when infeasibility is penalized and the architecture has a GLB
    /// budget; a guard re-evaluates everything whenever skipping could
    /// change the ranking, so results are bit-identical either way.
    pub prune: bool,
}

impl Default for SearchSpec {
    fn default() -> Self {
        SearchSpec {
            algorithm: Algorithm::Exhaustive,
            objective: Objective::FeasibleEdp,
            seed: 1,
            samples: 2000,
            iters: 2000,
            population: 40,
            generations: 25,
            mapspace: MapSpaceConfig::default(),
            penalize_infeasible: true,
            prune: true,
        }
    }
}

impl SearchSpec {
    /// The score a search ranks by: the objective's score, with the
    /// infeasibility penalty applied when `penalize_infeasible` is set (and
    /// the objective does not already penalize).
    pub fn score(&self, m: &Metrics) -> f64 {
        self.score_objective(self.objective, m)
    }

    /// Score `m` under an arbitrary objective with this spec's penalty
    /// policy — the per-axis cost of the network-level Pareto front, which
    /// must match the scalar path bit for bit when the axis objective is the
    /// spec's own.
    pub fn score_objective(&self, objective: Objective, m: &Metrics) -> f64 {
        let base = objective.score(m);
        if self.penalize_infeasible && objective != Objective::FeasibleEdp && !m.capacity_ok {
            base * Objective::INFEASIBLE_PENALTY
        } else {
            base
        }
    }
}

/// A scored mapping.
#[derive(Debug, Clone)]
pub struct Scored {
    /// The mapping that was evaluated.
    pub mapping: InterLayerMapping,
    /// Its full evaluation metrics.
    pub metrics: Metrics,
    /// Its scalar score under the search's objective (lower is better).
    pub score: f64,
}

/// Result of a search: the best point plus everything evaluated (for Pareto
/// extraction).
#[derive(Debug, Clone)]
pub struct SearchResult {
    /// The minimum-score evaluated mapping.
    pub best: Scored,
    /// Every successfully evaluated candidate, in evaluation order.
    pub evaluated: Vec<Scored>,
    /// Candidates skipped without evaluation because the closed-form
    /// capacity lower bound proved them infeasible (see [`SearchSpec::prune`]).
    pub pruned: usize,
    /// How many evaluated candidates ran entirely on the tier-1 symbolic
    /// box walk ([`Metrics::path`]) — a diagnostic of how often the
    /// closed-form evaluator carries the search.
    pub symbolic_evals: usize,
    /// Symbolic attempts the session skipped during this search because an
    /// identical mapping had already refused mid-walk
    /// ([`Evaluator::refusal_memo_hits`]). Diagnostic only, and *not* part
    /// of the serialized search document: parallel batches may race the
    /// first refusal of duplicate candidates, so the count is
    /// run-to-run stable only for serial searches.
    pub refusal_memo_hits: i64,
}

/// Count of evaluations that ran entirely on the symbolic box walk.
fn count_symbolic(evaluated: &[Scored]) -> usize {
    evaluated.iter().filter(|s| s.metrics.path.symbolic).count()
}

/// Run a search described by `spec` on an [`Evaluator`] session. Returns
/// `None` when nothing evaluable was found (empty mapspace or every
/// candidate structurally invalid). Deterministic given (session, spec):
/// PRNG-driven algorithms derive all randomness from `spec.seed`.
pub fn run(ev: &Evaluator, spec: &SearchSpec, pool: &Coordinator) -> Option<SearchResult> {
    run_warm(ev, spec, pool, &[])
}

/// [`run`] with warm-start seeds for the stochastic algorithms: annealing
/// evaluates every seed up front and starts from the best one (instead of a
/// random draw), and genetic places the seeds at the head of generation 0.
/// Exhaustive and random searches enumerate/sample independently of any
/// starting point, so they ignore `warm`.
///
/// Seeds that fail to evaluate are dropped silently. An empty `warm` slice
/// is bit-identical to [`run`] — no PRNG state is consumed by seeding — so
/// the cold path is unchanged by construction. Because annealing's starting
/// point is the best evaluated seed and the seeds join `evaluated`, a
/// warm-started run whose seeds include a cold run's best mapping can never
/// report a worse best score than that cold run.
pub fn run_warm(
    ev: &Evaluator,
    spec: &SearchSpec,
    pool: &Coordinator,
    warm: &[InterLayerMapping],
) -> Option<SearchResult> {
    let memo_before = ev.refusal_memo_hits();
    let mut result = match spec.algorithm {
        Algorithm::Exhaustive => exhaustive(ev, spec, pool),
        Algorithm::Random => random(ev, spec, pool),
        Algorithm::Annealing => annealing(ev, spec, warm),
        Algorithm::Genetic => genetic(ev, spec, pool, warm),
    };
    if let Some(r) = result.as_mut() {
        r.refusal_memo_hits = ev.refusal_memo_hits() - memo_before;
    }
    result
}

fn score_all(
    ev: &Evaluator,
    mappings: &[InterLayerMapping],
    spec: &SearchSpec,
    pool: &Coordinator,
) -> Vec<Scored> {
    ev.evaluate_batch(mappings, pool)
        .into_iter()
        .zip(mappings)
        .filter_map(|(r, m)| {
            r.ok().map(|metrics| {
                let score = spec.score(&metrics);
                Scored { mapping: m.clone(), metrics, score }
            })
        })
        .collect()
}

fn best_of(evaluated: Vec<Scored>, pruned: usize) -> Option<SearchResult> {
    let best = evaluated
        .iter()
        .min_by(|a, b| a.score.total_cmp(&b.score))?
        .clone();
    let symbolic_evals = count_symbolic(&evaluated);
    Some(SearchResult { best, evaluated, pruned, symbolic_evals, refusal_memo_hits: 0 })
}

/// A provable lower bound on the score `mapping` would receive if evaluated,
/// given that its closed-form capacity lower bound is `cap_lb` and exceeds
/// the GLB budget (so the infeasibility penalty applies). Soundness: every
/// metric entering an objective is bounded below by the session floors
/// ([`Evaluator::floors`]), and penalized scores multiply by
/// [`Objective::INFEASIBLE_PENALTY`].
fn pruned_score_floor(
    ev: &Evaluator,
    spec: &SearchSpec,
    mapping: &InterLayerMapping,
    cap_lb: i64,
) -> f64 {
    let fl = ev.floors();
    let lat = match mapping.parallelism {
        crate::mapping::Parallelism::Sequential => fl.latency_seq,
        crate::mapping::Parallelism::Pipeline => fl.latency_pipe,
    } as f64;
    let base = match spec.objective {
        Objective::Latency => lat,
        Objective::Energy => fl.energy_pj,
        Objective::Edp | Objective::FeasibleEdp => lat * fl.energy_pj,
        Objective::Capacity => cap_lb as f64,
        Objective::Offchip => fl.offchip_elems as f64,
    };
    base * Objective::INFEASIBLE_PENALTY
}

/// [`score_all`] with provable capacity pruning (see [`SearchSpec::prune`]).
///
/// Candidates whose closed-form capacity lower bound already exceeds the
/// GLB budget would evaluate to a penalized score of at least
/// [`pruned_score_floor`]; when the best surviving score is *strictly*
/// below every pruned candidate's floor, no pruned candidate can win or
/// tie, so skipping them cannot change `best` (including its first-minimal
/// tie-breaking). Whenever that guard cannot be established — or nothing
/// is prunable — everything is evaluated in the original order, making the
/// result bit-identical to pruning disabled by construction.
fn score_all_pruned(
    ev: &Evaluator,
    mappings: &[InterLayerMapping],
    spec: &SearchSpec,
    pool: &Coordinator,
) -> (Vec<Scored>, usize) {
    let prunable = spec.prune
        && (spec.penalize_infeasible || spec.objective == Objective::FeasibleEdp);
    let cap = match (prunable, ev.arch().glb_capacity()) {
        (true, Some(cap)) => cap,
        _ => return (score_all(ev, mappings, spec, pool), 0),
    };
    let word = ev.arch().word_bytes;
    let mut survivors: Vec<InterLayerMapping> = Vec::with_capacity(mappings.len());
    let mut floors: Vec<f64> = Vec::new();
    for m in mappings {
        match ev.capacity_lower_bound(m) {
            // Provably infeasible: record the floor of its would-be score.
            Ok(lb) if lb.saturating_mul(word) > cap => {
                floors.push(pruned_score_floor(ev, spec, m, lb));
            }
            // Feasible-or-unknown (errors evaluate to the same error and are
            // dropped by `score_all` either way): evaluate normally.
            _ => survivors.push(m.clone()),
        }
    }
    if floors.is_empty() {
        return (score_all(ev, mappings, spec, pool), 0);
    }
    let scored = score_all(ev, &survivors, spec, pool);
    let best = scored.iter().map(|s| s.score).min_by(f64::total_cmp);
    let floor_min = floors.iter().copied().min_by(f64::total_cmp);
    if let (Some(bs), Some(fm)) = (best, floor_min) {
        if bs < fm {
            return (scored, floors.len());
        }
    }
    // Guard failed (a pruned candidate could plausibly rank first): fall
    // back to evaluating every candidate in the original order.
    (score_all(ev, mappings, spec, pool), 0)
}

/// Exhaustive search over the enumerated mapspace.
fn exhaustive(ev: &Evaluator, spec: &SearchSpec, pool: &Coordinator) -> Option<SearchResult> {
    let ms = MapSpace::enumerate(ev.fusion_set(), &spec.mapspace);
    let (scored, pruned) = score_all_pruned(ev, ms.mappings(), spec, pool);
    best_of(scored, pruned)
}

/// Uniform random sampling of `spec.samples` mappings.
fn random(ev: &Evaluator, spec: &SearchSpec, pool: &Coordinator) -> Option<SearchResult> {
    let mut rng = Prng::new(spec.seed);
    let mappings: Vec<InterLayerMapping> = (0..spec.samples)
        .map(|_| random_mapping(ev.fusion_set(), &mut rng))
        .collect();
    let (scored, pruned) = score_all_pruned(ev, &mappings, spec, pool);
    best_of(scored, pruned)
}

/// How many random mappings [`annealing`] samples before concluding that no
/// evaluable starting point exists. A single failed evaluation must not
/// abort the whole search — one bad draw is noise, not evidence the space
/// is empty.
const INITIAL_CANDIDATE_ATTEMPTS: usize = 64;

/// Draw random mappings until one evaluates, giving up after `attempts`
/// draws. Factored out of [`annealing`] so the retry policy is testable
/// against an evaluation function that fails intermittently.
fn initial_candidate<F>(
    fs: &FusionSet,
    rng: &mut Prng,
    attempts: usize,
    mut eval: F,
) -> Option<(InterLayerMapping, Metrics)>
where
    F: FnMut(&InterLayerMapping) -> Result<Metrics, String>,
{
    for _ in 0..attempts {
        let cand = random_mapping(fs, rng);
        if let Ok(metrics) = eval(&cand) {
            return Some((cand, metrics));
        }
    }
    None
}

/// Initial annealing temperature, derived from the *unpenalized* objective.
///
/// The acceptance test compares score differences against the temperature,
/// and scores of capacity-infeasible mappings carry the ×1e6
/// [`Objective::INFEASIBLE_PENALTY`]. Seeding `t0` from a penalized score
/// would set the temperature six orders of magnitude above any real score
/// difference, so every move — however bad — would be accepted for most of
/// the schedule and the search degenerates to a random walk. The temperature
/// therefore scales with the physical objective value only; the penalty
/// still applies to the scores being compared, so infeasible moves remain
/// strongly discouraged.
fn initial_temperature(spec: &SearchSpec, m: &Metrics) -> f64 {
    let raw = match spec.objective {
        Objective::FeasibleEdp => Objective::Edp.score(m),
        o => o.score(m),
    };
    (raw.abs() + 1.0) * 0.3
}

/// Simulated annealing (SET [29] uses the same strategy for inter-layer
/// scheduling). Serial by nature; `spec.iters` model evaluations.
/// Warm seeds (see [`run_warm`]) are evaluated up front without touching
/// the PRNG, and the best seed replaces the random starting draw.
fn annealing(
    ev: &Evaluator,
    spec: &SearchSpec,
    warm: &[InterLayerMapping],
) -> Option<SearchResult> {
    let fs = ev.fusion_set();
    let mut rng = Prng::new(spec.seed);
    let mut evaluated: Vec<Scored> = warm
        .iter()
        .filter_map(|m| {
            ev.evaluate(m).ok().map(|metrics| {
                let score = spec.score(&metrics);
                Scored { mapping: m.clone(), metrics, score }
            })
        })
        .collect();
    let seed_best = evaluated
        .iter()
        .min_by(|a, b| a.score.total_cmp(&b.score))
        .cloned();
    let (mut cur, mut cur_metrics) = match seed_best {
        Some(s) => (s.mapping, s.metrics),
        None => {
            let (c, m) =
                initial_candidate(fs, &mut rng, INITIAL_CANDIDATE_ATTEMPTS, |m| ev.evaluate(m))?;
            let score = spec.score(&m);
            evaluated.push(Scored { mapping: c.clone(), metrics: m.clone(), score });
            (c, m)
        }
    };
    let mut cur_score = spec.score(&cur_metrics);
    let mut best = Scored { mapping: cur.clone(), metrics: cur_metrics.clone(), score: cur_score };

    let t0 = initial_temperature(spec, &cur_metrics);
    for i in 0..spec.iters {
        let temp = t0 * (1.0 - i as f64 / spec.iters as f64).max(1e-3);
        let cand = mutate(fs, &cur, &mut rng);
        let Ok(metrics) = ev.evaluate(&cand) else {
            continue;
        };
        let score = spec.score(&metrics);
        evaluated.push(Scored { mapping: cand.clone(), metrics: metrics.clone(), score });
        let accept = score <= cur_score
            || rng.chance(((cur_score - score) / temp).exp().clamp(0.0, 1.0));
        if accept {
            cur = cand;
            cur_metrics = metrics;
            cur_score = score;
            if cur_score < best.score {
                best = Scored {
                    mapping: cur.clone(),
                    metrics: cur_metrics.clone(),
                    score: cur_score,
                };
            }
        }
    }
    // Annealing (and genetic below) never prune: their PRNG trajectories
    // consume state per evaluation, so skipping one would change every
    // subsequent draw.
    let symbolic_evals = count_symbolic(&evaluated);
    Some(SearchResult { best, evaluated, pruned: 0, symbolic_evals, refusal_memo_hits: 0 })
}

/// Genetic search: tournament selection + mutation (no crossover across
/// schedules — tile sizes and retention levels recombine).
/// Warm seeds (see [`run_warm`]) fill the head of generation 0; the
/// remainder is drawn randomly, so an empty seed set reproduces the cold
/// run's draws exactly.
fn genetic(
    ev: &Evaluator,
    spec: &SearchSpec,
    pool: &Coordinator,
    warm: &[InterLayerMapping],
) -> Option<SearchResult> {
    let fs = ev.fusion_set();
    let mut rng = Prng::new(spec.seed);
    let mut pop: Vec<InterLayerMapping> = warm.iter().take(spec.population).cloned().collect();
    while pop.len() < spec.population {
        pop.push(random_mapping(fs, &mut rng));
    }
    let mut all: Vec<Scored> = Vec::new();

    for _gen in 0..spec.generations {
        let scored = score_all(ev, &pop, spec, pool);
        if scored.is_empty() {
            return None;
        }
        all.extend(scored.iter().cloned());
        // Tournament selection + mutation into the next generation.
        let mut next = Vec::with_capacity(spec.population);
        // Elitism: keep the best.
        let elite = scored
            .iter()
            .min_by(|a, b| a.score.total_cmp(&b.score))
            .unwrap();
        next.push(elite.mapping.clone());
        while next.len() < spec.population {
            let a = rng.choose(&scored);
            let b = rng.choose(&scored);
            let parent = if a.score <= b.score { a } else { b };
            next.push(mutate(fs, &parent.mapping, &mut rng));
        }
        pop = next;
    }
    best_of(all, 0)
}

#[cfg(test)]
mod tests;
