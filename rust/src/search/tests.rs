use super::*;
use crate::einsum::workloads;
use crate::mapspace::MapSpaceConfig;

fn small_objective(m: &Metrics) -> f64 {
    // Capacity-weighted transfers: a common case-study objective.
    m.offchip_total() as f64 + 0.01 * m.occupancy_peak as f64
}

#[test]
fn exhaustive_finds_global_best() {
    let fs = workloads::conv_conv(14, 8);
    let arch = Arch::generic(1 << 20);
    let cfg = MapSpaceConfig {
        schedules: vec![vec![], vec!["P2".into()], vec!["C2".into()]],
        tile_sizes: vec![2, 4],
        ..Default::default()
    };
    let pool = Coordinator::new(2);
    let res = exhaustive(&fs, &arch, &cfg, small_objective, &pool).unwrap();
    // Best score really is the minimum of everything evaluated.
    let min = res
        .evaluated
        .iter()
        .map(|s| s.score)
        .fold(f64::INFINITY, f64::min);
    assert_eq!(res.best.score, min);
    assert!(!res.evaluated.is_empty());
}

#[test]
fn random_search_is_deterministic_per_seed() {
    let fs = workloads::conv_conv(14, 8);
    let arch = Arch::generic(1 << 20);
    let pool = Coordinator::new(2);
    let a = random_search(&fs, &arch, 40, 42, small_objective, &pool).unwrap();
    let b = random_search(&fs, &arch, 40, 42, small_objective, &pool).unwrap();
    assert_eq!(a.best.score, b.best.score);
    let c = random_search(&fs, &arch, 40, 43, small_objective, &pool).unwrap();
    // Different seed explores different mappings (scores may tie, but the
    // evaluated sets should differ).
    let sa: Vec<String> = a.evaluated.iter().map(|s| s.mapping.schedule_string(&fs)).collect();
    let sc: Vec<String> = c.evaluated.iter().map(|s| s.mapping.schedule_string(&fs)).collect();
    assert_ne!(sa, sc);
}

#[test]
fn annealing_improves_over_start() {
    let fs = workloads::conv_conv(14, 8);
    let arch = Arch::generic(1 << 20);
    let res = annealing(&fs, &arch, 120, 9, small_objective).unwrap();
    let first = res.evaluated.first().unwrap().score;
    assert!(res.best.score <= first);
    assert!(res.evaluated.len() > 10);
}

#[test]
fn genetic_converges_reasonably() {
    let fs = workloads::conv_conv(14, 8);
    let arch = Arch::generic(1 << 20);
    let pool = Coordinator::new(2);
    let res = genetic(&fs, &arch, 12, 5, 17, small_objective, &pool).unwrap();
    // The GA should find something at least as good as pure random with the
    // same budget.
    let rand = random_search(&fs, &arch, 60, 17, small_objective, &pool).unwrap();
    assert!(res.best.score <= rand.best.score * 1.5);
}

#[test]
fn mutation_preserves_validity() {
    let fs = workloads::pwise_dwise_pwise(14, 8);
    let mut rng = crate::util::prng::Prng::new(5);
    let mut m = random_mapping(&fs, &mut rng);
    for _ in 0..200 {
        m = mutate(&fs, &m, &mut rng);
        assert!(m.validate(&fs).is_ok());
    }
}
