use super::*;
use crate::arch::Arch;
use crate::einsum::workloads;

fn session(rows: i64, ch: i64, glb_kib: i64) -> Evaluator {
    let fs = workloads::conv_conv(rows, ch);
    let arch = Arch::generic(glb_kib);
    Evaluator::new(&fs, &arch).unwrap()
}

#[test]
fn exhaustive_finds_global_best() {
    let ev = session(14, 8, 1 << 20);
    let spec = SearchSpec {
        algorithm: Algorithm::Exhaustive,
        objective: Objective::Capacity,
        mapspace: MapSpaceConfig {
            schedules: vec![vec![], vec!["P2".into()], vec!["C2".into()]],
            tile_sizes: vec![2, 4],
            ..Default::default()
        },
        ..Default::default()
    };
    let pool = Coordinator::new(2);
    let res = run(&ev, &spec, &pool).unwrap();
    // Best score really is the minimum of everything evaluated.
    let min = res
        .evaluated
        .iter()
        .map(|s| s.score)
        .fold(f64::INFINITY, f64::min);
    assert_eq!(res.best.score, min);
    assert!(!res.evaluated.is_empty());
}

#[test]
fn random_search_is_deterministic_per_seed() {
    let ev = session(14, 8, 1 << 20);
    let pool = Coordinator::new(2);
    let spec = SearchSpec {
        algorithm: Algorithm::Random,
        objective: Objective::Edp,
        samples: 40,
        seed: 42,
        ..Default::default()
    };
    let a = run(&ev, &spec, &pool).unwrap();
    let b = run(&ev, &spec, &pool).unwrap();
    assert_eq!(a.best.score, b.best.score);
    assert_eq!(a.best.mapping, b.best.mapping);
    let c = run(&ev, &SearchSpec { seed: 43, ..spec }, &pool).unwrap();
    // Different seed explores different mappings (scores may tie, but the
    // evaluated sets should differ).
    let fs = ev.fusion_set();
    let sa: Vec<String> = a.evaluated.iter().map(|s| s.mapping.schedule_string(fs)).collect();
    let sc: Vec<String> = c.evaluated.iter().map(|s| s.mapping.schedule_string(fs)).collect();
    assert_ne!(sa, sc);
}

#[test]
fn annealing_improves_over_start() {
    let ev = session(14, 8, 1 << 20);
    let pool = Coordinator::new(1);
    let spec = SearchSpec {
        algorithm: Algorithm::Annealing,
        objective: Objective::Edp,
        iters: 120,
        seed: 9,
        ..Default::default()
    };
    let res = run(&ev, &spec, &pool).unwrap();
    let first = res.evaluated.first().unwrap().score;
    assert!(res.best.score <= first);
    assert!(res.evaluated.len() > 10);
}

#[test]
fn genetic_converges_reasonably() {
    let ev = session(14, 8, 1 << 20);
    let pool = Coordinator::new(2);
    let gen_spec = SearchSpec {
        algorithm: Algorithm::Genetic,
        objective: Objective::Edp,
        population: 12,
        generations: 5,
        seed: 17,
        ..Default::default()
    };
    let res = run(&ev, &gen_spec, &pool).unwrap();
    // The GA should find something at least as good as pure random with the
    // same budget.
    let rand_spec = SearchSpec {
        algorithm: Algorithm::Random,
        objective: Objective::Edp,
        samples: 60,
        seed: 17,
        ..Default::default()
    };
    let rand = run(&ev, &rand_spec, &pool).unwrap();
    assert!(res.best.score <= rand.best.score * 1.5);
}

#[test]
fn mutation_preserves_validity() {
    let fs = workloads::pwise_dwise_pwise(14, 8);
    let mut rng = crate::util::prng::Prng::new(5);
    let mut m = random_mapping(&fs, &mut rng);
    for _ in 0..200 {
        m = mutate(&fs, &m, &mut rng);
        assert!(m.validate(&fs).is_ok());
    }
}

// Regression (pre-fix: the retention mutation sampled *any* tensor, so a
// mutated mapping could carry retention for the output fmap, which
// `random_mapping` deliberately never assigns): across many seeded mutation
// chains on several workload shapes, every mapping must validate and no
// retention entry may name an output-fmap tensor.
#[test]
fn mutation_never_retains_output_fmap() {
    use crate::einsum::TensorKind;
    for fs in [
        workloads::conv_conv(14, 8),
        workloads::pwise_dwise_pwise(14, 8),
        workloads::self_attention(2, 2, 16, 8),
    ] {
        for seed in 0..8 {
            let mut rng = crate::util::prng::Prng::new(seed);
            let mut m = random_mapping(&fs, &mut rng);
            for _ in 0..300 {
                m = mutate(&fs, &m, &mut rng);
                assert!(m.validate(&fs).is_ok());
                for t in m.retention.keys() {
                    assert_ne!(
                        fs.tensor(*t).kind,
                        TensorKind::OutputFmap,
                        "{}: mutation assigned retention to output tensor {}",
                        fs.name,
                        fs.tensor(*t).name
                    );
                }
            }
        }
    }
}

// Regression (pre-fix: `annealing` evaluated exactly one random starting
// point and aborted the whole search via `.ok()?` when that single
// evaluation failed): the initial-candidate draw must retry up to the
// attempt budget before giving up.
#[test]
fn initial_candidate_retries_transient_failures() {
    let fs = workloads::conv_conv(14, 8);
    let mut rng = crate::util::prng::Prng::new(3);
    // Evaluation fails for the first 5 draws, then succeeds: a bounded
    // retry must still produce a starting point.
    let mut calls = 0;
    let got = initial_candidate(&fs, &mut rng, INITIAL_CANDIDATE_ATTEMPTS, |_| {
        calls += 1;
        if calls <= 5 {
            Err("transient".into())
        } else {
            Ok(Metrics::default())
        }
    });
    assert!(got.is_some(), "one failed evaluation must not abort the search");
    assert_eq!(calls, 6);
    // A persistently failing evaluator exhausts the budget and gives up
    // (rather than looping forever).
    let mut calls = 0;
    let got = initial_candidate(&fs, &mut rng, 7, |_| {
        calls += 1;
        Err("permanent".to_string())
    });
    assert!(got.is_none());
    assert_eq!(calls, 7);
}

// Regression (pre-fix: `t0` was derived from the *penalized* score, so a
// capacity-infeasible starting point inflated the temperature by the ×1e6
// penalty and the acceptance test degenerated to a random walk for most of
// the schedule): the initial temperature must come from the unpenalized
// objective, i.e. be identical whether or not the start is feasible.
#[test]
fn annealing_t0_ignores_infeasibility_penalty() {
    let ev = session(28, 32, 1); // 1 KiB GLB: the untiled mapping overflows
    let untiled = crate::mapping::InterLayerMapping::untiled(
        crate::mapping::Parallelism::Sequential,
    );
    let m = ev.evaluate(&untiled).unwrap();
    assert!(!m.capacity_ok);
    let mut feasible = m.clone();
    feasible.capacity_ok = true;

    let spec = SearchSpec { algorithm: Algorithm::Annealing, ..Default::default() };
    let t0 = initial_temperature(&spec, &m);
    assert_eq!(t0, initial_temperature(&spec, &feasible));
    assert_eq!(t0, (Objective::Edp.score(&m).abs() + 1.0) * 0.3);
    // The penalized derivation would be ~1e6× larger.
    assert!(t0 < spec.score(&m) * 0.3 / 1e5);

    // Plain objectives under the spec-level penalty flag behave the same.
    let lat = SearchSpec { objective: Objective::Latency, ..Default::default() };
    assert_eq!(
        initial_temperature(&lat, &m),
        (m.latency_cycles as f64 + 1.0) * 0.3
    );
}

// The stochastic searches must complete on a workload where most random
// mappings blow the GLB budget (the regime that used to trip both the
// initial-candidate abort and the temperature blowup).
#[test]
fn stochastic_searches_succeed_across_seeds() {
    let ev = session(14, 8, 1); // 1 KiB GLB: nearly everything is infeasible
    let pool = Coordinator::new(1);
    for seed in 0..100 {
        let ann = SearchSpec {
            algorithm: Algorithm::Annealing,
            iters: 30,
            seed,
            ..Default::default()
        };
        let res = run(&ev, &ann, &pool);
        assert!(res.is_some(), "annealing seed {seed} produced no result");
        let gen_spec = SearchSpec {
            algorithm: Algorithm::Genetic,
            population: 8,
            generations: 3,
            seed,
            ..Default::default()
        };
        let res = run(&ev, &gen_spec, &pool);
        assert!(res.is_some(), "genetic seed {seed} produced no result");
    }
}

#[test]
fn objective_scores_and_penalty() {
    let ev = session(28, 32, 1); // 1 KiB GLB: untiled mappings overflow
    let untiled = crate::mapping::InterLayerMapping::untiled(
        crate::mapping::Parallelism::Sequential,
    );
    let m = ev.evaluate(&untiled).unwrap();
    assert!(!m.capacity_ok);
    let edp = Objective::Edp.score(&m);
    let feasible = Objective::FeasibleEdp.score(&m);
    assert_eq!(feasible, edp * Objective::INFEASIBLE_PENALTY);
    assert_eq!(Objective::Latency.score(&m), m.latency_cycles as f64);
    assert_eq!(Objective::Energy.score(&m), m.energy.total_pj());
    assert_eq!(Objective::Capacity.score(&m), m.occupancy_peak as f64);
    assert_eq!(
        Objective::Offchip.score(&m),
        (m.offchip_reads + m.offchip_writes) as f64
    );
    // SearchSpec-level penalty (the old CLI semantics): plain objectives are
    // penalized too unless explicitly disabled.
    let penalized = SearchSpec { objective: Objective::Latency, ..Default::default() };
    assert_eq!(
        penalized.score(&m),
        Objective::Latency.score(&m) * Objective::INFEASIBLE_PENALTY
    );
    let unpenalized = SearchSpec {
        objective: Objective::Latency,
        penalize_infeasible: false,
        ..Default::default()
    };
    assert_eq!(unpenalized.score(&m), m.latency_cycles as f64);
    // FeasibleEdp is not double-penalized by the spec-level flag.
    let feas = SearchSpec { objective: Objective::FeasibleEdp, ..Default::default() };
    assert_eq!(feas.score(&m), Objective::FeasibleEdp.score(&m));
}

#[test]
fn objective_and_algorithm_names_round_trip() {
    for o in [
        Objective::Latency,
        Objective::Energy,
        Objective::Edp,
        Objective::Capacity,
        Objective::Offchip,
        Objective::FeasibleEdp,
    ] {
        assert_eq!(Objective::parse(o.name()).unwrap(), o);
    }
    for a in [
        Algorithm::Exhaustive,
        Algorithm::Random,
        Algorithm::Annealing,
        Algorithm::Genetic,
    ] {
        assert_eq!(Algorithm::parse(a.name()).unwrap(), a);
    }
    assert!(Objective::parse("bogus").is_err());
    assert!(Algorithm::parse("bogus").is_err());
}
