use super::*;
use crate::arch::Arch;
use crate::einsum::workloads;

fn session(rows: i64, ch: i64, glb_kib: i64) -> Evaluator {
    let fs = workloads::conv_conv(rows, ch);
    let arch = Arch::generic(glb_kib);
    Evaluator::new(&fs, &arch).unwrap()
}

#[test]
fn exhaustive_finds_global_best() {
    let ev = session(14, 8, 1 << 20);
    let spec = SearchSpec {
        algorithm: Algorithm::Exhaustive,
        objective: Objective::Capacity,
        mapspace: MapSpaceConfig {
            schedules: vec![vec![], vec!["P2".into()], vec!["C2".into()]],
            tile_sizes: vec![2, 4],
            ..Default::default()
        },
        ..Default::default()
    };
    let pool = Coordinator::new(2);
    let res = run(&ev, &spec, &pool).unwrap();
    // Best score really is the minimum of everything evaluated.
    let min = res
        .evaluated
        .iter()
        .map(|s| s.score)
        .fold(f64::INFINITY, f64::min);
    assert_eq!(res.best.score, min);
    assert!(!res.evaluated.is_empty());
}

#[test]
fn random_search_is_deterministic_per_seed() {
    let ev = session(14, 8, 1 << 20);
    let pool = Coordinator::new(2);
    let spec = SearchSpec {
        algorithm: Algorithm::Random,
        objective: Objective::Edp,
        samples: 40,
        seed: 42,
        ..Default::default()
    };
    let a = run(&ev, &spec, &pool).unwrap();
    let b = run(&ev, &spec, &pool).unwrap();
    assert_eq!(a.best.score, b.best.score);
    assert_eq!(a.best.mapping, b.best.mapping);
    let c = run(&ev, &SearchSpec { seed: 43, ..spec }, &pool).unwrap();
    // Different seed explores different mappings (scores may tie, but the
    // evaluated sets should differ).
    let fs = ev.fusion_set();
    let sa: Vec<String> = a.evaluated.iter().map(|s| s.mapping.schedule_string(fs)).collect();
    let sc: Vec<String> = c.evaluated.iter().map(|s| s.mapping.schedule_string(fs)).collect();
    assert_ne!(sa, sc);
}

#[test]
fn annealing_improves_over_start() {
    let ev = session(14, 8, 1 << 20);
    let pool = Coordinator::new(1);
    let spec = SearchSpec {
        algorithm: Algorithm::Annealing,
        objective: Objective::Edp,
        iters: 120,
        seed: 9,
        ..Default::default()
    };
    let res = run(&ev, &spec, &pool).unwrap();
    let first = res.evaluated.first().unwrap().score;
    assert!(res.best.score <= first);
    assert!(res.evaluated.len() > 10);
}

#[test]
fn genetic_converges_reasonably() {
    let ev = session(14, 8, 1 << 20);
    let pool = Coordinator::new(2);
    let gen_spec = SearchSpec {
        algorithm: Algorithm::Genetic,
        objective: Objective::Edp,
        population: 12,
        generations: 5,
        seed: 17,
        ..Default::default()
    };
    let res = run(&ev, &gen_spec, &pool).unwrap();
    // The GA should find something at least as good as pure random with the
    // same budget.
    let rand_spec = SearchSpec {
        algorithm: Algorithm::Random,
        objective: Objective::Edp,
        samples: 60,
        seed: 17,
        ..Default::default()
    };
    let rand = run(&ev, &rand_spec, &pool).unwrap();
    assert!(res.best.score <= rand.best.score * 1.5);
}

#[test]
fn mutation_preserves_validity() {
    let fs = workloads::pwise_dwise_pwise(14, 8);
    let mut rng = crate::util::prng::Prng::new(5);
    let mut m = random_mapping(&fs, &mut rng);
    for _ in 0..200 {
        m = mutate(&fs, &m, &mut rng);
        assert!(m.validate(&fs).is_ok());
    }
}

#[test]
fn objective_scores_and_penalty() {
    let ev = session(28, 32, 1); // 1 KiB GLB: untiled mappings overflow
    let untiled = crate::mapping::InterLayerMapping::untiled(
        crate::mapping::Parallelism::Sequential,
    );
    let m = ev.evaluate(&untiled).unwrap();
    assert!(!m.capacity_ok);
    let edp = Objective::Edp.score(&m);
    let feasible = Objective::FeasibleEdp.score(&m);
    assert_eq!(feasible, edp * Objective::INFEASIBLE_PENALTY);
    assert_eq!(Objective::Latency.score(&m), m.latency_cycles as f64);
    assert_eq!(Objective::Energy.score(&m), m.energy.total_pj());
    assert_eq!(Objective::Capacity.score(&m), m.occupancy_peak as f64);
    // SearchSpec-level penalty (the old CLI semantics): plain objectives are
    // penalized too unless explicitly disabled.
    let penalized = SearchSpec { objective: Objective::Latency, ..Default::default() };
    assert_eq!(
        penalized.score(&m),
        Objective::Latency.score(&m) * Objective::INFEASIBLE_PENALTY
    );
    let unpenalized = SearchSpec {
        objective: Objective::Latency,
        penalize_infeasible: false,
        ..Default::default()
    };
    assert_eq!(unpenalized.score(&m), m.latency_cycles as f64);
    // FeasibleEdp is not double-penalized by the spec-level flag.
    let feas = SearchSpec { objective: Objective::FeasibleEdp, ..Default::default() };
    assert_eq!(feas.score(&m), Objective::FeasibleEdp.score(&m));
}

#[test]
fn objective_and_algorithm_names_round_trip() {
    for o in [
        Objective::Latency,
        Objective::Energy,
        Objective::Edp,
        Objective::Capacity,
        Objective::FeasibleEdp,
    ] {
        assert_eq!(Objective::parse(o.name()).unwrap(), o);
    }
    for a in [
        Algorithm::Exhaustive,
        Algorithm::Random,
        Algorithm::Annealing,
        Algorithm::Genetic,
    ] {
        assert_eq!(Algorithm::parse(a.name()).unwrap(), a);
    }
    assert!(Objective::parse("bogus").is_err());
    assert!(Algorithm::parse("bogus").is_err());
}
