use super::*;
use crate::einsum::workloads;
use crate::mapping::Parallelism;

#[test]
fn enumeration_covers_untiled_and_tiled() {
    let fs = workloads::conv_conv(14, 8);
    let cfg = MapSpaceConfig {
        schedules: vec![vec![], vec!["P2".into()], vec!["P2".into(), "Q2".into()]],
        tile_sizes: vec![4],
        ..Default::default()
    };
    let ms = MapSpace::enumerate(&fs, &cfg);
    assert!(!ms.is_empty());
    // Untiled present.
    assert!(ms.mappings().iter().any(|m| m.partitions.is_empty()));
    // Two-level schedules present with per-tensor retention variants.
    assert!(ms.mappings().iter().any(|m| m.partitions.len() == 2));
    // All valid.
    for m in ms.mappings() {
        assert!(m.validate(&fs).is_ok());
    }
}

#[test]
fn uniform_retention_constrains_variants() {
    let fs = workloads::conv_conv(14, 8);
    let cfg_u = MapSpaceConfig {
        schedules: vec![vec!["P2".into()]],
        tile_sizes: vec![4],
        uniform_retention: true,
        ..Default::default()
    };
    let cfg_p = MapSpaceConfig {
        uniform_retention: false,
        ..cfg_u.clone()
    };
    let u = MapSpace::enumerate(&fs, &cfg_u);
    let p = MapSpace::enumerate(&fs, &cfg_p);
    // Per-tensor retention yields strictly more mappings.
    assert!(p.len() > u.len(), "per-tensor {} vs uniform {}", p.len(), u.len());
    // Uniform: k=1 => 2 retention levels per schedule point.
    assert_eq!(u.len(), 2);
}

#[test]
fn default_schedules_cover_rank_pairs() {
    let fs = workloads::fc_fc(32, 64);
    let cfg = MapSpaceConfig {
        tile_sizes: vec![8],
        max_mappings: 1_000_000,
        ..Default::default()
    };
    let ms = MapSpace::enumerate(&fs, &cfg);
    // fc last layer has 3 ranks (M2, E2, D2): untiled + 3 singles + 6 pairs.
    let schedules: std::collections::HashSet<String> = ms
        .mappings()
        .iter()
        .map(|m| m.schedule_string(&fs))
        .collect();
    assert!(schedules.contains("untiled"));
    assert!(schedules.contains("M2"));
    assert!(schedules.contains("M2,E2"));
    assert!(schedules.contains("E2,M2"));
    assert_eq!(schedules.len(), 1 + 3 + 6);
}

#[test]
fn max_mappings_cap_respected() {
    let fs = workloads::conv_conv(28, 32);
    let cfg = MapSpaceConfig {
        max_mappings: 100,
        ..Default::default()
    };
    let ms = MapSpace::enumerate(&fs, &cfg);
    assert_eq!(ms.len(), 100);
}

#[test]
fn parallelism_variants_enumerate() {
    let fs = workloads::conv_conv(14, 8);
    let cfg = MapSpaceConfig {
        schedules: vec![vec!["P2".into()]],
        tile_sizes: vec![4],
        uniform_retention: true,
        parallelism: vec![Parallelism::Sequential, Parallelism::Pipeline],
        ..Default::default()
    };
    let ms = MapSpace::enumerate(&fs, &cfg);
    assert!(ms.mappings().iter().any(|m| m.parallelism == Parallelism::Pipeline));
    assert!(ms.mappings().iter().any(|m| m.parallelism == Parallelism::Sequential));
}
