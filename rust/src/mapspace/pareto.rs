//! Pareto-front utilities for two-objective trade-off curves (the paper's
//! capacity-vs-recompute and capacity-vs-transfers figures).

/// A point on a 2-objective minimization trade-off with a payload.
#[derive(Debug, Clone)]
pub struct ParetoPoint<T> {
    pub x: f64,
    pub y: f64,
    pub payload: T,
}

/// Extract the Pareto front (minimizing both `x` and `y`), sorted by `x`
/// ascending. Dominated and duplicate points are dropped.
pub fn pareto_front<T: Clone>(mut points: Vec<ParetoPoint<T>>) -> Vec<ParetoPoint<T>> {
    points.sort_by(|a, b| a.x.total_cmp(&b.x).then(a.y.total_cmp(&b.y)));
    let mut front: Vec<ParetoPoint<T>> = Vec::new();
    let mut best_y = f64::INFINITY;
    for p in points {
        if p.y < best_y {
            best_y = p.y;
            front.push(p);
        }
    }
    front
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pt(x: f64, y: f64) -> ParetoPoint<()> {
        ParetoPoint { x, y, payload: () }
    }

    #[test]
    fn drops_dominated() {
        let front = pareto_front(vec![pt(1.0, 5.0), pt(2.0, 6.0), pt(3.0, 1.0)]);
        let coords: Vec<(f64, f64)> = front.iter().map(|p| (p.x, p.y)).collect();
        assert_eq!(coords, vec![(1.0, 5.0), (3.0, 1.0)]);
    }

    #[test]
    fn keeps_strictly_improving_chain() {
        let front = pareto_front(vec![pt(1.0, 3.0), pt(2.0, 2.0), pt(3.0, 1.0)]);
        assert_eq!(front.len(), 3);
    }

    #[test]
    fn duplicate_x_keeps_best_y() {
        let front = pareto_front(vec![pt(1.0, 3.0), pt(1.0, 2.0), pt(2.0, 2.5)]);
        let coords: Vec<(f64, f64)> = front.iter().map(|p| (p.x, p.y)).collect();
        assert_eq!(coords, vec![(1.0, 2.0)]);
    }

    #[test]
    fn empty_input() {
        let front = pareto_front::<()>(vec![]);
        assert!(front.is_empty());
    }
}
