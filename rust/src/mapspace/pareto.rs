//! Pareto-front utilities: the original two-objective front for the paper's
//! capacity-vs-recompute and capacity-vs-transfers figures, plus the
//! k-objective generalization used by the network-level front DP
//! (`network::search_network_pareto`).
//!
//! All comparisons go through [`f64::total_cmp`], so degenerate objective
//! values (NaN, infinities) order deterministically instead of panicking or
//! silently flipping results.

use std::cmp::Ordering;

/// A point on a 2-objective minimization trade-off with a payload.
#[derive(Debug, Clone)]
pub struct ParetoPoint<T> {
    /// First objective (minimized).
    pub x: f64,
    /// Second objective (minimized).
    pub y: f64,
    /// Carried value (e.g. the mapping).
    pub payload: T,
}

/// Extract the Pareto front (minimizing both `x` and `y`), sorted by `x`
/// ascending. Dominated and duplicate points are dropped.
pub fn pareto_front<T: Clone>(mut points: Vec<ParetoPoint<T>>) -> Vec<ParetoPoint<T>> {
    points.sort_by(|a, b| a.x.total_cmp(&b.x).then(a.y.total_cmp(&b.y)));
    let mut front: Vec<ParetoPoint<T>> = Vec::new();
    let mut best_y = f64::INFINITY;
    for p in points {
        if p.y < best_y {
            best_y = p.y;
            front.push(p);
        }
    }
    front
}

/// A point on a k-objective minimization trade-off with a payload. All
/// points of one front must share the same cost arity.
#[derive(Debug, Clone)]
pub struct ParetoPointK<T> {
    /// One value per objective; lower is better on every axis.
    pub costs: Vec<f64>,
    /// Carried value (e.g. the mapping).
    pub payload: T,
}

/// Lexicographic [`f64::total_cmp`] over equal-arity cost vectors — the
/// canonical deterministic ordering of front points.
pub fn cmp_costs(a: &[f64], b: &[f64]) -> Ordering {
    for (x, y) in a.iter().zip(b) {
        match x.total_cmp(y) {
            Ordering::Equal => continue,
            other => return other,
        }
    }
    a.len().cmp(&b.len())
}

/// Whether `a` dominates `b`: no worse on every axis, strictly better on at
/// least one (minimization, [`f64::total_cmp`] per axis). Equal vectors do
/// not dominate each other.
pub fn dominates(a: &[f64], b: &[f64]) -> bool {
    debug_assert_eq!(a.len(), b.len(), "dominance needs equal cost arity");
    let mut strict = false;
    for (x, y) in a.iter().zip(b) {
        match x.total_cmp(y) {
            Ordering::Greater => return false,
            Ordering::Less => strict = true,
            Ordering::Equal => {}
        }
    }
    strict
}

/// Extract the k-objective Pareto front, sorted lexicographically by cost
/// vector ([`cmp_costs`]). Dominated points are dropped; duplicate cost
/// vectors keep only the first in sorted order (the sort is stable, so ties
/// resolve to input order) — deterministic for any input permutation of
/// distinct points, and payload-preserving for the survivors.
pub fn pareto_front_k<T>(mut points: Vec<ParetoPointK<T>>) -> Vec<ParetoPointK<T>> {
    points.sort_by(|a, b| cmp_costs(&a.costs, &b.costs));
    let mut front: Vec<ParetoPointK<T>> = Vec::new();
    'next: for p in points {
        // A lexicographically later point can never dominate an earlier one
        // (it would have to be <= on every axis, hence sort before it), so
        // accepted points are final.
        for q in &front {
            if cmp_costs(&q.costs, &p.costs) == Ordering::Equal || dominates(&q.costs, &p.costs)
            {
                continue 'next;
            }
        }
        front.push(p);
    }
    front
}

/// Deterministically cap a (lexicographically sorted) Pareto front to at
/// most `cap` points; `cap == 0` means unbounded. With `cap >=` the cost
/// arity, the per-axis minimum of every objective is kept — capping thins
/// the interior of a front but never loses a single-objective optimum
/// (smaller caps keep the leading axes' minima only) — and the remaining
/// slots are filled evenly across the sorted front. Relative order is
/// preserved.
pub fn cap_front_k<T>(front: Vec<ParetoPointK<T>>, cap: usize) -> Vec<ParetoPointK<T>> {
    if cap == 0 || front.len() <= cap {
        return front;
    }
    let arity = front[0].costs.len();
    let mut keep = vec![false; front.len()];
    let mut kept = 0usize;
    for axis in 0..arity {
        if kept == cap {
            break;
        }
        let mut best = 0usize;
        for (i, p) in front.iter().enumerate() {
            if p.costs[axis].total_cmp(&front[best].costs[axis]) == Ordering::Less {
                best = i;
            }
        }
        if !keep[best] {
            keep[best] = true;
            kept += 1;
        }
    }
    let rest: Vec<usize> = (0..front.len()).filter(|&i| !keep[i]).collect();
    let want = cap - kept;
    if want > 0 {
        // len > cap ensures rest.len() >= want + 1, so the even spread below
        // picks strictly increasing (distinct) indices.
        let span = rest.len() - 1;
        for j in 0..want {
            let idx = if want == 1 { span / 2 } else { j * span / (want - 1) };
            keep[rest[idx]] = true;
        }
    }
    front
        .into_iter()
        .zip(keep)
        .filter_map(|(p, k)| k.then_some(p))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    fn pt(x: f64, y: f64) -> ParetoPoint<()> {
        ParetoPoint { x, y, payload: () }
    }

    fn ptk(costs: &[f64]) -> ParetoPointK<usize> {
        ParetoPointK { costs: costs.to_vec(), payload: 0 }
    }

    #[test]
    fn drops_dominated() {
        let front = pareto_front(vec![pt(1.0, 5.0), pt(2.0, 6.0), pt(3.0, 1.0)]);
        let coords: Vec<(f64, f64)> = front.iter().map(|p| (p.x, p.y)).collect();
        assert_eq!(coords, vec![(1.0, 5.0), (3.0, 1.0)]);
    }

    #[test]
    fn keeps_strictly_improving_chain() {
        let front = pareto_front(vec![pt(1.0, 3.0), pt(2.0, 2.0), pt(3.0, 1.0)]);
        assert_eq!(front.len(), 3);
    }

    #[test]
    fn duplicate_x_keeps_best_y() {
        let front = pareto_front(vec![pt(1.0, 3.0), pt(1.0, 2.0), pt(2.0, 2.5)]);
        let coords: Vec<(f64, f64)> = front.iter().map(|p| (p.x, p.y)).collect();
        assert_eq!(coords, vec![(1.0, 2.0)]);
    }

    #[test]
    fn empty_input() {
        let front = pareto_front::<()>(vec![]);
        assert!(front.is_empty());
    }

    // ------------------------------------------------- k-objective front --

    #[test]
    fn dominates_edge_cases() {
        assert!(dominates(&[1.0, 2.0], &[1.0, 3.0])); // tie on one axis
        assert!(dominates(&[1.0, 2.0], &[2.0, 3.0]));
        assert!(!dominates(&[1.0, 3.0], &[1.0, 3.0])); // equal: no dominance
        assert!(!dominates(&[1.0, 4.0], &[2.0, 3.0])); // incomparable
        assert!(!dominates(&[1.0, 3.0], &[1.0, 2.0]));
        // total_cmp ordering makes NaN comparisons well-defined (NaN sorts
        // above +inf, so a NaN axis is "worse" than any real value).
        assert!(dominates(&[1.0, 2.0], &[1.0, f64::NAN]));
        assert!(!dominates(&[1.0, f64::NAN], &[1.0, 2.0]));
    }

    #[test]
    fn front_k_dominance_and_ties() {
        let front = pareto_front_k(vec![
            ptk(&[2.0, 2.0, 5.0]),
            ptk(&[1.0, 3.0, 5.0]),
            ptk(&[2.0, 2.0, 6.0]), // dominated by the first (tie, tie, worse)
            ptk(&[3.0, 3.0, 5.0]), // dominated by the first
            ptk(&[5.0, 1.0, 5.0]),
        ]);
        let costs: Vec<&[f64]> = front.iter().map(|p| p.costs.as_slice()).collect();
        assert_eq!(
            costs,
            vec![&[1.0, 3.0, 5.0][..], &[2.0, 2.0, 5.0], &[5.0, 1.0, 5.0]]
        );
    }

    #[test]
    fn front_k_duplicates_keep_first_payload() {
        let front = pareto_front_k(vec![
            ParetoPointK { costs: vec![1.0, 2.0], payload: 7usize },
            ParetoPointK { costs: vec![1.0, 2.0], payload: 9usize },
        ]);
        assert_eq!(front.len(), 1);
        assert_eq!(front[0].payload, 7);
    }

    #[test]
    fn front_k_single_and_empty() {
        assert!(pareto_front_k::<()>(vec![]).is_empty());
        let one = pareto_front_k(vec![ptk(&[4.0, 2.0])]);
        assert_eq!(one.len(), 1);
    }

    #[test]
    fn front_k_order_is_input_permutation_invariant() {
        let pts = [
            [3.0, 1.0, 2.0],
            [1.0, 3.0, 2.0],
            [2.0, 2.0, 2.0],
            [4.0, 4.0, 4.0], // dominated
            [1.0, 3.0, 9.0], // dominated (tie, tie, worse)
        ];
        let as_points = |order: &[usize]| -> Vec<ParetoPointK<usize>> {
            order.iter().map(|&i| ptk(&pts[i])).collect()
        };
        let reference: Vec<Vec<f64>> = pareto_front_k(as_points(&[0, 1, 2, 3, 4]))
            .into_iter()
            .map(|p| p.costs)
            .collect();
        for order in [[4, 3, 2, 1, 0], [2, 0, 4, 1, 3], [1, 4, 0, 3, 2]] {
            let got: Vec<Vec<f64>> = pareto_front_k(as_points(&order))
                .into_iter()
                .map(|p| p.costs)
                .collect();
            assert_eq!(got, reference, "order {order:?}");
        }
        // And the output is lexicographically sorted.
        for w in reference.windows(2) {
            assert_eq!(cmp_costs(&w[0], &w[1]), std::cmp::Ordering::Less);
        }
    }

    // Property: on 2 objectives the k-front is exactly the legacy 2-front.
    #[test]
    fn front_k_matches_pareto_front_on_two_objectives() {
        let mut rng = Prng::new(0xC0FFEE);
        for case in 0..50 {
            let n = 1 + (rng.below(40) as usize);
            let pts2: Vec<ParetoPoint<usize>> = (0..n)
                .map(|i| ParetoPoint {
                    // Small integer grid to force plenty of ties/duplicates.
                    x: rng.below(8) as f64,
                    y: rng.below(8) as f64,
                    payload: i,
                })
                .collect();
            let ptsk: Vec<ParetoPointK<usize>> = pts2
                .iter()
                .map(|p| ParetoPointK { costs: vec![p.x, p.y], payload: p.payload })
                .collect();
            let f2: Vec<(f64, f64)> =
                pareto_front(pts2).into_iter().map(|p| (p.x, p.y)).collect();
            let fk: Vec<(f64, f64)> = pareto_front_k(ptsk)
                .into_iter()
                .map(|p| (p.costs[0], p.costs[1]))
                .collect();
            assert_eq!(fk, f2, "case {case}");
        }
    }

    #[test]
    fn cap_keeps_axis_minima_and_is_deterministic() {
        // A 2-objective staircase front of 10 points.
        let front: Vec<ParetoPointK<usize>> = (0..10)
            .map(|i| ParetoPointK {
                costs: vec![i as f64, (9 - i) as f64],
                payload: i,
            })
            .collect();
        let capped = cap_front_k(front.clone(), 4);
        assert_eq!(capped.len(), 4);
        // Both axis minima survive (the staircase endpoints).
        assert!(capped.iter().any(|p| p.costs[0] == 0.0));
        assert!(capped.iter().any(|p| p.costs[1] == 0.0));
        // Still sorted, and stable across calls.
        let again = cap_front_k(front.clone(), 4);
        let a: Vec<usize> = capped.iter().map(|p| p.payload).collect();
        let b: Vec<usize> = again.iter().map(|p| p.payload).collect();
        assert_eq!(a, b);
        for w in capped.windows(2) {
            assert!(cmp_costs(&w[0].costs, &w[1].costs) == std::cmp::Ordering::Less);
        }
        // cap = 0 and cap >= len are no-ops.
        assert_eq!(cap_front_k(front.clone(), 0).len(), 10);
        assert_eq!(cap_front_k(front, 10).len(), 10);
        // cap = 1 keeps the first axis minimum.
        let tiny = cap_front_k(
            (0..5)
                .map(|i| ParetoPointK { costs: vec![i as f64, (4 - i) as f64], payload: i })
                .collect::<Vec<_>>(),
            1,
        );
        assert_eq!(tiny.len(), 1);
        assert_eq!(tiny[0].costs[0], 0.0);
    }
}
