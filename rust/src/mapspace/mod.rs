//! The mapspace (paper §IV intro): enumeration of candidate mappings under
//! configurable constraints, plus Pareto-front utilities used throughout the
//! case studies.
//!
//! The constraints mirror the restricted design spaces of prior work
//! (paper Table I), so the case studies can compare "this work" against
//! e.g. uniform-retention or no-recompute subspaces by constraining the same
//! enumeration.

mod enumerate;
mod pareto;

pub use enumerate::{MapSpace, MapSpaceConfig};
pub use pareto::{
    cap_front_k, cmp_costs, dominates, pareto_front, pareto_front_k, ParetoPoint, ParetoPointK,
};

#[cfg(test)]
mod tests;
