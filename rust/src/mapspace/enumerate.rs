//! Mapping enumeration under constraints.

use crate::einsum::{FusionSet, TensorId, TensorKind};
use crate::mapping::{InterLayerMapping, Parallelism, Partition};
use crate::util::odometer::odometer_step;

/// Constraints defining a mapspace (the unconstrained default is the paper's
/// "this work" row in Table I).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MapSpaceConfig {
    /// Candidate schedules: ordered lists of last-layer rank *names*
    /// (e.g. `["P2","Q2"]`). Empty = derive all single- and double-rank
    /// schedules from the last layer's ranks.
    pub schedules: Vec<Vec<String>>,
    /// Candidate tile sizes per partitioned rank. Empty = powers of two up
    /// to the rank size (plus the size itself).
    pub tile_sizes: Vec<i64>,
    /// Force one retention level for every tensor (`Some` = the uniform
    /// retention constraint of prior work, paper Fig 16).
    pub uniform_retention: bool,
    /// If false, per-tensor retention levels are enumerated; if true only
    /// the levels are tied across tensors.
    pub parallelism: Vec<Parallelism>,
    /// Cap on enumerated mappings (guards exhaustive blowup).
    pub max_mappings: usize,
}

impl Default for MapSpaceConfig {
    fn default() -> Self {
        MapSpaceConfig {
            schedules: vec![],
            tile_sizes: vec![],
            uniform_retention: false,
            parallelism: vec![Parallelism::Sequential],
            max_mappings: 200_000,
        }
    }
}

/// An enumerated mapspace for one fusion set.
pub struct MapSpace {
    mappings: Vec<InterLayerMapping>,
}

impl MapSpace {
    /// Enumerate the mapspace.
    pub fn enumerate(fs: &FusionSet, cfg: &MapSpaceConfig) -> MapSpace {
        let last = fs.last();
        let schedules: Vec<Vec<usize>> = if cfg.schedules.is_empty() {
            default_schedules(fs)
        } else {
            cfg.schedules
                .iter()
                .map(|names| {
                    names
                        .iter()
                        .map(|n| {
                            last.rank_index(n)
                                .unwrap_or_else(|| panic!("unknown rank {n}"))
                        })
                        .collect()
                })
                .collect()
        };

        // Pre-size from the schedule/tile/retention counts so the push loop
        // never reallocates (the retention cross product dominates).
        let per_schedule_tiles: Vec<Vec<Vec<i64>>> = schedules
            .iter()
            .map(|sched| {
                sched
                    .iter()
                    .map(|&d| tile_choices(last.rank_sizes[d], &cfg.tile_sizes))
                    .collect()
            })
            .collect();
        let estimate: usize = schedules
            .iter()
            .zip(&per_schedule_tiles)
            .map(|(sched, per_level)| {
                let tiles: usize =
                    per_level.iter().map(Vec::len).fold(1usize, usize::saturating_mul).max(1);
                let ret = retention_variant_count(fs, sched.len(), cfg.uniform_retention);
                tiles
                    .saturating_mul(cfg.parallelism.len().max(1))
                    .saturating_mul(ret)
            })
            .fold(0usize, usize::saturating_add);
        let mut mappings = Vec::with_capacity(estimate.min(cfg.max_mappings));

        'outer: for (sched, per_level) in schedules.iter().zip(&per_schedule_tiles) {
            // Cartesian product of tile sizes via an odometer over choices.
            let mut stack = vec![0i64; sched.len()];
            let lens: Vec<i64> = per_level.iter().map(|v| v.len() as i64).collect();
            loop {
                let partitions: Vec<Partition> = sched
                    .iter()
                    .enumerate()
                    .map(|(lvl, &dim)| Partition {
                        dim,
                        tile: per_level[lvl][stack[lvl] as usize],
                    })
                    .collect();
                for &par in &cfg.parallelism {
                    for m in retention_variants(fs, &partitions, par, cfg.uniform_retention) {
                        if m.validate(fs).is_ok() {
                            mappings.push(m);
                            if mappings.len() >= cfg.max_mappings {
                                break 'outer;
                            }
                        }
                    }
                }
                if odometer_step(&mut stack, &lens).is_none() {
                    break; // exhausted (an untiled schedule yields one step)
                }
            }
        }
        MapSpace { mappings }
    }

    /// The enumerated mappings, in deterministic order.
    pub fn mappings(&self) -> &[InterLayerMapping] {
        &self.mappings
    }

    /// Number of enumerated mappings.
    pub fn len(&self) -> usize {
        self.mappings.len()
    }

    /// Whether enumeration produced nothing.
    pub fn is_empty(&self) -> bool {
        self.mappings.is_empty()
    }
}

/// Default schedule candidates: every single partitioned rank plus every
/// ordered pair of distinct ranks of the last layer (covering the paper's
/// P / P,Q / C,P / … choices), plus the untiled mapping.
fn default_schedules(fs: &FusionSet) -> Vec<Vec<usize>> {
    let last = fs.last();
    let nd = last.ndim();
    let mut out: Vec<Vec<usize>> = vec![vec![]];
    for d in 0..nd {
        if last.rank_sizes[d] > 1 {
            out.push(vec![d]);
        }
    }
    for a in 0..nd {
        for b in 0..nd {
            if a != b && last.rank_sizes[a] > 1 && last.rank_sizes[b] > 1 {
                out.push(vec![a, b]);
            }
        }
    }
    out
}

/// Tile-size candidates for a rank extent.
fn tile_choices(extent: i64, requested: &[i64]) -> Vec<i64> {
    if !requested.is_empty() {
        let mut v: Vec<i64> = requested
            .iter()
            .copied()
            .filter(|&t| t >= 1 && t <= extent)
            .collect();
        if v.is_empty() {
            v.push(extent);
        }
        v
    } else {
        let mut v = vec![];
        let mut t = 1;
        while t < extent {
            v.push(t);
            t *= 2;
        }
        v.push(extent);
        v
    }
}

/// Tensors with meaningful retention choices: everything except the final
/// output (whose writes are streaming).
fn retention_tensors(fs: &FusionSet) -> Vec<TensorId> {
    fs.tensors
        .iter()
        .enumerate()
        .filter(|(_, t)| t.kind != TensorKind::OutputFmap)
        .map(|(i, _)| TensorId(i))
        .collect()
}

/// How many tensors of the per-tensor cross product are enumerated before
/// the 500k variant guard trips (the remaining tensors keep the default
/// retention), and the resulting variant count.
fn retention_prefix(fs: &FusionSet, k: usize) -> (usize, usize) {
    let tensors = retention_tensors(fs).len();
    let mut nten = 0usize;
    let mut count = 1usize;
    while nten < tensors && count <= 500_000 {
        count = count.saturating_mul(k + 1);
        nten += 1;
    }
    (nten, count)
}

/// Number of mappings `retention_variants` yields for a `k`-level schedule.
fn retention_variant_count(fs: &FusionSet, k: usize, uniform: bool) -> usize {
    if k == 0 {
        1
    } else if uniform {
        k + 1
    } else {
        retention_prefix(fs, k).1
    }
}

/// All retention-level assignments for the given partitioning: an odometer
/// over per-tensor retention-level vectors, constructing each mapping once
/// (the legacy builder cloned whole mappings at every cross-product step).
fn retention_variants(
    fs: &FusionSet,
    partitions: &[Partition],
    par: Parallelism,
    uniform: bool,
) -> Vec<InterLayerMapping> {
    let k = partitions.len();
    let base = InterLayerMapping::tiled(partitions.to_vec(), par);
    if k == 0 {
        return vec![base];
    }
    if uniform {
        return (0..=k)
            .map(|lvl| base.clone().with_uniform_retention(lvl))
            .collect();
    }
    let tensors = retention_tensors(fs);
    let (nten, count) = retention_prefix(fs, k);
    let mut out = Vec::with_capacity(count);
    let mut levels = vec![0i64; nten];
    let radix = vec![(k + 1) as i64; nten];
    loop {
        let mut m = base.clone();
        for (&t, &lvl) in tensors[..nten].iter().zip(&levels) {
            m.retention.insert(t, lvl as usize);
        }
        out.push(m);
        if odometer_step(&mut levels, &radix).is_none() {
            break;
        }
    }
    out
}
