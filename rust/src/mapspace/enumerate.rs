//! Mapping enumeration under constraints.

use crate::einsum::{FusionSet, TensorId, TensorKind};
use crate::mapping::{InterLayerMapping, Parallelism, Partition};

/// Constraints defining a mapspace (the unconstrained default is the paper's
/// "this work" row in Table I).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MapSpaceConfig {
    /// Candidate schedules: ordered lists of last-layer rank *names*
    /// (e.g. `["P2","Q2"]`). Empty = derive all single- and double-rank
    /// schedules from the last layer's ranks.
    pub schedules: Vec<Vec<String>>,
    /// Candidate tile sizes per partitioned rank. Empty = powers of two up
    /// to the rank size (plus the size itself).
    pub tile_sizes: Vec<i64>,
    /// Force one retention level for every tensor (`Some` = the uniform
    /// retention constraint of prior work, paper Fig 16).
    pub uniform_retention: bool,
    /// If false, per-tensor retention levels are enumerated; if true only
    /// the levels are tied across tensors.
    pub parallelism: Vec<Parallelism>,
    /// Cap on enumerated mappings (guards exhaustive blowup).
    pub max_mappings: usize,
}

impl Default for MapSpaceConfig {
    fn default() -> Self {
        MapSpaceConfig {
            schedules: vec![],
            tile_sizes: vec![],
            uniform_retention: false,
            parallelism: vec![Parallelism::Sequential],
            max_mappings: 200_000,
        }
    }
}

/// An enumerated mapspace for one fusion set.
pub struct MapSpace {
    mappings: Vec<InterLayerMapping>,
}

impl MapSpace {
    /// Enumerate the mapspace.
    pub fn enumerate(fs: &FusionSet, cfg: &MapSpaceConfig) -> MapSpace {
        let last = fs.last();
        let schedules: Vec<Vec<usize>> = if cfg.schedules.is_empty() {
            default_schedules(fs)
        } else {
            cfg.schedules
                .iter()
                .map(|names| {
                    names
                        .iter()
                        .map(|n| {
                            last.rank_index(n)
                                .unwrap_or_else(|| panic!("unknown rank {n}"))
                        })
                        .collect()
                })
                .collect()
        };

        let mut mappings = Vec::new();
        'outer: for sched in &schedules {
            // Tile choices per level.
            let per_level: Vec<Vec<i64>> = sched
                .iter()
                .map(|&d| tile_choices(last.rank_sizes[d], &cfg.tile_sizes))
                .collect();
            // Cartesian product of tile sizes via an odometer over choices.
            let mut stack = vec![0usize; sched.len()];
            let mut exhausted = false;
            while !exhausted {
                let partitions: Vec<Partition> = sched
                    .iter()
                    .enumerate()
                    .map(|(lvl, &dim)| Partition { dim, tile: per_level[lvl][stack[lvl]] })
                    .collect();
                for &par in &cfg.parallelism {
                    for m in retention_variants(fs, &partitions, par, cfg.uniform_retention)
                    {
                        if m.validate(fs).is_ok() {
                            mappings.push(m);
                            if mappings.len() >= cfg.max_mappings {
                                break 'outer;
                            }
                        }
                    }
                }
                if sched.is_empty() {
                    break; // untiled: a single mapping
                }
                // Odometer increment (innermost level fastest).
                let mut lvl = sched.len();
                loop {
                    if lvl == 0 {
                        exhausted = true;
                        break;
                    }
                    lvl -= 1;
                    stack[lvl] += 1;
                    if stack[lvl] < per_level[lvl].len() {
                        break;
                    }
                    stack[lvl] = 0;
                }
            }
        }
        MapSpace { mappings }
    }

    pub fn mappings(&self) -> &[InterLayerMapping] {
        &self.mappings
    }

    pub fn len(&self) -> usize {
        self.mappings.len()
    }

    pub fn is_empty(&self) -> bool {
        self.mappings.is_empty()
    }
}

/// Default schedule candidates: every single partitioned rank plus every
/// ordered pair of distinct ranks of the last layer (covering the paper's
/// P / P,Q / C,P / … choices), plus the untiled mapping.
fn default_schedules(fs: &FusionSet) -> Vec<Vec<usize>> {
    let last = fs.last();
    let nd = last.ndim();
    let mut out: Vec<Vec<usize>> = vec![vec![]];
    for d in 0..nd {
        if last.rank_sizes[d] > 1 {
            out.push(vec![d]);
        }
    }
    for a in 0..nd {
        for b in 0..nd {
            if a != b && last.rank_sizes[a] > 1 && last.rank_sizes[b] > 1 {
                out.push(vec![a, b]);
            }
        }
    }
    out
}

/// Tile-size candidates for a rank extent.
fn tile_choices(extent: i64, requested: &[i64]) -> Vec<i64> {
    if !requested.is_empty() {
        let mut v: Vec<i64> = requested
            .iter()
            .copied()
            .filter(|&t| t >= 1 && t <= extent)
            .collect();
        if v.is_empty() {
            v.push(extent);
        }
        v
    } else {
        let mut v = vec![];
        let mut t = 1;
        while t < extent {
            v.push(t);
            t *= 2;
        }
        v.push(extent);
        v
    }
}

/// All retention-level assignments for the given partitioning.
fn retention_variants(
    fs: &FusionSet,
    partitions: &[Partition],
    par: Parallelism,
    uniform: bool,
) -> Vec<InterLayerMapping> {
    let k = partitions.len();
    let base = InterLayerMapping::tiled(partitions.to_vec(), par);
    if k == 0 {
        return vec![base];
    }
    // Tensors with meaningful retention choices: everything except the final
    // output (whose writes are streaming).
    let tensors: Vec<TensorId> = fs
        .tensors
        .iter()
        .enumerate()
        .filter(|(_, t)| t.kind != TensorKind::OutputFmap)
        .map(|(i, _)| TensorId(i))
        .collect();

    if uniform {
        return (0..=k)
            .map(|lvl| base.clone().with_uniform_retention(lvl))
            .collect();
    }
    // Per-tensor cross product (bounded: tensors ≤ ~7, k ≤ 3).
    let mut out = vec![base.clone()];
    for &t in &tensors {
        let mut next = Vec::with_capacity(out.len() * (k + 1));
        for m in &out {
            for lvl in 0..=k {
                next.push(m.clone().with_retention(t, lvl));
            }
        }
        out = next;
        if out.len() > 500_000 {
            break; // guarded by max_mappings upstream as well
        }
    }
    out
}
