//! # LoopTree — fused-layer dataflow accelerator design-space exploration
//!
//! A reproduction of *"LoopTree: Exploring the Fused-layer Dataflow
//! Accelerator Design Space"* (Gilbert, Wu, Emer, Sze — IEEE TCASAI 2024).
//!
//! ## Quickstart: sessions, search, and the spec layer
//!
//! The public API is built around three pieces:
//!
//! * [`model::Evaluator`] — a **validate-once session** for one
//!   (fusion set, architecture) pair. Construction validates both specs and
//!   precomputes per-layer intra-layer defaults; `evaluate` then walks one
//!   [`mapping::InterLayerMapping`] with only cheap per-call checks, and
//!   `evaluate_batch` fans a batch out over a [`coordinator::Coordinator`]
//!   worker pool. This is the hot path every search and case study uses.
//! * [`search::run`] — one entry point for all four search algorithms
//!   (exhaustive, random, annealing, genetic), driven by a serializable
//!   [`search::SearchSpec`] with a [`search::Objective`] enum instead of
//!   ad-hoc closures.
//! * [`spec`] — JSON `to_json`/`from_json` round-trips for every spec and
//!   result type ([`einsum::FusionSet`], [`arch::Arch`],
//!   [`mapping::InterLayerMapping`], [`mapspace::MapSpaceConfig`],
//!   [`search::SearchSpec`], [`model::Metrics`]), so external tools and the
//!   CLI (`looptree analyze|search --config file.json --json`) can drive the
//!   crate declaratively.
//!
//! ```text
//! let fs = einsum::workloads::conv_conv(28, 64);
//! let arch = arch::Arch::generic(256);
//! let ev = model::Evaluator::new(&fs, &arch)?;          // validate once
//! let m = ev.evaluate(&mapping)?;                       // evaluate many
//! let res = search::run(&ev, &search::SearchSpec::default(), &pool);
//! let doc = res.unwrap().best.mapping.to_json();        // serialize
//! ```
//!
//! ## Modules
//!
//! * [`einsum`] — extended-Einsum workload IR: layers, tensors, fusion sets.
//! * [`analysis`] — static mapping analysis: closed-form affine diagnostics
//!   (symbolic footprint movement, provable steady-state certification,
//!   capacity/objective lower bounds) and the `looptree lint` diagnostics.
//! * [`poly`] — exact rectilinear set algebra (the ISL-replacement substrate).
//! * [`arch`] — accelerator architecture specs + accelergy-lite energy model.
//! * [`mapping`] — the paper's mapping taxonomy (Table IV): partitioned
//!   ranks, tile shapes, schedules, per-tensor retention, parallelism.
//! * [`model`] — the LoopTree analytical model: latency, energy, buffer
//!   occupancy, off-chip transfers (paper §IV), via [`model::Evaluator`]
//!   sessions or the free one-shot [`model::evaluate`].
//! * [`sim`] — a reference tile-level simulator used as the validation
//!   comparator (paper §V methodology).
//! * [`mapspace`] / [`search`] — mapping enumeration, Pareto fronts, and the
//!   unified [`search::run`] entry point.
//! * [`network`] — whole-DNN graphs (ResNet-18 with its residual edges,
//!   MobileNetV2 with its skip connections, VGG-16, a BERT encoder block)
//!   and the fused-segment partitioner: [`network::search_network`]
//!   memoizes per-segment mapspace searches over canonical segment
//!   signatures and picks the optimal segment cover by dynamic programming
//!   (chain cut points on paths, graph cuts on DAGs);
//!   [`network::search_network_pareto`] generalizes the same DP to
//!   dominance over vector costs and emits whole-network Pareto fronts.
//! * [`coordinator`] — parallel DSE job execution (lock-free result merge).
//! * [`spec`] — the serializable JSON spec/query layer.
//! * [`serve`] — `looptree serve`: a persistent DSE server over the spec
//!   layer with a cross-request segment cache and warm-started search
//!   (protocol in `docs/PROTOCOL.md`).
//! * `runtime` *(feature `pjrt`)* — PJRT execution of AOT-compiled
//!   fused-tile artifacts.
//! * [`validation`] — encodings of DepFin, Fused-layer CNN, ISAAC,
//!   PipeLayer, and FLAT (paper Tables V–VIII, Fig 13).
//! * [`casestudies`] — drivers regenerating paper Figs 14–18.
//!
//! A prose map of how these modules fit together — the evaluator tier
//! hierarchy, the network DP, and the serve-layer caching story — lives in
//! `docs/ARCHITECTURE.md`.

#![forbid(unsafe_code)]
#![warn(missing_docs)]

pub mod analysis;
pub mod arch;
pub mod einsum;
pub mod mapping;
pub mod casestudies;
pub mod coordinator;
pub mod mapspace;
pub mod model;
pub mod network;
pub mod search;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod serve;
pub mod spec;
pub mod validation;
pub mod sim;
pub mod poly;
pub mod util;
