//! # LoopTree — fused-layer dataflow accelerator design-space exploration
//!
//! A reproduction of *"LoopTree: Exploring the Fused-layer Dataflow
//! Accelerator Design Space"* (Gilbert, Wu, Emer, Sze — IEEE TCASAI 2024).
//!
//! The crate provides:
//!
//! * [`einsum`] — extended-Einsum workload IR: layers, tensors, fusion sets.
//! * [`poly`] — exact rectilinear set algebra (the ISL-replacement substrate).
//! * [`arch`] — accelerator architecture specs + accelergy-lite energy model.
//! * [`mapping`] — the paper's mapping taxonomy (Table IV): partitioned
//!   ranks, tile shapes, schedules, per-tensor retention, parallelism.
//! * [`model`] — the LoopTree analytical model: latency, energy, buffer
//!   occupancy, off-chip transfers (paper §IV).
//! * [`sim`] — a reference tile-level simulator used as the validation
//!   comparator (paper §V methodology).
//! * [`mapspace`] / [`search`] — mapping enumeration, Pareto fronts, and
//!   search algorithms (exhaustive, random, annealing, genetic).
//! * [`coordinator`] — parallel DSE job execution.
//! * [`runtime`] — PJRT execution of AOT-compiled fused-tile artifacts.
//! * [`validation`] — encodings of DepFin, Fused-layer CNN, ISAAC,
//!   PipeLayer, and FLAT (paper Tables V–VIII, Fig 13).
//! * [`casestudies`] — drivers regenerating paper Figs 14–18.

pub mod arch;
pub mod einsum;
pub mod mapping;
pub mod casestudies;
pub mod coordinator;
pub mod mapspace;
pub mod model;
pub mod search;
pub mod runtime;
pub mod validation;
pub mod sim;
pub mod poly;
pub mod util;
