//! Minimal std-only HTTP/1.1 plumbing for `looptree serve`.
//!
//! Exactly the subset the protocol needs — `POST` with `Content-Length`
//! bodies, a `GET /health` probe, `Expect: 100-continue`, and
//! `Connection: close` responses — over [`std::net::TcpStream`]. No
//! keep-alive, no chunked transfer, no TLS; see `docs/PROTOCOL.md` for the
//! wire contract clients rely on.

use crate::util::json::Json;
use std::io::{Read, Write};
use std::net::TcpStream;

/// Header-section cap: a request line plus a handful of headers.
const MAX_HEADER_BYTES: usize = 64 * 1024;
/// Body cap — generous for config documents, small enough that a stray
/// client cannot buffer the server into the ground.
const MAX_BODY_BYTES: usize = 64 * 1024 * 1024;

/// A parsed inbound request: method, path, raw body bytes.
pub struct Request {
    /// HTTP method, uppercase as sent (`GET`, `POST`).
    pub method: String,
    /// Request target as sent (`/`, `/health`).
    pub path: String,
    /// Raw body (exactly `Content-Length` bytes; empty without one).
    pub body: Vec<u8>,
}

fn find_subslice(hay: &[u8], needle: &[u8]) -> Option<usize> {
    hay.windows(needle.len()).position(|w| w == needle)
}

/// Read one request from `stream`. `Ok(None)` means the peer connected and
/// closed without sending anything (a TCP health probe); errors describe
/// malformed or oversized requests and map to a 400 response.
pub fn read_request(stream: &mut TcpStream) -> Result<Option<Request>, String> {
    let mut buf: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 4096];
    let header_end = loop {
        if let Some(pos) = find_subslice(&buf, b"\r\n\r\n") {
            break pos;
        }
        if buf.len() > MAX_HEADER_BYTES {
            return Err("request header section too large".into());
        }
        let n = stream.read(&mut chunk).map_err(|e| format!("read: {e}"))?;
        if n == 0 {
            if buf.is_empty() {
                return Ok(None);
            }
            return Err("connection closed mid-header".into());
        }
        buf.extend_from_slice(&chunk[..n]);
    };
    let header =
        std::str::from_utf8(&buf[..header_end]).map_err(|_| "header section is not UTF-8")?;
    let mut lines = header.split("\r\n");
    let request_line = lines.next().unwrap_or_default();
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or_default().to_string();
    let path = parts.next().unwrap_or_default().to_string();
    if method.is_empty() || path.is_empty() {
        return Err(format!("malformed request line: {request_line:?}"));
    }
    let mut content_length = 0usize;
    let mut expect_continue = false;
    for line in lines {
        let Some((name, value)) = line.split_once(':') else { continue };
        let value = value.trim();
        match name.trim().to_ascii_lowercase().as_str() {
            "content-length" => {
                content_length = value
                    .parse()
                    .map_err(|_| format!("bad content-length: {value:?}"))?;
            }
            "expect" => expect_continue = value.eq_ignore_ascii_case("100-continue"),
            _ => {}
        }
    }
    if content_length > MAX_BODY_BYTES {
        return Err("request body too large".into());
    }
    if expect_continue {
        stream
            .write_all(b"HTTP/1.1 100 Continue\r\n\r\n")
            .map_err(|e| format!("write: {e}"))?;
    }
    let mut body = buf[header_end + 4..].to_vec();
    while body.len() < content_length {
        let n = stream.read(&mut chunk).map_err(|e| format!("read: {e}"))?;
        if n == 0 {
            return Err("connection closed mid-body".into());
        }
        body.extend_from_slice(&chunk[..n]);
    }
    body.truncate(content_length);
    Ok(Some(Request { method, path, body }))
}

/// Write a full `Connection: close` JSON response.
pub fn write_response(
    stream: &mut TcpStream,
    status: u16,
    reason: &str,
    body: &[u8],
) -> Result<(), String> {
    let head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream
        .write_all(head.as_bytes())
        .and_then(|_| stream.write_all(body))
        .map_err(|e| format!("write: {e}"))
}

/// Blocking JSON-over-HTTP client: POST `doc` to `http://{addr}{path}` and
/// return `(status, parsed response body)`. This is the in-process client
/// the integration tests and the serve bench harness drive; it relies on
/// the server's `Connection: close` framing (read to EOF), which also makes
/// it a minimal reference client for `docs/PROTOCOL.md`.
pub fn post_json(addr: &std::net::SocketAddr, path: &str, doc: &Json) -> Result<(u16, Json), String> {
    let body = doc.pretty();
    let mut stream = TcpStream::connect(addr).map_err(|e| format!("connect {addr}: {e}"))?;
    let head = format!(
        "POST {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream
        .write_all(head.as_bytes())
        .and_then(|_| stream.write_all(body.as_bytes()))
        .map_err(|e| format!("write {addr}: {e}"))?;
    let mut resp = Vec::new();
    stream
        .read_to_end(&mut resp)
        .map_err(|e| format!("read {addr}: {e}"))?;
    let pos = find_subslice(&resp, b"\r\n\r\n")
        .ok_or_else(|| "response missing header terminator".to_string())?;
    let header = std::str::from_utf8(&resp[..pos]).map_err(|_| "response header is not UTF-8")?;
    let status: u16 = header
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| format!("malformed status line: {header:?}"))?;
    let text = std::str::from_utf8(&resp[pos + 4..]).map_err(|_| "response body is not UTF-8")?;
    let json = Json::parse(text).map_err(|e| format!("response body: {e}"))?;
    Ok((status, json))
}

/// Raw-text POST: like [`post_json`] but returns the body bytes verbatim.
/// The byte-identity tests use this to compare server output against CLI
/// output without a parse→print round trip in the way.
pub fn post_json_raw(
    addr: &std::net::SocketAddr,
    path: &str,
    doc: &Json,
) -> Result<(u16, String), String> {
    let body = doc.pretty();
    let mut stream = TcpStream::connect(addr).map_err(|e| format!("connect {addr}: {e}"))?;
    let head = format!(
        "POST {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream
        .write_all(head.as_bytes())
        .and_then(|_| stream.write_all(body.as_bytes()))
        .map_err(|e| format!("write {addr}: {e}"))?;
    let mut resp = Vec::new();
    stream
        .read_to_end(&mut resp)
        .map_err(|e| format!("read {addr}: {e}"))?;
    let pos = find_subslice(&resp, b"\r\n\r\n")
        .ok_or_else(|| "response missing header terminator".to_string())?;
    let header = std::str::from_utf8(&resp[..pos]).map_err(|_| "response header is not UTF-8")?;
    let status: u16 = header
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| format!("malformed status line: {header:?}"))?;
    let text = std::str::from_utf8(&resp[pos + 4..])
        .map_err(|_| "response body is not UTF-8")?
        .to_string();
    Ok((status, text))
}
