//! The cross-request [`SegmentCache`] behind `looptree serve`.
//!
//! Entries are keyed by (canonical segment signature, architecture hash,
//! search-spec hash). The signature already canonicalizes segment shape —
//! repeated ResNet blocks share one signature — so repeated blocks *across
//! requests* are searched once, the DNNFuser observation at serve scale.
//! Three entry kinds share the table: scalar per-segment best mappings (the
//! scalar network DP's memo unit), dominance-pruned per-segment Pareto
//! fronts (the front DP's), and whole-search summaries (`search` requests).
//! The spec-hash component keeps the kinds and any differing search
//! configurations in disjoint key spaces.
//!
//! Determinism: a conforming entry holds exactly what a fresh search of the
//! same (signature, arch, spec) would compute — per-segment searches are
//! deterministic — so cache hits change latency and the `cache_hits`
//! counter, never a result document. Eviction is FIFO by first insertion,
//! bounded by the `--cache-cap` entry count (`0` = unbounded).
//!
//! Alongside the result cache sits a small *warm pool*: best mappings seen
//! per (signature, arch), across all spec hashes, feeding
//! [`crate::search::run_warm`] for `warm_start` requests. Warm seeds are
//! advisory (they join the evaluated set of a stochastic search), so the
//! pool deliberately ignores the spec hash — a mapping found by exhaustive
//! search is a fine starting point for annealing under another objective.

use crate::mapping::InterLayerMapping;
use crate::network::{FrontSegmentMemo, ScalarSegmentMemo, SegmentFrontPoint};
use crate::search::Scored;
use std::collections::hash_map::DefaultHasher;
use std::collections::{HashMap, VecDeque};
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Hash a canonical string (a serialized arch or spec) to a cache-key
/// component. [`DefaultHasher`] with its fixed default keys is
/// deterministic across runs and platforms, so cache keys — unlike
/// `HashMap` iteration order — are stable.
pub fn hash64(s: &str) -> u64 {
    let mut h = DefaultHasher::new();
    s.hash(&mut h);
    h.finish()
}

/// A cached whole-search summary: the pieces of a
/// [`SearchResult`](crate::search::SearchResult) that enter the serialized
/// result document (`SearchConfig::result_doc`), without the full evaluated
/// list. Sufficient to rebuild the response byte-identically.
#[derive(Debug, Clone)]
pub struct SearchSummary {
    /// The minimum-score evaluated mapping.
    pub best: Scored,
    /// `evaluated.len()` of the original run.
    pub evaluated: usize,
    /// Candidates skipped by provable capacity pruning.
    pub pruned: usize,
    /// Evaluations that ran entirely on the symbolic walk.
    pub symbolic_evals: usize,
}

#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct Key {
    signature: String,
    arch: u64,
    spec: u64,
}

#[derive(Clone)]
enum Entry {
    Scalar(Option<Scored>),
    Front(Option<Vec<SegmentFrontPoint>>),
    Search(SearchSummary),
}

/// Warm-pool bound per (signature, arch) key: enough seeds to be useful,
/// small enough that warm evaluation stays a negligible prefix of a search.
const WARM_POOL_CAP: usize = 8;

struct Inner {
    map: HashMap<Key, Entry>,
    order: VecDeque<Key>,
    warm: HashMap<(String, u64), Vec<InterLayerMapping>>,
    warm_order: VecDeque<(String, u64)>,
}

/// The shared cross-request cache. All methods take `&self`; interior
/// mutability is one mutex around the tables (entries are small relative to
/// the searches they save, so contention is irrelevant) plus lifetime
/// hit/miss totals for the `/health` endpoint.
pub struct SegmentCache {
    cap: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    inner: Mutex<Inner>,
}

impl SegmentCache {
    /// An empty cache holding at most `cap` result entries (`0` =
    /// unbounded). The warm pool is bounded by the same count of keys.
    pub fn new(cap: usize) -> SegmentCache {
        SegmentCache {
            cap,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            inner: Mutex::new(Inner {
                map: HashMap::new(),
                order: VecDeque::new(),
                warm: HashMap::new(),
                warm_order: VecDeque::new(),
            }),
        }
    }

    /// Current result-entry count.
    pub fn len(&self) -> usize {
        self.lock().map.len()
    }

    /// Whether the cache holds no result entries.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Lifetime `(hits, misses)` across all requests.
    pub fn totals(&self) -> (u64, u64) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn get(&self, key: &Key) -> Option<Entry> {
        let hit = self.lock().map.get(key).cloned();
        match &hit {
            Some(_) => self.hits.fetch_add(1, Ordering::Relaxed),
            None => self.misses.fetch_add(1, Ordering::Relaxed),
        };
        hit
    }

    fn put(&self, key: Key, entry: Entry) {
        let mut inner = self.lock();
        if inner.map.insert(key.clone(), entry).is_none() {
            inner.order.push_back(key);
            if self.cap > 0 {
                while inner.map.len() > self.cap {
                    let Some(oldest) = inner.order.pop_front() else { break };
                    inner.map.remove(&oldest);
                }
            }
        }
    }

    /// A per-request view binding this cache to one (arch hash, spec hash)
    /// context, implementing the network memo traits with request-local
    /// hit/miss counters.
    pub fn view(&self, arch: u64, spec: u64) -> CacheView<'_> {
        CacheView {
            cache: self,
            arch,
            spec,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Cached whole-search summary, if present. Counts toward the lifetime
    /// totals but not any view counters (search requests report their own).
    pub fn lookup_search(&self, signature: &str, arch: u64, spec: u64) -> Option<SearchSummary> {
        let key = Key { signature: signature.to_string(), arch, spec };
        match self.get(&key) {
            Some(Entry::Search(s)) => Some(s),
            _ => None,
        }
    }

    /// Record a completed search's summary.
    pub fn store_search(&self, signature: &str, arch: u64, spec: u64, summary: &SearchSummary) {
        let key = Key { signature: signature.to_string(), arch, spec };
        self.put(key, Entry::Search(summary.clone()));
    }

    /// The warm-start seeds recorded for (signature, arch), best-known
    /// order (most recently recorded last).
    pub fn warm_mappings(&self, signature: &str, arch: u64) -> Vec<InterLayerMapping> {
        self.lock()
            .warm
            .get(&(signature.to_string(), arch))
            .cloned()
            .unwrap_or_default()
    }

    /// Add `mapping` to the warm pool for (signature, arch). Duplicates are
    /// dropped; the per-key pool and the key count are both bounded (FIFO).
    pub fn remember_warm(&self, signature: &str, arch: u64, mapping: &InterLayerMapping) {
        let key = (signature.to_string(), arch);
        let mut inner = self.lock();
        if !inner.warm.contains_key(&key) {
            inner.warm_order.push_back(key.clone());
            if self.cap > 0 {
                while inner.warm.len() >= self.cap {
                    let Some(oldest) = inner.warm_order.pop_front() else { break };
                    inner.warm.remove(&oldest);
                }
            }
        }
        let pool = inner.warm.entry(key).or_default();
        if pool.contains(mapping) {
            return;
        }
        if pool.len() >= WARM_POOL_CAP {
            pool.remove(0);
        }
        pool.push(mapping.clone());
    }
}

/// One request's binding of the [`SegmentCache`] to a fixed (arch, spec)
/// context, with deterministic request-local counters. Implements both
/// network memo traits; the network search code consults it only in serial
/// pre-/post-passes, so the counters are reproducible for any worker count.
pub struct CacheView<'a> {
    cache: &'a SegmentCache,
    arch: u64,
    spec: u64,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl CacheView<'_> {
    /// Distinct signatures this request reused from the cache.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Distinct signatures this request searched and stored.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    fn key(&self, signature: &str) -> Key {
        Key { signature: signature.to_string(), arch: self.arch, spec: self.spec }
    }
}

impl ScalarSegmentMemo for CacheView<'_> {
    fn lookup_scalar(&self, signature: &str) -> Option<Option<Scored>> {
        match self.cache.get(&self.key(signature)) {
            Some(Entry::Scalar(v)) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(v)
            }
            _ => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    fn store_scalar(&self, signature: &str, value: &Option<Scored>) {
        self.cache.put(self.key(signature), Entry::Scalar(value.clone()));
        if let Some(s) = value {
            self.cache.remember_warm(signature, self.arch, &s.mapping);
        }
    }
}

impl FrontSegmentMemo for CacheView<'_> {
    fn lookup_front(&self, signature: &str) -> Option<Option<Vec<SegmentFrontPoint>>> {
        match self.cache.get(&self.key(signature)) {
            Some(Entry::Front(v)) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(v)
            }
            _ => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    fn store_front(&self, signature: &str, value: &Option<Vec<SegmentFrontPoint>>) {
        self.cache.put(self.key(signature), Entry::Front(value.clone()));
        // Front points seed the warm pool too: each is a distinct
        // best-known trade-off mapping for this segment shape.
        if let Some(front) = value {
            for p in front.iter().take(WARM_POOL_CAP) {
                self.cache.remember_warm(signature, self.arch, &p.payload.mapping);
            }
        }
    }
}
