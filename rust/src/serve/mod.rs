//! `looptree serve` — a persistent DSE server with a cross-request
//! segment cache.
//!
//! Interactive design-space exploration asks many *related* questions:
//! sweep an architecture parameter, re-partition the same backbone, re-run
//! a search with one knob changed. Run as one-shot CLI invocations, every
//! question re-searches every segment from scratch. This module keeps a
//! process alive between questions and memoizes per-segment search results
//! in a [`SegmentCache`] keyed by (canonical segment signature, arch hash,
//! search-spec hash), so the repeated structure *within* networks that the
//! network DP already exploits is also exploited *across* requests.
//!
//! The wire protocol (see `docs/PROTOCOL.md`) is deliberately thin:
//! HTTP/1.1 `POST /` with a JSON envelope `{"kind", "config", "id"?,
//! "warm_start"?}` where `config` is exactly the `--config` document the
//! CLI accepts, and the response's `result` field is byte-for-byte the
//! document the one-shot CLI prints with `--json`. Cache accounting
//! (`cache_hits` / `cache_misses` / `warm_starts`) rides in a separate
//! `serve` envelope section, so caching is observable without perturbing
//! the result documents. `GET /health` reports liveness and lifetime cache
//! totals.
//!
//! Determinism: per-segment searches are deterministic, cache traffic
//! happens in the network DP's serial pre-/post-passes, and concurrent
//! requests fan out over a shared [`Coordinator`] whose merge is
//! index-ordered — so response bytes are independent of `--threads` and of
//! request concurrency, and the counters are pinned by tests and CI. The
//! one deliberate exception is `warm_start: true`, which seeds stochastic
//! searches from previously cached mappings and is therefore allowed to
//! (only) improve on the cold result.

pub mod cache;
mod http;

pub use cache::{hash64, CacheView, SearchSummary, SegmentCache};
pub use http::{post_json, post_json_raw};

use crate::analysis::lint_document;
use crate::coordinator::Coordinator;
use crate::model::Evaluator;
use crate::network;
use crate::search::{self, Algorithm};
use crate::spec::{
    serve_error, serve_ok, AnalyzeConfig, NetworkConfig, RequestKind, SearchConfig, ServeRequest,
    ServeStats,
};
use crate::util::bench::LatencyStats;
use crate::util::json::Json;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Server configuration (the `looptree serve` CLI flags).
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Worker threads of the shared [`Coordinator`] (`0` = all cores).
    pub threads: usize,
    /// [`SegmentCache`] entry cap (`0` = unbounded).
    pub cache_cap: usize,
    /// Suppress the per-request log line.
    pub quiet: bool,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions { threads: 0, cache_cap: 1024, quiet: true }
    }
}

/// Shared server state: the cross-request cache and the worker pool. One
/// instance serves all connections; requests needing parallelism fan out
/// over the shared pool (deterministic index-ordered merge), so concurrent
/// requests time-share workers instead of oversubscribing cores.
pub struct ServeState {
    cache: SegmentCache,
    pool: Coordinator,
    quiet: bool,
}

impl ServeState {
    /// Fresh state per `opts` (cold cache).
    pub fn new(opts: &ServeOptions) -> ServeState {
        ServeState {
            cache: SegmentCache::new(opts.cache_cap),
            pool: Coordinator::new(opts.threads),
            quiet: opts.quiet,
        }
    }

    /// The cross-request cache (tests read its totals).
    pub fn cache(&self) -> &SegmentCache {
        &self.cache
    }
}

/// Process one request document end to end: parse the envelope, dispatch,
/// and wrap the outcome. Never panics on malformed input — every failure
/// becomes an error envelope carrying the request `id` when one was given.
/// This is the transport-independent core; the HTTP layer and in-process
/// tests both call it.
pub fn process_request(state: &ServeState, doc: &Json) -> Json {
    let req = match ServeRequest::from_json(doc) {
        Ok(r) => r,
        Err(e) => return serve_error(doc.get("id").cloned(), &e),
    };
    let id = req.id.clone();
    let kind = req.kind;
    match handle(state, &req) {
        Ok((result, stats)) => serve_ok(id, kind, result, &stats),
        Err(e) => serve_error(id, &e),
    }
}

/// Dispatch a parsed request to the matching subcommand path. Each arm
/// mirrors the one-shot CLI exactly — same config parser, same search
/// entry point, same `result_doc` builder — so the `result` section is
/// byte-identical to `looptree <kind> --json`.
fn handle(state: &ServeState, req: &ServeRequest) -> Result<(Json, ServeStats), String> {
    match req.kind {
        RequestKind::Analyze => {
            let cfg = AnalyzeConfig::from_json(&req.config)?;
            let ev = Evaluator::new(&cfg.workload, &cfg.arch)
                .map_err(|e| format!("invalid spec: {e}"))?;
            let m = ev.evaluate(&cfg.mapping).map_err(|e| format!("evaluation failed: {e}"))?;
            Ok((cfg.result_doc(&m), ServeStats::default()))
        }
        RequestKind::Search => handle_search(state, req),
        RequestKind::Network => handle_network(state, req),
        RequestKind::Lint => Ok((lint_document(&req.config).to_json(), ServeStats::default())),
    }
}

/// `search` requests cache whole-search summaries (the result document is
/// reconstructible from best + counters). `warm_start: true` on a
/// stochastic algorithm bypasses the summary cache and seeds the search
/// from the warm pool instead.
fn handle_search(state: &ServeState, req: &ServeRequest) -> Result<(Json, ServeStats), String> {
    let cfg = SearchConfig::from_json(&req.config)?;
    let arch_hash = hash64(&cfg.arch.to_json().to_string());
    let signature = format!("search:{:016x}", hash64(&cfg.workload.to_json().to_string()));
    let spec_hash = hash64(&format!("search:{}", cfg.search.to_json()));
    let stochastic = matches!(cfg.search.algorithm, Algorithm::Annealing | Algorithm::Genetic);
    if req.warm_start && stochastic {
        let warm = state.cache.warm_mappings(&signature, arch_hash);
        let ev = Evaluator::new(&cfg.workload, &cfg.arch)
            .map_err(|e| format!("invalid spec: {e}"))?;
        let r = search::run_warm(&ev, &cfg.search, &state.pool, &warm)
            .ok_or_else(|| "search found no feasible mapping".to_string())?;
        state.cache.remember_warm(&signature, arch_hash, &r.best.mapping);
        let stats =
            ServeStats { warm_starts: u64::from(!warm.is_empty()), ..ServeStats::default() };
        return Ok((cfg.result_doc(&r.best, r.evaluated.len(), r.pruned, r.symbolic_evals), stats));
    }
    if let Some(s) = state.cache.lookup_search(&signature, arch_hash, spec_hash) {
        let stats = ServeStats { cache_hits: 1, ..ServeStats::default() };
        return Ok((cfg.result_doc(&s.best, s.evaluated, s.pruned, s.symbolic_evals), stats));
    }
    let ev = Evaluator::new(&cfg.workload, &cfg.arch).map_err(|e| format!("invalid spec: {e}"))?;
    let r = search::run(&ev, &cfg.search, &state.pool)
        .ok_or_else(|| "search found no feasible mapping".to_string())?;
    state.cache.store_search(
        &signature,
        arch_hash,
        spec_hash,
        &SearchSummary {
            best: r.best.clone(),
            evaluated: r.evaluated.len(),
            pruned: r.pruned,
            symbolic_evals: r.symbolic_evals,
        },
    );
    state.cache.remember_warm(&signature, arch_hash, &r.best.mapping);
    let stats = ServeStats { cache_misses: 1, ..ServeStats::default() };
    Ok((cfg.result_doc(&r.best, r.evaluated.len(), r.pruned, r.symbolic_evals), stats))
}

/// `network` requests run through the existing DP entry points with a
/// [`CacheView`] plugged into their segment-memo hooks, so distinct
/// segment signatures are fetched or stored one by one — the per-request
/// hit/miss counters count *segments*, the cache's true unit of reuse.
fn handle_network(state: &ServeState, req: &ServeRequest) -> Result<(Json, ServeStats), String> {
    let cfg = NetworkConfig::from_json(&req.config)?;
    let arch_hash = hash64(&cfg.arch.to_json().to_string());
    if cfg.pareto {
        let spec = &cfg.segment_search;
        let names: Vec<&str> = spec.objectives.iter().map(|o| o.name()).collect();
        let spec_hash = hash64(&format!(
            "front:{}|objectives:{}|cap:{}",
            spec.search.to_json(),
            names.join(","),
            spec.max_front_per_state
        ));
        let view = state.cache.view(arch_hash, spec_hash);
        let r = network::search_network_pareto_memo(
            &cfg.network,
            &cfg.arch,
            spec,
            &state.pool,
            Some(&view),
        )?;
        let stats = ServeStats {
            cache_hits: view.hits(),
            cache_misses: view.misses(),
            warm_starts: 0,
        };
        return Ok((cfg.result_doc_pareto(&r), stats));
    }
    let spec_hash = hash64(&format!("scalar:{}", cfg.segment_search.search.to_json()));
    let view = state.cache.view(arch_hash, spec_hash);
    let r = match &cfg.cuts {
        Some(cuts) => network::evaluate_partition_memo(
            &cfg.network,
            &cfg.arch,
            &cfg.segment_search,
            cuts,
            &state.pool,
            Some(&view),
        ),
        None => network::search_network_memo(
            &cfg.network,
            &cfg.arch,
            &cfg.segment_search,
            &state.pool,
            Some(&view),
        ),
    }?;
    let stats =
        ServeStats { cache_hits: view.hits(), cache_misses: view.misses(), warm_starts: 0 };
    Ok((cfg.result_doc(&r), stats))
}

/// One row of `BENCH_serve.json`, built here so the serve bench binary and
/// [`crate::util::bench::check_serve_bench_schema`] cannot drift apart.
pub fn bench_row(
    scenario: &str,
    clients: usize,
    requests: usize,
    lat: &LatencyStats,
    elapsed: Duration,
    stats: &ServeStats,
    all_ok: bool,
) -> Json {
    let secs = elapsed.as_secs_f64();
    let throughput = if secs > 0.0 { requests as f64 / secs } else { 0.0 };
    Json::Obj(
        [
            ("workload".to_string(), Json::Str(scenario.to_string())),
            ("clients".to_string(), Json::Num(clients as f64)),
            ("requests".to_string(), Json::Num(requests as f64)),
            ("mean_ns".to_string(), Json::Num(lat.mean.as_nanos() as f64)),
            ("p50_ns".to_string(), Json::Num(lat.p50.as_nanos() as f64)),
            ("p90_ns".to_string(), Json::Num(lat.p90.as_nanos() as f64)),
            ("p99_ns".to_string(), Json::Num(lat.p99.as_nanos() as f64)),
            ("throughput_rps".to_string(), Json::Num(throughput)),
            ("cache_hits".to_string(), Json::Num(stats.cache_hits as f64)),
            ("cache_misses".to_string(), Json::Num(stats.cache_misses as f64)),
            ("warm_starts".to_string(), Json::Num(stats.warm_starts as f64)),
            ("all_ok".to_string(), Json::Bool(all_ok)),
        ]
        .into_iter()
        .collect(),
    )
}

/// Extract the `serve` counter section of a response envelope (zeros when
/// absent) — the accumulation helper for clients tallying many responses.
pub fn response_stats(resp: &Json) -> ServeStats {
    let g = |k: &str| {
        resp.get("serve")
            .and_then(|s| s.get(k))
            .and_then(Json::as_i64)
            .unwrap_or(0) as u64
    };
    ServeStats {
        cache_hits: g("cache_hits"),
        cache_misses: g("cache_misses"),
        warm_starts: g("warm_starts"),
    }
}

/// The bound server. [`Server::run`] serves forever on the calling thread
/// (the CLI path); [`Server::spawn`] serves on a background thread and
/// returns a stop handle (the test/bench path).
pub struct Server {
    listener: TcpListener,
    state: Arc<ServeState>,
}

impl Server {
    /// Bind `addr` (e.g. `127.0.0.1:4517`; port `0` picks a free port).
    pub fn bind(addr: &str, opts: ServeOptions) -> std::io::Result<Server> {
        Ok(Server {
            listener: TcpListener::bind(addr)?,
            state: Arc::new(ServeState::new(&opts)),
        })
    }

    /// The bound socket address (reports the picked port after binding 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.listener.local_addr().expect("bound listener has a local address")
    }

    /// Serve forever: accept loop on the calling thread, one short-lived
    /// thread per connection (the protocol is `Connection: close`, so
    /// connections are exactly one request long).
    pub fn run(self) {
        for stream in self.listener.incoming() {
            match stream {
                Ok(s) => {
                    let state = Arc::clone(&self.state);
                    std::thread::spawn(move || handle_connection(&state, s));
                }
                Err(e) => {
                    if !self.state.quiet {
                        eprintln!("[serve] accept failed: {e}");
                    }
                }
            }
        }
    }

    /// Serve on a background thread; the returned handle stops the server
    /// when dropped (or explicitly via [`ServerHandle::stop`]).
    pub fn spawn(self) -> ServerHandle {
        let addr = self.local_addr();
        let stop = Arc::new(AtomicBool::new(false));
        let state = Arc::clone(&self.state);
        let accept_state = Arc::clone(&self.state);
        let flag = Arc::clone(&stop);
        let listener = self.listener;
        let thread = std::thread::spawn(move || {
            for stream in listener.incoming() {
                if flag.load(Ordering::SeqCst) {
                    break;
                }
                match stream {
                    Ok(s) => {
                        let state = Arc::clone(&accept_state);
                        std::thread::spawn(move || handle_connection(&state, s));
                    }
                    Err(_) => break,
                }
            }
        });
        ServerHandle { addr, state, stop, thread: Some(thread) }
    }
}

/// Handle to a [`Server::spawn`]ed background server.
pub struct ServerHandle {
    addr: SocketAddr,
    state: Arc<ServeState>,
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// The server's socket address.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The shared server state (cache totals etc.).
    pub fn state(&self) -> &ServeState {
        &self.state
    }

    /// POST a request envelope to the server and parse the response.
    pub fn post(&self, doc: &Json) -> Result<(u16, Json), String> {
        http::post_json(&self.addr, "/", doc)
    }

    /// POST a request envelope and return the raw response body text.
    pub fn post_raw(&self, doc: &Json) -> Result<(u16, String), String> {
        http::post_json_raw(&self.addr, "/", doc)
    }

    /// Stop the accept loop and join the server thread. In-flight
    /// connection threads finish on their own.
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        if let Some(t) = self.thread.take() {
            self.stop.store(true, Ordering::SeqCst);
            // Unblock the accept loop with a throwaway connection.
            let _ = TcpStream::connect(self.addr);
            let _ = t.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn handle_connection(state: &ServeState, mut stream: TcpStream) {
    let req = match http::read_request(&mut stream) {
        Ok(Some(r)) => r,
        Ok(None) => return,
        Err(e) => {
            let body = serve_error(None, &e).pretty();
            let _ = http::write_response(&mut stream, 400, "Bad Request", body.as_bytes());
            return;
        }
    };
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/health") => {
            let (hits, misses) = state.cache.totals();
            let body = Json::Obj(
                [
                    ("ok".to_string(), Json::Bool(true)),
                    ("service".to_string(), Json::Str("looptree".to_string())),
                    ("cache_entries".to_string(), Json::Num(state.cache.len() as f64)),
                    ("cache_hits_total".to_string(), Json::Num(hits as f64)),
                    ("cache_misses_total".to_string(), Json::Num(misses as f64)),
                ]
                .into_iter()
                .collect(),
            )
            .pretty();
            let _ = http::write_response(&mut stream, 200, "OK", body.as_bytes());
        }
        ("POST", _) => {
            let doc = match std::str::from_utf8(&req.body)
                .map_err(|_| "request body is not UTF-8".to_string())
                .and_then(|t| Json::parse(t).map_err(|e| format!("request body: {e}")))
            {
                Ok(d) => d,
                Err(e) => {
                    let body = serve_error(None, &e).pretty();
                    let _ =
                        http::write_response(&mut stream, 400, "Bad Request", body.as_bytes());
                    return;
                }
            };
            let resp = process_request(state, &doc);
            let ok = resp.get("ok").and_then(Json::as_bool).unwrap_or(false);
            if !state.quiet {
                let kind = resp.get("kind").and_then(Json::as_str).unwrap_or("?");
                let s = response_stats(&resp);
                println!(
                    "[serve] kind={kind} ok={ok} cache_hits={} cache_misses={} warm_starts={}",
                    s.cache_hits, s.cache_misses, s.warm_starts
                );
            }
            let (status, reason) = if ok { (200, "OK") } else { (400, "Bad Request") };
            let _ = http::write_response(&mut stream, status, reason, resp.pretty().as_bytes());
        }
        _ => {
            let body = serve_error(None, "unsupported method or path (POST / or GET /health)")
                .pretty();
            let _ = http::write_response(&mut stream, 404, "Not Found", body.as_bytes());
        }
    }
}
