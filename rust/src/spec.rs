//! The serializable spec layer: JSON round-trips for every public spec and
//! result type, built on the vendored [`crate::util::json`] substrate.
//!
//! This is the machine-readable surface the CLI (`--config` / `--json`) and
//! external mappers drive (MAESTRO-style declarative specs; DNNFuser-style
//! learned mappers consume the same documents). Every `to_json` output
//! parses back with the matching `from_json` to an equal value, and
//! structural validation runs on the way in — a parsed [`FusionSet`] or
//! [`Arch`] is ready for [`crate::model::Evaluator::new`] without further
//! checks.
//!
//! Numbers are carried as JSON numbers (f64): exact for every count this
//! crate produces (|n| < 2^53).

use crate::arch::{presets, Arch, BufferLevel, ComputeSpec, NocSpec};
use crate::einsum::{
    workloads, EinsumSpec, FusionSet, OpKind, TensorAccess, TensorId, TensorInfo, TensorKind,
};
use crate::mapping::{InterLayerMapping, Parallelism, Partition};
use crate::mapspace::MapSpaceConfig;
use crate::model::{EnergyBreakdown, Metrics, PathCounts};
use crate::network::{
    self, LayerOp, LayerSpec, Network, NetworkParetoResult, NetworkSearchResult,
    NetworkSearchSpec,
};
use crate::poly::{AffineExpr, AffineMap};
use crate::search::{Algorithm, Objective, Scored, SearchSpec};
use crate::util::json::Json;
use std::collections::HashMap;

// ------------------------------------------------------------- helpers --

fn jnum_i(v: i64) -> Json {
    Json::Num(v as f64)
}

fn jnum_u(v: usize) -> Json {
    Json::Num(v as f64)
}

fn jstr(v: &str) -> Json {
    Json::Str(v.to_string())
}

fn jarr(v: Vec<Json>) -> Json {
    Json::Arr(v)
}

fn jobj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn field<'a>(j: &'a Json, key: &str, ctx: &str) -> Result<&'a Json, String> {
    j.get(key)
        .ok_or_else(|| format!("{ctx}: missing field '{key}'"))
}

fn str_field<'a>(j: &'a Json, key: &str, ctx: &str) -> Result<&'a str, String> {
    field(j, key, ctx)?
        .as_str()
        .ok_or_else(|| format!("{ctx}: field '{key}' must be a string"))
}

fn i64_field(j: &Json, key: &str, ctx: &str) -> Result<i64, String> {
    field(j, key, ctx)?
        .as_i64()
        .ok_or_else(|| format!("{ctx}: field '{key}' must be a number"))
}

fn f64_field(j: &Json, key: &str, ctx: &str) -> Result<f64, String> {
    field(j, key, ctx)?
        .as_f64()
        .ok_or_else(|| format!("{ctx}: field '{key}' must be a number"))
}

fn usize_field(j: &Json, key: &str, ctx: &str) -> Result<usize, String> {
    let v = i64_field(j, key, ctx)?;
    if v < 0 {
        return Err(format!("{ctx}: field '{key}' must be non-negative"));
    }
    Ok(v as usize)
}

fn arr_field<'a>(j: &'a Json, key: &str, ctx: &str) -> Result<&'a [Json], String> {
    field(j, key, ctx)?
        .as_arr()
        .ok_or_else(|| format!("{ctx}: field '{key}' must be an array"))
}

/// `parent.key` — the JSON-path context threaded through parsing so every
/// error names the offending key (e.g. `workload.einsums[3].inputs[0]`).
/// Lint reuses these paths as diagnostic spans.
fn jpath(parent: &str, key: &str) -> String {
    if parent.is_empty() {
        key.to_string()
    } else {
        format!("{parent}.{key}")
    }
}

/// `parent.key[i]` — indexed JSON-path context for array elements.
fn jidx(parent: &str, key: &str, i: usize) -> String {
    format!("{}[{i}]", jpath(parent, key))
}

fn i64_vec(j: &Json, ctx: &str) -> Result<Vec<i64>, String> {
    j.as_arr()
        .ok_or_else(|| format!("{ctx}: expected an array of numbers"))?
        .iter()
        .map(|v| v.as_i64().ok_or_else(|| format!("{ctx}: expected a number")))
        .collect()
}

fn str_vec(j: &Json, ctx: &str) -> Result<Vec<String>, String> {
    j.as_arr()
        .ok_or_else(|| format!("{ctx}: expected an array of strings"))?
        .iter()
        .map(|v| {
            v.as_str()
                .map(str::to_string)
                .ok_or_else(|| format!("{ctx}: expected a string"))
        })
        .collect()
}

// ------------------------------------------------------------ workloads --

/// Parse a compact workload spec string, e.g. `conv_conv:28x64`,
/// `pdp:28x16`, `fc_fc:512x256`, `conv3:24x8`, `attention:2,4,64,32`.
/// The JSON layer accepts either this shorthand or a full [`FusionSet`]
/// object wherever a workload is expected.
/// The workload shorthand grammar, quoted by parse errors so the CLI names
/// the valid wire formats instead of sending the user to the README.
pub const WORKLOAD_SHORTHANDS: &str =
    "conv_conv:RxC | conv3:RxC | pdp:RxC | fc_fc:TxE | attention:B,H,T,E";

/// Parse a compact workload shorthand string (grammar:
/// [`WORKLOAD_SHORTHANDS`]) into a built-in [`FusionSet`].
pub fn parse_workload(spec: &str) -> Result<FusionSet, String> {
    let (kind, rest) = spec
        .split_once(':')
        .ok_or_else(|| format!("workload spec needs kind:params (one of {WORKLOAD_SHORTHANDS})"))?;
    let nums: Vec<i64> = rest
        .split(|c| c == 'x' || c == ',')
        .map(|s| s.parse::<i64>().map_err(|e| format!("bad number {s}: {e}")))
        .collect::<Result<_, _>>()?;
    match (kind, nums.as_slice()) {
        ("conv_conv", [r, c]) => Ok(workloads::conv_conv(*r, *c)),
        ("conv3", [r, c]) => Ok(workloads::conv_conv_conv(*r, *c)),
        ("pdp", [r, c]) => Ok(workloads::pwise_dwise_pwise(*r, *c)),
        ("fc_fc", [t, e]) => Ok(workloads::fc_fc(*t, *e)),
        ("attention", [b, h, t, e]) => Ok(workloads::self_attention(*b, *h, *t, *e)),
        _ => Err(format!(
            "unknown workload spec: {spec} (expected {WORKLOAD_SHORTHANDS})"
        )),
    }
}

/// A workload position in a config: either the shorthand string or a full
/// [`FusionSet`] object.
pub fn workload_from_json(j: &Json) -> Result<FusionSet, String> {
    workload_from_json_at(j, "workload")
}

/// [`workload_from_json`] with an explicit JSON-path context.
fn workload_from_json_at(j: &Json, ctx: &str) -> Result<FusionSet, String> {
    match j {
        Json::Str(s) => parse_workload(s).map_err(|e| format!("{ctx}: {e}")),
        _ => FusionSet::from_json_at(j, ctx),
    }
}

/// An architecture position in a config: `"generic:<glb KiB>"`, a preset
/// name (`depfin` | `fused-cnn` | `isaac` | `pipelayer` | `flat`), or a full
/// [`Arch`] object.
pub fn arch_from_json(j: &Json) -> Result<Arch, String> {
    arch_from_json_at(j, "arch")
}

/// The architecture shorthand grammar, quoted by parse errors.
pub const ARCH_SHORTHANDS: &str =
    "depfin | fused-cnn | isaac | pipelayer | flat | generic:<GLB KiB>";

/// [`arch_from_json`] with an explicit JSON-path context.
fn arch_from_json_at(j: &Json, ctx: &str) -> Result<Arch, String> {
    match j {
        Json::Str(s) => match s.as_str() {
            "depfin" => Ok(presets::depfin()),
            "fused-cnn" => Ok(presets::fused_cnn()),
            "isaac" => Ok(presets::isaac()),
            "pipelayer" => Ok(presets::pipelayer()),
            "flat" => Ok(presets::flat()),
            other => {
                if let Some(kib) = other.strip_prefix("generic:") {
                    let kib: i64 = kib
                        .parse()
                        .map_err(|e| format!("{ctx}: generic:<KiB>: {e}"))?;
                    Ok(Arch::generic(kib))
                } else {
                    Err(format!(
                        "{ctx}: unknown arch shorthand: {other} (expected {ARCH_SHORTHANDS})"
                    ))
                }
            }
        },
        _ => Arch::from_json_at(j, ctx),
    }
}

// ----------------------------------------------------- einsum / workload --

fn tensor_kind_name(k: TensorKind) -> &'static str {
    match k {
        TensorKind::InputFmap => "input_fmap",
        TensorKind::Weight => "weight",
        TensorKind::Intermediate => "intermediate",
        TensorKind::OutputFmap => "output_fmap",
    }
}

fn tensor_kind_parse(s: &str) -> Result<TensorKind, String> {
    match s {
        "input_fmap" => Ok(TensorKind::InputFmap),
        "weight" => Ok(TensorKind::Weight),
        "intermediate" => Ok(TensorKind::Intermediate),
        "output_fmap" => Ok(TensorKind::OutputFmap),
        other => Err(format!("unknown tensor kind: {other}")),
    }
}

fn op_kind_name(k: OpKind) -> &'static str {
    match k {
        OpKind::Mac => "mac",
        OpKind::Max => "max",
        OpKind::Elementwise => "elementwise",
    }
}

fn op_kind_parse(s: &str) -> Result<OpKind, String> {
    match s {
        "mac" => Ok(OpKind::Mac),
        "max" => Ok(OpKind::Max),
        "elementwise" => Ok(OpKind::Elementwise),
        other => Err(format!("unknown op kind: {other}")),
    }
}

impl AffineExpr {
    /// Serialize to the JSON wire form.
    pub fn to_json(&self) -> Json {
        jobj(vec![
            (
                "terms",
                jarr(self
                    .terms
                    .iter()
                    .map(|&(d, c)| jarr(vec![jnum_u(d), jnum_i(c)]))
                    .collect()),
            ),
            ("offset", jnum_i(self.offset)),
        ])
    }

    /// Parse from the JSON wire form; errors carry the offending JSON path.
    pub fn from_json(j: &Json) -> Result<AffineExpr, String> {
        Self::from_json_at(j, "affine expr")
    }

    /// [`AffineExpr::from_json`] with an explicit JSON-path context.
    fn from_json_at(j: &Json, ctx: &str) -> Result<AffineExpr, String> {
        let mut terms = Vec::new();
        for (i, t) in arr_field(j, "terms", ctx)?.iter().enumerate() {
            let pair = i64_vec(t, &jidx(ctx, "terms", i))?;
            if pair.len() != 2 {
                return Err(format!("{ctx}: each term must be [dim, coeff]"));
            }
            if pair[0] < 0 {
                return Err(format!("{ctx}: negative dim index"));
            }
            terms.push((pair[0] as usize, pair[1]));
        }
        let offset = match j.get("offset") {
            Some(v) => v
                .as_i64()
                .ok_or_else(|| format!("{ctx}: offset must be a number"))?,
            None => 0,
        };
        Ok(AffineExpr { terms, offset })
    }
}

impl AffineMap {
    /// Serialize to the JSON wire form.
    pub fn to_json(&self) -> Json {
        jarr(self.exprs.iter().map(|e| e.to_json()).collect())
    }

    /// Parse from the JSON wire form; errors carry the offending JSON path.
    pub fn from_json(j: &Json) -> Result<AffineMap, String> {
        Self::from_json_at(j, "affine map")
    }

    /// [`AffineMap::from_json`] with an explicit JSON-path context.
    fn from_json_at(j: &Json, ctx: &str) -> Result<AffineMap, String> {
        let exprs = j
            .as_arr()
            .ok_or_else(|| format!("{ctx}: expected an array of expressions"))?
            .iter()
            .enumerate()
            .map(|(i, e)| AffineExpr::from_json_at(e, &format!("{ctx}[{i}]")))
            .collect::<Result<_, _>>()?;
        Ok(AffineMap { exprs })
    }
}

impl TensorAccess {
    /// Serialize to the JSON wire form.
    pub fn to_json(&self) -> Json {
        jobj(vec![
            ("tensor", jnum_u(self.tensor.0)),
            ("map", self.map.to_json()),
        ])
    }

    /// Parse from the JSON wire form; errors carry the offending JSON path.
    pub fn from_json(j: &Json) -> Result<TensorAccess, String> {
        Self::from_json_at(j, "tensor access")
    }

    /// [`TensorAccess::from_json`] with an explicit JSON-path context.
    fn from_json_at(j: &Json, ctx: &str) -> Result<TensorAccess, String> {
        Ok(TensorAccess {
            tensor: TensorId(usize_field(j, "tensor", ctx)?),
            map: AffineMap::from_json_at(field(j, "map", ctx)?, &jpath(ctx, "map"))?,
        })
    }
}

impl TensorInfo {
    /// Serialize to the JSON wire form.
    pub fn to_json(&self) -> Json {
        jobj(vec![
            ("name", jstr(&self.name)),
            ("shape", jarr(self.shape.iter().map(|&s| jnum_i(s)).collect())),
            ("kind", jstr(tensor_kind_name(self.kind))),
        ])
    }

    /// Parse from the JSON wire form; errors carry the offending JSON path.
    pub fn from_json(j: &Json) -> Result<TensorInfo, String> {
        Self::from_json_at(j, "tensor")
    }

    /// [`TensorInfo::from_json`] with an explicit JSON-path context.
    fn from_json_at(j: &Json, ctx: &str) -> Result<TensorInfo, String> {
        Ok(TensorInfo {
            name: str_field(j, "name", ctx)?.to_string(),
            shape: i64_vec(field(j, "shape", ctx)?, ctx)?,
            kind: tensor_kind_parse(str_field(j, "kind", ctx)?)?,
        })
    }
}

impl EinsumSpec {
    /// Serialize to the JSON wire form.
    pub fn to_json(&self) -> Json {
        jobj(vec![
            ("name", jstr(&self.name)),
            (
                "rank_names",
                jarr(self.rank_names.iter().map(|n| jstr(n)).collect()),
            ),
            (
                "rank_sizes",
                jarr(self.rank_sizes.iter().map(|&s| jnum_i(s)).collect()),
            ),
            ("output", self.output.to_json()),
            ("inputs", jarr(self.inputs.iter().map(|a| a.to_json()).collect())),
            ("op_kind", jstr(op_kind_name(self.op_kind))),
        ])
    }

    /// Parse from the JSON wire form; errors carry the offending JSON path.
    pub fn from_json(j: &Json) -> Result<EinsumSpec, String> {
        Self::from_json_at(j, "einsum")
    }

    /// [`EinsumSpec::from_json`] with an explicit JSON-path context.
    fn from_json_at(j: &Json, ctx: &str) -> Result<EinsumSpec, String> {
        Ok(EinsumSpec {
            name: str_field(j, "name", ctx)?.to_string(),
            rank_names: str_vec(field(j, "rank_names", ctx)?, &jpath(ctx, "rank_names"))?,
            rank_sizes: i64_vec(field(j, "rank_sizes", ctx)?, &jpath(ctx, "rank_sizes"))?,
            output: TensorAccess::from_json_at(
                field(j, "output", ctx)?,
                &jpath(ctx, "output"),
            )?,
            inputs: arr_field(j, "inputs", ctx)?
                .iter()
                .enumerate()
                .map(|(i, a)| TensorAccess::from_json_at(a, &jidx(ctx, "inputs", i)))
                .collect::<Result<_, _>>()?,
            op_kind: op_kind_parse(str_field(j, "op_kind", ctx)?)
                .map_err(|e| format!("{ctx}: {e}"))?,
        })
    }
}

impl FusionSet {
    /// Serialize to the JSON wire form.
    pub fn to_json(&self) -> Json {
        jobj(vec![
            ("name", jstr(&self.name)),
            ("tensors", jarr(self.tensors.iter().map(|t| t.to_json()).collect())),
            ("einsums", jarr(self.einsums.iter().map(|e| e.to_json()).collect())),
        ])
    }

    /// Parse and structurally validate; the returned fusion set satisfies
    /// [`FusionSet::validate`].
    pub fn from_json(j: &Json) -> Result<FusionSet, String> {
        Self::from_json_at(j, "fusion set")
    }

    /// [`FusionSet::from_json`] with an explicit JSON-path context.
    fn from_json_at(j: &Json, ctx: &str) -> Result<FusionSet, String> {
        let fs = FusionSet {
            name: str_field(j, "name", ctx)?.to_string(),
            tensors: arr_field(j, "tensors", ctx)?
                .iter()
                .enumerate()
                .map(|(i, t)| TensorInfo::from_json_at(t, &jidx(ctx, "tensors", i)))
                .collect::<Result<_, _>>()?,
            einsums: arr_field(j, "einsums", ctx)?
                .iter()
                .enumerate()
                .map(|(i, e)| EinsumSpec::from_json_at(e, &jidx(ctx, "einsums", i)))
                .collect::<Result<_, _>>()?,
        };
        for e in &fs.einsums {
            for acc in e.inputs.iter().chain(std::iter::once(&e.output)) {
                if acc.tensor.0 >= fs.tensors.len() {
                    return Err(format!(
                        "{ctx}: {} references tensor {} out of range",
                        e.name, acc.tensor.0
                    ));
                }
            }
        }
        fs.validate().map_err(|e| format!("{ctx}: {e}"))?;
        Ok(fs)
    }
}

// ---------------------------------------------------------------- arch --

impl BufferLevel {
    /// Serialize to the JSON wire form.
    pub fn to_json(&self) -> Json {
        // Bandwidth may be infinite (register files); JSON has no inf, so
        // `null` encodes it symmetrically with unbounded capacity.
        let bw = if self.bandwidth_words_per_cycle.is_finite() {
            Json::Num(self.bandwidth_words_per_cycle)
        } else {
            Json::Null
        };
        jobj(vec![
            ("name", jstr(&self.name)),
            (
                "capacity_bytes",
                self.capacity_bytes.map(jnum_i).unwrap_or(Json::Null),
            ),
            ("bandwidth_words_per_cycle", bw),
            ("read_energy_pj", Json::Num(self.read_energy_pj)),
            ("write_energy_pj", Json::Num(self.write_energy_pj)),
        ])
    }

    /// Parse from the JSON wire form; errors carry the offending JSON path.
    pub fn from_json(j: &Json) -> Result<BufferLevel, String> {
        Self::from_json_at(j, "buffer level")
    }

    /// [`BufferLevel::from_json`] with an explicit JSON-path context.
    fn from_json_at(j: &Json, ctx: &str) -> Result<BufferLevel, String> {
        let capacity_bytes = match field(j, "capacity_bytes", ctx)? {
            Json::Null => None,
            v => Some(
                v.as_i64()
                    .ok_or_else(|| format!("{ctx}: capacity_bytes must be a number or null"))?,
            ),
        };
        let bandwidth = match field(j, "bandwidth_words_per_cycle", ctx)? {
            Json::Null => f64::INFINITY,
            v => v.as_f64().ok_or_else(|| {
                format!("{ctx}: bandwidth_words_per_cycle must be a number or null")
            })?,
        };
        Ok(BufferLevel {
            name: str_field(j, "name", ctx)?.to_string(),
            capacity_bytes,
            bandwidth_words_per_cycle: bandwidth,
            read_energy_pj: f64_field(j, "read_energy_pj", ctx)?,
            write_energy_pj: f64_field(j, "write_energy_pj", ctx)?,
        })
    }
}

impl Arch {
    /// Serialize to the JSON wire form.
    pub fn to_json(&self) -> Json {
        jobj(vec![
            ("name", jstr(&self.name)),
            ("levels", jarr(self.levels.iter().map(|l| l.to_json()).collect())),
            (
                "compute",
                jobj(vec![
                    ("macs", jnum_i(self.compute.macs)),
                    ("mac_energy_pj", Json::Num(self.compute.mac_energy_pj)),
                    ("clock_ghz", Json::Num(self.compute.clock_ghz)),
                ]),
            ),
            (
                "noc",
                jobj(vec![
                    ("rows", jnum_i(self.noc.rows)),
                    ("cols", jnum_i(self.noc.cols)),
                    ("hop_energy_pj", Json::Num(self.noc.hop_energy_pj)),
                ]),
            ),
            ("word_bytes", jnum_i(self.word_bytes)),
        ])
    }

    /// Parse and structurally validate; the returned architecture satisfies
    /// [`Arch::validate`].
    pub fn from_json(j: &Json) -> Result<Arch, String> {
        Self::from_json_at(j, "arch")
    }

    /// [`Arch::from_json`] with an explicit JSON-path context.
    fn from_json_at(j: &Json, ctx: &str) -> Result<Arch, String> {
        let compute = field(j, "compute", ctx)?;
        let noc = field(j, "noc", ctx)?;
        let compute_ctx = jpath(ctx, "compute");
        let noc_ctx = jpath(ctx, "noc");
        let arch = Arch {
            name: str_field(j, "name", ctx)?.to_string(),
            levels: arr_field(j, "levels", ctx)?
                .iter()
                .enumerate()
                .map(|(i, l)| BufferLevel::from_json_at(l, &jidx(ctx, "levels", i)))
                .collect::<Result<_, _>>()?,
            compute: ComputeSpec {
                macs: i64_field(compute, "macs", &compute_ctx)?,
                mac_energy_pj: f64_field(compute, "mac_energy_pj", &compute_ctx)?,
                clock_ghz: f64_field(compute, "clock_ghz", &compute_ctx)?,
            },
            noc: NocSpec {
                rows: i64_field(noc, "rows", &noc_ctx)?,
                cols: i64_field(noc, "cols", &noc_ctx)?,
                hop_energy_pj: f64_field(noc, "hop_energy_pj", &noc_ctx)?,
            },
            word_bytes: i64_field(j, "word_bytes", ctx)?,
        };
        arch.validate().map_err(|e| format!("{ctx}: {e}"))?;
        Ok(arch)
    }
}

// ------------------------------------------------------------- mapping --

impl Parallelism {
    /// Serialize to the JSON wire form.
    pub fn to_json(&self) -> Json {
        jstr(match self {
            Parallelism::Sequential => "sequential",
            Parallelism::Pipeline => "pipeline",
        })
    }

    /// Parse from the JSON wire form; errors carry the offending JSON path.
    pub fn from_json(j: &Json) -> Result<Parallelism, String> {
        match j.as_str() {
            Some("sequential") => Ok(Parallelism::Sequential),
            Some("pipeline") => Ok(Parallelism::Pipeline),
            _ => Err("parallelism must be \"sequential\" or \"pipeline\"".into()),
        }
    }
}

impl Partition {
    /// Serialize to the JSON wire form.
    pub fn to_json(&self) -> Json {
        jobj(vec![("dim", jnum_u(self.dim)), ("tile", jnum_i(self.tile))])
    }

    /// Parse from the JSON wire form; errors carry the offending JSON path.
    pub fn from_json(j: &Json) -> Result<Partition, String> {
        Self::from_json_at(j, "partition")
    }

    /// [`Partition::from_json`] with an explicit JSON-path context.
    fn from_json_at(j: &Json, ctx: &str) -> Result<Partition, String> {
        Ok(Partition {
            dim: usize_field(j, "dim", ctx)?,
            tile: i64_field(j, "tile", ctx)?,
        })
    }
}

impl InterLayerMapping {
    /// Serialize to the JSON wire form.
    pub fn to_json(&self) -> Json {
        // Retention as sorted [tensor, level] pairs for deterministic output.
        let mut retention: Vec<(usize, usize)> =
            self.retention.iter().map(|(&t, &l)| (t.0, l)).collect();
        retention.sort_unstable();
        jobj(vec![
            (
                "partitions",
                jarr(self.partitions.iter().map(|p| p.to_json()).collect()),
            ),
            (
                "retention",
                jarr(retention
                    .into_iter()
                    .map(|(t, l)| jarr(vec![jnum_u(t), jnum_u(l)]))
                    .collect()),
            ),
            ("default_retention", jnum_u(self.default_retention)),
            ("parallelism", self.parallelism.to_json()),
        ])
    }

    /// Parse a mapping. `partitions` defaults to `[]` (untiled),
    /// `retention` to `[]`, `default_retention` to the number of partitions
    /// (the [`InterLayerMapping::tiled`] convention), and `parallelism` to
    /// sequential — so the minimal valid document is `{}`.
    pub fn from_json(j: &Json) -> Result<InterLayerMapping, String> {
        Self::from_json_at(j, "mapping")
    }

    /// [`InterLayerMapping::from_json`] with an explicit JSON-path context.
    fn from_json_at(j: &Json, ctx: &str) -> Result<InterLayerMapping, String> {
        let partitions: Vec<Partition> = match j.get("partitions") {
            Some(v) => v
                .as_arr()
                .ok_or_else(|| format!("{ctx}: partitions must be an array"))?
                .iter()
                .enumerate()
                .map(|(i, p)| Partition::from_json_at(p, &jidx(ctx, "partitions", i)))
                .collect::<Result<_, _>>()?,
            None => vec![],
        };
        let mut retention = HashMap::new();
        if let Some(v) = j.get("retention") {
            for (i, pair) in v
                .as_arr()
                .ok_or_else(|| format!("{ctx}: retention must be an array of pairs"))?
                .iter()
                .enumerate()
            {
                let ictx = jidx(ctx, "retention", i);
                let p = i64_vec(pair, &ictx)?;
                if p.len() != 2 || p[0] < 0 || p[1] < 0 {
                    return Err(format!("{ictx}: retention entries must be [tensor, level]"));
                }
                retention.insert(TensorId(p[0] as usize), p[1] as usize);
            }
        }
        let default_retention = match j.get("default_retention") {
            Some(v) => {
                let d = v
                    .as_i64()
                    .ok_or_else(|| format!("{ctx}: default_retention must be a number"))?;
                if d < 0 {
                    return Err(format!("{ctx}: default_retention must be non-negative"));
                }
                d as usize
            }
            None => partitions.len(),
        };
        let parallelism = match j.get("parallelism") {
            Some(v) => Parallelism::from_json(v)?,
            None => Parallelism::Sequential,
        };
        Ok(InterLayerMapping { partitions, retention, default_retention, parallelism })
    }
}

// ------------------------------------------------------------ mapspace --

impl MapSpaceConfig {
    /// Serialize to the JSON wire form.
    pub fn to_json(&self) -> Json {
        jobj(vec![
            (
                "schedules",
                jarr(self
                    .schedules
                    .iter()
                    .map(|names| jarr(names.iter().map(|n| jstr(n)).collect()))
                    .collect()),
            ),
            (
                "tile_sizes",
                jarr(self.tile_sizes.iter().map(|&t| jnum_i(t)).collect()),
            ),
            ("uniform_retention", Json::Bool(self.uniform_retention)),
            (
                "parallelism",
                jarr(self.parallelism.iter().map(|p| p.to_json()).collect()),
            ),
            ("max_mappings", jnum_u(self.max_mappings)),
        ])
    }

    /// Parse a mapspace config; every absent field takes its
    /// [`MapSpaceConfig::default`] value.
    pub fn from_json(j: &Json) -> Result<MapSpaceConfig, String> {
        Self::from_json_at(j, "mapspace")
    }

    /// [`MapSpaceConfig::from_json`] with an explicit JSON-path context.
    fn from_json_at(j: &Json, ctx: &str) -> Result<MapSpaceConfig, String> {
        let d = MapSpaceConfig::default();
        let schedules = match j.get("schedules") {
            Some(v) => v
                .as_arr()
                .ok_or_else(|| format!("{ctx}: schedules must be an array"))?
                .iter()
                .enumerate()
                .map(|(i, names)| str_vec(names, &jidx(ctx, "schedules", i)))
                .collect::<Result<_, _>>()?,
            None => d.schedules,
        };
        let tile_sizes = match j.get("tile_sizes") {
            Some(v) => i64_vec(v, &jpath(ctx, "tile_sizes"))?,
            None => d.tile_sizes,
        };
        let uniform_retention = match j.get("uniform_retention") {
            Some(v) => v
                .as_bool()
                .ok_or_else(|| format!("{ctx}: uniform_retention must be a bool"))?,
            None => d.uniform_retention,
        };
        let parallelism = match j.get("parallelism") {
            Some(v) => v
                .as_arr()
                .ok_or_else(|| format!("{ctx}: parallelism must be an array"))?
                .iter()
                .map(Parallelism::from_json)
                .collect::<Result<_, _>>()?,
            None => d.parallelism,
        };
        let max_mappings = match j.get("max_mappings") {
            Some(v) => {
                let m = v
                    .as_i64()
                    .ok_or_else(|| format!("{ctx}: max_mappings must be a number"))?;
                if m < 0 {
                    return Err(format!("{ctx}: max_mappings must be non-negative"));
                }
                m as usize
            }
            None => d.max_mappings,
        };
        Ok(MapSpaceConfig {
            schedules,
            tile_sizes,
            uniform_retention,
            parallelism,
            max_mappings,
        })
    }
}

// -------------------------------------------------------------- search --

impl Objective {
    /// Serialize to the JSON wire form.
    pub fn to_json(&self) -> Json {
        jstr(self.name())
    }

    /// Parse from the JSON wire form; errors carry the offending JSON path.
    pub fn from_json(j: &Json) -> Result<Objective, String> {
        Objective::parse(j.as_str().ok_or("objective must be a string")?)
    }
}

impl Algorithm {
    /// Serialize to the JSON wire form.
    pub fn to_json(&self) -> Json {
        jstr(self.name())
    }

    /// Parse from the JSON wire form; errors carry the offending JSON path.
    pub fn from_json(j: &Json) -> Result<Algorithm, String> {
        Algorithm::parse(j.as_str().ok_or("algorithm must be a string")?)
    }
}

impl SearchSpec {
    /// Serialize to the JSON wire form.
    pub fn to_json(&self) -> Json {
        jobj(vec![
            ("algorithm", self.algorithm.to_json()),
            ("objective", self.objective.to_json()),
            (
                // Exact for any u64: numbers up to 2^53, strings beyond
                // (f64 cannot carry larger integers losslessly).
                "seed",
                if self.seed <= (1 << 53) {
                    Json::Num(self.seed as f64)
                } else {
                    Json::Str(self.seed.to_string())
                },
            ),
            ("samples", jnum_u(self.samples)),
            ("iters", jnum_u(self.iters)),
            ("population", jnum_u(self.population)),
            ("generations", jnum_u(self.generations)),
            ("mapspace", self.mapspace.to_json()),
            ("penalize_infeasible", Json::Bool(self.penalize_infeasible)),
            ("prune", Json::Bool(self.prune)),
        ])
    }

    /// Parse a search spec; every absent field takes its
    /// [`SearchSpec::default`] value, so `{}` is a valid exhaustive search.
    pub fn from_json(j: &Json) -> Result<SearchSpec, String> {
        Self::from_json_at(j, "search")
    }

    /// [`SearchSpec::from_json`] with an explicit JSON-path context.
    fn from_json_at(j: &Json, ctx: &str) -> Result<SearchSpec, String> {
        let d = SearchSpec::default();
        let algorithm = match j.get("algorithm") {
            Some(v) => Algorithm::from_json(v).map_err(|e| format!("{ctx}.algorithm: {e}"))?,
            None => d.algorithm,
        };
        let objective = match j.get("objective") {
            Some(v) => Objective::from_json(v).map_err(|e| format!("{ctx}.objective: {e}"))?,
            None => d.objective,
        };
        let seed = match j.get("seed") {
            // Large seeds arrive as strings (see to_json); parse exactly.
            Some(Json::Str(s)) => s
                .parse::<u64>()
                .map_err(|e| format!("{ctx}: seed: {e}"))?,
            Some(v) => {
                // as_i64 is exact-integer-only, so fractional or >2^53 seeds
                // (unrepresentable in a JSON number) are rejected here.
                let s = v
                    .as_i64()
                    .ok_or_else(|| format!("{ctx}: seed must be an integer in [0, 2^53]"))?;
                if s < 0 {
                    return Err(format!("{ctx}: seed must be non-negative"));
                }
                s as u64
            }
            None => d.seed,
        };
        let usize_or = |key: &str, dflt: usize| -> Result<usize, String> {
            match j.get(key) {
                Some(v) => {
                    let n = v
                        .as_i64()
                        .ok_or_else(|| format!("{ctx}: {key} must be a number"))?;
                    if n < 0 {
                        return Err(format!("{ctx}: {key} must be non-negative"));
                    }
                    Ok(n as usize)
                }
                None => Ok(dflt),
            }
        };
        let samples = usize_or("samples", d.samples)?;
        let iters = usize_or("iters", d.iters)?;
        let population = usize_or("population", d.population)?;
        let generations = usize_or("generations", d.generations)?;
        let mapspace = match j.get("mapspace") {
            Some(v) => MapSpaceConfig::from_json_at(v, &jpath(ctx, "mapspace"))?,
            None => d.mapspace,
        };
        let penalize_infeasible = match j.get("penalize_infeasible") {
            Some(v) => v
                .as_bool()
                .ok_or_else(|| format!("{ctx}: penalize_infeasible must be a bool"))?,
            None => d.penalize_infeasible,
        };
        let prune = match j.get("prune") {
            Some(v) => v
                .as_bool()
                .ok_or_else(|| format!("{ctx}: prune must be a bool"))?,
            None => d.prune,
        };
        Ok(SearchSpec {
            algorithm,
            objective,
            seed,
            samples,
            iters,
            population,
            generations,
            mapspace,
            penalize_infeasible,
            prune,
        })
    }
}

// ------------------------------------------------------------- network --

impl LayerOp {
    /// Serialize to the JSON wire form.
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![("op", jstr(self.name()))];
        match self {
            LayerOp::Conv2d { out_channels, r, s, stride } => {
                pairs.push(("out_channels", jnum_i(*out_channels)));
                pairs.push(("r", jnum_i(*r)));
                pairs.push(("s", jnum_i(*s)));
                pairs.push(("stride", jnum_i(*stride)));
            }
            LayerOp::Pointwise { out_channels } => {
                pairs.push(("out_channels", jnum_i(*out_channels)));
            }
            LayerOp::Depthwise { r, s, stride } => {
                pairs.push(("r", jnum_i(*r)));
                pairs.push(("s", jnum_i(*s)));
                pairs.push(("stride", jnum_i(*stride)));
            }
            LayerOp::MaxPool { k, stride } => {
                pairs.push(("k", jnum_i(*k)));
                pairs.push(("stride", jnum_i(*stride)));
            }
            LayerOp::Fc { out_features } => {
                pairs.push(("out_features", jnum_i(*out_features)));
            }
            LayerOp::AttentionScores { seq } => pairs.push(("seq", jnum_i(*seq))),
            LayerOp::AttentionValues { emb } => pairs.push(("emb", jnum_i(*emb))),
            LayerOp::Add | LayerOp::Concat => {}
            LayerOp::Pad { h, w } => {
                pairs.push(("h", jnum_i(*h)));
                pairs.push(("w", jnum_i(*w)));
            }
        }
        jobj(pairs)
    }

    /// Parse from the JSON wire form; errors carry the offending JSON path.
    pub fn from_json(j: &Json) -> Result<LayerOp, String> {
        let ctx = "layer op";
        match str_field(j, "op", ctx)? {
            "conv2d" => Ok(LayerOp::Conv2d {
                out_channels: i64_field(j, "out_channels", ctx)?,
                r: i64_field(j, "r", ctx)?,
                s: i64_field(j, "s", ctx)?,
                stride: i64_field(j, "stride", ctx)?,
            }),
            "pointwise" => Ok(LayerOp::Pointwise {
                out_channels: i64_field(j, "out_channels", ctx)?,
            }),
            "depthwise" => Ok(LayerOp::Depthwise {
                r: i64_field(j, "r", ctx)?,
                s: i64_field(j, "s", ctx)?,
                stride: i64_field(j, "stride", ctx)?,
            }),
            "maxpool" => Ok(LayerOp::MaxPool {
                k: i64_field(j, "k", ctx)?,
                stride: i64_field(j, "stride", ctx)?,
            }),
            "fc" => Ok(LayerOp::Fc { out_features: i64_field(j, "out_features", ctx)? }),
            "attention_scores" => Ok(LayerOp::AttentionScores { seq: i64_field(j, "seq", ctx)? }),
            "attention_values" => Ok(LayerOp::AttentionValues { emb: i64_field(j, "emb", ctx)? }),
            "add" => Ok(LayerOp::Add),
            "concat" => Ok(LayerOp::Concat),
            "pad" => Ok(LayerOp::Pad { h: i64_field(j, "h", ctx)?, w: i64_field(j, "w", ctx)? }),
            other => Err(format!(
                "{ctx}: unknown op '{other}' (expected conv2d|pointwise|depthwise|maxpool|\
                 fc|attention_scores|attention_values|add|concat|pad)"
            )),
        }
    }
}

impl LayerSpec {
    /// Serialize to the JSON wire form.
    pub fn to_json(&self) -> Json {
        jobj(vec![
            ("name", jstr(&self.name)),
            (
                "input_shape",
                jarr(self.input_shape.iter().map(|&d| jnum_i(d)).collect()),
            ),
            ("op", self.op.to_json()),
            (
                "inputs",
                jarr(self.inputs.iter().map(|&p| jnum_u(p)).collect()),
            ),
        ])
    }

    /// Parse one node. The `inputs` edge list is optional: when absent, the
    /// node chains from the previous node (`[index - 1]`, or the network
    /// input for node 0) — which is also how the legacy chain schema
    /// (`layers` without edges) is interpreted.
    pub fn from_json(j: &Json, index: usize) -> Result<LayerSpec, String> {
        Self::from_json_at(j, index, "layer")
    }

    /// [`LayerSpec::from_json`] with an explicit JSON-path context.
    fn from_json_at(j: &Json, index: usize, ctx: &str) -> Result<LayerSpec, String> {
        let inputs = match j.get("inputs") {
            Some(v) => {
                let raw = i64_vec(v, &jpath(ctx, "inputs"))?;
                let mut inputs = Vec::with_capacity(raw.len());
                for p in raw {
                    if p < 0 {
                        return Err(format!(
                            "{}: negative input edge {p}",
                            jpath(ctx, "inputs")
                        ));
                    }
                    inputs.push(p as usize);
                }
                inputs
            }
            None if index == 0 => vec![],
            None => vec![index - 1],
        };
        Ok(LayerSpec {
            name: str_field(j, "name", ctx)?.to_string(),
            input_shape: i64_vec(field(j, "input_shape", ctx)?, &jpath(ctx, "input_shape"))?,
            op: LayerOp::from_json(field(j, "op", ctx)?)
                .map_err(|e| format!("{}: {e}", jpath(ctx, "op")))?,
            inputs,
        })
    }
}

impl Network {
    /// Serialize to the JSON wire form.
    pub fn to_json(&self) -> Json {
        jobj(vec![
            ("name", jstr(&self.name)),
            ("nodes", jarr(self.layers.iter().map(|l| l.to_json()).collect())),
        ])
    }

    /// Parse and structurally validate; the returned network satisfies
    /// [`Network::validate`]. Accepts the DAG schema (`nodes`, each with an
    /// explicit `inputs` edge list) and, for back-compat, the chain schema
    /// (`layers` without edges — every layer consumes its predecessor).
    pub fn from_json(j: &Json) -> Result<Network, String> {
        Self::from_json_at(j, "network")
    }

    /// [`Network::from_json`] with an explicit JSON-path context.
    fn from_json_at(j: &Json, ctx: &str) -> Result<Network, String> {
        let (nodes, key) = match j.get("nodes") {
            Some(v) => (
                v.as_arr()
                    .ok_or_else(|| format!("{ctx}: field 'nodes' must be an array"))?,
                "nodes",
            ),
            None => (
                arr_field(j, "layers", ctx)
                    .map_err(|_| format!("{ctx}: missing field 'nodes' (or legacy 'layers')"))?,
                "layers",
            ),
        };
        let net = Network {
            name: str_field(j, "name", ctx)?.to_string(),
            layers: nodes
                .iter()
                .enumerate()
                .map(|(i, v)| LayerSpec::from_json_at(v, i, &jidx(ctx, key, i)))
                .collect::<Result<_, _>>()?,
        };
        net.validate().map_err(|e| reroot_validate_error(e, ctx, key))?;
        Ok(net)
    }
}

/// Reroot a [`Network::validate`] error — which names the offending node as
/// `layer {i} '…'` — onto the JSON path of the node that failed, so lint
/// and CLI users see `network.nodes[3]: layer '…' (op add): …` and can jump
/// straight to the document span that needs fixing.
fn reroot_validate_error(e: String, ctx: &str, key: &str) -> String {
    if let Some(rest) = e.strip_prefix("layer ") {
        let digits: String = rest.chars().take_while(char::is_ascii_digit).collect();
        if let Ok(i) = digits.parse::<usize>() {
            if let Some(tail) = rest[digits.len()..].strip_prefix(' ') {
                return format!("{}: layer {tail}", jidx(ctx, key, i));
            }
        }
    }
    format!("{ctx}: {e}")
}

/// Parse a compact network spec string: `resnet18` (residual DAG) |
/// `resnet18_chain` (its chain projection) | `mobilenetv2` | `vgg16` |
/// `bert:B,H,T,E` (or bare `bert` for the BERT-base encoder block: 1
/// sequence, 12 heads, 512 tokens, 64-dim heads).
pub fn parse_network(spec: &str) -> Result<Network, String> {
    match spec {
        "resnet18" => Ok(network::resnet18()),
        "resnet18_chain" => Ok(network::resnet18_chain()),
        "mobilenetv2" => Ok(network::mobilenet_v2()),
        "vgg16" => Ok(network::vgg16()),
        "bert" => Ok(network::bert_encoder(1, 12, 512, 64)),
        other => {
            if let Some(rest) = other.strip_prefix("bert:") {
                let nums: Vec<i64> = rest
                    .split(',')
                    .map(|s| s.parse::<i64>().map_err(|e| format!("bad number {s}: {e}")))
                    .collect::<Result<_, _>>()?;
                match nums.as_slice() {
                    [b, h, t, e] => Ok(network::bert_encoder(*b, *h, *t, *e)),
                    _ => Err("bert spec needs bert:B,H,T,E".into()),
                }
            } else {
                Err(format!(
                    "unknown network spec: {other} (expected resnet18|resnet18_chain|mobilenetv2|vgg16|bert[:B,H,T,E])"
                ))
            }
        }
    }
}

/// A network position in a config: either the shorthand string or a full
/// [`Network`] object.
pub fn network_from_json(j: &Json) -> Result<Network, String> {
    network_from_json_at(j, "network")
}

/// [`network_from_json`] with an explicit JSON-path context.
fn network_from_json_at(j: &Json, ctx: &str) -> Result<Network, String> {
    match j {
        Json::Str(s) => parse_network(s).map_err(|e| format!("{ctx}: {e}")),
        _ => Network::from_json_at(j, ctx),
    }
}

impl NetworkSearchSpec {
    /// Serialize to the JSON wire form.
    pub fn to_json(&self) -> Json {
        jobj(vec![
            ("max_segment_layers", jnum_u(self.max_segment_layers)),
            ("search", self.search.to_json()),
            (
                "objectives",
                jarr(self.objectives.iter().map(|o| o.to_json()).collect()),
            ),
            ("max_front_per_state", jnum_u(self.max_front_per_state)),
        ])
    }

    /// Parse a network-search spec; every absent field takes its
    /// [`NetworkSearchSpec::default`] value, so `{}` is a valid spec (and
    /// pre-Pareto documents parse unchanged).
    pub fn from_json(j: &Json) -> Result<NetworkSearchSpec, String> {
        Self::from_json_at(j, "segment search")
    }

    /// [`NetworkSearchSpec::from_json`] with an explicit JSON-path context.
    fn from_json_at(j: &Json, ctx: &str) -> Result<NetworkSearchSpec, String> {
        let d = NetworkSearchSpec::default();
        let max_segment_layers = match j.get("max_segment_layers") {
            Some(v) => {
                let m = v
                    .as_i64()
                    .ok_or_else(|| format!("{ctx}: max_segment_layers must be a number"))?;
                if m < 1 {
                    return Err(format!("{ctx}: max_segment_layers must be >= 1"));
                }
                m as usize
            }
            None => d.max_segment_layers,
        };
        let search = match j.get("search") {
            Some(v) => SearchSpec::from_json_at(v, &jpath(ctx, "search"))?,
            None => d.search,
        };
        let objectives = match j.get("objectives") {
            Some(v) => {
                let arr = v
                    .as_arr()
                    .ok_or_else(|| format!("{ctx}: objectives must be an array"))?;
                if arr.is_empty() {
                    return Err(format!("{ctx}: objectives must not be empty"));
                }
                arr.iter()
                    .enumerate()
                    .map(|(i, o)| {
                        Objective::from_json(o)
                            .map_err(|e| format!("{}: {e}", jidx(ctx, "objectives", i)))
                    })
                    .collect::<Result<_, _>>()?
            }
            None => d.objectives,
        };
        let max_front_per_state = match j.get("max_front_per_state") {
            Some(v) => {
                let m = v
                    .as_i64()
                    .ok_or_else(|| format!("{ctx}: max_front_per_state must be a number"))?;
                if m < 0 {
                    return Err(format!("{ctx}: max_front_per_state must be non-negative"));
                }
                m as usize
            }
            None => d.max_front_per_state,
        };
        Ok(NetworkSearchSpec { max_segment_layers, search, objectives, max_front_per_state })
    }
}

// ------------------------------------------------------------- metrics --

impl EnergyBreakdown {
    /// Serialize to the JSON wire form.
    pub fn to_json(&self) -> Json {
        jobj(vec![
            ("dram_pj", Json::Num(self.dram_pj)),
            ("glb_pj", Json::Num(self.glb_pj)),
            ("rf_pj", Json::Num(self.rf_pj)),
            ("compute_pj", Json::Num(self.compute_pj)),
            ("noc_pj", Json::Num(self.noc_pj)),
            ("total_pj", Json::Num(self.total_pj())),
        ])
    }

    /// Parse from the JSON wire form; errors carry the offending JSON path.
    pub fn from_json(j: &Json) -> Result<EnergyBreakdown, String> {
        let ctx = "energy";
        Ok(EnergyBreakdown {
            dram_pj: f64_field(j, "dram_pj", ctx)?,
            glb_pj: f64_field(j, "glb_pj", ctx)?,
            rf_pj: f64_field(j, "rf_pj", ctx)?,
            compute_pj: f64_field(j, "compute_pj", ctx)?,
            noc_pj: f64_field(j, "noc_pj", ctx)?,
        })
    }
}

impl Metrics {
    /// Serialize to the JSON wire form.
    pub fn to_json(&self) -> Json {
        jobj(vec![
            ("latency_cycles", jnum_i(self.latency_cycles)),
            ("compute_cycles", jnum_i(self.compute_cycles)),
            ("memory_cycles", jnum_i(self.memory_cycles)),
            (
                "sequential_compute_cycles",
                jnum_i(self.sequential_compute_cycles),
            ),
            ("energy", self.energy.to_json()),
            ("offchip_reads", jnum_i(self.offchip_reads)),
            ("offchip_writes", jnum_i(self.offchip_writes)),
            ("glb_reads", jnum_i(self.glb_reads)),
            ("glb_writes", jnum_i(self.glb_writes)),
            ("noc_hop_words", Json::Num(self.noc_hop_words)),
            (
                "per_tensor_offchip",
                jarr(self.per_tensor_offchip.iter().map(|&v| jnum_i(v)).collect()),
            ),
            ("occupancy_peak", jnum_i(self.occupancy_peak)),
            (
                "per_tensor_occupancy",
                jarr(self
                    .per_tensor_occupancy
                    .iter()
                    .map(|&v| jnum_i(v))
                    .collect()),
            ),
            ("capacity_ok", Json::Bool(self.capacity_ok)),
            ("total_ops", jnum_i(self.total_ops)),
            ("recompute_ops", jnum_i(self.recompute_ops)),
            (
                "per_tensor_recompute",
                jarr(self
                    .per_tensor_recompute
                    .iter()
                    .map(|&v| jnum_i(v))
                    .collect()),
            ),
            ("iterations", jnum_i(self.iterations)),
            (
                "path",
                jobj(vec![
                    ("symbolic", Json::Bool(self.path.symbolic)),
                    ("proven_jumps", jnum_i(self.path.proven_jumps)),
                    ("certified_jumps", jnum_i(self.path.certified_jumps)),
                    ("walked_iterations", jnum_i(self.path.walked_iterations)),
                    (
                        "multibox_proven_jumps",
                        jnum_i(self.path.multibox_proven_jumps),
                    ),
                    (
                        "multibox_certified_jumps",
                        jnum_i(self.path.multibox_certified_jumps),
                    ),
                    ("peak_union_width", jnum_i(self.path.peak_union_width)),
                    (
                        "level_union_widths",
                        jarr(self
                            .path
                            .level_union_widths
                            .iter()
                            .map(|&v| jnum_i(v))
                            .collect()),
                    ),
                    ("sym_refused", Json::Bool(self.path.sym_refused)),
                ]),
            ),
        ])
    }

    /// Parse from the JSON wire form; errors carry the offending JSON path.
    pub fn from_json(j: &Json) -> Result<Metrics, String> {
        let ctx = "metrics";
        let i64_or = |key: &str| -> Result<i64, String> {
            match j.get(key) {
                Some(v) => v
                    .as_i64()
                    .ok_or_else(|| format!("{ctx}: {key} must be a number")),
                None => Ok(0),
            }
        };
        let vec_or = |key: &str| -> Result<Vec<i64>, String> {
            match j.get(key) {
                Some(v) => i64_vec(v, ctx),
                None => Ok(vec![]),
            }
        };
        Ok(Metrics {
            latency_cycles: i64_or("latency_cycles")?,
            compute_cycles: i64_or("compute_cycles")?,
            memory_cycles: i64_or("memory_cycles")?,
            sequential_compute_cycles: i64_or("sequential_compute_cycles")?,
            energy: match j.get("energy") {
                Some(v) => EnergyBreakdown::from_json(v)?,
                None => EnergyBreakdown::default(),
            },
            offchip_reads: i64_or("offchip_reads")?,
            offchip_writes: i64_or("offchip_writes")?,
            glb_reads: i64_or("glb_reads")?,
            glb_writes: i64_or("glb_writes")?,
            noc_hop_words: match j.get("noc_hop_words") {
                Some(v) => v
                    .as_f64()
                    .ok_or_else(|| format!("{ctx}: noc_hop_words must be a number"))?,
                None => 0.0,
            },
            per_tensor_offchip: vec_or("per_tensor_offchip")?,
            occupancy_peak: i64_or("occupancy_peak")?,
            per_tensor_occupancy: vec_or("per_tensor_occupancy")?,
            capacity_ok: match j.get("capacity_ok") {
                Some(v) => v
                    .as_bool()
                    .ok_or_else(|| format!("{ctx}: capacity_ok must be a bool"))?,
                None => true,
            },
            total_ops: i64_or("total_ops")?,
            recompute_ops: i64_or("recompute_ops")?,
            per_tensor_recompute: vec_or("per_tensor_recompute")?,
            iterations: i64_or("iterations")?,
            // Older documents predate path attribution; default to all-off.
            path: match j.get("path") {
                Some(p) => {
                    let pctx = "metrics.path";
                    let pi64 = |key: &str| -> Result<i64, String> {
                        match p.get(key) {
                            Some(v) => v
                                .as_i64()
                                .ok_or_else(|| format!("{pctx}: {key} must be a number")),
                            None => Ok(0),
                        }
                    };
                    PathCounts {
                        symbolic: match p.get("symbolic") {
                            Some(v) => v
                                .as_bool()
                                .ok_or_else(|| format!("{pctx}: symbolic must be a bool"))?,
                            None => false,
                        },
                        proven_jumps: pi64("proven_jumps")?,
                        certified_jumps: pi64("certified_jumps")?,
                        walked_iterations: pi64("walked_iterations")?,
                        // Documents from before the multibox calculus lack
                        // these; default to the single-box all-off shape.
                        multibox_proven_jumps: pi64("multibox_proven_jumps")?,
                        multibox_certified_jumps: pi64("multibox_certified_jumps")?,
                        peak_union_width: pi64("peak_union_width")?,
                        level_union_widths: match p.get("level_union_widths") {
                            Some(v) => i64_vec(v, pctx)?,
                            None => vec![],
                        },
                        sym_refused: match p.get("sym_refused") {
                            Some(v) => v.as_bool().ok_or_else(|| {
                                format!("{pctx}: sym_refused must be a bool")
                            })?,
                            None => false,
                        },
                    }
                }
                None => PathCounts::default(),
            },
        })
    }
}

// ----------------------------------------------------------- CLI configs --

/// A complete `looptree analyze` request: workload + architecture + one
/// mapping. The `--json` output of `analyze` is itself a valid document.
#[derive(Debug, Clone)]
pub struct AnalyzeConfig {
    /// The fusion set to evaluate.
    pub workload: FusionSet,
    /// The target architecture.
    pub arch: Arch,
    /// The single mapping to analyze.
    pub mapping: InterLayerMapping,
}

impl AnalyzeConfig {
    /// Serialize to the JSON wire form.
    pub fn to_json(&self) -> Json {
        jobj(vec![
            ("workload", self.workload.to_json()),
            ("arch", self.arch.to_json()),
            ("mapping", self.mapping.to_json()),
        ])
    }

    /// Parse a config document. `arch` defaults to `generic:256`; `mapping`
    /// defaults to the untiled sequential mapping.
    pub fn from_json(j: &Json) -> Result<AnalyzeConfig, String> {
        let ctx = "analyze config";
        let workload = workload_from_json_at(field(j, "workload", ctx)?, "workload")?;
        let arch = match j.get("arch") {
            Some(v) => arch_from_json_at(v, "arch")?,
            None => Arch::generic(256),
        };
        let mapping = match j.get("mapping") {
            Some(v) => InterLayerMapping::from_json_at(v, "mapping")?,
            None => InterLayerMapping::untiled(Parallelism::Sequential),
        };
        mapping.validate(&workload).map_err(|e| format!("mapping: {e}"))?;
        Ok(AnalyzeConfig { workload, arch, mapping })
    }

    /// The full `looptree analyze --json` result document: this config
    /// verbatim plus a `metrics` section. The CLI and the serve dispatcher
    /// both build their responses through this method, so a served analyze
    /// result is byte-identical to a one-shot run by construction.
    pub fn result_doc(&self, metrics: &Metrics) -> Json {
        let mut doc = self.to_json();
        if let Json::Obj(o) = &mut doc {
            o.insert("metrics".into(), metrics.to_json());
        }
        doc
    }
}

/// A complete `looptree search` request: workload + architecture + search
/// spec. The `--json` output of `search` embeds this config verbatim, so a
/// result document can be re-fed as `--config` and reproduces the run.
#[derive(Debug, Clone)]
pub struct SearchConfig {
    /// The fusion set whose map space is searched.
    pub workload: FusionSet,
    /// The target architecture.
    pub arch: Arch,
    /// Algorithm, objective, budgets, and mapspace constraints.
    pub search: SearchSpec,
}

impl SearchConfig {
    /// Serialize to the JSON wire form.
    pub fn to_json(&self) -> Json {
        jobj(vec![
            ("workload", self.workload.to_json()),
            ("arch", self.arch.to_json()),
            ("search", self.search.to_json()),
        ])
    }

    /// Parse a config document. `arch` defaults to `generic:256`; `search`
    /// defaults to [`SearchSpec::default`]. Extra fields (e.g. a `result`
    /// section from a previous run's `--json` output) are ignored.
    pub fn from_json(j: &Json) -> Result<SearchConfig, String> {
        let ctx = "search config";
        let workload = workload_from_json_at(field(j, "workload", ctx)?, "workload")?;
        let arch = match j.get("arch") {
            Some(v) => arch_from_json_at(v, "arch")?,
            None => Arch::generic(256),
        };
        let search = match j.get("search") {
            Some(v) => SearchSpec::from_json_at(v, "search")?,
            None => SearchSpec::default(),
        };
        Ok(SearchConfig { workload, arch, search })
    }

    /// The full `looptree search --json` result document: this config
    /// verbatim plus a `result` section (best mapping/schedule/score/metrics
    /// and the evaluation accounting). The counters are passed as plain
    /// numbers — not the whole [`crate::search::SearchResult`] — so a cached
    /// summary can rebuild the exact document without holding every
    /// evaluated mapping. The CLI and the serve dispatcher both build their
    /// responses through this method, so a served search result is
    /// byte-identical to a one-shot run by construction.
    pub fn result_doc(
        &self,
        best: &Scored,
        evaluated: usize,
        pruned: usize,
        symbolic_evals: usize,
    ) -> Json {
        let best = jobj(vec![
            ("mapping", best.mapping.to_json()),
            ("schedule", jstr(&best.mapping.schedule_string(&self.workload))),
            ("score", Json::Num(best.score)),
            ("metrics", best.metrics.to_json()),
        ]);
        let result = jobj(vec![
            ("best", best),
            ("evaluated", jnum_u(evaluated)),
            ("pruned", jnum_u(pruned)),
            ("symbolic_evals", jnum_u(symbolic_evals)),
        ]);
        let mut doc = self.to_json();
        if let Json::Obj(o) = &mut doc {
            o.insert("result".into(), result);
        }
        doc
    }
}

/// A complete `looptree network` request: a whole-DNN graph + architecture
/// + segment-search spec, optionally with a fixed cut set to score instead
/// of running the DP. The `--json` output of `network` embeds this config
/// verbatim, so a result document re-feeds as `--config` and reproduces the
/// run.
#[derive(Debug, Clone)]
pub struct NetworkConfig {
    /// The whole-DNN graph to partition into fused segments.
    pub network: Network,
    /// The target architecture.
    pub arch: Arch,
    /// The per-segment search spec and partitioner options.
    pub segment_search: NetworkSearchSpec,
    /// `Some` = score this exact partition; `None` = DP over all cut sets.
    pub cuts: Option<Vec<usize>>,
    /// `true` = emit the multi-objective Pareto front over cut sets
    /// ([`network::search_network_pareto`]) instead of the scalar optimum.
    pub pareto: bool,
}

impl NetworkConfig {
    /// Serialize to the JSON wire form.
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("network", self.network.to_json()),
            ("arch", self.arch.to_json()),
            ("segment_search", self.segment_search.to_json()),
        ];
        if let Some(cuts) = &self.cuts {
            pairs.push(("cuts", jarr(cuts.iter().map(|&c| jnum_u(c)).collect())));
        }
        if self.pareto {
            pairs.push(("pareto", Json::Bool(true)));
        }
        jobj(pairs)
    }

    /// Parse a config document. `arch` defaults to `generic:256`;
    /// `segment_search` defaults to [`NetworkSearchSpec::default`]. Extra
    /// fields (e.g. a `result` section from a previous run's `--json`
    /// output) are ignored.
    pub fn from_json(j: &Json) -> Result<NetworkConfig, String> {
        let ctx = "network config";
        let network = network_from_json_at(field(j, "network", ctx)?, "network")?;
        let arch = match j.get("arch") {
            Some(v) => arch_from_json_at(v, "arch")?,
            None => Arch::generic(256),
        };
        let segment_search = match j.get("segment_search") {
            Some(v) => NetworkSearchSpec::from_json_at(v, "segment_search")?,
            None => NetworkSearchSpec::default(),
        };
        let cuts = match j.get("cuts") {
            Some(v) => {
                let raw = i64_vec(v, "cuts")?;
                let mut cuts = Vec::with_capacity(raw.len());
                for c in raw {
                    if c < 0 {
                        return Err(format!("{ctx}: cuts must be non-negative"));
                    }
                    cuts.push(c as usize);
                }
                Some(cuts)
            }
            None => None,
        };
        let pareto = match j.get("pareto") {
            Some(v) => v
                .as_bool()
                .ok_or_else(|| format!("{ctx}: pareto must be a bool"))?,
            None => false,
        };
        if pareto && cuts.is_some() {
            return Err(format!(
                "{ctx}: 'pareto' searches the front over cut sets; it cannot be combined with \
                 a fixed 'cuts' list"
            ));
        }
        Ok(NetworkConfig { network, arch, segment_search, cuts, pareto })
    }

    /// The full `looptree network --json` result document (scalar DP or
    /// fixed-cuts evaluation): this config verbatim plus a `result` section
    /// with the cut set, per-segment choices, totals, and search
    /// accounting. The CLI and the serve dispatcher both build their
    /// responses through this method, so a served network result is
    /// byte-identical to a one-shot run by construction.
    pub fn result_doc(&self, r: &NetworkSearchResult) -> Json {
        let segments = Json::Arr(
            r.segments
                .iter()
                .map(|s| {
                    jobj(vec![
                        ("range", jarr(vec![jnum_u(s.lo), jnum_u(s.hi)])),
                        ("nodes", jarr(s.nodes.iter().map(|&i| jnum_u(i)).collect())),
                        ("span", jstr(&s.span)),
                        ("mapping", s.best.mapping.to_json()),
                        ("score", Json::Num(s.best.score)),
                        ("metrics", s.best.metrics.to_json()),
                    ])
                })
                .collect(),
        );
        let result = jobj(vec![
            ("cuts", jarr(r.cuts.iter().map(|&c| jnum_u(c)).collect())),
            ("segments", segments),
            ("total_score", Json::Num(r.total_score)),
            ("total_latency_cycles", jnum_i(r.total_latency())),
            ("total_energy_pj", Json::Num(r.total_energy_pj())),
            ("total_offchip_elems", jnum_i(r.total_offchip())),
            ("all_fit", Json::Bool(r.all_fit())),
            ("distinct_searched", jnum_u(r.distinct_searched)),
            ("candidate_segments", jnum_u(r.candidate_segments)),
            ("candidates_pruned", jnum_u(r.candidates_pruned)),
        ]);
        let mut doc = self.to_json();
        if let Json::Obj(o) = &mut doc {
            o.insert("result".into(), result);
        }
        doc
    }

    /// The full `looptree network --pareto --json` result document: this
    /// config verbatim plus [`NetworkParetoResult::to_json`] as the `result`
    /// section. Shared by the CLI and the serve dispatcher (see
    /// [`NetworkConfig::result_doc`]).
    pub fn result_doc_pareto(&self, r: &NetworkParetoResult) -> Json {
        let mut doc = self.to_json();
        if let Json::Obj(o) = &mut doc {
            o.insert("result".into(), r.to_json());
        }
        doc
    }
}

// ------------------------------------------------- network Pareto fronts --

impl NetworkParetoResult {
    /// The result section of a `looptree network --pareto --json` document:
    /// the objective axes, the beam cap, the search accounting, and one
    /// entry per front point — cost vector (axis order = `objectives`),
    /// cuts, per-segment mappings/metrics, and the standard totals. The
    /// surrounding document embeds the originating [`NetworkConfig`], so it
    /// re-feeds as `--config` and reproduces the same front.
    pub fn to_json(&self) -> Json {
        let points = Json::Arr(
            self.points
                .iter()
                .map(|p| {
                    let segments = Json::Arr(
                        p.segments
                            .iter()
                            .map(|s| {
                                jobj(vec![
                                    (
                                        "nodes",
                                        jarr(s.nodes.iter().map(|&i| jnum_u(i)).collect()),
                                    ),
                                    ("span", jstr(&s.span)),
                                    ("mapping", s.best.mapping.to_json()),
                                    ("score", Json::Num(s.best.score)),
                                    ("metrics", s.best.metrics.to_json()),
                                ])
                            })
                            .collect(),
                    );
                    jobj(vec![
                        (
                            "costs",
                            jarr(p.costs.iter().map(|&c| Json::Num(c)).collect()),
                        ),
                        ("cuts", jarr(p.cuts.iter().map(|&c| jnum_u(c)).collect())),
                        ("total_latency_cycles", jnum_i(p.total_latency())),
                        ("total_energy_pj", Json::Num(p.total_energy_pj())),
                        ("total_offchip_elems", jnum_i(p.total_offchip())),
                        ("all_fit", Json::Bool(p.all_fit())),
                        ("segments", segments),
                    ])
                })
                .collect(),
        );
        jobj(vec![
            (
                "objectives",
                jarr(self.objectives.iter().map(|o| o.to_json()).collect()),
            ),
            ("max_front_per_state", jnum_u(self.max_front_per_state)),
            ("front_points", jnum_u(self.points.len())),
            ("points", points),
            ("distinct_searched", jnum_u(self.distinct_searched)),
            ("candidate_segments", jnum_u(self.candidate_segments)),
            ("segment_front_points", jnum_u(self.segment_front_points)),
            ("candidates_pruned", jnum_u(self.candidates_pruned)),
        ])
    }
}

// ------------------------------------------------- serve wire envelopes --

/// The request kinds `looptree serve` dispatches — one per result-emitting
/// CLI subcommand. See `docs/PROTOCOL.md` for the wire format.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RequestKind {
    /// Evaluate one mapping ([`AnalyzeConfig`]).
    Analyze,
    /// Run a mapspace search ([`SearchConfig`]).
    Search,
    /// Partition a whole network — scalar DP, fixed cuts, or Pareto front,
    /// chosen by the config's own `cuts`/`pareto` fields ([`NetworkConfig`]).
    Network,
    /// Lint a config document ([`crate::analysis::lint_document`]).
    Lint,
}

impl RequestKind {
    /// Stable wire name (matches the CLI subcommand).
    pub fn name(&self) -> &'static str {
        match self {
            RequestKind::Analyze => "analyze",
            RequestKind::Search => "search",
            RequestKind::Network => "network",
            RequestKind::Lint => "lint",
        }
    }

    /// Inverse of [`RequestKind::name`].
    pub fn parse(s: &str) -> Result<RequestKind, String> {
        match s {
            "analyze" => Ok(RequestKind::Analyze),
            "search" => Ok(RequestKind::Search),
            "network" => Ok(RequestKind::Network),
            "lint" => Ok(RequestKind::Lint),
            other => Err(format!(
                "unknown request kind {other} (expected analyze|search|network|lint)"
            )),
        }
    }
}

/// A parsed serve request envelope: `{"kind": "...", "config": {...}}`,
/// optionally with a caller-chosen `id` (echoed verbatim in the response)
/// and `warm_start` (seed stochastic searches from previously cached best
/// mappings; see [`crate::search::run_warm`]).
#[derive(Debug, Clone)]
pub struct ServeRequest {
    /// Echoed verbatim in the response envelope; any JSON value.
    pub id: Option<Json>,
    /// Which dispatcher handles `config`.
    pub kind: RequestKind,
    /// The inner config document, in the exact shape the matching CLI
    /// subcommand accepts as `--config`.
    pub config: Json,
    /// Opt into warm-started stochastic search (annealing/genetic only).
    /// Warm-started responses are *not* covered by the byte-identity
    /// guarantee — that is the point of warm-starting.
    pub warm_start: bool,
}

impl ServeRequest {
    /// Parse a request envelope. `config` must be a JSON object; unknown
    /// envelope fields are ignored (forward compatibility).
    pub fn from_json(j: &Json) -> Result<ServeRequest, String> {
        let ctx = "serve request";
        let kind = RequestKind::parse(str_field(j, "kind", ctx)?)?;
        let config = field(j, "config", ctx)?;
        if config.as_obj().is_none() {
            return Err(format!("{ctx}: field 'config' must be an object"));
        }
        let warm_start = match j.get("warm_start") {
            Some(v) => v
                .as_bool()
                .ok_or_else(|| format!("{ctx}: warm_start must be a bool"))?,
            None => false,
        };
        Ok(ServeRequest { id: j.get("id").cloned(), kind, config: config.clone(), warm_start })
    }
}

/// Per-request cross-request-cache accounting, carried in the `serve`
/// section of every successful response envelope. All counters are
/// deterministic for a given request sequence (cache traffic happens in
/// serial pre-passes), so CI can pin them.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ServeStats {
    /// Distinct segment signatures (or whole-search summaries) this request
    /// reused from the cross-request cache.
    pub cache_hits: u64,
    /// Distinct signatures this request had to search and then stored.
    pub cache_misses: u64,
    /// 1 when a stochastic search was warm-started from cached mappings.
    pub warm_starts: u64,
}

impl ServeStats {
    /// Serialize to the `serve` section of a response envelope.
    pub fn to_json(&self) -> Json {
        jobj(vec![
            ("cache_hits", jnum_u(self.cache_hits as usize)),
            ("cache_misses", jnum_u(self.cache_misses as usize)),
            ("warm_starts", jnum_u(self.warm_starts as usize)),
        ])
    }
}

/// Build a success response envelope: `{"id"?, "kind", "ok": true,
/// "result": <the exact one-shot CLI --json document>, "serve": {...}}`.
pub fn serve_ok(id: Option<Json>, kind: RequestKind, result: Json, stats: &ServeStats) -> Json {
    let mut pairs = vec![
        ("kind", jstr(kind.name())),
        ("ok", Json::Bool(true)),
        ("result", result),
        ("serve", stats.to_json()),
    ];
    if let Some(id) = id {
        pairs.push(("id", id));
    }
    jobj(pairs)
}

/// Build an error response envelope: `{"id"?, "ok": false, "error": msg}`.
pub fn serve_error(id: Option<Json>, message: &str) -> Json {
    let mut pairs = vec![("ok", Json::Bool(false)), ("error", jstr(message))];
    if let Some(id) = id {
        pairs.push(("id", id));
    }
    jobj(pairs)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reser(j: &Json) -> Json {
        Json::parse(&j.to_string()).unwrap()
    }

    #[test]
    fn fusion_set_round_trips() {
        for fs in [
            workloads::conv_conv(14, 8),
            workloads::pwise_dwise_pwise(14, 8),
            workloads::fc_fc(32, 16),
            workloads::self_attention(2, 2, 16, 8),
        ] {
            let j = fs.to_json();
            let back = FusionSet::from_json(&reser(&j)).unwrap();
            assert_eq!(back.to_json().to_string(), j.to_string(), "{}", fs.name);
            assert!(back.validate().is_ok());
        }
    }

    #[test]
    fn arch_round_trips_including_infinite_bandwidth() {
        for arch in [
            Arch::generic(256),
            Arch::generic(1 << 20).unbounded_glb(),
            presets::depfin(),
            presets::flat(),
        ] {
            let j = arch.to_json();
            let back = Arch::from_json(&reser(&j)).unwrap();
            assert_eq!(back.to_json().to_string(), j.to_string(), "{}", arch.name);
            // The RF level's infinite bandwidth survives the null encoding.
            for (a, b) in arch.levels.iter().zip(&back.levels) {
                assert_eq!(
                    a.bandwidth_words_per_cycle.is_finite(),
                    b.bandwidth_words_per_cycle.is_finite()
                );
            }
        }
    }

    #[test]
    fn mapping_round_trips() {
        let fs = workloads::conv_conv(14, 8);
        let p2 = fs.last().rank_index("P2").unwrap();
        let q2 = fs.last().rank_index("Q2").unwrap();
        let m = InterLayerMapping::tiled(
            vec![
                Partition { dim: p2, tile: 4 },
                Partition { dim: q2, tile: 2 },
            ],
            Parallelism::Pipeline,
        )
        .with_retention(TensorId(0), 1)
        .with_retention(TensorId(2), 2);
        let back = InterLayerMapping::from_json(&reser(&m.to_json())).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn minimal_mapping_document_is_untiled() {
        let m = InterLayerMapping::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert_eq!(m, InterLayerMapping::untiled(Parallelism::Sequential));
    }

    #[test]
    fn search_spec_round_trips_and_defaults() {
        let spec = SearchSpec {
            algorithm: Algorithm::Genetic,
            objective: Objective::Capacity,
            seed: 99,
            population: 7,
            generations: 3,
            mapspace: MapSpaceConfig {
                schedules: vec![vec!["P2".into(), "Q2".into()]],
                tile_sizes: vec![2, 4],
                uniform_retention: true,
                ..Default::default()
            },
            ..Default::default()
        };
        let back = SearchSpec::from_json(&reser(&spec.to_json())).unwrap();
        assert_eq!(back, spec);
        // `{}` parses to the default spec.
        let d = SearchSpec::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert_eq!(d, SearchSpec::default());
    }

    #[test]
    fn workload_shorthand_accepted() {
        let fs = workload_from_json(&Json::Str("conv_conv:14x8".into())).unwrap();
        assert_eq!(fs.name, workloads::conv_conv(14, 8).name);
        assert!(workload_from_json(&Json::Str("bogus:1".into())).is_err());
    }

    #[test]
    fn arch_shorthand_accepted() {
        assert_eq!(arch_from_json(&Json::Str("generic:128".into())).unwrap().name, Arch::generic(128).name);
        assert_eq!(arch_from_json(&Json::Str("depfin".into())).unwrap().name, presets::depfin().name);
        assert!(arch_from_json(&Json::Str("nope".into())).is_err());
    }

    #[test]
    fn metrics_round_trip_via_evaluation() {
        let fs = workloads::conv_conv(14, 8);
        let arch = Arch::generic(256);
        let ev = crate::model::Evaluator::new(&fs, &arch).unwrap();
        let m = ev
            .evaluate(&InterLayerMapping::untiled(Parallelism::Sequential))
            .unwrap();
        let j = m.to_json();
        let back = Metrics::from_json(&reser(&j)).unwrap();
        assert_eq!(back.to_json().to_string(), j.to_string());
        assert_eq!(back.latency_cycles, m.latency_cycles);
        assert_eq!(back.energy.total_pj().to_bits(), m.energy.total_pj().to_bits());
    }

    #[test]
    fn path_counts_round_trip_and_old_documents_default() {
        // All multibox attribution fields survive the wire form.
        let m = Metrics {
            path: PathCounts {
                symbolic: true,
                proven_jumps: 3,
                certified_jumps: 1,
                walked_iterations: 9,
                multibox_proven_jumps: 2,
                multibox_certified_jumps: 1,
                peak_union_width: 2,
                level_union_widths: vec![1, 2],
                sym_refused: false,
            },
            ..Default::default()
        };
        let back = Metrics::from_json(&reser(&m.to_json())).unwrap();
        assert_eq!(back.path, m.path);

        // Documents written before the multibox calculus (path object with
        // only the original four keys) parse with the new fields defaulted.
        let old = Json::parse(
            r#"{"iterations": 4, "path": {"symbolic": true, "proven_jumps": 1,
                "certified_jumps": 0, "walked_iterations": 2}}"#,
        )
        .unwrap();
        let back = Metrics::from_json(&old).unwrap();
        assert!(back.path.symbolic);
        assert_eq!(back.path.proven_jumps, 1);
        assert_eq!(back.path.multibox_proven_jumps, 0);
        assert_eq!(back.path.multibox_certified_jumps, 0);
        assert_eq!(back.path.peak_union_width, 0);
        assert!(back.path.level_union_widths.is_empty());
        assert!(!back.path.sym_refused);
    }

    #[test]
    fn network_round_trips() {
        for net in [
            network::resnet18(),
            network::resnet18_chain(),
            network::mobilenet_v2(),
            network::vgg16(),
            network::bert_encoder(1, 2, 16, 8),
        ] {
            let j = net.to_json();
            let back = Network::from_json(&reser(&j)).unwrap();
            assert_eq!(back, net, "{}", net.name);
            assert!(back.validate().is_ok());
        }
    }

    #[test]
    fn network_shorthand_accepted() {
        assert_eq!(parse_network("resnet18").unwrap().name, "resnet18");
        assert_eq!(parse_network("resnet18").unwrap().num_layers(), 29);
        assert_eq!(parse_network("resnet18_chain").unwrap().num_layers(), 18);
        assert_eq!(parse_network("mobilenetv2").unwrap().num_layers(), 62);
        assert_eq!(parse_network("vgg16").unwrap().num_layers(), 18);
        assert_eq!(
            parse_network("bert:2,4,64,32").unwrap(),
            network::bert_encoder(2, 4, 64, 32)
        );
        assert!(parse_network("bert:1,2").is_err());
        assert!(parse_network("resnet50").is_err());
    }

    #[test]
    fn legacy_chain_network_schema_parses() {
        // PR 3 chain documents: "layers" without edge lists — every layer
        // implicitly consumes its predecessor.
        let doc = "{\"name\":\"tiny\",\"layers\":[\
            {\"name\":\"a\",\"input_shape\":[8,18,18],\
             \"op\":{\"op\":\"conv2d\",\"out_channels\":8,\"r\":3,\"s\":3,\"stride\":1}},\
            {\"name\":\"b\",\"input_shape\":[8,16,16],\
             \"op\":{\"op\":\"conv2d\",\"out_channels\":8,\"r\":3,\"s\":3,\"stride\":1}}]}";
        let net = Network::from_json(&Json::parse(doc).unwrap()).unwrap();
        assert!(net.is_chain());
        assert_eq!(net.layers[0].inputs, Vec::<usize>::new());
        assert_eq!(net.layers[1].inputs, vec![0]);
        // Round trip re-emits the DAG schema ("nodes" with explicit edges).
        let j = net.to_json();
        assert!(j.get("nodes").is_some());
        let back = Network::from_json(&reser(&j)).unwrap();
        assert_eq!(back, net);
    }

    #[test]
    fn dag_ops_round_trip() {
        // A residual block with an explicit pad: conv -> pad -> conv -> add.
        let mut net = Network { name: "res".into(), layers: vec![] };
        let a = net.push(
            "conv_a",
            &[8, 18, 18],
            crate::network::LayerOp::Conv2d { out_channels: 8, r: 3, s: 3, stride: 1 },
        );
        net.push("pad", &[8, 16, 16], crate::network::LayerOp::Pad { h: 1, w: 1 });
        let b = net.push(
            "conv_b",
            &[8, 18, 18],
            crate::network::LayerOp::Conv2d { out_channels: 8, r: 3, s: 3, stride: 1 },
        );
        net.push_from("add", &[8, 16, 16], crate::network::LayerOp::Add, vec![b, a]);
        net.validate().unwrap();
        let back = Network::from_json(&reser(&net.to_json())).unwrap();
        assert_eq!(back, net);
        // Concat parses too.
        let j = Json::parse("{\"op\":\"concat\"}").unwrap();
        assert_eq!(LayerOp::from_json(&j).unwrap(), crate::network::LayerOp::Concat);
    }

    #[test]
    fn network_config_round_trips_and_defaults() {
        let cfg = NetworkConfig {
            network: network::bert_encoder(1, 2, 16, 8),
            arch: Arch::generic(64),
            segment_search: NetworkSearchSpec {
                max_segment_layers: 2,
                objectives: vec![Objective::Latency, Objective::Offchip],
                max_front_per_state: 6,
                ..Default::default()
            },
            cuts: Some(vec![2]),
            pareto: false,
        };
        let back = NetworkConfig::from_json(&reser(&cfg.to_json())).unwrap();
        assert_eq!(back.network, cfg.network);
        assert_eq!(back.segment_search, cfg.segment_search);
        assert_eq!(back.cuts, cfg.cuts);
        assert!(!back.pareto);
        assert_eq!(back.arch.to_json().to_string(), cfg.arch.to_json().to_string());
        // The pareto flag survives the round trip (and excludes fixed cuts).
        let pareto_cfg = NetworkConfig { cuts: None, pareto: true, ..cfg.clone() };
        let back = NetworkConfig::from_json(&reser(&pareto_cfg.to_json())).unwrap();
        assert!(back.pareto);
        let clash = NetworkConfig { pareto: true, ..cfg.clone() }; // cuts still set
        assert!(NetworkConfig::from_json(&reser(&clash.to_json())).is_err());
        // Minimal document: shorthand network, everything else defaulted.
        let j = Json::parse("{\"network\": \"bert:1,2,16,8\"}").unwrap();
        let cfg = NetworkConfig::from_json(&j).unwrap();
        assert_eq!(cfg.segment_search, NetworkSearchSpec::default());
        assert!(cfg.cuts.is_none());
        assert!(!cfg.pareto);
        // Pre-Pareto segment_search documents parse to the default axes.
        let j = Json::parse(
            "{\"network\": \"bert:1,2,16,8\", \"segment_search\": {\"max_segment_layers\": 2}}",
        )
        .unwrap();
        let cfg = NetworkConfig::from_json(&j).unwrap();
        assert_eq!(cfg.segment_search.objectives, NetworkSearchSpec::default().objectives);
        assert_eq!(cfg.segment_search.max_front_per_state, 0);
        // An empty objectives list is rejected on parse.
        let j = Json::parse(
            "{\"network\": \"bert:1,2,16,8\", \"segment_search\": {\"objectives\": []}}",
        )
        .unwrap();
        assert!(NetworkConfig::from_json(&j).is_err());
        // A structurally broken network document is rejected on parse.
        let j = Json::parse(
            "{\"network\": {\"name\": \"x\", \"layers\": []}}",
        )
        .unwrap();
        assert!(NetworkConfig::from_json(&j).is_err());
    }

    #[test]
    fn invalid_documents_rejected() {
        assert!(FusionSet::from_json(&Json::parse("{}").unwrap()).is_err());
        assert!(Arch::from_json(&Json::parse("{\"name\":\"x\"}").unwrap()).is_err());
        // Structurally invalid fusion set: validation runs on parse.
        let fs = workloads::conv_conv(14, 8);
        let mut j = fs.to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("einsums".into(), Json::Arr(vec![]));
        }
        assert!(FusionSet::from_json(&j).is_err());
    }
}
