use super::*;
use crate::arch::Arch;
use crate::coordinator::Coordinator;
use crate::einsum::workloads;
use crate::mapspace::MapSpaceConfig;
use crate::model::Evaluator;
use crate::search::{self, Algorithm, SearchSpec};

/// A small chain of `n` identical 3×3 convs on an 8-channel 18×18 fmap
/// (declared with the pad-1 halo, like every conv preset).
fn tiny_conv_chain(n: usize) -> Network {
    Network {
        name: format!("tiny{n}"),
        layers: (0..n)
            .map(|i| LayerSpec {
                name: format!("conv{i}"),
                input_shape: vec![8, 18, 18],
                op: LayerOp::Conv2d { out_channels: 8, r: 3, s: 3, stride: 1 },
            })
            .collect(),
    }
}

/// A cheap spec for the tiny chains: exhaustive over a pruned mapspace.
fn tiny_spec(max_seg: usize) -> NetworkSearchSpec {
    NetworkSearchSpec {
        max_segment_layers: max_seg,
        search: SearchSpec {
            mapspace: MapSpaceConfig {
                tile_sizes: vec![2, 4],
                uniform_retention: true,
                ..Default::default()
            },
            ..Default::default()
        },
    }
}

#[test]
fn presets_validate() {
    for (net, layers) in [
        (resnet18(), 18),
        (mobilenet_v2(), 52),
        (vgg16(), 18),
        (bert_encoder(1, 2, 32, 16), 4),
    ] {
        assert_eq!(net.num_layers(), layers, "{}", net.name);
        net.validate().unwrap_or_else(|e| panic!("{}: {e}", net.name));
        // Every single layer must be materializable on its own.
        for lo in 0..net.num_layers() {
            net.segment_fusion_set(lo, lo + 1)
                .unwrap_or_else(|e| panic!("{}[{lo}]: {e}", net.name));
        }
    }
}

#[test]
fn resnet18_shapes_propagate_as_published() {
    let net = resnet18();
    assert_eq!(net.propagate(0, 1).unwrap(), vec![64, 112, 112]); // stem
    assert_eq!(net.propagate(1, 2).unwrap(), vec![64, 56, 56]); // pool
    assert_eq!(net.propagate(6, 7).unwrap(), vec![128, 28, 28]); // conv3 downsample
    assert_eq!(net.propagate(10, 11).unwrap(), vec![256, 14, 14]); // conv4 downsample
    assert_eq!(net.propagate(14, 15).unwrap(), vec![512, 7, 7]); // conv5 downsample
}

#[test]
fn repeated_blocks_share_signatures() {
    let net = resnet18();
    // The two stage-2 basic blocks are identical segments...
    assert_eq!(net.segment_signature(2, 4), net.segment_signature(4, 6));
    // ...as are their constituent single layers.
    assert_eq!(net.segment_signature(2, 3), net.segment_signature(5, 6));
    // A downsampling block is not interchangeable with an identity block.
    assert_ne!(net.segment_signature(6, 8), net.segment_signature(8, 10));
}

#[test]
fn reshape_boundary_is_a_mandatory_cut() {
    let net = bert_encoder(1, 2, 8, 4);
    assert!(net.segment_buildable(0, 2)); // scores+attend fuse
    assert!(net.segment_buildable(2, 4)); // ffn1+ffn2 fuse
    assert!(!net.segment_buildable(1, 3)); // attention -> FFN reshape
    assert!(!net.segment_buildable(0, 4));

    let arch = Arch::generic(256);
    let pool = Coordinator::new(2);
    let res = search_network(&net, &arch, &tiny_spec(4), &pool).unwrap();
    assert!(
        res.cuts.contains(&2),
        "partitioner must cut at the reshape boundary; got cuts {:?}",
        res.cuts
    );
    // Missing the mandatory cut is a hard error when the cuts are forced.
    assert!(evaluate_partition(&net, &arch, &tiny_spec(4), &[], &pool).is_err());
}

// The acceptance pin: DP over the ResNet-18 chain with cuts forced to the
// existing per-block boundaries reproduces the per-block `Evaluator` search
// results bit for bit (same best mapping, same metrics, same score bits).
#[test]
fn resnet_block_cuts_bit_match_per_block_search() {
    let net = resnet18();
    let arch = Arch::generic(128);
    let pool = Coordinator::new(2);
    let spec = NetworkSearchSpec {
        max_segment_layers: 2,
        search: SearchSpec {
            mapspace: MapSpaceConfig {
                schedules: vec![vec!["P2".into()], vec!["C2".into(), "P2".into()]],
                tile_sizes: vec![4, 14],
                ..Default::default()
            },
            ..Default::default()
        },
    };
    // Cut at every block boundary: stem | pool | 8 two-conv blocks.
    let cuts = [1, 2, 4, 6, 8, 10, 12, 14, 16];
    let res = evaluate_partition(&net, &arch, &spec, &cuts, &pool).unwrap();
    assert_eq!(res.segments.len(), 10);
    assert_eq!(res.cuts, cuts.to_vec());
    // Identical stage-2 blocks were searched once.
    assert!(res.distinct_searched < res.segments.len());

    // The second block of each stage is exactly `workloads::resnet18_block`:
    // (segment range, RESNET18_STAGES index).
    for (range, stage) in [((4, 6), 1), ((8, 10), 2), ((12, 14), 3), ((16, 18), 4)] {
        let seg = res
            .segments
            .iter()
            .find(|s| (s.lo, s.hi) == range)
            .unwrap_or_else(|| panic!("missing segment {range:?}"));
        let block = workloads::resnet18_block(stage);
        let seg_fs = net.segment_fusion_set(range.0, range.1).unwrap();
        // The materialized segment builds the same Einsums...
        assert_eq!(seg_fs.einsums.len(), block.einsums.len());
        for (a, b) in seg_fs.einsums.iter().zip(&block.einsums) {
            assert_eq!(a.rank_sizes, b.rank_sizes);
            assert_eq!(a.rank_names, b.rank_names);
        }
        // ...and the per-block search returns the identical result.
        let ev = Evaluator::new(&block, &arch).unwrap();
        let direct = search::run(&ev, &spec.search, &Coordinator::new(1)).unwrap().best;
        assert_eq!(seg.best.mapping, direct.mapping, "stage {stage} mapping");
        assert_eq!(seg.best.score.to_bits(), direct.score.to_bits(), "stage {stage} score");
        let (a, b) = (&seg.best.metrics, &direct.metrics);
        assert_eq!(a.latency_cycles, b.latency_cycles);
        assert_eq!(a.offchip_reads, b.offchip_reads);
        assert_eq!(a.offchip_writes, b.offchip_writes);
        assert_eq!(a.occupancy_peak, b.occupancy_peak);
        assert_eq!(a.total_ops, b.total_ops);
        assert_eq!(a.recompute_ops, b.recompute_ops);
        assert_eq!(a.energy.total_pj().to_bits(), b.energy.total_pj().to_bits());
    }
}

#[test]
fn dp_matches_bruteforce_on_small_chain() {
    // Shrinking chain: four convs with exactly chained (halo-free) shapes,
    // so every segment has a distinct signature.
    let mut w = 18i64;
    let layers = (0..4)
        .map(|i| {
            let l = LayerSpec {
                name: format!("conv{i}"),
                input_shape: vec![8, w, w],
                op: LayerOp::Conv2d { out_channels: 8, r: 3, s: 3, stride: 1 },
            };
            w -= 2;
            l
        })
        .collect();
    let net = Network { name: "chain4".into(), layers };
    net.validate().unwrap();

    let arch = Arch::generic(16);
    let pool = Coordinator::new(2);
    let spec = tiny_spec(3);
    let dp = search_network(&net, &arch, &spec, &pool).unwrap();

    // Brute force every cut subset respecting the segment-length cap.
    let mut best_total = f64::INFINITY;
    for mask in 0u32..8 {
        let cuts: Vec<usize> = (1..4).filter(|c| mask & (1 << (c - 1)) != 0).collect();
        let mut bounds = vec![0];
        bounds.extend(&cuts);
        bounds.push(4);
        if bounds.windows(2).any(|w| w[1] - w[0] > spec.max_segment_layers) {
            continue;
        }
        let res = evaluate_partition(&net, &arch, &spec, &cuts, &pool).unwrap();
        best_total = best_total.min(res.total_score);
    }
    assert_eq!(
        dp.total_score.to_bits(),
        best_total.to_bits(),
        "DP total {} != brute-force optimum {best_total}",
        dp.total_score
    );
    // The result's own accounting is consistent.
    let seg_sum: f64 = dp.segments.iter().map(|s| s.best.score).sum();
    assert_eq!(dp.total_score.to_bits(), seg_sum.to_bits());
}

#[test]
fn network_search_deterministic_across_worker_counts() {
    let net = tiny_conv_chain(5);
    let arch = Arch::generic(32);
    let spec = tiny_spec(2);
    let a = search_network(&net, &arch, &spec, &Coordinator::new(1)).unwrap();
    let b = search_network(&net, &arch, &spec, &Coordinator::new(4)).unwrap();
    assert_eq!(a.cuts, b.cuts);
    assert_eq!(a.total_score.to_bits(), b.total_score.to_bits());
    assert_eq!(a.segments.len(), b.segments.len());
    for (x, y) in a.segments.iter().zip(&b.segments) {
        assert_eq!(x.best.mapping, y.best.mapping);
        assert_eq!(x.best.score.to_bits(), y.best.score.to_bits());
    }
}

#[test]
fn identical_blocks_are_searched_once() {
    let net = tiny_conv_chain(6);
    let arch = Arch::generic(32);
    let res = search_network(&net, &arch, &tiny_spec(2), &Coordinator::new(2)).unwrap();
    // 6 single-layer + 5 two-layer candidates, but only two distinct shapes.
    assert_eq!(res.candidate_segments, 11);
    assert_eq!(res.distinct_searched, 2);
    // Equal-signature segments carry the identical memoized search result.
    for s in &res.segments {
        for t in &res.segments {
            if s.signature == t.signature {
                assert_eq!(s.best.mapping, t.best.mapping);
                assert_eq!(s.best.score.to_bits(), t.best.score.to_bits());
            }
        }
    }
}

#[test]
fn stochastic_segment_search_is_deterministic() {
    let net = tiny_conv_chain(4);
    let arch = Arch::generic(32);
    let spec = NetworkSearchSpec {
        max_segment_layers: 2,
        search: SearchSpec {
            algorithm: Algorithm::Annealing,
            iters: 25,
            seed: 11,
            ..Default::default()
        },
    };
    let a = search_network(&net, &arch, &spec, &Coordinator::new(1)).unwrap();
    let b = search_network(&net, &arch, &spec, &Coordinator::new(3)).unwrap();
    assert_eq!(a.cuts, b.cuts);
    assert_eq!(a.total_score.to_bits(), b.total_score.to_bits());
}

#[test]
fn evaluate_partition_rejects_bad_cuts() {
    let net = tiny_conv_chain(4);
    let arch = Arch::generic(32);
    let pool = Coordinator::new(1);
    let spec = tiny_spec(4);
    assert!(evaluate_partition(&net, &arch, &spec, &[0], &pool).is_err());
    assert!(evaluate_partition(&net, &arch, &spec, &[4], &pool).is_err());
    assert!(evaluate_partition(&net, &arch, &spec, &[2, 2], &pool).is_err());
    assert!(evaluate_partition(&net, &arch, &spec, &[3, 1], &pool).is_err());
    let ok = evaluate_partition(&net, &arch, &spec, &[1, 3], &pool).unwrap();
    assert_eq!(ok.cuts, vec![1, 3]);
    assert_eq!(ok.segments.len(), 3);
}

#[test]
fn invalid_networks_rejected() {
    // Channel mismatch across a boundary.
    let net = Network {
        name: "bad".into(),
        layers: vec![
            LayerSpec {
                name: "a".into(),
                input_shape: vec![8, 18, 18],
                op: LayerOp::Conv2d { out_channels: 8, r: 3, s: 3, stride: 1 },
            },
            LayerSpec {
                name: "b".into(),
                input_shape: vec![16, 18, 18],
                op: LayerOp::Conv2d { out_channels: 8, r: 3, s: 3, stride: 1 },
            },
        ],
    };
    assert!(net.validate().is_err());
    // Window larger than the fmap.
    let net = Network {
        name: "bad2".into(),
        layers: vec![LayerSpec {
            name: "a".into(),
            input_shape: vec![8, 2, 2],
            op: LayerOp::Conv2d { out_channels: 8, r: 3, s: 3, stride: 1 },
        }],
    };
    assert!(net.validate().is_err());
    // Empty network.
    assert!(Network { name: "empty".into(), layers: vec![] }.validate().is_err());
    // Non-positive op parameters must be rejected here (an error), not
    // deep inside the builder (a panic) — e.g. from hand-written JSON.
    let net = Network {
        name: "bad3".into(),
        layers: vec![LayerSpec {
            name: "a".into(),
            input_shape: vec![8, 18, 18],
            op: LayerOp::Conv2d { out_channels: 0, r: 3, s: 3, stride: 1 },
        }],
    };
    assert!(net.validate().is_err());
    assert!(!net.segment_buildable(0, 1));
    assert!(net.segment_fusion_set(0, 1).is_err());
}

#[test]
fn totals_are_consistent_with_segments() {
    let net = tiny_conv_chain(3);
    let arch = Arch::generic(32);
    let res = search_network(&net, &arch, &tiny_spec(2), &Coordinator::new(1)).unwrap();
    let lat: i64 = res.segments.iter().map(|s| s.best.metrics.latency_cycles).sum();
    assert_eq!(res.total_latency(), lat);
    let off: i64 = res
        .segments
        .iter()
        .map(|s| s.best.metrics.offchip_reads + s.best.metrics.offchip_writes)
        .sum();
    assert_eq!(res.total_offchip(), off);
    assert!(res.total_energy_pj() > 0.0);
}
