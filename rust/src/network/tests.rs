use super::partition::{chain_candidates, dag_candidates};
use super::*;
use crate::arch::Arch;
use crate::coordinator::Coordinator;
use crate::einsum::{workloads, TensorKind};
use crate::mapping::{InterLayerMapping, Parallelism, Partition};
use crate::mapspace::{pareto_front_k, MapSpaceConfig, ParetoPointK};
use crate::model::Evaluator;
use crate::search::{self, Algorithm, Objective, SearchSpec};
use crate::util::bench::check_network_bench_schema;
use crate::util::json::Json;
use std::collections::HashMap;

/// A small chain of `n` identical 3×3 convs on an 8-channel 18×18 fmap
/// (declared with the pad-1 halo, like every conv preset).
fn tiny_conv_chain(n: usize) -> Network {
    let mut net = Network { name: format!("tiny{n}"), layers: vec![] };
    for i in 0..n {
        net.push(
            &format!("conv{i}"),
            &[8, 18, 18],
            LayerOp::Conv2d { out_channels: 8, r: 3, s: 3, stride: 1 },
        );
    }
    net
}

/// A small residual graph: conv0 -> conv_a -> conv_b -> add(conv_b, conv0).
fn tiny_residual() -> Network {
    let conv = || LayerOp::Conv2d { out_channels: 8, r: 3, s: 3, stride: 1 };
    let mut net = Network { name: "tinyres".into(), layers: vec![] };
    let c0 = net.push("conv0", &[8, 18, 18], conv());
    net.push("conv_a", &[8, 18, 18], conv());
    let cb = net.push("conv_b", &[8, 18, 18], conv());
    net.push_from("add", &[8, 16, 16], LayerOp::Add, vec![cb, c0]);
    net
}

/// A cheap spec for the tiny graphs: exhaustive over a pruned mapspace.
fn tiny_spec(max_seg: usize) -> NetworkSearchSpec {
    NetworkSearchSpec {
        max_segment_layers: max_seg,
        search: SearchSpec {
            mapspace: MapSpaceConfig {
                tile_sizes: vec![2, 4],
                uniform_retention: true,
                ..Default::default()
            },
            ..Default::default()
        },
        ..Default::default()
    }
}

#[test]
fn presets_validate() {
    for (net, layers) in [
        (resnet18(), 29),
        (resnet18_chain(), 18),
        (mobilenet_v2(), 62),
        (vgg16(), 18),
        (bert_encoder(1, 2, 32, 16), 4),
    ] {
        assert_eq!(net.num_layers(), layers, "{}", net.name);
        net.validate().unwrap_or_else(|e| panic!("{}: {e}", net.name));
        // Every single (non-virtual) node must be materializable on its own.
        for lo in 0..net.num_layers() {
            if net.layers[lo].op.is_virtual() {
                continue;
            }
            net.segment_fusion_set_nodes(&[lo])
                .unwrap_or_else(|e| panic!("{}[{lo}]: {e}", net.name));
        }
    }
}

#[test]
fn preset_chain_flags() {
    assert!(!resnet18().is_chain());
    assert!(!mobilenet_v2().is_chain());
    assert!(resnet18_chain().is_chain());
    assert!(vgg16().is_chain());
    assert!(bert_encoder(1, 2, 32, 16).is_chain());
}

#[test]
fn resnet18_shapes_propagate_as_published() {
    let net = resnet18_chain();
    assert_eq!(net.propagate(0, 1).unwrap(), vec![64, 112, 112]); // stem
    assert_eq!(net.propagate(1, 2).unwrap(), vec![64, 56, 56]); // pool
    assert_eq!(net.propagate(6, 7).unwrap(), vec![128, 28, 28]); // conv3 downsample
    assert_eq!(net.propagate(10, 11).unwrap(), vec![256, 14, 14]); // conv4 downsample
    assert_eq!(net.propagate(14, 15).unwrap(), vec![512, 7, 7]); // conv5 downsample

    // The residual DAG reproduces the same published shapes, including the
    // projection shortcuts and the adds.
    let dag = resnet18();
    let shapes = dag.ref_output_shapes().unwrap();
    let by_name = |n: &str| {
        let i = dag.layers.iter().position(|l| l.name == n).unwrap_or_else(|| panic!("{n}"));
        shapes[i].clone()
    };
    assert_eq!(by_name("pool1"), vec![64, 56, 56]);
    assert_eq!(by_name("add2_2"), vec![64, 56, 56]);
    assert_eq!(by_name("conv3_proj"), vec![128, 28, 28]);
    assert_eq!(by_name("add3_1"), vec![128, 28, 28]);
    assert_eq!(by_name("add5_2"), vec![512, 7, 7]);
}

#[test]
fn repeated_blocks_share_signatures() {
    let net = resnet18();
    let conv2_1a = 2; // conv2_1a, conv2_1b, add2_1 | conv2_2a, conv2_2b, add2_2
    let block1 = [conv2_1a, conv2_1a + 1, conv2_1a + 2];
    let block2 = [conv2_1a + 3, conv2_1a + 4, conv2_1a + 5];
    // The two stage-2 residual blocks are identical branch-spanning
    // segments (different producers, same canonical graph hash) ...
    assert_eq!(
        net.segment_signature_nodes(&block1),
        net.segment_signature_nodes(&block2)
    );
    // ... as are their constituent single layers.
    assert_eq!(net.segment_signature_nodes(&[2]), net.segment_signature_nodes(&[5]));
    // A downsampling block is not interchangeable with an identity block.
    assert_ne!(net.segment_signature_nodes(&[8, 9]), net.segment_signature_nodes(&[12, 13]));
    // The chain projection still memoizes contiguous ranges.
    let chain = resnet18_chain();
    assert_eq!(chain.segment_signature(2, 4), chain.segment_signature(4, 6));
    assert_ne!(chain.segment_signature(6, 8), chain.segment_signature(8, 10));
}

#[test]
fn reshape_boundary_is_a_mandatory_cut() {
    let net = bert_encoder(1, 2, 8, 4);
    assert!(net.segment_buildable(0, 2)); // scores+attend fuse
    assert!(net.segment_buildable(2, 4)); // ffn1+ffn2 fuse
    assert!(!net.segment_buildable(1, 3)); // attention -> FFN reshape
    assert!(!net.segment_buildable(0, 4));

    let arch = Arch::generic(256);
    let pool = Coordinator::new(2);
    let res = search_network(&net, &arch, &tiny_spec(4), &pool).unwrap();
    assert!(
        res.cuts.contains(&2),
        "partitioner must cut at the reshape boundary; got cuts {:?}",
        res.cuts
    );
    // Missing the mandatory cut is a hard error when the cuts are forced.
    assert!(evaluate_partition(&net, &arch, &tiny_spec(4), &[], &pool).is_err());
}

// The acceptance pin: DP over the ResNet-18 chain with cuts forced to the
// existing per-block boundaries reproduces the per-block `Evaluator` search
// results bit for bit (same best mapping, same metrics, same score bits).
#[test]
fn resnet_block_cuts_bit_match_per_block_search() {
    let net = resnet18_chain();
    let arch = Arch::generic(128);
    let pool = Coordinator::new(2);
    let spec = NetworkSearchSpec {
        max_segment_layers: 2,
        search: SearchSpec {
            mapspace: MapSpaceConfig {
                schedules: vec![vec!["P2".into()], vec!["C2".into(), "P2".into()]],
                tile_sizes: vec![4, 14],
                ..Default::default()
            },
            ..Default::default()
        },
        ..Default::default()
    };
    // Cut at every block boundary: stem | pool | 8 two-conv blocks.
    let cuts = [1, 2, 4, 6, 8, 10, 12, 14, 16];
    let res = evaluate_partition(&net, &arch, &spec, &cuts, &pool).unwrap();
    assert_eq!(res.segments.len(), 10);
    assert_eq!(res.cuts, cuts.to_vec());
    // Identical stage-2 blocks were searched once.
    assert!(res.distinct_searched < res.segments.len());

    // The second block of each stage is exactly `workloads::resnet18_block`:
    // (segment range, RESNET18_STAGES index).
    for (range, stage) in [((4, 6), 1), ((8, 10), 2), ((12, 14), 3), ((16, 18), 4)] {
        let seg = res
            .segments
            .iter()
            .find(|s| (s.lo, s.hi) == range)
            .unwrap_or_else(|| panic!("missing segment {range:?}"));
        let block = workloads::resnet18_block(stage);
        let seg_fs = net.segment_fusion_set(range.0, range.1).unwrap();
        // The materialized segment builds the same Einsums...
        assert_eq!(seg_fs.einsums.len(), block.einsums.len());
        for (a, b) in seg_fs.einsums.iter().zip(&block.einsums) {
            assert_eq!(a.rank_sizes, b.rank_sizes);
            assert_eq!(a.rank_names, b.rank_names);
        }
        // ...and the per-block search returns the identical result.
        let ev = Evaluator::new(&block, &arch).unwrap();
        let direct = search::run(&ev, &spec.search, &Coordinator::new(1)).unwrap().best;
        assert_eq!(seg.best.mapping, direct.mapping, "stage {stage} mapping");
        assert_eq!(seg.best.score.to_bits(), direct.score.to_bits(), "stage {stage} score");
        let (a, b) = (&seg.best.metrics, &direct.metrics);
        assert_eq!(a.latency_cycles, b.latency_cycles);
        assert_eq!(a.offchip_reads, b.offchip_reads);
        assert_eq!(a.offchip_writes, b.offchip_writes);
        assert_eq!(a.occupancy_peak, b.occupancy_peak);
        assert_eq!(a.total_ops, b.total_ops);
        assert_eq!(a.recompute_ops, b.recompute_ops);
        assert_eq!(a.energy.total_pj().to_bits(), b.energy.total_pj().to_bits());
    }
}

// The path-pin: on pure chains the graph-cut DP must reproduce the chain
// cut-point DP (the preserved pre-graph-IR code path) bit for bit.
#[test]
fn graph_dp_matches_chain_dp_on_paths() {
    let arch = Arch::generic(256);
    let pool = Coordinator::new(2);
    let spec = NetworkSearchSpec {
        max_segment_layers: 2,
        search: SearchSpec {
            mapspace: MapSpaceConfig {
                uniform_retention: true,
                tile_sizes: vec![32],
                ..Default::default()
            },
            ..Default::default()
        },
        ..Default::default()
    };
    for net in [vgg16(), resnet18_chain()] {
        assert!(net.is_chain());
        let chain = search_network(&net, &arch, &spec, &pool).unwrap();
        let dag = search_network_dag(&net, &arch, &spec, &pool).unwrap();
        assert_eq!(chain.cuts, dag.cuts, "{}", net.name);
        assert_eq!(chain.total_score.to_bits(), dag.total_score.to_bits(), "{}", net.name);
        assert_eq!(chain.candidate_segments, dag.candidate_segments, "{}", net.name);
        assert_eq!(chain.distinct_searched, dag.distinct_searched, "{}", net.name);
        assert_eq!(chain.segments.len(), dag.segments.len());
        for (a, b) in chain.segments.iter().zip(&dag.segments) {
            assert_eq!(a.nodes, b.nodes);
            assert_eq!(a.signature, b.signature);
            assert_eq!(a.best.mapping, b.best.mapping);
            assert_eq!(a.best.score.to_bits(), b.best.score.to_bits());
            assert_eq!(a.best.metrics.latency_cycles, b.best.metrics.latency_cycles);
            assert_eq!(
                a.best.metrics.energy.total_pj().to_bits(),
                b.best.metrics.energy.total_pj().to_bits()
            );
        }
    }
}

#[test]
fn residual_segments_materialize_and_evaluate() {
    let net = resnet18();
    // conv2_1b + add2_1: a branch-spanning segment. The main path arrives
    // as the halo'd external input; the skip (pool1's output) arrives as a
    // second off-chip input fmap.
    let fs = net.segment_fusion_set_nodes(&[3, 4]).unwrap();
    assert_eq!(fs.einsums.len(), 2);
    assert_eq!(fs.einsums[1].inputs.len(), 2);
    let input_fmaps = fs.tensors_of_kind(TensorKind::InputFmap);
    assert_eq!(input_fmaps.len(), 2);
    assert_eq!(fs.tensor(input_fmaps[0]).shape, vec![64, 58, 58]);
    assert_eq!(fs.tensor(input_fmaps[1]).shape, vec![64, 56, 56]);
    fs.validate().unwrap();

    // The whole stage-2 block {conv2_1a, conv2_1b, add2_1}: two convs of
    // valid-conv shrinkage against an un-shrunk skip — the skip is
    // center-cropped to the 54×54 interior.
    let fs3 = net.segment_fusion_set_nodes(&[2, 3, 4]).unwrap();
    let out = fs3.tensors_of_kind(TensorKind::OutputFmap);
    assert_eq!(fs3.tensor(out[0]).shape, vec![64, 54, 54]);

    // Segments reaching *around* a branch without its add are rejected (the
    // intermediate would be needed both inside and outside); pulling the
    // branch point itself in is fine and creates a true internal fan-out:
    // pool1's output feeds both conv2_1a and (center-cropped) the add.
    assert!(!net.segment_buildable_nodes(&[1, 2])); // pool1 also feeds add2_1
    let fs4 = net.segment_fusion_set_nodes(&[1, 2, 3, 4]).unwrap();
    assert!(!fs4.is_chain()); // multi-consumer intermediate
    let out = fs4.tensors_of_kind(TensorKind::OutputFmap);
    assert_eq!(fs4.tensor(out[0]).shape, vec![64, 52, 52]);

    // The analytical model evaluates residual segments — including the
    // internal fan-out — and the fast path and reference walk agree bit
    // for bit.
    let arch = Arch::generic(256);
    for fs in [&fs, &fs3, &fs4] {
        let ev = Evaluator::new(fs, &arch).unwrap();
        let last = fs.last();
        let p = last.rank_index(&format!("P{}", fs.einsums.len())).unwrap();
        for tile in [4, 7] {
            let m = InterLayerMapping::tiled(
                vec![Partition { dim: p, tile }],
                Parallelism::Sequential,
            );
            let fast = ev.evaluate(&m).unwrap();
            let refr = ev.evaluate_reference(&m).unwrap();
            assert_eq!(fast.offchip_reads, refr.offchip_reads);
            assert_eq!(fast.offchip_writes, refr.offchip_writes);
            assert_eq!(fast.latency_cycles, refr.latency_cycles);
            assert_eq!(fast.total_ops, refr.total_ops);
            assert_eq!(fast.occupancy_peak, refr.occupancy_peak);
            assert_eq!(
                fast.energy.total_pj().to_bits(),
                refr.energy.total_pj().to_bits()
            );
        }
        // Untiled: no recompute, algorithmic op count.
        let untiled = ev.evaluate(&InterLayerMapping::untiled(Parallelism::Sequential)).unwrap();
        assert_eq!(untiled.recompute_ops, 0);
        assert_eq!(untiled.total_ops, fs.total_ops());
    }

    // The element-driven simulator stays restricted to chain dataflow; a
    // fused set with an internal fan-out is rejected with a clear error.
    let m = InterLayerMapping::untiled(Parallelism::Sequential);
    assert!(crate::sim::simulate(&fs4, &arch, &m).is_err());
}

#[test]
fn dp_matches_bruteforce_on_small_chain() {
    // Shrinking chain: four convs with exactly chained (halo-free) shapes,
    // so every segment has a distinct signature.
    let mut net = Network { name: "chain4".into(), layers: vec![] };
    let mut w = 18i64;
    for i in 0..4 {
        net.push(
            &format!("conv{i}"),
            &[8, w, w],
            LayerOp::Conv2d { out_channels: 8, r: 3, s: 3, stride: 1 },
        );
        w -= 2;
    }
    net.validate().unwrap();

    let arch = Arch::generic(16);
    let pool = Coordinator::new(2);
    let spec = tiny_spec(3);
    let dp = search_network(&net, &arch, &spec, &pool).unwrap();

    // Brute force every cut subset respecting the segment-length cap.
    let mut best_total = f64::INFINITY;
    for mask in 0u32..8 {
        let cuts: Vec<usize> = (1..4).filter(|c| mask & (1 << (c - 1)) != 0).collect();
        let mut bounds = vec![0];
        bounds.extend(&cuts);
        bounds.push(4);
        if bounds.windows(2).any(|w| w[1] - w[0] > spec.max_segment_layers) {
            continue;
        }
        let res = evaluate_partition(&net, &arch, &spec, &cuts, &pool).unwrap();
        best_total = best_total.min(res.total_score);
    }
    assert_eq!(
        dp.total_score.to_bits(),
        best_total.to_bits(),
        "DP total {} != brute-force optimum {best_total}",
        dp.total_score
    );
    // The result's own accounting is consistent.
    let seg_sum: f64 = dp.segments.iter().map(|s| s.best.score).sum();
    assert_eq!(dp.total_score.to_bits(), seg_sum.to_bits());
}

/// Enumerate all partitions of `0..n` into non-empty subsets (Bell
/// enumeration via restricted growth strings).
fn set_partitions(n: usize) -> Vec<Vec<Vec<usize>>> {
    let mut out = Vec::new();
    let mut assign = vec![0usize; n];
    fn rec(i: usize, groups: usize, assign: &mut Vec<usize>, out: &mut Vec<Vec<Vec<usize>>>) {
        let n = assign.len();
        if i == n {
            let mut part = vec![Vec::new(); groups];
            for (x, &g) in assign.iter().enumerate() {
                part[g].push(x);
            }
            out.push(part);
            return;
        }
        for g in 0..=groups {
            assign[i] = g;
            rec(i + 1, groups.max(g + 1), assign, out);
        }
    }
    rec(0, 0, &mut assign, &mut out);
    out
}

// The branched acceptance pin: the graph DP equals brute force over every
// fusable partition of a residual graph, and the optimum fuses across the
// branch point (the residual add sits inside a multi-node segment).
#[test]
fn dp_matches_bruteforce_on_branched_graph() {
    let net = tiny_residual();
    net.validate().unwrap();
    assert!(!net.is_chain());

    let arch = Arch::generic(64);
    let pool = Coordinator::new(2);
    let mut spec = tiny_spec(3);
    spec.search.objective = Objective::Offchip;

    let dp = search_network(&net, &arch, &spec, &pool).unwrap();

    let mut best_total = f64::INFINITY;
    let mut feasible = 0;
    for part in set_partitions(4) {
        if part.iter().any(|s| s.len() > spec.max_segment_layers) {
            continue;
        }
        if part.iter().any(|s| !net.segment_buildable_nodes(s)) {
            continue;
        }
        let res = evaluate_segments(&net, &arch, &spec, &part, &pool).unwrap();
        feasible += 1;
        best_total = best_total.min(res.total_score);
    }
    assert!(feasible > 2, "brute force found too few fusable partitions");
    assert_eq!(
        dp.total_score.to_bits(),
        best_total.to_bits(),
        "graph DP total {} != brute-force optimum {best_total}",
        dp.total_score
    );
    // Fusing into the add saves the main-path round trip, so the optimal
    // cover spans the branch point.
    assert!(
        dp.segments.iter().any(|s| s.spans_branch(&net)),
        "expected a branch-spanning segment; got {:?}",
        dp.segments.iter().map(|s| s.nodes.clone()).collect::<Vec<_>>()
    );
}

#[test]
fn resnet18_dag_search_fuses_across_a_branch() {
    // The real acceptance demo at network scale, kept cheap: restrict the
    // per-segment mapspace and search the residual DAG under the off-chip
    // objective. At least one chosen segment must contain a residual add
    // together with a feeding conv.
    let net = resnet18();
    let arch = Arch::generic(256);
    let pool = Coordinator::new(4);
    let spec = NetworkSearchSpec {
        max_segment_layers: 2,
        search: SearchSpec {
            objective: Objective::Offchip,
            mapspace: MapSpaceConfig {
                uniform_retention: true,
                tile_sizes: vec![8],
                ..Default::default()
            },
            ..Default::default()
        },
        ..Default::default()
    };
    let res = search_network(&net, &arch, &spec, &pool).unwrap();
    // Every non-virtual node covered exactly once.
    let mut covered = vec![false; net.num_layers()];
    for s in &res.segments {
        for &i in &s.nodes {
            assert!(!covered[i], "node {i} covered twice");
            covered[i] = true;
        }
    }
    assert!(covered.iter().all(|&c| c));
    assert!(
        res.segments.iter().any(|s| s.spans_branch(&net)),
        "expected at least one branch-spanning segment in {:?}",
        res.segments.iter().map(|s| s.range_label()).collect::<Vec<_>>()
    );
    // Memoization still collapses the repeated residual blocks.
    assert!(res.distinct_searched < res.candidate_segments);
}

#[test]
fn pad_fuses_at_segment_head_only() {
    let conv = || LayerOp::Conv2d { out_channels: 8, r: 3, s: 3, stride: 1 };
    let mut net = Network { name: "padded".into(), layers: vec![] };
    net.push("conv0", &[8, 18, 18], conv()); // -> [8,16,16]
    net.push("pad1", &[8, 16, 16], LayerOp::Pad { h: 1, w: 1 }); // -> [8,18,18]
    net.push("conv1", &[8, 18, 18], conv()); // -> [8,16,16]
    net.validate().unwrap();

    // Pad at the head of a segment: absorbed into the (pre-padded) external
    // input, exactly the declared-halo convention.
    assert!(net.segment_buildable_nodes(&[1, 2]));
    let fs = net.segment_fusion_set_nodes(&[1, 2]).unwrap();
    assert_eq!(fs.einsums.len(), 1); // the pad contributes no einsum
    assert_eq!(fs.tensor(fs.einsums[0].inputs[0].tensor).shape, vec![8, 18, 18]);
    // Same signature as a plain halo-declared conv segment — both stream
    // the same padded tensor.
    let plain = tiny_conv_chain(2);
    assert_eq!(
        net.segment_signature_nodes(&[1, 2]),
        plain.segment_signature_nodes(&[1])
    );

    // Interior pad: mandatory cut. Pad alone: nothing to materialize.
    assert!(!net.segment_buildable_nodes(&[0, 1, 2]));
    assert!(!net.segment_buildable_nodes(&[1]));
    assert!(!net.segment_buildable_nodes(&[0, 1]));

    // The partitioner covers the pad by fusing it with its consumer.
    let arch = Arch::generic(64);
    let pool = Coordinator::new(1);
    let res = search_network(&net, &arch, &tiny_spec(2), &pool).unwrap();
    assert!(res.segments.iter().any(|s| s.nodes.contains(&1) && s.nodes.contains(&2)));

    // A pad may also pad the network input itself (node 0, no producer):
    // validation must not choke on the missing edge, and the pad still
    // fuses only into its consumer.
    let mut headpad = Network { name: "headpad".into(), layers: vec![] };
    headpad.push("pad0", &[8, 16, 16], LayerOp::Pad { h: 1, w: 1 });
    headpad.push("conv0", &[8, 18, 18], conv());
    headpad.validate().unwrap();
    assert!(!headpad.segment_buildable_nodes(&[0]));
    assert!(headpad.segment_buildable_nodes(&[0, 1]));
    let fs = headpad.segment_fusion_set_nodes(&[0, 1]).unwrap();
    assert_eq!(fs.tensor(fs.einsums[0].inputs[0].tensor).shape, vec![8, 18, 18]);
}

#[test]
fn concat_is_virtual_and_never_fused() {
    let conv = |c| LayerOp::Conv2d { out_channels: c, r: 3, s: 3, stride: 1 };
    let mut net = Network { name: "cat".into(), layers: vec![] };
    let c0 = net.push("conv0", &[4, 18, 18], conv(4)); // -> [4,16,16]
    let a = net.push_from("conv_a", &[4, 18, 18], conv(4), vec![c0]);
    let b = net.push_from("conv_b", &[4, 18, 18], conv(4), vec![c0]);
    let cat = net.push_from("cat", &[4, 16, 16], LayerOp::Concat, vec![a, b]);
    net.push_from("conv_c", &[8, 18, 18], conv(8), vec![cat]);
    net.validate().unwrap();
    assert_eq!(net.ref_output_shapes().unwrap()[cat], vec![8, 16, 16]);

    // No segment may contain the concat.
    assert!(!net.segment_buildable_nodes(&[cat]));
    assert!(!net.segment_buildable_nodes(&[a, b, cat]));
    // conv_a and conv_b cannot co-fuse either (two sinks), but each fuses
    // with conv0... no — conv0 feeds both, so closure forbids it. Singles
    // remain.
    assert!(!net.segment_buildable_nodes(&[a, b]));
    assert!(!net.segment_buildable_nodes(&[c0, a]));

    let arch = Arch::generic(64);
    let pool = Coordinator::new(2);
    let res = search_network(&net, &arch, &tiny_spec(3), &pool).unwrap();
    assert!(res.segments.iter().all(|s| !s.nodes.contains(&cat)));
    // All four compute nodes covered (the concat costs nothing).
    let covered: usize = res.segments.iter().map(|s| s.nodes.len()).sum();
    assert_eq!(covered, 4);
}

#[test]
fn signatures_are_collision_free_across_presets() {
    // Satellite property: equal signature ⟺ identical materialized Einsums
    // (pairwise distinct-shape ⇒ distinct-signature), across every
    // buildable candidate segment of all four presets.
    let canon = |fs: &crate::einsum::FusionSet| -> String {
        let mut s = String::new();
        for t in &fs.tensors {
            s.push_str(&format!("{:?}:{:?};", t.kind, t.shape));
        }
        for e in &fs.einsums {
            s.push_str(&format!(
                "{:?}{:?}{:?}->{}{:?}|",
                e.rank_names, e.rank_sizes, e.op_kind, e.output.tensor.0, e.output.map
            ));
            for a in &e.inputs {
                s.push_str(&format!("<{}{:?}", a.tensor.0, a.map));
            }
        }
        s
    };
    let mut by_sig: HashMap<String, String> = HashMap::new();
    let mut checked = 0usize;
    for net in [resnet18(), mobilenet_v2(), vgg16(), bert_encoder(1, 2, 32, 16)] {
        let candidates = if net.is_chain() {
            chain_candidates(&net, 3)
        } else {
            dag_candidates(&net, 3).unwrap()
        };
        assert!(!candidates.is_empty(), "{}", net.name);
        for c in candidates {
            let fs = net.segment_fusion_set_nodes(&c.nodes).unwrap();
            let shape = canon(&fs);
            checked += 1;
            match by_sig.get(&c.signature) {
                None => {
                    by_sig.insert(c.signature.clone(), shape);
                }
                Some(prev) => assert_eq!(
                    *prev, shape,
                    "{}: signature {} collides across distinct shapes",
                    net.name, c.signature
                ),
            }
        }
    }
    assert!(checked > 100, "expected a meaningful candidate population, got {checked}");
}

#[test]
fn bench_smoke_json_schema_is_pinned() {
    // The bench binary builds rows through `NetworkSearchResult::bench_row`
    // / `NetworkParetoResult::bench_row` and asserts
    // `check_network_bench_schema` before writing — this test pins both
    // sides so the CI artifact cannot silently drift.
    let net = tiny_conv_chain(3);
    let arch = Arch::generic(32);
    let res = search_network(&net, &arch, &tiny_spec(2), &Coordinator::new(1)).unwrap();
    let row = res.bench_row(&net.name, net.num_layers(), 123.0);
    let front = search_network_pareto(&net, &arch, &tiny_spec(2), &Coordinator::new(1)).unwrap();
    let pareto_row = front.bench_row(&net.name, net.num_layers(), 123.0);
    let doc = |rows: Vec<Json>, pareto_rows: Vec<Json>| {
        Json::Obj(
            [
                ("rows".to_string(), Json::Arr(rows)),
                ("pareto_rows".to_string(), Json::Arr(pareto_rows)),
            ]
            .into_iter()
            .collect(),
        )
    };
    check_network_bench_schema(&doc(vec![row.clone()], vec![pareto_row.clone()])).unwrap();
    // A row losing a key (schema drift) must fail the check — both sections.
    let (Json::Obj(m), Json::Obj(pm)) = (&row, &pareto_row) else {
        panic!("bench rows must be objects");
    };
    let mut broken = m.clone();
    broken.remove("total_offchip_elems");
    let bad = doc(vec![Json::Obj(broken)], vec![pareto_row.clone()]);
    assert!(check_network_bench_schema(&bad).is_err());
    let mut broken = pm.clone();
    broken.remove("front_points");
    let bad = doc(vec![row.clone()], vec![Json::Obj(broken)]);
    assert!(check_network_bench_schema(&bad).is_err());
    // And so must an empty or missing section.
    assert!(check_network_bench_schema(&Json::parse("{}").unwrap()).is_err());
    assert!(check_network_bench_schema(&Json::parse("{\"rows\":[]}").unwrap()).is_err());
    assert!(check_network_bench_schema(&doc(vec![row.clone()], vec![])).is_err());
    let only_pareto = Json::parse("{\"pareto_rows\":[{\"workload\":\"x\"}]}").unwrap();
    assert!(check_network_bench_schema(&only_pareto).is_err());
}

#[test]
fn network_search_deterministic_across_worker_counts() {
    let net = tiny_conv_chain(5);
    let arch = Arch::generic(32);
    let spec = tiny_spec(2);
    let a = search_network(&net, &arch, &spec, &Coordinator::new(1)).unwrap();
    let b = search_network(&net, &arch, &spec, &Coordinator::new(4)).unwrap();
    assert_eq!(a.cuts, b.cuts);
    assert_eq!(a.total_score.to_bits(), b.total_score.to_bits());
    assert_eq!(a.segments.len(), b.segments.len());
    for (x, y) in a.segments.iter().zip(&b.segments) {
        assert_eq!(x.best.mapping, y.best.mapping);
        assert_eq!(x.best.score.to_bits(), y.best.score.to_bits());
    }
    // Branched graphs too.
    let net = tiny_residual();
    let a = search_network(&net, &arch, &spec, &Coordinator::new(1)).unwrap();
    let b = search_network(&net, &arch, &spec, &Coordinator::new(4)).unwrap();
    assert_eq!(a.total_score.to_bits(), b.total_score.to_bits());
    let an: Vec<_> = a.segments.iter().map(|s| s.nodes.clone()).collect();
    let bn: Vec<_> = b.segments.iter().map(|s| s.nodes.clone()).collect();
    assert_eq!(an, bn);
}

#[test]
fn identical_blocks_are_searched_once() {
    let net = tiny_conv_chain(6);
    let arch = Arch::generic(32);
    let res = search_network(&net, &arch, &tiny_spec(2), &Coordinator::new(2)).unwrap();
    // 6 single-layer + 5 two-layer candidates, but only two distinct shapes.
    assert_eq!(res.candidate_segments, 11);
    assert_eq!(res.distinct_searched, 2);
    // Equal-signature segments carry the identical memoized search result.
    for s in &res.segments {
        for t in &res.segments {
            if s.signature == t.signature {
                assert_eq!(s.best.mapping, t.best.mapping);
                assert_eq!(s.best.score.to_bits(), t.best.score.to_bits());
            }
        }
    }
}

#[test]
fn stochastic_segment_search_is_deterministic() {
    let net = tiny_conv_chain(4);
    let arch = Arch::generic(32);
    let spec = NetworkSearchSpec {
        max_segment_layers: 2,
        search: SearchSpec {
            algorithm: Algorithm::Annealing,
            iters: 25,
            seed: 11,
            ..Default::default()
        },
        ..Default::default()
    };
    let a = search_network(&net, &arch, &spec, &Coordinator::new(1)).unwrap();
    let b = search_network(&net, &arch, &spec, &Coordinator::new(3)).unwrap();
    assert_eq!(a.cuts, b.cuts);
    assert_eq!(a.total_score.to_bits(), b.total_score.to_bits());
}

#[test]
fn evaluate_partition_rejects_bad_cuts() {
    let net = tiny_conv_chain(4);
    let arch = Arch::generic(32);
    let pool = Coordinator::new(1);
    let spec = tiny_spec(4);
    assert!(evaluate_partition(&net, &arch, &spec, &[0], &pool).is_err());
    assert!(evaluate_partition(&net, &arch, &spec, &[4], &pool).is_err());
    assert!(evaluate_partition(&net, &arch, &spec, &[2, 2], &pool).is_err());
    assert!(evaluate_partition(&net, &arch, &spec, &[3, 1], &pool).is_err());
    let ok = evaluate_partition(&net, &arch, &spec, &[1, 3], &pool).unwrap();
    assert_eq!(ok.cuts, vec![1, 3]);
    assert_eq!(ok.segments.len(), 3);
    // Explicit node-set covers reject overlaps, gaps, and junk.
    assert!(evaluate_segments(&net, &arch, &spec, &[vec![0, 1], vec![1, 2, 3]], &pool).is_err());
    assert!(evaluate_segments(&net, &arch, &spec, &[vec![0, 1]], &pool).is_err());
    assert!(evaluate_segments(&net, &arch, &spec, &[vec![0, 1], vec![2, 9]], &pool).is_err());
    let ok = evaluate_segments(&net, &arch, &spec, &[vec![0, 1], vec![2, 3]], &pool).unwrap();
    assert_eq!(ok.segments.len(), 2);
}

#[test]
fn invalid_networks_rejected_with_located_errors() {
    // Channel mismatch across a boundary: the error names layer 1 and its op.
    let mut net = Network { name: "bad".into(), layers: vec![] };
    net.push("a", &[8, 18, 18], LayerOp::Conv2d { out_channels: 8, r: 3, s: 3, stride: 1 });
    net.push("b", &[16, 18, 18], LayerOp::Conv2d { out_channels: 8, r: 3, s: 3, stride: 1 });
    let err = net.validate().unwrap_err();
    assert!(err.contains("layer 1"), "{err}");
    assert!(err.contains("'b'"), "{err}");
    assert!(err.contains("conv2d"), "{err}");

    // Window larger than the fmap.
    let mut net = Network { name: "bad2".into(), layers: vec![] };
    net.push("a", &[8, 2, 2], LayerOp::Conv2d { out_channels: 8, r: 3, s: 3, stride: 1 });
    let err = net.validate().unwrap_err();
    assert!(err.contains("layer 0"), "{err}");

    // Empty network.
    assert!(Network { name: "empty".into(), layers: vec![] }.validate().is_err());

    // Non-positive op parameters must be rejected here (an error), not
    // deep inside the builder (a panic) — e.g. from hand-written JSON.
    let mut net = Network { name: "bad3".into(), layers: vec![] };
    net.push("a", &[8, 18, 18], LayerOp::Conv2d { out_channels: 0, r: 3, s: 3, stride: 1 });
    assert!(net.validate().is_err());
    assert!(!net.segment_buildable(0, 1));
    assert!(net.segment_fusion_set(0, 1).is_err());

    // Forward edges (non-topological order) are rejected.
    let mut net = Network { name: "bad4".into(), layers: vec![] };
    net.push("a", &[8, 18, 18], LayerOp::Conv2d { out_channels: 8, r: 3, s: 3, stride: 1 });
    net.layers[0].inputs = vec![0];
    let err = net.validate().unwrap_err();
    assert!(err.contains("earlier node"), "{err}");

    // An add with mismatched operand shapes names the bad operand.
    let conv = |c| LayerOp::Conv2d { out_channels: c, r: 3, s: 3, stride: 1 };
    let mut net = Network { name: "bad5".into(), layers: vec![] };
    let c0 = net.push("a", &[8, 18, 18], conv(8));
    let c1 = net.push("b", &[8, 18, 18], conv(16));
    net.push_from("sum", &[8, 16, 16], LayerOp::Add, vec![c1, c0]);
    let err = net.validate().unwrap_err();
    assert!(err.contains("layer 2") && err.contains("add"), "{err}");
}

// ------------------------------------------------ network Pareto fronts --

/// The acceptance pin: on both a branched graph (resnet18) and a path
/// (vgg16), the scalar DP optimum for every objective lies on the emitted
/// network Pareto front, bit for bit. Exact because the per-segment
/// searches are exhaustive (the front and the scalar path rank the same
/// evaluated sets).
#[test]
fn scalar_optima_lie_on_pareto_front() {
    let arch = Arch::generic(256);
    let pool = Coordinator::new(2);
    for (net, tiles) in [(resnet18(), vec![8]), (vgg16(), vec![32])] {
        let spec = NetworkSearchSpec {
            max_segment_layers: 2,
            search: SearchSpec {
                mapspace: MapSpaceConfig {
                    uniform_retention: true,
                    tile_sizes: tiles,
                    ..Default::default()
                },
                ..Default::default()
            },
            // A beam cap bounds the label sets on the full networks; axis
            // minima survive capping (cap >= #objectives), so the
            // scalar-optimum pin below stays exact.
            max_front_per_state: 24,
            ..Default::default()
        };
        let front = search_network_pareto(&net, &arch, &spec, &pool).unwrap();
        assert!(!front.points.is_empty(), "{}", net.name);
        assert_eq!(front.objectives.len(), 4, "default axes");
        for (axis, &objective) in front.objectives.iter().enumerate() {
            let scalar_spec = NetworkSearchSpec {
                search: SearchSpec { objective, ..spec.search.clone() },
                ..spec.clone()
            };
            let scalar = search_network(&net, &arch, &scalar_spec, &pool).unwrap();
            let front_min = front.min_cost(axis).unwrap();
            // Latency/capacity/offchip scores are integer counts (exactly
            // representable, sums exact) and chain sums share the scalar
            // DP's association order — pinned bit for bit. The energy axis
            // on a branched graph may differ from the scalar lattice DP by
            // association order alone when distinct covers tie exactly, so
            // it gets an ulp-scale bound instead.
            if net.is_chain() || objective != Objective::Energy {
                assert_eq!(
                    front_min.to_bits(),
                    scalar.total_score.to_bits(),
                    "{}: scalar {} optimum {} not on the front (front min {})",
                    net.name,
                    objective.name(),
                    scalar.total_score,
                    front_min
                );
            } else {
                let tol = 1e-12 * scalar.total_score.abs().max(1.0);
                assert!(
                    (front_min - scalar.total_score).abs() <= tol,
                    "{}: scalar {} optimum {} not on the front (front min {})",
                    net.name,
                    objective.name(),
                    scalar.total_score,
                    front_min
                );
            }
        }
        // Front invariants: sorted, mutually non-dominated, accounting
        // consistent with the chosen segments.
        for w in front.points.windows(2) {
            let ord = crate::mapspace::cmp_costs(&w[0].costs, &w[1].costs);
            assert_eq!(ord, std::cmp::Ordering::Less);
        }
        for p in &front.points {
            for q in &front.points {
                if !std::ptr::eq(p, q) {
                    assert!(!crate::mapspace::dominates(&p.costs, &q.costs));
                }
            }
            let mut covered = vec![false; net.num_layers()];
            for s in &p.segments {
                for &i in &s.nodes {
                    assert!(!covered[i], "node {i} covered twice");
                    covered[i] = true;
                }
            }
            for (i, l) in net.layers.iter().enumerate() {
                assert_eq!(covered[i], !l.op.is_virtual());
            }
            let cuts: Vec<usize> = p.segments.iter().skip(1).map(|s| s.lo).collect();
            assert_eq!(p.cuts, cuts);
            // Recompute every axis from the chosen segment metrics.
            for (axis, &objective) in front.objectives.iter().enumerate() {
                let total: f64 = p
                    .segments
                    .iter()
                    .map(|s| spec.search.score_objective(objective, &s.best.metrics))
                    .sum();
                assert_eq!(total.to_bits(), p.costs[axis].to_bits());
            }
        }
    }
}

/// The branched acceptance pin: the front DP equals brute force over every
/// fusable partition x every combination of per-segment Pareto choices.
#[test]
fn pareto_front_matches_bruteforce_on_branched_graph() {
    let net = tiny_residual();
    let arch = Arch::generic(64);
    let pool = Coordinator::new(2);
    let mut spec = tiny_spec(3);
    spec.objectives = vec![Objective::Latency, Objective::Capacity, Objective::Offchip];
    // The Pareto DP runs its inner searches with capacity pruning off (it
    // ranks full evaluated sets); the brute-force reference below calls
    // `search::run` directly, so it must match that setting.
    spec.search.prune = false;

    let dp = search_network_pareto(&net, &arch, &spec, &pool).unwrap();

    let add = |a: &[f64], b: &[f64]| -> Vec<f64> {
        a.iter().zip(b).map(|(x, y)| x + y).collect()
    };
    let mut all: Vec<ParetoPointK<()>> = Vec::new();
    let mut feasible = 0usize;
    for part in set_partitions(4) {
        if part.iter().any(|s| s.len() > spec.max_segment_layers) {
            continue;
        }
        if part.iter().any(|s| !net.segment_buildable_nodes(s)) {
            continue;
        }
        feasible += 1;
        // Segments in sink order (= ascending largest node), per-segment
        // evaluated sets pruned to fronts (combining front choices suffices:
        // any dominated per-segment choice is replaceable axis-by-axis).
        let mut segs = part.clone();
        segs.sort_by_key(|s| *s.iter().max().unwrap());
        let mut per_seg: Vec<Vec<Vec<f64>>> = Vec::new();
        for nodes in &segs {
            let fs = net.segment_fusion_set_nodes(nodes).unwrap();
            let ev = Evaluator::new(&fs, &arch).unwrap();
            let r = search::run(&ev, &spec.search, &Coordinator::new(1)).unwrap();
            let pts: Vec<ParetoPointK<()>> = r
                .evaluated
                .iter()
                .map(|sc| ParetoPointK {
                    costs: spec
                        .objectives
                        .iter()
                        .map(|&o| spec.search.score_objective(o, &sc.metrics))
                        .collect(),
                    payload: (),
                })
                .collect();
            per_seg.push(pareto_front_k(pts).into_iter().map(|p| p.costs).collect());
        }
        // Cartesian sum across segments, accumulating in sink order (the
        // DP's canonical association order).
        let mut sums: Vec<Vec<f64>> = vec![Vec::new()];
        for front in &per_seg {
            let mut next = Vec::with_capacity(sums.len() * front.len());
            for base in &sums {
                for c in front {
                    next.push(if base.is_empty() { c.clone() } else { add(base, c) });
                }
            }
            sums = next;
        }
        all.extend(sums.into_iter().map(|costs| ParetoPointK { costs, payload: () }));
        // Incremental global prune keeps the candidate pool small without
        // weakening the check (front(A ∪ B) == front(front(A) ∪ B)).
        all = pareto_front_k(all);
    }
    assert!(feasible > 2, "brute force found too few fusable partitions");
    let brute = pareto_front_k(all);
    assert_eq!(
        dp.points.len(),
        brute.len(),
        "front sizes differ: DP {:?} vs brute {:?}",
        dp.points.iter().map(|p| p.costs.clone()).collect::<Vec<_>>(),
        brute.iter().map(|p| p.costs.clone()).collect::<Vec<_>>()
    );
    for (d, b) in dp.points.iter().zip(&brute) {
        assert_eq!(d.costs.len(), b.costs.len());
        for (x, y) in d.costs.iter().zip(&b.costs) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }
    // The interesting part of the front fuses across the branch point.
    assert!(dp
        .points
        .iter()
        .any(|p| p.segments.iter().any(|s| s.spans_branch(&net))));
}

/// On pure paths the graph-cut front DP emits the same front (cost for
/// cost, bit for bit) as the chain cut-point front DP.
#[test]
fn pareto_graph_dp_matches_chain_dp_on_paths() {
    let arch = Arch::generic(32);
    let pool = Coordinator::new(2);
    let mut spec = tiny_spec(2);
    spec.objectives = vec![Objective::Latency, Objective::Energy, Objective::Offchip];
    // Capped: identical label *cost sets* at every state make the capped
    // selection identical too, and vgg16's uncapped 3-axis fronts would be
    // needlessly large for a parity pin.
    spec.max_front_per_state = 32;
    for net in [tiny_conv_chain(5), vgg16()] {
        assert!(net.is_chain());
        let chain = search_network_pareto(&net, &arch, &spec, &pool).unwrap();
        let dag = search_network_pareto_dag(&net, &arch, &spec, &pool).unwrap();
        assert_eq!(chain.points.len(), dag.points.len(), "{}", net.name);
        assert_eq!(chain.candidate_segments, dag.candidate_segments, "{}", net.name);
        assert_eq!(chain.distinct_searched, dag.distinct_searched, "{}", net.name);
        assert_eq!(chain.segment_front_points, dag.segment_front_points, "{}", net.name);
        for (a, b) in chain.points.iter().zip(&dag.points) {
            for (x, y) in a.costs.iter().zip(&b.costs) {
                assert_eq!(x.to_bits(), y.to_bits(), "{}", net.name);
            }
        }
    }
}

#[test]
fn pareto_front_deterministic_across_worker_counts() {
    let arch = Arch::generic(32);
    let mut spec = tiny_spec(2);
    spec.objectives = vec![Objective::Latency, Objective::Capacity, Objective::Offchip];
    for net in [tiny_conv_chain(5), tiny_residual()] {
        let a = search_network_pareto(&net, &arch, &spec, &Coordinator::new(1)).unwrap();
        let b = search_network_pareto(&net, &arch, &spec, &Coordinator::new(4)).unwrap();
        assert_eq!(a.points.len(), b.points.len(), "{}", net.name);
        for (x, y) in a.points.iter().zip(&b.points) {
            for (cx, cy) in x.costs.iter().zip(&y.costs) {
                assert_eq!(cx.to_bits(), cy.to_bits());
            }
            assert_eq!(x.cuts, y.cuts);
            let xn: Vec<_> = x.segments.iter().map(|s| s.nodes.clone()).collect();
            let yn: Vec<_> = y.segments.iter().map(|s| s.nodes.clone()).collect();
            assert_eq!(xn, yn);
            for (sx, sy) in x.segments.iter().zip(&y.segments) {
                assert_eq!(sx.best.mapping, sy.best.mapping);
                assert_eq!(sx.best.score.to_bits(), sy.best.score.to_bits());
            }
        }
    }
}

/// The beam cap bounds the front but never loses a per-axis minimum (the
/// cap-keeps-axis-minima policy of `cap_front_k`, applied at every DP
/// state and per-segment front).
#[test]
fn beam_cap_bounds_front_and_keeps_axis_minima() {
    let net = tiny_residual();
    let arch = Arch::generic(64);
    let pool = Coordinator::new(2);
    let mut spec = tiny_spec(3);
    spec.objectives = vec![Objective::Latency, Objective::Capacity, Objective::Offchip];
    let exact = search_network_pareto(&net, &arch, &spec, &pool).unwrap();
    let mut capped_spec = spec.clone();
    capped_spec.max_front_per_state = spec.objectives.len();
    let capped = search_network_pareto(&net, &arch, &capped_spec, &pool).unwrap();
    assert!(capped.points.len() <= capped_spec.max_front_per_state);
    assert!(capped.points.len() <= exact.points.len());
    for axis in 0..spec.objectives.len() {
        assert_eq!(
            capped.min_cost(axis).unwrap().to_bits(),
            exact.min_cost(axis).unwrap().to_bits(),
            "axis {axis} minimum lost under capping"
        );
    }
    // A cap below the arity is rejected up front.
    let mut bad = spec.clone();
    bad.max_front_per_state = 2;
    assert!(search_network_pareto(&net, &arch, &bad, &pool).is_err());
    // As is an empty objectives list.
    let mut bad = spec.clone();
    bad.objectives.clear();
    assert!(search_network_pareto(&net, &arch, &bad, &pool).is_err());
}

/// A single-objective "front" degenerates to exactly the scalar optimum.
#[test]
fn single_objective_front_is_the_scalar_optimum() {
    let net = tiny_conv_chain(4);
    let arch = Arch::generic(32);
    let pool = Coordinator::new(1);
    let mut spec = tiny_spec(2);
    spec.objectives = vec![Objective::Offchip];
    spec.search.objective = Objective::Offchip;
    let front = search_network_pareto(&net, &arch, &spec, &pool).unwrap();
    assert_eq!(front.points.len(), 1);
    let scalar = search_network(&net, &arch, &spec, &pool).unwrap();
    assert_eq!(front.points[0].costs[0].to_bits(), scalar.total_score.to_bits());
    assert_eq!(front.points[0].cuts, scalar.cuts);
}

#[test]
fn totals_are_consistent_with_segments() {
    let net = tiny_conv_chain(3);
    let arch = Arch::generic(32);
    let res = search_network(&net, &arch, &tiny_spec(2), &Coordinator::new(1)).unwrap();
    let lat: i64 = res.segments.iter().map(|s| s.best.metrics.latency_cycles).sum();
    assert_eq!(res.total_latency(), lat);
    let off: i64 = res
        .segments
        .iter()
        .map(|s| s.best.metrics.offchip_reads + s.best.metrics.offchip_writes)
        .sum();
    assert_eq!(res.total_offchip(), off);
    assert!(res.total_energy_pj() > 0.0);
}

// -------------------------------------------- static candidate pruning --

/// A 2-layer 96-channel conv stack whose fused pair provably overflows a
/// 128 KiB GLB: producing even one sink output element needs every
/// intermediate channel, hence all of conv0's weights — 96·96·3·3 = 82944
/// elems = 165888 B > 131072 B — while each single layer maps comfortably.
/// The closed-form floor prunes exactly the fused candidate.
fn prune_stack() -> Network {
    let conv = || LayerOp::Conv2d { out_channels: 96, r: 3, s: 3, stride: 1 };
    let mut net = Network { name: "prune_stack".into(), layers: vec![] };
    net.push("conv0", &[96, 22, 22], conv());
    net.push("conv1", &[96, 20, 20], conv());
    net
}

/// A mapspace in which the prune-stack single layers have feasible
/// mappings, so the survivor optimum is unpenalized and the lossless guard
/// passes with orders of magnitude to spare.
fn prune_spec() -> NetworkSearchSpec {
    NetworkSearchSpec {
        max_segment_layers: 2,
        search: SearchSpec {
            mapspace: MapSpaceConfig {
                uniform_retention: true,
                tile_sizes: vec![4, 8],
                ..Default::default()
            },
            ..Default::default()
        },
        ..Default::default()
    }
}

fn assert_scalar_results_identical(a: &NetworkSearchResult, b: &NetworkSearchResult, name: &str) {
    assert_eq!(a.cuts, b.cuts, "{name}");
    assert_eq!(a.total_score.to_bits(), b.total_score.to_bits(), "{name}");
    assert_eq!(a.segments.len(), b.segments.len(), "{name}");
    for (x, y) in a.segments.iter().zip(&b.segments) {
        assert_eq!(x.nodes, y.nodes, "{name}");
        assert_eq!(x.signature, y.signature, "{name}");
        assert_eq!(x.best.mapping, y.best.mapping, "{name}");
        assert_eq!(x.best.score.to_bits(), y.best.score.to_bits(), "{name}");
        assert_eq!(x.best.metrics.latency_cycles, y.best.metrics.latency_cycles, "{name}");
        assert_eq!(
            x.best.metrics.energy.total_pj().to_bits(),
            y.best.metrics.energy.total_pj().to_bits(),
            "{name}"
        );
    }
}

fn assert_fronts_identical(a: &NetworkParetoResult, b: &NetworkParetoResult, name: &str) {
    assert_eq!(a.points.len(), b.points.len(), "{name}");
    for (x, y) in a.points.iter().zip(&b.points) {
        let xc: Vec<u64> = x.costs.iter().map(|c| c.to_bits()).collect();
        let yc: Vec<u64> = y.costs.iter().map(|c| c.to_bits()).collect();
        assert_eq!(xc, yc, "{name}");
        assert_eq!(x.cuts, y.cuts, "{name}");
        assert_eq!(x.segments.len(), y.segments.len(), "{name}");
        for (s, t) in x.segments.iter().zip(&y.segments) {
            assert_eq!(s.nodes, t.nodes, "{name}");
            assert_eq!(s.best.mapping, t.best.mapping, "{name}");
            assert_eq!(s.best.score.to_bits(), t.best.score.to_bits(), "{name}");
        }
    }
}

/// The acceptance pin: the static floor prunes the provably-oversized
/// fused candidate before any mapspace search, the lossless guard
/// certifies the survivor optimum, and the result is bit-identical to the
/// unpruned run — with fewer distinct shapes searched.
#[test]
fn static_pruning_fires_and_is_bit_lossless() {
    let net = prune_stack();
    let arch = Arch::generic(128);
    let pool = Coordinator::new(2);
    let spec = prune_spec();
    let on = search_network(&net, &arch, &spec, &pool).unwrap();
    // 2 single-layer + 1 fused candidate; only the fused pair overflows.
    assert_eq!(on.candidate_segments, 3);
    assert_eq!(on.candidates_pruned, 1);
    assert_eq!(on.distinct_searched, 2);
    let mut off_spec = spec.clone();
    off_spec.search.prune = false;
    let off = search_network(&net, &arch, &off_spec, &pool).unwrap();
    assert_eq!(off.candidates_pruned, 0);
    assert_eq!(off.distinct_searched, 3);
    assert_scalar_results_identical(&on, &off, "prune_stack");
    // The same holds for the Pareto front over the same candidates.
    let front_on = search_network_pareto(&net, &arch, &spec, &pool).unwrap();
    assert_eq!(front_on.candidates_pruned, 1);
    let front_off = search_network_pareto(&net, &arch, &off_spec, &pool).unwrap();
    assert_eq!(front_off.candidates_pruned, 0);
    assert_fronts_identical(&front_on, &front_off, "prune_stack");
}

/// Bit-identity of the scalar DP with pruning on vs off on the real
/// presets (branched resnet18 and mobilenet exercise the graph DP, the
/// tiny residual the brute-force-checked path). Whether the floors prune,
/// guard-pass, or fall back, the output may not move by a single bit.
#[test]
fn static_pruning_is_bit_lossless_on_presets() {
    let pool = Coordinator::new(2);
    let spec = NetworkSearchSpec {
        max_segment_layers: 2,
        search: SearchSpec {
            mapspace: MapSpaceConfig {
                uniform_retention: true,
                tile_sizes: vec![32],
                ..Default::default()
            },
            ..Default::default()
        },
        ..Default::default()
    };
    let mut off = spec.clone();
    off.search.prune = false;
    for (net, arch) in [
        (resnet18(), Arch::generic(64)),
        (mobilenet_v2(), Arch::generic(64)),
    ] {
        let a = search_network(&net, &arch, &spec, &pool).unwrap();
        let b = search_network(&net, &arch, &off, &pool).unwrap();
        assert_eq!(b.candidates_pruned, 0, "{}", net.name);
        assert_scalar_results_identical(&a, &b, &net.name);
    }
    // The tiny residual graph with its own (brute-force-scaled) mapspace.
    let net = tiny_residual();
    let arch = Arch::generic(32);
    let spec = tiny_spec(2);
    let mut off = spec.clone();
    off.search.prune = false;
    let a = search_network(&net, &arch, &spec, &pool).unwrap();
    let b = search_network(&net, &arch, &off, &pool).unwrap();
    assert_scalar_results_identical(&a, &b, &net.name);
}

/// The front analogue of the preset bit-identity pin: uncapped Pareto
/// fronts with pruning on vs off are byte-identical on a branched preset
/// and the tiny residual graph.
#[test]
fn pareto_pruning_is_bit_lossless_on_presets() {
    let pool = Coordinator::new(2);
    let spec = NetworkSearchSpec {
        max_segment_layers: 2,
        search: SearchSpec {
            mapspace: MapSpaceConfig {
                uniform_retention: true,
                tile_sizes: vec![32],
                ..Default::default()
            },
            ..Default::default()
        },
        ..Default::default()
    };
    let mut off = spec.clone();
    off.search.prune = false;
    let net = resnet18();
    let arch = Arch::generic(64);
    let a = search_network_pareto(&net, &arch, &spec, &pool).unwrap();
    let b = search_network_pareto(&net, &arch, &off, &pool).unwrap();
    assert_eq!(b.candidates_pruned, 0, "{}", net.name);
    assert_fronts_identical(&a, &b, &net.name);
    let net = tiny_residual();
    let arch = Arch::generic(32);
    let spec = tiny_spec(2);
    let mut off = spec.clone();
    off.search.prune = false;
    let a = search_network_pareto(&net, &arch, &spec, &pool).unwrap();
    let b = search_network_pareto(&net, &arch, &off, &pool).unwrap();
    assert_fronts_identical(&a, &b, &net.name);
}

/// Lint soundness: a network that lints clean yields a valid fusion set
/// for every candidate the DPs enumerate — the plan-time acceptance the
/// linter reuses and the full builder cannot disagree.
#[test]
fn lint_clean_networks_have_buildable_candidates() {
    use crate::analysis::lint_document;
    for net in [resnet18(), mobilenet_v2(), vgg16(), bert_encoder(1, 2, 32, 16)] {
        let doc = Json::parse(&format!("{{\"network\": {}}}", net.to_json())).unwrap();
        let report = lint_document(&doc);
        assert_eq!(report.exit_code(), 0, "{}: {:#?}", net.name, report.diagnostics);
        let candidates = if net.is_chain() {
            chain_candidates(&net, 3)
        } else {
            dag_candidates(&net, 3).unwrap()
        };
        assert!(!candidates.is_empty(), "{}", net.name);
        for c in &candidates {
            let fs = net
                .segment_fusion_set_nodes(&c.nodes)
                .unwrap_or_else(|e| panic!("{} {:?}: {e}", net.name, c.nodes));
            fs.validate()
                .unwrap_or_else(|e| panic!("{} {:?}: {e}", net.name, c.nodes));
        }
    }
}
