//! Whole-network workloads and fused-segment partitioning.
//!
//! LoopTree's case studies (paper §VI) evaluate one fusion set at a time,
//! but the decision the paper motivates — *which* layers to fuse, and where
//! to cut — is a network-level question (DNNFuser frames layer fusion as a
//! network-level mapping problem; CMDS shows cross-layer choices interact
//! across cuts). This module represents a whole DNN as a **chain of layer
//! specs** ([`Network`]), materializes any contiguous run of layers as a
//! [`FusionSet`] segment (via the existing [`FusionSetBuilder`]), and —
//! in [`search_network`] — searches the mapspace of every candidate segment
//! and picks the optimal cut set by dynamic programming.
//!
//! ## Shape conventions
//!
//! Each [`LayerSpec`] carries the fmap shape its layer consumes *in the
//! original padded network* (e.g. `[64, 58, 58]` for a 3×3/pad-1 conv on a
//! 56×56 fmap — the repo-wide halo convention of `einsum::workloads`).
//! When a segment is cut at layer `lo`, the [`FusionSetBuilder`] starts
//! from `layers[lo].input_shape` and propagates shapes through the
//! remaining ops with *valid-convolution* semantics: fused interior layers
//! see the un-padded shrunk fmap of their producer, exactly as the fused
//! pyramid of the paper's Fig 1 (and of `workloads::conv_conv`) does. A
//! single-block segment of [`resnet18`] therefore builds the *identical*
//! Einsums as `workloads::resnet18_block` — the per-block and network-level
//! views agree bit for bit.
//!
//! Consecutive layers must agree on every non-spatial dimension; spatial
//! dims may be re-declared across a cut (that is where the padding halo
//! returns). A boundary whose shapes are only reshape-compatible (equal
//! element count, different arity — e.g. BERT's `[B,H,T,E] → [B·T, H·E]`
//! attention→FFN boundary) is a **mandatory cut**: no fused segment can
//! span it, and the partitioner never proposes one.

mod partition;

pub use partition::{
    evaluate_partition, search_network, NetworkSearchResult, NetworkSearchSpec, SegmentChoice,
};

use crate::einsum::{FusionSet, FusionSetBuilder};

/// One layer's operator, mirroring the [`FusionSetBuilder`] vocabulary.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum LayerOp {
    /// 2D convolution (`[C,H,W] → [M,P,Q]`), valid padding.
    Conv2d { out_channels: i64, r: i64, s: i64, stride: i64 },
    /// 1×1 convolution (`[C,H,W] → [M,H,W]`).
    Pointwise { out_channels: i64 },
    /// Depthwise convolution (`[C,H,W] → [C,P,Q]`).
    Depthwise { r: i64, s: i64, stride: i64 },
    /// Max pooling (`[C,H,W] → [C,P,Q]`).
    MaxPool { k: i64, stride: i64 },
    /// Fully connected (`[M,D] → [M,E]`).
    Fc { out_features: i64 },
    /// Attention score matmul (`[B,H,M,E] → [B,H,M,N]`, `N = seq`).
    AttentionScores { seq: i64 },
    /// Attention value matmul (`[B,H,M,N] → [B,H,M,E]`, `E = emb`).
    AttentionValues { emb: i64 },
}

impl LayerOp {
    /// Stable wire name (the JSON spec layer uses these).
    pub fn name(&self) -> &'static str {
        match self {
            LayerOp::Conv2d { .. } => "conv2d",
            LayerOp::Pointwise { .. } => "pointwise",
            LayerOp::Depthwise { .. } => "depthwise",
            LayerOp::MaxPool { .. } => "maxpool",
            LayerOp::Fc { .. } => "fc",
            LayerOp::AttentionScores { .. } => "attention_scores",
            LayerOp::AttentionValues { .. } => "attention_values",
        }
    }

    /// Canonical parameter string, e.g. `conv2d(64,3,3,2)` — the unit of the
    /// segment [`Network::segment_signature`] memoization key.
    pub fn signature(&self) -> String {
        match self {
            LayerOp::Conv2d { out_channels, r, s, stride } => {
                format!("conv2d({out_channels},{r},{s},{stride})")
            }
            LayerOp::Pointwise { out_channels } => format!("pointwise({out_channels})"),
            LayerOp::Depthwise { r, s, stride } => format!("depthwise({r},{s},{stride})"),
            LayerOp::MaxPool { k, stride } => format!("maxpool({k},{stride})"),
            LayerOp::Fc { out_features } => format!("fc({out_features})"),
            LayerOp::AttentionScores { seq } => format!("attention_scores({seq})"),
            LayerOp::AttentionValues { emb } => format!("attention_values({emb})"),
        }
    }

    /// The fmap shape this op produces from `input`, with valid-convolution
    /// semantics (mirrors the [`FusionSetBuilder`] math exactly, but returns
    /// an error where the builder would panic — arity mismatch or an empty
    /// output).
    pub fn output_shape(&self, input: &[i64]) -> Result<Vec<i64>, String> {
        // All op parameters must be positive, or the builder's fusion-set
        // validation would panic downstream.
        let params = match self {
            LayerOp::Conv2d { out_channels, r, s, stride } => vec![*out_channels, *r, *s, *stride],
            LayerOp::Pointwise { out_channels } => vec![*out_channels],
            LayerOp::Depthwise { r, s, stride } => vec![*r, *s, *stride],
            LayerOp::MaxPool { k, stride } => vec![*k, *stride],
            LayerOp::Fc { out_features } => vec![*out_features],
            LayerOp::AttentionScores { seq } => vec![*seq],
            LayerOp::AttentionValues { emb } => vec![*emb],
        };
        if params.iter().any(|&p| p < 1) {
            return Err(format!("{}: all op parameters must be >= 1", self.signature()));
        }
        let spatial = |h: i64, w: i64, r: i64, s: i64, stride: i64| -> Result<(i64, i64), String> {
            let p = (h - r) / stride + 1;
            let q = (w - s) / stride + 1;
            if h < r || w < s || p < 1 || q < 1 {
                return Err(format!(
                    "{}: window {r}x{s} does not fit input {h}x{w}",
                    self.signature()
                ));
            }
            Ok((p, q))
        };
        match (self, input) {
            (LayerOp::Conv2d { out_channels, r, s, stride }, [_, h, w]) => {
                let (p, q) = spatial(*h, *w, *r, *s, *stride)?;
                Ok(vec![*out_channels, p, q])
            }
            (LayerOp::Pointwise { out_channels }, [_, h, w]) => Ok(vec![*out_channels, *h, *w]),
            (LayerOp::Depthwise { r, s, stride }, [c, h, w]) => {
                let (p, q) = spatial(*h, *w, *r, *s, *stride)?;
                Ok(vec![*c, p, q])
            }
            (LayerOp::MaxPool { k, stride }, [c, h, w]) => {
                let (p, q) = spatial(*h, *w, *k, *k, *stride)?;
                Ok(vec![*c, p, q])
            }
            (LayerOp::Fc { out_features }, [m, _]) => Ok(vec![*m, *out_features]),
            (LayerOp::AttentionScores { seq }, [b, hd, m, _]) => Ok(vec![*b, *hd, *m, *seq]),
            (LayerOp::AttentionValues { emb }, [b, hd, m, _]) => Ok(vec![*b, *hd, *m, *emb]),
            _ => Err(format!(
                "{}: input shape {:?} has the wrong arity",
                self.signature(),
                input
            )),
        }
    }

    /// Append this op to a builder (the shapes must already have been
    /// checked with [`LayerOp::output_shape`]; the builder panics on
    /// mismatches).
    fn apply(&self, b: &mut FusionSetBuilder) {
        match *self {
            LayerOp::Conv2d { out_channels, r, s, stride } => {
                b.conv2d(out_channels, r, s, stride);
            }
            LayerOp::Pointwise { out_channels } => {
                b.pointwise(out_channels);
            }
            LayerOp::Depthwise { r, s, stride } => {
                b.depthwise(r, s, stride);
            }
            LayerOp::MaxPool { k, stride } => {
                b.maxpool(k, stride);
            }
            LayerOp::Fc { out_features } => {
                b.fc(out_features);
            }
            LayerOp::AttentionScores { seq } => {
                b.attention_scores(seq);
            }
            LayerOp::AttentionValues { emb } => {
                b.attention_values(emb);
            }
        }
    }
}

/// One layer of a [`Network`]: a display name, the fmap shape it consumes in
/// the original (padded) network, and its operator.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayerSpec {
    pub name: String,
    pub input_shape: Vec<i64>,
    pub op: LayerOp,
}

/// A whole DNN as a chain of layers (the fused-segment partitioner's input).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Network {
    pub name: String,
    pub layers: Vec<LayerSpec>,
}

impl Network {
    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    /// Check structural invariants:
    /// * every op applies to its declared input shape,
    /// * consecutive layers agree on all non-spatial dims (spatial dims may
    ///   be re-declared across a layer boundary — the padding halo), and
    ///   arity changes are element-count-preserving reshapes.
    pub fn validate(&self) -> Result<(), String> {
        if self.layers.is_empty() {
            return Err(format!("network {} has no layers", self.name));
        }
        for (i, l) in self.layers.iter().enumerate() {
            if l.input_shape.iter().any(|&d| d <= 0) {
                return Err(format!("{}: non-positive input dim", l.name));
            }
            let out = l
                .op
                .output_shape(&l.input_shape)
                .map_err(|e| format!("{}: {e}", l.name))?;
            if let Some(next) = self.layers.get(i + 1) {
                let nin = &next.input_shape;
                if nin.len() == out.len() {
                    // Same arity: non-spatial dims must match; the trailing
                    // two (spatial) dims of 3D fmaps may carry a halo.
                    let fixed = if out.len() == 3 { 1 } else { out.len() };
                    if out[..fixed] != nin[..fixed] {
                        return Err(format!(
                            "{} -> {}: shape mismatch {:?} vs {:?}",
                            l.name, next.name, out, nin
                        ));
                    }
                } else {
                    // Arity change: a reshape boundary — sizes must agree.
                    let a: i64 = out.iter().product();
                    let b: i64 = nin.iter().product();
                    if a != b {
                        return Err(format!(
                            "{} -> {}: reshape {:?} -> {:?} changes element count",
                            l.name, next.name, out, nin
                        ));
                    }
                }
            }
        }
        Ok(())
    }

    /// Whether layers `lo..hi` can be fused into one segment: shapes must
    /// propagate through the builder without error. A reshape boundary
    /// (arity change) inside the range makes it unbuildable, forcing a cut.
    pub fn segment_buildable(&self, lo: usize, hi: usize) -> bool {
        self.propagate(lo, hi).is_ok()
    }

    /// Shape propagation for a candidate segment, with valid-convolution
    /// semantics starting from `layers[lo].input_shape`.
    fn propagate(&self, lo: usize, hi: usize) -> Result<Vec<i64>, String> {
        if lo >= hi || hi > self.layers.len() {
            return Err(format!("segment [{lo}..{hi}) out of range"));
        }
        let mut shape = self.layers[lo].input_shape.clone();
        for l in &self.layers[lo..hi] {
            shape = l.op.output_shape(&shape)?;
        }
        Ok(shape)
    }

    /// Materialize layers `lo..hi` as a [`FusionSet`].
    pub fn segment_fusion_set(&self, lo: usize, hi: usize) -> Result<FusionSet, String> {
        self.propagate(lo, hi)
            .map_err(|e| format!("{}[{lo}..{hi}): {e}", self.name))?;
        let mut b = FusionSetBuilder::new(
            &format!("{}[{lo}..{hi})", self.name),
            &self.layers[lo].input_shape,
        );
        for l in &self.layers[lo..hi] {
            l.op.apply(&mut b);
        }
        Ok(b.build())
    }

    /// Memoization key for the segment `lo..hi`: two segments with equal
    /// signatures build identical Einsums (up to the fusion-set name, which
    /// carries no model semantics), so their mapspace searches return
    /// identical results and are run once. Repeated blocks — e.g. the
    /// identical stage-2 basic blocks of ResNet — collapse this way.
    pub fn segment_signature(&self, lo: usize, hi: usize) -> String {
        let ops: Vec<String> = self.layers[lo..hi].iter().map(|l| l.op.signature()).collect();
        format!("{:?}|{}", self.layers[lo].input_shape, ops.join("+"))
    }

    /// Human-readable span, e.g. `conv2_1a..conv2_1b`.
    pub fn span_name(&self, lo: usize, hi: usize) -> String {
        if hi == lo + 1 {
            self.layers[lo].name.clone()
        } else {
            format!("{}..{}", self.layers[lo].name, self.layers[hi - 1].name)
        }
    }
}

// ------------------------------------------------------------- presets --

/// Push one ResNet basic block (two 3×3/pad-1 convs) on a `w`×`w`, `c`-channel
/// fmap. A single-block segment builds exactly `workloads::conv_conv(w, c)`.
fn basic_block(layers: &mut Vec<LayerSpec>, stage: &str, block: usize, w: i64, c: i64) {
    for half in ["a", "b"] {
        layers.push(LayerSpec {
            name: format!("{stage}_{n}{half}", n = block + 1),
            input_shape: vec![c, w + 2, w + 2],
            op: LayerOp::Conv2d { out_channels: c, r: 3, s: 3, stride: 1 },
        });
    }
}

/// Full ResNet-18 main path (He et al. [34]): 7×7/2 stem, 3×3/2 max pool,
/// four stages of two basic blocks each (stage transitions downsample with a
/// stride-2 first conv and double the channels). Residual adds and the final
/// classifier head are not part of the fused-dataflow chain.
pub fn resnet18() -> Network {
    let mut layers = vec![
        LayerSpec {
            name: "conv1".into(),
            input_shape: vec![3, 230, 230], // 224 + 2·3 halo, 7×7/2 -> 112
            op: LayerOp::Conv2d { out_channels: 64, r: 7, s: 7, stride: 2 },
        },
        LayerSpec {
            name: "pool1".into(),
            input_shape: vec![64, 114, 114], // 112 + 2·1 halo, 3×3/2 -> 56
            op: LayerOp::MaxPool { k: 3, stride: 2 },
        },
    ];
    // Stage 2: two identical blocks at 56×56×64.
    for b in 0..2 {
        basic_block(&mut layers, "conv2", b, 56, 64);
    }
    // Stages 3–5: a stride-2, channel-doubling transition block, then an
    // identity-shaped block.
    for (stage, &(w, c)) in [(28i64, 128i64), (14, 256), (7, 512)].iter().enumerate() {
        let stage_name = format!("conv{}", stage + 3);
        layers.push(LayerSpec {
            name: format!("{stage_name}_1a"),
            input_shape: vec![c / 2, 2 * w + 2, 2 * w + 2],
            op: LayerOp::Conv2d { out_channels: c, r: 3, s: 3, stride: 2 },
        });
        layers.push(LayerSpec {
            name: format!("{stage_name}_1b"),
            input_shape: vec![c, w + 2, w + 2],
            op: LayerOp::Conv2d { out_channels: c, r: 3, s: 3, stride: 1 },
        });
        basic_block(&mut layers, &stage_name, 1, w, c);
    }
    Network { name: "resnet18".into(), layers }
}

/// Full MobileNetV2 main path (Sandler et al. [1]): 3×3/2 stem, seventeen
/// inverted-residual blocks per the paper's (t, c, n, s) table, and the
/// final 1×1 expansion conv. Each block is `pwise(t·c_in) → dwise(3×3/s) →
/// pwise(c_out)`; the t = 1 first block has no expansion pointwise.
pub fn mobilenet_v2() -> Network {
    // (expansion t, output channels c, repeats n, first-block stride s) —
    // the MobileNetV2 paper's Table 2, at 224×224 input.
    const BLOCKS: [(i64, i64, usize, i64); 7] = [
        (1, 16, 1, 1),
        (6, 24, 2, 2),
        (6, 32, 3, 2),
        (6, 64, 4, 2),
        (6, 96, 3, 1),
        (6, 160, 3, 2),
        (6, 320, 1, 1),
    ];
    let mut layers = vec![LayerSpec {
        name: "conv0".into(),
        input_shape: vec![3, 226, 226], // 224 + 2·1 halo, 3×3/2 -> 112
        op: LayerOp::Conv2d { out_channels: 32, r: 3, s: 3, stride: 2 },
    }];
    let mut c_in = 32i64;
    let mut w = 112i64; // fmap width entering the next block
    let mut idx = 0usize;
    for &(t, c_out, n, s) in &BLOCKS {
        for rep in 0..n {
            let stride = if rep == 0 { s } else { 1 };
            idx += 1;
            let expanded = t * c_in;
            if t > 1 {
                layers.push(LayerSpec {
                    name: format!("block{idx}_expand"),
                    input_shape: vec![c_in, w, w],
                    op: LayerOp::Pointwise { out_channels: expanded },
                });
            }
            layers.push(LayerSpec {
                name: format!("block{idx}_dwise"),
                input_shape: vec![expanded, w + 2, w + 2], // 3×3/pad-1 halo
                op: LayerOp::Depthwise { r: 3, s: 3, stride },
            });
            w = (w + 2 - 3) / stride + 1;
            layers.push(LayerSpec {
                name: format!("block{idx}_project"),
                input_shape: vec![expanded, w, w],
                op: LayerOp::Pointwise { out_channels: c_out },
            });
            c_in = c_out;
        }
    }
    layers.push(LayerSpec {
        name: "conv_last".into(),
        input_shape: vec![c_in, w, w],
        op: LayerOp::Pointwise { out_channels: 1280 },
    });
    Network { name: "mobilenetv2".into(), layers }
}

/// Full VGG-16 conv trunk (Simonyan & Zisserman [3]): thirteen 3×3/pad-1
/// convs in five stages separated by 2×2/2 max pools. The classifier head is
/// not part of the fused-dataflow chain.
pub fn vgg16() -> Network {
    const STAGES: [(i64, usize); 5] = [(64, 2), (128, 2), (256, 3), (512, 3), (512, 3)];
    let mut layers = Vec::new();
    let mut c_in = 3i64;
    let mut w = 224i64;
    for (stage, &(c, n)) in STAGES.iter().enumerate() {
        for rep in 0..n {
            layers.push(LayerSpec {
                name: format!("conv{}_{}", stage + 1, rep + 1),
                input_shape: vec![c_in, w + 2, w + 2],
                op: LayerOp::Conv2d { out_channels: c, r: 3, s: 3, stride: 1 },
            });
            c_in = c;
        }
        layers.push(LayerSpec {
            name: format!("pool{}", stage + 1),
            input_shape: vec![c, w, w],
            op: LayerOp::MaxPool { k: 2, stride: 2 },
        });
        w /= 2;
    }
    Network { name: "vgg16".into(), layers }
}

/// One BERT encoder block (Devlin et al. [6]) from the existing attention
/// and FC pieces: `QKᵀ` scores, score·V attend, then the two FFN matmuls.
/// The attention→FFN boundary is a reshape (`[B,H,T,E] → [B·T, H·E]`), so
/// it is a mandatory cut — the partitioner can fuse within the attention
/// pair and within the FFN pair, but never across.
pub fn bert_encoder(batch: i64, heads: i64, tokens: i64, emb: i64) -> Network {
    let d_model = heads * emb;
    Network {
        name: format!("bert-encoder(b{batch},h{heads},t{tokens},e{emb})"),
        layers: vec![
            LayerSpec {
                name: "scores".into(),
                input_shape: vec![batch, heads, tokens, emb],
                op: LayerOp::AttentionScores { seq: tokens },
            },
            LayerSpec {
                name: "attend".into(),
                input_shape: vec![batch, heads, tokens, tokens],
                op: LayerOp::AttentionValues { emb },
            },
            LayerSpec {
                name: "ffn1".into(),
                input_shape: vec![batch * tokens, d_model],
                op: LayerOp::Fc { out_features: 4 * d_model },
            },
            LayerSpec {
                name: "ffn2".into(),
                input_shape: vec![batch * tokens, 4 * d_model],
                op: LayerOp::Fc { out_features: d_model },
            },
        ],
    }
}

#[cfg(test)]
mod tests;
