//! Whole-network workloads and fused-segment partitioning over a graph IR.
//!
//! LoopTree's case studies (paper §VI) evaluate one fusion set at a time,
//! but the decision the paper motivates — *which* layers to fuse, and where
//! to cut — is a network-level question (DNNFuser frames layer fusion as a
//! network-level mapping problem; CMDS shows cross-layer choices interact
//! across cuts). This module represents a whole DNN as a **DAG of layer
//! nodes** ([`Network`]): each [`LayerSpec`] carries a [`LayerOp`] plus
//! explicit input edges (`inputs`, indices of earlier nodes), so residual
//! adds, skip connections, and fan-outs are first-class. Any *convex* node
//! set with a single sink materializes as a [`FusionSet`] segment (via the
//! [`FusionSetBuilder`]), and [`search_network`] searches the mapspace of
//! every candidate segment and picks the optimal segment cover by dynamic
//! programming — over chain cut points when the graph is a path (the exact
//! PR 3 behavior), over graph cuts otherwise. [`search_network_pareto`]
//! generalizes the same DP from one scalar objective to dominance over
//! vector costs, emitting the whole-network latency/energy/capacity/
//! off-chip Pareto front (the paper's Figs 15-18 at network scale).
//!
//! ## Shape conventions
//!
//! Each [`LayerSpec`] carries the fmap shape its (primary) input has *in the
//! original padded network* (e.g. `[64, 58, 58]` for a 3×3/pad-1 conv on a
//! 56×56 fmap — the repo-wide halo convention of `einsum::workloads`).
//! Single-input edges tolerate spatial re-declaration (that is where the
//! padding halo returns); the explicit [`LayerOp::Pad`] op makes the halo an
//! exact per-edge fact instead. When a segment is materialized, the
//! [`FusionSetBuilder`] starts from each head node's declared input shape
//! and propagates *valid-convolution* semantics through interior edges:
//! fused interior layers see the un-padded shrunk fmap of their producer,
//! exactly as the fused pyramid of the paper's Fig 1 does.
//!
//! ## Multi-input ops and mandatory cuts
//!
//! * [`LayerOp::Add`] (residual merge) fuses: inside a segment it becomes an
//!   elementwise N-ary einsum; valid-convolution shrinkage between branches
//!   is reconciled by center-cropping larger operands to the common
//!   interior (even margins only).
//! * [`LayerOp::Concat`] is *virtual*: concatenation of DRAM-resident
//!   tensors is pure address arithmetic, so a concat node never joins a
//!   segment and costs nothing — all its edges are mandatory cuts.
//! * [`LayerOp::Pad`] fuses only at a segment head (the padded border is
//!   fetched as data, the existing halo convention); an interior pad is a
//!   mandatory cut.
//! * A boundary whose shapes are only reshape-compatible (equal element
//!   count, different arity — e.g. BERT's `[B,H,T,E] → [B·T, H·E]`
//!   attention→FFN boundary) is a mandatory cut, as in the chain IR.

mod pareto;
mod partition;
mod presets;

pub use pareto::{
    search_network_pareto, search_network_pareto_dag, search_network_pareto_memo,
    FrontSegmentMemo, NetworkParetoPoint, NetworkParetoResult, SegmentFrontPoint,
};
pub use partition::{
    evaluate_partition, evaluate_partition_memo, evaluate_segments, evaluate_segments_memo,
    search_network, search_network_dag, search_network_memo, NetworkSearchResult,
    NetworkSearchSpec, ScalarSegmentMemo, SegmentChoice,
};
pub use presets::{bert_encoder, mobilenet_v2, resnet18, resnet18_chain, vgg16};

use crate::einsum::{FusionSet, FusionSetBuilder, TensorId};

/// One layer's operator, mirroring the [`FusionSetBuilder`] vocabulary plus
/// the graph-only ops ([`LayerOp::Add`], [`LayerOp::Concat`],
/// [`LayerOp::Pad`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum LayerOp {
    /// 2D convolution (`[C,H,W] → [M,P,Q]`), valid padding.
    Conv2d { out_channels: i64, r: i64, s: i64, stride: i64 },
    /// 1×1 convolution (`[C,H,W] → [M,H,W]`).
    Pointwise { out_channels: i64 },
    /// Depthwise convolution (`[C,H,W] → [C,P,Q]`).
    Depthwise { r: i64, s: i64, stride: i64 },
    /// Max pooling (`[C,H,W] → [C,P,Q]`).
    MaxPool { k: i64, stride: i64 },
    /// Fully connected (`[M,D] → [M,E]`).
    Fc { out_features: i64 },
    /// Attention score matmul (`[B,H,M,E] → [B,H,M,N]`, `N = seq`).
    AttentionScores { seq: i64 },
    /// Attention value matmul (`[B,H,M,N] → [B,H,M,E]`, `E = emb`).
    AttentionValues { emb: i64 },
    /// Elementwise N-ary addition (residual/skip merge); all inputs share
    /// one shape.
    Add,
    /// Channel concatenation (`[C_i,H,W] → [ΣC_i,H,W]`). Virtual: modeled
    /// as DRAM address arithmetic, never fused.
    Concat,
    /// Explicit zero-padding halo (`[C,H,W] → [C,H+2h,W+2w]`), resolving
    /// the padding convention per edge instead of per chain position.
    Pad { h: i64, w: i64 },
}

impl LayerOp {
    /// Stable wire name (the JSON spec layer uses these).
    pub fn name(&self) -> &'static str {
        match self {
            LayerOp::Conv2d { .. } => "conv2d",
            LayerOp::Pointwise { .. } => "pointwise",
            LayerOp::Depthwise { .. } => "depthwise",
            LayerOp::MaxPool { .. } => "maxpool",
            LayerOp::Fc { .. } => "fc",
            LayerOp::AttentionScores { .. } => "attention_scores",
            LayerOp::AttentionValues { .. } => "attention_values",
            LayerOp::Add => "add",
            LayerOp::Concat => "concat",
            LayerOp::Pad { .. } => "pad",
        }
    }

    /// Canonical parameter string, e.g. `conv2d(64,3,3,2)` — one token of
    /// the canonical segment signature ([`Network::segment_signature`]).
    pub fn signature(&self) -> String {
        match self {
            LayerOp::Conv2d { out_channels, r, s, stride } => {
                format!("conv2d({out_channels},{r},{s},{stride})")
            }
            LayerOp::Pointwise { out_channels } => format!("pointwise({out_channels})"),
            LayerOp::Depthwise { r, s, stride } => format!("depthwise({r},{s},{stride})"),
            LayerOp::MaxPool { k, stride } => format!("maxpool({k},{stride})"),
            LayerOp::Fc { out_features } => format!("fc({out_features})"),
            LayerOp::AttentionScores { seq } => format!("attention_scores({seq})"),
            LayerOp::AttentionValues { emb } => format!("attention_values({emb})"),
            LayerOp::Add => "add".into(),
            LayerOp::Concat => "concat".into(),
            LayerOp::Pad { h, w } => format!("pad({h},{w})"),
        }
    }

    /// Allowed input-edge count `(min, max)`.
    pub fn arity(&self) -> (usize, usize) {
        match self {
            LayerOp::Add | LayerOp::Concat => (2, usize::MAX),
            _ => (1, 1),
        }
    }

    /// Virtual ops never join a fused segment and cost nothing on their own
    /// (concatenation of DRAM-resident tensors is address arithmetic).
    pub fn is_virtual(&self) -> bool {
        matches!(self, LayerOp::Concat)
    }

    /// The fmap shape this op produces from its input shapes (one per input
    /// edge), with valid-convolution semantics for windowed ops — mirrors
    /// the [`FusionSetBuilder`] math exactly, but returns an error where the
    /// builder would panic (arity mismatch or an empty output).
    pub fn output_shape(&self, inputs: &[&[i64]]) -> Result<Vec<i64>, String> {
        let (min_in, max_in) = self.arity();
        if inputs.len() < min_in || inputs.len() > max_in {
            return Err(format!(
                "{}: expected {} input(s), got {}",
                self.signature(),
                if min_in == max_in { min_in.to_string() } else { format!(">= {min_in}") },
                inputs.len()
            ));
        }
        // All op parameters must be positive (pad halos may be zero), or the
        // builder's fusion-set validation would panic downstream.
        let params = match self {
            LayerOp::Conv2d { out_channels, r, s, stride } => vec![*out_channels, *r, *s, *stride],
            LayerOp::Pointwise { out_channels } => vec![*out_channels],
            LayerOp::Depthwise { r, s, stride } => vec![*r, *s, *stride],
            LayerOp::MaxPool { k, stride } => vec![*k, *stride],
            LayerOp::Fc { out_features } => vec![*out_features],
            LayerOp::AttentionScores { seq } => vec![*seq],
            LayerOp::AttentionValues { emb } => vec![*emb],
            LayerOp::Add | LayerOp::Concat => vec![],
            LayerOp::Pad { h, w } => {
                if *h < 0 || *w < 0 {
                    return Err(format!("{}: negative pad halo", self.signature()));
                }
                vec![]
            }
        };
        if params.iter().any(|&p| p < 1) {
            return Err(format!("{}: all op parameters must be >= 1", self.signature()));
        }
        let spatial = |h: i64, w: i64, r: i64, s: i64, stride: i64| -> Result<(i64, i64), String> {
            let p = (h - r) / stride + 1;
            let q = (w - s) / stride + 1;
            if h < r || w < s || p < 1 || q < 1 {
                return Err(format!(
                    "{}: window {r}x{s} does not fit input {h}x{w}",
                    self.signature()
                ));
            }
            Ok((p, q))
        };
        let first = inputs[0];
        match (self, first) {
            (LayerOp::Conv2d { out_channels, r, s, stride }, [_, h, w]) => {
                let (p, q) = spatial(*h, *w, *r, *s, *stride)?;
                Ok(vec![*out_channels, p, q])
            }
            (LayerOp::Pointwise { out_channels }, [_, h, w]) => Ok(vec![*out_channels, *h, *w]),
            (LayerOp::Depthwise { r, s, stride }, [c, h, w]) => {
                let (p, q) = spatial(*h, *w, *r, *s, *stride)?;
                Ok(vec![*c, p, q])
            }
            (LayerOp::MaxPool { k, stride }, [c, h, w]) => {
                let (p, q) = spatial(*h, *w, *k, *k, *stride)?;
                Ok(vec![*c, p, q])
            }
            (LayerOp::Fc { out_features }, [m, _]) => Ok(vec![*m, *out_features]),
            (LayerOp::AttentionScores { seq }, [b, hd, m, _]) => Ok(vec![*b, *hd, *m, *seq]),
            (LayerOp::AttentionValues { emb }, [b, hd, m, _]) => Ok(vec![*b, *hd, *m, *emb]),
            (LayerOp::Add, _) => {
                for s in &inputs[1..] {
                    if *s != first {
                        return Err(format!(
                            "add: operand shapes differ ({first:?} vs {s:?})"
                        ));
                    }
                }
                Ok(first.to_vec())
            }
            (LayerOp::Concat, [_, _, _]) => {
                let mut channels = first[0];
                for s in &inputs[1..] {
                    if s.len() != 3 || s[1..] != first[1..] {
                        return Err(format!(
                            "concat: operand shapes incompatible ({first:?} vs {s:?})"
                        ));
                    }
                    channels += s[0];
                }
                let mut out = first.to_vec();
                out[0] = channels;
                Ok(out)
            }
            (LayerOp::Pad { h, w }, [c, ih, iw]) => Ok(vec![*c, ih + 2 * h, iw + 2 * w]),
            _ => Err(format!(
                "{}: input shape {:?} has the wrong arity",
                self.signature(),
                first
            )),
        }
    }

    /// Append this single-input compute op to a builder (shapes must already
    /// have been checked with [`LayerOp::output_shape`]; the builder panics
    /// on mismatches). `Add`, `Concat`, and `Pad` are wired by the segment
    /// materializer, not here.
    fn apply_unary(&self, b: &mut FusionSetBuilder) {
        match *self {
            LayerOp::Conv2d { out_channels, r, s, stride } => {
                b.conv2d(out_channels, r, s, stride);
            }
            LayerOp::Pointwise { out_channels } => {
                b.pointwise(out_channels);
            }
            LayerOp::Depthwise { r, s, stride } => {
                b.depthwise(r, s, stride);
            }
            LayerOp::MaxPool { k, stride } => {
                b.maxpool(k, stride);
            }
            LayerOp::Fc { out_features } => {
                b.fc(out_features);
            }
            LayerOp::AttentionScores { seq } => {
                b.attention_scores(seq);
            }
            LayerOp::AttentionValues { emb } => {
                b.attention_values(emb);
            }
            LayerOp::Add | LayerOp::Concat | LayerOp::Pad { .. } => {
                panic!("{} is not a unary builder op", self.name())
            }
        }
    }
}

/// One node of a [`Network`] DAG: a display name, the fmap shape its
/// *primary* (first) input has in the original padded network, its operator,
/// and explicit input edges.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayerSpec {
    /// Display name of the layer.
    pub name: String,
    /// Fmap shape of the primary input in the padded network.
    pub input_shape: Vec<i64>,
    /// The layer operator.
    pub op: LayerOp,
    /// Producing node indices, all smaller than this node's own index
    /// (networks are stored in topological order). Empty = this node
    /// consumes the network input.
    pub inputs: Vec<usize>,
}

/// A whole DNN as a DAG of layer nodes (the fused-segment partitioner's
/// input). Nodes are stored in topological order: every edge references an
/// earlier node.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Network {
    /// Display name of the network.
    pub name: String,
    /// Nodes in topological order.
    pub layers: Vec<LayerSpec>,
}

/// Where a segment-internal wire comes from: an off-chip external input (by
/// slot) or the output of an earlier materialized member (by local order).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Wire {
    Ext(usize),
    Member(usize),
}

/// A validated materialization plan for one candidate segment: the external
/// input tensors (deduplicated by producer and shape) and, per materialized
/// member, the resolved input wires. `segment_fusion_set` executes the plan;
/// `segment_signature` canonicalizes it.
#[derive(Debug, Clone)]
pub(crate) struct SegmentPlan {
    /// External input shapes, in first-use order. Keyed by (producer node or
    /// network input, shape as consumed): the same producer read through two
    /// different declared halos yields two streamed tensors.
    externals: Vec<Vec<i64>>,
    /// Per materialized (non-pad) member, in node order: (node index, input
    /// wires).
    members: Vec<(usize, Vec<Wire>)>,
    /// The sink's propagated output shape (valid-convolution semantics).
    out_shape: Vec<i64>,
}

impl Network {
    /// Number of layer nodes.
    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    /// Append a node consuming the previous node's output (the network input
    /// when the network is empty). Returns the node index.
    pub fn push(&mut self, name: &str, input_shape: &[i64], op: LayerOp) -> usize {
        let inputs = if self.layers.is_empty() { vec![] } else { vec![self.layers.len() - 1] };
        self.push_from(name, input_shape, op, inputs)
    }

    /// Append a node with explicit input edges. Returns the node index.
    pub fn push_from(
        &mut self,
        name: &str,
        input_shape: &[i64],
        op: LayerOp,
        inputs: Vec<usize>,
    ) -> usize {
        self.layers.push(LayerSpec {
            name: name.into(),
            input_shape: input_shape.to_vec(),
            op,
            inputs,
        });
        self.layers.len() - 1
    }

    /// Whether the graph is a pure path: node `i` consumes exactly node
    /// `i-1` (and node 0 the network input). Path networks take the chain
    /// cut-point DP in [`search_network`], reproducing the chain IR bit for
    /// bit.
    pub fn is_chain(&self) -> bool {
        self.layers.iter().enumerate().all(|(i, l)| {
            if i == 0 {
                l.inputs.is_empty()
            } else {
                l.inputs.as_slice() == [i - 1]
            }
        })
    }

    /// Consumers of each node (node indices listing it as an input, with
    /// multiplicity collapsed).
    pub(crate) fn consumer_lists(&self) -> Vec<Vec<usize>> {
        let mut out = vec![Vec::new(); self.layers.len()];
        for (i, l) in self.layers.iter().enumerate() {
            for &p in &l.inputs {
                if out[p].last() != Some(&i) {
                    out[p].push(i);
                }
            }
        }
        out
    }

    /// Reference (padded-network) output shape per node, performing every
    /// structural check along the way. This *is* the validator:
    /// [`Network::validate`] discards the shapes.
    pub(crate) fn ref_output_shapes(&self) -> Result<Vec<Vec<i64>>, String> {
        if self.layers.is_empty() {
            return Err(format!("network {} has no layers", self.name));
        }
        let mut out: Vec<Vec<i64>> = Vec::with_capacity(self.layers.len());
        for (i, l) in self.layers.iter().enumerate() {
            // Error context only; built lazily so the success path (run per
            // candidate-plan in the enumeration loop) never formats.
            let ctx = || format!("layer {i} '{}' (op {})", l.name, l.op.name());
            if l.input_shape.iter().any(|&d| d <= 0) {
                return Err(format!("{}: non-positive input dim in {:?}", ctx(), l.input_shape));
            }
            for &p in &l.inputs {
                if p >= i {
                    return Err(format!(
                        "{}: input edge {p} must reference an earlier node (topological order)",
                        ctx()
                    ));
                }
            }
            let (min_in, max_in) = l.op.arity();
            let n_in = if l.inputs.is_empty() { 1 } else { l.inputs.len() };
            if n_in < min_in || n_in > max_in {
                return Err(format!("{}: {n_in} input edge(s) out of the op's arity range", ctx()));
            }
            if l.inputs.is_empty() && min_in > 1 {
                return Err(format!("{}: a multi-input op cannot consume the network input", ctx()));
            }
            // Per-edge shape compatibility against each producer's reference
            // output.
            match &l.op {
                LayerOp::Add => {
                    for (k, &p) in l.inputs.iter().enumerate() {
                        if out[p] != l.input_shape {
                            return Err(format!(
                                "{}: operand {k} from '{}' has shape {:?}, expected {:?}",
                                ctx(),
                                self.layers[p].name,
                                out[p],
                                l.input_shape
                            ));
                        }
                    }
                }
                LayerOp::Concat => {
                    if out[l.inputs[0]] != l.input_shape {
                        return Err(format!(
                            "{}: declared input shape {:?} differs from first operand {:?}",
                            ctx(),
                            l.input_shape,
                            out[l.inputs[0]]
                        ));
                    }
                }
                LayerOp::Pad { .. } => {
                    // A pad may also pad the network input (no producer).
                    if let Some(&p) = l.inputs.first() {
                        if out[p] != l.input_shape {
                            return Err(format!(
                                "{}: pad input shape {:?} must exactly match producer '{}' \
                                 output {:?} (pad is the explicit halo)",
                                ctx(),
                                l.input_shape,
                                self.layers[p].name,
                                out[p]
                            ));
                        }
                    }
                }
                _ => {
                    if let Some(&p) = l.inputs.first() {
                        let prod = &out[p];
                        let nin = &l.input_shape;
                        if nin.len() == prod.len() {
                            // Same arity: non-spatial dims must match; the
                            // trailing two (spatial) dims of 3D fmaps may
                            // carry a halo.
                            let fixed = if prod.len() == 3 { 1 } else { prod.len() };
                            if prod[..fixed] != nin[..fixed] {
                                return Err(format!(
                                    "{}: shape mismatch with producer '{}' ({:?} vs {:?})",
                                    ctx(),
                                    self.layers[p].name,
                                    prod,
                                    nin
                                ));
                            }
                        } else {
                            // Arity change: a reshape boundary — sizes must
                            // agree.
                            let a: i64 = prod.iter().product();
                            let b: i64 = nin.iter().product();
                            if a != b {
                                return Err(format!(
                                    "{}: reshape from '{}' ({:?} -> {:?}) changes element count",
                                    ctx(),
                                    self.layers[p].name,
                                    prod,
                                    nin
                                ));
                            }
                        }
                    }
                }
            }
            // Output shape from the declared (unary) or producer
            // (multi-input) shapes.
            let shape = match &l.op {
                LayerOp::Add | LayerOp::Concat => {
                    let edges: Vec<&[i64]> =
                        l.inputs.iter().map(|&p| out[p].as_slice()).collect();
                    l.op.output_shape(&edges)
                }
                _ => l.op.output_shape(&[&l.input_shape]),
            }
            .map_err(|e| format!("{}: {e}", ctx()))?;
            out.push(shape);
        }
        Ok(out)
    }

    /// Check structural invariants: topological edge order, per-op edge
    /// arity, per-edge shape compatibility (non-spatial dims must match
    /// across single-input edges; spatial dims may be re-declared — the
    /// padding halo; arity changes are element-count-preserving reshapes;
    /// `add`/`pad` edges must match exactly), and that every op applies to
    /// its input shapes. Error messages name the offending layer index and
    /// op.
    pub fn validate(&self) -> Result<(), String> {
        self.ref_output_shapes().map(|_| ())
    }

    // --------------------------------------------------------- segments --

    /// Build the materialization plan for a candidate segment (sorted node
    /// indices). Errors describe why the node set cannot fuse: a virtual
    /// member, a non-convex set, multiple sinks, an interior output also
    /// needed outside, an interior pad, or shape propagation failure.
    pub(crate) fn segment_plan(&self, nodes: &[usize]) -> Result<SegmentPlan, String> {
        let n = self.layers.len();
        if nodes.is_empty() {
            return Err("segment has no nodes".into());
        }
        if nodes.windows(2).any(|w| w[0] >= w[1]) || *nodes.last().unwrap() >= n {
            return Err(format!("segment nodes {nodes:?} must be sorted, unique, and < {n}"));
        }
        let in_set = |i: usize| nodes.binary_search(&i).is_ok();
        for &i in nodes {
            if self.layers[i].op.is_virtual() {
                return Err(format!(
                    "'{}' is a {} node; it never joins a fused segment",
                    self.layers[i].name,
                    self.layers[i].op.name()
                ));
            }
        }
        // Convexity: no path may leave the set and re-enter. Mark
        // descendants of the set within the index range; an external
        // producer of a member must not be one.
        let lo = nodes[0];
        let hi = *nodes.last().unwrap();
        let mut desc = vec![false; hi - lo + 1];
        for i in lo..=hi {
            desc[i - lo] = in_set(i)
                || self.layers[i].inputs.iter().any(|&p| p >= lo && desc[p - lo]);
        }
        for &i in nodes {
            for &p in &self.layers[i].inputs {
                if !in_set(p) && p >= lo && desc[p - lo] {
                    return Err(format!(
                        "segment is not convex: excluded node '{}' is downstream of the segment \
                         but feeds its member '{}'",
                        self.layers[p].name, self.layers[i].name
                    ));
                }
            }
        }
        // Single sink; interior outputs fully consumed inside. The consumer
        // lists are per-network constants recomputed per plan — O(nodes)
        // small-vec work, dwarfed by the per-segment mapspace searches that
        // follow for every candidate that survives; revisit only if
        // enumeration itself ever shows up in BENCH_network.json.
        let consumers = self.consumer_lists();
        let mut sink = None;
        for &i in nodes {
            let cons_in = consumers[i].iter().any(|&c| in_set(c));
            let cons_out = consumers[i].iter().any(|&c| !in_set(c));
            if !cons_in {
                if let Some(prev) = sink.replace(i) {
                    return Err(format!(
                        "segment has more than one sink ('{}' and '{}')",
                        self.layers[prev].name, self.layers[i].name
                    ));
                }
            } else if cons_out {
                return Err(format!(
                    "output of '{}' is consumed both inside and outside the segment",
                    self.layers[i].name
                ));
            }
        }
        let sink = sink.ok_or_else(|| "segment has no sink (cycle?)".to_string())?;
        if matches!(self.layers[sink].op, LayerOp::Pad { .. }) {
            return Err(format!(
                "'{}' (pad) cannot be a segment sink; fuse it with its consumer",
                self.layers[sink].name
            ));
        }
        // Shape propagation with valid-convolution semantics, resolving
        // wires and external inputs as we go. Reference output shapes are
        // only needed for `add` operands cut off from the segment, and this
        // runs once per candidate in the enumeration hot loop — compute
        // them lazily.
        let mut ref_out: Option<Vec<Vec<i64>>> = None;
        type ExtKey = (Option<usize>, Vec<i64>);
        fn ext_slot(key: ExtKey, exts: &mut Vec<ExtKey>) -> usize {
            match exts.iter().position(|e| *e == key) {
                Some(k) => k,
                None => {
                    exts.push(key);
                    exts.len() - 1
                }
            }
        }
        let mut externals: Vec<ExtKey> = Vec::new();
        // Per member: its wire (how a consumer reaches its output) and its
        // propagated shape.
        let mut wire_of: Vec<Option<(Wire, Vec<i64>)>> = vec![None; hi - lo + 1];
        let mut members: Vec<(usize, Vec<Wire>)> = Vec::new();
        for &i in nodes {
            let l = &self.layers[i];
            let ctx = || format!("layer {i} '{}' (op {})", l.name, l.op.name());
            match &l.op {
                LayerOp::Pad { .. } => {
                    if l.inputs.iter().any(|&p| in_set(p)) {
                        return Err(format!(
                            "{}: explicit pad inside a fused segment — cut before it",
                            ctx()
                        ));
                    }
                    // Absorbed: the external input arrives pre-padded (the
                    // zero border streams as data, the halo convention).
                    let padded = l
                        .op
                        .output_shape(&[&l.input_shape])
                        .map_err(|e| format!("{}: {e}", ctx()))?;
                    let src = l.inputs.first().copied();
                    let k = ext_slot((src, padded.clone()), &mut externals);
                    wire_of[i - lo] = Some((Wire::Ext(k), padded));
                }
                LayerOp::Add => {
                    let mut wires = Vec::with_capacity(l.inputs.len());
                    let mut shapes: Vec<Vec<i64>> = Vec::with_capacity(l.inputs.len());
                    for &p in &l.inputs {
                        if in_set(p) {
                            let (w, s) = wire_of[p - lo].clone().expect("member resolved");
                            wires.push(w);
                            shapes.push(s);
                        } else {
                            if ref_out.is_none() {
                                ref_out = Some(self.ref_output_shapes()?);
                            }
                            let shape = ref_out.as_ref().unwrap()[p].clone();
                            let k = ext_slot((Some(p), shape.clone()), &mut externals);
                            wires.push(Wire::Ext(k));
                            shapes.push(shape);
                        }
                    }
                    // Center-crop reconciliation: the single authority is
                    // `einsum::residual_merge_shape`, which the builder's
                    // `add_residual` also consults — plan-time acceptance
                    // and build-time wiring cannot drift apart.
                    let operands: Vec<&[i64]> = shapes.iter().map(|s| s.as_slice()).collect();
                    let out_shape = crate::einsum::residual_merge_shape(&operands)
                        .map_err(|e| format!("{}: {e}", ctx()))?;
                    wire_of[i - lo] = Some((Wire::Member(members.len()), out_shape));
                    members.push((i, wires));
                }
                _ => {
                    // Single-input compute op: internal edges see the
                    // producer's shrunk (valid-conv) shape, head edges the
                    // declared (halo) shape.
                    let (wire, in_shape) = match l.inputs.first() {
                        Some(&p) if in_set(p) => {
                            wire_of[p - lo].clone().expect("member resolved")
                        }
                        src => {
                            let key = (src.copied(), l.input_shape.clone());
                            let k = ext_slot(key, &mut externals);
                            (Wire::Ext(k), l.input_shape.clone())
                        }
                    };
                    let out = l
                        .op
                        .output_shape(&[&in_shape])
                        .map_err(|e| format!("{}: {e}", ctx()))?;
                    wire_of[i - lo] = Some((Wire::Member(members.len()), out));
                    members.push((i, vec![wire]));
                }
            }
        }
        if members.is_empty() {
            return Err("segment contains only pad nodes; fuse them with a consumer".into());
        }
        let out_shape = wire_of[sink - lo].as_ref().expect("sink resolved").1.clone();
        Ok(SegmentPlan {
            externals: externals.into_iter().map(|(_, s)| s).collect(),
            members,
            out_shape,
        })
    }

    /// Whether the node set can be fused into one segment.
    pub fn segment_buildable_nodes(&self, nodes: &[usize]) -> bool {
        self.segment_plan(nodes).is_ok()
    }

    /// Whether layers `lo..hi` (a contiguous index range) can be fused.
    pub fn segment_buildable(&self, lo: usize, hi: usize) -> bool {
        if lo >= hi || hi > self.layers.len() {
            return false;
        }
        let nodes: Vec<usize> = (lo..hi).collect();
        self.segment_buildable_nodes(&nodes)
    }

    /// The sink's propagated output shape of a contiguous segment
    /// (valid-convolution semantics): what the fused pyramid actually
    /// produces, which shrinks relative to the padded reference network.
    pub fn propagate(&self, lo: usize, hi: usize) -> Result<Vec<i64>, String> {
        if lo >= hi || hi > self.layers.len() {
            return Err(format!("segment [{lo}..{hi}) out of range"));
        }
        let nodes: Vec<usize> = (lo..hi).collect();
        self.segment_plan(&nodes).map(|p| p.out_shape)
    }

    /// Materialize a node set as a [`FusionSet`]: members are emitted in
    /// topological order through the [`FusionSetBuilder`], with residual
    /// `add` nodes merging branches and external skip sources arriving as
    /// additional off-chip input fmaps.
    pub fn segment_fusion_set_nodes(&self, nodes: &[usize]) -> Result<FusionSet, String> {
        let plan = self
            .segment_plan(nodes)
            .map_err(|e| format!("{}{}: {e}", self.name, Self::nodes_label(nodes)))?;
        let mut b =
            FusionSetBuilder::new(&format!("{}{}", self.name, Self::nodes_label(nodes)), &plan.externals[0]);
        let mut ext_ids: Vec<TensorId> = vec![TensorId(0)];
        for shape in &plan.externals[1..] {
            ext_ids.push(b.external(shape));
        }
        let mut member_out: Vec<TensorId> = Vec::with_capacity(plan.members.len());
        for (i, wires) in &plan.members {
            let tensor = |w: &Wire| match *w {
                Wire::Ext(k) => ext_ids[k],
                Wire::Member(m) => member_out[m],
            };
            let l = &self.layers[*i];
            match &l.op {
                LayerOp::Add => {
                    let others: Vec<TensorId> = wires[1..].iter().map(tensor).collect();
                    b.select(tensor(&wires[0]));
                    b.add_residual(&others);
                }
                op => {
                    b.select(tensor(&wires[0]));
                    op.apply_unary(&mut b);
                }
            }
            member_out.push(b.cur());
        }
        Ok(b.build())
    }

    /// Materialize layers `lo..hi` as a [`FusionSet`].
    pub fn segment_fusion_set(&self, lo: usize, hi: usize) -> Result<FusionSet, String> {
        if lo >= hi || hi > self.layers.len() {
            return Err(format!("{}: segment [{lo}..{hi}) out of range", self.name));
        }
        let nodes: Vec<usize> = (lo..hi).collect();
        self.segment_fusion_set_nodes(&nodes)
    }

    /// Memoization key for a node set: a canonical graph hash. External
    /// input shapes are listed in first-use order and each materialized
    /// member records its op and input wires by local index, so the
    /// signature determines the built Einsums exactly (up to tensor names,
    /// which carry no model semantics) — two segments with equal signatures
    /// share one mapspace search. Repeated blocks — e.g. the identical
    /// stage-2 residual blocks of ResNet — collapse this way.
    pub fn segment_signature_nodes(&self, nodes: &[usize]) -> String {
        match self.segment_plan(nodes) {
            Ok(plan) => self.plan_signature(&plan),
            // Unbuildable sets never reach the memo table; key by identity.
            Err(_) => format!("unbuildable{nodes:?}"),
        }
    }

    /// Canonical signature of a materialization plan (see
    /// [`Network::segment_signature_nodes`]).
    pub(crate) fn plan_signature(&self, plan: &SegmentPlan) -> String {
        let exts: Vec<String> = plan.externals.iter().map(|s| format!("{s:?}")).collect();
        let local = |w: &Wire| match *w {
            Wire::Ext(k) => format!("e{k}"),
            Wire::Member(m) => format!("n{m}"),
        };
        let ops: Vec<String> = plan
            .members
            .iter()
            .map(|(i, wires)| {
                let ws: Vec<String> = wires.iter().map(local).collect();
                format!("{}<{}", self.layers[*i].op.signature(), ws.join(","))
            })
            .collect();
        format!("{}|{}", exts.join(";"), ops.join("+"))
    }

    /// Memoization key for the contiguous segment `lo..hi`.
    pub fn segment_signature(&self, lo: usize, hi: usize) -> String {
        let nodes: Vec<usize> = (lo..hi).collect();
        self.segment_signature_nodes(&nodes)
    }

    /// Human-readable span, e.g. `conv2_1a..conv2_1b`.
    pub fn span_name_nodes(&self, nodes: &[usize]) -> String {
        match nodes {
            [] => String::new(),
            [i] => self.layers[*i].name.clone(),
            _ => format!(
                "{}..{}",
                self.layers[nodes[0]].name,
                self.layers[*nodes.last().unwrap()].name
            ),
        }
    }

    /// Human-readable span of a contiguous segment.
    pub fn span_name(&self, lo: usize, hi: usize) -> String {
        let nodes: Vec<usize> = (lo..hi).collect();
        self.span_name_nodes(&nodes)
    }

    /// Compact label for a node set: `[lo..hi)` when contiguous, the node
    /// list otherwise.
    pub(crate) fn nodes_label(nodes: &[usize]) -> String {
        if nodes.is_empty() {
            return "{}".into();
        }
        let (lo, hi) = (nodes[0], *nodes.last().unwrap() + 1);
        if hi - lo == nodes.len() {
            format!("[{lo}..{hi})")
        } else {
            let list: Vec<String> = nodes.iter().map(|i| i.to_string()).collect();
            format!("{{{}}}", list.join(","))
        }
    }
}

#[cfg(test)]
mod tests;
