//! Fused-segment partitioning: per-segment mapspace search, memoized over
//! distinct segment shapes, plus dynamic programming over cut points.
//!
//! A partition of an `n`-layer [`Network`] is a set of cut points
//! `0 < c_1 < … < c_k < n` splitting the chain into contiguous fused
//! segments. Each segment is materialized as a
//! [`FusionSet`](crate::einsum::FusionSet) and searched with the ordinary
//! [`search::run`] machinery (one [`Evaluator`] session per *distinct*
//! segment shape — repeated blocks are searched once); the optimal cut set
//! then minimizes the sum of per-segment scores by DP over the chain.
//! Additive objectives (latency, energy, off-chip transfers) are exact; EDP
//! is the standard per-segment-sum proxy for sequentially executed
//! segments. Capacity-infeasible segments keep the
//! [`INFEASIBLE_PENALTY`](crate::search::Objective::INFEASIBLE_PENALTY)
//! from the inner search, so the DP prefers any feasible partition over an
//! infeasible one — the "under a GLB budget" constraint.
//!
//! Distinct segments fan out over the [`Coordinator`]; each per-segment
//! search runs serially inside its worker. Results are merged by segment
//! index, so the outcome is bit-identical for any worker count.

use crate::arch::Arch;
use crate::coordinator::Coordinator;
use crate::mapspace::MapSpaceConfig;
use crate::model::Evaluator;
use crate::search::{self, Scored, SearchSpec};
use std::collections::{HashMap, HashSet};
use super::Network;

/// A complete, serializable network-search request: how long segments may
/// get, and the per-segment mapspace search to run.
#[derive(Debug, Clone, PartialEq)]
pub struct NetworkSearchSpec {
    /// Longest fused segment considered (in layers). Bounds both the DP
    /// fan-in and the cost of the deepest per-segment searches.
    pub max_segment_layers: usize,
    /// The mapspace search run on every candidate segment. Its objective is
    /// also the DP's per-segment cost (summed across segments), and its
    /// seed makes the whole network search deterministic. Schedules naming
    /// ranks absent from a segment's last layer are dropped for that
    /// segment (rank names vary with segment depth); an empty remainder
    /// falls back to the auto-derived schedules.
    pub search: SearchSpec,
}

impl Default for NetworkSearchSpec {
    fn default() -> Self {
        NetworkSearchSpec {
            max_segment_layers: 3,
            // Whole networks search hundreds of segments, so the default
            // per-segment mapspace is deliberately coarse: uniform
            // retention and a few tile sizes over the auto-derived
            // schedules. Configs can override any of it.
            search: SearchSpec {
                mapspace: MapSpaceConfig {
                    uniform_retention: true,
                    tile_sizes: vec![2, 8, 32],
                    ..Default::default()
                },
                ..Default::default()
            },
        }
    }
}

/// One chosen segment of the optimal partition, with its search result.
#[derive(Debug, Clone)]
pub struct SegmentChoice {
    /// Layer range `[lo, hi)`.
    pub lo: usize,
    pub hi: usize,
    /// Human-readable span (first..last layer names).
    pub span: String,
    /// Memoization key; segments with equal signatures share one search.
    pub signature: String,
    /// Best mapping found for this segment.
    pub best: Scored,
}

/// Result of a network-level search: the optimal cut set and the per-segment
/// best mappings.
#[derive(Debug, Clone)]
pub struct NetworkSearchResult {
    /// Interior cut points (ascending, exclusive of 0 and n).
    pub cuts: Vec<usize>,
    /// The chosen segments, in chain order.
    pub segments: Vec<SegmentChoice>,
    /// Sum of per-segment best scores (the DP objective).
    pub total_score: f64,
    /// How many distinct segment shapes were actually searched.
    pub distinct_searched: usize,
    /// How many candidate segments the DP considered.
    pub candidate_segments: usize,
}

impl NetworkSearchResult {
    /// Total off-chip traffic across segments (elements).
    pub fn total_offchip(&self) -> i64 {
        self.segments.iter().map(|s| s.best.metrics.offchip_total()).sum()
    }

    /// Total latency across sequentially executed segments (cycles).
    pub fn total_latency(&self) -> i64 {
        self.segments.iter().map(|s| s.best.metrics.latency_cycles).sum()
    }

    /// Total energy across segments (pJ).
    pub fn total_energy_pj(&self) -> f64 {
        self.segments.iter().map(|s| s.best.metrics.energy.total_pj()).sum()
    }

    /// Whether every chosen segment fits the GLB budget.
    pub fn all_fit(&self) -> bool {
        self.segments.iter().all(|s| s.best.metrics.capacity_ok)
    }
}

/// Drop schedules naming ranks the segment's last layer does not have
/// (segment depth changes the rank-name suffix); an empty remainder falls
/// back to the auto-derived schedules.
fn mapspace_for_segment(base: &MapSpaceConfig, fs: &crate::einsum::FusionSet) -> MapSpaceConfig {
    if base.schedules.is_empty() {
        return base.clone();
    }
    let last = fs.last();
    let schedules: Vec<Vec<String>> = base
        .schedules
        .iter()
        .filter(|names| names.iter().all(|n| last.rank_index(n).is_some()))
        .cloned()
        .collect();
    MapSpaceConfig { schedules, ..base.clone() }
}

/// Search every distinct signature among `segments` once, in parallel, and
/// return the best `Scored` per signature. Segments whose search finds
/// nothing (or whose specs fail validation) map to `None`.
fn search_distinct(
    net: &Network,
    arch: &Arch,
    spec: &NetworkSearchSpec,
    segments: &[(usize, usize)],
    pool: &Coordinator,
) -> Result<HashMap<String, Option<Scored>>, String> {
    let mut order: Vec<(String, (usize, usize))> = Vec::new();
    let mut seen: HashSet<String> = HashSet::new();
    for &(lo, hi) in segments {
        let sig = net.segment_signature(lo, hi);
        if seen.insert(sig.clone()) {
            order.push((sig, (lo, hi)));
        }
    }
    // One Evaluator session per distinct shape; the inner search is serial
    // so the outer fan-out over distinct shapes owns all the parallelism.
    let results: Vec<Result<Option<Scored>, String>> = pool.run(order.len(), |i| {
        let (lo, hi) = order[i].1;
        let fs = net.segment_fusion_set(lo, hi)?;
        let ev = Evaluator::new(&fs, arch)?;
        let seg_spec = SearchSpec {
            mapspace: mapspace_for_segment(&spec.search.mapspace, &fs),
            ..spec.search.clone()
        };
        let inner = Coordinator::new(1);
        Ok(search::run(&ev, &seg_spec, &inner).map(|r| r.best))
    });
    let mut out = HashMap::new();
    for ((sig, _), res) in order.into_iter().zip(results) {
        out.insert(sig, res?);
    }
    Ok(out)
}

fn assemble(
    net: &Network,
    ranges: &[(usize, usize)],
    costs: &HashMap<String, Option<Scored>>,
    candidate_segments: usize,
) -> Result<NetworkSearchResult, String> {
    let mut segments = Vec::with_capacity(ranges.len());
    for &(lo, hi) in ranges {
        let sig = net.segment_signature(lo, hi);
        let best = costs
            .get(&sig)
            .and_then(|o| o.clone())
            .ok_or_else(|| format!("segment {} found no mapping", net.span_name(lo, hi)))?;
        segments.push(SegmentChoice {
            lo,
            hi,
            span: net.span_name(lo, hi),
            signature: sig,
            best,
        });
    }
    let total_score = segments.iter().map(|s| s.best.score).sum();
    Ok(NetworkSearchResult {
        cuts: ranges.iter().skip(1).map(|&(lo, _)| lo).collect(),
        segments,
        total_score,
        distinct_searched: costs.len(),
        candidate_segments,
    })
}

/// Find the optimal contiguous fused-segment partition of `net` under
/// `spec`, minimizing the sum of per-segment best scores.
///
/// Deterministic given (network, architecture, spec) for any worker count.
pub fn search_network(
    net: &Network,
    arch: &Arch,
    spec: &NetworkSearchSpec,
    pool: &Coordinator,
) -> Result<NetworkSearchResult, String> {
    net.validate()?;
    if spec.max_segment_layers == 0 {
        return Err("max_segment_layers must be >= 1".into());
    }
    let n = net.num_layers();
    // Candidate segments: every buildable [lo, hi) up to the length cap.
    let mut candidates: Vec<(usize, usize)> = Vec::new();
    for lo in 0..n {
        for hi in (lo + 1)..=(lo + spec.max_segment_layers).min(n) {
            if net.segment_buildable(lo, hi) {
                candidates.push((lo, hi));
            }
        }
    }
    let costs = search_distinct(net, arch, spec, &candidates, pool)?;

    // DP over prefix lengths: best[j] = min over candidate (lo, j) of
    // best[lo] + cost(lo, j). Ties resolve to the smallest lo (longest
    // final segment), making the cut set deterministic.
    let mut best = vec![f64::INFINITY; n + 1];
    let mut back: Vec<Option<usize>> = vec![None; n + 1];
    best[0] = 0.0;
    for &(lo, hi) in &candidates {
        let Some(scored) = costs.get(&net.segment_signature(lo, hi)).and_then(|o| o.as_ref())
        else {
            continue; // segment search found nothing: unusable
        };
        let total = best[lo] + scored.score;
        if total < best[hi] {
            best[hi] = total;
            back[hi] = Some(lo);
        }
    }
    if best[n].is_infinite() {
        return Err(format!(
            "no feasible partition of {} (every covering segment's search came up empty)",
            net.name
        ));
    }
    // Reconstruct the chosen ranges.
    let mut ranges = Vec::new();
    let mut hi = n;
    while hi > 0 {
        let lo = back[hi].expect("DP backpointer chain broken");
        ranges.push((lo, hi));
        hi = lo;
    }
    ranges.reverse();
    assemble(net, &ranges, &costs, candidates.len())
}

/// Score a *given* partition (cut points, ascending, interior) of `net`:
/// the per-segment searches run exactly as in [`search_network`], but the
/// cut set is fixed. Errors if a cut is out of range or a forced segment is
/// unbuildable (e.g. the user failed to cut at a reshape boundary).
pub fn evaluate_partition(
    net: &Network,
    arch: &Arch,
    spec: &NetworkSearchSpec,
    cuts: &[usize],
    pool: &Coordinator,
) -> Result<NetworkSearchResult, String> {
    net.validate()?;
    let n = net.num_layers();
    let mut bounds = Vec::with_capacity(cuts.len() + 2);
    bounds.push(0);
    for &c in cuts {
        if c == 0 || c >= n {
            return Err(format!("cut {c} out of range (0, {n})"));
        }
        if let Some(&prev) = bounds.last() {
            if c <= prev {
                return Err(format!("cuts must be strictly ascending (saw {c} after {prev})"));
            }
        }
        bounds.push(c);
    }
    bounds.push(n);
    let ranges: Vec<(usize, usize)> =
        bounds.windows(2).map(|w| (w[0], w[1])).collect();
    for &(lo, hi) in &ranges {
        if !net.segment_buildable(lo, hi) {
            return Err(format!(
                "segment {} is not fusable (missing a mandatory cut?)",
                net.span_name(lo, hi)
            ));
        }
    }
    let costs = search_distinct(net, arch, spec, &ranges, pool)?;
    let nranges = ranges.len();
    assemble(net, &ranges, &costs, nranges)
}
