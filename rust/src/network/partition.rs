//! Fused-segment partitioning: per-segment mapspace search, memoized over
//! distinct segment shapes, plus dynamic programming over cuts.
//!
//! A partition of a [`Network`] covers its (non-virtual) nodes with disjoint
//! fusable segments — convex single-sink node sets (see
//! [`Network::segment_plan`]). Each segment is materialized as a
//! [`FusionSet`](crate::einsum::FusionSet) and searched with the ordinary
//! [`search::run`] machinery (one [`Evaluator`] session per *distinct*
//! segment signature — repeated blocks are searched once); the optimal
//! cover then minimizes the sum of per-segment scores by dynamic
//! programming. Path-shaped networks take the chain DP over cut points —
//! the exact pre-graph-IR behavior, bit for bit; general DAGs take a DP
//! over the ideal lattice of the graph (frontier-based over the
//! topological order), where a state is the set of already-covered nodes
//! and a transition applies one candidate segment whose external producers
//! are all covered. Additive objectives (latency, energy, off-chip
//! transfers) are exact; EDP is the standard per-segment-sum proxy for
//! sequentially executed segments. Capacity-infeasible segments keep the
//! [`INFEASIBLE_PENALTY`](crate::search::Objective::INFEASIBLE_PENALTY)
//! from the inner search, so the DP prefers any feasible partition over an
//! infeasible one — the "under a GLB budget" constraint.
//!
//! Before any search runs, candidates whose closed-form capacity floor
//! ([`crate::analysis::segment_floors`]) already exceeds the GLB budget are
//! statically pruned — skipped without a mapspace search — under a lossless
//! guard (see `run_scalar_dp`): the survivor optimum must strictly beat
//! every pruned floor, else everything is re-searched. Results are
//! bit-identical with pruning on ([`SearchSpec::prune`]) or off;
//! [`NetworkSearchResult::candidates_pruned`] reports the savings.
//!
//! Distinct segments fan out over the [`Coordinator`]; each per-segment
//! search runs serially inside its worker. Results are merged by segment
//! index, so the outcome is bit-identical for any worker count.

use super::Network;
use crate::arch::Arch;
use crate::coordinator::Coordinator;
use crate::mapspace::MapSpaceConfig;
use crate::model::Evaluator;
use crate::search::{self, Objective, Scored, SearchSpec};
use crate::util::json::Json;
use std::collections::{BTreeMap, HashMap, HashSet};

/// A complete, serializable network-search request: how large segments may
/// get, and the per-segment mapspace search to run.
#[derive(Debug, Clone, PartialEq)]
pub struct NetworkSearchSpec {
    /// Largest fused segment considered (in nodes). Bounds both the DP
    /// fan-in and the cost of the deepest per-segment searches.
    pub max_segment_layers: usize,
    /// The mapspace search run on every candidate segment. Its objective is
    /// also the DP's per-segment cost (summed across segments), and its
    /// seed makes the whole network search deterministic. Schedules naming
    /// ranks absent from a segment's sink layer are dropped for that
    /// segment (rank names vary with segment depth); an empty remainder
    /// falls back to the auto-derived schedules.
    pub search: SearchSpec,
    /// The cost axes of [`search_network_pareto`](super::search_network_pareto)
    /// (ignored by the scalar DP). Each axis is scored like a scalar run
    /// with that objective — including `search.penalize_infeasible` — so
    /// every single-objective scalar optimum lies on the emitted front.
    pub objectives: Vec<Objective>,
    /// Beam cap on every Pareto set the front DP carries (per DP state and
    /// per memoized segment front). `0` = unbounded (exact front). Capping
    /// keeps each per-axis minimum — single-objective optima survive — and
    /// thins the interior of large fronts deterministically.
    pub max_front_per_state: usize,
}

impl Default for NetworkSearchSpec {
    fn default() -> Self {
        NetworkSearchSpec {
            max_segment_layers: 3,
            // Whole networks search hundreds of segments, so the default
            // per-segment mapspace is deliberately coarse: uniform
            // retention and a few tile sizes over the auto-derived
            // schedules. Configs can override any of it.
            search: SearchSpec {
                mapspace: MapSpaceConfig {
                    uniform_retention: true,
                    tile_sizes: vec![2, 8, 32],
                    ..Default::default()
                },
                ..Default::default()
            },
            // The paper's trade-off axes (Figs 15-18 at network scale).
            objectives: vec![
                Objective::Latency,
                Objective::Energy,
                Objective::Capacity,
                Objective::Offchip,
            ],
            max_front_per_state: 0,
        }
    }
}

/// One chosen segment of the optimal partition, with its search result.
#[derive(Debug, Clone)]
pub struct SegmentChoice {
    /// Sorted member node indices.
    pub nodes: Vec<usize>,
    /// Smallest member index (segment start for contiguous segments).
    pub lo: usize,
    /// Largest member index + 1 (segment end for contiguous segments).
    pub hi: usize,
    /// Human-readable span (first..last layer names).
    pub span: String,
    /// Memoization key; segments with equal signatures share one search.
    pub signature: String,
    /// Best mapping found for this segment.
    pub best: Scored,
}

impl SegmentChoice {
    /// Whether the member indices form the contiguous range `[lo, hi)`.
    pub fn is_contiguous(&self) -> bool {
        self.hi - self.lo == self.nodes.len()
    }

    /// Compact label: `[lo..hi)` when contiguous, the node list otherwise.
    pub fn range_label(&self) -> String {
        Network::nodes_label(&self.nodes)
    }

    /// Whether this segment fuses across a branch point: it contains a
    /// multi-input (residual `add`) node together with at least one of the
    /// layers feeding it — the merge actually happens on-chip. A segment
    /// whose head is an add with all operands external does not count.
    pub fn spans_branch(&self, net: &Network) -> bool {
        self.nodes.iter().any(|&i| {
            net.layers[i].inputs.len() > 1
                && net.layers[i]
                    .inputs
                    .iter()
                    .any(|p| self.nodes.binary_search(p).is_ok())
        })
    }
}

/// Result of a network-level search: the optimal segment cover and the
/// per-segment best mappings.
#[derive(Debug, Clone)]
pub struct NetworkSearchResult {
    /// Interior segment boundaries: the start index of every segment but
    /// the first (for path networks, exactly the chain cut points).
    pub cuts: Vec<usize>,
    /// The chosen segments, ordered by their largest node index.
    pub segments: Vec<SegmentChoice>,
    /// Sum of per-segment best scores (the DP objective).
    pub total_score: f64,
    /// How many distinct segment signatures were actually searched.
    pub distinct_searched: usize,
    /// How many candidate segments the DP considered.
    pub candidate_segments: usize,
    /// How many candidate segments were skipped without a search because
    /// their closed-form capacity floor already exceeds the GLB budget
    /// (see [`crate::analysis::segment_floors`]). `0` whenever the
    /// lossless guard forced the re-evaluate fallback, so a nonzero count
    /// certifies the pruned run — the result itself is bit-identical with
    /// pruning on or off either way.
    pub candidates_pruned: usize,
}

impl NetworkSearchResult {
    /// Total off-chip traffic across segments (elements).
    pub fn total_offchip(&self) -> i64 {
        self.segments.iter().map(|s| s.best.metrics.offchip_total()).sum()
    }

    /// Total latency across sequentially executed segments (cycles).
    pub fn total_latency(&self) -> i64 {
        self.segments.iter().map(|s| s.best.metrics.latency_cycles).sum()
    }

    /// Total energy across segments (pJ).
    pub fn total_energy_pj(&self) -> f64 {
        self.segments.iter().map(|s| s.best.metrics.energy.total_pj()).sum()
    }

    /// Whether every chosen segment fits the GLB budget.
    pub fn all_fit(&self) -> bool {
        self.segments.iter().all(|s| s.best.metrics.capacity_ok)
    }

    /// How many chosen segments' best mappings evaluated entirely on the
    /// tier-1 symbolic box walk (see
    /// [`Metrics::path`](crate::model::Metrics)).
    pub fn symbolic_segments(&self) -> usize {
        self.segments
            .iter()
            .filter(|s| s.best.metrics.path.symbolic)
            .count()
    }

    /// One row of `BENCH_network.json`. The bench binary and the schema
    /// test both build rows through this method, so the CI artifact cannot
    /// silently drift from `util::bench::check_network_bench_schema`.
    pub fn bench_row(&self, workload: &str, layers: usize, mean_ns: f64) -> Json {
        Json::Obj(
            [
                ("workload".to_string(), Json::Str(workload.to_string())),
                ("mean_ns".to_string(), Json::Num(mean_ns)),
                ("layers".to_string(), Json::Num(layers as f64)),
                ("cuts".to_string(), Json::Num(self.cuts.len() as f64)),
                (
                    "candidate_segments".to_string(),
                    Json::Num(self.candidate_segments as f64),
                ),
                (
                    "distinct_searched".to_string(),
                    Json::Num(self.distinct_searched as f64),
                ),
                ("total_score".to_string(), Json::Num(self.total_score)),
                (
                    "total_offchip_elems".to_string(),
                    Json::Num(self.total_offchip() as f64),
                ),
                (
                    "symbolic_segments".to_string(),
                    Json::Num(self.symbolic_segments() as f64),
                ),
                (
                    "candidates_pruned".to_string(),
                    Json::Num(self.candidates_pruned as f64),
                ),
                ("all_fit".to_string(), Json::Bool(self.all_fit())),
            ]
            .into_iter()
            .collect(),
        )
    }
}

/// A candidate segment with its precomputed signature — computed once per
/// candidate, so neither the memo table nor the DP inner loop rebuilds
/// signature or span strings.
#[derive(Debug, Clone)]
pub(crate) struct Candidate {
    pub(crate) nodes: Vec<usize>,
    pub(crate) signature: String,
}

/// An external memo for per-segment scalar search results, letting callers
/// (the serve-mode [`SegmentCache`](crate::serve::SegmentCache)) reuse
/// segment searches *across* top-level requests. The in-request
/// deduplication over equal signatures is unchanged; the memo is consulted
/// once per distinct signature, during the serial pre-pass before the
/// parallel fan-out, so lookup/store ordering is deterministic for any
/// worker count.
///
/// Contract: `lookup` must only return values previously passed to `store`
/// under the same signature *and* the same (architecture, search-spec)
/// context — the caller owns context keying. Per-segment searches are
/// deterministic functions of (signature, arch, spec), so a conforming memo
/// never changes any result, only whether the search re-runs.
/// `Some(None)` records a search that found no feasible mapping.
pub trait ScalarSegmentMemo {
    /// Cached scalar result for `signature`, or `None` on a miss.
    fn lookup_scalar(&self, signature: &str) -> Option<Option<Scored>>;
    /// Record the freshly searched scalar result for `signature`.
    fn store_scalar(&self, signature: &str, value: &Option<Scored>);
}

/// Drop schedules naming ranks the segment's sink layer does not have
/// (segment depth changes the rank-name suffix); an empty remainder falls
/// back to the auto-derived schedules.
fn mapspace_for_segment(base: &MapSpaceConfig, fs: &crate::einsum::FusionSet) -> MapSpaceConfig {
    if base.schedules.is_empty() {
        return base.clone();
    }
    let last = fs.last();
    let schedules: Vec<Vec<String>> = base
        .schedules
        .iter()
        .filter(|names| names.iter().all(|n| last.rank_index(n).is_some()))
        .cloned()
        .collect();
    MapSpaceConfig { schedules, ..base.clone() }
}

/// Search every distinct signature among `candidates` once, in parallel,
/// and return the best `Scored` per signature. Segments whose search finds
/// nothing (or whose specs fail validation) map to `None`. Signatures the
/// `memo` already holds are not re-searched; fresh results are stored back.
fn search_distinct(
    net: &Network,
    arch: &Arch,
    spec: &NetworkSearchSpec,
    candidates: &[Candidate],
    pool: &Coordinator,
    memo: Option<&dyn ScalarSegmentMemo>,
) -> Result<HashMap<String, Option<Scored>>, String> {
    search_distinct_map(
        net,
        arch,
        spec,
        candidates,
        pool,
        |r| r.best,
        |sig| memo.and_then(|m| m.lookup_scalar(sig)),
        |sig, v| {
            if let Some(m) = memo {
                m.store_scalar(sig, v);
            }
        },
    )
}

/// The shared memoized per-segment fan-out: search every distinct signature
/// among `candidates` once, in parallel, and keep `map(result)` per
/// signature — the best `Scored` for the scalar DP, a pruned Pareto front
/// for the front DP. Segments whose search finds nothing (or whose specs
/// fail validation) map to `None`.
///
/// `lookup`/`store` bridge to an optional cross-request memo: both run in
/// the serial pre-/post-pass (never inside `pool.run`), so memo traffic is
/// deterministic — one lookup per distinct signature in candidate order,
/// then one store per freshly searched signature in the same order.
#[allow(clippy::too_many_arguments)]
pub(crate) fn search_distinct_map<T: Send>(
    net: &Network,
    arch: &Arch,
    spec: &NetworkSearchSpec,
    candidates: &[Candidate],
    pool: &Coordinator,
    map: impl Fn(search::SearchResult) -> T + Sync,
    lookup: impl Fn(&str) -> Option<Option<T>>,
    store: impl Fn(&str, &Option<T>),
) -> Result<HashMap<String, Option<T>>, String> {
    let mut order: Vec<(&str, &[usize])> = Vec::new();
    let mut seen: HashSet<&str> = HashSet::new();
    let mut out: HashMap<String, Option<T>> = HashMap::new();
    for c in candidates {
        if seen.insert(c.signature.as_str()) {
            match lookup(c.signature.as_str()) {
                Some(cached) => {
                    out.insert(c.signature.clone(), cached);
                }
                None => order.push((c.signature.as_str(), c.nodes.as_slice())),
            }
        }
    }
    // One Evaluator session per distinct shape; the inner search is serial
    // so the outer fan-out over distinct shapes owns all the parallelism.
    let results: Vec<Result<Option<T>, String>> = pool.run(order.len(), |i| {
        let fs = net.segment_fusion_set_nodes(order[i].1)?;
        let ev = Evaluator::new(&fs, arch)?;
        let seg_spec = SearchSpec {
            mapspace: mapspace_for_segment(&spec.search.mapspace, &fs),
            ..spec.search.clone()
        };
        let inner = Coordinator::new(1);
        Ok(search::run(&ev, &seg_spec, &inner).map(&map))
    });
    for ((sig, _), res) in order.into_iter().zip(results) {
        let v = res?;
        store(sig, &v);
        out.insert(sig.to_string(), v);
    }
    Ok(out)
}

fn assemble(
    net: &Network,
    mut chosen: Vec<Candidate>,
    costs: &HashMap<String, Option<Scored>>,
    candidate_segments: usize,
    candidates_pruned: usize,
) -> Result<NetworkSearchResult, String> {
    // Present segments in topological order of their sinks.
    chosen.sort_by_key(|c| *c.nodes.last().unwrap());
    let mut segments = Vec::with_capacity(chosen.len());
    for c in chosen {
        let best = costs
            .get(&c.signature)
            .and_then(|o| o.clone())
            .ok_or_else(|| {
                format!("segment {} found no mapping", net.span_name_nodes(&c.nodes))
            })?;
        segments.push(SegmentChoice {
            lo: c.nodes[0],
            hi: *c.nodes.last().unwrap() + 1,
            span: net.span_name_nodes(&c.nodes),
            signature: c.signature,
            best,
            nodes: c.nodes,
        });
    }
    let total_score = segments.iter().map(|s| s.best.score).sum();
    Ok(NetworkSearchResult {
        cuts: segments.iter().skip(1).map(|s| s.lo).collect(),
        segments,
        total_score,
        distinct_searched: costs.len(),
        candidate_segments,
        candidates_pruned,
    })
}

// ------------------------------------------- static candidate pruning --

/// Partition `candidates` into search survivors and statically-pruned
/// candidates, memoizing [`crate::analysis::segment_floors`] per signature
/// (equal signatures build identical einsums, so they share one floor). A
/// candidate is pruned exactly when every mapping of it is provably
/// GLB-infeasible; `floor(f)` — the scalar score floor here, the per-axis
/// cost floor vector in the Pareto DP — rides along for the lossless
/// guard. Candidates whose floors cannot be computed are kept. Relative
/// enumeration order is preserved within each part, keeping every DP
/// tie-break stable.
pub(crate) fn static_prune<T: Clone>(
    net: &Network,
    arch: &Arch,
    candidates: &[Candidate],
    floor: impl Fn(&crate::analysis::SegmentFloors) -> T,
) -> (Vec<Candidate>, Vec<Candidate>, Vec<T>) {
    let mut floor_of: HashMap<&str, Option<T>> = HashMap::new();
    let mut survivors = Vec::new();
    let mut pruned = Vec::new();
    let mut floors = Vec::new();
    for c in candidates {
        let fl = floor_of.entry(c.signature.as_str()).or_insert_with(|| {
            match crate::analysis::segment_floors(net, arch, &c.nodes) {
                Ok(f) if f.provably_infeasible(arch) => Some(floor(&f)),
                _ => None,
            }
        });
        match fl {
            Some(f) => {
                pruned.push(c.clone());
                floors.push(f.clone());
            }
            None => survivors.push(c.clone()),
        }
    }
    (survivors, pruned, floors)
}

/// The shared scalar search-and-DP driver behind [`search_network`] (chain
/// arm) and [`search_network_dag`]: search every distinct candidate shape,
/// run `dp` over the candidates, assemble the result — with provably
/// lossless static candidate pruning when the spec allows it.
///
/// Pruning discipline (the network-scale analogue of the search pruner's
/// `score_all_pruned`): candidates whose closed-form capacity floor exceeds
/// the GLB are skipped and the DP runs over the survivors. The survivor
/// optimum `T` is accepted only when `T` strictly beats every pruned
/// candidate's score floor — then any cover using a pruned candidate would
/// total at least that floor (scores are nonnegative, so a partial sum
/// already exceeding `T` can never come back down), the winning backpointer
/// chain is survivor-only, and candidate enumeration order is preserved
/// among survivors, so the first-strict-minimum tie-breaks match: the
/// result is bit-identical to the unpruned run. When the guard fails (or no
/// survivor cover exists), the pruned shapes are searched after all and the
/// DP reruns over the full candidate set — per-signature searches are
/// independent and deterministic, so the fallback, too, is bit-identical to
/// a run with pruning disabled (it reports `candidates_pruned: 0`).
fn run_scalar_dp(
    net: &Network,
    arch: &Arch,
    spec: &NetworkSearchSpec,
    candidates: Vec<Candidate>,
    pool: &Coordinator,
    memo: Option<&dyn ScalarSegmentMemo>,
    dp: fn(
        &Network,
        &[Candidate],
        &HashMap<String, Option<Scored>>,
    ) -> Result<Vec<Candidate>, String>,
) -> Result<NetworkSearchResult, String> {
    // Same gate as the mapping-level pruner: pruning needs the penalty (or
    // FeasibleEdp's built-in one) for the floor to bound the score, and a
    // GLB capacity to be infeasible against.
    let prunable = spec.search.prune
        && (spec.search.penalize_infeasible || spec.search.objective == Objective::FeasibleEdp)
        && arch.glb_capacity().is_some();
    if prunable {
        let (survivors, pruned, floors) =
            static_prune(net, arch, &candidates, |f| f.floor_score(&spec.search));
        if !pruned.is_empty() && !survivors.is_empty() {
            let mut costs = search_distinct(net, arch, spec, &survivors, pool, memo)?;
            let min_floor = floors.iter().fold(f64::INFINITY, |a, &b| a.min(b));
            if let Ok(chosen) = dp(net, &survivors, &costs) {
                let total: f64 = chosen
                    .iter()
                    .map(|c| {
                        costs
                            .get(&c.signature)
                            .and_then(|o| o.as_ref())
                            .map_or(f64::INFINITY, |s| s.score)
                    })
                    .sum();
                if total.total_cmp(&min_floor) == std::cmp::Ordering::Less {
                    return assemble(net, chosen, &costs, candidates.len(), pruned.len());
                }
            }
            // Lossless-guard fallback: a pruned candidate could still
            // matter. Search the pruned shapes too (their signatures are
            // disjoint from the survivors') and rerun over everything.
            costs.extend(search_distinct(net, arch, spec, &pruned, pool, memo)?);
            let chosen = dp(net, &candidates, &costs)?;
            return assemble(net, chosen, &costs, candidates.len(), 0);
        }
    }
    let costs = search_distinct(net, arch, spec, &candidates, pool, memo)?;
    let chosen = dp(net, &candidates, &costs)?;
    let n = candidates.len();
    assemble(net, chosen, &costs, n, 0)
}

// ------------------------------------------------------ chain (path) DP --

/// Candidate segments of a path network: every buildable contiguous range
/// `[lo, hi)` up to the length cap, in `(lo asc, hi asc)` order — the cut
/// enumeration and DP of the chain IR, preserved exactly.
pub(crate) fn chain_candidates(net: &Network, max_seg: usize) -> Vec<Candidate> {
    let n = net.num_layers();
    let mut candidates = Vec::new();
    for lo in 0..n {
        for hi in (lo + 1)..=(lo + max_seg).min(n) {
            let nodes: Vec<usize> = (lo..hi).collect();
            if let Ok(plan) = net.segment_plan(&nodes) {
                candidates.push(Candidate { signature: net.plan_signature(&plan), nodes });
            }
        }
    }
    candidates
}

fn chain_dp(
    net: &Network,
    candidates: &[Candidate],
    costs: &HashMap<String, Option<Scored>>,
) -> Result<Vec<Candidate>, String> {
    let n = net.num_layers();
    // DP over prefix lengths: best[j] = min over candidate (lo, j) of
    // best[lo] + cost(lo, j). Ties resolve to the smallest lo (longest
    // final segment), making the cut set deterministic.
    let mut best = vec![f64::INFINITY; n + 1];
    let mut back: Vec<Option<usize>> = vec![None; n + 1];
    best[0] = 0.0;
    for (ci, c) in candidates.iter().enumerate() {
        let Some(scored) = costs.get(&c.signature).and_then(|o| o.as_ref()) else {
            continue; // segment search found nothing: unusable
        };
        let (lo, hi) = (c.nodes[0], *c.nodes.last().unwrap() + 1);
        let total = best[lo] + scored.score;
        if total < best[hi] {
            best[hi] = total;
            back[hi] = Some(ci);
        }
    }
    if best[n].is_infinite() {
        return Err(format!(
            "no feasible partition of {} (every covering segment's search came up empty)",
            net.name
        ));
    }
    // Reconstruct the chosen ranges.
    let mut chosen = Vec::new();
    let mut hi = n;
    while hi > 0 {
        let ci = back[hi].expect("DP backpointer chain broken");
        chosen.push(candidates[ci].clone());
        hi = candidates[ci].nodes[0];
    }
    Ok(chosen)
}

// ------------------------------------------------------- graph-cut DP --

/// Bit positions of the non-virtual (coverable) nodes. Virtual nodes
/// (concat) are pure DRAM address arithmetic: they belong to no segment and
/// cost nothing.
pub(crate) fn real_positions(net: &Network) -> Result<Vec<Option<usize>>, String> {
    let mut pos = vec![None; net.num_layers()];
    let mut next = 0usize;
    for (i, l) in net.layers.iter().enumerate() {
        if !l.op.is_virtual() {
            pos[i] = Some(next);
            next += 1;
        }
    }
    if next > 128 {
        return Err(format!(
            "graph DP supports up to 128 coverable nodes, network has {next}"
        ));
    }
    Ok(pos)
}

/// The non-virtual ancestors a node exposes when used as a segment input:
/// itself when non-virtual, else the closure of its producers (virtual
/// nodes pass through).
pub(crate) fn nonvirtual_closure(net: &Network, pos: &[Option<usize>]) -> Vec<u128> {
    let mut closure = vec![0u128; net.num_layers()];
    for (i, l) in net.layers.iter().enumerate() {
        closure[i] = match pos[i] {
            Some(b) => 1u128 << b,
            None => l.inputs.iter().map(|&p| closure[p]).fold(0, |a, c| a | c),
        };
    }
    closure
}

/// Candidate segments of a general DAG: for every non-virtual sink,
/// subsets of its non-virtual ancestors within `max_seg - 1` hops, filtered
/// to fusable plans. Every fusable segment arises exactly once (at its
/// unique sink).
pub(crate) fn dag_candidates(net: &Network, max_seg: usize) -> Result<Vec<Candidate>, String> {
    let n = net.num_layers();
    let mut candidates = Vec::new();
    for sink in 0..n {
        if net.layers[sink].op.is_virtual() {
            continue;
        }
        // Backward BFS from the sink, collecting non-virtual ancestors
        // within max_seg - 1 hops. Virtual nodes are walls: a member path
        // to the sink can only run through members, which are non-virtual.
        let mut pool: Vec<usize> = Vec::new();
        let mut frontier = vec![sink];
        let mut seen: HashSet<usize> = frontier.iter().copied().collect();
        for _ in 1..max_seg {
            let mut next = Vec::new();
            for &v in &frontier {
                for &p in &net.layers[v].inputs {
                    if seen.insert(p) && !net.layers[p].op.is_virtual() {
                        pool.push(p);
                        next.push(p);
                    }
                }
            }
            frontier = next;
        }
        pool.sort_unstable();
        // Subsets of the pool of size < max_seg, plus the sink.
        let mut subsets_checked = 0usize;
        let mut stack_nodes: Vec<usize> = Vec::new();
        enumerate_subsets(
            &pool,
            0,
            max_seg - 1,
            &mut stack_nodes,
            &mut |subset: &[usize]| -> Result<(), String> {
                subsets_checked += 1;
                if subsets_checked > 200_000 {
                    return Err(format!(
                        "candidate segment explosion around '{}'; reduce max_segment_layers",
                        net.layers[sink].name
                    ));
                }
                let mut nodes: Vec<usize> = subset.to_vec();
                nodes.push(sink);
                nodes.sort_unstable();
                if let Ok(plan) = net.segment_plan(&nodes) {
                    candidates.push(Candidate { signature: net.plan_signature(&plan), nodes });
                }
                Ok(())
            },
        )?;
    }
    Ok(candidates)
}

fn enumerate_subsets(
    pool: &[usize],
    start: usize,
    budget: usize,
    stack: &mut Vec<usize>,
    visit: &mut dyn FnMut(&[usize]) -> Result<(), String>,
) -> Result<(), String> {
    visit(stack)?;
    if budget == 0 {
        return Ok(());
    }
    for k in start..pool.len() {
        stack.push(pool[k]);
        enumerate_subsets(pool, k + 1, budget - 1, stack, visit)?;
        stack.pop();
    }
    Ok(())
}

/// DP over the ideal lattice: a state is the set of covered non-virtual
/// nodes (an ideal of the DAG); a transition applies a candidate segment
/// whose non-virtual external producers are all covered. States are
/// processed by ascending popcount, then ascending mask; candidates in
/// enumeration order; strict improvement keeps the first minimum — all
/// deterministic, and on a path graph it coincides with the chain DP's
/// tie-breaking.
fn dag_dp(
    net: &Network,
    candidates: &[Candidate],
    costs: &HashMap<String, Option<Scored>>,
) -> Result<Vec<Candidate>, String> {
    let pos = real_positions(net)?;
    let closure = nonvirtual_closure(net, &pos);
    let nbits = pos.iter().flatten().count();
    let full: u128 = if nbits == 128 { u128::MAX } else { (1u128 << nbits) - 1 };

    // Per-candidate cover mask, requirement mask, and score — resolved
    // once here so the DP inner loop is hash- and allocation-free
    // (candidates whose search found nothing drop out entirely; relative
    // order of the usable ones is preserved, keeping tie-breaks stable).
    let mut trans: Vec<(usize, u128, u128, f64)> = Vec::with_capacity(candidates.len());
    for (ci, c) in candidates.iter().enumerate() {
        let Some(scored) = costs.get(&c.signature).and_then(|o| o.as_ref()) else {
            continue; // segment search found nothing: unusable
        };
        let mut mask = 0u128;
        for &i in &c.nodes {
            mask |= 1u128 << pos[i].expect("candidate members are non-virtual");
        }
        let mut need = 0u128;
        for &i in &c.nodes {
            for &p in &net.layers[i].inputs {
                if c.nodes.binary_search(&p).is_err() {
                    need |= closure[p];
                }
            }
        }
        trans.push((ci, mask, need & !mask, scored.score));
    }

    // States layered by popcount; BTreeMap gives ascending-mask iteration.
    // Real DNN graphs are narrow (width ≤ 2-3), so the reachable ideal
    // count stays near-linear in n; the cap turns a pathologically wide
    // hand-written graph into a clean error instead of an OOM.
    const MAX_STATES: usize = 500_000;
    let mut num_states = 1usize;
    let mut layers: Vec<BTreeMap<u128, (f64, usize, u128)>> =
        vec![BTreeMap::new(); nbits + 1];
    layers[0].insert(0, (0.0, usize::MAX, 0));
    for k in 0..nbits {
        let states: Vec<(u128, f64)> =
            layers[k].iter().map(|(&m, &(s, _, _))| (m, s)).collect();
        for (state, score) in states {
            for &(ci, mask, need, seg_score) in &trans {
                if mask & state != 0 || need & !state != 0 {
                    continue;
                }
                let nm = state | mask;
                let total = score + seg_score;
                let slot = layers[nm.count_ones() as usize].entry(nm);
                match slot {
                    std::collections::btree_map::Entry::Vacant(v) => {
                        num_states += 1;
                        if num_states > MAX_STATES {
                            return Err(format!(
                                "graph-cut DP state explosion on {} (> {MAX_STATES} cover \
                                 states); the graph is too wide — reduce max_segment_layers \
                                 or cut the network",
                                net.name
                            ));
                        }
                        v.insert((total, ci, state));
                    }
                    std::collections::btree_map::Entry::Occupied(mut o) => {
                        if total < o.get().0 {
                            o.insert((total, ci, state));
                        }
                    }
                }
            }
        }
    }
    let Some(&(_, mut ci, mut prev)) = layers[nbits].get(&full) else {
        return Err(format!(
            "no feasible partition of {} (every covering segment's search came up empty)",
            net.name
        ));
    };
    let mut chosen = Vec::new();
    loop {
        chosen.push(candidates[ci].clone());
        if prev == 0 {
            break;
        }
        let k = prev.count_ones() as usize;
        let &(_, pci, pprev) = layers[k].get(&prev).expect("DP backpointer chain broken");
        ci = pci;
        prev = pprev;
    }
    Ok(chosen)
}

// ------------------------------------------------------------- entries --

/// Find the optimal fused-segment partition of `net` under `spec`,
/// minimizing the sum of per-segment best scores. Path-shaped networks run
/// the chain cut-point DP (identical to the chain IR); general DAGs run
/// the graph-cut DP.
///
/// Deterministic given (network, architecture, spec) for any worker count.
pub fn search_network(
    net: &Network,
    arch: &Arch,
    spec: &NetworkSearchSpec,
    pool: &Coordinator,
) -> Result<NetworkSearchResult, String> {
    search_network_memo(net, arch, spec, pool, None)
}

/// [`search_network`] with an optional cross-request segment memo (see
/// [`ScalarSegmentMemo`]). With a conforming memo the result is
/// bit-identical to the memo-less run — only already-searched signatures
/// are skipped.
pub fn search_network_memo(
    net: &Network,
    arch: &Arch,
    spec: &NetworkSearchSpec,
    pool: &Coordinator,
    memo: Option<&dyn ScalarSegmentMemo>,
) -> Result<NetworkSearchResult, String> {
    net.validate()?;
    if spec.max_segment_layers == 0 {
        return Err("max_segment_layers must be >= 1".into());
    }
    if net.is_chain() {
        let candidates = chain_candidates(net, spec.max_segment_layers);
        run_scalar_dp(net, arch, spec, candidates, pool, memo, chain_dp)
    } else {
        search_network_dag_impl(net, arch, spec, pool, memo)
    }
}

/// Force the graph-cut DP even on path-shaped networks. [`search_network`]
/// dispatches paths to the chain DP; this entry exists so tests can pin
/// that both DPs return bit-identical results on paths.
pub fn search_network_dag(
    net: &Network,
    arch: &Arch,
    spec: &NetworkSearchSpec,
    pool: &Coordinator,
) -> Result<NetworkSearchResult, String> {
    net.validate()?;
    if spec.max_segment_layers == 0 {
        return Err("max_segment_layers must be >= 1".into());
    }
    search_network_dag_impl(net, arch, spec, pool, None)
}

fn search_network_dag_impl(
    net: &Network,
    arch: &Arch,
    spec: &NetworkSearchSpec,
    pool: &Coordinator,
    memo: Option<&dyn ScalarSegmentMemo>,
) -> Result<NetworkSearchResult, String> {
    // Cheap structural limit first: reject oversized graphs before paying
    // for hundreds of per-segment mapspace searches the DP cannot use.
    real_positions(net)?;
    let candidates = dag_candidates(net, spec.max_segment_layers)?;
    run_scalar_dp(net, arch, spec, candidates, pool, memo, dag_dp)
}

/// Score a *given* partition of `net` into explicit node-set segments: the
/// per-segment searches run exactly as in [`search_network`], but the cover
/// is fixed. Segments must be disjoint, fusable, and together cover every
/// non-virtual node.
pub fn evaluate_segments(
    net: &Network,
    arch: &Arch,
    spec: &NetworkSearchSpec,
    segments: &[Vec<usize>],
    pool: &Coordinator,
) -> Result<NetworkSearchResult, String> {
    evaluate_segments_memo(net, arch, spec, segments, pool, None)
}

/// [`evaluate_segments`] with an optional cross-request segment memo (see
/// [`ScalarSegmentMemo`]); bit-identical to the memo-less run.
pub fn evaluate_segments_memo(
    net: &Network,
    arch: &Arch,
    spec: &NetworkSearchSpec,
    segments: &[Vec<usize>],
    pool: &Coordinator,
    memo: Option<&dyn ScalarSegmentMemo>,
) -> Result<NetworkSearchResult, String> {
    net.validate()?;
    let n = net.num_layers();
    let mut covered = vec![false; n];
    let mut candidates = Vec::with_capacity(segments.len());
    for seg in segments {
        let mut nodes = seg.clone();
        nodes.sort_unstable();
        nodes.dedup();
        if nodes.len() != seg.len() {
            return Err(format!("segment {seg:?} has duplicate nodes"));
        }
        for &i in &nodes {
            if i >= n {
                return Err(format!("segment node {i} out of range (network has {n} layers)"));
            }
            if covered[i] {
                return Err(format!(
                    "node {i} ('{}') appears in more than one segment",
                    net.layers[i].name
                ));
            }
            covered[i] = true;
        }
        let plan = net.segment_plan(&nodes).map_err(|e| {
            format!(
                "segment {} is not fusable (missing a mandatory cut?): {e}",
                net.span_name_nodes(&nodes)
            )
        })?;
        candidates.push(Candidate { signature: net.plan_signature(&plan), nodes });
    }
    for (i, l) in net.layers.iter().enumerate() {
        if !covered[i] && !l.op.is_virtual() {
            return Err(format!("node {i} ('{}') is not covered by any segment", l.name));
        }
    }
    // A fixed partition is scored as given: no candidate is skipped, so the
    // static pruner does not apply here.
    let costs = search_distinct(net, arch, spec, &candidates, pool, memo)?;
    let nseg = candidates.len();
    assemble(net, candidates, &costs, nseg, 0)
}

/// Score a *given* partition described by chain cut points (ascending,
/// interior) — the contiguous ranges between cuts become the segments,
/// with virtual nodes dropped (they belong to no segment). Errors if a cut
/// is out of range or a forced segment is unbuildable (e.g. the user failed
/// to cut at a reshape boundary).
pub fn evaluate_partition(
    net: &Network,
    arch: &Arch,
    spec: &NetworkSearchSpec,
    cuts: &[usize],
    pool: &Coordinator,
) -> Result<NetworkSearchResult, String> {
    evaluate_partition_memo(net, arch, spec, cuts, pool, None)
}

/// [`evaluate_partition`] with an optional cross-request segment memo (see
/// [`ScalarSegmentMemo`]); bit-identical to the memo-less run.
pub fn evaluate_partition_memo(
    net: &Network,
    arch: &Arch,
    spec: &NetworkSearchSpec,
    cuts: &[usize],
    pool: &Coordinator,
    memo: Option<&dyn ScalarSegmentMemo>,
) -> Result<NetworkSearchResult, String> {
    net.validate()?;
    let n = net.num_layers();
    let mut bounds = Vec::with_capacity(cuts.len() + 2);
    bounds.push(0);
    for &c in cuts {
        if c == 0 || c >= n {
            return Err(format!("cut {c} out of range (0, {n})"));
        }
        if let Some(&prev) = bounds.last() {
            if c <= prev {
                return Err(format!("cuts must be strictly ascending (saw {c} after {prev})"));
            }
        }
        bounds.push(c);
    }
    bounds.push(n);
    let segments: Vec<Vec<usize>> = bounds
        .windows(2)
        .map(|w| (w[0]..w[1]).filter(|&i| !net.layers[i].op.is_virtual()).collect())
        .filter(|s: &Vec<usize>| !s.is_empty())
        .collect();
    evaluate_segments_memo(net, arch, spec, &segments, pool, memo)
}
