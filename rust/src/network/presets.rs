//! Built-in whole-DNN graphs: ResNet-18 (with its real residual edges, and
//! a chain-projected regression variant), MobileNetV2 (with its inverted-
//! residual skip edges), VGG-16, and a BERT encoder block.

use super::{LayerOp, Network};

/// Full ResNet-18 (He et al. [34]) **with its residual edges**: 7×7/2 stem,
/// 3×3/2 max pool, four stages of two residual blocks each. Stage
/// transitions downsample with a stride-2 first conv and a 1×1/2 projection
/// on the skip path; every block ends in an elementwise `add` merging the
/// main path with the skip. 29 nodes. The classifier head is not part of
/// the fused-dataflow graph.
pub fn resnet18() -> Network {
    let mut net = Network { name: "resnet18".into(), layers: vec![] };
    net.push(
        "conv1",
        &[3, 230, 230], // 224 + 2·3 halo, 7×7/2 -> 112
        LayerOp::Conv2d { out_channels: 64, r: 7, s: 7, stride: 2 },
    );
    // 112 + 2·1 halo, 3×3/2 -> 56
    let mut prev = net.push("pool1", &[64, 114, 114], LayerOp::MaxPool { k: 3, stride: 2 });
    // Stage 2: two identity residual blocks at 56×56×64.
    for b in 1..=2 {
        let conv = LayerOp::Conv2d { out_channels: 64, r: 3, s: 3, stride: 1 };
        net.push_from(&format!("conv2_{b}a"), &[64, 58, 58], conv.clone(), vec![prev]);
        let main = net.push(&format!("conv2_{b}b"), &[64, 58, 58], conv);
        prev = net.push_from(&format!("add2_{b}"), &[64, 56, 56], LayerOp::Add, vec![main, prev]);
    }
    // Stages 3–5: a downsampling block (stride-2 main path, 1×1/2 projected
    // skip), then an identity block.
    for (si, &(w, c)) in [(28i64, 128i64), (14, 256), (7, 512)].iter().enumerate() {
        let stage = si + 3;
        let half = c / 2;
        let conv1 = LayerOp::Conv2d { out_channels: c, r: 3, s: 3, stride: 1 };
        net.push_from(
            &format!("conv{stage}_1a"),
            &[half, 2 * w + 2, 2 * w + 2],
            LayerOp::Conv2d { out_channels: c, r: 3, s: 3, stride: 2 },
            vec![prev],
        );
        let main = net.push(&format!("conv{stage}_1b"), &[c, w + 2, w + 2], conv1.clone());
        let proj = net.push_from(
            &format!("conv{stage}_proj"),
            &[half, 2 * w, 2 * w],
            LayerOp::Conv2d { out_channels: c, r: 1, s: 1, stride: 2 },
            vec![prev],
        );
        prev = net.push_from(
            &format!("add{stage}_1"),
            &[c, w, w],
            LayerOp::Add,
            vec![main, proj],
        );
        net.push_from(&format!("conv{stage}_2a"), &[c, w + 2, w + 2], conv1.clone(), vec![prev]);
        let main = net.push(&format!("conv{stage}_2b"), &[c, w + 2, w + 2], conv1);
        prev = net.push_from(&format!("add{stage}_2"), &[c, w, w], LayerOp::Add, vec![main, prev]);
    }
    net
}

/// The PR 3 chain projection of ResNet-18: the 18-layer main path with the
/// residual adds and skip projections dropped. Kept as a regression anchor —
/// path-shaped graphs must reproduce the chain partitioner bit for bit.
pub fn resnet18_chain() -> Network {
    let mut net = Network { name: "resnet18_chain".into(), layers: vec![] };
    net.push(
        "conv1",
        &[3, 230, 230], // 224 + 2·3 halo, 7×7/2 -> 112
        LayerOp::Conv2d { out_channels: 64, r: 7, s: 7, stride: 2 },
    );
    net.push("pool1", &[64, 114, 114], LayerOp::MaxPool { k: 3, stride: 2 });
    // Stage 2: two identical blocks at 56×56×64.
    for b in 1..=2 {
        for half in ["a", "b"] {
            net.push(
                &format!("conv2_{b}{half}"),
                &[64, 58, 58],
                LayerOp::Conv2d { out_channels: 64, r: 3, s: 3, stride: 1 },
            );
        }
    }
    // Stages 3–5: a stride-2, channel-doubling transition block, then an
    // identity-shaped block.
    for (si, &(w, c)) in [(28i64, 128i64), (14, 256), (7, 512)].iter().enumerate() {
        let stage = si + 3;
        net.push(
            &format!("conv{stage}_1a"),
            &[c / 2, 2 * w + 2, 2 * w + 2],
            LayerOp::Conv2d { out_channels: c, r: 3, s: 3, stride: 2 },
        );
        net.push(
            &format!("conv{stage}_1b"),
            &[c, w + 2, w + 2],
            LayerOp::Conv2d { out_channels: c, r: 3, s: 3, stride: 1 },
        );
        for half in ["a", "b"] {
            net.push(
                &format!("conv{stage}_2{half}"),
                &[c, w + 2, w + 2],
                LayerOp::Conv2d { out_channels: c, r: 3, s: 3, stride: 1 },
            );
        }
    }
    net
}

/// Full MobileNetV2 (Sandler et al. [1]) **with its skip edges**: 3×3/2
/// stem, seventeen inverted-residual blocks per the paper's (t, c, n, s)
/// table, and the final 1×1 expansion conv. Each block is
/// `pwise(t·c_in) → dwise(3×3/s) → pwise(c_out)`; the t = 1 first block has
/// no expansion pointwise, and every stride-1, shape-preserving repeat ends
/// in a residual `add` with the block input. 62 nodes.
pub fn mobilenet_v2() -> Network {
    // (expansion t, output channels c, repeats n, first-block stride s) —
    // the MobileNetV2 paper's Table 2, at 224×224 input.
    const BLOCKS: [(i64, i64, usize, i64); 7] = [
        (1, 16, 1, 1),
        (6, 24, 2, 2),
        (6, 32, 3, 2),
        (6, 64, 4, 2),
        (6, 96, 3, 1),
        (6, 160, 3, 2),
        (6, 320, 1, 1),
    ];
    let mut net = Network { name: "mobilenetv2".into(), layers: vec![] };
    // 224 + 2·1 halo, 3×3/2 -> 112
    let mut prev = net.push(
        "conv0",
        &[3, 226, 226],
        LayerOp::Conv2d { out_channels: 32, r: 3, s: 3, stride: 2 },
    );
    let mut c_in = 32i64;
    let mut w = 112i64; // fmap width entering the next block
    let mut idx = 0usize;
    for &(t, c_out, n, s) in &BLOCKS {
        for rep in 0..n {
            let stride = if rep == 0 { s } else { 1 };
            idx += 1;
            let block_in = prev;
            let expanded = t * c_in;
            if t > 1 {
                prev = net.push_from(
                    &format!("block{idx}_expand"),
                    &[c_in, w, w],
                    LayerOp::Pointwise { out_channels: expanded },
                    vec![prev],
                );
            }
            prev = net.push_from(
                &format!("block{idx}_dwise"),
                &[expanded, w + 2, w + 2], // 3×3/pad-1 halo
                LayerOp::Depthwise { r: 3, s: 3, stride },
                vec![prev],
            );
            w = (w + 2 - 3) / stride + 1;
            prev = net.push_from(
                &format!("block{idx}_project"),
                &[expanded, w, w],
                LayerOp::Pointwise { out_channels: c_out },
                vec![prev],
            );
            if stride == 1 && c_in == c_out {
                prev = net.push_from(
                    &format!("block{idx}_add"),
                    &[c_out, w, w],
                    LayerOp::Add,
                    vec![prev, block_in],
                );
            }
            c_in = c_out;
        }
    }
    net.push_from(
        "conv_last",
        &[c_in, w, w],
        LayerOp::Pointwise { out_channels: 1280 },
        vec![prev],
    );
    net
}

/// Full VGG-16 conv trunk (Simonyan & Zisserman [3]): thirteen 3×3/pad-1
/// convs in five stages separated by 2×2/2 max pools — a pure chain. The
/// classifier head is not part of the fused-dataflow graph.
pub fn vgg16() -> Network {
    const STAGES: [(i64, usize); 5] = [(64, 2), (128, 2), (256, 3), (512, 3), (512, 3)];
    let mut net = Network { name: "vgg16".into(), layers: vec![] };
    let mut c_in = 3i64;
    let mut w = 224i64;
    for (stage, &(c, n)) in STAGES.iter().enumerate() {
        for rep in 0..n {
            net.push(
                &format!("conv{}_{}", stage + 1, rep + 1),
                &[c_in, w + 2, w + 2],
                LayerOp::Conv2d { out_channels: c, r: 3, s: 3, stride: 1 },
            );
            c_in = c;
        }
        net.push(&format!("pool{}", stage + 1), &[c, w, w], LayerOp::MaxPool { k: 2, stride: 2 });
        w /= 2;
    }
    net
}

/// One BERT encoder block (Devlin et al. [6]) from the existing attention
/// and FC pieces: `QKᵀ` scores, score·V attend, then the two FFN matmuls.
/// The attention→FFN boundary is a reshape (`[B,H,T,E] → [B·T, H·E]`), so
/// it is a mandatory cut — the partitioner can fuse within the attention
/// pair and within the FFN pair, but never across.
pub fn bert_encoder(batch: i64, heads: i64, tokens: i64, emb: i64) -> Network {
    let d_model = heads * emb;
    let mut net = Network {
        name: format!("bert-encoder(b{batch},h{heads},t{tokens},e{emb})"),
        layers: vec![],
    };
    net.push(
        "scores",
        &[batch, heads, tokens, emb],
        LayerOp::AttentionScores { seq: tokens },
    );
    net.push(
        "attend",
        &[batch, heads, tokens, tokens],
        LayerOp::AttentionValues { emb },
    );
    net.push("ffn1", &[batch * tokens, d_model], LayerOp::Fc { out_features: 4 * d_model });
    net.push("ffn2", &[batch * tokens, 4 * d_model], LayerOp::Fc { out_features: d_model });
    net
}
