//! Network-level Pareto fronts over graph cuts: the vector-cost
//! generalization of [`search_network`](super::search_network).
//!
//! The scalar DP collapses whole-network DSE to one objective; every
//! headline result in the paper, though, is a trade-off *front* (Figs
//! 15-18). [`search_network_pareto`] emits that front for a whole DNN: each
//! point is a complete partition (cut set + one mapping per segment) with a
//! vector cost, one axis per [`NetworkSearchSpec::objectives`] entry, and no
//! point on the front is dominated by any reachable partition.
//!
//! Structure mirrors the scalar path exactly — candidate segments are
//! enumerated per sink, each *distinct* segment signature is searched once
//! (fanned out over the [`Coordinator`], serial inner searches, so results
//! are bit-identical for any worker count) — but the memo table keeps a
//! dominance-pruned Pareto front of the evaluated mappings per segment
//! instead of a single best, and the DP carries a bounded Pareto set of
//! labels per state:
//!
//! * path networks run the chain cut-point DP over prefix states;
//! * general DAGs run the ideal-lattice DP over cover masks, with
//!   transitions restricted to ascending segment-sink order (every cover is
//!   reached by exactly one application order — the one that sums costs in
//!   canonical sink order, so floating-point association noise cannot split
//!   one partition into spurious "distinct" points).
//!
//! On merge, each state's label set is dominance-pruned
//! ([`pareto_front_k`]; ties resolved by lexicographic [`f64::total_cmp`]
//! order, duplicates dropped) and optionally beam-capped
//! ([`NetworkSearchSpec::max_front_per_state`], `0` = exact). The cap always
//! keeps every per-axis minimum, and all states of a mask share the same
//! extension set, so the standard exchange argument goes through level by
//! level: **each single-objective scalar optimum lies on the emitted front
//! even under capping** (given the same per-segment search; exact for
//! exhaustive searches, where the evaluated set is the whole constrained
//! mapspace). Axis costs reuse
//! [`SearchSpec::score_objective`](crate::search::SearchSpec::score_objective),
//! so the infeasibility penalty applies per axis exactly as in scalar runs.
//!
//! Like the scalar DPs, both entries run the once-per-network static
//! analysis first: candidates whose closed-form capacity floor
//! ([`crate::analysis::segment_floors`]) already exceeds the GLB are
//! skipped without a mapspace search, under a lossless guard that accepts
//! the survivor front only when it strictly dominates every pruned
//! candidate's per-axis floor vector — otherwise the pruned shapes are
//! searched after all and the front is re-derived over the full candidate
//! set. Either way the emitted front is bit-identical to a run with
//! [`SearchSpec::prune`](crate::search::SearchSpec::prune) off;
//! [`NetworkParetoResult::candidates_pruned`] reports the skips.

use super::partition::{
    chain_candidates, dag_candidates, nonvirtual_closure, real_positions, search_distinct_map,
    static_prune, Candidate, NetworkSearchSpec, SegmentChoice,
};
use super::Network;
use crate::arch::Arch;
use crate::coordinator::Coordinator;
use crate::mapspace::{cap_front_k, cmp_costs, pareto_front_k, ParetoPointK};
use crate::search::{Objective, Scored};
use crate::util::json::Json;
use std::collections::{BTreeMap, HashMap, HashSet};

/// One point of a network-level Pareto front: a complete partition with its
/// vector cost (one value per requested objective, same order).
#[derive(Debug, Clone)]
pub struct NetworkParetoPoint {
    /// Per-objective cost, summed over segments in sink order (the same
    /// association order the scalar DP's `total_score` uses).
    pub costs: Vec<f64>,
    /// Interior segment boundaries (the scalar result's cut convention).
    pub cuts: Vec<usize>,
    /// The partition's segments, ordered by their largest node index, each
    /// with the chosen mapping for this trade-off point.
    pub segments: Vec<SegmentChoice>,
}

impl NetworkParetoPoint {
    /// Total latency across sequentially executed segments (cycles).
    pub fn total_latency(&self) -> i64 {
        self.segments.iter().map(|s| s.best.metrics.latency_cycles).sum()
    }

    /// Total energy across segments (pJ).
    pub fn total_energy_pj(&self) -> f64 {
        self.segments.iter().map(|s| s.best.metrics.energy.total_pj()).sum()
    }

    /// Total off-chip traffic across segments (elements).
    pub fn total_offchip(&self) -> i64 {
        self.segments.iter().map(|s| s.best.metrics.offchip_total()).sum()
    }

    /// Whether every chosen segment fits the GLB budget.
    pub fn all_fit(&self) -> bool {
        self.segments.iter().all(|s| s.best.metrics.capacity_ok)
    }
}

/// Result of a network-level Pareto search: the front plus the same search
/// accounting the scalar result carries.
#[derive(Debug, Clone)]
pub struct NetworkParetoResult {
    /// The cost axes, in `costs` order.
    pub objectives: Vec<Objective>,
    /// The beam cap the DP ran with (`0` = exact front).
    pub max_front_per_state: usize,
    /// The front, sorted lexicographically by cost vector. Non-empty on
    /// success.
    pub points: Vec<NetworkParetoPoint>,
    /// How many distinct segment signatures were actually searched.
    pub distinct_searched: usize,
    /// How many candidate segments the DP considered.
    pub candidate_segments: usize,
    /// Total pruned per-segment front points across distinct signatures
    /// (the memo table's size, and the DP's branching driver).
    pub segment_front_points: usize,
    /// How many candidate segments were skipped without a search because
    /// their closed-form capacity floor already exceeds the GLB budget
    /// (see [`crate::analysis::segment_floors`]). `0` whenever the
    /// lossless guard forced the re-evaluate fallback; the emitted front
    /// is bit-identical with pruning on or off either way.
    pub candidates_pruned: usize,
}

impl NetworkParetoResult {
    /// The minimum cost reached on one axis across the front.
    pub fn min_cost(&self, axis: usize) -> Option<f64> {
        self.points
            .iter()
            .map(|p| p.costs[axis])
            .min_by(|a, b| a.total_cmp(b))
    }

    /// One row of the `pareto_rows` section of `BENCH_network.json`. Like
    /// [`super::NetworkSearchResult::bench_row`], the bench binary and the
    /// schema test both build rows through this method, so the CI artifact
    /// cannot silently drift from
    /// [`crate::util::bench::check_network_bench_schema`].
    pub fn bench_row(&self, workload: &str, layers: usize, mean_ns: f64) -> Json {
        Json::Obj(
            [
                ("workload".to_string(), Json::Str(workload.to_string())),
                ("mean_ns".to_string(), Json::Num(mean_ns)),
                ("layers".to_string(), Json::Num(layers as f64)),
                ("objectives".to_string(), Json::Num(self.objectives.len() as f64)),
                ("front_points".to_string(), Json::Num(self.points.len() as f64)),
                (
                    "segment_front_points".to_string(),
                    Json::Num(self.segment_front_points as f64),
                ),
                (
                    "candidate_segments".to_string(),
                    Json::Num(self.candidate_segments as f64),
                ),
                (
                    "distinct_searched".to_string(),
                    Json::Num(self.distinct_searched as f64),
                ),
                (
                    "candidates_pruned".to_string(),
                    Json::Num(self.candidates_pruned as f64),
                ),
            ]
            .into_iter()
            .collect(),
        )
    }
}

/// A pruned per-segment front point: vector cost (one value per requested
/// objective) + the scored mapping that achieves it. This is the unit the
/// per-segment memo table holds — and what a cross-request
/// [`FrontSegmentMemo`] caches.
pub type SegmentFrontPoint = ParetoPointK<Scored>;

/// Internal shorthand.
type SegPoint = SegmentFrontPoint;

/// An external memo for per-segment *Pareto fronts*, the front-DP analogue
/// of [`super::ScalarSegmentMemo`]. Consulted once per distinct signature
/// in the serial pre-pass before the parallel fan-out, so memo traffic is
/// deterministic for any worker count.
///
/// Contract: `lookup` must only return values previously passed to `store`
/// under the same signature *and* the same (architecture, search spec,
/// objectives, front cap) context — the caller owns context keying.
/// Per-segment front extraction is a deterministic function of that
/// context, so a conforming memo never changes any result. `Some(None)`
/// records a segment whose search produced no evaluations.
pub trait FrontSegmentMemo {
    /// Cached pruned front for `signature`, or `None` on a miss.
    fn lookup_front(&self, signature: &str) -> Option<Option<Vec<SegmentFrontPoint>>>;
    /// Record the freshly computed pruned front for `signature`.
    fn store_front(&self, signature: &str, value: &Option<Vec<SegmentFrontPoint>>);
}

/// A DP label: running vector cost + backpointer provenance. `S` is the
/// state id type (prefix length for the chain DP, cover mask for the graph
/// DP).
#[derive(Debug, Clone)]
struct Back<S> {
    prev: S,
    prev_label: usize,
    /// Candidate index applied to reach this label; `usize::MAX` marks the
    /// root label.
    cand: usize,
    /// Index into the candidate's per-segment front.
    choice: usize,
}

type Label<S> = ParetoPointK<Back<S>>;

fn root_label<S: Default>(arity: usize) -> Label<S> {
    ParetoPointK {
        costs: vec![0.0; arity],
        payload: Back { prev: S::default(), prev_label: 0, cand: usize::MAX, choice: 0 },
    }
}

fn add_costs(a: &[f64], b: &[f64]) -> Vec<f64> {
    a.iter().zip(b).map(|(x, y)| x + y).collect()
}

/// Dominance-prune and beam-cap one state's label set.
fn prune_labels<S>(pool: Vec<Label<S>>, cap: usize) -> Vec<Label<S>> {
    cap_front_k(pareto_front_k(pool), cap)
}

/// Total labels the DP may materialize before erroring out — the front
/// analogue of the scalar DP's state cap (an uncapped front on a
/// pathologically wide graph should fail cleanly, not OOM; the fix is
/// `max_front_per_state`).
const MAX_LABELS: usize = 500_000;

fn label_explosion(net: &Network) -> String {
    format!(
        "Pareto DP label explosion on {} (> {MAX_LABELS} labels); set \
         max_front_per_state (beam cap) or reduce max_segment_layers",
        net.name
    )
}

/// Per-signature pruned fronts of the evaluated per-segment mappings,
/// memoized exactly like the scalar path (one search per distinct
/// signature, deterministic for any worker count).
fn search_distinct_fronts(
    net: &Network,
    arch: &Arch,
    spec: &NetworkSearchSpec,
    candidates: &[Candidate],
    pool: &Coordinator,
    memo: Option<&dyn FrontSegmentMemo>,
) -> Result<HashMap<String, Option<Vec<SegPoint>>>, String> {
    let objectives = spec.objectives.clone();
    let search = spec.search.clone();
    let cap = spec.max_front_per_state;
    // The front is extracted from the *full* evaluated set, so capacity
    // pruning must stay off here: a skipped (provably infeasible) candidate
    // cannot win a scalar search, but its penalized cost vector could still
    // sit on a multi-objective front.
    let mut spec = spec.clone();
    spec.search.prune = false;
    search_distinct_map(
        net,
        arch,
        &spec,
        candidates,
        pool,
        move |r| {
            let points: Vec<SegPoint> = r
                .evaluated
                .into_iter()
                .map(|s| ParetoPointK {
                    costs: objectives
                        .iter()
                        .map(|&o| search.score_objective(o, &s.metrics))
                        .collect(),
                    payload: s,
                })
                .collect();
            cap_front_k(pareto_front_k(points), cap)
        },
        |sig| memo.and_then(|m| m.lookup_front(sig)),
        |sig, v| {
            if let Some(m) = memo {
                m.store_front(sig, v);
            }
        },
    )
}

// ------------------------------------------------------ chain (path) DP --

/// Chain cut-point DP over prefix states, carrying a pruned label front per
/// prefix. Returns, per surviving full-network label, the chosen
/// `(candidate, front choice)` pairs in sink order.
fn chain_dp_fronts(
    net: &Network,
    candidates: &[Candidate],
    fronts: &HashMap<String, Option<Vec<SegPoint>>>,
    arity: usize,
    cap: usize,
) -> Result<Vec<Vec<(usize, usize)>>, String> {
    let n = net.num_layers();
    let mut by_hi: Vec<Vec<usize>> = vec![Vec::new(); n + 1];
    for (ci, c) in candidates.iter().enumerate() {
        by_hi[c.nodes.last().unwrap() + 1].push(ci);
    }
    let mut labels: Vec<Vec<Label<usize>>> = vec![Vec::new(); n + 1];
    labels[0].push(root_label(arity));
    let mut total_labels = 1usize;
    for hi in 1..=n {
        let mut pool: Vec<Label<usize>> = Vec::new();
        for &ci in &by_hi[hi] {
            let Some(front) = fronts.get(&candidates[ci].signature).and_then(|o| o.as_ref())
            else {
                continue; // segment search found nothing: unusable
            };
            let lo = candidates[ci].nodes[0];
            for (li, lab) in labels[lo].iter().enumerate() {
                for (fi, fp) in front.iter().enumerate() {
                    pool.push(ParetoPointK {
                        costs: add_costs(&lab.costs, &fp.costs),
                        payload: Back { prev: lo, prev_label: li, cand: ci, choice: fi },
                    });
                }
            }
        }
        total_labels += pool.len();
        if total_labels > MAX_LABELS {
            return Err(label_explosion(net));
        }
        labels[hi] = prune_labels(pool, cap);
    }
    if labels[n].is_empty() {
        return Err(format!(
            "no feasible partition of {} (every covering segment's search came up empty)",
            net.name
        ));
    }
    // Reconstruct each surviving label's segment choices.
    let mut out = Vec::with_capacity(labels[n].len());
    for lab in &labels[n] {
        let mut chosen = Vec::new();
        let mut back = &lab.payload;
        while back.cand != usize::MAX {
            chosen.push((back.cand, back.choice));
            back = &labels[back.prev][back.prev_label].payload;
        }
        chosen.reverse(); // walked sink-to-source; emit in sink order
        out.push(chosen);
    }
    Ok(out)
}

// ------------------------------------------------------- graph-cut DP --

/// Ideal-lattice DP over cover masks, carrying a pruned label front per
/// state. Transitions are restricted to ascending segment-sink order: a
/// candidate applies only when its sink (= its largest node, = its highest
/// mask bit) exceeds the state's highest covered bit. Every cover is still
/// reachable (an external producer consumed outside its own segment is that
/// segment's sink, so sinks of producers precede sinks of consumers), each
/// cover is reached exactly once, and running costs accumulate in canonical
/// sink order.
fn dag_dp_fronts(
    net: &Network,
    candidates: &[Candidate],
    fronts: &HashMap<String, Option<Vec<SegPoint>>>,
    arity: usize,
    cap: usize,
) -> Result<Vec<Vec<(usize, usize)>>, String> {
    let pos = real_positions(net)?;
    let closure = nonvirtual_closure(net, &pos);
    let nbits = pos.iter().flatten().count();
    let full: u128 = if nbits == 128 { u128::MAX } else { (1u128 << nbits) - 1 };

    // Per-candidate cover mask, requirement mask, and front — resolved once
    // (candidates whose search found nothing drop out; relative order of
    // the usable ones is preserved, keeping tie-breaks stable).
    let mut trans: Vec<(usize, u128, u128, &Vec<SegPoint>)> = Vec::with_capacity(candidates.len());
    for (ci, c) in candidates.iter().enumerate() {
        let Some(front) = fronts.get(&c.signature).and_then(|o| o.as_ref()) else {
            continue;
        };
        let mut mask = 0u128;
        for &i in &c.nodes {
            mask |= 1u128 << pos[i].expect("candidate members are non-virtual");
        }
        let mut need = 0u128;
        for &i in &c.nodes {
            for &p in &net.layers[i].inputs {
                if c.nodes.binary_search(&p).is_err() {
                    need |= closure[p];
                }
            }
        }
        trans.push((ci, mask, need & !mask, front));
    }

    // States layered by popcount; BTreeMap gives ascending-mask iteration.
    // A state's labels are complete once every lower layer has expanded, so
    // each layer is pruned exactly once, right before its states expand —
    // backpointer indices into the pruned vectors stay valid.
    let mut layers: Vec<BTreeMap<u128, Vec<Label<u128>>>> = vec![BTreeMap::new(); nbits + 1];
    layers[0].insert(0, vec![root_label(arity)]);
    let mut total_labels = 1usize;
    for kpop in 0..nbits {
        let masks: Vec<u128> = layers[kpop].keys().copied().collect();
        for m in &masks {
            let labs = layers[kpop].remove(m).expect("state listed");
            layers[kpop].insert(*m, prune_labels(labs, cap));
        }
        for state in masks {
            let labs = layers[kpop].get(&state).expect("state pruned").clone();
            for &(ci, mask, need, front) in &trans {
                if mask & state != 0
                    || need & !state != 0
                    || mask.leading_zeros() >= state.leading_zeros()
                {
                    continue; // overlaps, unmet producers, or out of sink order
                }
                let nm = state | mask;
                total_labels += labs.len() * front.len();
                if total_labels > MAX_LABELS {
                    return Err(label_explosion(net));
                }
                let tgt = layers[nm.count_ones() as usize].entry(nm).or_default();
                for (li, lab) in labs.iter().enumerate() {
                    for (fi, fp) in front.iter().enumerate() {
                        tgt.push(ParetoPointK {
                            costs: add_costs(&lab.costs, &fp.costs),
                            payload: Back { prev: state, prev_label: li, cand: ci, choice: fi },
                        });
                    }
                }
            }
        }
    }
    let finals = match layers[nbits].remove(&full) {
        Some(labs) => prune_labels(labs, cap),
        None => Vec::new(),
    };
    if finals.is_empty() {
        return Err(format!(
            "no feasible partition of {} (every covering segment's search came up empty)",
            net.name
        ));
    }
    let mut out = Vec::with_capacity(finals.len());
    for lab in &finals {
        let mut chosen = Vec::new();
        let mut back = &lab.payload;
        while back.cand != usize::MAX {
            chosen.push((back.cand, back.choice));
            let prev_layer = &layers[back.prev.count_ones() as usize];
            back = &prev_layer.get(&back.prev).expect("DP backpointer chain broken")
                [back.prev_label]
                .payload;
        }
        chosen.reverse(); // applied in ascending sink order; walk reversed it
        out.push(chosen);
    }
    Ok(out)
}

// ------------------------------------------------------------ assembly --

/// Turn raw `(candidate, choice)` solutions into the final front:
/// deduplicate identical partitions, recompute each cost vector canonically
/// (per-segment costs summed in sink order), build the `SegmentChoice`
/// lists, and dominance-prune once more on the canonical costs.
fn assemble_front(
    net: &Network,
    candidates: &[Candidate],
    fronts: &HashMap<String, Option<Vec<SegPoint>>>,
    solutions: Vec<Vec<(usize, usize)>>,
) -> Result<Vec<NetworkParetoPoint>, String> {
    let mut seen: HashSet<Vec<(usize, usize)>> = HashSet::new();
    let mut points: Vec<ParetoPointK<NetworkParetoPoint>> = Vec::new();
    for mut solution in solutions {
        // Sink order == ascending largest-node order of the candidates.
        solution.sort_by_key(|&(ci, _)| *candidates[ci].nodes.last().unwrap());
        if !seen.insert(solution.clone()) {
            continue;
        }
        let mut costs = Vec::new();
        let mut segments = Vec::with_capacity(solution.len());
        for (ci, fi) in solution {
            let c = &candidates[ci];
            let fp = fronts
                .get(&c.signature)
                .and_then(|o| o.as_ref())
                .and_then(|f| f.get(fi))
                .ok_or_else(|| {
                    format!("segment {} lost its front point", net.span_name_nodes(&c.nodes))
                })?;
            costs = if costs.is_empty() { fp.costs.clone() } else { add_costs(&costs, &fp.costs) };
            segments.push(SegmentChoice {
                lo: c.nodes[0],
                hi: *c.nodes.last().unwrap() + 1,
                span: net.span_name_nodes(&c.nodes),
                signature: c.signature.clone(),
                best: fp.payload.clone(),
                nodes: c.nodes.clone(),
            });
        }
        let cuts = segments.iter().skip(1).map(|s| s.lo).collect();
        points.push(ParetoPointK {
            payload: NetworkParetoPoint { costs: costs.clone(), cuts, segments },
            costs,
        });
    }
    Ok(pareto_front_k(points).into_iter().map(|p| p.payload).collect())
}

fn front_size(fronts: &HashMap<String, Option<Vec<SegPoint>>>) -> usize {
    fronts.values().flatten().map(|f| f.len()).sum()
}

// ------------------------------------------------------------- entries --

fn check_spec(spec: &NetworkSearchSpec) -> Result<(), String> {
    if spec.max_segment_layers == 0 {
        return Err("max_segment_layers must be >= 1".into());
    }
    if spec.objectives.is_empty() {
        return Err("pareto search needs at least one objective".into());
    }
    if spec.max_front_per_state != 0 && spec.max_front_per_state < spec.objectives.len() {
        // A cap below the arity could drop a trailing axis's minimum from a
        // state — the one guarantee capping is documented to preserve.
        return Err(format!(
            "max_front_per_state ({}) must be 0 (unbounded) or >= the number of objectives \
             ({})",
            spec.max_front_per_state,
            spec.objectives.len()
        ));
    }
    Ok(())
}

/// Compute the network-level Pareto front over fused-segment partitions of
/// `net`: every point is a complete partition + per-segment mappings, no
/// point is dominated on [`NetworkSearchSpec::objectives`], and the front
/// is sorted lexicographically by cost vector. Path-shaped networks run the
/// chain cut-point DP; general DAGs run the graph-cut DP.
///
/// Deterministic given (network, architecture, spec) for any worker count.
pub fn search_network_pareto(
    net: &Network,
    arch: &Arch,
    spec: &NetworkSearchSpec,
    pool: &Coordinator,
) -> Result<NetworkParetoResult, String> {
    search_network_pareto_memo(net, arch, spec, pool, None)
}

/// [`search_network_pareto`] with an optional cross-request segment-front
/// memo (see [`FrontSegmentMemo`]). With a conforming memo the emitted
/// front is bit-identical to the memo-less run — only already-searched
/// signatures are skipped.
pub fn search_network_pareto_memo(
    net: &Network,
    arch: &Arch,
    spec: &NetworkSearchSpec,
    pool: &Coordinator,
    memo: Option<&dyn FrontSegmentMemo>,
) -> Result<NetworkParetoResult, String> {
    net.validate()?;
    check_spec(spec)?;
    if net.is_chain() {
        let candidates = chain_candidates(net, spec.max_segment_layers);
        run_front_dp(net, arch, spec, candidates, pool, memo, chain_dp_fronts)
    } else {
        search_network_pareto_dag_impl(net, arch, spec, pool, memo)
    }
}

/// Force the graph-cut front DP even on path-shaped networks.
/// [`search_network_pareto`] dispatches paths to the chain DP; this entry
/// exists so tests can pin that both DPs emit the same front on paths.
pub fn search_network_pareto_dag(
    net: &Network,
    arch: &Arch,
    spec: &NetworkSearchSpec,
    pool: &Coordinator,
) -> Result<NetworkParetoResult, String> {
    net.validate()?;
    check_spec(spec)?;
    search_network_pareto_dag_impl(net, arch, spec, pool, None)
}

fn search_network_pareto_dag_impl(
    net: &Network,
    arch: &Arch,
    spec: &NetworkSearchSpec,
    pool: &Coordinator,
    memo: Option<&dyn FrontSegmentMemo>,
) -> Result<NetworkParetoResult, String> {
    // Cheap structural limit first, as in the scalar path.
    real_positions(net)?;
    let candidates = dag_candidates(net, spec.max_segment_layers)?;
    run_front_dp(net, arch, spec, candidates, pool, memo, dag_dp_fronts)
}

/// The shared front search-and-DP driver behind [`search_network_pareto`]
/// (chain arm) and [`search_network_pareto_dag`], with provably lossless
/// static candidate pruning when the spec allows it.
///
/// Pruning discipline (the front analogue of the scalar DP's guard):
/// candidates whose closed-form capacity floor exceeds the GLB
/// ([`crate::analysis::segment_floors`]) are skipped and the front DP runs
/// over the survivors. The survivor front is accepted only when, for every
/// pruned candidate, some front point strictly beats the candidate's
/// per-axis cost floor vector on *every* axis — then no label routed
/// through a pruned candidate (componentwise at least that floor, and
/// route costs only grow) can dominate any front-bound label or land on
/// the front itself, and exact (uncapped) dominance filtering keeps a
/// superset of labels when competitors are removed, so the emitted front
/// is identical. The gate therefore also requires `max_front_per_state ==
/// 0`: a beam cap breaks the superset argument. When the guard fails, the
/// pruned shapes are searched after all and the DP reruns over the full
/// candidate set (reporting `candidates_pruned: 0`) — per-signature
/// searches are independent and deterministic, so the fallback, too, is
/// bit-identical to a run with pruning disabled.
fn run_front_dp(
    net: &Network,
    arch: &Arch,
    spec: &NetworkSearchSpec,
    candidates: Vec<Candidate>,
    pool: &Coordinator,
    memo: Option<&dyn FrontSegmentMemo>,
    dp: fn(
        &Network,
        &[Candidate],
        &HashMap<String, Option<Vec<SegPoint>>>,
        usize,
        usize,
    ) -> Result<Vec<Vec<(usize, usize)>>, String>,
) -> Result<NetworkParetoResult, String> {
    let arity = spec.objectives.len();
    let prunable = spec.search.prune
        && spec.max_front_per_state == 0
        && (spec.search.penalize_infeasible
            || spec.objectives.iter().all(|&o| o == Objective::FeasibleEdp))
        && arch.glb_capacity().is_some();
    if prunable {
        let (survivors, pruned, floor_vecs) = static_prune(net, arch, &candidates, |f| {
            f.floor_costs(&spec.objectives, &spec.search)
        });
        if !pruned.is_empty() && !survivors.is_empty() {
            let mut fronts = search_distinct_fronts(net, arch, spec, &survivors, pool, memo)?;
            let attempt = dp(net, &survivors, &fronts, arity, 0)
                .and_then(|sols| assemble_front(net, &survivors, &fronts, sols));
            if let Ok(points) = attempt {
                let beaten = |fv: &Vec<f64>| {
                    points.iter().any(|p| {
                        p.costs
                            .iter()
                            .zip(fv)
                            .all(|(c, f)| c.total_cmp(f) == std::cmp::Ordering::Less)
                    })
                };
                if floor_vecs.iter().all(beaten) {
                    return Ok(finish(spec, &fronts, candidates.len(), pruned.len(), points));
                }
            }
            // Lossless-guard fallback: a pruned candidate could still reach
            // the front. Search the pruned shapes too (their signatures are
            // disjoint from the survivors') and rerun over everything.
            fronts.extend(search_distinct_fronts(net, arch, spec, &pruned, pool, memo)?);
            let sols = dp(net, &candidates, &fronts, arity, 0)?;
            let points = assemble_front(net, &candidates, &fronts, sols)?;
            return Ok(finish(spec, &fronts, candidates.len(), 0, points));
        }
    }
    let fronts = search_distinct_fronts(net, arch, spec, &candidates, pool, memo)?;
    let sols = dp(net, &candidates, &fronts, arity, spec.max_front_per_state)?;
    let points = assemble_front(net, &candidates, &fronts, sols)?;
    Ok(finish(spec, &fronts, candidates.len(), 0, points))
}

fn finish(
    spec: &NetworkSearchSpec,
    fronts: &HashMap<String, Option<Vec<SegPoint>>>,
    candidate_segments: usize,
    candidates_pruned: usize,
    points: Vec<NetworkParetoPoint>,
) -> NetworkParetoResult {
    debug_assert!(points
        .windows(2)
        .all(|w| cmp_costs(&w[0].costs, &w[1].costs) == std::cmp::Ordering::Less));
    NetworkParetoResult {
        objectives: spec.objectives.clone(),
        max_front_per_state: spec.max_front_per_state,
        points,
        distinct_searched: fronts.len(),
        candidate_segments,
        segment_front_points: front_size(fronts),
        candidates_pruned,
    }
}
