//! Affine expressions and maps over a rank (iteration) space.
//!
//! An [`AffineExpr`] is `Σ coeff_i · rank_i + offset` where `rank_i` indexes a
//! dimension of the iteration space. An [`AffineMap`] is one expression per
//! output (tensor) dimension. Images of boxes under such maps are boxes
//! (coefficients are per-dimension independent), which is what makes the
//! analysis in `model/` exact and fast.

use super::{IBox, Interval, Region};

/// `Σ coeff·rank + offset` over the dims of an iteration space.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct AffineExpr {
    /// `(iteration-space dim index, coefficient)`; coefficients are nonzero.
    pub terms: Vec<(usize, i64)>,
    /// Constant term.
    pub offset: i64,
}

impl AffineExpr {
    /// The expression `dim` (a bare index, coefficient 1).
    pub fn var(dim: usize) -> Self {
        AffineExpr { terms: vec![(dim, 1)], offset: 0 }
    }

    /// `coeff * dim`.
    pub fn scaled(dim: usize, coeff: i64) -> Self {
        assert!(coeff != 0, "zero coefficient");
        AffineExpr { terms: vec![(dim, coeff)], offset: 0 }
    }

    /// A constant expression.
    pub fn constant(c: i64) -> Self {
        AffineExpr { terms: vec![], offset: c }
    }

    /// `a*x + b*y` (e.g. the sliding-window index `p + r`, or strided `2p + r`).
    pub fn sum(a: (usize, i64), b: (usize, i64)) -> Self {
        assert!(a.0 != b.0, "duplicate dim in affine sum");
        AffineExpr { terms: vec![a, b], offset: 0 }
    }

    /// Add a constant to the expression's offset.
    pub fn with_offset(mut self, offset: i64) -> Self {
        self.offset += offset;
        self
    }

    /// Is this expression a bare `1·dim + 0`? Returns the dim if so.
    pub fn as_identity(&self) -> Option<usize> {
        if self.offset == 0 && self.terms.len() == 1 && self.terms[0].1 == 1 {
            Some(self.terms[0].0)
        } else {
            None
        }
    }

    /// Dims referenced by this expression.
    pub fn dims(&self) -> impl Iterator<Item = usize> + '_ {
        self.terms.iter().map(|&(d, _)| d)
    }

    /// Exact range of the expression over a box of the iteration space.
    ///
    /// The image of a box under a separable affine form is an interval: each
    /// term contributes `coeff · [lo, hi)` independently. (This is the image
    /// of the *box*, i.e. every integer in the returned interval is attained
    /// whenever some coefficient is ±1; for strided accesses with |coeff|>1
    /// and no unit-coefficient companion term the interval over-approximates
    /// the attained set — the standard dense-footprint convention, which
    /// matches how strided conv halos are counted in Timeloop.)
    pub fn range_over(&self, domain: &IBox) -> Interval {
        if domain.is_empty() {
            return Interval::empty();
        }
        let mut lo = self.offset;
        let mut hi = self.offset; // max attained value (inclusive)
        for &(dim, coeff) in &self.terms {
            let iv = domain.dims[dim];
            debug_assert!(!iv.is_empty());
            if coeff >= 0 {
                lo += coeff * iv.lo;
                hi += coeff * (iv.hi - 1);
            } else {
                lo += coeff * (iv.hi - 1);
                hi += coeff * iv.lo;
            }
        }
        Interval::new(lo, hi + 1)
    }
}

impl std::fmt::Display for AffineExpr {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut first = true;
        for &(d, c) in &self.terms {
            if !first {
                write!(f, "+")?;
            }
            if c == 1 {
                write!(f, "d{d}")?;
            } else {
                write!(f, "{c}·d{d}")?;
            }
            first = false;
        }
        if self.offset != 0 || first {
            if !first {
                write!(f, "+")?;
            }
            write!(f, "{}", self.offset)?;
        }
        Ok(())
    }
}

/// One affine expression per output dimension: a map from an iteration space
/// to a tensor's coordinate space.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct AffineMap {
    /// One expression per output (tensor) dimension.
    pub exprs: Vec<AffineExpr>,
}

impl AffineMap {
    /// A map from the given per-output-dim expressions.
    pub fn new(exprs: Vec<AffineExpr>) -> Self {
        AffineMap { exprs }
    }

    /// The identity map on `dims` (dim order gives output dim order).
    pub fn identity(dims: &[usize]) -> Self {
        AffineMap {
            exprs: dims.iter().map(|&d| AffineExpr::var(d)).collect(),
        }
    }

    /// Number of output dimensions.
    pub fn out_ndim(&self) -> usize {
        self.exprs.len()
    }

    /// Image of an iteration-space box: the (box) data footprint it touches.
    pub fn image_box(&self, domain: &IBox) -> IBox {
        let mut out = IBox::empty(self.out_ndim());
        self.image_box_into(domain, &mut out);
        out
    }

    /// [`AffineMap::image_box`] into a caller-provided box (reuses storage).
    pub fn image_box_into(&self, domain: &IBox, out: &mut IBox) {
        out.dims.clear();
        if domain.is_empty() {
            out.dims.resize(self.out_ndim(), Interval::empty());
            return;
        }
        out.dims.extend(self.exprs.iter().map(|e| e.range_over(domain)));
    }

    /// Image of a region (union of per-box images; re-disjointified).
    pub fn image(&self, domain: &Region) -> Region {
        let mut out = Region::empty(self.out_ndim());
        for b in domain.boxes() {
            out.union_box(&self.image_box(b));
        }
        out
    }

    /// Preimage of a data box for an *identity-per-dimension* map: the
    /// iteration sub-box (over the dims this map mentions) whose image is the
    /// data box. `full_domain` supplies the extent of unmentioned dims.
    ///
    /// Only identity output accesses need preimages in the LoopTree analysis
    /// (the operations required to produce a piece of an output tensor), and
    /// output tensors in our Einsums are always indexed by bare ranks — the
    /// assertion enforces this documented restriction.
    pub fn preimage_identity_box(&self, data: &IBox, full_domain: &IBox) -> IBox {
        let mut out = IBox::empty(full_domain.ndim());
        self.preimage_identity_box_into(data, full_domain, &mut out);
        out
    }

    /// [`AffineMap::preimage_identity_box`] into a caller-provided box.
    pub fn preimage_identity_box_into(&self, data: &IBox, full_domain: &IBox, out: &mut IBox) {
        debug_assert_eq!(data.ndim(), self.out_ndim());
        out.dims.clear();
        if data.is_empty() {
            out.dims.resize(full_domain.ndim(), Interval::empty());
            return;
        }
        out.dims.extend_from_slice(&full_domain.dims);
        for (expr, iv) in self.exprs.iter().zip(&data.dims) {
            let dim = expr
                .as_identity()
                .expect("preimage requires identity output access");
            out.dims[dim] = out.dims[dim].intersect(iv);
        }
        if out.is_empty() {
            out.dims.clear();
            out.dims.resize(full_domain.ndim(), Interval::empty());
        }
    }

    /// Preimage of a data region under an identity-per-dim map.
    pub fn preimage_identity(&self, data: &Region, full_domain: &IBox) -> Region {
        let mut out = Region::empty(full_domain.ndim());
        for b in data.boxes() {
            out.union_box(&self.preimage_identity_box(b, full_domain));
        }
        out
    }

    /// Dims of the iteration space mentioned by this map.
    pub fn referenced_dims(&self) -> Vec<usize> {
        let mut dims: Vec<usize> = self.exprs.iter().flat_map(|e| e.dims()).collect();
        dims.sort_unstable();
        dims.dedup();
        dims
    }
}

impl std::fmt::Display for AffineMap {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "[")?;
        for (i, e) in self.exprs.iter().enumerate() {
            if i > 0 {
                write!(f, ", ")?;
            }
            write!(f, "{e}")?;
        }
        write!(f, "]")
    }
}
