use super::*;

fn bx(bounds: &[(i64, i64)]) -> IBox {
    IBox::from_bounds(bounds)
}

#[test]
fn interval_basics() {
    let a = Interval::new(2, 7);
    assert_eq!(a.len(), 5);
    assert!(!a.is_empty());
    assert!(a.contains(2));
    assert!(!a.contains(7));
    assert!(Interval::new(3, 3).is_empty());
    assert_eq!(Interval::new(5, 2).len(), 0);
}

#[test]
fn interval_intersect_hull() {
    let a = Interval::new(0, 10);
    let b = Interval::new(5, 15);
    assert_eq!(a.intersect(&b), Interval::new(5, 10));
    assert_eq!(a.hull(&b), Interval::new(0, 15));
    let c = Interval::new(20, 30);
    assert!(a.intersect(&c).is_empty());
    assert!(a.overlaps(&b));
    assert!(!a.overlaps(&c));
}

#[test]
fn interval_empty_hull_identity() {
    let a = Interval::new(1, 4);
    assert_eq!(a.hull(&Interval::empty()), a);
    assert_eq!(Interval::empty().hull(&a), a);
}

#[test]
fn box_volume_empty() {
    assert_eq!(bx(&[(0, 4), (0, 3)]).volume(), 12);
    assert_eq!(bx(&[(0, 4), (3, 3)]).volume(), 0);
    assert!(bx(&[(0, 4), (3, 3)]).is_empty());
}

#[test]
fn box_intersect_contains() {
    let a = bx(&[(0, 10), (0, 10)]);
    let b = bx(&[(5, 15), (2, 8)]);
    let i = a.intersect(&b);
    assert_eq!(i, bx(&[(5, 10), (2, 8)]));
    assert!(a.contains_box(&bx(&[(1, 2), (1, 2)])));
    assert!(!a.contains_box(&b));
    assert!(a.contains_box(&IBox::empty(2)));
}

#[test]
fn box_subtract_disjoint_exact() {
    // Subtract a centered box: 4 slabs in 2D, volumes must add up.
    let a = bx(&[(0, 10), (0, 10)]);
    let b = bx(&[(3, 7), (3, 7)]);
    let parts = a.subtract(&b);
    let total: i64 = parts.iter().map(|p| p.volume()).sum();
    assert_eq!(total, 100 - 16);
    // Pairwise disjoint.
    for i in 0..parts.len() {
        for j in (i + 1)..parts.len() {
            assert!(!parts[i].overlaps(&parts[j]), "{} vs {}", parts[i], parts[j]);
        }
    }
    // None overlap b.
    for p in &parts {
        assert!(!p.overlaps(&b));
    }
}

#[test]
fn box_subtract_edge_cases() {
    let a = bx(&[(0, 10)]);
    assert_eq!(a.subtract(&bx(&[(0, 10)])), vec![]);
    assert_eq!(a.subtract(&bx(&[(20, 30)])), vec![a.clone()]);
    let parts = a.subtract(&bx(&[(0, 4)]));
    assert_eq!(parts, vec![bx(&[(4, 10)])]);
}

#[test]
fn region_union_disjointness_and_volume() {
    let mut r = Region::empty(2);
    r.union_box(&bx(&[(0, 4), (0, 4)]));
    r.union_box(&bx(&[(2, 6), (2, 6)])); // overlaps the first
    assert_eq!(r.volume(), 16 + 16 - 4);
    // Adding a covered box changes nothing.
    r.union_box(&bx(&[(1, 3), (1, 3)]));
    assert_eq!(r.volume(), 28);
}

#[test]
fn region_subtract_intersect() {
    let mut r = Region::from_box(bx(&[(0, 10), (0, 10)]));
    r = r.subtract_box(&bx(&[(0, 10), (4, 6)])); // cut a horizontal band
    assert_eq!(r.volume(), 80);
    let i = r.intersect_box(&bx(&[(0, 10), (0, 5)]));
    assert_eq!(i.volume(), 40);
    let j = r.intersect(&Region::from_box(bx(&[(0, 5), (0, 10)])));
    assert_eq!(j.volume(), 40);
}

#[test]
fn region_set_eq_and_contains() {
    // Same set built two different ways.
    let mut a = Region::empty(1);
    a.union_box(&bx(&[(0, 5)]));
    a.union_box(&bx(&[(5, 10)]));
    let b = Region::from_box(bx(&[(0, 10)]));
    assert!(a.set_eq(&b));
    assert!(b.contains_region(&a));
    let c = Region::from_box(bx(&[(0, 11)]));
    assert!(!a.set_eq(&c));
    assert!(c.contains_region(&a));
    assert!(!a.contains_region(&c));
}

#[test]
fn region_coalesce_merges_abutting() {
    let mut a = Region::empty(2);
    for i in 0..8 {
        a.union_box(&bx(&[(i, i + 1), (0, 4)]));
    }
    assert_eq!(a.volume(), 32);
    a.coalesce();
    assert_eq!(a.complexity(), 1);
    assert_eq!(a.volume(), 32);
}

#[test]
fn region_coalesce_many_slabs_canonical() {
    // A long walk's worth of unit slabs in both orders, plus a second row
    // that only becomes mergeable after the slabs fuse: the single-pass
    // retry must reach the same canonical single box as the old
    // restart-from-scratch scan.
    let mut a = Region::empty(2);
    for i in 0..32 {
        a.union_box(&bx(&[(i, i + 1), (0, 4)]));
    }
    for i in (0..32).rev() {
        a.union_box(&bx(&[(i, i + 1), (4, 8)]));
    }
    assert_eq!(a.volume(), 32 * 8);
    a.coalesce();
    assert_eq!(a.complexity(), 1, "must coalesce to one box, got {a}");
    assert_eq!(a.bounding_box(), bx(&[(0, 32), (0, 8)]));
    assert_eq!(a.volume(), 32 * 8);
}

#[test]
fn region_inplace_ops_match_functional() {
    let base = {
        let mut r = Region::empty(2);
        r.union_box(&bx(&[(0, 8), (0, 8)]));
        r.union_box(&bx(&[(8, 12), (2, 6)]));
        r
    };
    let cut = Region::from_box(bx(&[(3, 10), (3, 10)]));

    let functional = base.subtract(&cut);
    let mut inplace = base.clone();
    inplace.subtract_assign(&cut);
    assert!(functional.set_eq(&inplace));
    assert_eq!(functional.volume(), inplace.volume());

    let functional = base.intersect(&cut);
    let mut inplace = base.clone();
    inplace.intersect_assign(&cut);
    assert!(functional.set_eq(&inplace));

    let mut shifted = base.clone();
    shifted.shift_assign(&[5, -2]);
    assert_eq!(shifted.volume(), base.volume());
    assert_eq!(shifted.bounding_box(), bx(&[(5, 17), (-2, 6)]));
}

#[test]
fn region_bounding_box_into_reuses_storage() {
    let mut a = Region::empty(2);
    a.union_box(&bx(&[(0, 2), (0, 2)]));
    a.union_box(&bx(&[(8, 10), (5, 6)]));
    let mut out = IBox::default();
    a.bounding_box_into(&mut out);
    assert_eq!(out, bx(&[(0, 10), (0, 6)]));
    Region::empty(3).bounding_box_into(&mut out);
    assert!(out.is_empty());
    assert_eq!(out.ndim(), 3);
}

#[test]
fn region_bounding_box() {
    let mut a = Region::empty(2);
    a.union_box(&bx(&[(0, 2), (0, 2)]));
    a.union_box(&bx(&[(8, 10), (5, 6)]));
    assert_eq!(a.bounding_box(), bx(&[(0, 10), (0, 6)]));
}

#[test]
fn affine_range_sliding_window() {
    // input index p + r with p in [0,4), r in [0,3): touches [0, 6).
    let e = AffineExpr::sum((0, 1), (1, 1));
    let dom = bx(&[(0, 4), (0, 3)]);
    assert_eq!(e.range_over(&dom), Interval::new(0, 6));
}

#[test]
fn affine_range_strided() {
    // 2p + r with p in [0,4), r in [0,3): [0, 9).
    let e = AffineExpr::sum((0, 2), (1, 1));
    let dom = bx(&[(0, 4), (0, 3)]);
    assert_eq!(e.range_over(&dom), Interval::new(0, 9));
}

#[test]
fn affine_range_negative_coeff() {
    let e = AffineExpr::scaled(0, -1).with_offset(10);
    let dom = bx(&[(2, 5)]);
    // -p + 10 for p in {2,3,4} -> {6,7,8} -> [6,9)
    assert_eq!(e.range_over(&dom), Interval::new(6, 9));
}

#[test]
fn affine_map_image_conv_footprint() {
    // 1D conv input access: [c, p+r] over ranks (m, p, c, r).
    let map = AffineMap::new(vec![AffineExpr::var(2), AffineExpr::sum((1, 1), (3, 1))]);
    let ops = bx(&[(0, 4), (0, 6), (0, 3), (0, 3)]); // m,p,c,r
    let img = map.image_box(&ops);
    assert_eq!(img, bx(&[(0, 3), (0, 8)])); // C=3 channels, H = 6+3-1 = 8
}

#[test]
fn affine_map_preimage_identity() {
    // Output access [m, p] over ranks (m, p, c, r): ops to produce rows [2,4).
    let map = AffineMap::identity(&[0, 1]);
    let full = bx(&[(0, 4), (0, 6), (0, 3), (0, 3)]);
    let data = bx(&[(0, 4), (2, 4)]);
    let ops = map.preimage_identity_box(&data, &full);
    assert_eq!(ops, bx(&[(0, 4), (2, 4), (0, 3), (0, 3)]));
}

#[test]
fn image_of_region_unions() {
    let map = AffineMap::new(vec![AffineExpr::sum((0, 1), (1, 1))]);
    let mut dom = Region::empty(2);
    dom.union_box(&bx(&[(0, 2), (0, 3)]));
    dom.union_box(&bx(&[(10, 12), (0, 3)]));
    let img = map.image(&dom);
    assert_eq!(img.volume(), 4 + 4); // [0,4) and [10,14)
}

#[test]
fn subtract_overlapping_windows_matches_halo() {
    // Consecutive conv input windows with halo 2: tile 4, window = 6 rows.
    // Window(i) = [4i, 4i+6). Fresh part of window 1 = [6, 10) -> 4 rows.
    let w0 = bx(&[(0, 6)]);
    let w1 = bx(&[(4, 10)]);
    let fresh = Region::from_box(w1.clone()).subtract_box(&w0);
    assert_eq!(fresh.volume(), 4);
    assert_eq!(w1.intersect(&w0).volume(), 2);
}
