//! Polyhedral-lite: exact set algebra on rectilinear integer regions.
//!
//! LoopTree's analysis is built on set/relation operations over operation and
//! data tiles (the paper uses ISL [39]). Every Einsum in the paper has
//! per-dimension affine accesses (`p`, `p + r`, `2p + r`) over dense box
//! iteration domains, so all tiles, overlaps, and fresh regions are finite
//! unions of axis-aligned integer boxes. This module implements exact algebra
//! on that domain: intervals, boxes, disjoint unions of boxes ([`Region`]),
//! and affine maps with image/preimage over boxes.
//!
//! All intervals are half-open `[lo, hi)`.

mod interval;
mod ibox;
mod region;
mod affine;

pub use affine::{AffineExpr, AffineMap};
pub use ibox::IBox;
pub use interval::Interval;
pub use region::Region;

#[cfg(test)]
mod tests;
