//! Rectilinear regions: finite disjoint unions of boxes.

use super::{IBox, Interval};

/// A rectilinear region: a finite union of pairwise-disjoint boxes.
///
/// The disjointness invariant is maintained by every constructor and
/// operation, so `volume` is a simple sum. Box count stays small in practice
/// (fresh regions after halo subtraction are unions of a few slabs), but
/// [`Region::coalesce`] merges adjacent boxes to keep representations tight.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Region {
    ndim: usize,
    boxes: Vec<IBox>,
}

impl Region {
    pub fn empty(ndim: usize) -> Self {
        Region { ndim, boxes: vec![] }
    }

    pub fn from_box(b: IBox) -> Self {
        let ndim = b.ndim();
        if b.is_empty() {
            Region::empty(ndim)
        } else {
            Region { ndim, boxes: vec![b] }
        }
    }

    pub fn ndim(&self) -> usize {
        self.ndim
    }

    pub fn is_empty(&self) -> bool {
        self.boxes.is_empty()
    }

    pub fn boxes(&self) -> &[IBox] {
        &self.boxes
    }

    pub fn volume(&self) -> i64 {
        self.boxes.iter().map(|b| b.volume()).sum()
    }

    /// Number of boxes in the representation.
    pub fn complexity(&self) -> usize {
        self.boxes.len()
    }

    /// Add a box, preserving disjointness (the parts of `b` already covered
    /// are not duplicated).
    pub fn union_box(&mut self, b: &IBox) {
        if b.is_empty() {
            return;
        }
        debug_assert_eq!(b.ndim(), self.ndim);
        let mut pieces = vec![b.clone()];
        for existing in &self.boxes {
            if pieces.is_empty() {
                return;
            }
            let mut next = Vec::with_capacity(pieces.len());
            for p in pieces {
                if p.overlaps(existing) {
                    next.extend(p.subtract(existing));
                } else {
                    next.push(p);
                }
            }
            pieces = next;
        }
        self.boxes.extend(pieces);
    }

    pub fn union(&mut self, other: &Region) {
        for b in &other.boxes {
            self.union_box(b);
        }
    }

    pub fn union_of(a: &Region, b: &Region) -> Region {
        let mut r = a.clone();
        r.union(b);
        r
    }

    pub fn intersect_box(&self, b: &IBox) -> Region {
        let boxes: Vec<IBox> = self
            .boxes
            .iter()
            .map(|x| x.intersect(b))
            .filter(|x| !x.is_empty())
            .collect();
        Region { ndim: self.ndim, boxes }
    }

    pub fn intersect(&self, other: &Region) -> Region {
        let mut out = Region::empty(self.ndim);
        // Pieces of disjoint unions intersected pairwise are still disjoint.
        for b in &other.boxes {
            let part = self.intersect_box(b);
            out.boxes.extend(part.boxes);
        }
        out
    }

    pub fn subtract_box(&self, b: &IBox) -> Region {
        if b.is_empty() {
            return self.clone();
        }
        let mut boxes = Vec::with_capacity(self.boxes.len());
        for x in &self.boxes {
            if x.overlaps(b) {
                boxes.extend(x.subtract(b));
            } else {
                boxes.push(x.clone());
            }
        }
        Region { ndim: self.ndim, boxes }
    }

    pub fn subtract(&self, other: &Region) -> Region {
        let mut r = self.clone();
        for b in &other.boxes {
            r = r.subtract_box(b);
        }
        r
    }

    /// `other ⊆ self`.
    pub fn contains_region(&self, other: &Region) -> bool {
        other.subtract(self).is_empty()
    }

    /// Set equality (representation-independent).
    pub fn set_eq(&self, other: &Region) -> bool {
        self.subtract(other).is_empty() && other.subtract(self).is_empty()
    }

    /// Smallest box containing the region (empty box if region is empty).
    pub fn bounding_box(&self) -> IBox {
        let mut it = self.boxes.iter();
        match it.next() {
            None => IBox::empty(self.ndim),
            Some(first) => it.fold(first.clone(), |acc, b| acc.hull(b)),
        }
    }

    /// Merge pairs of adjacent boxes that differ in exactly one dimension and
    /// abut there. Keeps representation size down for long-running unions.
    pub fn coalesce(&mut self) {
        let mut changed = true;
        while changed {
            changed = false;
            'outer: for i in 0..self.boxes.len() {
                for j in (i + 1)..self.boxes.len() {
                    if let Some(merged) = try_merge(&self.boxes[i], &self.boxes[j]) {
                        self.boxes[i] = merged;
                        self.boxes.swap_remove(j);
                        changed = true;
                        break 'outer;
                    }
                }
            }
        }
    }
}

/// Merge two boxes if they are identical in all dimensions but one, where
/// they abut or overlap.
fn try_merge(a: &IBox, b: &IBox) -> Option<IBox> {
    let mut diff_dim = None;
    for d in 0..a.ndim() {
        if a.dims[d] != b.dims[d] {
            if diff_dim.is_some() {
                return None;
            }
            diff_dim = Some(d);
        }
    }
    let d = diff_dim?; // identical boxes can't both be present (disjointness)
    let (x, y) = (a.dims[d], b.dims[d]);
    if x.hi >= y.lo && y.hi >= x.lo {
        let mut merged = a.clone();
        merged.dims[d] = Interval::new(x.lo.min(y.lo), x.hi.max(y.hi));
        Some(merged)
    } else {
        None
    }
}

impl std::fmt::Display for Region {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.boxes.is_empty() {
            return write!(f, "∅");
        }
        for (i, b) in self.boxes.iter().enumerate() {
            if i > 0 {
                write!(f, " ∪ ")?;
            }
            write!(f, "{b}")?;
        }
        Ok(())
    }
}
