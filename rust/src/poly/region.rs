//! Rectilinear regions: finite disjoint unions of boxes.

use super::{IBox, Interval};

/// A rectilinear region: a finite union of pairwise-disjoint boxes.
///
/// The disjointness invariant is maintained by every constructor and
/// operation, so `volume` is a simple sum. Box count stays small in practice
/// (fresh regions after halo subtraction are unions of a few slabs), but
/// [`Region::coalesce`] merges adjacent boxes to keep representations tight.
#[derive(Debug, PartialEq, Eq)]
pub struct Region {
    ndim: usize,
    boxes: Vec<IBox>,
}

/// The empty zero-dimensional region (scratch placeholder; callers
/// overwrite or `reset` it).
impl Default for Region {
    fn default() -> Self {
        Region::empty(0)
    }
}

// Manual `Clone` so `clone_from` reuses the box storage (and each box's
// interval storage) — the model engine snapshots availability regions on
// every schedule level without reallocating.
impl Clone for Region {
    fn clone(&self) -> Self {
        Region { ndim: self.ndim, boxes: self.boxes.clone() }
    }

    fn clone_from(&mut self, source: &Self) {
        self.ndim = source.ndim;
        self.boxes.clone_from(&source.boxes);
    }
}

impl Region {
    /// The empty region in `ndim` dimensions.
    pub fn empty(ndim: usize) -> Self {
        Region { ndim, boxes: vec![] }
    }

    /// Empty this region in place and (re)set its dimensionality, keeping
    /// the box storage for reuse.
    pub fn reset(&mut self, ndim: usize) {
        self.ndim = ndim;
        self.boxes.clear();
    }

    /// Replace the contents with a single box (empty region if the box is
    /// empty), keeping the storage.
    pub fn assign_box(&mut self, b: &IBox) {
        self.ndim = b.ndim();
        self.boxes.clear();
        if !b.is_empty() {
            self.boxes.push(b.clone());
        }
    }

    /// A region consisting of a single box.
    pub fn from_box(b: IBox) -> Self {
        let ndim = b.ndim();
        if b.is_empty() {
            Region::empty(ndim)
        } else {
            Region { ndim, boxes: vec![b] }
        }
    }

    /// Dimensionality of the ambient space.
    pub fn ndim(&self) -> usize {
        self.ndim
    }

    /// Whether the region contains no points.
    pub fn is_empty(&self) -> bool {
        self.boxes.is_empty()
    }

    /// The disjoint boxes making up the region.
    pub fn boxes(&self) -> &[IBox] {
        &self.boxes
    }

    /// Total number of points.
    pub fn volume(&self) -> i64 {
        self.boxes.iter().map(|b| b.volume()).sum()
    }

    /// Number of boxes in the representation.
    pub fn complexity(&self) -> usize {
        self.boxes.len()
    }

    /// Add a box, preserving disjointness (the parts of `b` already covered
    /// are not duplicated).
    pub fn union_box(&mut self, b: &IBox) {
        if b.is_empty() {
            return;
        }
        debug_assert_eq!(b.ndim(), self.ndim);
        let mut pieces = vec![b.clone()];
        for existing in &self.boxes {
            if pieces.is_empty() {
                return;
            }
            let mut next = Vec::with_capacity(pieces.len());
            for p in pieces {
                if p.overlaps(existing) {
                    next.extend(p.subtract(existing));
                } else {
                    next.push(p);
                }
            }
            pieces = next;
        }
        self.boxes.extend(pieces);
    }

    /// Union `other` into `self` in place.
    pub fn union(&mut self, other: &Region) {
        for b in &other.boxes {
            self.union_box(b);
        }
    }

    /// The union of two regions.
    pub fn union_of(a: &Region, b: &Region) -> Region {
        let mut r = a.clone();
        r.union(b);
        r
    }

    /// The intersection with a single box.
    pub fn intersect_box(&self, b: &IBox) -> Region {
        let boxes: Vec<IBox> = self
            .boxes
            .iter()
            .map(|x| x.intersect(b))
            .filter(|x| !x.is_empty())
            .collect();
        Region { ndim: self.ndim, boxes }
    }

    /// The intersection of two regions.
    pub fn intersect(&self, other: &Region) -> Region {
        let mut out = Region::empty(self.ndim);
        // Pieces of disjoint unions intersected pairwise are still disjoint.
        for b in &other.boxes {
            let part = self.intersect_box(b);
            out.boxes.extend(part.boxes);
        }
        out
    }

    /// In-place `self ∩= b`.
    pub fn intersect_box_assign(&mut self, b: &IBox) {
        let mut i = 0;
        while i < self.boxes.len() {
            let x = self.boxes[i].intersect(b);
            if x.is_empty() {
                self.boxes.swap_remove(i);
            } else {
                self.boxes[i] = x;
                i += 1;
            }
        }
    }

    /// In-place `self ∩= other`.
    pub fn intersect_assign(&mut self, other: &Region) {
        if other.boxes.is_empty() {
            self.boxes.clear();
            return;
        }
        if other.boxes.len() == 1 {
            self.intersect_box_assign(&other.boxes[0]);
            return;
        }
        let src = std::mem::take(&mut self.boxes);
        for b in &other.boxes {
            for x in &src {
                let y = x.intersect(b);
                if !y.is_empty() {
                    self.boxes.push(y);
                }
            }
        }
    }

    /// The points of `self` not in box `b`.
    pub fn subtract_box(&self, b: &IBox) -> Region {
        if b.is_empty() {
            return self.clone();
        }
        let mut boxes = Vec::with_capacity(self.boxes.len());
        for x in &self.boxes {
            if x.overlaps(b) {
                boxes.extend(x.subtract(b));
            } else {
                boxes.push(x.clone());
            }
        }
        Region { ndim: self.ndim, boxes }
    }

    /// The points of `self` not in `other`.
    pub fn subtract(&self, other: &Region) -> Region {
        let mut r = self.clone();
        r.subtract_assign(other);
        r
    }

    /// In-place `self −= b`. Overlapping boxes are replaced by their slab
    /// decomposition without rebuilding the box vector.
    pub fn subtract_box_assign(&mut self, b: &IBox) {
        if b.is_empty() || self.boxes.is_empty() {
            return;
        }
        let mut i = 0;
        while i < self.boxes.len() {
            if self.boxes[i].overlaps(b) {
                let x = self.boxes.swap_remove(i);
                // Pieces never overlap `b`, so appending them is final; the
                // box swapped into slot `i` still needs checking.
                x.subtract_into(b, &mut self.boxes);
            } else {
                i += 1;
            }
        }
    }

    /// In-place `self −= other`.
    pub fn subtract_assign(&mut self, other: &Region) {
        for b in &other.boxes {
            if self.boxes.is_empty() {
                return;
            }
            self.subtract_box_assign(b);
        }
    }

    /// Translate in place by a per-dimension offset.
    pub fn shift_assign(&mut self, offsets: &[i64]) {
        for b in &mut self.boxes {
            b.shift_assign(offsets);
        }
    }

    /// `other ⊆ self`.
    pub fn contains_region(&self, other: &Region) -> bool {
        other.subtract(self).is_empty()
    }

    /// Set equality (representation-independent).
    pub fn set_eq(&self, other: &Region) -> bool {
        self.subtract(other).is_empty() && other.subtract(self).is_empty()
    }

    /// Smallest box containing the region (empty box if region is empty).
    pub fn bounding_box(&self) -> IBox {
        let mut out = IBox::empty(self.ndim);
        self.bounding_box_into(&mut out);
        out
    }

    /// Write the smallest box containing the region into `out` without
    /// allocating (when `out` already has capacity for `ndim` intervals).
    pub fn bounding_box_into(&self, out: &mut IBox) {
        out.dims.clear();
        match self.boxes.first() {
            None => out.dims.resize(self.ndim, Interval::empty()),
            Some(first) => {
                out.dims.extend_from_slice(&first.dims);
                for b in &self.boxes[1..] {
                    out.hull_assign(b);
                }
            }
        }
    }

    /// Merge pairs of adjacent boxes that differ in exactly one dimension and
    /// abut there. Keeps representation size down for long-running unions.
    ///
    /// Each pass fixes a pivot box and folds every mergeable partner into it,
    /// retrying only against the freshly merged pivot (not restarting the
    /// whole O(n²) scan per merge); passes repeat until a full pass performs
    /// no merge, so the result is maximal exactly like the old
    /// restart-from-scratch scan, at a fraction of the cost on long walks.
    pub fn coalesce(&mut self) {
        loop {
            let mut changed = false;
            let mut i = 0;
            while i < self.boxes.len() {
                let mut j = i + 1;
                while j < self.boxes.len() {
                    if let Some(merged) = try_merge(&self.boxes[i], &self.boxes[j]) {
                        self.boxes[i] = merged;
                        self.boxes.swap_remove(j);
                        changed = true;
                        // The grown pivot may newly abut boxes already
                        // scanned this pass: retry them against it.
                        j = i + 1;
                    } else {
                        j += 1;
                    }
                }
                i += 1;
            }
            if !changed {
                break;
            }
        }
    }
}

/// Merge two boxes if they are identical in all dimensions but one, where
/// they abut or overlap.
fn try_merge(a: &IBox, b: &IBox) -> Option<IBox> {
    let mut diff_dim = None;
    for d in 0..a.ndim() {
        if a.dims[d] != b.dims[d] {
            if diff_dim.is_some() {
                return None;
            }
            diff_dim = Some(d);
        }
    }
    let d = diff_dim?; // identical boxes can't both be present (disjointness)
    let (x, y) = (a.dims[d], b.dims[d]);
    if x.hi >= y.lo && y.hi >= x.lo {
        let mut merged = a.clone();
        merged.dims[d] = Interval::new(x.lo.min(y.lo), x.hi.max(y.hi));
        Some(merged)
    } else {
        None
    }
}

impl std::fmt::Display for Region {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.boxes.is_empty() {
            return write!(f, "∅");
        }
        for (i, b) in self.boxes.iter().enumerate() {
            if i > 0 {
                write!(f, " ∪ ")?;
            }
            write!(f, "{b}")?;
        }
        Ok(())
    }
}
