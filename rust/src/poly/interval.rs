//! Half-open integer intervals `[lo, hi)`.

/// A half-open integer interval `[lo, hi)`. Empty iff `hi <= lo`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Interval {
    /// Inclusive lower bound.
    pub lo: i64,
    /// Exclusive upper bound.
    pub hi: i64,
}

impl Interval {
    /// `[lo, hi)`.
    pub fn new(lo: i64, hi: i64) -> Self {
        Interval { lo, hi }
    }

    /// The canonical empty interval `[0, 0)`.
    pub fn empty() -> Self {
        Interval { lo: 0, hi: 0 }
    }

    /// `[0, n)`.
    pub fn upto(n: i64) -> Self {
        Interval { lo: 0, hi: n }
    }

    /// Whether the interval contains no integers.
    pub fn is_empty(&self) -> bool {
        self.hi <= self.lo
    }

    /// Number of integers in the interval (0 if empty).
    pub fn len(&self) -> i64 {
        (self.hi - self.lo).max(0)
    }

    /// Whether `x` lies in `[lo, hi)`.
    pub fn contains(&self, x: i64) -> bool {
        self.lo <= x && x < self.hi
    }

    /// `other` is a subset of `self` (empty sets are subsets of everything).
    pub fn contains_interval(&self, other: &Interval) -> bool {
        other.is_empty() || (self.lo <= other.lo && other.hi <= self.hi)
    }

    /// Set intersection; result may be empty.
    pub fn intersect(&self, other: &Interval) -> Interval {
        let i = Interval::new(self.lo.max(other.lo), self.hi.min(other.hi));
        if i.is_empty() {
            Interval::empty()
        } else {
            i
        }
    }

    /// Smallest interval containing both (union hull).
    pub fn hull(&self, other: &Interval) -> Interval {
        if self.is_empty() {
            return *other;
        }
        if other.is_empty() {
            return *self;
        }
        Interval::new(self.lo.min(other.lo), self.hi.max(other.hi))
    }

    /// Translate by `d`.
    pub fn shift(&self, d: i64) -> Interval {
        Interval::new(self.lo + d, self.hi + d)
    }

    /// Do the two intervals intersect?
    pub fn overlaps(&self, other: &Interval) -> bool {
        !self.intersect(other).is_empty()
    }
}

impl std::fmt::Display for Interval {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "[{},{})", self.lo, self.hi)
    }
}
