//! Axis-aligned integer boxes (products of intervals).

use super::Interval;

/// An axis-aligned box: the Cartesian product of one interval per dimension.
/// The box is empty iff any dimension's interval is empty.
#[derive(Debug, PartialEq, Eq, Hash)]
pub struct IBox {
    /// One interval per dimension.
    pub dims: Vec<Interval>,
}

/// The zero-dimensional box (scratch placeholder; callers overwrite it).
impl Default for IBox {
    fn default() -> Self {
        IBox { dims: Vec::new() }
    }
}

// Manual `Clone` so `clone_from` reuses the existing `dims` allocation —
// the model engine copies boxes on every inter-layer iteration.
impl Clone for IBox {
    fn clone(&self) -> Self {
        IBox { dims: self.dims.clone() }
    }

    fn clone_from(&mut self, source: &Self) {
        self.dims.clone_from(&source.dims);
    }
}

impl IBox {
    /// A box from per-dimension intervals.
    pub fn new(dims: Vec<Interval>) -> Self {
        IBox { dims }
    }

    /// A box from `(lo, hi)` pairs.
    pub fn from_bounds(bounds: &[(i64, i64)]) -> Self {
        IBox {
            dims: bounds.iter().map(|&(lo, hi)| Interval::new(lo, hi)).collect(),
        }
    }

    /// The canonical empty box of dimension `ndim`.
    pub fn empty(ndim: usize) -> Self {
        IBox {
            dims: vec![Interval::empty(); ndim],
        }
    }

    /// Dimensionality.
    pub fn ndim(&self) -> usize {
        self.dims.len()
    }

    /// Whether any dimension is empty.
    pub fn is_empty(&self) -> bool {
        self.dims.iter().any(|d| d.is_empty())
    }

    /// Number of integer points in the box.
    pub fn volume(&self) -> i64 {
        if self.is_empty() {
            return 0;
        }
        self.dims.iter().map(|d| d.len()).product()
    }

    /// Pointwise intersection. Empty if disjoint in any dimension.
    pub fn intersect(&self, other: &IBox) -> IBox {
        debug_assert_eq!(self.ndim(), other.ndim());
        let dims: Vec<Interval> = self
            .dims
            .iter()
            .zip(&other.dims)
            .map(|(a, b)| a.intersect(b))
            .collect();
        if dims.iter().any(|d| d.is_empty()) {
            IBox::empty(self.ndim())
        } else {
            IBox { dims }
        }
    }

    /// Whether the two boxes share a point.
    pub fn overlaps(&self, other: &IBox) -> bool {
        !self.intersect(other).is_empty()
    }

    /// `other ⊆ self`.
    pub fn contains_box(&self, other: &IBox) -> bool {
        if other.is_empty() {
            return true;
        }
        self.dims
            .iter()
            .zip(&other.dims)
            .all(|(a, b)| a.contains_interval(b))
    }

    /// Grow `self` in place to the smallest box containing both.
    pub fn hull_assign(&mut self, other: &IBox) {
        if other.is_empty() {
            return;
        }
        if self.is_empty() {
            self.clone_from(other);
            return;
        }
        for (a, b) in self.dims.iter_mut().zip(&other.dims) {
            *a = a.hull(b);
        }
    }

    /// Smallest box containing both.
    pub fn hull(&self, other: &IBox) -> IBox {
        if self.is_empty() {
            return other.clone();
        }
        if other.is_empty() {
            return self.clone();
        }
        IBox {
            dims: self
                .dims
                .iter()
                .zip(&other.dims)
                .map(|(a, b)| a.hull(b))
                .collect(),
        }
    }

    /// Set difference `self − other` as a list of disjoint boxes.
    ///
    /// Standard slab decomposition: walk the dimensions; at each dimension,
    /// peel off the parts of `self` that lie below/above `other`'s extent in
    /// that dimension (each peel is a disjoint box), then narrow `self` to the
    /// overlapping slab and continue. Produces at most `2 * ndim` boxes.
    pub fn subtract(&self, other: &IBox) -> Vec<IBox> {
        let mut out = Vec::new();
        self.subtract_into(other, &mut out);
        out
    }

    /// Set difference `self − other`, appending the disjoint pieces to `out`
    /// (same slab decomposition as [`IBox::subtract`], allocation-free for
    /// the caller).
    pub fn subtract_into(&self, other: &IBox, out: &mut Vec<IBox>) {
        if self.is_empty() {
            return;
        }
        let inter = self.intersect(other);
        if inter.is_empty() {
            out.push(self.clone());
            return;
        }
        if other.contains_box(self) {
            return;
        }
        let mut rest = self.clone();
        for d in 0..self.ndim() {
            let s = rest.dims[d];
            let o = inter.dims[d];
            // Part of `rest` below `other` in dim d.
            if s.lo < o.lo {
                let mut b = rest.clone();
                b.dims[d] = Interval::new(s.lo, o.lo);
                out.push(b);
            }
            // Part of `rest` above `other` in dim d.
            if o.hi < s.hi {
                let mut b = rest.clone();
                b.dims[d] = Interval::new(o.hi, s.hi);
                out.push(b);
            }
            // Narrow to the overlapping slab and continue.
            rest.dims[d] = Interval::new(s.lo.max(o.lo), s.hi.min(o.hi));
        }
    }

    /// Translate by a per-dimension offset.
    pub fn shift(&self, offsets: &[i64]) -> IBox {
        let mut b = self.clone();
        b.shift_assign(offsets);
        b
    }

    /// Translate in place by a per-dimension offset.
    pub fn shift_assign(&mut self, offsets: &[i64]) {
        debug_assert_eq!(self.ndim(), offsets.len());
        for (d, &o) in self.dims.iter_mut().zip(offsets) {
            *d = d.shift(o);
        }
    }
}

impl std::fmt::Display for IBox {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "(")?;
        for (i, d) in self.dims.iter().enumerate() {
            if i > 0 {
                write!(f, "×")?;
            }
            write!(f, "{d}")?;
        }
        write!(f, ")")
    }
}
