//! Per-tile hardware action counts (paper §IV-B, Timeloop-style).
//!
//! For each processed tile we count, per tensor: reads from the GLB by the
//! PE array (after register-level temporal reuse and NoC multicast), NoC
//! hop-words, and register-file traffic. The intra-layer loop order is
//! abstracted to its first-order effects (paper §III-E: intra-layer choices
//! are supported but not the focus):
//!
//! * **register reuse** — an operand word fetched to a PE is reused across
//!   iterations of tile ranks absent from its tensor's projection (we take
//!   the largest such rank extent, capped by the register file capacity);
//! * **multicast** — a GLB read is shared by all PEs spatialized along ranks
//!   absent from the tensor's projection, at the cost of NoC hops.

use crate::arch::Arch;
use crate::einsum::EinsumSpec;
use crate::mapping::IntraLayerMapping;
use crate::poly::Region;

/// Action counts for processing one tile of one layer.
#[derive(Debug, Clone, Default)]
pub struct IntraCounts {
    /// Words read from the GLB by the PE array (operands).
    pub glb_reads: i64,
    /// Words written to the GLB by the PE array (results).
    pub glb_writes: i64,
    /// NoC hop·words for operand distribution.
    pub noc_hop_words: f64,
    /// Register-file reads/writes at the PEs.
    pub rf_reads: i64,
    /// Register-file writes at the PEs.
    pub rf_writes: i64,
}

impl IntraCounts {
    /// Accumulate another tile's counts into this one.
    pub fn add(&mut self, o: &IntraCounts) {
        self.glb_reads += o.glb_reads;
        self.glb_writes += o.glb_writes;
        self.noc_hop_words += o.noc_hop_words;
        self.rf_reads += o.rf_reads;
        self.rf_writes += o.rf_writes;
    }
}

/// Count actions for one layer's op region in one iteration.
///
/// `produced` is the number of output elements this tile writes (post
/// retention subtraction — recomputed elements are written again).
pub fn tile_counts(
    einsum: &EinsumSpec,
    intra: &IntraLayerMapping,
    arch: &Arch,
    ops_region: &Region,
    produced: i64,
) -> IntraCounts {
    tile_counts_from(
        einsum,
        intra,
        arch,
        ops_region.volume(),
        &ops_region.bounding_box(),
        produced,
    )
}

/// Per-input-slot operand movement for one tile: `(pe_words, glb_reads)`
/// from the op count, the ops bounding box, the slot's candidate
/// register-reuse dims (layer dims absent from the operand's projection),
/// and its multicast factor.
///
/// This is **the** definition of the dataflow's operand action counts:
/// [`tile_counts_from`] (and through it the element-level simulator) and
/// the model engine's steady-state fast path (which precomputes
/// `reuse_dims`/`multicast` per session) both call it, so the two analyses
/// cannot silently diverge.
pub(crate) fn operand_slot_counts(
    rf_gt1: bool,
    reuse_dims: &[usize],
    multicast: i64,
    ops: i64,
    bbox: &crate::poly::IBox,
) -> (i64, i64) {
    // Temporal register reuse: largest tile extent among dims absent from
    // the projection (1 if the RF can't hold a word).
    let mut reuse = 1i64;
    if rf_gt1 {
        for &d in reuse_dims {
            reuse = reuse.max(bbox.dims[d].len());
        }
        reuse = reuse.clamp(1, 256);
    }
    let pe_words = ops.div_ceil(reuse); // words arriving at PEs
    let reads = pe_words.div_ceil(multicast); // GLB reads after multicast
    (pe_words, reads)
}

/// Action-count arithmetic from an op count and the op region's bounding
/// box. Shared by the model (symbolic regions) and the simulator (element
/// sets): the *semantics* of the dataflow's action counts are defined once,
/// while each caller derives `ops`/`bbox`/`produced` through its own
/// analysis.
pub fn tile_counts_from(
    einsum: &EinsumSpec,
    intra: &IntraLayerMapping,
    arch: &Arch,
    ops: i64,
    bbox: &crate::poly::IBox,
    produced: i64,
) -> IntraCounts {
    let mut c = IntraCounts::default();
    if ops == 0 {
        return c;
    }
    // Register capacity in words (level 2 if present).
    let rf_words = arch
        .levels
        .get(2)
        .and_then(|l| l.capacity_bytes)
        .map(|b| (b / arch.word_bytes).max(1))
        .unwrap_or(1);

    for acc in &einsum.inputs {
        let proj = acc.map.referenced_dims();
        let reuse_dims: Vec<usize> =
            (0..einsum.ndim()).filter(|d| !proj.contains(d)).collect();
        // Spatial multicast: PEs along spatialized dims absent from the
        // projection receive the same word.
        let mut multicast = 1i64;
        for &(d, f) in &intra.spatial {
            if !proj.contains(&d) {
                multicast *= f;
            }
        }
        let (pe_words, reads) =
            operand_slot_counts(rf_words > 1, &reuse_dims, multicast, ops, bbox);
        c.glb_reads += reads;
        c.noc_hop_words += reads as f64 * arch.noc.multicast_hops(multicast);
        c.rf_writes += pe_words;
        c.rf_reads += ops; // one operand read per op per input tensor
    }
    // Results: partial sums accumulate in the PE register file and are
    // written to the GLB once per produced element.
    c.glb_writes += produced;
    c.rf_reads += ops; // psum read
    c.rf_writes += ops; // psum write
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::einsum::workloads;
    use crate::mapping::IntraLayerMapping;

    #[test]
    fn weight_reuse_reduces_glb_reads() {
        let fs = workloads::conv_conv(28, 16);
        let arch = Arch::generic(256);
        let e = &fs.einsums[0];
        let intra = IntraLayerMapping::default_for(e, arch.noc.num_pes());
        let ops = Region::from_box(e.domain());
        let c = tile_counts(e, &intra, &arch, &ops, e.output.map.image(&ops).volume());
        let total_ops = e.total_ops();
        // Two input tensors but far fewer GLB reads than 2×ops.
        assert!(c.glb_reads < 2 * total_ops, "no reuse modeled");
        assert!(c.glb_reads > 0);
        // Output written once per element.
        assert_eq!(c.glb_writes, 16 * 28 * 28);
    }

    #[test]
    fn empty_region_counts_nothing() {
        let fs = workloads::conv_conv(28, 16);
        let arch = Arch::generic(256);
        let e = &fs.einsums[0];
        let intra = IntraLayerMapping::default_for(e, arch.noc.num_pes());
        let c = tile_counts(e, &intra, &arch, &Region::empty(e.ndim()), 0);
        assert_eq!(c.glb_reads, 0);
        assert_eq!(c.rf_reads, 0);
    }

    #[test]
    fn multicast_counts_hops() {
        let fs = workloads::conv_conv(28, 16);
        let arch = Arch::generic(256);
        let e = &fs.einsums[0];
        // Spatialize M (dim 0): input fmap (projection C,P,Q) is multicast.
        let intra = IntraLayerMapping { spatial: vec![(0, 16)] };
        let ops = Region::from_box(e.domain());
        let c = tile_counts(e, &intra, &arch, &ops, 16 * 28 * 28);
        assert!(c.noc_hop_words > 0.0);
    }
}
