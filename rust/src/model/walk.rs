//! Inter-layer tile iteration: windows and lexicographic walks.
//!
//! The mapping partitions ranks of the last Einsum into a k-level loop nest.
//! [`TileWindows`] turns an iteration index (or index prefix) into the
//! operation-space *window* of the last layer — the box of last-layer
//! operations processed inside that (partial) iteration. [`IterWalk`]
//! enumerates full indices in schedule order, reporting the advancing level
//! (the deepest loop that incremented), which drives retention updates.

use crate::einsum::FusionSet;
use crate::mapping::InterLayerMapping;
use crate::poly::{IBox, Interval};
use crate::util::odometer::odometer_step;

/// Computes last-layer operation windows for iteration prefixes.
#[derive(Debug, Clone)]
pub struct TileWindows {
    /// Full iteration domain of the last Einsum.
    full: IBox,
    /// `(dim, tile)` per schedule level.
    parts: Vec<(usize, i64)>,
    /// Iterations per level.
    counts: Vec<i64>,
}

impl TileWindows {
    /// Tile windows for `mapping`'s partition stack over the last layer of `fs`.
    pub fn new(fs: &FusionSet, mapping: &InterLayerMapping) -> Self {
        let full = fs.last().domain();
        let parts: Vec<(usize, i64)> =
            mapping.partitions.iter().map(|p| (p.dim, p.tile)).collect();
        let counts = mapping.level_counts(fs);
        TileWindows { full, parts, counts }
    }

    /// Number of partitioned schedule levels.
    pub fn num_levels(&self) -> usize {
        self.parts.len()
    }

    /// Child count per level (a ragged last child counts as one).
    pub fn counts(&self) -> &[i64] {
        &self.counts
    }

    /// Product of all level counts: the total number of leaf windows.
    pub fn total_iterations(&self) -> i64 {
        self.counts.iter().product()
    }

    /// The last-layer op window after fixing the first `prefix.len()` levels
    /// at the given indices. Deeper levels stay at their full (parent-window)
    /// extent. A zero-length prefix yields the full domain.
    ///
    /// A repeated rank narrows its own parent window (hierarchical
    /// re-partitioning); the last tile at each level is clipped (ragged
    /// tiles, paper §III-E "imperfect factorization").
    pub fn window(&self, prefix: &[i64]) -> IBox {
        let mut win = IBox::empty(self.full.ndim());
        self.window_into(prefix, &mut win);
        win
    }

    /// [`TileWindows::window`] into a caller-provided box (reuses storage —
    /// the engine computes a window on every inter-layer iteration).
    pub fn window_into(&self, prefix: &[i64], win: &mut IBox) {
        debug_assert!(prefix.len() <= self.parts.len());
        win.clone_from(&self.full);
        for (lvl, &idx) in prefix.iter().enumerate() {
            let (dim, tile) = self.parts[lvl];
            let cur = win.dims[dim];
            let lo = cur.lo + idx * tile;
            let hi = (lo + tile).min(cur.hi);
            debug_assert!(lo < cur.hi, "window index {idx} out of range at level {lvl}");
            win.dims[dim] = Interval::new(lo, hi);
        }
    }
}

/// Lexicographic walk over the k-level iteration space.
///
/// Yields `(index, advancing_level)` where `advancing_level` is the deepest
/// level whose counter incremented to reach this index (`None` for the very
/// first iteration). All levels deeper than the advancing level have reset
/// to zero.
pub struct IterWalk {
    counts: Vec<i64>,
    idx: Vec<i64>,
    started: bool,
    done: bool,
}

impl IterWalk {
    /// An odometer over `counts`, most-significant digit first.
    pub fn new(counts: &[i64]) -> Self {
        IterWalk {
            counts: counts.to_vec(),
            idx: vec![0; counts.len()],
            started: false,
            done: counts.iter().any(|&c| c <= 0),
        }
    }

    /// Streaming advance: yields the next `(index, advancing_level)` without
    /// cloning the index vector. The borrow ends when the caller is done
    /// with the slice, so hot loops walk allocation-free.
    pub fn step(&mut self) -> Option<(&[i64], Option<usize>)> {
        if self.done {
            return None;
        }
        if !self.started {
            self.started = true;
            return Some((&self.idx, None));
        }
        match odometer_step(&mut self.idx, &self.counts) {
            Some(lvl) => Some((&self.idx, Some(lvl))),
            None => {
                self.done = true;
                None
            }
        }
    }
}

impl Iterator for IterWalk {
    type Item = (Vec<i64>, Option<usize>);

    fn next(&mut self) -> Option<Self::Item> {
        self.step().map(|(idx, adv)| (idx.to_vec(), adv))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::einsum::workloads;
    use crate::mapping::{InterLayerMapping, Parallelism, Partition};

    #[test]
    fn walk_order_and_advancing_levels() {
        let w: Vec<_> = IterWalk::new(&[2, 3]).collect();
        let idxs: Vec<Vec<i64>> = w.iter().map(|(i, _)| i.clone()).collect();
        assert_eq!(
            idxs,
            vec![
                vec![0, 0],
                vec![0, 1],
                vec![0, 2],
                vec![1, 0],
                vec![1, 1],
                vec![1, 2]
            ]
        );
        let levels: Vec<Option<usize>> = w.iter().map(|(_, l)| *l).collect();
        assert_eq!(
            levels,
            vec![None, Some(1), Some(1), Some(0), Some(1), Some(1)]
        );
    }

    #[test]
    fn walk_empty_levels_single_iteration() {
        let w: Vec<_> = IterWalk::new(&[]).collect();
        assert_eq!(w.len(), 1);
        assert_eq!(w[0], (vec![], None));
    }

    #[test]
    fn windows_tile_and_clip() {
        let fs = workloads::conv_conv(14, 8); // P2 = Q2 = 12
        let p2 = fs.last().rank_index("P2").unwrap();
        let m = InterLayerMapping::tiled(
            vec![Partition { dim: p2, tile: 5 }],
            Parallelism::Sequential,
        );
        let tw = TileWindows::new(&fs, &m);
        assert_eq!(tw.counts(), &[3]);
        let w0 = tw.window(&[0]);
        let w2 = tw.window(&[2]);
        assert_eq!(w0.dims[p2], crate::poly::Interval::new(0, 5));
        assert_eq!(w2.dims[p2], crate::poly::Interval::new(10, 12)); // ragged
        // Unpartitioned dims stay full.
        assert_eq!(w0.dims[0], crate::poly::Interval::new(0, 8)); // M2
        // Empty prefix = full domain.
        assert_eq!(tw.window(&[]), fs.last().domain());
    }

    #[test]
    fn repartitioned_windows_nest() {
        let fs = workloads::conv_conv(30, 8); // P2 = 28
        let p2 = fs.last().rank_index("P2").unwrap();
        let m = InterLayerMapping::tiled(
            vec![
                Partition { dim: p2, tile: 14 },
                Partition { dim: p2, tile: 5 },
            ],
            Parallelism::Sequential,
        );
        let tw = TileWindows::new(&fs, &m);
        assert_eq!(tw.counts(), &[2, 3]);
        // Second outer window, last inner tile: [14+10, min(14+15, 28)) = [24, 28).
        let w = tw.window(&[1, 2]);
        assert_eq!(w.dims[p2], crate::poly::Interval::new(24, 28));
    }
}
